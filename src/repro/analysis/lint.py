"""Engine-contract linter: AST checks for repo-wide rules (DESIGN.md §12).

The engine's performance and layering contracts are codebase properties, not
plan properties, so they can't live in the plan verifier.  This module lints
``src/`` with Python's ``ast`` — no imports of the linted code — against a
committed allowlist (``tools/lint_allowlist.json``):

* ``sync-call`` — no host-sync calls on the steady-state paths:
  ``jax.device_get`` / ``jax.block_until_ready`` (module or method form),
  ``.item()``, and ``float()`` / ``np.asarray()`` / ``np.array()`` wrapping
  a fresh ``jax``/``jnp`` call result.  The no-sync rule (DESIGN.md §11) is
  what keeps a tick one async dispatch; the allowlist names the few modules
  with *documented* sync points (ingest, checkpoint gather, train-loop
  logging, autotune timing probes, snapshot export).
* ``obs-no-device`` — nothing under ``obs/`` may import ``jax``: telemetry
  must observe the engine without ever touching (and so never syncing)
  device values.
* ``engine-outside-core`` — ``Engine`` construction and ``compile`` /
  ``compile_incremental`` calls on it are ``core/``-internal; everything
  else goes through the session facade (``repro.connect`` → ``Database``),
  which is what lets the deprecation shims eventually be deleted.
* ``random-key`` — no ``jax.random.PRNGKey(<literal>)``: keys must thread
  in from config/args, or parallel runs silently share randomness.

Run via ``tools/lint_contracts.py`` (the CI entry point) or the installed
``repro-lint`` script.  Violations print the rule id, ``file:line:col``,
the message, and the allowlist remedy; the process exits non-zero if any
survive the allowlist.
"""

from __future__ import annotations

import argparse
import ast
import dataclasses
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional, Sequence

RULES = ("sync-call", "obs-no-device", "engine-outside-core", "random-key")

#: documented per-rule remedies, rendered with each violation
_REMEDY = {
    "sync-call": ("hoist the sync off the steady-state path, or add the "
                  "file under \"sync-call\" in tools/lint_allowlist.json "
                  "with a reason documenting the sync point"),
    "obs-no-device": ("keep obs/ device-free (record host scalars the "
                      "caller already has); there is deliberately no "
                      "allowlist story for device work in telemetry"),
    "engine-outside-core": ("use repro.connect(...).views(...) instead of "
                            "constructing Engine directly, or add the file "
                            "under \"engine-outside-core\" in "
                            "tools/lint_allowlist.json with a reason"),
    "random-key": ("thread the key (or seed) in from config/arguments "
                   "instead of a literal PRNGKey, or add the file under "
                   "\"random-key\" in tools/lint_allowlist.json with a "
                   "reason"),
}


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    path: str           # repo-relative posix path
    line: int
    col: int
    message: str

    def render(self) -> str:
        return (f"{self.rule}: {self.path}:{self.line}:{self.col}  "
                f"{self.message}\n    remedy: {_REMEDY[self.rule]}")


def _dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` attribute chains as a dotted string (None otherwise)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _Linter(ast.NodeVisitor):
    def __init__(self, rel: str):
        self.rel = rel
        self.in_obs = "obs" in Path(rel).parts
        self.in_core = "repro/core/" in rel
        self.violations: List[Violation] = []
        # local alias -> canonical dotted module/object name
        self.aliases: Dict[str, str] = {}
        # variables assigned from Engine(...) calls (any scope; linear and
        # flow-insensitive — good enough for a contract lint)
        self.engine_vars: set = set()

    def flag(self, node: ast.AST, rule: str, message: str) -> None:
        self.violations.append(Violation(rule, self.rel, node.lineno,
                                         node.col_offset, message))

    # -- alias tracking ------------------------------------------------------

    def visit_Import(self, node: ast.Import) -> None:
        for a in node.names:
            self.aliases[a.asname or a.name.split(".")[0]] = \
                a.name if a.asname else a.name.split(".")[0]
            if a.asname:
                self.aliases[a.asname] = a.name
        if self.in_obs:
            for a in node.names:
                if a.name == "jax" or a.name.startswith("jax."):
                    self.flag(node, "obs-no-device",
                              f"import {a.name}: obs/ must stay device-free "
                              "(the §11 no-sync telemetry rule)")
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        mod = node.module or ""
        for a in node.names:
            self.aliases[a.asname or a.name] = f"{mod}.{a.name}"
        if self.in_obs and (mod == "jax" or mod.startswith("jax.")):
            self.flag(node, "obs-no-device",
                      f"from {mod} import ...: obs/ must stay device-free "
                      "(the §11 no-sync telemetry rule)")
        self.generic_visit(node)

    def _canon(self, node: ast.AST) -> Optional[str]:
        """Canonical dotted name of an expression through import aliases:
        ``jnp.sum`` -> ``jax.numpy.sum`` under ``import jax.numpy as jnp``."""
        dotted = _dotted(node)
        if dotted is None:
            return None
        head, _, rest = dotted.partition(".")
        head = self.aliases.get(head, head)
        return f"{head}.{rest}" if rest else head

    # -- assignments: track Engine(...) receivers ----------------------------

    def _note_engine_assign(self, targets, value) -> None:
        if not (isinstance(value, ast.Call)
                and self._is_engine_name(value.func)):
            return
        for t in targets:
            if isinstance(t, ast.Name):
                self.engine_vars.add(t.id)

    def visit_Assign(self, node: ast.Assign) -> None:
        self._note_engine_assign(node.targets, node.value)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None:
            self._note_engine_assign([node.target], node.value)
        self.generic_visit(node)

    def _is_engine_name(self, func: ast.AST) -> bool:
        canon = self._canon(func)
        return canon in ("repro.core.Engine", "repro.core.engine.Engine")

    # -- call checks ---------------------------------------------------------

    def _contains_device_call(self, node: ast.AST) -> bool:
        """Whether a subtree calls into jax/jnp — the result is a freshly
        produced traced/device value, so host-converting it is a sync."""
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                canon = self._canon(sub.func) or ""
                if canon == "jax" or canon.startswith(("jax.", "jnp.")):
                    return True
        return False

    def visit_Call(self, node: ast.Call) -> None:
        canon = self._canon(node.func) or ""

        # sync-call: explicit jax host-sync entry points
        if canon in ("jax.device_get", "jax.block_until_ready"):
            self.flag(node, "sync-call",
                      f"{canon.split('.')[-1]} blocks on device→host "
                      "transfer — the steady-state no-sync rule "
                      "(DESIGN.md §11)")
        elif (isinstance(node.func, ast.Attribute)
                and node.func.attr in ("block_until_ready", "item")):
            self.flag(node, "sync-call",
                      f".{node.func.attr}() syncs the array to host — the "
                      "steady-state no-sync rule (DESIGN.md §11)")
        elif ((canon == "float"
               or canon in ("numpy.asarray", "numpy.array"))
              and node.args
              and self._contains_device_call(node.args[0])):
            self.flag(node, "sync-call",
                      f"{canon}(…) over a fresh jax result forces a "
                      "device→host sync — the steady-state no-sync rule "
                      "(DESIGN.md §11)")

        # engine-outside-core: construction + legacy compile entry points
        if not self.in_core:
            if self._is_engine_name(node.func):
                self.flag(node, "engine-outside-core",
                          "Engine(...) constructed outside core/ — the "
                          "session facade (repro.connect → Database) is "
                          "the public compile surface (DESIGN.md §9)")
            elif (isinstance(node.func, ast.Attribute)
                  and node.func.attr in ("compile", "compile_incremental",
                                         "_compile", "_compile_incremental")
                  and (node.func.attr.endswith("compile_incremental")
                       or (isinstance(node.func.value, ast.Name)
                           and node.func.value.id in self.engine_vars))):
                self.flag(node, "engine-outside-core",
                          f".{node.func.attr}(...) on an Engine outside "
                          "core/ — use Database.views(queries"
                          + (", maintain=True" if "incremental"
                             in node.func.attr else "") + ")")

        # random-key: literal PRNGKey seeds
        if canon.endswith("random.PRNGKey"):
            if node.args and isinstance(node.args[0], ast.Constant):
                self.flag(node, "random-key",
                          "PRNGKey with a literal seed — thread keys/seeds "
                          "from config so parallel runs don't share "
                          "randomness")

        self.generic_visit(node)


def lint_source(source: str, rel: str) -> List[Violation]:
    """Lint one module's source text (repo-relative path for reporting)."""
    tree = ast.parse(source, filename=rel)
    linter = _Linter(rel)
    linter.visit(tree)
    return linter.violations


def load_allowlist(path) -> Dict[str, Dict[str, str]]:
    """``{rule: {repo-relative-posix-path: reason}}``; validates shape so a
    malformed allowlist fails loudly instead of silently allowing."""
    with open(path) as f:
        data = json.load(f)
    for rule, entries in data.items():
        if rule not in RULES:
            raise ValueError(f"allowlist names unknown rule {rule!r} "
                             f"(rules: {', '.join(RULES)})")
        for p, reason in entries.items():
            if not isinstance(reason, str) or not reason.strip():
                raise ValueError(f"allowlist entry {rule}/{p} needs a "
                                 "non-empty reason string")
    return data


def lint_paths(paths: Sequence, allowlist: Dict[str, Dict[str, str]],
               root) -> List[Violation]:
    """Lint every ``.py`` file under the given paths; returns the
    violations that survive the allowlist, sorted for stable output."""
    root = Path(root)
    files: List[Path] = []
    for p in paths:
        p = Path(p)
        files.extend(sorted(p.rglob("*.py")) if p.is_dir() else [p])
    out: List[Violation] = []
    for f in files:
        try:
            rel = f.resolve().relative_to(root.resolve()).as_posix()
        except ValueError:
            rel = f.as_posix()
        for v in lint_source(f.read_text(), rel):
            if v.path not in allowlist.get(v.rule, {}):
                out.append(v)
    return sorted(out, key=lambda v: (v.path, v.line, v.col, v.rule))


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Lint engine contracts (DESIGN.md §12) over src/")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--allowlist", default="tools/lint_allowlist.json",
                    help="committed allowlist JSON")
    ap.add_argument("--root", default=".",
                    help="repo root for relative paths (default: cwd)")
    args = ap.parse_args(argv)
    allowlist = (load_allowlist(args.allowlist)
                 if Path(args.allowlist).exists() else {})
    violations = lint_paths(args.paths, allowlist, args.root)
    for v in violations:
        print(v.render())
    if violations:
        print(f"\n{len(violations)} contract violation(s)", file=sys.stderr)
        return 1
    print("engine contracts clean "
          f"({', '.join(RULES)}; allowlist entries: "
          f"{sum(len(v) for v in allowlist.values())})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
