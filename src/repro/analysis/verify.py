"""Plan verifier: execution-free invariant checking over compiled artifacts.

Every layer of the engine hands the next one a typed artifact — pushdown
emits ``ViewDef``s, IR building emits ``GroupProgram``s, the scheduler emits
a ``Schedule`` plus fused ``StepProgram``s, IVM emits ``DeltaProgram``s and
``TickProgram``s, and the data layer emits resident relations.  Each handoff
carries invariants that, until now, were enforced only dynamically (oracle
equivalence tests, 4-device subprocess runs under ``jax.transfer_guard``).
This module re-derives each invariant *structurally* from the schema and the
artifact alone — no tracing, no device work, no JAX import — and raises a
structured :class:`PlanInvariantError` naming the violated rule, so a
malformed plan fails at compile time instead of producing silently wrong
tensors (DESIGN.md §12 catalogs the rules).

Enablement: ``verification_enabled(flag)`` — an explicit ``True``/``False``
(from ``ExecutionConfig.verify_plans`` / ``PlanConfig.verify_plans``) wins;
otherwise the ``REPRO_VERIFY`` env var decides; otherwise verification is on
exactly when running under pytest, so the whole test suite doubles as a
zero-false-positive corpus for the verifier.
"""

from __future__ import annotations

import dataclasses
import math
import os
from typing import Dict, Mapping, Optional, Tuple


def _batched_fixpoint(views):
    # imported lazily: analysis.verify must stay an import leaf (stdlib
    # only at module scope) — core.plan and core.ivm import it while the
    # repro.core package is still initializing
    from repro.core.ir import compute_batched_vids
    return compute_batched_vids(views)

# -- invariant rule ids (DESIGN.md §12 catalog) ------------------------------

GATHER_PREFIX = "gather-prefix"       # gather/rest split + leading-axes rule
SEGMENT_LAYOUT = "segment-layout"     # segment attrs/dims/count vs domains
ACC_SHAPE = "acc-shape"               # accumulator/output geometry
AXIS_FRAME = "axis-frame"             # product axis frames: pulled ++ extra
DTYPE_FLOW = "dtype-flow"             # attr existence/kind + column bindings
SCHEDULE_TOPO = "schedule-topo"       # shared-scan fusion + dependency order
BATCHED_FLAG = "batched-flag"         # param-batch flags vs the fixpoint
DELTA_FIRST_ORDER = "delta-first-order"  # one affected factor per product
WEIGHT_COMPAT = "weight-compat"       # signed ±1 weights only on delta scans
RESIDENT_CAPACITY = "resident-capacity"  # pow2 capacity, n_valid bounds
PSUM_BEFORE_FOLD = "psum-before-fold"    # partitioned scan → psum → fold
ROUTE_SUBSUME = "route-subsume"          # secondary re-aggregation soundness

ALL_INVARIANTS = (
    GATHER_PREFIX, SEGMENT_LAYOUT, ACC_SHAPE, AXIS_FRAME, DTYPE_FLOW,
    SCHEDULE_TOPO, BATCHED_FLAG, DELTA_FIRST_ORDER, WEIGHT_COMPAT,
    RESIDENT_CAPACITY, PSUM_BEFORE_FOLD, ROUTE_SUBSUME,
)


class PlanInvariantError(Exception):
    """A compiled artifact violates a typed engine invariant.

    Attributes: ``invariant`` (rule id from the DESIGN.md §12 catalog),
    ``artifact`` (which plan component), ``detail`` (what broke).
    """

    def __init__(self, invariant: str, artifact: str, detail: str):
        self.invariant = invariant
        self.artifact = artifact
        self.detail = detail
        super().__init__(f"[{invariant}] {artifact}: {detail}")


@dataclasses.dataclass(frozen=True)
class VerificationReport:
    """What one verification pass covered (surfaced by ``explain()``)."""

    artifact: str
    n_checks: int
    invariants: Tuple[str, ...]

    def summary(self) -> str:
        return (f"{self.artifact} ok ({self.n_checks} checks, "
                f"{len(self.invariants)} invariants)")


def verification_enabled(flag: Optional[bool]) -> bool:
    """Resolve a tri-state verify setting: explicit flag > ``REPRO_VERIFY``
    env var > auto-on under pytest."""
    if flag is not None:
        return bool(flag)
    env = os.environ.get("REPRO_VERIFY")
    if env is not None:
        return env.strip().lower() not in ("", "0", "false", "no", "off")
    return "PYTEST_CURRENT_TEST" in os.environ


class _Ctx:
    """Check counter: every invariant evaluation is tallied so reports can
    state coverage, and the first failure raises."""

    def __init__(self):
        self.n_checks = 0
        self.invariants = set()

    def check(self, cond: bool, invariant: str, artifact: str, detail: str):
        self.n_checks += 1
        self.invariants.add(invariant)
        if not cond:
            raise PlanInvariantError(invariant, artifact, detail)

    def report(self, artifact: str) -> VerificationReport:
        return VerificationReport(artifact, self.n_checks,
                                  tuple(sorted(self.invariants)))


# -- scan-program checks (shared by batch plans and delta programs) ----------

def _verify_scan_program(ctx: _Ctx, schema, views: Mapping[int, object],
                         prog, batched: frozenset, where: str) -> None:
    """Invariants of one scan program (``GroupProgram``/``StepProgram``):
    gather specs, per-view geometry, product axis frames, term bindings,
    batched flags — everything the lowering backends index by without
    re-checking."""
    rel = prog.rel
    ctx.check(rel in schema.relations, DTYPE_FLOW, where,
              f"scans unknown relation {rel!r}")
    rel_attrs = schema.relation(rel).attr_set
    gathers: Dict[int, object] = {}
    for gs in prog.gathers:
        art = f"{where}: gather v{gs.vid}"
        ctx.check(gs.vid in views, GATHER_PREFIX, art,
                  "gathers a view the plan never defined")
        child_gb = views[gs.vid].group_by
        exp_gather = tuple(a for a in child_gb if a in rel_attrs)
        exp_rest = tuple(a for a in child_gb if a not in rel_attrs)
        ctx.check(gs.gather == exp_gather, GATHER_PREFIX, art,
                  f"gather attrs {gs.gather} != child group-by ∩ {rel!r} "
                  f"attrs {exp_gather}")
        ctx.check(gs.rest == exp_rest, GATHER_PREFIX, art,
                  f"rest attrs {gs.rest} != child group-by ∖ {rel!r} "
                  f"attrs {exp_rest}")
        ctx.check(child_gb[:len(exp_gather)] == exp_gather, GATHER_PREFIX,
                  art, f"gather attrs {exp_gather} are not the child's "
                  f"leading axes (child group-by {child_gb}) — the backend "
                  "flattens leading axes into one take index")
        ctx.check(gs.batched == (gs.vid in batched), BATCHED_FLAG, art,
                  f"gather marked batched={gs.batched} but the "
                  f"compute_batched_vids fixpoint says {gs.vid in batched}")
        gathers[gs.vid] = gs
    for vp in prog.views:
        _verify_view_program(ctx, schema, views, vp, rel, rel_attrs,
                             batched, gathers, where)


def _verify_view_program(ctx: _Ctx, schema, views, vp, rel, rel_attrs,
                         batched, gathers, where: str) -> None:
    art = f"{where}: view v{vp.vid}"
    ctx.check(vp.vid in views, DTYPE_FLOW, art,
              "computes a view the plan never defined")
    w = views[vp.vid]
    ctx.check(vp.rel == w.rel == rel, SCHEDULE_TOPO, art,
              f"scans {vp.rel!r} inside a {rel!r} step (definition says "
              f"{w.rel!r}) — shared-scan fusion only merges same-relation "
              "views")
    for a in vp.group_by:
        ctx.check(a in schema.attributes, DTYPE_FLOW, art,
                  f"groups by unknown attribute {a!r}")
        ctx.check(schema.attr(a).is_discrete, DTYPE_FLOW, art,
                  f"groups by continuous attribute {a!r} — group-by axes "
                  "need finite domains")
    ctx.check(vp.group_by == w.group_by, ACC_SHAPE, art,
              f"group-by {vp.group_by} != definition {w.group_by}")
    ctx.check(vp.n_aggs == w.n_aggs and len(vp.cols) == vp.n_aggs,
              ACC_SHAPE, art,
              f"column layout {len(vp.cols)}/{vp.n_aggs} != definition "
              f"{w.n_aggs} — parents index child columns by position")
    exp_local = tuple(a for a in vp.group_by if a in rel_attrs)
    exp_pulled = tuple(a for a in vp.group_by if a not in rel_attrs)
    ctx.check(vp.local == exp_local and vp.pulled == exp_pulled,
              SEGMENT_LAYOUT, art,
              f"local/pulled split ({vp.local}, {vp.pulled}) != partition "
              f"of group-by by {rel!r} attrs ({exp_local}, {exp_pulled})")
    if exp_local:
        ctx.check(vp.seg is not None, SEGMENT_LAYOUT, art,
                  "local group-by attrs but no segment spec")
        seg = vp.seg
        ctx.check(seg.attrs == exp_local, SEGMENT_LAYOUT, art,
                  f"segment attrs {seg.attrs} != local group-by {exp_local}")
        dims = tuple(schema.domain(a) for a in seg.attrs)
        ctx.check(seg.dims == dims, SEGMENT_LAYOUT, art,
                  f"segment dims {seg.dims} != attribute domains {dims}")
        n_seg = int(math.prod(dims))
        ctx.check(seg.n_segments == n_seg and seg.n_segments >= 1,
                  SEGMENT_LAYOUT, art,
                  f"segment count {seg.n_segments} != prod{dims} = {n_seg} "
                  "— segment ids could land out of accumulator bounds")
    else:
        ctx.check(vp.seg is None, SEGMENT_LAYOUT, art,
                  "segment spec present without local group-by attrs")
    pulled_dims = tuple(schema.domain(a) for a in exp_pulled)
    ctx.check(vp.pulled_dims == pulled_dims, ACC_SHAPE, art,
              f"pulled dims {vp.pulled_dims} != domains {pulled_dims}")
    exp_acc = (((vp.seg.n_segments,) if vp.seg is not None else ())
               + pulled_dims + (vp.n_aggs,))
    ctx.check(vp.acc_shape == exp_acc, ACC_SHAPE, art,
              f"accumulator shape {vp.acc_shape} != {exp_acc}")
    exp_out = tuple(schema.domain(a) for a in exp_local) + pulled_dims
    ctx.check(vp.out_dims == exp_out, ACC_SHAPE, art,
              f"output dims {vp.out_dims} != {exp_out}")
    computed = list(exp_local) + list(exp_pulled)
    exp_perm = tuple(computed.index(a) for a in vp.group_by) + (len(computed),)
    ctx.check(vp.out_perm == exp_perm, ACC_SHAPE, art,
              f"output permutation {vp.out_perm} != {exp_perm} — parents "
              "would gather transposed axes")
    ctx.check(vp.batched == (vp.vid in batched), BATCHED_FLAG, art,
              f"batched={vp.batched} but the fixpoint says "
              f"{vp.vid in batched}")
    for ci, col in enumerate(vp.cols):
        for pi, prod in enumerate(col.products):
            part = f"{art} col {ci} product {pi}"
            used = set()
            any_batched = False
            for ref in prod.child_refs:
                ctx.check(ref.vid in gathers, GATHER_PREFIX, part,
                          f"references child v{ref.vid} with no gather spec "
                          "in its scan step")
                ctx.check(ref.rest == gathers[ref.vid].rest, AXIS_FRAME,
                          part, f"child rest axes {ref.rest} != gathered "
                          f"rest {gathers[ref.vid].rest}")
                ctx.check(ref.vid in views
                          and 0 <= ref.col < views[ref.vid].n_aggs,
                          DTYPE_FLOW, part,
                          f"child column {ref.col} out of range for "
                          f"v{ref.vid}")
                ctx.check(ref.batched == (ref.vid in batched), BATCHED_FLAG,
                          part, f"child ref batched={ref.batched} but the "
                          f"fixpoint says {ref.vid in batched}")
                any_batched |= ref.batched
                used |= set(ref.rest)
            for ta in prod.local_terms:
                attrs = ta.term.attrs()
                exp_col = tuple(sorted(a for a in attrs if a in rel_attrs))
                exp_dom = tuple(sorted(a for a in attrs
                                       if a not in rel_attrs))
                ctx.check(ta.col_attrs == exp_col, DTYPE_FLOW, part,
                          f"term column bindings {ta.col_attrs} != the "
                          f"term's {rel!r} attrs {exp_col} — the lowering "
                          "would feed the term the wrong scanned columns")
                ctx.check(ta.dom_attrs == exp_dom, DTYPE_FLOW, part,
                          f"term domain attrs {ta.dom_attrs} != non-{rel!r} "
                          f"attrs {exp_dom}")
                for a in exp_dom:
                    ctx.check(a in schema.attributes
                              and schema.attr(a).is_discrete, DTYPE_FLOW,
                              part, f"domain-iota attribute {a!r} is not "
                              "discrete")
                exp_dd = tuple(schema.domain(a) for a in ta.dom_attrs)
                ctx.check(ta.dom_dims == exp_dd, DTYPE_FLOW, part,
                          f"domain dims {ta.dom_dims} != {exp_dd}")
                ctx.check(ta.batched == ta.term.is_batched(), BATCHED_FLAG,
                          part, f"term marked batched={ta.batched} but "
                          f"is_batched()={ta.term.is_batched()}")
                any_batched |= ta.batched
                used |= set(ta.dom_attrs)
            exp_axes = vp.pulled + tuple(sorted(used - set(vp.pulled)))
            ctx.check(prod.axes == exp_axes, AXIS_FRAME, part,
                      f"axis frame {prod.axes} != pulled ++ extra "
                      f"{exp_axes}")
            ctx.check(prod.n_keep == len(vp.pulled), AXIS_FRAME, part,
                      f"keeps {prod.n_keep} leading axes but the pulled "
                      f"frame has {len(vp.pulled)} — sum-out would drop or "
                      "keep the wrong axes")
            for a in prod.axes:
                ctx.check(a in schema.attributes
                          and schema.attr(a).is_discrete, DTYPE_FLOW, part,
                          f"axis attribute {a!r} is not discrete")
            exp_ad = tuple(schema.domain(a) for a in prod.axes)
            ctx.check(prod.axis_dims == exp_ad, AXIS_FRAME, part,
                      f"axis dims {prod.axis_dims} != domains {exp_ad}")
            ctx.check(prod.batched == any_batched, BATCHED_FLAG, part,
                      f"product batched={prod.batched} but its factors say "
                      f"{any_batched}")
    if vp.hist is not None:
        h = vp.hist
        ah = f"{art} hist"
        ctx.check(len(vp.local) == 1 and not vp.pulled and vp.n_aggs == 3,
                  DTYPE_FLOW, ah,
                  "tree-hist pattern requires exactly "
                  "[Σcond, Σcond·y, Σcond·y²] grouped by one local "
                  "attribute")
        ctx.check(h.code_attr == vp.local[0], DTYPE_FLOW, ah,
                  f"bucket attribute {h.code_attr!r} != local group-by "
                  f"{vp.local[0]!r}")
        ctx.check(h.n_buckets == schema.domain(h.code_attr), SEGMENT_LAYOUT,
                  ah, f"bucket count {h.n_buckets} != domain of "
                  f"{h.code_attr!r} ({schema.domain(h.code_attr)})")
        ctx.check(h.y_attr in rel_attrs, DTYPE_FLOW, ah,
                  f"moment attribute {h.y_attr!r} is not scanned by {rel!r}")


# -- public entry points -----------------------------------------------------

def verify_plan(plan) -> VerificationReport:
    """Verify a compiled batch plan end to end: every ``GroupProgram``,
    the shared-scan ``Schedule``, and the fused per-step ``StepProgram``s
    the backends actually execute."""
    ctx = _Ctx()
    schema, views = plan.schema, plan.views
    batched = _batched_fixpoint(views)
    for gid in sorted(plan.programs):
        _verify_scan_program(ctx, schema, views, plan.programs[gid],
                             batched, f"group {gid}")
    _verify_schedule(ctx, plan.schedule, plan.groups)
    sched = plan.schedule
    ctx.check(len(plan.step_programs) == len(sched.steps), SCHEDULE_TOPO,
              "schedule", f"{len(plan.step_programs)} fused step programs "
              f"for {len(sched.steps)} scan steps")
    for step, sp in zip(sched.steps, plan.step_programs):
        art = f"step {step.sid} ({step.rel})"
        ctx.check(sp.rel == step.rel, SCHEDULE_TOPO, art,
                  f"fused program scans {sp.rel!r}")
        ctx.check(tuple(sp.gids) == tuple(step.gids), SCHEDULE_TOPO, art,
                  f"fused program covers groups {sp.gids} != step's "
                  f"{step.gids}")
        ctx.check(tuple(vp.vid for vp in sp.views) == tuple(step.vids),
                  SCHEDULE_TOPO, art,
                  "fused program's view order diverges from the step's vids")
        _verify_scan_program(ctx, schema, views, sp, batched, art)
    return ctx.report("plan")


def _verify_schedule(ctx: _Ctx, sched, groups) -> None:
    by_gid = {g.gid: g for g in groups}
    step_gids = sorted(g for s in sched.steps for g in s.gids)
    ctx.check(step_gids == sorted(by_gid), SCHEDULE_TOPO, "schedule",
              "scan steps do not partition the view groups (a group is "
              "missing or scanned twice)")
    ctx.check([s.sid for s in sched.steps] == list(range(len(sched.steps))),
              SCHEDULE_TOPO, "schedule", "step ids are not dense execution "
              "order")
    sid_of = {g: s.sid for s in sched.steps for g in s.gids}
    for s in sched.steps:
        art = f"step {s.sid} ({s.rel})"
        for g in s.gids:
            ctx.check(by_gid[g].rel == s.rel, SCHEDULE_TOPO, art,
                      f"fuses group {g} which scans {by_gid[g].rel!r} — "
                      "shared scans must share the relation")
        exp_vids = tuple(v for g in s.gids for v in by_gid[g].vids)
        ctx.check(tuple(s.vids) == exp_vids, SCHEDULE_TOPO, art,
                  f"step vids {s.vids} != concatenated group vids "
                  f"{exp_vids}")
        for d in s.deps:
            ctx.check(0 <= d < s.sid, SCHEDULE_TOPO, art,
                      f"depends on step {d}, which does not execute "
                      "earlier")
            ctx.check(sched.steps[d].level < s.level, SCHEDULE_TOPO, art,
                      f"level {s.level} not above dependency step {d}'s "
                      f"level {sched.steps[d].level}")
        for g in s.gids:
            for dep_g in by_gid[g].deps:
                ctx.check(sid_of[dep_g] < s.sid, SCHEDULE_TOPO, art,
                          f"group {g} needs group {dep_g}, scheduled at "
                          f"step {sid_of[dep_g]} — child views would be "
                          "gathered before they exist")
                ctx.check(sid_of[dep_g] in s.deps, SCHEDULE_TOPO, art,
                          f"group dependency {dep_g} (step "
                          f"{sid_of[dep_g]}) missing from step deps "
                          f"{s.deps}")


def verify_delta_program(plan, dp) -> VerificationReport:
    """Verify one relation's maintenance plan: every delta step's scan
    program, first-order soundness (exactly one affected factor per kept
    product, none on tier-1 scans), step ordering over the affected
    sub-DAG, and the weight/state contracts the tick runners rely on."""
    ctx = _Ctx()
    schema, views = plan.schema, plan.views
    batched = _batched_fixpoint(views)
    art = f"Δ{dp.rel}"
    ctx.check(dp.rel in schema.relations, DTYPE_FLOW, art,
              f"maintains unknown relation {dp.rel!r}")
    if not dp.steps:
        ctx.check(not dp.affected, DELTA_FIRST_ORDER, art,
                  f"views {sorted(dp.affected)} are affected but no step "
                  "maintains them")
        return ctx.report(art)
    produced = set()
    out_vids = []
    for i, st in enumerate(dp.steps):
        sart = f"{art} step {i} ({st.rel})"
        ctx.check(st.scans_delta == (st.rel == dp.rel), WEIGHT_COMPAT, sart,
                  f"scans_delta={st.scans_delta} but the step scans "
                  f"{st.rel!r} and the update targets {dp.rel!r} — signed "
                  "±1 multiplicities are only sound on the update's own "
                  "delta tuples")
        _verify_scan_program(ctx, schema, views, st.prog, batched, sart)
        for gs in st.prog.gathers:
            if gs.vid in dp.affected:
                ctx.check(not st.scans_delta, DELTA_FIRST_ORDER, sart,
                          f"tier-1 delta scan gathers affected child "
                          f"v{gs.vid} — a second-order term (join-tree "
                          "subtrees below the update relation are disjoint "
                          "from it)")
                ctx.check(gs.vid in produced, DELTA_FIRST_ORDER, sart,
                          f"gathers affected child v{gs.vid} before its "
                          "delta is computed — it would read stale state")
        for vp in st.prog.views:
            ctx.check(vp.vid in dp.affected, DELTA_FIRST_ORDER, sart,
                      f"computes v{vp.vid}, which the update does not "
                      "affect")
            out_vids.append(vp.vid)
            if not st.scans_delta:
                for ci, col in enumerate(vp.cols):
                    for pi, prod in enumerate(col.products):
                        hits = [r.vid for r in prod.child_refs
                                if r.vid in dp.affected]
                        ctx.check(len(hits) == 1, DELTA_FIRST_ORDER,
                                  f"{sart}: v{vp.vid} col {ci} product "
                                  f"{pi}",
                                  f"{len(hits)} {dp.rel}-dependent child "
                                  "factors (affected children "
                                  f"{hits or '[]'}) — first-order "
                                  "Δ(product) needs exactly one")
        produced.update(vp.vid for vp in st.prog.views)
    ctx.check(sorted(out_vids) == sorted(dp.affected), DELTA_FIRST_ORDER,
              art, f"steps compute {sorted(out_vids)} but the affected set "
              f"is {sorted(dp.affected)} (each exactly once)")
    exp_base = tuple(sorted({s.rel for s in dp.steps if not s.scans_delta}))
    ctx.check(tuple(dp.base_rels) == exp_base, DELTA_FIRST_ORDER, art,
              f"base_rels {dp.base_rels} != rescanned relations {exp_base}")
    gathered = {gs.vid for s in dp.steps for gs in s.prog.gathers}
    ctx.check(set(dp.state_vids) >= (set(dp.affected) | gathered),
              DELTA_FIRST_ORDER, art,
              f"state inputs {sorted(dp.state_vids)} miss affected or "
              "gathered views — the fold would read undefined arrays")
    return ctx.report(art)


def verify_tick_program(tp, dp) -> VerificationReport:
    """Verify a tick program against its delta program: weights applied
    exactly on the delta-tuple scan, and — the sharding soundness rule —
    every step that scans partitioned rows psums *all* of its view deltas
    before any later gather or the state fold (DESIGN.md §8)."""
    ctx = _Ctx()
    where = (f"tick Δ{tp.rel}" if tp.shard_rel is None
             else f"tick Δ{tp.rel} (shard {tp.shard_rel}@{tp.axis})")
    ctx.check(tp.rel == dp.rel, PSUM_BEFORE_FOLD, where,
              f"tick targets {tp.rel!r} but the delta program maintains "
              f"{dp.rel!r}")
    ctx.check((tp.shard_rel is None) == (tp.axis is None), PSUM_BEFORE_FOLD,
              where, "partitioned relation and mesh axis must be set "
              "together")
    ctx.check(len(tp.steps) == len(dp.steps), PSUM_BEFORE_FOLD, where,
              f"{len(tp.steps)} tick steps for {len(dp.steps)} delta steps")
    for i, (ts, st) in enumerate(zip(tp.steps, dp.steps)):
        sart = f"{where} step {i} ({ts.rel})"
        ctx.check(ts.prog is st.prog and ts.rel == st.rel
                  and ts.scans_delta == st.scans_delta, PSUM_BEFORE_FOLD,
                  sart, "tick step diverges from its delta step")
        ctx.check(ts.weighted == ts.scans_delta, WEIGHT_COMPAT, sart,
                  f"weighted={ts.weighted} on a "
                  f"{'delta' if ts.scans_delta else 'base-rescan'} step — "
                  "signed ±1 update weights must be folded into the "
                  "validity mask exactly on the delta-tuple scan")
        partitioned = tp.shard_rel is not None and ts.rel == tp.shard_rel
        ctx.check(ts.partitioned == partitioned, PSUM_BEFORE_FOLD, sart,
                  f"partitioned={ts.partitioned} but the step scans "
                  f"{ts.rel!r} and the sharded relation is "
                  f"{tp.shard_rel!r}")
        step_vids = tuple(vp.vid for vp in ts.prog.views)
        if partitioned:
            ctx.check(tuple(ts.psum_vids) == step_vids, PSUM_BEFORE_FOLD,
                      sart, f"psums {tuple(ts.psum_vids)} != the step's "
                      f"views {step_vids} — a later gather or the state "
                      "fold would read partial per-shard deltas and the "
                      "published epoch would stop being replicated")
        else:
            ctx.check(not ts.psum_vids, PSUM_BEFORE_FOLD, sart,
                      "psum after a replicated-row scan would multiply its "
                      "delta by the device count")
    ctx.check(tuple(tp.fold_vids) == tuple(sorted(dp.affected)),
              PSUM_BEFORE_FOLD, where,
              f"state fold covers {tuple(tp.fold_vids)} != affected views "
              f"{tuple(sorted(dp.affected))}")
    return ctx.report(where)


def verify_secondary_program(sp) -> VerificationReport:
    """Verify a serving-router secondary program (``core/subsume.py``):
    the closed-form re-aggregation answering a routed query from a wider
    materialized view.  The admission gate for tier-1/tier-2 routed
    answers — purely structural, like every rule here: group-by
    derivability (partition refinement), agg-column render equality,
    domain agreement on shared dims, and the sum/permute geometry the
    lowered function indexes by."""
    ctx = _Ctx()
    src, tgt = sp.source, sp.target
    art = f"route {src.name!r} -> {tgt.name!r}"
    ctx.check(len(src.dims) == len(src.domains), ROUTE_SUBSUME, art,
              f"source dims {src.dims} vs domains {src.domains} ragged")
    ctx.check(len(tgt.dims) == len(tgt.domains), ROUTE_SUBSUME, art,
              f"target dims {tgt.dims} vs domains {tgt.domains} ragged")
    keep = set(tgt.dims)
    ctx.check(keep <= set(src.dims), ROUTE_SUBSUME, art,
              f"target group-by {sorted(keep - set(src.dims))} not in the "
              "source view — coarser groupings only (partition refinement)")
    src_dom = dict(zip(src.dims, src.domains))
    for d, n in zip(tgt.dims, tgt.domains):
        ctx.check(src_dom.get(d) == n, ROUTE_SUBSUME, art,
                  f"dim {d!r} domain {n} != source's {src_dom.get(d)} — "
                  "the answer tensor would be mis-shaped")
    ctx.check(len(sp.col_idx) == len(tgt.aggs), ROUTE_SUBSUME, art,
              f"{len(sp.col_idx)} column picks for {len(tgt.aggs)} target "
              "aggregates")
    for j, i in enumerate(sp.col_idx):
        ctx.check(0 <= i < len(src.aggs), ROUTE_SUBSUME, art,
                  f"target column {j} gathers source column {i}, outside "
                  f"[0, {len(src.aggs)})")
        ctx.check(src.aggs[i] == tgt.aggs[j], ROUTE_SUBSUME, art,
                  f"target column {j} ({tgt.aggs[j]!r}) gathers source "
                  f"column {i} ({src.aggs[i]!r}) — summing a different "
                  "aggregate would serve wrong answers")
    exp_sum = tuple(i for i, d in enumerate(src.dims) if d not in keep)
    ctx.check(tuple(sp.sum_axes) == exp_sum, ROUTE_SUBSUME, art,
              f"sum axes {tuple(sp.sum_axes)} != the source axes not in "
              f"the target group-by {exp_sum}")
    kept = [d for d in src.dims if d in keep]
    ctx.check(sorted(sp.perm) == list(range(len(kept))), ROUTE_SUBSUME, art,
              f"{sp.perm} is not a permutation of the {len(kept)} kept "
              "axes")
    got = tuple(kept[p] for p in sp.perm) if sorted(sp.perm) == \
        list(range(len(kept))) else ()
    ctx.check(got == tuple(tgt.dims), ROUTE_SUBSUME, art,
              f"permutation yields axis order {got} != target group-by "
              f"{tuple(tgt.dims)}")
    return ctx.report(art)


def verify_resident(rr) -> VerificationReport:
    """Verify a resident relation's capacity contract: pow2 capacity,
    uniform column buffers, ``0 ≤ n_valid ≤ capacity``, and (sharded) the
    per-shard row bounds and global-id geometry.  Metadata-only — never
    touches device values."""
    ctx = _Ctx()
    sharded = hasattr(rr, "gids")
    art = f"{'sharded ' if sharded else ''}resident {rr.name!r}"
    lens = {a: int(c.shape[0]) for a, c in rr.buffers.items()}
    ctx.check(len(rr.buffers) > 0, RESIDENT_CAPACITY, art,
              "no column buffers")
    ctx.check(len(set(lens.values())) == 1, RESIDENT_CAPACITY, art,
              f"ragged column buffers {lens}")
    cap = rr.capacity
    ctx.check(cap >= 1 and (cap & (cap - 1)) == 0, RESIDENT_CAPACITY, art,
              f"capacity {cap} is not a power of two — growth doubling and "
              "pad-bucket runner caches assume pow2")
    if sharded:
        ndev = rr.n_devices
        total = cap * ndev
        ctx.check(next(iter(lens.values())) == total, RESIDENT_CAPACITY,
                  art, f"buffer length {next(iter(lens.values()))} != "
                  f"{ndev} shards × capacity {cap}")
        ctx.check(int(rr.gids.shape[0]) == total, RESIDENT_CAPACITY, art,
                  f"global-id column length {int(rr.gids.shape[0])} != "
                  f"{total}")
        ctx.check(0 <= rr.n_valid <= total, RESIDENT_CAPACITY, art,
                  f"n_valid {rr.n_valid} outside [0, {total}]")
        ub = rr.n_valid_ub
        ctx.check(tuple(ub.shape) == (ndev,), RESIDENT_CAPACITY, art,
                  f"per-shard row bound shape {tuple(ub.shape)} != "
                  f"({ndev},)")
        ctx.check(int(ub.min()) >= 0 and int(ub.max()) <= cap,
                  RESIDENT_CAPACITY, art,
                  f"per-shard row bounds {ub.tolist()} escape "
                  f"[0, {cap}] — an insert would scatter past a shard's "
                  "buffer")
        ctx.check(rr.n_valid <= int(ub.sum()), RESIDENT_CAPACITY, art,
                  f"exact count {rr.n_valid} exceeds the per-shard upper "
                  f"bounds Σ{ub.tolist()}")
        ctx.check(tuple(rr.n_valid_dev.shape) == (ndev,),
                  RESIDENT_CAPACITY, art,
                  f"device counter shape {tuple(rr.n_valid_dev.shape)} != "
                  f"({ndev},)")
    else:
        ctx.check(0 <= rr.n_valid <= cap, RESIDENT_CAPACITY, art,
                  f"n_valid {rr.n_valid} outside [0, {cap}]")
        ctx.check(tuple(rr.n_valid_dev.shape) == (), RESIDENT_CAPACITY,
                  art, "device row counter is not a scalar")
    import numpy as _np
    ctx.check(_np.issubdtype(_np.dtype(rr.n_valid_dev.dtype), _np.integer),
              RESIDENT_CAPACITY, art,
              f"device row counter dtype {rr.n_valid_dev.dtype} is not "
              "integral")
    return ctx.report(art)
