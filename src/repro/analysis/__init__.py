"""Static analysis over compiled artifacts and the codebase (DESIGN.md §12).

Two halves:

* :mod:`repro.analysis.verify` — an execution-free plan verifier that walks
  every compiled artifact (group-program IR, shared-scan schedule, delta
  programs, tick programs, resident relations) and raises structured
  :class:`~repro.analysis.verify.PlanInvariantError`\\ s before anything runs.
* :mod:`repro.analysis.lint` — an AST-based engine-contract linter over
  ``src/`` (host-sync calls, device work in ``obs/``, ``Engine`` outside
  ``core/``, unthreaded PRNG keys) with a committed allowlist; the CLI
  wrapper lives at ``tools/lint_contracts.py``.
"""

from repro.analysis.verify import (PlanInvariantError, VerificationReport,
                                   verification_enabled, verify_delta_program,
                                   verify_plan, verify_resident,
                                   verify_secondary_program,
                                   verify_tick_program)

__all__ = [
    "PlanInvariantError", "VerificationReport", "verification_enabled",
    "verify_delta_program", "verify_plan", "verify_resident",
    "verify_secondary_program", "verify_tick_program",
]
