"""Columnar relation storage.

Relations are dictionaries of same-length 1-D JAX arrays: int32 codes for
key/categorical attributes, float32 for continuous ones.  This is the
TPU-native analogue of LMFAO's sorted in-memory arrays of structs.

Updates: :meth:`Relation.append` / :meth:`Relation.delete_rows` produce new
relations (columns are immutable arrays), and :class:`DeltaBatchUpdate`
bundles per-relation insert/delete batches — the unit consumed by the IVM
subsystem (``core/ivm.py``) and by :func:`apply_delta`, which applies an
update to a plain :class:`Database` (the from-scratch oracle the maintained
path is tested against).

:class:`ResidentRelation` is the device-resident representation the IVM
subsystem stores between ticks: capacity-padded (power-of-two) column
buffers plus a dynamic valid-row count — the same static-shape-plus-validity
scheme the scan backends use for row blocks — so appends and deletes are
on-device scatter/compaction ops and a steady-state maintenance tick never
round-trips relation columns through host numpy.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import schema as sch


def next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def check_update_columns(dbs: sch.DatabaseSchema, rel_name: str,
                         columns: Mapping[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Validate + cast an insert batch for ``rel_name`` (dtype/domain checks
    mirroring :meth:`Relation.validate`); returns engine-dtype *host numpy*
    columns — callers decide when the batch crosses to the device (the IVM
    tick pads on the host first, then does one explicit ``device_put``)."""
    rs = dbs.relation(rel_name)
    if set(columns) != set(rs.attrs):
        raise ValueError(
            f"update for {rel_name!r}: columns {sorted(columns)} != schema {sorted(rs.attrs)}")
    n = int(np.asarray(next(iter(columns.values()))).shape[0])
    out: Dict[str, np.ndarray] = {}
    for a in rs.attrs:
        col = np.asarray(columns[a])
        if col.shape != (n,):
            raise ValueError(
                f"update for {rel_name!r}: column {a!r} shape {col.shape} != ({n},)")
        attr = dbs.attr(a)
        if attr.is_discrete:
            if not np.issubdtype(col.dtype, np.integer):
                raise ValueError(
                    f"{rel_name}.{a}: discrete update column must be integer, got {col.dtype}")
            codes = col.astype(np.int32)
            if codes.size and (codes.min() < 0 or codes.max() >= attr.domain):
                raise ValueError(
                    f"{rel_name}.{a}: update codes outside [0, {attr.domain}) "
                    f"(min {codes.min()}, max {codes.max()})")
            out[a] = codes
        else:
            if not np.issubdtype(col.dtype, np.floating):
                raise ValueError(
                    f"{rel_name}.{a}: continuous update column must be float, got {col.dtype}")
            out[a] = col.astype(np.float32)
    return out


def check_delete_idx(rel_name: str, idx: np.ndarray, n_rows: int) -> np.ndarray:
    """Validate a positional delete batch: unique integer indices in
    ``[0, n_rows)`` (shared by :meth:`Relation.delete_rows`,
    :meth:`DeltaBatchUpdate.validate`, and the IVM apply path)."""
    idx = np.asarray(idx)
    if idx.size == 0:
        return idx.reshape(0).astype(np.int64)
    if not np.issubdtype(idx.dtype, np.integer):
        raise ValueError(f"delete from {rel_name!r}: indices must be integer, got {idx.dtype}")
    if idx.min() < 0 or idx.max() >= n_rows:
        raise ValueError(
            f"delete from {rel_name!r}: indices outside [0, {n_rows}) "
            f"(min {idx.min()}, max {idx.max()})")
    if len(np.unique(idx)) != len(idx):
        raise ValueError(f"delete from {rel_name!r}: duplicate row indices")
    return idx


@dataclasses.dataclass
class Relation:
    name: str
    columns: Dict[str, jnp.ndarray]

    @property
    def n_rows(self) -> int:
        return int(next(iter(self.columns.values())).shape[0])

    def column(self, attr: str) -> jnp.ndarray:
        return self.columns[attr]

    def validate(self, dbs: sch.DatabaseSchema) -> None:
        rs = dbs.relation(self.name)
        if set(self.columns) != set(rs.attrs):
            raise ValueError(
                f"relation {self.name!r}: columns {sorted(self.columns)} != schema {sorted(rs.attrs)}")
        n = self.n_rows
        for a, col in self.columns.items():
            if col.shape != (n,):
                raise ValueError(f"relation {self.name!r}: column {a!r} shape {col.shape} != ({n},)")
            attr = dbs.attr(a)
            if attr.is_discrete:
                if not jnp.issubdtype(col.dtype, jnp.integer):
                    raise ValueError(f"{self.name}.{a}: discrete column must be integer, got {col.dtype}")
            else:
                if not jnp.issubdtype(col.dtype, jnp.floating):
                    raise ValueError(f"{self.name}.{a}: continuous column must be float, got {col.dtype}")

    def append(self, columns: Mapping[str, np.ndarray],
               dbs: Optional[sch.DatabaseSchema] = None) -> "Relation":
        """New relation with ``columns`` rows appended.  With a schema the
        batch is validated and cast (:func:`check_update_columns`).  Without
        one, appending to a discrete (integer) column is an error: the
        attribute's code domain is unreachable, so out-of-range codes could
        not be bounds-checked here and would be *silently dropped* by the
        downstream ``segment_sum`` — corrupting aggregates instead of
        failing loudly.  Schema-less appends therefore only accept
        all-continuous relations (names/lengths/dtype kinds still checked)."""
        if dbs is not None:
            cast = check_update_columns(dbs, self.name, columns)
        else:
            if set(columns) != set(self.columns):
                raise ValueError(
                    f"append to {self.name!r}: columns {sorted(columns)} != {sorted(self.columns)}")
            n = int(np.asarray(next(iter(columns.values()))).shape[0])
            cast = {}
            for a, cur in self.columns.items():
                col = np.asarray(columns[a])
                if col.shape != (n,):
                    raise ValueError(
                        f"append to {self.name!r}: column {a!r} shape {col.shape} != ({n},)")
                if jnp.issubdtype(cur.dtype, jnp.integer) != np.issubdtype(col.dtype, np.integer):
                    raise ValueError(
                        f"append to {self.name}.{a}: dtype kind {col.dtype} != {cur.dtype}")
                if jnp.issubdtype(cur.dtype, jnp.integer):
                    raise ValueError(
                        f"append to {self.name}.{a}: discrete column codes cannot "
                        "be bounds-checked without a schema (out-of-range codes "
                        "would silently corrupt aggregates); pass dbs=")
                cast[a] = col.astype(cur.dtype)
        return Relation(self.name, {a: jnp.concatenate([c, jnp.asarray(cast[a])])
                                    for a, c in self.columns.items()})

    def delete_rows(self, idx: np.ndarray) -> "Relation":
        """New relation with the rows at positions ``idx`` removed.  Indices
        must be unique and in ``[0, n_rows)`` — deletes are positional, so a
        duplicate would silently delete fewer tuples than the delta scan
        subtracts."""
        idx = check_delete_idx(self.name, idx, self.n_rows)
        if idx.size == 0:
            return Relation(self.name, dict(self.columns))
        keep = np.ones(self.n_rows, dtype=bool)
        keep[idx] = False
        return Relation(self.name, {a: jnp.asarray(np.asarray(c)[keep])
                                    for a, c in self.columns.items()})


@dataclasses.dataclass
class Database:
    schema: sch.DatabaseSchema
    relations: Dict[str, Relation]

    def validate(self) -> None:
        for r in self.relations.values():
            r.validate(self.schema)
        if set(self.relations) != set(self.schema.relations):
            raise ValueError("database relations do not match schema relations")

    def relation(self, name: str) -> Relation:
        return self.relations[name]

    def sizes(self) -> Dict[str, int]:
        return {n: r.n_rows for n, r in self.relations.items()}

    def total_tuples(self) -> int:
        return sum(self.sizes().values())


def from_numpy(dbs: sch.DatabaseSchema, tables: Mapping[str, Mapping[str, np.ndarray]]) -> Database:
    """Build a Database from host numpy columns, casting to engine dtypes."""
    rels = {}
    for name, cols in tables.items():
        rs = dbs.relation(name)
        jcols = {}
        for a in rs.attrs:
            col = np.asarray(cols[a])
            attr = dbs.attr(a)
            if attr.is_discrete:
                codes = col.astype(np.int32)
                if codes.size and (codes.min() < 0 or codes.max() >= attr.domain):
                    raise ValueError(
                        f"{name}.{a}: codes outside [0, {attr.domain}) "
                        f"(min {codes.min()}, max {codes.max()})")
                jcols[a] = jnp.asarray(codes)
            else:
                jcols[a] = jnp.asarray(col.astype(np.float32))
        rels[name] = Relation(name, jcols)
    db = Database(dbs, rels)
    db.validate()
    return db


def sort_by(rel: Relation, attrs: list) -> Relation:
    """Sort a relation by the given attribute order (LMFAO's trie order)."""
    keys = [np.asarray(rel.columns[a]) for a in reversed(attrs)]
    order = np.lexsort(keys)
    return Relation(rel.name, {a: jnp.asarray(np.asarray(c)[order]) for a, c in rel.columns.items()})


# ------------------------------------------------------- device residency

#: traces of the resident-advance program (steady-state ticks must not grow
#: this; `benchmarks/bench_ivm.py` and tests read it as a retrace counter)
_ADVANCE_TRACES = 0


def advance_trace_count() -> int:
    return _ADVANCE_TRACES


@functools.partial(jax.jit, static_argnames=("compact",))
def _resident_advance(buffers, n_valid, ins, del_idx, n_ins, n_del, *,
                      compact: bool):
    """Device-side relation tick: delete ``del_idx`` rows (order-preserving
    compaction of the valid prefix), then append ``ins`` at the new end.

    Shapes are static — ``buffers`` are capacity-length, ``ins`` columns and
    ``del_idx`` are pow2-padded (pads: arbitrary rows / the capacity
    sentinel) — while ``n_valid``/``n_ins``/``n_del`` are traced scalars, so
    a steady-state stream of varying batch sizes reuses one executable per
    (capacity, pad-bucket) and never retraces or touches the host."""
    global _ADVANCE_TRACES
    _ADVANCE_TRACES += 1
    cap = next(iter(buffers.values())).shape[0]
    if compact:
        rows = jnp.arange(cap, dtype=jnp.int32)
        deleted = jnp.zeros((cap,), bool).at[del_idx].set(True, mode="drop")
        # stable argsort floats kept-valid rows to the front in original
        # order — the same sequential semantics as the host oracle's
        # boolean-mask delete (apply_delta)
        order = jnp.argsort(deleted | (rows >= n_valid))
        buffers = {a: c[order] for a, c in buffers.items()}
    n_after = n_valid - n_del
    out = {}
    for a, col in buffers.items():
        ia = ins.get(a)
        if ia is not None and ia.shape[0]:
            pos = n_after + jnp.arange(ia.shape[0], dtype=jnp.int32)
            # pad rows land past the valid region (garbage zone) or drop OOB
            col = col.at[pos].set(ia.astype(col.dtype), mode="drop")
        out[a] = col
    return out, n_after + n_ins


@dataclasses.dataclass(frozen=True)
class ResidentRelation:
    """A relation pinned on device: power-of-two *capacity* column buffers
    plus a valid-row count carried twice — ``n_valid`` as a host mirror
    (drives capacity/retrace bookkeeping without device syncs) and
    ``n_valid_dev`` as a device scalar (flows into jitted scans as a traced
    validity bound, mirroring the scan blocks' ``n_valid`` machinery).

    Rows ``[0, n_valid)`` are live and ordered exactly like the equivalent
    host :class:`Relation`; rows beyond are garbage hidden by validity
    masks.  All update ops are functional — buffers are never mutated, so a
    published epoch's relations stay readable while the next tick builds."""

    name: str
    buffers: Dict[str, jnp.ndarray]
    n_valid: int
    n_valid_dev: jnp.ndarray

    @property
    def capacity(self) -> int:
        return int(next(iter(self.buffers.values())).shape[0])

    @classmethod
    def from_relation(cls, rel: Relation, min_capacity: int = 1) -> "ResidentRelation":
        n = rel.n_rows
        cap = next_pow2(max(n, min_capacity, 1))
        bufs = {a: jnp.pad(c, (0, cap - n)) if cap > n else c
                for a, c in rel.columns.items()}
        return cls(rel.name, bufs, n,
                   jax.device_put(np.asarray(n, np.int32)))

    def to_relation(self) -> Relation:
        """Trimmed plain relation (a lazy device slice — host transfer only
        happens if the caller materializes the columns)."""
        return Relation(self.name, {a: c[:self.n_valid]
                                    for a, c in self.buffers.items()})

    def grown(self, min_rows: int) -> "ResidentRelation":
        """Same relation with capacity >= ``min_rows`` (pow2 doubling, so a
        growing stream re-keys downstream executables only log2 times)."""
        cap = next_pow2(max(min_rows, 1))
        if cap <= self.capacity:
            return self
        bufs = {a: jnp.pad(c, (0, cap - self.capacity))
                for a, c in self.buffers.items()}
        return ResidentRelation(self.name, bufs, self.n_valid, self.n_valid_dev)

    def advance(self, ins: Optional[Mapping[str, jnp.ndarray]],
                del_idx: Optional[jnp.ndarray],
                n_ins: int, n_del: int) -> "ResidentRelation":
        """Functional update: delete then append, all on device.  ``ins``
        columns and ``del_idx`` must already be pow2-padded device arrays
        (see ``core/ivm.py``'s prepare step); ``n_ins``/``n_del`` are the
        true counts (host ints — they update the host mirror and enter the
        device program through ``device_put``, an explicit transfer)."""
        grown = self.grown(self.n_valid - n_del + n_ins)
        bufs, n_valid_dev = _resident_advance(
            grown.buffers, grown.n_valid_dev, dict(ins or {}),
            del_idx if del_idx is not None else jnp.zeros((0,), jnp.int32),
            jax.device_put(np.asarray(n_ins, np.int32)),
            jax.device_put(np.asarray(n_del, np.int32)),
            compact=bool(n_del))
        return ResidentRelation(self.name, bufs,
                                self.n_valid - n_del + n_ins, n_valid_dev)


@dataclasses.dataclass(frozen=True)
class ShardedResidentRelation:
    """A resident relation partitioned row-wise over one mesh axis: every
    column is a ``(n_devices * capacity,)`` buffer sharded ``P(axis)``, so
    each device owns a ``capacity``-row (power-of-two, uniform) shard with
    its *own* valid prefix — ``n_valid_dev`` is a per-shard ``(n_devices,)``
    counter vector sharded the same way.  Compaction and append stay local
    to a shard (DESIGN.md §8): there is no global row order on device.

    The oracle's row order survives through ``gids``: an int32 buffer
    holding, per live row, its position in the equivalent single-device
    :class:`Relation` (deletes renumber survivors on device, appends take
    fresh trailing positions round-robin across shards).  Positional delete
    batches route to their owning shard by matching ``gids`` — no host-side
    placement map, so a steady-state tick stays free of host transfers.

    Host mirrors: ``n_valid`` is the *exact* total row count (pure host
    arithmetic, like the single-device mirror); ``n_valid_ub`` is a
    per-shard **upper bound** (inserts are counted, local deletes are not —
    their shard is data-dependent).  Capacity growth checks run against the
    bound and call :meth:`synced` (one explicit ``device_get`` of the
    ``(n_devices,)`` counters — metadata, never relation columns) only when
    the bound would overflow, so steady-state ticks never sync."""

    name: str
    buffers: Dict[str, jnp.ndarray]     # (ndev * cap,) each, P(axis)
    gids: jnp.ndarray                   # (ndev * cap,) int32, P(axis)
    n_valid: int                        # exact total live rows (host)
    n_valid_ub: np.ndarray              # (ndev,) per-shard upper bound (host)
    n_valid_dev: jnp.ndarray            # (ndev,) int32, P(axis)
    mesh: object                        # jax.sharding.Mesh
    axis: str

    @property
    def n_devices(self) -> int:
        return int(self.mesh.shape[self.axis])

    @property
    def capacity(self) -> int:
        """Per-shard capacity (uniform across shards)."""
        return int(next(iter(self.buffers.values())).shape[0]) // self.n_devices

    def _sharding(self):
        from jax.sharding import NamedSharding, PartitionSpec
        return NamedSharding(self.mesh, PartitionSpec(self.axis))

    @classmethod
    def from_relation(cls, rel: Relation, mesh, axis: str,
                      min_capacity: int = 1) -> "ShardedResidentRelation":
        """Contiguous row split: shard ``s`` takes global rows
        ``[s*rps, (s+1)*rps)`` (``rps = ceil(n/ndev)``) with gids equal to
        the global row indices — any split works, gids carry the order."""
        from jax.sharding import NamedSharding, PartitionSpec
        ndev = int(mesh.shape[axis])
        sh = NamedSharding(mesh, PartitionSpec(axis))
        n = rel.n_rows
        rps = -(-n // ndev) if n else 0
        cap = next_pow2(max(rps, min_capacity, 1))

        def lay(col):
            col = np.asarray(col)
            out = np.zeros((ndev * cap,), col.dtype)
            for s in range(ndev):
                lo, hi = s * rps, min((s + 1) * rps, n)
                if hi > lo:
                    out[s * cap:s * cap + hi - lo] = col[lo:hi]
            return jax.device_put(out, sh)

        nv = np.asarray([max(0, min(n - s * rps, rps)) for s in range(ndev)],
                        np.int64)
        return cls(rel.name, {a: lay(c) for a, c in rel.columns.items()},
                   lay(np.arange(n, dtype=np.int32)), n, nv,
                   jax.device_put(nv.astype(np.int32), sh), mesh, axis)

    def to_relation(self) -> Relation:
        """Gather every shard's valid prefix to host **once** and restore
        the oracle row order by sorting on gids.  Host numpy columns — this
        is the checkpoint/oracle exit, never the tick path."""
        ndev, cap = self.n_devices, self.capacity
        bufs, gids, nv = jax.device_get((dict(self.buffers), self.gids,
                                         self.n_valid_dev))
        keep = np.zeros((ndev * cap,), bool)
        for s in range(ndev):
            keep[s * cap:s * cap + int(nv[s])] = True
        order = np.argsort(np.asarray(gids)[keep], kind="stable")
        return Relation(self.name, {a: np.asarray(c)[keep][order]
                                    for a, c in bufs.items()})

    def synced(self) -> "ShardedResidentRelation":
        """Refresh the per-shard upper bound to the exact device counters
        (one explicit transfer of ``(n_devices,)`` int32 — metadata only)."""
        nv = np.asarray(jax.device_get(self.n_valid_dev), np.int64)
        return dataclasses.replace(self, n_valid_ub=nv)

    def grown(self, min_rows_per_shard: int) -> "ShardedResidentRelation":
        """Uniform per-shard capacity >= ``min_rows_per_shard`` (pow2
        doubling; every shard grows together so buffers stay uniform)."""
        cap = next_pow2(max(min_rows_per_shard, 1))
        old = self.capacity
        if cap <= old:
            return self
        ndev, sh = self.n_devices, self._sharding()

        def pad(buf):
            x = jnp.pad(buf.reshape(ndev, old), ((0, 0), (0, cap - old)))
            return jax.device_put(x.reshape(ndev * cap), sh)

        return dataclasses.replace(
            self, buffers={a: pad(c) for a, c in self.buffers.items()},
            gids=pad(self.gids))


# --------------------------------------------------------------------- deltas

@dataclasses.dataclass
class RelationDelta:
    """One relation's update batch: ``inserts`` are new rows (full column
    dict), ``delete_idx`` are positional row indices into the relation *as it
    was when the update was created*.  Either may be empty/None."""

    inserts: Optional[Mapping[str, np.ndarray]] = None
    delete_idx: Optional[np.ndarray] = None

    @property
    def n_inserts(self) -> int:
        if not self.inserts:
            return 0
        return int(np.asarray(next(iter(self.inserts.values()))).shape[0])

    @property
    def n_deletes(self) -> int:
        return 0 if self.delete_idx is None else int(np.asarray(self.delete_idx).shape[0])

    @property
    def n_rows(self) -> int:
        return self.n_inserts + self.n_deletes


@dataclasses.dataclass
class DeltaBatchUpdate:
    """A multi-relation update batch (the IVM unit of work): relation name →
    :class:`RelationDelta`.  Relations are applied in sorted name order; the
    post-update database equals applying every per-relation delta
    sequentially, which is also how ``core/ivm.py`` maintains view state."""

    updates: Dict[str, RelationDelta] = dataclasses.field(default_factory=dict)

    def insert(self, rel: str, columns: Mapping[str, np.ndarray]) -> "DeltaBatchUpdate":
        d = self.updates.setdefault(rel, RelationDelta())
        if d.inserts is not None:
            raise ValueError(f"update already has inserts for {rel!r}")
        d.inserts = columns
        return self

    def delete(self, rel: str, idx: np.ndarray) -> "DeltaBatchUpdate":
        d = self.updates.setdefault(rel, RelationDelta())
        if d.delete_idx is not None:
            raise ValueError(f"update already has deletes for {rel!r}")
        d.delete_idx = np.asarray(idx)
        return self

    def relations(self):
        """Updated relation names in application order (sorted, non-empty)."""
        return [r for r in sorted(self.updates) if self.updates[r].n_rows > 0]

    def validate(self, db: "Database") -> None:
        for name, d in self.updates.items():
            if name not in db.relations:
                raise ValueError(f"update targets unknown relation {name!r}")
            if d.inserts is not None:
                check_update_columns(db.schema, name, d.inserts)
            if d.delete_idx is not None:
                check_delete_idx(name, d.delete_idx, db.relation(name).n_rows)


def apply_delta(db: Database, update: DeltaBatchUpdate) -> Database:
    """Apply an update batch to a plain database (deletes first, then
    inserts, per relation in sorted order) — the from-scratch semantics the
    maintained path in ``core/ivm.py`` must agree with."""
    update.validate(db)
    rels = dict(db.relations)
    for name in update.relations():
        d = update.updates[name]
        r = rels[name]
        if d.n_deletes:
            r = r.delete_rows(np.asarray(d.delete_idx))
        if d.n_inserts:
            r = r.append(d.inserts, db.schema)
        rels[name] = r
    return Database(db.schema, rels)
