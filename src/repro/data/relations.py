"""Columnar relation storage.

Relations are dictionaries of same-length 1-D JAX arrays: int32 codes for
key/categorical attributes, float32 for continuous ones.  This is the
TPU-native analogue of LMFAO's sorted in-memory arrays of structs.

Updates: :meth:`Relation.append` / :meth:`Relation.delete_rows` produce new
relations (columns are immutable arrays), and :class:`DeltaBatchUpdate`
bundles per-relation insert/delete batches — the unit consumed by the IVM
subsystem (``core/ivm.py``) and by :func:`apply_delta`, which applies an
update to a plain :class:`Database` (the from-scratch oracle the maintained
path is tested against).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import schema as sch


def check_update_columns(dbs: sch.DatabaseSchema, rel_name: str,
                         columns: Mapping[str, np.ndarray]) -> Dict[str, jnp.ndarray]:
    """Validate + cast an insert batch for ``rel_name`` (dtype/domain checks
    mirroring :meth:`Relation.validate`); returns engine-dtype jnp columns."""
    rs = dbs.relation(rel_name)
    if set(columns) != set(rs.attrs):
        raise ValueError(
            f"update for {rel_name!r}: columns {sorted(columns)} != schema {sorted(rs.attrs)}")
    n = int(np.asarray(next(iter(columns.values()))).shape[0])
    out: Dict[str, jnp.ndarray] = {}
    for a in rs.attrs:
        col = np.asarray(columns[a])
        if col.shape != (n,):
            raise ValueError(
                f"update for {rel_name!r}: column {a!r} shape {col.shape} != ({n},)")
        attr = dbs.attr(a)
        if attr.is_discrete:
            if not np.issubdtype(col.dtype, np.integer):
                raise ValueError(
                    f"{rel_name}.{a}: discrete update column must be integer, got {col.dtype}")
            codes = col.astype(np.int32)
            if codes.size and (codes.min() < 0 or codes.max() >= attr.domain):
                raise ValueError(
                    f"{rel_name}.{a}: update codes outside [0, {attr.domain}) "
                    f"(min {codes.min()}, max {codes.max()})")
            out[a] = jnp.asarray(codes)
        else:
            if not np.issubdtype(col.dtype, np.floating):
                raise ValueError(
                    f"{rel_name}.{a}: continuous update column must be float, got {col.dtype}")
            out[a] = jnp.asarray(col.astype(np.float32))
    return out


def check_delete_idx(rel_name: str, idx: np.ndarray, n_rows: int) -> np.ndarray:
    """Validate a positional delete batch: unique integer indices in
    ``[0, n_rows)`` (shared by :meth:`Relation.delete_rows`,
    :meth:`DeltaBatchUpdate.validate`, and the IVM apply path)."""
    idx = np.asarray(idx)
    if idx.size == 0:
        return idx.reshape(0).astype(np.int64)
    if not np.issubdtype(idx.dtype, np.integer):
        raise ValueError(f"delete from {rel_name!r}: indices must be integer, got {idx.dtype}")
    if idx.min() < 0 or idx.max() >= n_rows:
        raise ValueError(
            f"delete from {rel_name!r}: indices outside [0, {n_rows}) "
            f"(min {idx.min()}, max {idx.max()})")
    if len(np.unique(idx)) != len(idx):
        raise ValueError(f"delete from {rel_name!r}: duplicate row indices")
    return idx


@dataclasses.dataclass
class Relation:
    name: str
    columns: Dict[str, jnp.ndarray]

    @property
    def n_rows(self) -> int:
        return int(next(iter(self.columns.values())).shape[0])

    def column(self, attr: str) -> jnp.ndarray:
        return self.columns[attr]

    def validate(self, dbs: sch.DatabaseSchema) -> None:
        rs = dbs.relation(self.name)
        if set(self.columns) != set(rs.attrs):
            raise ValueError(
                f"relation {self.name!r}: columns {sorted(self.columns)} != schema {sorted(rs.attrs)}")
        n = self.n_rows
        for a, col in self.columns.items():
            if col.shape != (n,):
                raise ValueError(f"relation {self.name!r}: column {a!r} shape {col.shape} != ({n},)")
            attr = dbs.attr(a)
            if attr.is_discrete:
                if not jnp.issubdtype(col.dtype, jnp.integer):
                    raise ValueError(f"{self.name}.{a}: discrete column must be integer, got {col.dtype}")
            else:
                if not jnp.issubdtype(col.dtype, jnp.floating):
                    raise ValueError(f"{self.name}.{a}: continuous column must be float, got {col.dtype}")

    def append(self, columns: Mapping[str, np.ndarray],
               dbs: Optional[sch.DatabaseSchema] = None) -> "Relation":
        """New relation with ``columns`` rows appended.  With a schema the
        batch is validated and cast (:func:`check_update_columns`); without
        one only column names/lengths/dtype kinds are checked."""
        if dbs is not None:
            cast = check_update_columns(dbs, self.name, columns)
        else:
            if set(columns) != set(self.columns):
                raise ValueError(
                    f"append to {self.name!r}: columns {sorted(columns)} != {sorted(self.columns)}")
            n = int(np.asarray(next(iter(columns.values()))).shape[0])
            cast = {}
            for a, cur in self.columns.items():
                col = jnp.asarray(np.asarray(columns[a]))
                if col.shape != (n,):
                    raise ValueError(
                        f"append to {self.name!r}: column {a!r} shape {col.shape} != ({n},)")
                if jnp.issubdtype(cur.dtype, jnp.integer) != jnp.issubdtype(col.dtype, jnp.integer):
                    raise ValueError(
                        f"append to {self.name}.{a}: dtype kind {col.dtype} != {cur.dtype}")
                cast[a] = col.astype(cur.dtype)
        return Relation(self.name, {a: jnp.concatenate([c, cast[a]])
                                    for a, c in self.columns.items()})

    def delete_rows(self, idx: np.ndarray) -> "Relation":
        """New relation with the rows at positions ``idx`` removed.  Indices
        must be unique and in ``[0, n_rows)`` — deletes are positional, so a
        duplicate would silently delete fewer tuples than the delta scan
        subtracts."""
        idx = check_delete_idx(self.name, idx, self.n_rows)
        if idx.size == 0:
            return Relation(self.name, dict(self.columns))
        keep = np.ones(self.n_rows, dtype=bool)
        keep[idx] = False
        return Relation(self.name, {a: jnp.asarray(np.asarray(c)[keep])
                                    for a, c in self.columns.items()})


@dataclasses.dataclass
class Database:
    schema: sch.DatabaseSchema
    relations: Dict[str, Relation]

    def validate(self) -> None:
        for r in self.relations.values():
            r.validate(self.schema)
        if set(self.relations) != set(self.schema.relations):
            raise ValueError("database relations do not match schema relations")

    def relation(self, name: str) -> Relation:
        return self.relations[name]

    def sizes(self) -> Dict[str, int]:
        return {n: r.n_rows for n, r in self.relations.items()}

    def total_tuples(self) -> int:
        return sum(self.sizes().values())


def from_numpy(dbs: sch.DatabaseSchema, tables: Mapping[str, Mapping[str, np.ndarray]]) -> Database:
    """Build a Database from host numpy columns, casting to engine dtypes."""
    rels = {}
    for name, cols in tables.items():
        rs = dbs.relation(name)
        jcols = {}
        for a in rs.attrs:
            col = np.asarray(cols[a])
            attr = dbs.attr(a)
            if attr.is_discrete:
                codes = col.astype(np.int32)
                if codes.size and (codes.min() < 0 or codes.max() >= attr.domain):
                    raise ValueError(
                        f"{name}.{a}: codes outside [0, {attr.domain}) "
                        f"(min {codes.min()}, max {codes.max()})")
                jcols[a] = jnp.asarray(codes)
            else:
                jcols[a] = jnp.asarray(col.astype(np.float32))
        rels[name] = Relation(name, jcols)
    db = Database(dbs, rels)
    db.validate()
    return db


def sort_by(rel: Relation, attrs: list) -> Relation:
    """Sort a relation by the given attribute order (LMFAO's trie order)."""
    keys = [np.asarray(rel.columns[a]) for a in reversed(attrs)]
    order = np.lexsort(keys)
    return Relation(rel.name, {a: jnp.asarray(np.asarray(c)[order]) for a, c in rel.columns.items()})


# --------------------------------------------------------------------- deltas

@dataclasses.dataclass
class RelationDelta:
    """One relation's update batch: ``inserts`` are new rows (full column
    dict), ``delete_idx`` are positional row indices into the relation *as it
    was when the update was created*.  Either may be empty/None."""

    inserts: Optional[Mapping[str, np.ndarray]] = None
    delete_idx: Optional[np.ndarray] = None

    @property
    def n_inserts(self) -> int:
        if not self.inserts:
            return 0
        return int(np.asarray(next(iter(self.inserts.values()))).shape[0])

    @property
    def n_deletes(self) -> int:
        return 0 if self.delete_idx is None else int(np.asarray(self.delete_idx).shape[0])

    @property
    def n_rows(self) -> int:
        return self.n_inserts + self.n_deletes


@dataclasses.dataclass
class DeltaBatchUpdate:
    """A multi-relation update batch (the IVM unit of work): relation name →
    :class:`RelationDelta`.  Relations are applied in sorted name order; the
    post-update database equals applying every per-relation delta
    sequentially, which is also how ``core/ivm.py`` maintains view state."""

    updates: Dict[str, RelationDelta] = dataclasses.field(default_factory=dict)

    def insert(self, rel: str, columns: Mapping[str, np.ndarray]) -> "DeltaBatchUpdate":
        d = self.updates.setdefault(rel, RelationDelta())
        if d.inserts is not None:
            raise ValueError(f"update already has inserts for {rel!r}")
        d.inserts = columns
        return self

    def delete(self, rel: str, idx: np.ndarray) -> "DeltaBatchUpdate":
        d = self.updates.setdefault(rel, RelationDelta())
        if d.delete_idx is not None:
            raise ValueError(f"update already has deletes for {rel!r}")
        d.delete_idx = np.asarray(idx)
        return self

    def relations(self):
        """Updated relation names in application order (sorted, non-empty)."""
        return [r for r in sorted(self.updates) if self.updates[r].n_rows > 0]

    def validate(self, db: "Database") -> None:
        for name, d in self.updates.items():
            if name not in db.relations:
                raise ValueError(f"update targets unknown relation {name!r}")
            if d.inserts is not None:
                check_update_columns(db.schema, name, d.inserts)
            if d.delete_idx is not None:
                check_delete_idx(name, d.delete_idx, db.relation(name).n_rows)


def apply_delta(db: Database, update: DeltaBatchUpdate) -> Database:
    """Apply an update batch to a plain database (deletes first, then
    inserts, per relation in sorted order) — the from-scratch semantics the
    maintained path in ``core/ivm.py`` must agree with."""
    update.validate(db)
    rels = dict(db.relations)
    for name in update.relations():
        d = update.updates[name]
        r = rels[name]
        if d.n_deletes:
            r = r.delete_rows(np.asarray(d.delete_idx))
        if d.n_inserts:
            r = r.append(d.inserts, db.schema)
        rels[name] = r
    return Database(db.schema, rels)
