"""Columnar relation storage.

Relations are dictionaries of same-length 1-D JAX arrays: int32 codes for
key/categorical attributes, float32 for continuous ones.  This is the
TPU-native analogue of LMFAO's sorted in-memory arrays of structs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import schema as sch


@dataclasses.dataclass
class Relation:
    name: str
    columns: Dict[str, jnp.ndarray]

    @property
    def n_rows(self) -> int:
        return int(next(iter(self.columns.values())).shape[0])

    def column(self, attr: str) -> jnp.ndarray:
        return self.columns[attr]

    def validate(self, dbs: sch.DatabaseSchema) -> None:
        rs = dbs.relation(self.name)
        if set(self.columns) != set(rs.attrs):
            raise ValueError(
                f"relation {self.name!r}: columns {sorted(self.columns)} != schema {sorted(rs.attrs)}")
        n = self.n_rows
        for a, col in self.columns.items():
            if col.shape != (n,):
                raise ValueError(f"relation {self.name!r}: column {a!r} shape {col.shape} != ({n},)")
            attr = dbs.attr(a)
            if attr.is_discrete:
                if not jnp.issubdtype(col.dtype, jnp.integer):
                    raise ValueError(f"{self.name}.{a}: discrete column must be integer, got {col.dtype}")
            else:
                if not jnp.issubdtype(col.dtype, jnp.floating):
                    raise ValueError(f"{self.name}.{a}: continuous column must be float, got {col.dtype}")


@dataclasses.dataclass
class Database:
    schema: sch.DatabaseSchema
    relations: Dict[str, Relation]

    def validate(self) -> None:
        for r in self.relations.values():
            r.validate(self.schema)
        if set(self.relations) != set(self.schema.relations):
            raise ValueError("database relations do not match schema relations")

    def relation(self, name: str) -> Relation:
        return self.relations[name]

    def sizes(self) -> Dict[str, int]:
        return {n: r.n_rows for n, r in self.relations.items()}

    def total_tuples(self) -> int:
        return sum(self.sizes().values())


def from_numpy(dbs: sch.DatabaseSchema, tables: Mapping[str, Mapping[str, np.ndarray]]) -> Database:
    """Build a Database from host numpy columns, casting to engine dtypes."""
    rels = {}
    for name, cols in tables.items():
        rs = dbs.relation(name)
        jcols = {}
        for a in rs.attrs:
            col = np.asarray(cols[a])
            attr = dbs.attr(a)
            if attr.is_discrete:
                codes = col.astype(np.int32)
                if codes.size and (codes.min() < 0 or codes.max() >= attr.domain):
                    raise ValueError(
                        f"{name}.{a}: codes outside [0, {attr.domain}) "
                        f"(min {codes.min()}, max {codes.max()})")
                jcols[a] = jnp.asarray(codes)
            else:
                jcols[a] = jnp.asarray(col.astype(np.float32))
        rels[name] = Relation(name, jcols)
    db = Database(dbs, rels)
    db.validate()
    return db


def sort_by(rel: Relation, attrs: list) -> Relation:
    """Sort a relation by the given attribute order (LMFAO's trie order)."""
    keys = [np.asarray(rel.columns[a]) for a in reversed(attrs)]
    order = np.lexsort(keys)
    return Relation(rel.name, {a: jnp.asarray(np.asarray(c)[order]) for a, c in rel.columns.items()})
