"""Synthetic datasets mirroring the paper's four benchmark schemas (App. A).

Retailer and TPC-DS are snowflakes, Favorita is a star, Yelp is a star with
many-to-many joins (Category/Attribute) that blow up the join result — the
exact structural variety the paper exercises.  Generators are deterministic
in ``seed`` and scale-free: ``scale=1.0`` ≈ 60k fact rows (CPU-friendly);
benchmarks raise it.

Continuous features are also *bucketized* into companion categorical
attributes (``<attr>__b``) at generation time — the decision-tree workload
groups by bucket codes (paper §4.2 bucketizes into 20 buckets).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.schema import DatabaseSchema, schema
from repro.data import relations as rel_mod

N_BUCKETS = 20


@dataclasses.dataclass
class Dataset:
    name: str
    schema: DatabaseSchema
    tables: Dict[str, Dict[str, np.ndarray]]
    edges: List[Tuple[str, str]]              # join tree (paper Fig. 6)
    features_cont: List[str]                  # continuous model features
    features_cat: List[str]                   # categorical model features
    label: str                                # continuous label (fact table)
    fact: str

    _db: Optional[object] = None

    @property
    def db(self):
        if self._db is None:
            self._db = rel_mod.from_numpy(self.schema, self.tables)
        return self._db

    def bucket_attr(self, cont_attr: str) -> str:
        return cont_attr + "__b"


def _bucketize(x: np.ndarray, n: int = N_BUCKETS) -> Tuple[np.ndarray, np.ndarray]:
    qs = np.quantile(x, np.linspace(0, 1, n + 1)[1:-1])
    return np.searchsorted(qs, x).astype(np.int32), qs.astype(np.float32)


def _zipf_codes(rng, n, domain, a=1.3):
    z = rng.zipf(a, size=n)
    return ((z - 1) % domain).astype(np.int32)


# ---------------------------------------------------------------------------
# Favorita (paper Fig. 3): star, fact = Sales
# ---------------------------------------------------------------------------

def make_favorita(scale: float = 1.0, seed: int = 0) -> Dataset:
    rng = np.random.default_rng(seed)
    n_date, n_store, n_item = 334, 54, max(40, int(100 * min(scale, 4.0)))
    n_fact = int(60_000 * scale)

    attr_specs = [
        ("date", "key", n_date), ("store", "key", n_store), ("item", "key", n_item),
        ("units", "continuous", 0), ("promo", "categorical", 2),
        ("txns", "continuous", 0),
        ("city", "categorical", 22), ("state", "categorical", 16),
        ("stype", "categorical", 5), ("cluster", "categorical", 17),
        ("price", "continuous", 0),
        ("htype", "categorical", 6), ("locale", "categorical", 3),
        ("transferred", "categorical", 2),
        ("family", "categorical", 33), ("iclass", "categorical", 30),
        ("perishable", "categorical", 2),
    ]
    cont = ["units", "txns", "price"]
    attr_specs += [(c + "__b", "categorical", N_BUCKETS) for c in cont]

    S = schema(attr_specs, [
        ("Sales", ["date", "store", "item", "units", "promo", "units__b"]),
        ("Transactions", ["date", "store", "txns", "txns__b"]),
        ("Stores", ["store", "city", "state", "stype", "cluster"]),
        ("Oil", ["date", "price", "price__b"]),
        ("Holiday", ["date", "htype", "locale", "transferred"]),
        ("Items", ["item", "family", "iclass", "perishable"]),
    ])

    date = rng.integers(0, n_date, n_fact).astype(np.int32)
    store = _zipf_codes(rng, n_fact, n_store)
    item = _zipf_codes(rng, n_fact, n_item)
    promo = rng.integers(0, 2, n_fact).astype(np.int32)
    txns = np.maximum(1.0, rng.normal(1000, 300, n_date * n_store)).astype(np.float32)
    txns_b, _ = _bucketize(txns)
    td, ts = np.divmod(np.arange(n_date * n_store, dtype=np.int32), n_store)
    price = np.abs(rng.normal(60, 20, n_date)).astype(np.float32)
    price_b, _ = _bucketize(price)
    # label with genuine signal through the join: promo, store traffic,
    # item family effects, and the (date-level) oil price
    fam = rng.integers(0, 33, n_item).astype(np.int32)
    fam_eff = rng.normal(0, 2.0, 33).astype(np.float32)
    units = (8.0 + 2.5 * promo + 0.004 * txns[date * n_store + store]
             + fam_eff[fam[item]] - 0.03 * price[date]
             + rng.normal(0, 2.0, n_fact)).astype(np.float32)
    units_b, _ = _bucketize(units)

    tables = {
        "Sales": {"date": date, "store": store, "item": item, "units": units,
                  "promo": promo, "units__b": units_b},
        "Transactions": {"date": td, "store": ts, "txns": txns, "txns__b": txns_b},
        "Stores": {"store": np.arange(n_store, dtype=np.int32),
                   "city": rng.integers(0, 22, n_store).astype(np.int32),
                   "state": rng.integers(0, 16, n_store).astype(np.int32),
                   "stype": rng.integers(0, 5, n_store).astype(np.int32),
                   "cluster": rng.integers(0, 17, n_store).astype(np.int32)},
        "Oil": {"date": np.arange(n_date, dtype=np.int32), "price": price,
                "price__b": price_b},
        "Holiday": {"date": np.arange(n_date, dtype=np.int32),
                    "htype": rng.integers(0, 6, n_date).astype(np.int32),
                    "locale": rng.integers(0, 3, n_date).astype(np.int32),
                    "transferred": rng.integers(0, 2, n_date).astype(np.int32)},
        "Items": {"item": np.arange(n_item, dtype=np.int32),
                  "family": fam,
                  "iclass": rng.integers(0, 30, n_item).astype(np.int32),
                  "perishable": rng.integers(0, 2, n_item).astype(np.int32)},
    }
    edges = [("Sales", "Transactions"), ("Transactions", "Stores"),
             ("Transactions", "Oil"), ("Sales", "Holiday"), ("Sales", "Items")]
    return Dataset("favorita", S, tables, edges,
                   features_cont=["txns", "price"],
                   features_cat=["promo", "city", "state", "stype", "cluster",
                                 "htype", "locale", "transferred", "family",
                                 "iclass", "perishable"],
                   label="units", fact="Sales")


# ---------------------------------------------------------------------------
# Retailer (App. A): snowflake, fact = Inventory
# ---------------------------------------------------------------------------

def make_retailer(scale: float = 1.0, seed: int = 1) -> Dataset:
    rng = np.random.default_rng(seed)
    n_date, n_locn, n_zip, n_sku = 124, 40, 30, max(60, int(120 * min(scale, 4.0)))
    n_fact = int(60_000 * scale)

    cont = ["inventoryunits", "maxtemp", "population", "medianage", "distance",
            "sales_area", "avghhi", "supertargetdistance"]
    attr_specs = [
        ("date", "key", n_date), ("locn", "key", n_locn), ("zip", "key", n_zip),
        ("sku", "key", n_sku),
        ("rain", "categorical", 2), ("snow", "categorical", 2),
        ("thunder", "categorical", 2),
        ("rgn_cd", "categorical", 5), ("clim_zn", "categorical", 6),
        ("category", "categorical", 10), ("subcategory", "categorical", 25),
        ("categoryCluster", "categorical", 8), ("prize", "continuous", 0),
    ] + [(c, "continuous", 0) for c in cont]
    attr_specs += [(c + "__b", "categorical", N_BUCKETS)
                   for c in ["inventoryunits", "maxtemp", "population", "prize"]]

    S = schema(attr_specs, [
        ("Inventory", ["date", "locn", "sku", "inventoryunits", "inventoryunits__b"]),
        ("Weather", ["date", "locn", "rain", "snow", "thunder", "maxtemp", "maxtemp__b"]),
        ("Location", ["locn", "zip", "rgn_cd", "clim_zn", "distance",
                      "sales_area", "supertargetdistance"]),
        ("Census", ["zip", "population", "population__b", "medianage", "avghhi"]),
        ("Items", ["sku", "category", "subcategory", "categoryCluster", "prize",
                   "prize__b"]),
    ])

    maxtemp = rng.normal(60, 20, n_date * n_locn).astype(np.float32)
    maxtemp_b, _ = _bucketize(maxtemp)
    wd, wl = np.divmod(np.arange(n_date * n_locn, dtype=np.int32), n_locn)
    pop = np.abs(rng.normal(30_000, 12_000, n_zip)).astype(np.float32)
    pop_b, _ = _bucketize(pop)
    prize = np.abs(rng.normal(25, 10, n_sku)).astype(np.float32)
    prize_b, _ = _bucketize(prize)
    zip_of = rng.integers(0, n_zip, n_locn).astype(np.int32)
    cat_of = rng.integers(0, 10, n_sku).astype(np.int32)
    cat_eff = rng.normal(0, 5.0, 10).astype(np.float32)
    f_date = rng.integers(0, n_date, n_fact).astype(np.int32)
    f_locn = _zipf_codes(rng, n_fact, n_locn)
    f_sku = _zipf_codes(rng, n_fact, n_sku)
    inv = (12.0 + 0.0004 * pop[zip_of[f_locn]] + cat_eff[cat_of[f_sku]]
           + 0.1 * maxtemp[f_date * n_locn + f_locn] - 0.2 * prize[f_sku]
           + rng.normal(0, 4.0, n_fact)).astype(np.float32)
    inv_b, _ = _bucketize(inv)

    tables = {
        "Inventory": {"date": f_date, "locn": f_locn, "sku": f_sku,
                      "inventoryunits": inv, "inventoryunits__b": inv_b},
        "Weather": {"date": wd, "locn": wl,
                    "rain": rng.integers(0, 2, n_date * n_locn).astype(np.int32),
                    "snow": rng.integers(0, 2, n_date * n_locn).astype(np.int32),
                    "thunder": rng.integers(0, 2, n_date * n_locn).astype(np.int32),
                    "maxtemp": maxtemp, "maxtemp__b": maxtemp_b},
        "Location": {"locn": np.arange(n_locn, dtype=np.int32),
                     "zip": zip_of,
                     "rgn_cd": rng.integers(0, 5, n_locn).astype(np.int32),
                     "clim_zn": rng.integers(0, 6, n_locn).astype(np.int32),
                     "distance": np.abs(rng.normal(5, 3, n_locn)).astype(np.float32),
                     "sales_area": np.abs(rng.normal(2000, 700, n_locn)).astype(np.float32),
                     "supertargetdistance": np.abs(rng.normal(8, 4, n_locn)).astype(np.float32)},
        "Census": {"zip": np.arange(n_zip, dtype=np.int32),
                   "population": pop, "population__b": pop_b,
                   "medianage": np.abs(rng.normal(38, 8, n_zip)).astype(np.float32),
                   "avghhi": np.abs(rng.normal(60_000, 15_000, n_zip)).astype(np.float32)},
        "Items": {"sku": np.arange(n_sku, dtype=np.int32),
                  "category": cat_of,
                  "subcategory": rng.integers(0, 25, n_sku).astype(np.int32),
                  "categoryCluster": rng.integers(0, 8, n_sku).astype(np.int32),
                  "prize": prize, "prize__b": prize_b},
    }
    edges = [("Inventory", "Weather"), ("Inventory", "Location"),
             ("Location", "Census"), ("Inventory", "Items")]
    return Dataset("retailer", S, tables, edges,
                   features_cont=["maxtemp", "population", "medianage", "avghhi",
                                  "distance", "sales_area", "supertargetdistance",
                                  "prize"],
                   features_cat=["rain", "snow", "thunder", "rgn_cd", "clim_zn",
                                 "category", "subcategory", "categoryCluster"],
                   label="inventoryunits", fact="Inventory")


# ---------------------------------------------------------------------------
# Yelp: star with many-to-many Category/Attribute joins
# ---------------------------------------------------------------------------

def make_yelp(scale: float = 1.0, seed: int = 2) -> Dataset:
    rng = np.random.default_rng(seed)
    n_user, n_biz = max(80, int(200 * min(scale, 4.0))), max(50, int(120 * min(scale, 4.0)))
    n_fact = int(40_000 * scale)
    n_cat_rows, n_attr_rows = n_biz * 3, n_biz * 4

    attr_specs = [
        ("user", "key", n_user), ("business", "key", n_biz),
        ("stars", "continuous", 0), ("useful", "continuous", 0),
        ("u_review_count", "continuous", 0), ("u_avg_stars", "continuous", 0),
        ("b_city", "categorical", 30), ("b_stars", "continuous", 0),
        ("b_review_count", "continuous", 0), ("b_open", "categorical", 2),
        ("cat", "categorical", 40), ("attr", "categorical", 50),
        ("attr_val", "categorical", 2),
    ]
    attr_specs += [(c + "__b", "categorical", N_BUCKETS)
                   for c in ["stars", "u_avg_stars", "b_stars"]]

    S = schema(attr_specs, [
        ("Review", ["user", "business", "stars", "stars__b", "useful"]),
        ("User", ["user", "u_review_count", "u_avg_stars", "u_avg_stars__b"]),
        ("Business", ["business", "b_city", "b_stars", "b_stars__b",
                      "b_review_count", "b_open"]),
        ("Category", ["business", "cat"]),
        ("Attribute", ["business", "attr", "attr_val"]),
    ])

    stars = rng.integers(1, 6, n_fact).astype(np.float32)
    stars_b, _ = _bucketize(stars)
    u_avg = rng.uniform(1, 5, n_user).astype(np.float32)
    u_avg_b, _ = _bucketize(u_avg)
    b_stars = rng.uniform(1, 5, n_biz).astype(np.float32)
    b_stars_b, _ = _bucketize(b_stars)

    tables = {
        "Review": {"user": _zipf_codes(rng, n_fact, n_user),
                   "business": _zipf_codes(rng, n_fact, n_biz),
                   "stars": stars, "stars__b": stars_b,
                   "useful": np.abs(rng.normal(2, 2, n_fact)).astype(np.float32)},
        "User": {"user": np.arange(n_user, dtype=np.int32),
                 "u_review_count": np.abs(rng.normal(50, 40, n_user)).astype(np.float32),
                 "u_avg_stars": u_avg, "u_avg_stars__b": u_avg_b},
        "Business": {"business": np.arange(n_biz, dtype=np.int32),
                     "b_city": rng.integers(0, 30, n_biz).astype(np.int32),
                     "b_stars": b_stars, "b_stars__b": b_stars_b,
                     "b_review_count": np.abs(rng.normal(120, 80, n_biz)).astype(np.float32),
                     "b_open": rng.integers(0, 2, n_biz).astype(np.int32)},
        "Category": {"business": rng.integers(0, n_biz, n_cat_rows).astype(np.int32),
                     "cat": rng.integers(0, 40, n_cat_rows).astype(np.int32)},
        "Attribute": {"business": rng.integers(0, n_biz, n_attr_rows).astype(np.int32),
                      "attr": rng.integers(0, 50, n_attr_rows).astype(np.int32),
                      "attr_val": rng.integers(0, 2, n_attr_rows).astype(np.int32)},
    }
    edges = [("Review", "User"), ("Review", "Business"),
             ("Business", "Category"), ("Business", "Attribute")]
    return Dataset("yelp", S, tables, edges,
                   features_cont=["useful", "u_review_count", "u_avg_stars",
                                  "b_stars", "b_review_count"],
                   features_cat=["b_city", "b_open", "cat", "attr", "attr_val"],
                   label="stars", fact="Review")


# ---------------------------------------------------------------------------
# TPC-DS (excerpt, store_sales snowflake, 10 relations)
# ---------------------------------------------------------------------------

def make_tpcds(scale: float = 1.0, seed: int = 3) -> Dataset:
    rng = np.random.default_rng(seed)
    n_date, n_item, n_cust, n_cd, n_hd = 240, max(60, int(120 * min(scale, 4.0))), \
        max(80, int(160 * min(scale, 4.0))), 48, 36
    n_store, n_promo, n_addr, n_time = 12, 16, 60, 48
    n_fact = int(60_000 * scale)

    attr_specs = [
        ("d_date_sk", "key", n_date), ("i_item_sk", "key", n_item),
        ("c_customer_sk", "key", n_cust), ("cd_demo_sk", "key", n_cd),
        ("hd_demo_sk", "key", n_hd), ("s_store_sk", "key", n_store),
        ("p_promo_sk", "key", n_promo), ("ca_address_sk", "key", n_addr),
        ("t_time_sk", "key", n_time),
        ("ss_quantity", "continuous", 0), ("ss_sales_price", "continuous", 0),
        ("ss_ext_discount", "continuous", 0),
        ("d_year", "categorical", 5), ("d_moy", "categorical", 12),
        ("d_dow", "categorical", 7),
        ("i_category", "categorical", 10), ("i_brand", "categorical", 20),
        ("i_price", "continuous", 0),
        ("c_preferred", "categorical", 2), ("c_birth_year", "categorical", 40),
        ("cd_gender", "categorical", 2), ("cd_marital", "categorical", 5),
        ("cd_education", "categorical", 7),
        ("hd_income_band", "categorical", 20), ("hd_dep_count", "categorical", 10),
        ("s_city", "categorical", 8), ("s_tax", "continuous", 0),
        ("p_channel", "categorical", 4),
        ("ca_state", "categorical", 25), ("ca_gmt", "categorical", 6),
        ("t_hour", "categorical", 24),
    ]
    attr_specs += [(c + "__b", "categorical", N_BUCKETS)
                   for c in ["ss_quantity", "ss_sales_price", "i_price"]]

    S = schema(attr_specs, [
        ("store_sales", ["d_date_sk", "t_time_sk", "i_item_sk", "c_customer_sk",
                         "s_store_sk", "p_promo_sk", "ss_quantity", "ss_quantity__b",
                         "ss_sales_price", "ss_sales_price__b", "ss_ext_discount"]),
        ("date_dim", ["d_date_sk", "d_year", "d_moy", "d_dow"]),
        ("time_dim", ["t_time_sk", "t_hour"]),
        ("item", ["i_item_sk", "i_category", "i_brand", "i_price", "i_price__b"]),
        ("customer", ["c_customer_sk", "cd_demo_sk", "hd_demo_sk", "ca_address_sk",
                      "c_preferred", "c_birth_year"]),
        ("customer_demographics", ["cd_demo_sk", "cd_gender", "cd_marital",
                                   "cd_education"]),
        ("household_demographics", ["hd_demo_sk", "hd_income_band", "hd_dep_count"]),
        ("customer_address", ["ca_address_sk", "ca_state", "ca_gmt"]),
        ("store", ["s_store_sk", "s_city", "s_tax"]),
        ("promotion", ["p_promo_sk", "p_channel"]),
    ])

    sp = np.abs(rng.normal(35, 18, n_fact)).astype(np.float32)
    sp_b, _ = _bucketize(sp)
    ip = np.abs(rng.normal(40, 20, n_item)).astype(np.float32)
    ip_b, _ = _bucketize(ip)
    # demographics drive c_preferred (classification label, paper §4.2)
    cd_of = rng.integers(0, n_cd, n_cust).astype(np.int32)
    hd_of = rng.integers(0, n_hd, n_cust).astype(np.int32)
    educ = rng.integers(0, 7, n_cd).astype(np.int32)
    inc = rng.integers(0, 20, n_hd).astype(np.int32)
    logit = -0.6 + 0.45 * (educ[cd_of] - 3) + 0.12 * (inc[hd_of] - 10)
    c_pref = (rng.random(n_cust) < 1 / (1 + np.exp(-logit))).astype(np.int32)
    # quantity depends on item price, promo channel, and sales price
    f_item = _zipf_codes(rng, n_fact, n_item)
    f_promo = rng.integers(0, n_promo, n_fact).astype(np.int32)
    ch_of = rng.integers(0, 4, n_promo).astype(np.int32)
    ch_eff = np.array([0.0, 2.0, 4.0, -1.5], dtype=np.float32)
    qty = (24.0 - 0.15 * ip[f_item] + ch_eff[ch_of[f_promo]] - 0.05 * sp
           + rng.normal(0, 5.0, n_fact)).astype(np.float32)
    qty_b, _ = _bucketize(qty)

    tables = {
        "store_sales": {"d_date_sk": rng.integers(0, n_date, n_fact).astype(np.int32),
                        "t_time_sk": rng.integers(0, n_time, n_fact).astype(np.int32),
                        "i_item_sk": f_item,
                        "c_customer_sk": _zipf_codes(rng, n_fact, n_cust),
                        "s_store_sk": rng.integers(0, n_store, n_fact).astype(np.int32),
                        "p_promo_sk": f_promo,
                        "ss_quantity": qty, "ss_quantity__b": qty_b,
                        "ss_sales_price": sp, "ss_sales_price__b": sp_b,
                        "ss_ext_discount": np.abs(rng.normal(3, 2, n_fact)).astype(np.float32)},
        "date_dim": {"d_date_sk": np.arange(n_date, dtype=np.int32),
                     "d_year": (np.arange(n_date) * 5 // n_date).astype(np.int32),
                     "d_moy": (np.arange(n_date) % 12).astype(np.int32),
                     "d_dow": (np.arange(n_date) % 7).astype(np.int32)},
        "time_dim": {"t_time_sk": np.arange(n_time, dtype=np.int32),
                     "t_hour": (np.arange(n_time) % 24).astype(np.int32)},
        "item": {"i_item_sk": np.arange(n_item, dtype=np.int32),
                 "i_category": rng.integers(0, 10, n_item).astype(np.int32),
                 "i_brand": rng.integers(0, 20, n_item).astype(np.int32),
                 "i_price": ip, "i_price__b": ip_b},
        "customer": {"c_customer_sk": np.arange(n_cust, dtype=np.int32),
                     "cd_demo_sk": cd_of,
                     "hd_demo_sk": hd_of,
                     "ca_address_sk": rng.integers(0, n_addr, n_cust).astype(np.int32),
                     "c_preferred": c_pref,
                     "c_birth_year": rng.integers(0, 40, n_cust).astype(np.int32)},
        "customer_demographics": {"cd_demo_sk": np.arange(n_cd, dtype=np.int32),
                                  "cd_gender": rng.integers(0, 2, n_cd).astype(np.int32),
                                  "cd_marital": rng.integers(0, 5, n_cd).astype(np.int32),
                                  "cd_education": educ},
        "household_demographics": {"hd_demo_sk": np.arange(n_hd, dtype=np.int32),
                                   "hd_income_band": inc,
                                   "hd_dep_count": rng.integers(0, 10, n_hd).astype(np.int32)},
        "customer_address": {"ca_address_sk": np.arange(n_addr, dtype=np.int32),
                             "ca_state": rng.integers(0, 25, n_addr).astype(np.int32),
                             "ca_gmt": rng.integers(0, 6, n_addr).astype(np.int32)},
        "store": {"s_store_sk": np.arange(n_store, dtype=np.int32),
                  "s_city": rng.integers(0, 8, n_store).astype(np.int32),
                  "s_tax": rng.uniform(0, 0.1, n_store).astype(np.float32)},
        "promotion": {"p_promo_sk": np.arange(n_promo, dtype=np.int32),
                      "p_channel": rng.integers(0, 4, n_promo).astype(np.int32)},
    }
    edges = [("store_sales", "date_dim"), ("store_sales", "time_dim"),
             ("store_sales", "item"), ("store_sales", "customer"),
             ("store_sales", "store"), ("store_sales", "promotion"),
             ("customer", "customer_demographics"),
             ("customer", "household_demographics"),
             ("customer", "customer_address")]
    return Dataset("tpcds", S, tables, edges,
                   features_cont=["ss_sales_price", "ss_ext_discount", "i_price",
                                  "s_tax"],
                   features_cat=["d_year", "d_moy", "d_dow", "i_category", "i_brand",
                                 "cd_gender", "cd_marital", "cd_education",
                                 "hd_income_band", "hd_dep_count", "s_city",
                                 "p_channel", "ca_state", "ca_gmt", "t_hour",
                                 "c_preferred"],
                   label="ss_quantity", fact="store_sales")


MAKERS = {
    "favorita": make_favorita,
    "retailer": make_retailer,
    "yelp": make_yelp,
    "tpcds": make_tpcds,
}


def make(name: str, scale: float = 1.0, seed: Optional[int] = None) -> Dataset:
    kw = {} if seed is None else {"seed": seed}
    return MAKERS[name](scale=scale, **kw)
