"""Data substrate: columnar relations, synthetic schemas, LM token pipeline."""

from repro.data.relations import Database, Relation, from_numpy, sort_by

__all__ = ["Database", "Relation", "from_numpy", "sort_by"]
