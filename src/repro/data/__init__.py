"""Data substrate: columnar relations, synthetic schemas, LM token pipeline."""

from repro.data.relations import (Database, DeltaBatchUpdate, Relation,
                                  RelationDelta, ResidentRelation,
                                  apply_delta, from_numpy, sort_by)

__all__ = ["Database", "DeltaBatchUpdate", "Relation", "RelationDelta",
           "ResidentRelation", "apply_delta", "from_numpy", "sort_by"]
