"""Deterministic, restart-safe synthetic token pipeline.

Batches are a pure function of ``(seed, step)`` — a crashed/elastic-resized
run that resumes at step ``k`` sees *exactly* the batch it would have seen,
regardless of host count (each host slices its shard of the same global
batch).  The sequences follow an affine recurrence modulo vocab so models
have real signal to learn (loss decreases in the end-to-end example).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class PipelineConfig:
    global_batch: int
    seq_len: int
    vocab: int
    seed: int = 0


class TokenPipeline:
    def __init__(self, cfg: PipelineConfig, model_cfg: Optional[ModelConfig] = None):
        self.cfg = cfg
        self.model_cfg = model_cfg
        self._root = jax.random.PRNGKey(cfg.seed)

    def batch_at(self, step: int) -> Dict[str, jnp.ndarray]:
        """Global batch for a given step (pure function of (seed, step))."""
        c = self.cfg
        key = jax.random.fold_in(self._root, step)
        k1, k2, k3 = jax.random.split(key, 3)
        start = jax.random.randint(k1, (c.global_batch, 1), 0, c.vocab)
        stride = 1 + jax.random.randint(k2, (c.global_batch, 1), 0, 2)
        steps = jnp.arange(c.seq_len, dtype=jnp.int32)[None, :]
        # arithmetic progression t_i = (t_0 + i·stride) mod vocab: next-token
        # prediction is learnable from local context, so example/test runs
        # show real loss decrease
        tokens = jnp.mod(start + steps * stride, c.vocab).astype(jnp.int32)
        out = {"tokens": tokens}
        if self.model_cfg is not None:
            mc = self.model_cfg
            if mc.family == "vlm":
                out["vision"] = jax.random.normal(
                    k3, (c.global_batch, mc.vision_tokens, mc.d_model),
                    jnp.float32) * 0.02
            if mc.family == "audio":
                out["frames"] = jax.random.normal(
                    k3, (c.global_batch, mc.encoder_frames, mc.d_model),
                    jnp.float32) * 0.02
        return out

    def host_shard(self, batch: Dict[str, jnp.ndarray], process_index: int,
                   process_count: int) -> Dict[str, jnp.ndarray]:
        """Slice this host's rows of the global batch (multi-host loading)."""
        def sl(a):
            per = a.shape[0] // process_count
            return a[process_index * per:(process_index + 1) * per]
        return {k: sl(v) for k, v in batch.items()}
