"""Framework integration of the LMFAO engine as the data-layer statistics
service (DESIGN.md §Arch-applicability).

Training pipelines routinely need sufficient statistics over metadata-joined
corpora: feature covariances for normalization, pairwise MI for feature
selection, per-key load counts.  These are exactly LMFAO aggregate batches;
this module is the thin bridge the LM side of the framework calls.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence

import numpy as np

from repro.api import ExecutionConfig, connect
from repro.core import COUNT, query, sum_of, sum_sq
from repro.data.datasets import Dataset


def feature_moments(ds: Dataset, attrs: Optional[Sequence[str]] = None,
                    block_size: int = 4096) -> Dict[str, Dict[str, float]]:
    """Mean/var of continuous features over the (non-materialized) join —
    the normalization statistics a data pipeline applies before training."""
    attrs = list(attrs if attrs is not None else ds.features_cont)
    qs = [query("n", [], [COUNT])]
    for a in attrs:
        qs.append(query(f"m_{a}", [], [sum_of(a), sum_sq(a)]))
    sess = connect(ds, config=ExecutionConfig(block_size=block_size))
    out = sess.views(qs).run()
    n = float(np.asarray(out["n"])[0])
    stats = {}
    for a in attrs:
        s, s2 = np.asarray(out[f"m_{a}"], np.float64)
        mean = s / n
        stats[a] = {"count": n, "mean": mean, "var": max(s2 / n - mean * mean, 0.0)}
    return stats


def expert_load_aggregate(expert_ids: np.ndarray, n_experts: int) -> np.ndarray:
    """MoE router load counters expressed as a group-by-expert COUNT through
    the engine (single-relation degenerate join) — the same statistic
    moe.router_stats computes inline, here via the in-database path."""
    from repro.core.schema import schema as mk_schema
    from repro.data.relations import from_numpy

    S = mk_schema([("expert", "categorical", n_experts)], [("Route", ["expert"])])
    db = from_numpy(S, {"Route": {"expert": expert_ids.astype(np.int32)}})
    out = connect(db).views([query("load", ["expert"], [COUNT])]).run()
    return np.asarray(out["load"])[:, 0]
