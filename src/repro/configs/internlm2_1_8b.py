"""internlm2-1.8b [dense]: GQA. 24L d_model=2048 16H (kv=8) d_ff=8192
vocab=92544 [arXiv:2403.17297; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b", family="dense",
    n_layers=24, d_model=2048, n_heads=16, n_kv=8, d_ff=8192, vocab=92544,
)

def smoke_config() -> ModelConfig:
    return CONFIG.with_(n_layers=3, d_model=64, n_heads=4, n_kv=2, d_ff=128,
                        vocab=128, dtype="float32", remat=False)
