"""Assigned input shapes (one set shared by all LM-family archs).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of ``seq_len``); the others lower ``train_step``.  ``long_500k``
requires sub-quadratic attention — pure full-attention archs skip it (noted
in DESIGN.md §Arch-applicability and EXPERIMENTS.md).
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "train"),  # fwd-only prefill
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# prefill is inference: it lowers forward-only (no optimizer update)
PREFILL = {"prefill_32k"}


def applicable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "skip: full-attention arch (long_500k needs sub-quadratic)"
    return True, ""


def cells(cfg: ModelConfig) -> List[Tuple[InputShape, bool, str]]:
    return [(s,) + applicable(cfg, s) for s in SHAPES.values()]
