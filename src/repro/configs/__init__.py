"""Architecture registry: one module per assigned architecture.

``get(arch_id)`` returns the exact assigned config; ``get_smoke(arch_id)``
returns the reduced same-family config used by CPU smoke tests.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS: List[str] = [
    "zamba2-1.2b",
    "llama-3.2-vision-90b",
    "mamba2-2.7b",
    "qwen3-moe-235b-a22b",
    "deepseek-v2-lite-16b",
    "h2o-danube-3-4b",
    "minicpm-2b",
    "internlm2-1.8b",
    "llama3-8b",
    "whisper-small",
]

_MOD = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MOD[arch_id]}")
    return mod.CONFIG


def get_smoke(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MOD[arch_id]}")
    return mod.smoke_config()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get(a) for a in ARCH_IDS}
