"""qwen3-moe-235b-a22b [moe]: 128 experts, top-8 routing.

94L d_model=4096 64H (GQA kv=4) expert d_ff=1536 vocab=151936
[hf:Qwen/Qwen3-30B-A3B; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv=4, d_ff=0, vocab=151936,
    n_experts=128, top_k=8, moe_d_ff=1536, head_dim=128,
    rope_theta=1_000_000.0,
)

def smoke_config() -> ModelConfig:
    return CONFIG.with_(n_layers=3, d_model=64, n_heads=4, n_kv=2, vocab=128,
                        n_experts=8, top_k=2, moe_d_ff=32, head_dim=16,
                        dtype="float32", remat=False)
