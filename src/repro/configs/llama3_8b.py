"""llama3-8b [dense]: GQA, 128k vocab. 32L d_model=4096 32H (kv=8)
d_ff=14336 vocab=128256 [arXiv:2407.21783; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=14336, vocab=128256,
    rope_theta=500_000.0,
)

def smoke_config() -> ModelConfig:
    return CONFIG.with_(n_layers=3, d_model=64, n_heads=4, n_kv=2, d_ff=128,
                        vocab=128, dtype="float32", remat=False)
