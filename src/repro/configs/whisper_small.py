"""whisper-small [audio]: encoder-decoder; conv frontend is a stub —
input_specs() provides precomputed frame embeddings (B, 1500, d_model).

12L (decoder) d_model=768 12H d_ff=3072 vocab=51865, 12 encoder layers
[arXiv:2212.04356; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv=12, d_ff=3072, vocab=51865,
    encoder_layers=12, encoder_frames=1500,
)

def smoke_config() -> ModelConfig:
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=128,
                        vocab=128, encoder_layers=2, encoder_frames=24,
                        dtype="float32", remat=False)
