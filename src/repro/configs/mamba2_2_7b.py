"""mamba2-2.7b [ssm]: SSD (state-space duality), attention-free.

64L d_model=2560 d_ff=0 vocab=50280, ssm_state=128 [arXiv:2405.21060; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=1, n_kv=1, d_ff=0, vocab=50280,
    ssm_state=128, ssm_head_dim=64, expand=2,
)

def smoke_config() -> ModelConfig:
    return CONFIG.with_(n_layers=3, d_model=64, vocab=128, ssm_state=8,
                        ssm_head_dim=16, ssm_chunk=8, dtype="float32",
                        remat=False)
