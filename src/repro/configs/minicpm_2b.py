"""minicpm-2b [dense]: llama-like; trained with the WSD schedule (the
warmup-stable-decay schedule is implemented in repro.train.schedules and
selected by this config) [arXiv:2404.06395; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv=36, d_ff=5760, vocab=122753,
)
SCHEDULE = "wsd"

def smoke_config() -> ModelConfig:
    return CONFIG.with_(n_layers=3, d_model=72, n_heads=6, n_kv=6, d_ff=144,
                        vocab=128, dtype="float32", remat=False)
