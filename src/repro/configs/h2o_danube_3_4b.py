"""h2o-danube-3-4b [dense]: llama+mistral mix with sliding-window attention.

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000 [arXiv:2401.16818; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv=8, d_ff=10240, vocab=32000,
    window=4096,
)

def smoke_config() -> ModelConfig:
    return CONFIG.with_(n_layers=3, d_model=64, n_heads=4, n_kv=2, d_ff=128,
                        vocab=128, window=16, dtype="float32", remat=False)
