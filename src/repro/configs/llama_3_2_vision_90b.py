"""llama-3.2-vision-90b [vlm]: cross-attention image layers.

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256; every 5th layer
cross-attends to precomputed patch embeddings (frontend stub per brief)
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv=8, d_ff=28672, vocab=128256,
    cross_every=5, vision_tokens=1024, rope_theta=500_000.0,
)

def smoke_config() -> ModelConfig:
    return CONFIG.with_(n_layers=4, d_model=64, n_heads=4, n_kv=2, d_ff=128,
                        vocab=128, cross_every=2, vision_tokens=16,
                        dtype="float32", remat=False)
