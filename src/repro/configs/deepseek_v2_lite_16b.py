"""deepseek-v2-lite-16b [moe]: MLA (kv_lora=512), 2 shared + routed top-6.

27L d_model=2048 16H expert d_ff=1408 vocab=102400, 64 routed experts
[arXiv:2405.04434; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv=16, d_ff=0, vocab=102400,
    kv_lora=512, rope_dim=64, head_dim=128,
    n_experts=64, n_shared_experts=2, top_k=6, moe_d_ff=1408,
)

def smoke_config() -> ModelConfig:
    return CONFIG.with_(n_layers=3, d_model=64, n_heads=4, n_kv=4, vocab=128,
                        kv_lora=32, rope_dim=16, head_dim=16,
                        n_experts=8, n_shared_experts=1, top_k=2, moe_d_ff=32,
                        dtype="float32", remat=False)
