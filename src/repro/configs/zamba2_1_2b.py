"""zamba2-1.2b [hybrid]: Mamba2 backbone + shared attention block.

38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64
[arXiv:2411.15242; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv=32, d_ff=8192, vocab=32000,
    ssm_state=64, ssm_head_dim=64, expand=2, attn_every=6,
)

def smoke_config() -> ModelConfig:
    return CONFIG.with_(n_layers=4, d_model=64, n_heads=4, n_kv=4, d_ff=128,
                        vocab=128, ssm_state=8, ssm_head_dim=16, attn_every=2,
                        ssm_chunk=8, dtype="float32", remat=False)
