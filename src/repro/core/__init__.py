"""LMFAO core: the paper's layered aggregate engine in JAX.

Layers (paper Fig. 1): join tree -> find roots -> aggregate pushdown
(directional views) -> merge views -> group views -> multi-output plans ->
parallelization (shard_map) -> code generation (jit/XLA).
"""

from repro.core.aggregates import (Aggregate, Constant, Delta, Lambda, Param,
                                   Pow, ProductAgg, Query, Term, Var, agg,
                                   COUNT, query, sum_of, sum_prod, sum_sq)
from repro.core.engine import (BatchStats, CompiledBatch, Engine,
                               EngineDeprecationWarning)
from repro.core.jointree import JoinTree, materialize_bag
from repro.core.schema import (Attribute, DatabaseSchema, RelationSchema,
                               CATEGORICAL, CONTINUOUS, KEY, schema)

# NOTE: the IVM subsystem (repro.core.ivm: MaintainedBatch, DeltaProgram) is
# deliberately not imported here — it depends on repro.data.relations, which
# imports repro.core.schema, and an eager import would cycle whenever
# repro.data is imported first.  Reach it via Engine.compile_incremental or
# `from repro.core.ivm import MaintainedBatch`.

__all__ = [
    "Aggregate", "Constant", "Delta", "Lambda", "Param", "Pow", "ProductAgg",
    "Query", "Term", "Var", "agg", "COUNT", "query", "sum_of", "sum_prod",
    "sum_sq", "BatchStats", "CompiledBatch", "Engine",
    "EngineDeprecationWarning", "JoinTree",
    "materialize_bag", "Attribute", "DatabaseSchema", "RelationSchema",
    "CATEGORICAL", "CONTINUOUS", "KEY", "schema",
]
