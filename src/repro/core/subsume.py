"""Subsumption lattice + secondary-program lowering over view tensors.

The serving router (DESIGN.md §13) answers an ad-hoc group-by aggregate
from an already-materialized view whenever the algebra allows it.  Views
are dense code-domain tensors shaped ``(*group_domains, n_aggs)`` with one
axis per group-by attribute (query order) and a trailing aggregate-column
axis.  Group-bys form a lattice under partition refinement: grouping by a
*superset* of attributes refines the partition, so summing a wider view
over its extra attribute axes recovers the coarser grouping exactly —
SUM/COUNT-style aggregates (everything this engine materializes) are
additive across the summed-away cells.  That makes subsumption a purely
structural test:

    wide ⊒ narrow  ⟺  dims(narrow) ⊆ dims(wide)
                       ∧ every aggregate of narrow appears (by canonical
                         render, filters inline) as a column of wide

No semantic analysis of the aggregate expressions is needed beyond render
equality: the canonical render (``obs/workload.py``) already normalizes
term order and filter constants, and a filter factor ``1[x<c]`` rides
inside its aggregate's render, so a filtered column only matches a column
with the *same* filter — summing it over extra dims is still exact.

A :class:`SecondaryProgram` is the lowered answer plan: gather the needed
aggregate columns, sum away the extra attribute axes, permute the kept
axes into the asking query's group-by order.  It is a tiny closed-form
``GroupProgram`` over *view tensors* — never base relations — so it runs
in microseconds on-device, and on sharded sessions it runs unchanged on
the replicated epoch views (psum-before-fold keeps them replicated; no new
collectives).  Programs are verified structurally at admission time by
``analysis/verify.py:verify_secondary_program`` (rule ``route-subsume``).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.aggregates import Query
from repro.core.schema import DatabaseSchema
from repro.obs.workload import agg_renders

__all__ = ["ViewShape", "view_shape_of", "subsumes", "reagg_cost",
           "SecondaryProgram", "build_secondary_program", "lower_secondary"]


@dataclasses.dataclass(frozen=True)
class ViewShape:
    """Structural shape of a materialized view tensor: axis order, per-axis
    code domains, and the canonical render of each trailing agg column."""

    name: str                   # view (query) name
    dims: Tuple[str, ...]       # tensor axis order = query group_by order
    domains: Tuple[int, ...]    # code-domain size per dim axis
    aggs: Tuple[str, ...]       # canonical render per agg column, in order

    @property
    def cells(self) -> int:
        n = 1
        for d in self.domains:
            n *= d
        return n


def view_shape_of(q: Query, schema: DatabaseSchema,
                  name: Optional[str] = None) -> ViewShape:
    """Shape of the tensor ``q`` materializes under ``schema``."""
    return ViewShape(name=name or q.name,
                     dims=tuple(q.group_by),
                     domains=tuple(schema.domain(a) for a in q.group_by),
                     aggs=agg_renders(q))


def _column_map(wide: ViewShape,
                narrow: ViewShape) -> Optional[Tuple[int, ...]]:
    """Per narrow agg column, the wide column carrying the same canonical
    render — or None if any narrow column is missing from wide."""
    idx: Dict[str, int] = {}
    for i, r in enumerate(wide.aggs):
        idx.setdefault(r, i)
    cols = []
    for r in narrow.aggs:
        i = idx.get(r)
        if i is None:
            return None
        cols.append(i)
    return tuple(cols)


def subsumes(wide: ViewShape, narrow: ViewShape) -> bool:
    """Whether ``narrow`` is answerable from ``wide`` by re-aggregation."""
    if not set(narrow.dims) <= set(wide.dims):
        return False
    return _column_map(wide, narrow) is not None


def reagg_cost(wide: ViewShape) -> int:
    """Cells read to re-aggregate from ``wide`` — the planner prefers the
    smallest subsuming source tensor."""
    return wide.cells


@dataclasses.dataclass(frozen=True)
class SecondaryProgram:
    """Closed-form re-aggregation plan: view tensor of ``source`` shape →
    answer tensor of ``target`` shape.  ``is_exact`` means no axis is
    summed away (pure axis/column shuffle — the exact-match adapter)."""

    source: ViewShape
    target: ViewShape
    col_idx: Tuple[int, ...]    # source agg column per target agg column
    sum_axes: Tuple[int, ...]   # source dim axes summed away (sorted)
    perm: Tuple[int, ...]       # post-sum kept-axis permutation → target
                                # dim order (agg axis stays last)

    @property
    def is_exact(self) -> bool:
        return not self.sum_axes


def build_secondary_program(wide: ViewShape,
                            narrow: ViewShape) -> SecondaryProgram:
    """Derive the re-aggregation plan, or raise ``ValueError`` when
    ``wide`` does not subsume ``narrow``."""
    missing = set(narrow.dims) - set(wide.dims)
    if missing:
        raise ValueError(
            f"view '{wide.name}' cannot answer '{narrow.name}': "
            f"group-by attrs {sorted(missing)} not in source dims "
            f"{wide.dims}")
    cols = _column_map(wide, narrow)
    if cols is None:
        have = set(wide.aggs)
        lost = [r for r in narrow.aggs if r not in have]
        raise ValueError(
            f"view '{wide.name}' cannot answer '{narrow.name}': "
            f"aggregate columns {lost} not materialized")
    keep = set(narrow.dims)
    sum_axes = tuple(i for i, d in enumerate(wide.dims) if d not in keep)
    kept_dims = [d for d in wide.dims if d in keep]
    perm = tuple(kept_dims.index(d) for d in narrow.dims)
    return SecondaryProgram(source=wide, target=narrow, col_idx=cols,
                            sum_axes=sum_axes, perm=perm)


def lower_secondary(sp: SecondaryProgram) -> Callable:
    """Lower to one jitted device function over the source view tensor.
    Column gather → additive fold over the summed-away axes → axis permute
    into the target's group-by order.  Compiled once per (source, target)
    signature pair and cached by the router."""
    col_idx = jnp.asarray(sp.col_idx, dtype=jnp.int32)
    sum_axes = sp.sum_axes
    # full transpose spec: permuted kept axes, then the trailing agg axis
    out_perm = tuple(sp.perm) + (len(sp.perm),)

    def reagg(arr: jnp.ndarray) -> jnp.ndarray:
        arr = jnp.take(arr, col_idx, axis=-1)
        if sum_axes:
            arr = jnp.sum(arr, axis=sum_axes)
        return jnp.transpose(arr, out_perm)

    return jax.jit(reagg)
