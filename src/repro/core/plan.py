"""Executable plan: IR build -> shared-scan schedule -> backend lowering.

The paper's bottom layers (Fig. 1 layers 6–8) as three separable stages:

  * ``ir.py`` compiles each view group into a typed :class:`GroupProgram`
    (gather specs, product axis frames, segment layouts, output perms) —
    built once here, never re-derived per call;
  * ``schedule.py`` fuses same-relation, dependency-independent groups into
    single shared scans and fixes execution order;
  * ``lowering/`` turns each fused step into device code: the ``xla``
    backend traces a blocked ``lax.scan`` (tracing *is* LMFAO's code
    generation, DESIGN.md §2 — the emitted HLO is specialized to the schema,
    the fused view set, and the aggregate batch), the ``pallas`` backend
    launches the MXU kernels in ``repro.kernels``.

Dynamic UDAF parameters (decision-tree thresholds) arrive through ``params``
as traced arrays — no recompilation between CART iterations.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.verify import verification_enabled
from repro.core.aggregates import Params
from repro.core.groups import ViewGroup
from repro.core.ir import (StepProgram, batched_param_names, build_programs,
                           compute_batched_vids, fuse_programs)
from repro.core.jointree import JoinTree
from repro.core.lowering import get_backend
from repro.core.pushdown import PushdownResult
from repro.core.schedule import Schedule, build_schedule
from repro.core.schema import DatabaseSchema
from repro.obs.trace import span

Columns = Mapping[str, Mapping[str, jnp.ndarray]]  # rel -> attr -> (n,)


def _ceil_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def validate_blocking(block_size, block_rows) -> None:
    """Shared validation for the two blocking knobs (PlanConfig and the
    session ExecutionConfig raise identically).  ``"auto"`` defers either to
    the compile-time autotuner (``core/autotune.py``)."""
    if block_size != "auto" and (
            not isinstance(block_size, int) or isinstance(block_size, bool)
            or block_size < 1):
        raise ValueError("block_size must be a positive int or 'auto'; "
                         f"got {block_size!r}")
    if block_rows != "auto" and (
            not isinstance(block_rows, int) or isinstance(block_rows, bool)
            or block_rows < 1 or block_rows % 8):
        raise ValueError("block_rows must be a positive multiple of 8 (the "
                         "MXU sublane tile: kernel row blocks below/off that "
                         f"alignment cannot be lowered) or 'auto'; got "
                         f"{block_rows!r}")


@dataclasses.dataclass
class PlanConfig:
    block_size: object = 4096       # lax.scan row-block (int | "auto")
    backend: str = "xla"            # lowering backend: "xla" | "pallas"
    interpret: Optional[bool] = None  # Pallas interpret mode; None = auto
                                      # (True everywhere except real TPU)
    fuse_scans: bool = True         # shared-scan fusion across view groups
    block_rows: object = 512        # Pallas kernel row grid (int | "auto")
    fuse_kernels: bool = True       # whole-step fused kernel launch (pallas)
    double_buffer: bool = True      # manual HBM→VMEM DMA pipeline (pallas)
    autotune_cache: Optional[str] = None  # autotuner cache path override
    verify_plans: Optional[bool] = None   # static plan verification
                                          # (DESIGN.md §12); None = auto:
                                          # on under pytest / REPRO_VERIFY

    def __post_init__(self):
        validate_blocking(self.block_size, self.block_rows)
        if self.verify_plans not in (None, True, False):
            raise ValueError("verify_plans must be True, False, or None "
                             f"(auto); got {self.verify_plans!r}")


class ExecutablePlan:
    """Executes a pushed-down, merged, grouped aggregate batch by driving the
    scheduler's fused scan steps through the configured lowering backend."""

    def __init__(self, schema: DatabaseSchema, tree: JoinTree, result: PushdownResult,
                 groups: Sequence[ViewGroup], config: Optional[PlanConfig] = None):
        self.schema = schema
        self.tree = tree
        self.result = result
        self.views = result.views
        self.groups = list(groups)
        self.config = config or PlanConfig()
        with span("compile.ir", n_groups=len(self.groups)):
            self.programs = build_programs(schema, result.views, self.groups)
        with span("compile.schedule", fuse=self.config.fuse_scans):
            self.schedule: Schedule = build_schedule(
                self.groups, fuse=self.config.fuse_scans)
            self.step_programs: List[StepProgram] = [
                fuse_programs([self.programs[gid] for gid in step.gids])
                for step in self.schedule.steps]
        #: :class:`~repro.analysis.verify.VerificationReport` of the static
        #: plan check (DESIGN.md §12), or None when verification is off
        self.last_verification = None
        if verification_enabled(self.config.verify_plans):
            from repro.analysis.verify import verify_plan
            with span("compile.verify"):
                self.last_verification = verify_plan(self)
        self.backend = get_backend(self.config.backend)
        # param-batch (node) axis bookkeeping (DESIGN.md §7.4)
        self.batched_vids = compute_batched_vids(result.views)
        self.batched_params = batched_param_names(result.views)
        self._autotuner = None
        #: per-step record of the last blocking resolution (``bind`` fills
        #: it when the config carries "auto"); surfaced by ``explain()``
        self.last_autotune: Optional[List[Dict[str, object]]] = None
        #: same, for the IVM delta tick (``resolve_delta_configs``)
        self.last_autotune_delta: Optional[List[Dict[str, object]]] = None

    # ------------------------------------------------------------- autotune

    @property
    def autotuner(self):
        """Lazily constructed (loads the on-disk cache once per plan)."""
        if self._autotuner is None:
            from repro.core.autotune import Autotuner
            self._autotuner = Autotuner(self.config.autotune_cache)
        return self._autotuner

    def concrete_config(self) -> PlanConfig:
        """The config with any ``"auto"`` blocking replaced by the static
        defaults — the last-resort fallback for paths that execute without a
        bind-time resolution.  The IVM delta tick no longer uses this: it
        resolves per-step via :meth:`resolve_delta_configs` against
        |update|-bucketed signatures."""
        from repro.core import autotune as at

        cfg = self.config
        if cfg.block_size == "auto" or cfg.block_rows == "auto":
            cfg = dataclasses.replace(
                cfg,
                block_size=(at.DEFAULT_BLOCK_SIZE
                            if cfg.block_size == "auto" else cfg.block_size),
                block_rows=(at.DEFAULT_BLOCK_ROWS
                            if cfg.block_rows == "auto" else cfg.block_rows))
        return cfg

    def resolve_step_configs(self, n_rows: Mapping[str, int],
                             n_nodes: Optional[int] = None) -> List[PlanConfig]:
        """One concrete :class:`PlanConfig` per scan step.  Static blocking
        passes the session config through untouched; ``"auto"`` resolves via
        the autotuner, keyed per step on (relation row count, widest segment
        layout, total payload width, node axis, backend, platform) — runs at
        ``bind`` time, *outside* any jit trace, so timing probes are legal."""
        cfg = self.config
        steps = self.schedule.steps
        if cfg.block_size != "auto" and cfg.block_rows != "auto":
            return [cfg] * len(steps)
        from repro.core import autotune as at

        platform = jax.default_backend()
        interpret = self._interpret_flag(platform)
        out, report = [], []
        with span("compile.autotune", n_steps=len(steps)):
            for step, prog in zip(steps, self.step_programs):
                n_seg, width = self._prog_tune_dims(prog, n_nodes)
                sig = at.signature_for_step(cfg.backend, platform, interpret,
                                            n_rows[step.rel], n_seg, width,
                                            n_nodes)
                res = self.autotuner.tune(sig)
                bs = (res.block_size if cfg.block_size == "auto"
                      else cfg.block_size)
                br = (res.block_rows if cfg.block_rows == "auto"
                      else cfg.block_rows)
                out.append(dataclasses.replace(cfg, block_size=bs,
                                               block_rows=br))
                report.append({"rel": step.rel, "key": sig.key(),
                               "block_size": bs, "block_rows": br,
                               "from_cache": res.from_cache,
                               "fallback": res.fallback})
        self.last_autotune = report
        return out

    def _interpret_flag(self, platform: str) -> bool:
        cfg = self.config
        if cfg.backend != "pallas":
            return False
        return (bool(cfg.interpret) if cfg.interpret is not None
                else platform != "tpu")

    def _prog_tune_dims(self, prog: StepProgram, n_nodes: Optional[int]):
        """(widest segment layout, total payload width) of one fused step —
        the shape facts a tuning signature carries besides the row count."""
        n_seg, width = 1, 0
        for vp in prog.views:
            lead = (n_nodes or 1) if vp.batched else 1
            if vp.hist is not None:
                n_seg = max(n_seg, vp.hist.n_buckets)
                width += 3 * lead
            else:
                if vp.seg is not None:
                    n_seg = max(n_seg, vp.seg.n_segments)
                w = vp.n_aggs * lead
                for d in vp.pulled_dims:
                    w *= d
                width += w
        return n_seg, max(width, 1)

    def resolve_delta_configs(self, steps, n_rows: Sequence[int],
                              n_nodes: Optional[int] = None) -> List[PlanConfig]:
        """One concrete :class:`PlanConfig` per IVM delta step (objects with
        ``.prog`` / ``.rel`` / ``.scans_delta``, see ``core/ivm.py``).
        ``n_rows[i]`` is step i's static scan length: the |update| pad bucket
        for delta scans, the rescanned relation's (per-shard) capacity
        otherwise.  Delta scans tune under ``delta=True`` signatures — their
        own cache lane — so ``block_size="auto"`` no longer degrades to the
        static defaults on the tick path.  Runs at tick-runner *build* time,
        outside any jit trace, so timing probes are legal."""
        cfg = self.config
        if cfg.block_size != "auto" and cfg.block_rows != "auto":
            return [cfg] * len(steps)
        from repro.core import autotune as at

        platform = jax.default_backend()
        interpret = self._interpret_flag(platform)
        out, report = [], []
        with span("compile.autotune", n_steps=len(steps), delta=True):
            for st, rows in zip(steps, n_rows):
                n_seg, width = self._prog_tune_dims(st.prog, n_nodes)
                sig = at.signature_for_step(cfg.backend, platform, interpret,
                                            max(int(rows), 1), n_seg, width,
                                            n_nodes, delta=st.scans_delta)
                res = self.autotuner.tune(sig)
                bs = (res.block_size if cfg.block_size == "auto"
                      else cfg.block_size)
                br = (res.block_rows if cfg.block_rows == "auto"
                      else cfg.block_rows)
                out.append(dataclasses.replace(cfg, block_size=bs,
                                               block_rows=br))
                report.append({"rel": st.rel, "delta": st.scans_delta,
                               "key": sig.key(), "block_size": bs,
                               "block_rows": br, "from_cache": res.from_cache,
                               "fallback": res.fallback})
        self.last_autotune_delta = report
        return out

    def n_kernel_launches(self) -> int:
        """Static kernel-launch *sites* per full pass (how many distinct
        device kernels one scan block dispatches, summed over steps) — the
        quantity launch fusion shrinks.  0 for the xla backend (no custom
        kernels)."""
        count = getattr(self.backend, "count_launches", None)
        if count is None:
            return 0
        return sum(count(prog, self.config) for prog in self.step_programs)

    # ------------------------------------------------------------------ api

    def bind(self, n_rows: Dict[str, int], n_nodes: Optional[int] = None):
        """Returns a pure fn(columns, params, offsets) -> {query: array}; the
        caller jits it.  ``n_rows`` are the *valid* row counts (columns may be
        padded beyond them); ``offsets`` shift validity windows for sharded
        execution (see distributed.py).  ``n_nodes`` is the param-batch (node)
        axis size — required iff the plan has batched params, in which case
        each batched param must carry a leading axis of that size and batched
        query outputs gain a leading node axis."""
        # the closure must capture its own copy: a retrace of a cached runner
        # would otherwise read row counts from whichever bind() ran last
        n_rows = dict(n_rows)
        if self.batched_params and n_nodes is None:
            raise ValueError(
                f"plan has batched params {sorted(self.batched_params)}; "
                "bind with n_nodes (use CompiledBatch.run_batched)")
        # "auto" blocking resolves here, once per bind, outside any trace —
        # the closure runs with concrete per-step configs
        with span("compile.bind", n_steps=len(self.schedule.steps)):
            step_configs = self.resolve_step_configs(n_rows, n_nodes)

        def run(columns: Columns, params: Params, offsets: Optional[Mapping[str, jnp.ndarray]] = None,
                psum_axes: Optional[Mapping[str, str]] = None):
            arrays = self._run_steps(columns, params, n_rows, n_nodes,
                                     offsets, psum_axes,
                                     step_configs=step_configs)
            return self.extract_outputs(arrays)

        return run

    def bind_arrays(self, n_rows: Dict[str, int], n_nodes: Optional[int] = None):
        """Like :meth:`bind`, but the returned fn yields *every* materialized
        view array keyed by vid (not just query outputs) — the full-recompute
        entry point of the IVM subsystem (``core/ivm.py``), which persists
        these arrays as maintained state.

        ``n_rows`` fixes the *column lengths* (static shapes).  The optional
        ``n_valid`` argument of the returned fn overrides per-relation valid
        row counts with **traced scalars** — how capacity-padded resident
        relations scan only their live prefix: the executable is keyed on
        buffer capacity while the row count stays a runtime value, so a
        growing stream retraces log2 times, not per tick."""
        n_rows = dict(n_rows)
        if self.batched_params and n_nodes is None:
            raise ValueError(
                f"plan has batched params {sorted(self.batched_params)}; "
                "bind with n_nodes")
        with span("compile.bind", n_steps=len(self.schedule.steps),
                  arrays=True):
            step_configs = self.resolve_step_configs(n_rows, n_nodes)

        def run(columns: Columns, params: Params,
                n_valid: Optional[Mapping[str, jnp.ndarray]] = None,
                psum_axes: Optional[Mapping[str, str]] = None):
            nv = dict(n_rows)
            if n_valid:
                nv.update(n_valid)
            return self._run_steps(columns, params, nv, n_nodes,
                                   psum_axes=psum_axes,
                                   step_configs=step_configs)

        return run

    def _run_steps(self, columns: Columns, params: Params,
                   n_rows: Dict[str, int], n_nodes: Optional[int],
                   offsets: Optional[Mapping[str, jnp.ndarray]] = None,
                   psum_axes: Optional[Mapping[str, str]] = None,
                   step_configs: Optional[Sequence[PlanConfig]] = None) -> Dict[int, jnp.ndarray]:
        offsets = offsets or {}
        psum_axes = psum_axes or {}
        if step_configs is None:
            step_configs = [self.concrete_config()] * len(self.schedule.steps)
        arrays: Dict[int, jnp.ndarray] = {}
        for step, prog, cfg in zip(self.schedule.steps, self.step_programs,
                                   step_configs):
            self.backend.run_step(
                prog, columns[step.rel], arrays, params,
                n_valid=n_rows[step.rel],
                offset=offsets.get(step.rel, 0), config=cfg,
                n_nodes=n_nodes)
            if step.rel in psum_axes:
                for vid in step.vids:
                    arrays[vid] = jax.lax.psum(arrays[vid],
                                               psum_axes[step.rel])
        return arrays

    def extract_outputs(self, arrays: Mapping[int, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
        """Read query results out of view arrays (column select + transpose
        from canonical to user group-by order)."""
        out = {}
        for qname, qo in self.result.outputs.items():
            arr = arrays[qo.vid]
            cols = jnp.take(arr, jnp.asarray(qo.cols), axis=-1)
            # canonical axis order -> user group-by order; a leading node
            # axis (batched outputs) stays in front
            lead = 1 if qo.vid in self.batched_vids else 0
            perm = [qo.canonical_group_by.index(a) + lead
                    for a in qo.query.group_by]
            perm = list(range(lead)) + perm + [lead + len(qo.query.group_by)]
            out[qname] = jnp.transpose(cols, perm)
        return out


# ---------------------------------------------------------------------------
# Naive baseline: materialize the join, then aggregate (the "DBMS" strategy
# the paper outperforms; used by benchmarks and as a test oracle).
# ---------------------------------------------------------------------------

def materialize_join(schema: DatabaseSchema, tables: Mapping[str, Mapping[str, np.ndarray]],
                     order: Optional[Sequence[str]] = None) -> Dict[str, np.ndarray]:
    """Host-side hash join of all relations (natural join), numpy columns."""
    names = list(order or schema.relations)
    joined: Dict[str, np.ndarray] = {a: np.asarray(c) for a, c in tables[names[0]].items()}
    for name in names[1:]:
        right = {a: np.asarray(c) for a, c in tables[name].items()}
        shared = sorted(set(joined) & set(right))
        if not shared:
            raise ValueError(f"cartesian product at {name}; provide a join order")
        # build hash index on right
        rkeys = list(zip(*[right[a].tolist() for a in shared]))
        index: Dict[Tuple, List[int]] = {}
        for i, k in enumerate(rkeys):
            index.setdefault(k, []).append(i)
        lkeys = list(zip(*[joined[a].tolist() for a in shared]))
        li, ri = [], []
        for i, k in enumerate(lkeys):
            for j in index.get(k, ()):
                li.append(i)
                ri.append(j)
        li = np.asarray(li, dtype=np.int64)
        ri = np.asarray(ri, dtype=np.int64)
        out = {a: c[li] for a, c in joined.items()}
        for a, c in right.items():
            if a not in out:
                out[a] = c[ri]
        joined = out
    return joined
