"""Multi-Output Optimization + execution (paper Fig. 1 layers 6–8).

Each view group becomes one *multi-output plan*: a single blocked scan over the
group's relation that computes every outgoing view at once.  The scan is the
TPU-native analogue of LMFAO's trie-ordered nested-loop pass:

  * the relation's rows stream through ``lax.scan`` in fixed-size blocks
    (HBM→VMEM tiles on real hardware);
  * incoming views are **dense tensors** gathered once per block per view —
    the "lookup into incoming views" — and shared by all aggregates in the
    group (the paper's shared scan);
  * group-by attributes local to the relation become segment ids
    (``segment_sum`` = the trie's grouped visit); attributes pulled up from
    child views are dense axes, so products across subtrees are broadcast
    outer products lowered onto the MXU;
  * the whole plan is traced and ``jax.jit``-compiled — tracing *is* LMFAO's
    code-generation layer (DESIGN.md §2): the emitted HLO is specialized to
    the schema, the view group, and the aggregate batch, with XLA performing
    the constant/common-subexpression work of the paper's generated C++.

Dynamic UDAF parameters (decision-tree thresholds) arrive through ``params``
as traced arrays — no recompilation between CART iterations.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregates import Params, Term
from repro.core.groups import ViewGroup
from repro.core.jointree import JoinTree
from repro.core.pushdown import AggColSpec, ColRef, PushdownResult, ViewDef
from repro.core.schema import DatabaseSchema

Columns = Mapping[str, Mapping[str, jnp.ndarray]]  # rel -> attr -> (n,)


def _ceil_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


@dataclasses.dataclass
class PlanConfig:
    block_size: int = 4096
    interpret_kernels: bool = False  # route hot inner ops through Pallas (interpret on CPU)


class ExecutablePlan:
    """Executes a pushed-down, merged, grouped aggregate batch."""

    def __init__(self, schema: DatabaseSchema, tree: JoinTree, result: PushdownResult,
                 groups: Sequence[ViewGroup], config: Optional[PlanConfig] = None):
        self.schema = schema
        self.tree = tree
        self.result = result
        self.views = result.views
        self.groups = list(groups)
        self.config = config or PlanConfig()
        self._n_rows: Dict[str, int] = {}

    # ------------------------------------------------------------------ api

    def bind(self, n_rows: Dict[str, int]):
        """Returns a pure fn(columns, params, offsets) -> {query: array}; the
        caller jits it.  ``n_rows`` are the *valid* row counts (columns may be
        padded beyond them); ``offsets`` shift validity windows for sharded
        execution (see distributed.py)."""
        self._n_rows = dict(n_rows)

        def run(columns: Columns, params: Params, offsets: Optional[Mapping[str, jnp.ndarray]] = None,
                psum_axes: Optional[Mapping[str, str]] = None):
            offsets = offsets or {}
            psum_axes = psum_axes or {}
            arrays: Dict[int, jnp.ndarray] = {}
            for g in self.groups:
                self._run_group(g, columns[g.rel], arrays, params,
                                offsets.get(g.rel, 0))
                if g.rel in psum_axes:
                    for vid in g.vids:
                        arrays[vid] = jax.lax.psum(arrays[vid], psum_axes[g.rel])
            out = {}
            for qname, qo in self.result.outputs.items():
                arr = arrays[qo.vid]
                cols = jnp.take(arr, jnp.asarray(qo.cols), axis=-1)
                # canonical axis order -> user group-by order
                perm = [qo.canonical_group_by.index(a) for a in qo.query.group_by]
                perm = perm + [len(perm)]  # agg axis last
                out[qname] = jnp.transpose(cols, perm)
            return out

        return run

    # ------------------------------------------------------------- internals

    def _rel_attrs(self, rel: str) -> frozenset:
        return self.schema.relation(rel).attr_set

    def _dom(self, attr: str) -> int:
        return self.schema.domain(attr)

    def _run_group(self, g: ViewGroup, rel_cols: Mapping[str, jnp.ndarray],
                   arrays: Dict[int, jnp.ndarray], params: Params, offset) -> None:
        n_valid = self._n_rows[g.rel]
        n_pad = int(next(iter(rel_cols.values())).shape[0])
        B = min(self.config.block_size, max(n_pad, 1))
        n_blocks = max(_ceil_to(n_pad, B) // B, 1)

        rel_attr_set = self._rel_attrs(g.rel)
        out_views = [self.views[vid] for vid in g.vids]

        # --- static prep per view -----------------------------------------
        # child views referenced by this group, with their gather attrs
        child_vids = sorted({ref.vid
                             for w in out_views
                             for col in w.agg_cols
                             for prod in col.products
                             for ref in prod.child_cols})
        child_gather: Dict[int, Tuple[Tuple[str, ...], Tuple[str, ...]]] = {}
        for vid in child_vids:
            v = self.views[vid]
            gat = tuple(a for a in v.group_by if a in rel_attr_set)
            rest = tuple(a for a in v.group_by if a not in rel_attr_set)
            # gather attrs must form the axis prefix of the child array
            if v.group_by[:len(gat)] != gat:
                raise AssertionError(f"view {vid}: gather attrs not a prefix: "
                                     f"{v.group_by} vs {gat}")
            child_gather[vid] = (gat, rest)

        specs = []
        for w in out_views:
            local = tuple(a for a in w.group_by if a in rel_attr_set)
            pulled_out = tuple(a for a in w.group_by if a not in rel_attr_set)
            specs.append((w, local, pulled_out))

        # --- pad + block the relation --------------------------------------
        total = n_blocks * B
        cols_blocked = {}
        for a, c in rel_cols.items():
            pad = total - n_pad
            cp = jnp.pad(c, (0, pad)) if pad else c
            cols_blocked[a] = cp.reshape(n_blocks, B)
        iota = jnp.arange(n_blocks, dtype=jnp.int32)

        # --- accumulators ---------------------------------------------------
        accs = []
        for w, local, pulled_out in specs:
            n_local = int(np.prod([self._dom(a) for a in local], dtype=np.int64)) if local else 0
            shape = ([n_local] if local else []) + [self._dom(a) for a in pulled_out] + [w.n_aggs]
            accs.append(jnp.zeros(shape, dtype=jnp.float32))

        def body(carry, xs):
            accs = carry
            blk_cols, blk_i = xs
            # local row index within this shard's (possibly padded) partition;
            # valid iff inside both the local partition and the global window
            row_idx = blk_i * B + jnp.arange(B, dtype=jnp.int32)
            limit = jnp.minimum(jnp.asarray(n_pad, jnp.int32),
                                jnp.asarray(n_valid, jnp.int32) - jnp.asarray(offset, jnp.int32))
            valid = (row_idx < limit).astype(jnp.float32)

            gathered: Dict[int, jnp.ndarray] = {}
            for vid in child_vids:
                gat, _rest = child_gather[vid]
                idx = tuple(blk_cols[a] for a in gat)
                gathered[vid] = arrays[vid][idx] if idx else (
                    jnp.broadcast_to(arrays[vid], (B,) + arrays[vid].shape))

            new_accs = []
            for (w, local, pulled_out), acc in zip(specs, accs):
                payload = self._view_payload(w, pulled_out, blk_cols, gathered,
                                             child_gather, params, valid, B)
                if local:
                    seg = self._segment_ids(blk_cols, local)
                    n_local = acc.shape[0]
                    contrib = jax.ops.segment_sum(payload, seg, num_segments=n_local)
                else:
                    contrib = payload.sum(axis=0)
                new_accs.append(acc + contrib)
            return tuple(new_accs), None

        accs, _ = jax.lax.scan(body, tuple(accs), (cols_blocked, iota))

        # --- finalize shapes -------------------------------------------------
        for (w, local, pulled_out), acc in zip(specs, accs):
            dims = [self._dom(a) for a in local] + [self._dom(a) for a in pulled_out]
            arr = acc.reshape(dims + [w.n_aggs])
            computed_order = list(local) + list(pulled_out)
            perm = [computed_order.index(a) for a in w.group_by] + [len(computed_order)]
            arrays[w.vid] = jnp.transpose(arr, perm)

    def _segment_ids(self, blk_cols, local: Tuple[str, ...]) -> jnp.ndarray:
        seg = jnp.zeros_like(blk_cols[local[0]])
        for a in local:
            seg = seg * self._dom(a) + blk_cols[a]
        return seg

    def _view_payload(self, w: ViewDef, pulled_out: Tuple[str, ...], blk_cols,
                      gathered, child_gather, params: Params, valid, B: int) -> jnp.ndarray:
        """(B, *pulled_out_dims, n_aggs) contributions of one row block to view w."""
        out_cols = []
        for colspec in w.agg_cols:
            col = None
            for prod in colspec.products:
                p = self._product_payload(w, prod, pulled_out, blk_cols, gathered,
                                          child_gather, params, B)
                col = p if col is None else col + p
            out_cols.append(col * self._reshape_axes(valid, (), tuple(pulled_out), B))
        target = (B,) + tuple(self._dom(a) for a in pulled_out)
        out_cols = [jnp.broadcast_to(c, target) for c in out_cols]
        return jnp.stack(out_cols, axis=-1)

    def _product_payload(self, w: ViewDef, prod, pulled_out: Tuple[str, ...], blk_cols,
                         gathered, child_gather, params: Params, B: int) -> jnp.ndarray:
        rel_attr_set = self._rel_attrs(w.rel)
        used = set()
        for ref in prod.child_cols:
            used |= set(child_gather[ref.vid][1])
        for t in prod.local_terms:
            used |= {a for a in t.attrs() if a not in rel_attr_set}
        # compute axes: output pulled dims first (kept), extra used dims after (summed)
        extra = tuple(sorted(used - set(pulled_out)))
        axes = tuple(pulled_out) + extra

        acc = None
        for ref in prod.child_cols:
            _gat, rest = child_gather[ref.vid]
            x = gathered[ref.vid][..., ref.col]  # (B, *rest_dims)
            x = self._align(x, rest, axes, B)
            acc = x if acc is None else acc * x
        for t in prod.local_terms:
            env = {}
            for a in t.attrs():
                if a in rel_attr_set:
                    env[a] = self._reshape_axes(blk_cols[a], (), axes, B)
                else:
                    dom = jnp.arange(self._dom(a), dtype=jnp.int32)
                    env[a] = self._align(dom[None, :], (a,), axes, B, broadcast_rows=True)
            x = t.evaluate(env, params)
            x = jnp.asarray(x, dtype=jnp.float32)
            if x.ndim == 0:
                x = jnp.broadcast_to(x, (B,) + (1,) * len(axes))
            acc = x if acc is None else acc * x
        if acc is None:  # pure count: Π over empty set = 1
            acc = jnp.ones((B,) + (1,) * len(axes), dtype=jnp.float32)
        # marginalize the non-output axes
        if extra:
            full = (B,) + tuple(self._dom(a) for a in axes)
            acc = jnp.broadcast_to(acc, full)
            acc = acc.sum(axis=tuple(range(1 + len(pulled_out), 1 + len(axes))))
        return acc

    def _align(self, x: jnp.ndarray, src_axes: Tuple[str, ...], dst_axes: Tuple[str, ...],
               B: int, broadcast_rows: bool = False) -> jnp.ndarray:
        """Map (B, *src_dims) onto (B, *dst positions) with singleton axes
        elsewhere.  All src axes must appear in dst."""
        present = [a for a in dst_axes if a in src_axes]
        if tuple(present) != tuple(src_axes):
            perm = [0] + [1 + src_axes.index(a) for a in present]
            x = jnp.transpose(x, perm)
        shape = [x.shape[0]] + [x.shape[1 + present.index(a)] if a in present else 1
                                for a in dst_axes]
        return x.reshape(shape)

    def _reshape_axes(self, col: jnp.ndarray, src: Tuple[str, ...],
                      dst_axes: Tuple[str, ...], B: int) -> jnp.ndarray:
        return col.reshape((B,) + (1,) * len(dst_axes))


# ---------------------------------------------------------------------------
# Naive baseline: materialize the join, then aggregate (the "DBMS" strategy
# the paper outperforms; used by benchmarks and as a test oracle).
# ---------------------------------------------------------------------------

def materialize_join(schema: DatabaseSchema, tables: Mapping[str, Mapping[str, np.ndarray]],
                     order: Optional[Sequence[str]] = None) -> Dict[str, np.ndarray]:
    """Host-side hash join of all relations (natural join), numpy columns."""
    names = list(order or schema.relations)
    joined: Dict[str, np.ndarray] = {a: np.asarray(c) for a, c in tables[names[0]].items()}
    for name in names[1:]:
        right = {a: np.asarray(c) for a, c in tables[name].items()}
        shared = sorted(set(joined) & set(right))
        if not shared:
            raise ValueError(f"cartesian product at {name}; provide a join order")
        # build hash index on right
        rkeys = list(zip(*[right[a].tolist() for a in shared]))
        index: Dict[Tuple, List[int]] = {}
        for i, k in enumerate(rkeys):
            index.setdefault(k, []).append(i)
        lkeys = list(zip(*[joined[a].tolist() for a in shared]))
        li, ri = [], []
        for i, k in enumerate(lkeys):
            for j in index.get(k, ()):
                li.append(i)
                ri.append(j)
        li = np.asarray(li, dtype=np.int64)
        ri = np.asarray(ri, dtype=np.int64)
        out = {a: c[li] for a, c in joined.items()}
        for a, c in right.items():
            if a not in out:
                out[a] = c[ri]
        joined = out
    return joined
