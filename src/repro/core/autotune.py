"""Compile-time kernel autotuning with a persisted on-disk cache.

The Pallas backend ran one static ``block_rows = 512`` kernel row grid and
one static ``block_size = 4096`` scan block for every relation shape; LMFAO's
bottom layers win precisely by specializing this kind of low-level choice to
the workload.  This module times candidate ``(block_size, block_rows)``
pairs on synthetic data matching a step's *signature* — relation row count,
segment-layout width, payload width, node-axis N, backend, host platform —
and memoizes the winner.

Keying follows the PR-5 runner-cache convention (a tuple of exactly the
inputs that determine the compiled program); signatures bucket the continuous
dimensions (row count, widths) to the next power of two so one tuning run
serves a whole neighborhood of shapes instead of re-timing per relation.

The cache persists as JSON (``REPRO_AUTOTUNE_CACHE`` env, default
``~/.cache/repro/autotune.json``) so *warm sessions never re-tune*: a second
process with the same signatures does zero timing runs (``n_timed`` stays 0 —
counter-asserted in tests).  Corrupt files load as empty (re-tune); corrupt
or stale *entries* fall back to the static defaults instead of raising — a
bad cache must never take down a session (DESIGN.md §10).

Entry points: :class:`Autotuner` (owned by ``ExecutablePlan`` when the
config carries ``block_size="auto"`` / ``block_rows="auto"``) and
:func:`signature_for_step` (the bucketing rule).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, Optional, Tuple

from repro.obs.trace import span

DEFAULT_BLOCK_SIZE = 4096
DEFAULT_BLOCK_ROWS = 512
#: candidate grids — block_rows stays MXU-sublane aligned (multiples of 8)
BLOCK_SIZE_CANDIDATES = (1024, 4096, 16384)
BLOCK_ROWS_CANDIDATES = (128, 256, 512, 1024)
#: timing probes cap the row axis: above this the per-row cost is flat
MAX_PROBE_ROWS = 16384
CACHE_VERSION = 2   # v2: delta-scan signatures (|update|-bucketed IVM shapes)


def default_cache_path() -> str:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "autotune.json")


def _pow2_bucket(n: int) -> int:
    b = 1
    while b < max(int(n), 1):
        b *= 2
    return b


@dataclasses.dataclass(frozen=True)
class TuneSignature:
    """What a tuned choice is keyed on — the step facts that change the
    optimal blocking.  Continuous dims are pow2-bucketed."""

    backend: str        # lowering backend ("xla" | "pallas")
    platform: str       # jax.default_backend(): "cpu" | "tpu" | "gpu"
    interpret: bool     # Pallas interpret mode (CPU) times very differently
    n_rows: int         # pow2 bucket of the scanned relation's row count
    n_segments: int     # pow2 bucket of the widest segment layout in the step
    payload_width: int  # pow2 bucket of the step's total payload columns
    n_nodes: int        # param-batch (node) axis size (1 when unbatched)
    delta: bool = False  # IVM delta scan: n_rows is the |update| pad bucket

    def key(self) -> str:
        return (f"v{CACHE_VERSION}/{self.backend}/{self.platform}/"
                f"i{int(self.interpret)}/r{self.n_rows}/s{self.n_segments}/"
                f"w{self.payload_width}/n{self.n_nodes}/d{int(self.delta)}")


@dataclasses.dataclass(frozen=True)
class TuneResult:
    block_size: int
    block_rows: int
    from_cache: bool
    fallback: bool = False   # True when a corrupt entry forced the defaults


def signature_for_step(backend: str, platform: str, interpret: bool,
                       n_rows: int, n_segments: int, payload_width: int,
                       n_nodes: Optional[int], delta: bool = False) -> TuneSignature:
    """``delta=True`` marks an IVM delta scan: ``n_rows`` is then the
    |update| pad bucket, tiny relative to full-relation scans, and the
    optimal blocking differs enough to deserve its own cache lane."""
    return TuneSignature(
        backend=backend, platform=platform, interpret=bool(interpret),
        n_rows=_pow2_bucket(n_rows), n_segments=_pow2_bucket(n_segments),
        payload_width=_pow2_bucket(payload_width),
        n_nodes=_pow2_bucket(n_nodes or 1), delta=bool(delta))


def _valid_entry(e) -> bool:
    if not isinstance(e, dict):
        return False
    bs, br = e.get("block_size"), e.get("block_rows")
    if not isinstance(bs, int) or isinstance(bs, bool) or bs < 1:
        return False
    if not isinstance(br, int) or isinstance(br, bool) or br < 8 or br % 8:
        return False
    return True


class Autotuner:
    """Times candidates per signature; memoizes in memory and on disk.

    ``n_timed`` counts individual timing runs (0 across a warm session),
    ``n_hits``/``n_misses`` count cache lookups, ``n_fallbacks`` counts
    corrupt entries that degraded to the static defaults."""

    def __init__(self, cache_path: Optional[str] = None):
        self.cache_path = cache_path or default_cache_path()
        self.n_timed = 0
        self.n_hits = 0
        self.n_misses = 0
        self.n_fallbacks = 0
        self._entries: Dict[str, dict] = self._load()

    # -- persistence ---------------------------------------------------------

    def _load(self) -> Dict[str, dict]:
        try:
            with open(self.cache_path) as f:
                blob = json.load(f)
        except (OSError, ValueError):
            return {}     # missing or corrupt file: start empty, re-tune
        if not isinstance(blob, dict) or blob.get("version") != CACHE_VERSION:
            return {}     # stale format: discard wholesale
        entries = blob.get("entries")
        return dict(entries) if isinstance(entries, dict) else {}

    def _save(self) -> None:
        path = self.cache_path
        try:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            tmp = f"{path}.tmp.{os.getpid()}"
            with open(tmp, "w") as f:
                json.dump({"version": CACHE_VERSION, "entries": self._entries},
                          f, indent=1, sort_keys=True)
            os.replace(tmp, path)    # atomic: concurrent readers never see a
        except OSError:              # torn file
            pass                     # read-only FS etc.: cache stays in-memory

    # -- tuning --------------------------------------------------------------

    def tune(self, sig: TuneSignature) -> TuneResult:
        """The tuned ``(block_size, block_rows)`` for a signature — from the
        in-memory/on-disk cache when present (zero timing runs), otherwise
        timed now and persisted."""
        key = sig.key()
        entry = self._entries.get(key)
        if entry is not None:
            if _valid_entry(entry):
                self.n_hits += 1
                return TuneResult(entry["block_size"], entry["block_rows"],
                                  from_cache=True)
            # corrupt entry: degrade to defaults, never raise mid-compile
            self.n_fallbacks += 1
            return TuneResult(DEFAULT_BLOCK_SIZE, DEFAULT_BLOCK_ROWS,
                              from_cache=False, fallback=True)
        self.n_misses += 1
        with span("autotune.tune", key=key):
            block_size, block_rows = self._time_candidates(sig)
        self._entries[key] = {"block_size": int(block_size),
                              "block_rows": int(block_rows),
                              "sig": dataclasses.asdict(sig)}
        self._save()
        return TuneResult(int(block_size), int(block_rows), from_cache=False)

    # -- timing probes -------------------------------------------------------

    def _probe_rows(self, sig: TuneSignature) -> int:
        return min(sig.n_rows, MAX_PROBE_ROWS)

    def _time(self, fn) -> float:
        """Median-of-3 wall seconds after one warmup (compile) run.

        The only telemetry site allowed to sync the device: probes run at
        bind time, outside any trace and outside the steady-state contract
        (their whole purpose is wall timing)."""
        import jax
        with span("autotune.probe"):
            jax.block_until_ready(fn())
            self.n_timed += 1
            times = []
            for _ in range(3):
                t0 = time.perf_counter()
                jax.block_until_ready(fn())
                times.append(time.perf_counter() - t0)
        times.sort()
        return times[1]

    def _time_candidates(self, sig: TuneSignature) -> Tuple[int, int]:
        import jax
        import jax.numpy as jnp
        import numpy as np

        rng = np.random.default_rng(0)
        n = self._probe_rows(sig)
        n_seg = max(sig.n_segments, 1)
        width = max(sig.payload_width, 1)
        seg = jnp.asarray(rng.integers(0, n_seg, n).astype(np.int32))
        pay = jnp.asarray(rng.normal(size=(n, width)).astype(np.float32))

        # block_rows: the kernel row grid (pallas only — the xla backend has
        # no kernel grid, so it keeps the default)
        block_rows = DEFAULT_BLOCK_ROWS
        if sig.backend == "pallas":
            from repro.kernels import ops
            best = None
            for cand in BLOCK_ROWS_CANDIDATES:
                t = self._time(lambda: ops.seg_aggregate(
                    seg, pay, n_seg, block_rows=cand,
                    interpret=sig.interpret))
                if best is None or t < best[0]:
                    best = (t, cand)
            block_rows = best[1]

        # block_size: the outer lax.scan row block (both backends) — probe a
        # blocked segment-sum scan shaped like one step
        best = None
        for cand in BLOCK_SIZE_CANDIDATES:
            B = min(cand, n)
            n_blocks = max(n // B, 1)
            segs = seg[:n_blocks * B].reshape(n_blocks, B)
            pays = pay[:n_blocks * B].reshape(n_blocks, B, width)

            def probe(segs=segs, pays=pays):
                def body(acc, xs):
                    s, p = xs
                    return acc + jax.ops.segment_sum(
                        p, s, num_segments=n_seg), None
                acc = jnp.zeros((n_seg, width), jnp.float32)
                return jax.lax.scan(body, acc, (segs, pays))[0]

            t = self._time(jax.jit(probe))
            if best is None or t < best[0]:
                best = (t, cand)
        return best[1], block_rows
