"""Find Roots layer (paper §3.3, Fig. 1 layer 2) — novel in LMFAO.

Each query in the batch may be evaluated over the *same* join tree rooted at a
*different* node.  Root choice follows the paper's approximation: weight each
relation by the fraction of the query's group-by attributes it holds (equal
fractions across all relations for group-by-free queries), accumulate weights
over the batch, then assign relations as roots in decreasing total weight —
each relation claims all unassigned queries that considered it a possible
root.  Ties break toward larger relations.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.aggregates import Query
from repro.core.jointree import JoinTree


def find_roots(tree: JoinTree, queries: Sequence[Query],
               sizes: Optional[Dict[str, int]] = None) -> Dict[str, str]:
    """Returns query name → root relation name."""
    sizes = sizes or {}
    nodes = tree.nodes
    m = len(nodes)

    weight: Dict[str, float] = {n: 0.0 for n in nodes}
    candidates: Dict[str, List[str]] = {}
    for q in queries:
        if not q.group_by:
            for n in nodes:
                weight[n] += 1.0 / m
            candidates[q.name] = list(nodes)
        else:
            f = float(len(q.group_by))
            cand = []
            for n in nodes:
                k = len(frozenset(q.group_by) & tree.schema.relation(n).attr_set)
                if k:
                    weight[n] += k / f
                    cand.append(n)
            # a query whose group-by attrs appear nowhere is invalid upstream;
            # if none of its attrs are local to a single relation, all nodes
            # carrying at least one attr are candidates (views pull the rest).
            candidates[q.name] = cand if cand else list(nodes)

    order = sorted(nodes, key=lambda n: (weight[n], sizes.get(n, 0)), reverse=True)

    roots: Dict[str, str] = {}
    for n in order:
        for q in queries:
            if q.name not in roots and n in candidates[q.name]:
                roots[q.name] = n
    return roots


def single_root(tree: JoinTree, queries: Sequence[Query],
                sizes: Optional[Dict[str, int]] = None) -> Dict[str, str]:
    """Ablation baseline: all queries share one root (the heaviest/largest
    relation) — 'LMFAO without multi-root' in Fig. 5."""
    sizes = sizes or {}
    multi = find_roots(tree, queries, sizes)
    counts: Dict[str, int] = {}
    for r in multi.values():
        counts[r] = counts.get(r, 0) + 1
    best = max(tree.nodes, key=lambda n: (counts.get(n, 0), sizes.get(n, 0)))
    return {q.name: best for q in queries}
