"""Group-program IR: the compile-time form of a multi-output shared scan.

The seed executor re-derived every static fact about a view group (child
gather axes, product alignment, segment layouts, output permutations) on each
``bind``; this module lifts that preparation into a typed, frozen IR built
once at compile time from ``PushdownResult`` + ``ViewGroup``s (DESIGN.md §3).
A :class:`GroupProgram` is the scan program for one view group; the scheduler
(``schedule.py``) fuses programs over the same relation into a
:class:`StepProgram`, and the lowering backends (``lowering/``) consume step
programs without ever touching ``ViewDef``/``ViewGroup`` again.

Layout conventions (shared by every backend):

  * a view's accumulator is ``(n_segments?, *pulled_dims, n_aggs)`` — the
    flattened local group-by key first (if any), pulled-up dense axes next,
    the aggregate column axis last;
  * a product's working axes are ``pulled ++ extra`` where ``extra`` are
    attribute axes used by terms/child columns but marginalized before
    accumulation (paper §3.4's partial aggregates);
  * the finalize step reshapes the flat segment axis back into one axis per
    local attribute and transposes into the view's canonical group-by order.

:class:`HistSpec` marks views matching the decision-tree node-histogram
pattern ``[Σ cond, Σ cond·y, Σ cond·y²]`` grouped by one local attribute —
the shape the fused ``kernels/tree_hist`` Pallas kernel computes in a single
VMEM-resident pass (paper Table 3 row 3).

**Param-batch (node) axis** (DESIGN.md §7.4): a term consuming a
``Param(batched=True)`` makes its :class:`TermApp` *batched*; batchedness
propagates to the product, to the view, and transitively to every view that
gathers a batched child (:func:`compute_batched_vids`).  Batched view
accumulators grow an optional leading node axis of runtime size ``N``
(``acc_shape`` stays the unbatched shape; backends prepend ``N``), so one
relation pass serves all ``N`` parameter settings of the compiled batch.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Dict, FrozenSet, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.aggregates import Pow, Term, Var
from repro.core.groups import ViewGroup
from repro.core.pushdown import ViewDef
from repro.core.schema import DatabaseSchema


@dataclasses.dataclass(frozen=True)
class GatherSpec:
    """How a scan gathers one incoming child view: ``gather`` attrs (local
    columns of the scanned relation) index the child array's axis prefix;
    ``rest`` are the dense axes the gathered slice keeps.  ``batched`` child
    arrays carry a leading node axis the gather must skip."""

    vid: int
    gather: Tuple[str, ...]
    rest: Tuple[str, ...]
    batched: bool = False


@dataclasses.dataclass(frozen=True)
class ChildColRef:
    """One gathered child-view column inside a product, with the dense axes
    (``rest``) it carries after the gather."""

    vid: int
    col: int
    rest: Tuple[str, ...]
    batched: bool = False


@dataclasses.dataclass(frozen=True)
class TermApp:
    """A local term application: ``col_attrs`` bind to scanned columns,
    ``dom_attrs`` bind to domain-iota axes of the product's axis frame.
    ``batched`` terms resolve a batched param and emit a leading node axis."""

    term: Term
    col_attrs: Tuple[str, ...]
    dom_attrs: Tuple[str, ...]
    dom_dims: Tuple[int, ...]
    batched: bool = False


@dataclasses.dataclass(frozen=True)
class ProductProgram:
    """One product Π child-cols × Π local-terms evaluated in the axis frame
    ``axes = pulled ++ extra``; the trailing ``len(axes) - n_keep`` axes are
    marginalized (summed out) before the product joins its column."""

    child_refs: Tuple[ChildColRef, ...]
    local_terms: Tuple[TermApp, ...]
    axes: Tuple[str, ...]
    axis_dims: Tuple[int, ...]
    n_keep: int
    batched: bool = False


@dataclasses.dataclass(frozen=True)
class ColProgram:
    """One output aggregate column: a sum of product programs."""

    products: Tuple[ProductProgram, ...]


@dataclasses.dataclass(frozen=True)
class SegmentSpec:
    """Flattened local group-by key: mixed-radix code over ``attrs``."""

    attrs: Tuple[str, ...]
    dims: Tuple[int, ...]
    n_segments: int


@dataclasses.dataclass(frozen=True)
class HistSpec:
    """Decision-tree node-histogram pattern (see module docstring): the
    view's three columns are ``cond``, ``cond·y``, ``cond·y²`` bucketed by
    ``code_attr`` — routable through ``kernels/tree_hist``."""

    code_attr: str
    n_buckets: int
    y_attr: str
    cond: ColProgram


@dataclasses.dataclass(frozen=True)
class ViewProgram:
    """Complete scan program for one output view."""

    vid: int
    rel: str
    group_by: Tuple[str, ...]
    local: Tuple[str, ...]
    pulled: Tuple[str, ...]
    pulled_dims: Tuple[int, ...]
    n_aggs: int
    seg: Optional[SegmentSpec]
    cols: Tuple[ColProgram, ...]
    acc_shape: Tuple[int, ...]      # unbatched; batched views prepend (N,)
    out_dims: Tuple[int, ...]
    out_perm: Tuple[int, ...]
    hist: Optional[HistSpec]
    batched: bool = False


@dataclasses.dataclass(frozen=True)
class GroupProgram:
    """Scan program for one view group: all its view programs plus the union
    of child gathers they need."""

    gid: int
    rel: str
    views: Tuple[ViewProgram, ...]
    gathers: Tuple[GatherSpec, ...]


@dataclasses.dataclass(frozen=True)
class StepProgram:
    """Scan program for one (possibly fused) scheduler step: the shared scan
    computing every view of every fused group in a single relation pass."""

    rel: str
    gids: Tuple[int, ...]
    views: Tuple[ViewProgram, ...]
    gathers: Tuple[GatherSpec, ...]


# ---------------------------------------------------------------------- build

def compute_batched_vids(views: Mapping[int, ViewDef]) -> FrozenSet[int]:
    """Vids whose accumulators carry the param-batch (node) axis: a view is
    batched iff any of its terms consumes a batched param, or (transitively)
    it gathers a batched child view.  Fixpoint over the view DAG."""
    batched: set = set()
    for vid, w in views.items():
        for col in w.agg_cols:
            for prod in col.products:
                if any(t.is_batched() for t in prod.local_terms):
                    batched.add(vid)
    changed = True
    while changed:
        changed = False
        for vid, w in views.items():
            if vid in batched:
                continue
            refs = {ref.vid for col in w.agg_cols for prod in col.products
                    for ref in prod.child_cols}
            if refs & batched:
                batched.add(vid)
                changed = True
    return frozenset(batched)


def batched_param_names(views: Mapping[int, ViewDef]) -> FrozenSet[str]:
    """Names of all batched params referenced anywhere in the view DAG —
    ``run_batched`` reads the node-batch size ``N`` off their leading axis."""
    return frozenset(p.name for w in views.values() for col in w.agg_cols
                     for prod in col.products for t in prod.local_terms
                     for p in t.params() if p.batched)


def build_group_program(schema: DatabaseSchema, views: Mapping[int, ViewDef],
                        group: ViewGroup,
                        batched_vids: FrozenSet[int] = frozenset()) -> GroupProgram:
    rel_attrs = schema.relation(group.rel).attr_set
    out_views = [views[vid] for vid in group.vids]

    child_vids = sorted({ref.vid
                         for w in out_views
                         for col in w.agg_cols
                         for prod in col.products
                         for ref in prod.child_cols})
    gathers = []
    child_rest: Dict[int, Tuple[str, ...]] = {}
    for vid in child_vids:
        v = views[vid]
        gat = tuple(a for a in v.group_by if a in rel_attrs)
        rest = tuple(a for a in v.group_by if a not in rel_attrs)
        # gather attrs must form the axis prefix of the child array
        if v.group_by[:len(gat)] != gat:
            raise AssertionError(f"view {vid}: gather attrs not a prefix: "
                                 f"{v.group_by} vs {gat}")
        gathers.append(GatherSpec(vid, gat, rest, batched=vid in batched_vids))
        child_rest[vid] = rest

    vps = tuple(_build_view_program(schema, w, rel_attrs, child_rest,
                                    batched_vids)
                for w in out_views)
    return GroupProgram(gid=group.gid, rel=group.rel, views=vps,
                        gathers=tuple(gathers))


def build_programs(schema: DatabaseSchema, views: Mapping[int, ViewDef],
                   groups: Sequence[ViewGroup]) -> Dict[int, GroupProgram]:
    batched_vids = compute_batched_vids(views)
    return {g.gid: build_group_program(schema, views, g, batched_vids)
            for g in groups}


def fuse_programs(progs: Sequence[GroupProgram]) -> StepProgram:
    """Merge same-relation group programs into one shared-scan step program.
    Gather specs for a child view are identical across groups (they depend
    only on the scanned relation), so the union dedups by vid."""
    rel = progs[0].rel
    assert all(p.rel == rel for p in progs), [p.rel for p in progs]
    views = tuple(vp for p in progs for vp in p.views)
    by_vid: Dict[int, GatherSpec] = {}
    for p in progs:
        for gs in p.gathers:
            by_vid[gs.vid] = gs
    return StepProgram(rel=rel, gids=tuple(p.gid for p in progs), views=views,
                       gathers=tuple(by_vid[v] for v in sorted(by_vid)))


def _build_view_program(schema: DatabaseSchema, w: ViewDef,
                        rel_attrs: frozenset,
                        child_rest: Mapping[int, Tuple[str, ...]],
                        batched_vids: FrozenSet[int] = frozenset()) -> ViewProgram:
    local = tuple(a for a in w.group_by if a in rel_attrs)
    pulled = tuple(a for a in w.group_by if a not in rel_attrs)
    pulled_dims = tuple(schema.domain(a) for a in pulled)

    seg = None
    if local:
        dims = tuple(schema.domain(a) for a in local)
        seg = SegmentSpec(attrs=local, dims=dims,
                          n_segments=int(np.prod(dims, dtype=np.int64)))

    cols = []
    for colspec in w.agg_cols:
        prods = []
        for prod in colspec.products:
            used = set()
            refs = []
            for ref in prod.child_cols:
                rest = child_rest[ref.vid]
                used |= set(rest)
                refs.append(ChildColRef(ref.vid, ref.col, rest,
                                        batched=ref.vid in batched_vids))
            term_apps = []
            for t in prod.local_terms:
                col_attrs = tuple(sorted(a for a in t.attrs() if a in rel_attrs))
                dom_attrs = tuple(sorted(a for a in t.attrs() if a not in rel_attrs))
                used |= set(dom_attrs)
                term_apps.append(TermApp(
                    t, col_attrs, dom_attrs,
                    tuple(schema.domain(a) for a in dom_attrs),
                    batched=t.is_batched()))
            extra = tuple(sorted(used - set(pulled)))
            axes = pulled + extra
            prods.append(ProductProgram(
                child_refs=tuple(refs), local_terms=tuple(term_apps),
                axes=axes, axis_dims=tuple(schema.domain(a) for a in axes),
                n_keep=len(pulled),
                batched=(any(r.batched for r in refs)
                         or any(ta.batched for ta in term_apps))))
        cols.append(ColProgram(tuple(prods)))
    cols = tuple(cols)

    acc_shape = (((seg.n_segments,) if seg else ())
                 + pulled_dims + (w.n_aggs,))
    out_dims = tuple(schema.domain(a) for a in local) + pulled_dims
    computed_order = list(local) + list(pulled)
    out_perm = tuple([computed_order.index(a) for a in w.group_by]
                     + [len(computed_order)])

    return ViewProgram(
        vid=w.vid, rel=w.rel, group_by=w.group_by, local=local, pulled=pulled,
        pulled_dims=pulled_dims, n_aggs=w.n_aggs, seg=seg, cols=cols,
        acc_shape=acc_shape, out_dims=out_dims, out_perm=out_perm,
        hist=_detect_hist(schema, rel_attrs, local, pulled, cols),
        batched=w.vid in batched_vids)


def _detect_hist(schema: DatabaseSchema, rel_attrs: frozenset,
                 local: Tuple[str, ...], pulled: Tuple[str, ...],
                 cols: Tuple[ColProgram, ...]) -> Optional[HistSpec]:
    """Match ``[Σ P, Σ P·y, Σ P·y²] GROUP BY code`` with a single local key,
    no pulled/extra axes, and a shared mask product P."""
    if len(local) != 1 or pulled or len(cols) != 3:
        return None
    if any(len(cp.products) != 1 for cp in cols):
        return None
    p0, p1, p2 = (cp.products[0] for cp in cols)
    if p0.axes or p1.axes or p2.axes:
        return None
    if not (p0.child_refs == p1.child_refs == p2.child_refs):
        return None

    def keys(p: ProductProgram):
        return collections.Counter(repr(ta.term.key()) for ta in p.local_terms)

    k0 = keys(p0)
    extras = []
    for p in (p1, p2):
        diff = keys(p) - k0
        if (k0 - keys(p)) or sum(diff.values()) != 1:
            return None
        extra_key = next(iter(diff))
        ta = next(t for t in p.local_terms if repr(t.term.key()) == extra_key)
        extras.append(ta.term)
    t_y, t_y2 = extras
    if not (isinstance(t_y, Var) and isinstance(t_y2, Pow) and t_y2.k == 2
            and t_y.attr == t_y2.attr and t_y.attr in rel_attrs):
        return None
    return HistSpec(code_attr=local[0], n_buckets=schema.domain(local[0]),
                    y_attr=t_y.attr, cond=cols[0])
