"""Database schema: attributes, relation schemas, and the attribute registry.

LMFAO operates over a database of named relations whose attributes are either
join keys, categorical (dictionary-encoded to ``[0, domain)`` int32 codes), or
continuous (float32).  Dense code domains are the TPU-native replacement for
LMFAO's sorted-relation tries and hashmaps (DESIGN.md §2): joins become gathers
and group-bys become segment reductions over integer codes.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

KEY = "key"
CATEGORICAL = "categorical"
CONTINUOUS = "continuous"

_KINDS = (KEY, CATEGORICAL, CONTINUOUS)


@dataclasses.dataclass(frozen=True)
class Attribute:
    """A database attribute.

    ``domain`` is the number of distinct dictionary codes for key/categorical
    attributes; it is ignored (0) for continuous attributes.
    """

    name: str
    kind: str
    domain: int = 0

    def __post_init__(self) -> None:
        if self.kind not in _KINDS:
            raise ValueError(f"unknown attribute kind {self.kind!r}")
        if self.kind in (KEY, CATEGORICAL) and self.domain <= 0:
            raise ValueError(f"attribute {self.name!r}: {self.kind} needs domain > 0")

    @property
    def is_discrete(self) -> bool:
        return self.kind in (KEY, CATEGORICAL)


@dataclasses.dataclass(frozen=True)
class RelationSchema:
    """Named relation with an ordered attribute list."""

    name: str
    attrs: Tuple[str, ...]

    def __post_init__(self) -> None:
        if len(set(self.attrs)) != len(self.attrs):
            raise ValueError(f"relation {self.name!r} has duplicate attributes")

    @property
    def attr_set(self) -> frozenset:
        return frozenset(self.attrs)


class DatabaseSchema:
    """Attribute registry + relation schemas; the static input to the engine."""

    def __init__(self, attributes: Iterable[Attribute], relations: Iterable[RelationSchema]):
        self.attributes: Dict[str, Attribute] = {a.name: a for a in attributes}
        self.relations: Dict[str, RelationSchema] = {r.name: r for r in relations}
        for r in self.relations.values():
            for a in r.attrs:
                if a not in self.attributes:
                    raise ValueError(f"relation {r.name!r} references unknown attribute {a!r}")

    def attr(self, name: str) -> Attribute:
        return self.attributes[name]

    def relation(self, name: str) -> RelationSchema:
        return self.relations[name]

    def shared_attrs(self, r1: str, r2: str) -> frozenset:
        return self.relations[r1].attr_set & self.relations[r2].attr_set

    def relations_with(self, attr: str) -> List[str]:
        return [r.name for r in self.relations.values() if attr in r.attr_set]

    def domain(self, attr: str) -> int:
        a = self.attributes[attr]
        if not a.is_discrete:
            raise ValueError(f"attribute {attr!r} is continuous; no domain")
        return a.domain

    def all_attrs(self) -> List[str]:
        return list(self.attributes)

    def validate(self) -> None:
        """Sanity: every attribute appears in at least one relation."""
        seen = set()
        for r in self.relations.values():
            seen |= r.attr_set
        missing = set(self.attributes) - seen
        if missing:
            raise ValueError(f"attributes not used by any relation: {sorted(missing)}")


def schema(attr_specs: Sequence[Tuple[str, str, int]],
           relation_specs: Sequence[Tuple[str, Sequence[str]]]) -> DatabaseSchema:
    """Terse constructor: ``schema([("date", "key", 366), ...], [("Sales", [...]), ...])``."""
    attrs = [Attribute(n, k, d) for (n, k, d) in attr_specs]
    rels = [RelationSchema(n, tuple(a)) for (n, a) in relation_specs]
    s = DatabaseSchema(attrs, rels)
    s.validate()
    return s
