"""Pallas lowering backend: segment reductions on the MXU kernels.

Rows stream through ``lax.scan`` in ``PlanConfig.block_size`` blocks (same
bounded-memory structure as the XLA backend — payloads never materialize
beyond one block), but each block's reduction runs through the one-hot-matmul
kernels — the TPU-native form of the multi-output trie scan, with the dense
view accumulators pinned in VMEM across the kernel's row grid.

Launch fusion (``PlanConfig.fuse_kernels``, default): the **union of a
step's reductions** — every local group-by bucket *and* every histogram-
pattern view — dispatches as ONE ``kernels/fused_scan`` launch per row
block, so the shared row block is read from HBM once and the MXU runs
back-to-back contractions against it; with ``double_buffer`` the kernel
drives its own two-slot HBM→VMEM DMA pipeline so compute on block *i*
overlaps the copy of block *i+1* (DESIGN.md §10).  The unfused path (one
``seg_aggregate`` launch per bucket + one ``tree_hist`` per hist view)
remains as the comparison baseline the roofline harness measures against.

Kernel blocking comes from the config: ``block_rows`` sizes the kernel row
grid (``"auto"`` is resolved by the bind-time autotuner before this backend
ever runs; an unresolved "auto" degrades to the static default rather than
raising).  On CPU the kernels run in interpret mode (``PlanConfig.
interpret``; ``None`` auto-selects interpret off-TPU), which keeps this
backend testable everywhere and allclose to the XLA backend up to fp32
reduction order.
"""

from __future__ import annotations

from typing import Dict, List, Mapping, Tuple

import jax
import jax.numpy as jnp

from repro.core.aggregates import Params
from repro.core.autotune import DEFAULT_BLOCK_ROWS, DEFAULT_BLOCK_SIZE
from repro.core.ir import StepProgram, ViewProgram
from repro.core.lowering import common


def _resolve_interpret(config) -> bool:
    if config.interpret is not None:
        return bool(config.interpret)
    return jax.default_backend() != "tpu"


def _step_split(prog: StepProgram):
    """Static split of a step's views: hist-pattern views, then general
    views bucketed by their local segment key (views sharing a key reduce in
    one scatter pass — the MOO promise at kernel granularity)."""
    hist_views = [vp for vp in prog.views if vp.hist is not None]
    bucket_map: Dict[Tuple[str, ...], List[ViewProgram]] = {}
    for vp in prog.views:
        if vp.hist is None:
            key = vp.seg.attrs if vp.seg is not None else ()
            bucket_map.setdefault(key, []).append(vp)
    return hist_views, sorted(bucket_map.items())


class PallasBackend:
    """Lowers one scan step to blocked Pallas kernel launches."""

    name = "pallas"

    @staticmethod
    def count_launches(prog: StepProgram, config) -> int:
        """Kernel-launch sites this step dispatches per row block: 1 fused,
        or one per bucket plus one per hist view unfused."""
        hist_views, buckets = _step_split(prog)
        if getattr(config, "fuse_kernels", True):
            return 1 if (hist_views or buckets) else 0
        return len(hist_views) + len(buckets)

    def run_step(self, prog: StepProgram, rel_cols: Mapping[str, jnp.ndarray],
                 arrays: Dict[int, jnp.ndarray], params: Params, *,
                 n_valid, offset, config, n_nodes=None,
                 weights=None) -> None:
        """``weights`` (optional, (n_rows,) float) multiply each row's
        contribution — signed multiplicities for IVM delta scans (+1 insert,
        -1 delete, 0 padding).  ``None`` keeps the unweighted path.
        ``n_valid``/``offset`` may be Python ints or traced scalars (dynamic
        valid-row counts of capacity-padded resident relations)."""
        from repro.kernels import ops

        interpret = _resolve_interpret(config)
        block_size = (config.block_size if isinstance(config.block_size, int)
                      else DEFAULT_BLOCK_SIZE)
        block_rows = (config.block_rows if isinstance(config.block_rows, int)
                      else DEFAULT_BLOCK_ROWS)
        cols_blocked, iota, B, n_pad = common.block_columns(
            rel_cols, weights, block_size)

        hist_views, buckets = _step_split(prog)

        def flat_width(vp: ViewProgram) -> int:
            # batched views fold the node axis into the kernel's aggregate
            # column axis: one launch still reduces every node's columns
            w = vp.n_aggs * (n_nodes if vp.batched else 1)
            for d in vp.pulled_dims:
                w *= d
            return w

        def _flat_payload(vp: ViewProgram, blk_cols, gathered, valid):
            p = common.view_payload(vp, blk_cols, gathered, params, valid, B,
                                    n_nodes)
            if vp.batched:   # (N, B, *pulled, n_aggs) -> (B, N·pulled·n_aggs)
                p = jnp.moveaxis(p, 0, 1)
            return p.reshape(B, -1)

        if getattr(config, "fuse_kernels", True) and (hist_views or buckets):
            self._run_fused(prog, arrays, params, cols_blocked, iota, B,
                            n_pad, n_valid, offset, n_nodes, hist_views,
                            buckets, flat_width, _flat_payload,
                            block_rows=block_rows, interpret=interpret,
                            double_buffer=getattr(config, "double_buffer",
                                                  True))
            return

        hist_accs = tuple(
            jnp.zeros(((n_nodes,) if vp.batched else ())
                      + (vp.hist.n_buckets, 3), jnp.float32)
            for vp in hist_views)
        bucket_accs = tuple(
            jnp.zeros((vps[0].seg.n_segments if key else 1,
                       sum(flat_width(vp) for vp in vps)), jnp.float32)
            for key, vps in buckets)

        def body(carry, xs):
            hist_accs, bucket_accs = carry
            blk_cols, blk_i = xs
            blk_cols, valid = common.block_validity(
                dict(blk_cols), blk_i, B, n_pad, n_valid, offset)

            gathered = common.gather_children(prog.gathers, blk_cols, arrays, B)

            new_hist = []
            for vp, acc in zip(hist_views, hist_accs):
                cond = common.col_payload(vp.hist.cond, blk_cols, gathered,
                                          params, B) * valid
                if vp.batched:
                    # cond (N, B): one multi-node kernel pass serves the
                    # entire frontier (accumulator (N, D, 3) stays in VMEM)
                    out = ops.tree_hist_batched(
                        blk_cols[vp.hist.code_attr],
                        blk_cols[vp.hist.y_attr].astype(jnp.float32),
                        jnp.swapaxes(cond, 0, 1), vp.hist.n_buckets,
                        block_rows=block_rows, interpret=interpret)
                else:
                    out = ops.tree_hist(
                        blk_cols[vp.hist.code_attr],
                        blk_cols[vp.hist.y_attr].astype(jnp.float32),
                        cond, vp.hist.n_buckets,
                        block_rows=block_rows, interpret=interpret)
                new_hist.append(acc + out)

            new_buckets = []
            for (key, vps), acc in zip(buckets, bucket_accs):
                payload = jnp.concatenate(
                    [_flat_payload(vp, blk_cols, gathered, valid)
                     for vp in vps], axis=1)
                if key:
                    seg = common.segment_ids(blk_cols, vps[0].seg)
                    n_seg = vps[0].seg.n_segments
                else:
                    seg = jnp.zeros((B,), dtype=jnp.int32)
                    n_seg = 1
                out = ops.seg_aggregate(seg, payload, n_seg,
                                        block_rows=block_rows,
                                        interpret=interpret)
                new_buckets.append(acc + out)
            return (tuple(new_hist), tuple(new_buckets)), None

        (hist_accs, bucket_accs), _ = jax.lax.scan(
            body, (hist_accs, bucket_accs), (cols_blocked, iota))

        for vp, acc in zip(hist_views, hist_accs):
            arrays[vp.vid] = common.finalize(vp, acc)
        self._unpack_buckets(arrays, buckets, bucket_accs, flat_width,
                             n_nodes)

    # -- fused whole-step launch ---------------------------------------------

    def _run_fused(self, prog, arrays, params, cols_blocked, iota, B, n_pad,
                   n_valid, offset, n_nodes, hist_views, buckets, flat_width,
                   _flat_payload, *, block_rows, interpret, double_buffer):
        """One ``fused_scan_block`` launch per row block reduces the union of
        the step's buckets and hist views: the block's codes/payloads pack
        into two arrays and static :class:`ReduceSpec` offsets route each
        reduction to its slice (hist payloads ``cond ⊗ [1,y,y²]`` are formed
        inside the kernel's VMEM, never materialized in HBM)."""
        from repro.kernels import ops

        # static packing layout: bucket specs first, then hist specs; the
        # [1, y, y²] triple is shared by every hist view on the same y attr
        specs: List[ops.ReduceSpec] = []
        c, off = 0, 0
        for key, vps in buckets:
            w = sum(flat_width(vp) for vp in vps)
            n_seg = vps[0].seg.n_segments if key else 1
            specs.append(ops.ReduceSpec("seg", c, n_seg, w, off))
            c += 1
            off += w
        cond_slots = []
        for vp in hist_views:
            nc = n_nodes if vp.batched else 1
            cond_slots.append((c, off, nc))
            c += 1
            off += nc
        yk_offs: Dict[str, int] = {}
        for vp in hist_views:
            if vp.hist.y_attr not in yk_offs:
                yk_offs[vp.hist.y_attr] = off
                off += 3
        for (ci, po, nc), vp in zip(cond_slots, hist_views):
            specs.append(ops.ReduceSpec("hist", ci, vp.hist.n_buckets, nc * 3,
                                        po, n_cond=nc,
                                        yk_off=yk_offs[vp.hist.y_attr]))
        specs = tuple(specs)

        accs = tuple(jnp.zeros((sp.n_segments, sp.width), jnp.float32)
                     for sp in specs)

        def body(carry, xs):
            accs = carry
            blk_cols, blk_i = xs
            blk_cols, valid = common.block_validity(
                dict(blk_cols), blk_i, B, n_pad, n_valid, offset)
            gathered = common.gather_children(prog.gathers, blk_cols, arrays,
                                              B)
            code_cols, pay_cols = [], []
            for key, vps in buckets:
                if key:
                    code_cols.append(common.segment_ids(
                        blk_cols, vps[0].seg).astype(jnp.int32))
                else:
                    code_cols.append(jnp.zeros((B,), jnp.int32))
                pay_cols.append(jnp.concatenate(
                    [_flat_payload(vp, blk_cols, gathered, valid)
                     for vp in vps], axis=1))
            for vp in hist_views:
                cond = common.col_payload(vp.hist.cond, blk_cols, gathered,
                                          params, B) * valid
                cond = (jnp.swapaxes(cond, 0, 1) if vp.batched
                        else cond[:, None])
                code_cols.append(blk_cols[vp.hist.code_attr].astype(jnp.int32))
                pay_cols.append(cond.astype(jnp.float32))
            for ya in yk_offs:
                y = blk_cols[ya].astype(jnp.float32)
                pay_cols.append(jnp.stack([jnp.ones_like(y), y, y * y],
                                          axis=1))
            outs = ops.fused_scan_block(
                jnp.stack(code_cols, axis=1),
                jnp.concatenate(pay_cols, axis=1), specs,
                block_rows=block_rows, interpret=interpret,
                double_buffer=double_buffer)
            return tuple(a + o for a, o in zip(accs, outs)), None

        accs, _ = jax.lax.scan(body, accs, (cols_blocked, iota))

        self._unpack_buckets(arrays, buckets, accs[:len(buckets)], flat_width,
                             n_nodes)
        for vp, acc in zip(hist_views, accs[len(buckets):]):
            if vp.batched:
                # fused hist columns are [node j, stat k] -> node axis front
                acc = jnp.moveaxis(
                    acc.reshape(vp.hist.n_buckets, n_nodes, 3), 1, 0)
            arrays[vp.vid] = common.finalize(vp, acc)

    # -- shared unpacking ----------------------------------------------------

    @staticmethod
    def _unpack_buckets(arrays, buckets, bucket_accs, flat_width, n_nodes):
        for (key, vps), out in zip(buckets, bucket_accs):
            o = 0
            for vp in vps:
                w = flat_width(vp)
                n_seg = vp.seg.n_segments if vp.seg is not None else 1
                lead = (n_nodes,) if vp.batched else ()
                acc = out[:, o:o + w].reshape((n_seg,) + lead + vp.pulled_dims
                                              + (vp.n_aggs,))
                if vp.seg is None:
                    acc = acc[0]
                elif vp.batched:
                    acc = jnp.moveaxis(acc, 1, 0)   # node axis back in front
                arrays[vp.vid] = common.finalize(vp, acc)
                o += w
