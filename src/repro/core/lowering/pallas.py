"""Pallas lowering backend: segment reductions on the MXU kernels.

Rows stream through ``lax.scan`` in ``PlanConfig.block_size`` blocks (same
bounded-memory structure as the XLA backend — payloads never materialize
beyond one block), but each block's reduction runs through the
``kernels/seg_aggregate`` one-hot-matmul kernel — the TPU-native form of the
multi-output trie scan, with the dense view accumulator pinned in VMEM
across the kernel's row grid.  Views of a fused step that share the same
local group-by key are *concatenated into one kernel launch* (one scatter
pass computes all their aggregate columns — the MOO promise at kernel
granularity); views matching the decision-tree histogram pattern route
through the fused ``kernels/tree_hist`` kernel instead.

On CPU the kernels run in interpret mode (``PlanConfig.interpret``;
``None`` auto-selects interpret off-TPU), which keeps this backend testable
everywhere and allclose to the XLA backend up to fp32 reduction order.
"""

from __future__ import annotations

from typing import Dict, List, Mapping, Tuple

import jax
import jax.numpy as jnp

from repro.core.aggregates import Params
from repro.core.ir import StepProgram, ViewProgram
from repro.core.lowering import common


def _resolve_interpret(config) -> bool:
    if config.interpret is not None:
        return bool(config.interpret)
    return jax.default_backend() != "tpu"


class PallasBackend:
    """Lowers one scan step to blocked Pallas kernel launches."""

    name = "pallas"

    # kernel row-grid block: independent of PlanConfig.block_size (which
    # sizes the outer lax.scan blocks); the ops wrappers pad to a multiple
    block_rows = 512

    def run_step(self, prog: StepProgram, rel_cols: Mapping[str, jnp.ndarray],
                 arrays: Dict[int, jnp.ndarray], params: Params, *,
                 n_valid, offset, config, n_nodes=None,
                 weights=None) -> None:
        """``weights`` (optional, (n_rows,) float) multiply each row's
        contribution — signed multiplicities for IVM delta scans (+1 insert,
        -1 delete, 0 padding).  ``None`` keeps the unweighted path.
        ``n_valid``/``offset`` may be Python ints or traced scalars (dynamic
        valid-row counts of capacity-padded resident relations)."""
        from repro.kernels import ops

        interpret = _resolve_interpret(config)
        cols_blocked, iota, B, n_pad = common.block_columns(
            rel_cols, weights, config.block_size)

        # static split: hist-pattern views, then general views bucketed by
        # their local segment key so one seg_aggregate launch per block
        # reduces every aggregate column keyed the same way
        hist_views = [vp for vp in prog.views if vp.hist is not None]
        bucket_map: Dict[Tuple[str, ...], List[ViewProgram]] = {}
        for vp in prog.views:
            if vp.hist is None:
                key = vp.seg.attrs if vp.seg is not None else ()
                bucket_map.setdefault(key, []).append(vp)
        buckets = sorted(bucket_map.items())

        def flat_width(vp: ViewProgram) -> int:
            # batched views fold the node axis into the kernel's aggregate
            # column axis: one launch still reduces every node's columns
            w = vp.n_aggs * (n_nodes if vp.batched else 1)
            for d in vp.pulled_dims:
                w *= d
            return w

        hist_accs = tuple(
            jnp.zeros(((n_nodes,) if vp.batched else ())
                      + (vp.hist.n_buckets, 3), jnp.float32)
            for vp in hist_views)
        bucket_accs = tuple(
            jnp.zeros((vps[0].seg.n_segments if key else 1,
                       sum(flat_width(vp) for vp in vps)), jnp.float32)
            for key, vps in buckets)

        def _flat_payload(vp: ViewProgram, blk_cols, gathered, valid):
            p = common.view_payload(vp, blk_cols, gathered, params, valid, B,
                                    n_nodes)
            if vp.batched:   # (N, B, *pulled, n_aggs) -> (B, N·pulled·n_aggs)
                p = jnp.moveaxis(p, 0, 1)
            return p.reshape(B, -1)

        def body(carry, xs):
            hist_accs, bucket_accs = carry
            blk_cols, blk_i = xs
            blk_cols, valid = common.block_validity(
                dict(blk_cols), blk_i, B, n_pad, n_valid, offset)

            gathered = common.gather_children(prog.gathers, blk_cols, arrays, B)

            new_hist = []
            for vp, acc in zip(hist_views, hist_accs):
                cond = common.col_payload(vp.hist.cond, blk_cols, gathered,
                                          params, B) * valid
                if vp.batched:
                    # cond (N, B): one multi-node kernel pass serves the
                    # entire frontier (accumulator (N, D, 3) stays in VMEM)
                    out = ops.tree_hist_batched(
                        blk_cols[vp.hist.code_attr],
                        blk_cols[vp.hist.y_attr].astype(jnp.float32),
                        jnp.swapaxes(cond, 0, 1), vp.hist.n_buckets,
                        block_rows=self.block_rows, interpret=interpret)
                else:
                    out = ops.tree_hist(
                        blk_cols[vp.hist.code_attr],
                        blk_cols[vp.hist.y_attr].astype(jnp.float32),
                        cond, vp.hist.n_buckets,
                        block_rows=self.block_rows, interpret=interpret)
                new_hist.append(acc + out)

            new_buckets = []
            for (key, vps), acc in zip(buckets, bucket_accs):
                payload = jnp.concatenate(
                    [_flat_payload(vp, blk_cols, gathered, valid)
                     for vp in vps], axis=1)
                if key:
                    seg = common.segment_ids(blk_cols, vps[0].seg)
                    n_seg = vps[0].seg.n_segments
                else:
                    seg = jnp.zeros((B,), dtype=jnp.int32)
                    n_seg = 1
                out = ops.seg_aggregate(seg, payload, n_seg,
                                        block_rows=self.block_rows,
                                        interpret=interpret)
                new_buckets.append(acc + out)
            return (tuple(new_hist), tuple(new_buckets)), None

        (hist_accs, bucket_accs), _ = jax.lax.scan(
            body, (hist_accs, bucket_accs), (cols_blocked, iota))

        for vp, acc in zip(hist_views, hist_accs):
            arrays[vp.vid] = common.finalize(vp, acc)
        for (key, vps), out in zip(buckets, bucket_accs):
            o = 0
            for vp in vps:
                w = flat_width(vp)
                n_seg = vp.seg.n_segments if vp.seg is not None else 1
                lead = (n_nodes,) if vp.batched else ()
                acc = out[:, o:o + w].reshape((n_seg,) + lead + vp.pulled_dims
                                              + (vp.n_aggs,))
                if vp.seg is None:
                    acc = acc[0]
                elif vp.batched:
                    acc = jnp.moveaxis(acc, 1, 0)   # node axis back in front
                arrays[vp.vid] = common.finalize(vp, acc)
                o += w
