"""Backend-shared lowering primitives over the group-program IR.

Payload construction — gathers of incoming views, term evaluation in the
product's axis frame, marginalization of extra axes, validity masking — is
identical across backends; only the scan strategy and the reduction differ
(``xla.py``: blocked ``lax.scan`` + ``segment_sum``; ``pallas.py``:
whole-relation payloads + MXU one-hot kernels).  Everything here is shape
polymorphic in the leading row axis: ``B`` is a block for the XLA backend and
the whole padded relation for the Pallas backend.
"""

from __future__ import annotations

from typing import Dict, Mapping, Tuple

import jax.numpy as jnp

from repro.core.aggregates import Params
from repro.core.ir import (ColProgram, GatherSpec, ProductProgram,
                           SegmentSpec, ViewProgram)

Cols = Mapping[str, jnp.ndarray]


def align(x: jnp.ndarray, src_axes: Tuple[str, ...],
          dst_axes: Tuple[str, ...]) -> jnp.ndarray:
    """Map (B, *src_dims) onto (B, *dst positions) with singleton axes
    elsewhere.  All src axes must appear in dst."""
    present = [a for a in dst_axes if a in src_axes]
    if tuple(present) != tuple(src_axes):
        perm = [0] + [1 + src_axes.index(a) for a in present]
        x = jnp.transpose(x, perm)
    shape = [x.shape[0]] + [x.shape[1 + present.index(a)] if a in present else 1
                            for a in dst_axes]
    return x.reshape(shape)


def reshape_axes(col: jnp.ndarray, dst_axes: Tuple[str, ...]) -> jnp.ndarray:
    """Row vector -> (B, 1, ..., 1) in the destination axis frame."""
    return col.reshape((col.shape[0],) + (1,) * len(dst_axes))


def segment_ids(cols: Cols, seg: SegmentSpec) -> jnp.ndarray:
    """Mixed-radix flattening of the local group-by columns."""
    out = jnp.zeros_like(cols[seg.attrs[0]])
    for a, d in zip(seg.attrs, seg.dims):
        out = out * d + cols[a]
    return out


def gather_children(gathers: Tuple[GatherSpec, ...], cols: Cols,
                    arrays: Mapping[int, jnp.ndarray],
                    n_rows: int) -> Dict[int, jnp.ndarray]:
    """Per child view: the (B, *rest_dims) slice each row sees — the paper's
    'lookup into incoming views', shared by all aggregates of the step."""
    out: Dict[int, jnp.ndarray] = {}
    for gs in gathers:
        idx = tuple(cols[a] for a in gs.gather)
        out[gs.vid] = arrays[gs.vid][idx] if idx else (
            jnp.broadcast_to(arrays[gs.vid], (n_rows,) + arrays[gs.vid].shape))
    return out


def product_payload(pp: ProductProgram, cols: Cols,
                    gathered: Mapping[int, jnp.ndarray], params: Params,
                    n_rows: int) -> jnp.ndarray:
    """(B, *kept_axis_dims) contribution of one product, extra axes summed."""
    acc = None
    for ref in pp.child_refs:
        x = gathered[ref.vid][..., ref.col]        # (B, *rest_dims)
        x = align(x, ref.rest, pp.axes)
        acc = x if acc is None else acc * x
    for ta in pp.local_terms:
        env = {}
        for a in ta.col_attrs:
            env[a] = reshape_axes(cols[a], pp.axes)
        for a, d in zip(ta.dom_attrs, ta.dom_dims):
            dom = jnp.arange(d, dtype=jnp.int32)
            env[a] = align(dom[None, :], (a,), pp.axes)
        x = ta.term.evaluate(env, params)
        x = jnp.asarray(x, dtype=jnp.float32)
        if x.ndim == 0:
            x = jnp.broadcast_to(x, (n_rows,) + (1,) * len(pp.axes))
        acc = x if acc is None else acc * x
    if acc is None:  # pure count: Π over empty set = 1
        acc = jnp.ones((n_rows,) + (1,) * len(pp.axes), dtype=jnp.float32)
    if len(pp.axes) > pp.n_keep:  # marginalize the non-output axes
        full = (n_rows,) + pp.axis_dims
        acc = jnp.broadcast_to(acc, full)
        acc = acc.sum(axis=tuple(range(1 + pp.n_keep, 1 + len(pp.axes))))
    return acc


def col_payload(cp: ColProgram, cols: Cols,
                gathered: Mapping[int, jnp.ndarray], params: Params,
                n_rows: int) -> jnp.ndarray:
    out = None
    for pp in cp.products:
        p = product_payload(pp, cols, gathered, params, n_rows)
        out = p if out is None else out + p
    return out


def view_payload(vp: ViewProgram, cols: Cols,
                 gathered: Mapping[int, jnp.ndarray], params: Params,
                 valid: jnp.ndarray, n_rows: int) -> jnp.ndarray:
    """(B, *pulled_dims, n_aggs) contributions of a row block to view vp."""
    out_cols = [col_payload(cp, cols, gathered, params, n_rows)
                * reshape_axes(valid, vp.pulled)
                for cp in vp.cols]
    target = (n_rows,) + vp.pulled_dims
    out_cols = [jnp.broadcast_to(c, target) for c in out_cols]
    return jnp.stack(out_cols, axis=-1)


def finalize(vp: ViewProgram, acc: jnp.ndarray) -> jnp.ndarray:
    """Unflatten the segment axis and transpose to canonical group-by order."""
    arr = acc.reshape(vp.out_dims + (vp.n_aggs,))
    return jnp.transpose(arr, vp.out_perm)
