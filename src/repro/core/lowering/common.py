"""Backend-shared lowering primitives over the group-program IR.

Payload construction — gathers of incoming views, term evaluation in the
product's axis frame, marginalization of extra axes, validity masking — is
identical across backends; only the scan strategy and the reduction differ
(``xla.py``: blocked ``lax.scan`` + ``segment_sum``; ``pallas.py``:
whole-relation payloads + MXU one-hot kernels).  Everything here is shape
polymorphic in the leading row axis: ``B`` is a block for the XLA backend and
the whole padded relation for the Pallas backend.

Param-batch (node) axis (DESIGN.md §7.4): batched products/views carry an
extra *leading* node axis of size ``N`` before the row axis, so arrays are
``(N, B, *frame)``.  Non-batched factors stay ``(B, *frame)`` and broadcast
against batched ones from the right; the static ``batched`` flags on the IR
decide where the axis exists, so every shape is known at trace time.
"""

from __future__ import annotations

from typing import Dict, Mapping, Optional, Tuple

import jax.numpy as jnp

from repro.core.aggregates import Params
from repro.core.ir import (ColProgram, GatherSpec, ProductProgram,
                           SegmentSpec, ViewProgram)

Cols = Mapping[str, jnp.ndarray]

#: synthetic column carrying per-row signed multiplicities through the
#: blocked scan (IVM delta weights ride the same xs pytree as real columns)
ROW_WEIGHT = "__row_weight__"


def block_columns(rel_cols: Cols, weights: Optional[jnp.ndarray],
                  block_size: int):
    """Reshape relation columns (and optional row weights) into scan blocks:
    returns ``(cols_blocked, iota, B, n_pad)`` where every column becomes
    ``(n_blocks, B)`` and ``iota`` indexes blocks.  Shared by both backends —
    the scan strategy differs below this split, the blocking does not."""
    n_pad = int(next(iter(rel_cols.values())).shape[0])
    B = min(block_size, max(n_pad, 1))
    n_blocks = max(-(-n_pad // B), 1)
    total = n_blocks * B
    pad = total - n_pad
    cols_blocked = {a: (jnp.pad(c, (0, pad)) if pad else c).reshape(n_blocks, B)
                    for a, c in rel_cols.items()}
    if weights is not None:
        w = jnp.asarray(weights, dtype=jnp.float32)
        cols_blocked[ROW_WEIGHT] = (jnp.pad(w, (0, pad)) if pad else
                                    w).reshape(n_blocks, B)
    iota = jnp.arange(n_blocks, dtype=jnp.int32)
    return cols_blocked, iota, B, n_pad


def block_validity(blk_cols: Dict[str, jnp.ndarray], blk_i: jnp.ndarray,
                   B: int, n_pad: int, n_valid, offset):
    """Per-row validity of one scan block: inside both the local (possibly
    capacity-padded) partition and the global ``[offset, offset+n_valid)``
    window, times the signed row weight if present.  ``n_valid`` and
    ``offset`` may be Python ints *or traced scalars* — device-resident
    relations pass their dynamic valid-row count here, which is what keeps
    steady-state IVM ticks retrace-free while buffers stay capacity-shaped.
    Pops the weight column; returns ``(blk_cols, valid)``."""
    w_blk = blk_cols.pop(ROW_WEIGHT, None)
    row_idx = blk_i * B + jnp.arange(B, dtype=jnp.int32)
    limit = jnp.minimum(jnp.asarray(n_pad, jnp.int32),
                        jnp.asarray(n_valid, jnp.int32)
                        - jnp.asarray(offset, jnp.int32))
    valid = (row_idx < limit).astype(jnp.float32)
    if w_blk is not None:
        valid = valid * w_blk
    return blk_cols, valid


def align(x: jnp.ndarray, src_axes: Tuple[str, ...],
          dst_axes: Tuple[str, ...], lead: int = 1) -> jnp.ndarray:
    """Map (*lead, *src_dims) onto (*lead, *dst positions) with singleton axes
    elsewhere.  All src axes must appear in dst; ``lead`` counts the leading
    non-frame axes kept in place (row axis, or node+row axes)."""
    present = [a for a in dst_axes if a in src_axes]
    if tuple(present) != tuple(src_axes):
        perm = list(range(lead)) + [lead + src_axes.index(a) for a in present]
        x = jnp.transpose(x, perm)
    shape = list(x.shape[:lead]) + [
        x.shape[lead + present.index(a)] if a in present else 1
        for a in dst_axes]
    return x.reshape(shape)


def reshape_axes(col: jnp.ndarray, dst_axes: Tuple[str, ...]) -> jnp.ndarray:
    """Row vector -> (B, 1, ..., 1) in the destination axis frame."""
    return col.reshape((col.shape[0],) + (1,) * len(dst_axes))


def segment_ids(cols: Cols, seg: SegmentSpec) -> jnp.ndarray:
    """Mixed-radix flattening of the local group-by columns."""
    out = jnp.zeros_like(cols[seg.attrs[0]])
    for a, d in zip(seg.attrs, seg.dims):
        out = out * d + cols[a]
    return out


def gather_children(gathers: Tuple[GatherSpec, ...], cols: Cols,
                    arrays: Mapping[int, jnp.ndarray],
                    n_rows: int) -> Dict[int, jnp.ndarray]:
    """Per child view: the (B, *rest_dims) slice each row sees — the paper's
    'lookup into incoming views', shared by all aggregates of the step.
    Batched children ((N, ...) arrays) gather past their node axis, yielding
    (N, B, *rest_dims) slices."""
    out: Dict[int, jnp.ndarray] = {}
    for gs in gathers:
        idx = tuple(cols[a] for a in gs.gather)
        arr = arrays[gs.vid]
        if gs.batched:
            if idx:
                out[gs.vid] = arr[(slice(None),) + idx]
            else:
                out[gs.vid] = jnp.broadcast_to(
                    arr[:, None], arr.shape[:1] + (n_rows,) + arr.shape[1:])
        else:
            out[gs.vid] = arr[idx] if idx else (
                jnp.broadcast_to(arr, (n_rows,) + arr.shape))
    return out


def product_payload(pp: ProductProgram, cols: Cols,
                    gathered: Mapping[int, jnp.ndarray], params: Params,
                    n_rows: int) -> jnp.ndarray:
    """(B, *kept_axis_dims) contribution of one product, extra axes summed;
    (N, B, *kept) when the product is batched."""
    n_frame = len(pp.axes)
    acc = None
    for ref in pp.child_refs:
        x = gathered[ref.vid][..., ref.col]        # (N?, B, *rest_dims)
        x = align(x, ref.rest, pp.axes, lead=2 if ref.batched else 1)
        acc = x if acc is None else acc * x
    for ta in pp.local_terms:
        env = {}
        for a in ta.col_attrs:
            env[a] = reshape_axes(cols[a], pp.axes)
        for a, d in zip(ta.dom_attrs, ta.dom_dims):
            dom = jnp.arange(d, dtype=jnp.int32)
            env[a] = align(dom[None, :], (a,), pp.axes)
        x = ta.term.evaluate(env, params)
        x = jnp.asarray(x, dtype=jnp.float32)
        if ta.batched:
            if x.ndim == 1:        # (N,) per-node scalar -> (N, 1, ..., 1)
                x = x.reshape(x.shape + (1,) * (1 + n_frame))
        elif x.ndim == 0:
            x = jnp.broadcast_to(x, (n_rows,) + (1,) * n_frame)
        acc = x if acc is None else acc * x
    if acc is None:  # pure count: Π over empty set = 1
        acc = jnp.ones((n_rows,) + (1,) * n_frame, dtype=jnp.float32)
    lead = acc.ndim - n_frame  # 1, or 2 when the node axis is present
    if n_frame > pp.n_keep:  # marginalize the non-output axes
        full = acc.shape[:lead - 1] + (n_rows,) + pp.axis_dims
        acc = jnp.broadcast_to(acc, full)
        acc = acc.sum(axis=tuple(range(lead + pp.n_keep, lead + n_frame)))
    return acc


def col_payload(cp: ColProgram, cols: Cols,
                gathered: Mapping[int, jnp.ndarray], params: Params,
                n_rows: int) -> jnp.ndarray:
    out = None
    for pp in cp.products:
        p = product_payload(pp, cols, gathered, params, n_rows)
        out = p if out is None else out + p
    return out


def view_payload(vp: ViewProgram, cols: Cols,
                 gathered: Mapping[int, jnp.ndarray], params: Params,
                 valid: jnp.ndarray, n_rows: int,
                 n_nodes: Optional[int] = None) -> jnp.ndarray:
    """(B, *pulled_dims, n_aggs) contributions of a row block to view vp —
    (N, B, *pulled_dims, n_aggs) for batched views.  Columns with no
    products contribute zeros (IVM delta views keep the full column layout
    of their base view and zero out products the delta cannot reach)."""
    target = (n_rows,) + vp.pulled_dims
    if vp.batched:
        assert n_nodes is not None, f"view {vp.vid}: batched but n_nodes unset"
        target = (n_nodes,) + target
    out_cols = []
    for cp in vp.cols:
        if cp.products:
            c = (col_payload(cp, cols, gathered, params, n_rows)
                 * reshape_axes(valid, vp.pulled))
        else:
            c = jnp.zeros(target, dtype=jnp.float32)
        out_cols.append(jnp.broadcast_to(c, target))
    return jnp.stack(out_cols, axis=-1)


def finalize(vp: ViewProgram, acc: jnp.ndarray) -> jnp.ndarray:
    """Unflatten the segment axis and transpose to canonical group-by order;
    leading node axis (batched views) stays in place."""
    lead = acc.ndim - len(vp.acc_shape)
    arr = acc.reshape(acc.shape[:lead] + vp.out_dims + (vp.n_aggs,))
    perm = tuple(range(lead)) + tuple(lead + p for p in vp.out_perm)
    return jnp.transpose(arr, perm)
