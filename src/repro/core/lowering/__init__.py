"""Pluggable lowering backends for the aggregate engine (DESIGN.md §5).

A backend lowers one scheduler :class:`~repro.core.ir.StepProgram` — a fused
multi-output scan over one relation — to device code and writes the finished
view arrays.  ``xla`` (default) traces a blocked ``lax.scan``; ``pallas``
routes the reductions through the hand-written MXU kernels in
``repro.kernels`` (interpret mode on CPU).  Select via
``PlanConfig.backend`` / ``Engine.compile(backend=...)``.
"""

from __future__ import annotations

BACKENDS = ("xla", "pallas")


def get_backend(name: str):
    """Instantiate a lowering backend by name (imports lazily so the Pallas
    dependency chain only loads when requested)."""
    if name == "xla":
        from repro.core.lowering.xla import XlaBackend
        return XlaBackend()
    if name == "pallas":
        from repro.core.lowering.pallas import PallasBackend
        return PallasBackend()
    raise ValueError(f"unknown lowering backend {name!r}; "
                     f"available: {', '.join(BACKENDS)}")
