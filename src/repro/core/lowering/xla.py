"""XLA lowering backend: blocked ``lax.scan`` + ``segment_sum``.

The paper-faithful default.  Rows stream through ``lax.scan`` in fixed-size
blocks (HBM→VMEM tiles on real hardware); each block gathers incoming views
once, evaluates every fused view's payload, and accumulates via
``jax.ops.segment_sum`` (local group-bys) or a plain axis-sum (scalar /
pulled-only views).  Tracing the step program *is* LMFAO's code-generation
layer (DESIGN.md §2): the emitted HLO is specialized to the schema, the
fused view set, and the aggregate batch.
"""

from __future__ import annotations

from typing import Dict, Mapping

import jax
import jax.numpy as jnp

from repro.core.aggregates import Params
from repro.core.ir import StepProgram
from repro.core.lowering import common


class XlaBackend:
    """Lowers one scan step to a blocked ``lax.scan`` over the relation."""

    name = "xla"

    def run_step(self, prog: StepProgram, rel_cols: Mapping[str, jnp.ndarray],
                 arrays: Dict[int, jnp.ndarray], params: Params, *,
                 n_valid, offset, config, n_nodes=None,
                 weights=None) -> None:
        """``weights`` (optional, (n_rows,) float) multiply each row's
        contribution — signed multiplicities for IVM delta scans (+1 insert,
        -1 delete, 0 padding).  ``None`` keeps the unweighted path.
        ``n_valid``/``offset`` may be Python ints or traced scalars (dynamic
        valid-row counts of capacity-padded resident relations)."""
        from repro.core.autotune import DEFAULT_BLOCK_SIZE

        block_size = (config.block_size if isinstance(config.block_size, int)
                      else DEFAULT_BLOCK_SIZE)  # unresolved "auto" -> default
        cols_blocked, iota, B, n_pad = common.block_columns(
            rel_cols, weights, block_size)

        # batched views carry the param-batch (node) axis in front: one
        # relation pass accumulates all N parameter settings at once
        accs = tuple(jnp.zeros(((n_nodes,) if vp.batched else ())
                               + vp.acc_shape, dtype=jnp.float32)
                     for vp in prog.views)

        def body(carry, xs):
            accs = carry
            blk_cols, blk_i = xs
            blk_cols, valid = common.block_validity(
                dict(blk_cols), blk_i, B, n_pad, n_valid, offset)

            gathered = common.gather_children(prog.gathers, blk_cols, arrays, B)

            new_accs = []
            for vp, acc in zip(prog.views, accs):
                payload = common.view_payload(vp, blk_cols, gathered, params,
                                              valid, B, n_nodes)
                if vp.seg is not None:
                    seg = common.segment_ids(blk_cols, vp.seg)
                    if vp.batched:
                        # segment_sum reduces axis 0: rows forward, node
                        # axis back, then restore the leading node axis
                        contrib = jnp.swapaxes(jax.ops.segment_sum(
                            jnp.swapaxes(payload, 0, 1), seg,
                            num_segments=vp.seg.n_segments), 0, 1)
                    else:
                        contrib = jax.ops.segment_sum(
                            payload, seg, num_segments=vp.seg.n_segments)
                else:
                    contrib = payload.sum(axis=1 if vp.batched else 0)
                new_accs.append(acc + contrib)
            return tuple(new_accs), None

        accs, _ = jax.lax.scan(body, accs, (cols_blocked, iota))

        for vp, acc in zip(prog.views, accs):
            arrays[vp.vid] = common.finalize(vp, acc)
