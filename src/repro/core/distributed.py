"""Domain parallelism for the aggregate engine (paper Fig. 1 layer 7).

LMFAO partitions the largest relation across threads and merges per-thread
view hashmaps.  On a TPU mesh we partition the relation's rows across the
``data`` axis with ``shard_map``; each device runs the same fused scan steps
(the scheduler's shared-scan schedule, DESIGN.md §4/§6) on its row shard and
the (small, dense) view tensors are ``psum``-combined immediately after their
step — the collective-friendly direction, since views are orders of magnitude
smaller than fact tables (paper Table 2).  Fusion is sound under sharding
because a view is psum'd before any later step gathers it.
"""

from __future__ import annotations

import functools
from typing import Dict, Mapping, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.plan import ExecutablePlan, _ceil_to


def shard_columns(db, mesh: Mesh, axis: str, shard_rel: str):
    """Pad the sharded relation to a multiple of the axis size and build the
    per-relation column pytree + sharding specs."""
    ndev = mesh.shape[axis]
    cols = {}
    specs = {}
    for name, rel in db.relations.items():
        if name == shard_rel:
            n = rel.n_rows
            n_pad = _ceil_to(max(n, 1), ndev)
            c = {a: jnp.pad(v, (0, n_pad - n)) if n_pad > n else v
                 for a, v in rel.columns.items()}
            cols[name] = c
            specs[name] = {a: P(axis) for a in c}
        else:
            cols[name] = dict(rel.columns)
            specs[name] = {a: P() for a in rel.columns}
    return cols, specs


def sharded_runner(plan: ExecutablePlan, db, mesh: Mesh, axis: str, shard_rel: str,
                   n_nodes=None):
    """Build a jitted shard_map runner. Returns (fn, cols).  ``n_nodes`` is
    the param-batch (node) axis size for plans with batched params
    (DESIGN.md §7.4); batched view tensors psum with the node axis intact."""
    from jax.experimental.shard_map import shard_map

    ndev = mesh.shape[axis]
    n_rows = db.sizes()
    cols, specs = shard_columns(db, mesh, axis, shard_rel)
    run = plan.bind(n_rows, n_nodes=n_nodes)
    rows_per_shard = int(next(iter(cols[shard_rel].values())).shape[0]) // ndev

    def local(columns, params):
        off = jax.lax.axis_index(axis).astype(jnp.int32) * rows_per_shard
        return run(columns, params,
                   offsets={shard_rel: off},
                   psum_axes={shard_rel: axis})

    in_specs = (specs, P())
    fn = shard_map(local, mesh=mesh, in_specs=in_specs, out_specs=P(),
                   check_rep=False)
    return jax.jit(fn), cols


def lower_sharded(plan: ExecutablePlan, db, mesh: Mesh, axis: str, shard_rel: str):
    """Dry-run lowering of the sharded aggregate batch (no execution)."""
    fn, cols = sharded_runner(plan, db, mesh, axis, shard_rel)
    spec_cols = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), cols)
    return fn.lower(spec_cols, {})


# ------------------------------------------------------------------ sharded IVM
# Building blocks for the sharded delta tick (core/ivm.py, DESIGN.md §8).
# Update staging is explicit device_put (allowed under the transfer guard);
# the per-shard delete/advance helpers run *inside* the tick's shard_map.

# Delete batches are padded with a gid no live row can hold.
GID_SENTINEL = np.iinfo(np.int32).max


def put_replicated(arr, mesh: Mesh):
    """Explicitly place a host array replicated across the mesh."""
    return jax.device_put(arr, NamedSharding(mesh, P()))


def put_sharded(arr, mesh: Mesh, axis: str):
    """Explicitly place a host array row-sharded over ``axis`` (leading dim
    must be a multiple of the axis size)."""
    return jax.device_put(arr, NamedSharding(mesh, P(axis)))


def strided_insert_layout(block: int, ndev: int):
    """Host permutation laying a padded insert batch out so that global
    insert rank ``j`` lands on shard ``j % ndev`` at local slot ``j // ndev``
    — round-robin keeps shards balanced under any update pattern, and shard
    ``s``'s valid inserts are the first ``ceil((n_ins - s) / ndev)`` rows of
    its contiguous block."""
    return np.arange(block * ndev).reshape(block, ndev).T.reshape(-1)


def local_insert_count(n_ins, shard, ndev: int, block: int):
    """Valid inserts owned by ``shard`` under the strided layout (traced)."""
    return jnp.clip((n_ins - shard + ndev - 1) // ndev, 0, block).astype(jnp.int32)


def local_delete(gids, live, del_gids, del_pad: int, capacity: int):
    """Route a replicated, sorted, sentinel-padded global delete batch to the
    rows this shard owns, by matching oracle positions (gids).

    Returns ``(hit, slots, n_del_local)``: a boolean mask over the shard's
    rows, the (sorted, ``del_pad``-sized, ``capacity``-filled) local slot
    indices of deleted rows, and their count.  All static-shape, so the
    delete batch size only enters the jit cache through its pow2 pad."""
    pos = jnp.searchsorted(del_gids, gids).astype(jnp.int32)
    match = jnp.take(del_gids, pos, mode="clip") == gids
    hit = match & (pos < del_pad) & live
    slots = jnp.nonzero(hit, size=del_pad, fill_value=capacity)[0].astype(jnp.int32)
    return hit, slots, jnp.sum(hit).astype(jnp.int32)


def local_advance(buffers, gids, n_valid, hit, del_gids, ins, gid_base,
                  shard, ndev: int, ins_block: int, n_ins_local, n_del_local,
                  *, compact: bool):
    """Shard-local epoch advance: compact deleted rows out (stable argsort,
    mirroring ``_resident_advance``), renumber surviving gids to the oracle's
    post-delete positions (``gid' = gid - #deleted_gids < gid``), then append
    this shard's insert block with fresh trailing gids
    ``gid_base + shard + ndev * arange`` (round-robin, matching the strided
    insert layout).  Everything indexes within the shard — no collectives."""
    cap = gids.shape[0]
    rows = jnp.arange(cap, dtype=jnp.int32)
    live = rows < n_valid
    if compact:
        gids = gids - jnp.searchsorted(del_gids, gids).astype(jnp.int32)
        order = jnp.argsort(hit | ~live)
        buffers = {a: c[order] for a, c in buffers.items()}
        gids = gids[order]
    n_after = n_valid - n_del_local
    if ins_block:
        pos = n_after + jnp.arange(ins_block, dtype=jnp.int32)
        pos = jnp.where(jnp.arange(ins_block) < n_ins_local, pos, cap)
        buffers = {a: c.at[pos].set(ins[a].astype(c.dtype), mode="drop")
                   for a, c in buffers.items()}
        new_gid = (gid_base + shard + ndev * jnp.arange(ins_block)).astype(jnp.int32)
        gids = gids.at[pos].set(new_gid, mode="drop")
    return buffers, gids, (n_after + n_ins_local).astype(jnp.int32)
