"""Domain parallelism for the aggregate engine (paper Fig. 1 layer 7).

LMFAO partitions the largest relation across threads and merges per-thread
view hashmaps.  On a TPU mesh we partition the relation's rows across the
``data`` axis with ``shard_map``; each device runs the same fused scan steps
(the scheduler's shared-scan schedule, DESIGN.md §4/§6) on its row shard and
the (small, dense) view tensors are ``psum``-combined immediately after their
step — the collective-friendly direction, since views are orders of magnitude
smaller than fact tables (paper Table 2).  Fusion is sound under sharding
because a view is psum'd before any later step gathers it.
"""

from __future__ import annotations

import functools
from typing import Dict, Mapping, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.plan import ExecutablePlan, _ceil_to


def shard_columns(db, mesh: Mesh, axis: str, shard_rel: str):
    """Pad the sharded relation to a multiple of the axis size and build the
    per-relation column pytree + sharding specs."""
    ndev = mesh.shape[axis]
    cols = {}
    specs = {}
    for name, rel in db.relations.items():
        if name == shard_rel:
            n = rel.n_rows
            n_pad = _ceil_to(max(n, 1), ndev)
            c = {a: jnp.pad(v, (0, n_pad - n)) if n_pad > n else v
                 for a, v in rel.columns.items()}
            cols[name] = c
            specs[name] = {a: P(axis) for a in c}
        else:
            cols[name] = dict(rel.columns)
            specs[name] = {a: P() for a in rel.columns}
    return cols, specs


def sharded_runner(plan: ExecutablePlan, db, mesh: Mesh, axis: str, shard_rel: str,
                   n_nodes=None):
    """Build a jitted shard_map runner. Returns (fn, cols).  ``n_nodes`` is
    the param-batch (node) axis size for plans with batched params
    (DESIGN.md §7.4); batched view tensors psum with the node axis intact."""
    from jax.experimental.shard_map import shard_map

    ndev = mesh.shape[axis]
    n_rows = db.sizes()
    cols, specs = shard_columns(db, mesh, axis, shard_rel)
    run = plan.bind(n_rows, n_nodes=n_nodes)
    rows_per_shard = int(next(iter(cols[shard_rel].values())).shape[0]) // ndev

    def local(columns, params):
        off = jax.lax.axis_index(axis).astype(jnp.int32) * rows_per_shard
        return run(columns, params,
                   offsets={shard_rel: off},
                   psum_axes={shard_rel: axis})

    in_specs = (specs, P())
    fn = shard_map(local, mesh=mesh, in_specs=in_specs, out_specs=P(),
                   check_rep=False)
    return jax.jit(fn), cols


def lower_sharded(plan: ExecutablePlan, db, mesh: Mesh, axis: str, shard_rel: str):
    """Dry-run lowering of the sharded aggregate batch (no execution)."""
    fn, cols = sharded_runner(plan, db, mesh, axis, shard_rel)
    spec_cols = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), cols)
    return fn.lower(spec_cols, {})
