"""Incremental view maintenance: delta programs over the materialized view DAG.

``Engine.compile_incremental(queries)`` returns a :class:`MaintainedBatch`
that keeps every view's dense accumulator as **persistent state** and, per
base relation, derives a **delta program**: the sub-DAG of views transitively
reachable from that relation, re-derived so that an update batch (inserts and
deletes with signed multiplicities) is folded into the stored view tensors
with work proportional to the update — not the database (DESIGN.md §8).

Soundness for the engine's SUM-of-products aggregates, updating relation R:

* every view is linear in the rows of its scanned relation, so a view
  scanning R is maintained by running its *unchanged* scan program over the
  delta tuples only, with per-row weights +1 (insert) / -1 (delete) folded
  into the validity mask (``lowering/*.run_step(weights=...)``);
* a view scanning S ≠ R sees R through **exactly one** child edge — join-tree
  subtrees below distinct children are disjoint, so no product ever has two
  R-dependent factors and the product rule collapses to first order:
  ``Δ(terms × c_R × rest) = terms × Δc_R × rest`` with ``rest`` unchanged.
  The delta view rescans S, gathering the child's *delta* array in place of
  its materialized value; products with no R-dependent factor are dropped
  (their delta is zero), and columns left empty contribute explicit zeros so
  the column layout — which parents index by position — is preserved.

Delta programs reuse the whole existing pipeline unchanged in the inner
loop: view programs are built by ``ir.build_group_program`` from filtered
``ViewDef``s, fused by ``schedule.build_schedule``, and executed by the
batch's configured lowering backend (``xla`` or ``pallas``); a delta scan is
just a scan over a smaller relation plus a ``+=`` into view state.

State is **epoch-versioned and device-resident** (DESIGN.md §8): every
epoch is an immutable :class:`EpochState` — view tensors plus
capacity-padded :class:`~repro.data.relations.ResidentRelation` buffers —
and ``apply`` validates the whole update batch up front, folds deltas and
advances relations *functionally* (JAX arrays are immutable, so the
previous epoch doubles as the read buffer at zero copy cost), then
publishes the next epoch with a single atomic reference swap.  Readers
(``results``, ``serve/views.py``) resolve an epoch once and see a frozen
snapshot; a failed batch publishes nothing and is a clean no-op.  A
steady-state tick is one cached jit call per updated relation — no host
round-trip of relation columns and no retrace.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import threading
import time
from typing import Dict, FrozenSet, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.verify import (verification_enabled,
                                   verify_delta_program, verify_resident,
                                   verify_tick_program)
from repro.core.groups import ViewGroup
from repro.obs.metrics import Registry
from repro.obs.trace import span
from repro.core.ir import StepProgram, build_programs, fuse_programs
from repro.core.pushdown import AggColSpec, ViewDef
from repro.core.schedule import build_schedule
from repro.core.schema import DatabaseSchema
from repro.data.relations import (Database, DeltaBatchUpdate, Relation,
                                  ResidentRelation, ShardedResidentRelation,
                                  _resident_advance, check_delete_idx,
                                  check_update_columns, next_pow2)

_pow2 = next_pow2


def _replicate_resident(rr: ResidentRelation, mesh) -> ResidentRelation:
    """Pin a resident relation replicated across a mesh (explicit placement,
    so GSPMD never guesses and the transfer-guard contract stays clean)."""
    from jax.sharding import NamedSharding, PartitionSpec
    sh = NamedSharding(mesh, PartitionSpec())
    return ResidentRelation(
        rr.name, {a: jax.device_put(c, sh) for a, c in rr.buffers.items()},
        rr.n_valid, jax.device_put(rr.n_valid_dev, sh))


# ----------------------------------------------------------- delta derivation

def relation_reach(views: Mapping[int, ViewDef]) -> Dict[int, FrozenSet[str]]:
    """vid → set of base relations its value depends on (scanned relation
    plus, transitively, every child's).  Memoized walk over the view DAG."""
    memo: Dict[int, FrozenSet[str]] = {}

    def reach(vid: int) -> FrozenSet[str]:
        if vid not in memo:
            w = views[vid]
            s = {w.rel}
            for col in w.agg_cols:
                for prod in col.products:
                    for ref in prod.child_cols:
                        s |= reach(ref.vid)
            memo[vid] = frozenset(s)
        return memo[vid]

    for vid in views:
        reach(vid)
    return memo


@dataclasses.dataclass(frozen=True)
class DeltaStep:
    """One fused scan step of a delta program.  ``scans_delta`` steps scan
    the update's delta tuples (weighted); the rest rescan their full base
    relation against child *deltas*."""

    prog: StepProgram
    rel: str
    scans_delta: bool


@dataclasses.dataclass(frozen=True)
class DeltaProgram:
    """Compiled maintenance plan for updates to one base relation."""

    rel: str
    affected: FrozenSet[int]        # vids whose state the update changes
    steps: Tuple[DeltaStep, ...]
    base_rels: Tuple[str, ...]      # relations rescanned in full
    state_vids: Tuple[int, ...]     # state entries the runner needs as input

    @property
    def n_scans(self) -> int:
        return len(self.steps)

    def summary(self) -> str:
        return (f"Δ{self.rel}: {len(self.affected)} views, "
                f"{self.n_scans} scans ({sum(s.scans_delta for s in self.steps)} delta, "
                f"rescans {sorted(self.base_rels)})")


@dataclasses.dataclass(frozen=True)
class TickStep:
    """One step of a tick, with its runtime obligations made declarative:
    ``weighted`` steps fold the update's signed ±1 multiplicities into the
    validity mask; ``partitioned`` steps scanned row-partitioned buffers, so
    their view deltas in ``psum_vids`` must all-reduce over the mesh axis
    *before* any later gather or the state fold reads them."""

    prog: StepProgram
    rel: str
    scans_delta: bool
    weighted: bool
    partitioned: bool
    psum_vids: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class TickProgram:
    """The declarative form of one relation's tick: which steps apply
    update weights, which psum, and which vids the state fold covers.  Both
    tick runners (local and ``shard_map``) execute exactly this artifact,
    so the psum-before-fold soundness rule (DESIGN.md §8) is data the
    verifier can check, not control flow buried in a traced closure."""

    rel: str
    axis: Optional[str]             # mesh axis name (None = unsharded)
    shard_rel: Optional[str]        # row-partitioned relation (None = local)
    steps: Tuple[TickStep, ...]
    fold_vids: Tuple[int, ...]      # state entries the fold writes

    def summary(self) -> str:
        n_psum = sum(len(ts.psum_vids) for ts in self.steps)
        shard = f", {n_psum} psums @{self.axis}" if self.shard_rel else ""
        return (f"tick Δ{self.rel}: {len(self.steps)} steps, "
                f"folds {len(self.fold_vids)} views{shard}")


def build_tick_program(dp: DeltaProgram, shard_rel: Optional[str] = None,
                       axis: Optional[str] = None) -> TickProgram:
    """Lower a delta program to its tick form under a placement: weights
    ride exactly the delta-tuple scans, and under a mesh every step that
    scans the partitioned relation (tier-1 delta scan of partitioned delta
    tuples, or tier-2 rescan of the partitioned base rows) psums all of its
    view deltas immediately.  Pure — safe to build and verify without a
    mesh or any device state."""
    steps = []
    for st in dp.steps:
        partitioned = shard_rel is not None and st.rel == shard_rel
        steps.append(TickStep(
            prog=st.prog, rel=st.rel, scans_delta=st.scans_delta,
            weighted=st.scans_delta, partitioned=partitioned,
            psum_vids=(tuple(vp.vid for vp in st.prog.views)
                       if partitioned else ())))
    return TickProgram(rel=dp.rel, axis=axis, shard_rel=shard_rel,
                       steps=tuple(steps),
                       fold_vids=tuple(sorted(dp.affected)))


def build_delta_program(schema: DatabaseSchema, views: Mapping[int, ViewDef],
                        rel: str, fuse: bool = True) -> DeltaProgram:
    """Derive the delta program for updates to base relation ``rel``."""
    reach = relation_reach(views)
    affected = frozenset(vid for vid, rs in reach.items() if rel in rs)
    if not affected:
        return DeltaProgram(rel=rel, affected=affected, steps=(),
                            base_rels=(), state_vids=())

    # delta view defs: tier-1 (scan rel) keep every product — they are linear
    # in rel's rows; tier-2 keep only products with an affected child factor
    delta_defs: Dict[int, ViewDef] = {}
    for vid in affected:
        w = views[vid]
        if w.rel == rel:
            delta_defs[vid] = w
            continue
        cols = []
        for colspec in w.agg_cols:
            kept = []
            for p in colspec.products:
                hit = [r for r in p.child_cols if r.vid in affected]
                if not hit:
                    continue            # R-independent product: delta is zero
                if len(hit) > 1:
                    # would need second-order delta terms; cannot happen for
                    # join-tree pushdown (subtrees below distinct children
                    # are disjoint), so treat it as a soundness bug
                    raise ValueError(
                        f"view {vid}: product with {len(hit)} {rel}-dependent "
                        "factors — first-order delta derivation is unsound")
                kept.append(p)
            cols.append(AggColSpec(tuple(kept)))
        delta_defs[vid] = ViewDef(
            vid=w.vid, edge=w.edge, rel=w.rel, group_by=w.group_by,
            local_keys=w.local_keys, pulled_keys=w.pulled_keys, agg_cols=cols)

    # group the delta sub-DAG: peel dependency levels restricted to affected
    # vids, bucketing ready views per scanned relation (mirrors group_views)
    deps = {vid: {r.vid for col in delta_defs[vid].agg_cols
                  for p in col.products for r in p.child_cols} & affected
            for vid in affected}
    groups: List[ViewGroup] = []
    vid_group: Dict[int, int] = {}
    remaining, done = set(affected), set()
    level = 0
    while remaining:
        ready = sorted(v for v in remaining if deps[v] <= done)
        if not ready:
            raise ValueError("cyclic delta-view dependencies (bug)")
        buckets: Dict[str, List[int]] = {}
        for vid in ready:
            buckets.setdefault(delta_defs[vid].rel, []).append(vid)
        for r in sorted(buckets):
            vids = tuple(buckets[r])
            gdeps = sorted({vid_group[d] for vid in vids for d in deps[vid]})
            gid = len(groups)
            groups.append(ViewGroup(gid=gid, rel=r, vids=vids, level=level,
                                    deps=tuple(gdeps)))
            for vid in vids:
                vid_group[vid] = gid
        done.update(ready)
        remaining.difference_update(ready)
        level += 1

    # lower through the existing IR builder + shared-scan scheduler; child
    # gather specs only need the (unchanged) group_by of each child ViewDef
    merged = dict(views)
    merged.update(delta_defs)
    progs = build_programs(schema, merged, groups)
    sched = build_schedule(groups, fuse=fuse)
    # a fused step scans one relation, so it is either all-delta (rel == R:
    # every view scanning R is tier-1) or all-base — never mixed
    steps = tuple(DeltaStep(prog=fuse_programs([progs[gid] for gid in st.gids]),
                            rel=st.rel, scans_delta=(st.rel == rel))
                  for st in sched.steps)
    base_rels = tuple(sorted({s.rel for s in steps if not s.scans_delta}))
    gathered = {gs.vid for s in steps for gs in s.prog.gathers}
    return DeltaProgram(rel=rel, affected=affected, steps=steps,
                        base_rels=base_rels,
                        state_vids=tuple(sorted(affected | gathered)))


# -------------------------------------------------------------- maintenance

class EpochEvictedError(KeyError):
    """A read hit an epoch whose pin was evicted under the server's
    ``max_pinned_epochs`` budget.  Long-lived pins retain whole epochs of
    device memory, so the budget force-releases the least-recently-used pin
    once exceeded; a reader holding an evicted handle must re-snapshot."""


@dataclasses.dataclass(frozen=True)
class EpochState:
    """One immutable published version of the maintained state: every view
    tensor plus every base relation's device-resident buffers.  Epochs are
    never mutated — ``apply`` builds the successor functionally and swaps a
    single reference, so any number of readers holding (or pinning) an
    epoch see a frozen, mutually consistent snapshot for free."""

    epoch: int
    step: int
    views: Mapping[int, jnp.ndarray]
    relations: Mapping[str, ResidentRelation]

    def database(self, schema) -> Database:
        return Database(schema, {name: rr.to_relation()
                                 for name, rr in self.relations.items()})


class MaintainedBatch:
    """A compiled aggregate batch with epoch-versioned, device-resident view
    state and per-base-relation delta programs —
    ``Engine.compile_incremental``'s return type.

        mb = eng.compile_incremental(queries)
        mb.init(db)                     # full scan; state device-resident
        mb.apply(update)                # work ∝ |update|; publishes epoch+1
        results = mb.results()          # current epoch
        e = mb.pin(); ... mb.results(epoch=e) ...; mb.unpin(e)

    ``apply`` is transactional: the **whole** update batch is validated
    before anything folds, the fold itself only builds new arrays (one
    cached jit call per updated relation: delta-tuple assembly, delta scans,
    and the relation's scatter/compaction advance all fused), and the new
    epoch becomes visible in a single atomic swap — so an invalid batch is
    a clean no-op and readers never observe half-folded state.

    Runners are cached on (relation, pad-bucket, capacity) keys — delta
    batches pad to the next power of two with zero-weight rows and resident
    buffers grow by doubling, so a stream of varying batch sizes against
    growing relations compiles at most log₂ distinct executables per
    relation and a steady-state tick retraces nothing.

    With a ``mesh`` the batch is **sharded** (DESIGN.md §6/§8): one relation
    (``shard_rel``, default the largest) lives row-partitioned over
    ``mesh_axis`` as a :class:`ShardedResidentRelation`, the rest replicate,
    and each relation tick is a single cached ``jit(shard_map(...))`` —
    delta tuples partition like their relation, every step's view tensors
    psum right after the step that scans the sharded relation (before the
    state fold, so replicated state stays replicated), and
    compaction/append never leave their shard.  The zero-host-transfer /
    log₂-retrace contract is unchanged.
    """

    def __init__(self, batch, mesh=None, mesh_axis: str = "data",
                 shard_rel: Optional[str] = None):
        self.batch = batch
        self.plan = batch.plan
        if self.plan.batched_params:
            raise ValueError(
                "incremental maintenance does not support param-batched "
                f"plans (batched params: {sorted(self.plan.batched_params)})")
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        if mesh is not None and mesh_axis not in mesh.shape:
            raise ValueError(f"mesh has no axis {mesh_axis!r} "
                             f"(axes: {tuple(mesh.shape)})")
        self.shard_rel = shard_rel    # resolved at first init/load_state
        self._current: Optional[EpochState] = None
        #: delta scan steps executed across all applied updates
        self.n_delta_scan_steps = 0
        #: tick-runner traces (steady-state applies must not grow this)
        self.n_fold_traces = 0
        self._delta_programs: Dict[str, DeltaProgram] = {}
        self._tick_programs: Dict[str, TickProgram] = {}
        # static verification (DESIGN.md §12): checked once per compiled
        # artifact at build time — never on the per-tick hot path
        self._verify = verification_enabled(self.plan.config.verify_plans)
        #: artifact name -> :class:`~repro.analysis.verify.VerificationReport`
        #: for every delta/tick program verified so far (``explain()`` shows
        #: them); empty when verification is off
        self.last_verifications: Dict[str, object] = {}
        self._runners: Dict[Tuple, object] = {}
        self._init_runners: Dict[Tuple, object] = {}
        self._extract = jax.jit(self.plan.extract_outputs)
        # epoch -> [EpochState, refs]; ordered LRU-first (reads/pins
        # move_to_end) so the pin budget can evict the coldest epoch
        self._pins: "collections.OrderedDict[int, list]" = \
            collections.OrderedDict()
        self._pin_lock = threading.Lock()
        #: pin budget: beyond this many distinct pinned epochs the LRU pin
        #: is force-released (None = unbounded; serve/views.py sets it)
        self.max_pinned_epochs: Optional[int] = None
        #: pins force-released under the budget (reads of those epochs
        #: raise :class:`EpochEvictedError`)
        self.n_evicted_pins = 0
        # evicted epoch ids, newest last, for clear read errors; bounded by
        # trimming the oldest records into _evicted_floor, so the
        # unpin-after-evict no-op contract survives arbitrarily long streams
        self._evicted: "collections.OrderedDict[int, None]" = \
            collections.OrderedDict()
        self._evicted_floor = -1      # every evicted epoch <= this is trimmed
        #: per-batch telemetry (DESIGN.md §11): ``ivm.tick_us`` is the host
        #: dispatch wall of each ``apply`` — no sync, so async dispatch cost,
        #: which is what the steady-state contract allows us to measure
        self.metrics = Registry()
        self._tick_hist = self.metrics.histogram("ivm.tick_us")

    # -- lifecycle -----------------------------------------------------------

    def _require(self) -> EpochState:
        es = self._current
        if es is None:
            raise ValueError("call init(db) first")
        return es

    @property
    def initialized(self) -> bool:
        """Whether an epoch has been published (init/restore has run)."""
        return self._current is not None

    @property
    def epoch(self) -> int:
        """Id of the currently published epoch."""
        return self._require().epoch

    @property
    def step(self) -> int:
        """Update batches applied since (or encoded in) the last init/restore."""
        es = self._current
        return es.step if es is not None else 0

    @property
    def state(self) -> Optional[Dict[int, jnp.ndarray]]:
        """Current epoch's view tensors keyed by vid (back-compat read API)."""
        es = self._current
        return dict(es.views) if es is not None else None

    @property
    def db(self) -> Database:
        """Current database snapshot (base relations after applied updates;
        columns are lazy device slices of the resident buffers)."""
        return self._require().database(self.batch.schema)

    def _resolve_shard_rel(self, sizes: Mapping[str, int]) -> str:
        """Fix the partitioned relation (config override or the largest) the
        first time state materializes; frozen afterwards so runner caches
        and epochs agree."""
        if self.shard_rel is None:
            self.shard_rel = max(sorted(sizes), key=lambda r: sizes[r])
        elif self.shard_rel not in sizes:
            raise ValueError(f"shard_rel {self.shard_rel!r} is not a "
                             f"relation (have: {sorted(sizes)})")
        return self.shard_rel

    def _make_resident(self, rel: Relation):
        """Relation → device-resident form under the batch's placement."""
        if self.mesh is None:
            return ResidentRelation.from_relation(rel)
        if rel.name == self.shard_rel:
            return ShardedResidentRelation.from_relation(
                rel, self.mesh, self.mesh_axis)
        return _replicate_resident(ResidentRelation.from_relation(rel),
                                   self.mesh)

    def init(self, db: Database, params=None) -> Dict[str, jnp.ndarray]:
        """Full recompute: move every base relation into capacity-padded
        device buffers and materialize every view array, then publish the
        first epoch.  Re-init on a live batch publishes a fresh epoch (the
        epoch clock keeps counting so pinned readers stay unambiguous)."""
        with span("ivm.init"):
            if self.mesh is not None:
                self._resolve_shard_rel(db.sizes())
            rels = {name: self._make_resident(r)
                    for name, r in db.relations.items()}
            if self._verify:
                for rr in rels.values():
                    verify_resident(rr)
            params = dict(params or {})
            caps = {name: rr.capacity for name, rr in rels.items()}
            runner = self._init_runner(caps, rels, params)
            cols = {name: dict(rr.buffers) for name, rr in rels.items()}
            n_valid = {name: rr.n_valid_dev for name, rr in rels.items()}
            views = dict(runner(cols, params, n_valid))
            prev = self._current
            self._current = EpochState(epoch=prev.epoch + 1 if prev else 0,
                                       step=0, views=views, relations=rels)
        return self.results()

    def _init_runner(self, caps: Mapping[str, int], rels, params):
        """Cached jitted full-scan runner.  Under a mesh it is a
        ``shard_map``: the sharded relation scans its local rows against its
        local ``n_valid``, every other scan sees replicated inputs, and the
        sharded relation's view tensors psum right after their step (the
        batch path's rule, distributed.py) so outputs land replicated."""
        key = (tuple(sorted(caps.items())), tuple(sorted(params)),
               self.mesh is None or ("mesh", self.mesh_axis, self.shard_rel))
        if key in self._init_runners:
            return self._init_runners[key]
        run = self.plan.bind_arrays(caps)   # sharded rel: per-shard capacity
        if self.mesh is None:
            self._init_runners[key] = jax.jit(
                lambda c, p, nv: run(c, p, n_valid=nv))
            return self._init_runners[key]
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        mesh, axis, srel = self.mesh, self.mesh_axis, self.shard_rel
        col_specs = {name: {a: (P(axis) if name == srel else P())
                            for a in rels[name].buffers} for name in rels}
        nv_specs = {name: (P(axis) if name == srel else P()) for name in rels}

        def local(cols, p, nv):
            nvv = {name: (v[0] if name == srel else v)
                   for name, v in nv.items()}
            return run(cols, p, n_valid=nvv, psum_axes={srel: axis})

        self._init_runners[key] = jax.jit(shard_map(
            local, mesh=mesh, in_specs=(col_specs, P(), nv_specs),
            out_specs=P(), check_rep=False))
        return self._init_runners[key]

    def epoch_state(self, epoch: Optional[int] = None) -> EpochState:
        """Resolve an epoch to its immutable state: the published epoch by
        default, or a previously pinned one."""
        es = self._require()
        if epoch is None or epoch == es.epoch:
            return es
        with self._pin_lock:
            ent = self._pins.get(epoch)
            if ent is not None:
                self._pins.move_to_end(epoch)     # LRU touch
                return ent[0]
            if epoch in self._evicted or epoch <= self._evicted_floor:
                raise EpochEvictedError(
                    f"epoch {epoch} was evicted under the pin budget "
                    f"(max_pinned_epochs={self.max_pinned_epochs}); its "
                    "device state has been released — take a fresh "
                    "snapshot/pin to read current state")
        raise KeyError(
            f"epoch {epoch} is neither current ({es.epoch}) nor pinned — "
            "pin() an epoch before reading it across updates")

    def results(self, epoch: Optional[int] = None) -> Dict[str, jnp.ndarray]:
        """Query outputs read from one epoch's state (no relation scans).
        Always snapshot-consistent: every output comes from the same epoch,
        regardless of concurrently folding updates."""
        return dict(self._extract(dict(self.epoch_state(epoch).views)))

    # -- epoch pinning (serve/views.py) --------------------------------------

    def pin(self) -> int:
        """Retain the current epoch for consistent reads across updates;
        returns its id.  Balance every pin with :meth:`unpin` — the epoch's
        device arrays stay alive while pinned.  With a ``max_pinned_epochs``
        budget set, pinning past it force-releases the least-recently-used
        pinned epoch (its readers get :class:`EpochEvictedError`)."""
        es = self._require()
        with self._pin_lock:
            ent = self._pins.setdefault(es.epoch, [es, 0])
            ent[1] += 1
            self._pins.move_to_end(es.epoch)
            budget = self.max_pinned_epochs
            while budget is not None and len(self._pins) > budget:
                victim, _ = self._pins.popitem(last=False)   # LRU
                self._evicted[victim] = None
                self.n_evicted_pins += 1
                while len(self._evicted) > 1024:             # bound bookkeeping
                    old, _ = self._evicted.popitem(last=False)
                    self._evicted_floor = max(self._evicted_floor, old)
        return es.epoch

    def unpin(self, epoch: int) -> None:
        with self._pin_lock:
            ent = self._pins.get(epoch)
            if ent is None:
                if epoch in self._evicted or epoch <= self._evicted_floor:
                    return          # pin was force-released by the budget
                raise KeyError(f"epoch {epoch} is not pinned")
            ent[1] -= 1
            if ent[1] <= 0:
                del self._pins[epoch]

    @contextlib.contextmanager
    def pinned(self):
        """``with mb.pinned() as epoch:`` — pin for the block's duration."""
        epoch = self.pin()
        try:
            yield epoch
        finally:
            self.unpin(epoch)

    @property
    def n_pinned_epochs(self) -> int:
        with self._pin_lock:
            return len(self._pins)

    def pinned_epochs(self) -> Tuple[int, ...]:
        """Currently pinned epoch ids (ascending) — the server derives
        epoch lag (head minus oldest pin) from this."""
        with self._pin_lock:
            return tuple(sorted(self._pins))

    # -- delta path ----------------------------------------------------------

    def delta_program(self, rel: str) -> DeltaProgram:
        """The (cached) maintenance plan for updates to ``rel``."""
        if rel not in self._delta_programs:
            dp = build_delta_program(
                self.batch.schema, self.plan.views, rel,
                fuse=self.plan.config.fuse_scans)
            if self._verify:
                self.last_verifications[f"Δ{rel}"] = \
                    verify_delta_program(self.plan, dp)
            self._delta_programs[rel] = dp
        return self._delta_programs[rel]

    def tick_program(self, rel: str) -> TickProgram:
        """The (cached, verified) tick form of ``rel``'s delta program
        under this batch's placement — the artifact both tick runners
        execute."""
        if rel not in self._tick_programs:
            dp = self.delta_program(rel)
            shard = self.shard_rel if self.mesh is not None else None
            axis = self.mesh_axis if self.mesh is not None else None
            tp = build_tick_program(dp, shard_rel=shard, axis=axis)
            if self._verify:
                self.last_verifications[f"tick Δ{rel}"] = \
                    verify_tick_program(tp, dp)
            self._tick_programs[rel] = tp
        return self._tick_programs[rel]

    def apply(self, update: DeltaBatchUpdate, params=None) -> Dict[str, jnp.ndarray]:
        """Fold an update batch into view state and the resident relations,
        publishing the next epoch.  Relations are processed sequentially in
        sorted order; the published state is exactly ``init`` on the
        post-update database (up to fp32 summation order).

        Transactional: *every* relation's delta is validated before any
        state folds, so a rejected batch raises without publishing and the
        current epoch is untouched.  Thread safety: any number of readers
        may overlap with one ``apply``; concurrent writers need external
        serialization (``serve.views.ViewServer`` provides it)."""
        cur = self._require()
        params = dict(params or {})
        t_tick = time.perf_counter()

        with span("ivm.apply", epoch=cur.epoch):
            # phase 1 — validate the whole batch against the current epoch
            # (host-side numpy on the update only; state untouched)
            with span("ivm.validate"):
                prepared = []
                for rel in update.relations():
                    if rel not in cur.relations:
                        raise ValueError(
                            f"update targets unknown relation {rel!r}")
                    rr = cur.relations[rel]
                    d = update.updates[rel]
                    ins = (check_update_columns(self.batch.schema, rel,
                                                d.inserts)
                           if d.n_inserts else None)
                    del_idx = (check_delete_idx(rel, d.delete_idx, rr.n_valid)
                               if d.n_deletes else None)
                    prepared.append((rel, ins, del_idx))

            # phase 2 — functional fold: new arrays only, current epoch
            # readable throughout; the update's columns cross to the device
            # exactly once (explicit device_put), relation columns never
            # cross back
            views = dict(cur.views)
            rels = dict(cur.relations)
            n_scans = 0
            for rel, ins, del_idx in prepared:
                with span("ivm.tick", rel=rel):
                    rr = rels[rel]
                    n_ins = (0 if ins is None
                             else int(next(iter(ins.values())).shape[0]))
                    n_del = 0 if del_idx is None else len(del_idx)
                    if self.mesh is not None:
                        n_scans += self._apply_rel_mesh(
                            views, rels, rel, ins, del_idx, n_ins, n_del,
                            params)
                        continue
                    ins_pad = _pow2(n_ins) if n_ins else 0
                    del_pad = _pow2(n_del) if n_del else 0
                    ins_dev = {a: jax.device_put(np.pad(c, (0, ins_pad - n_ins)))
                               for a, c in (ins or {}).items()}
                    # delete pads point past the valid region: harmless for
                    # the compaction scatter, zero-filled by the delta gather
                    del_dev = jax.device_put(
                        np.pad(del_idx.astype(np.int32), (0, del_pad - n_del),
                               constant_values=rr.capacity)
                        if n_del else np.zeros((0,), np.int32))
                    rr = rr.grown(rr.n_valid - n_del + n_ins)
                    rels[rel] = rr
                    dp = self.delta_program(rel)
                    if dp.steps:
                        n_ins_dev = jax.device_put(np.asarray(n_ins, np.int32))
                        n_del_dev = jax.device_put(np.asarray(n_del, np.int32))
                        runner = self._tick_runner(dp, rr.capacity, ins_pad,
                                                   del_pad, rels, params)
                        state_in = {vid: views[vid] for vid in dp.state_vids}
                        base_cols = {r: dict(rels[r].buffers)
                                     for r in dp.base_rels}
                        base_n = {r: rels[r].n_valid_dev for r in dp.base_rels}
                        new_views, bufs, n_valid_dev = runner(
                            state_in, dict(rr.buffers), rr.n_valid_dev,
                            base_cols, base_n, ins_dev, del_dev, n_ins_dev,
                            n_del_dev, params)
                        views.update(new_views)
                        rels[rel] = ResidentRelation(rel, bufs,
                                                     rr.n_valid - n_del + n_ins,
                                                     n_valid_dev)
                        n_scans += dp.n_scans
                    else:
                        rels[rel] = rr.advance(ins_dev, del_dev, n_ins, n_del)

            # phase 3 — atomic publish; capacity contracts re-checked on the
            # advanced relations first (host metadata only — no sync)
            if self._verify:
                for rel, _, _ in prepared:
                    verify_resident(rels[rel])
            with span("ivm.publish"):
                self._current = EpochState(epoch=cur.epoch + 1,
                                           step=cur.step + 1,
                                           views=views, relations=rels)
                self.n_delta_scan_steps += n_scans
        # host dispatch wall of the whole tick (validate + fold + publish);
        # no block_until_ready — the no-sync instrumentation rule
        self._tick_hist.observe((time.perf_counter() - t_tick) * 1e6)
        return self.results()

    def _tick_runner(self, dp: DeltaProgram, cap: int, ins_pad: int,
                     del_pad: int, rels: Mapping[str, ResidentRelation],
                     params):
        """One jitted device program for a whole relation tick: assemble the
        delta tuples ([insert block | deleted-row gather block], pads carry
        weight 0), run the delta scans, add into view state, and advance the
        relation's resident buffers — so a steady-state ``apply`` is a
        single cached dispatch with no host transfer of relation columns.

        Cache key: (relation, pad buckets, own + rescanned capacities) —
        true row counts and delta sizes enter as traced scalars."""
        base_caps = {r: rels[r].capacity for r in dp.base_rels}
        key = (dp.rel, cap, ins_pad, del_pad,
               tuple(sorted(base_caps.items())), tuple(sorted(params)))
        if key in self._runners:
            return self._runners[key]
        # per-step blocking resolves at runner-build time (outside the jit)
        # against |update|-bucketed delta signatures — "auto" no longer
        # degrades to the static defaults on the tick path
        backend = self.plan.backend
        n_delta = ins_pad + del_pad
        tp = self.tick_program(dp.rel)
        step_cfgs = self.plan.resolve_delta_configs(
            dp.steps, [n_delta if st.scans_delta else base_caps[st.rel]
                       for st in dp.steps])

        def run(state, rel_bufs, rel_n, base_cols, base_n, ins, del_idx,
                n_ins, n_del, p):
            self.n_fold_traces += 1   # python side effect: counts traces only
            delta_cols = {}
            for a, buf in rel_bufs.items():
                segs = []
                if ins_pad:
                    segs.append(ins[a].astype(buf.dtype))
                if del_pad:
                    segs.append(jnp.take(buf, del_idx, mode="fill",
                                         fill_value=0))
                delta_cols[a] = (jnp.concatenate(segs) if len(segs) > 1
                                 else segs[0])
            w = []
            if ins_pad:
                w.append((jnp.arange(ins_pad) < n_ins).astype(jnp.float32))
            if del_pad:
                w.append(-(jnp.arange(del_pad) < n_del).astype(jnp.float32))
            weights = jnp.concatenate(w) if len(w) > 1 else w[0]
            # arrays doubles as state reads (unaffected children) and delta
            # writes: a step's finalize overwrites its vid, so a later
            # gather of an affected child reads its *delta*
            arrays = dict(state)
            for ts, cfg in zip(tp.steps, step_cfgs):
                if ts.scans_delta:
                    backend.run_step(ts.prog, delta_cols, arrays, p,
                                     n_valid=n_delta, offset=0, config=cfg,
                                     weights=weights if ts.weighted
                                     else None)
                else:
                    backend.run_step(ts.prog, base_cols[ts.rel], arrays, p,
                                     n_valid=base_n[ts.rel], offset=0,
                                     config=cfg)
            new_views = {vid: state[vid] + arrays[vid]
                         for vid in tp.fold_vids}
            new_bufs, new_n = _resident_advance(
                rel_bufs, rel_n, ins, del_idx, n_ins, n_del,
                compact=bool(del_pad))
            return new_views, new_bufs, new_n

        self._runners[key] = jax.jit(run)
        return self._runners[key]

    # -- sharded delta path (DESIGN.md §6/§8) --------------------------------

    def _apply_rel_mesh(self, views, rels, rel, ins, del_idx, n_ins, n_del,
                        params) -> int:
        """One relation's tick under a mesh: stage the update (explicit
        device_put — partitioned inserts / replicated deletes for the
        sharded relation, replicated both for the rest), then run the cached
        ``jit(shard_map)`` tick runner.  Returns the delta scan count."""
        from repro.core import distributed as dist
        mesh, axis, srel = self.mesh, self.mesh_axis, self.shard_rel
        ndev = int(mesh.shape[axis])
        rr = rels[rel]
        sharded = rel == srel
        if sharded:
            # inserts go round-robin to shards; deletes travel replicated as
            # *sorted global oracle positions* and route on device by gid
            blk = _pow2(-(-n_ins // ndev)) if n_ins else 0
            ins_pad = blk * ndev
            del_pad = _pow2(n_del) if n_del else 0
            if n_ins:
                perm = dist.strided_insert_layout(blk, ndev)
                ins_dev = {a: dist.put_sharded(
                    np.pad(c, (0, ins_pad - n_ins))[perm], mesh, axis)
                    for a, c in ins.items()}
            else:
                ins_dev = {}
            del_dev = dist.put_replicated(
                np.pad(np.sort(del_idx).astype(np.int32),
                       (0, del_pad - n_del),
                       constant_values=dist.GID_SENTINEL)
                if n_del else np.zeros((0,), np.int32), mesh)
            # growth check against the per-shard upper bound; sync the exact
            # (ndev,) counters — metadata, not columns — only on overflow
            shares = np.maximum(
                (n_ins - np.arange(ndev) + ndev - 1) // ndev, 0)
            if _pow2(max(int((rr.n_valid_ub + shares).max()), 1)) > rr.capacity:
                rr = rr.synced()
                rr = rr.grown(int((rr.n_valid_ub + shares).max()))
        else:
            ins_pad = _pow2(n_ins) if n_ins else 0
            del_pad = _pow2(n_del) if n_del else 0
            ins_dev = {a: dist.put_replicated(
                np.pad(c, (0, ins_pad - n_ins)), mesh)
                for a, c in (ins or {}).items()}
            del_dev = dist.put_replicated(
                np.pad(del_idx.astype(np.int32), (0, del_pad - n_del),
                       constant_values=rr.capacity)
                if n_del else np.zeros((0,), np.int32), mesh)
            rr = rr.grown(rr.n_valid - n_del + n_ins)
        rels[rel] = rr
        dp = self.delta_program(rel)
        runner = self._tick_runner_mesh(dp, rr.capacity, ins_pad, del_pad,
                                        rels, params)

        def scal(v):
            return dist.put_replicated(np.asarray(v, np.int32), mesh)

        state_in = {vid: views[vid] for vid in dp.state_vids}
        base_cols = {r: dict(rels[r].buffers) for r in dp.base_rels}
        base_n = {r: rels[r].n_valid_dev for r in dp.base_rels}
        if sharded:
            new_views, bufs, gids, nv_dev = runner(
                state_in, dict(rr.buffers), rr.gids, rr.n_valid_dev,
                base_cols, base_n, ins_dev, del_dev, scal(n_ins),
                scal(n_del), scal(rr.n_valid - n_del), params)
            rels[rel] = dataclasses.replace(
                rr, buffers=bufs, gids=gids,
                n_valid=rr.n_valid - n_del + n_ins,
                n_valid_ub=rr.n_valid_ub + shares, n_valid_dev=nv_dev)
        else:
            new_views, bufs, nv_dev = runner(
                state_in, dict(rr.buffers), rr.n_valid_dev, base_cols,
                base_n, ins_dev, del_dev, scal(n_ins), scal(n_del), params)
            rels[rel] = ResidentRelation(rel, bufs,
                                         rr.n_valid - n_del + n_ins, nv_dev)
        views.update(new_views)
        return dp.n_scans

    def _tick_runner_mesh(self, dp: DeltaProgram, cap: int, ins_pad: int,
                          del_pad: int, rels, params):
        """The sharded counterpart of :meth:`_tick_runner`: one cached
        ``jit(shard_map)`` per (relation, pad buckets, capacities) running
        delta-tuple assembly, the delta scans, the psum-before-fold combine,
        and the shard-local buffer advance in a single dispatch.

        Partitioned view deltas psum immediately after any step that scans
        the sharded relation — a tier-1 delta scan of partitioned delta
        tuples, or a tier-2 rescan of the partitioned base rows — so every
        later gather and the final ``state + delta`` fold read replicated
        values and the published epoch stays replicated (the soundness
        argument of DESIGN.md §8)."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.core import distributed as dist
        mesh, axis, srel = self.mesh, self.mesh_axis, self.shard_rel
        ndev = int(mesh.shape[axis])
        sharded = dp.rel == srel
        base_caps = {r: rels[r].capacity for r in dp.base_rels}
        key = ("mesh", dp.rel, cap, ins_pad, del_pad,
               tuple(sorted(base_caps.items())), tuple(sorted(params)))
        if key in self._runners:
            return self._runners[key]
        backend = self.plan.backend
        blk = ins_pad // ndev if sharded else ins_pad
        n_delta = blk + del_pad
        tp = self.tick_program(dp.rel)
        step_cfgs = self.plan.resolve_delta_configs(
            dp.steps, [n_delta if st.scans_delta else base_caps[st.rel]
                       for st in dp.steps])
        base_col_specs = {r: {a: (P(axis) if r == srel else P())
                              for a in rels[r].buffers} for r in dp.base_rels}
        base_n_specs = {r: (P(axis) if r == srel else P())
                        for r in dp.base_rels}

        def scan_steps(state, delta_cols, weights, base_cols, base_n, p):
            arrays = dict(state)
            for ts, cfg in zip(tp.steps, step_cfgs):
                if ts.scans_delta:
                    backend.run_step(ts.prog, delta_cols, arrays, p,
                                     n_valid=n_delta, offset=0, config=cfg,
                                     weights=weights if ts.weighted
                                     else None)
                else:
                    bn = base_n[ts.rel]
                    backend.run_step(ts.prog, base_cols[ts.rel], arrays, p,
                                     n_valid=bn[0] if ts.partitioned else bn,
                                     offset=0, config=cfg)
                # psum-before-fold: partitioned-row scans all-reduce their
                # view deltas before anything downstream reads them
                for vid in ts.psum_vids:
                    arrays[vid] = jax.lax.psum(arrays[vid], tp.axis)
            return {vid: state[vid] + arrays[vid] for vid in tp.fold_vids}

        def delta_block(rel_bufs, ins, slots, n_ins_loc, n_del_loc, b):
            delta_cols = {}
            for a, buf in rel_bufs.items():
                segs = []
                if b:
                    segs.append(ins[a].astype(buf.dtype))
                if del_pad:
                    segs.append(jnp.take(buf, slots, mode="fill",
                                         fill_value=0))
                delta_cols[a] = (jnp.concatenate(segs) if len(segs) > 1
                                 else segs[0])
            w = []
            if b:
                w.append((jnp.arange(b) < n_ins_loc).astype(jnp.float32))
            if del_pad:
                w.append(-(jnp.arange(del_pad) < n_del_loc).astype(jnp.float32))
            return delta_cols, (jnp.concatenate(w) if len(w) > 1 else w[0])

        if sharded:
            def run(state, rel_bufs, gid, rel_n, base_cols, base_n, ins,
                    dels, n_ins, n_del, gid_base, p):
                self.n_fold_traces += 1   # python side effect: traces only
                shard = jax.lax.axis_index(axis).astype(jnp.int32)
                nv = rel_n[0]
                live = jnp.arange(cap, dtype=jnp.int32) < nv
                if del_pad:
                    hit, slots, n_del_loc = dist.local_delete(
                        gid, live, dels, del_pad, cap)
                else:
                    hit = jnp.zeros((cap,), bool)
                    slots, n_del_loc = None, jnp.int32(0)
                n_ins_loc = (dist.local_insert_count(n_ins, shard, ndev, blk)
                             if blk else jnp.int32(0))
                delta_cols, weights = delta_block(rel_bufs, ins, slots,
                                                  n_ins_loc, n_del_loc, blk)
                new_views = scan_steps(state, delta_cols, weights,
                                       base_cols, base_n, p)
                new_bufs, new_gid, new_nv = dist.local_advance(
                    rel_bufs, gid, nv, hit, dels, ins, gid_base, shard,
                    ndev, blk, n_ins_loc, n_del_loc, compact=bool(del_pad))
                return new_views, new_bufs, new_gid, new_nv[None]

            in_specs = (P(), P(axis), P(axis), P(axis), base_col_specs,
                        base_n_specs, P(axis), P(), P(), P(), P(), P())
            out_specs = (P(), P(axis), P(axis), P(axis))
        else:
            def run(state, rel_bufs, rel_n, base_cols, base_n, ins, dels,
                    n_ins, n_del, p):
                self.n_fold_traces += 1   # python side effect: traces only
                delta_cols, weights = delta_block(rel_bufs, ins, dels,
                                                  n_ins, n_del, ins_pad)
                new_views = scan_steps(state, delta_cols, weights,
                                       base_cols, base_n, p)
                new_bufs, new_nv = _resident_advance(
                    rel_bufs, rel_n, ins, dels, n_ins, n_del,
                    compact=bool(del_pad))
                return new_views, new_bufs, new_nv

            in_specs = (P(), P(), P(), base_col_specs, base_n_specs,
                        P(), P(), P(), P(), P())
            out_specs = (P(), P(), P())

        self._runners[key] = jax.jit(shard_map(
            run, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False))
        return self._runners[key]

    # -- explain/serve introspection -----------------------------------------

    def shard_topology(self) -> Optional[Dict[str, object]]:
        """Shard facts for ``explain()``/server stats: device count, the
        partitioned relation and its per-shard geometry, and the psum count
        one tick of each relation issues.  ``None`` when unsharded."""
        if self.mesh is None:
            return None
        ndev = int(self.mesh.shape[self.mesh_axis])
        top: Dict[str, object] = {
            "n_devices": ndev, "mesh_axis": self.mesh_axis,
            "shard_rel": self.shard_rel}
        es = self._current
        if es is not None and self.shard_rel in es.relations:
            rr = es.relations[self.shard_rel]
            top["rows"] = rr.n_valid
            top["rows_per_shard"] = -(-rr.n_valid // ndev)
            top["capacity_per_shard"] = rr.capacity
        top["psums_per_tick"] = {
            rel: sum(len(st.prog.views) for st in dp.steps
                     if st.rel == self.shard_rel)
            for rel, dp in sorted(self._delta_programs.items())}
        return top

    # -- snapshots (checkpoint/store.py hooks) -------------------------------

    def state_skeleton(self):
        """A pytree with the snapshot's structure (leaf values unused) —
        lets ``restore`` run before ``init``."""
        return {"epoch": 0, "step": 0,
                "views": {f"v{vid:04d}": 0 for vid in sorted(self.plan.views)},
                "relations": {name: {a: 0 for a in rs.attrs}
                              for name, rs in self.batch.schema.relations.items()}}

    def snapshot_state(self, epoch: Optional[int] = None):
        """Host pytree of one epoch's full maintained state: epoch/update
        counters, every view tensor, and the base relations trimmed to their
        valid rows.  Resolving the epoch up front makes the snapshot
        atomic — a concurrent ``apply`` publishing mid-serialization cannot
        tear it, and passing a pinned ``epoch`` checkpoints that exact
        version."""
        es = self.epoch_state(epoch)
        # one explicit device→host gather for the view tensors; sharded
        # relations likewise gather once inside to_relation()
        views_host = jax.device_get({f"v{vid:04d}": a
                                     for vid, a in sorted(es.views.items())})
        return {"epoch": np.asarray(es.epoch, np.int64),
                "step": np.asarray(es.step, np.int64),
                "views": {k: np.asarray(v) for k, v in views_host.items()},
                "relations": {name: {a: np.asarray(c) for a, c in
                                     rr.to_relation().columns.items()}
                              for name, rr in es.relations.items()}}

    def load_state(self, tree) -> None:
        """Rebuild an epoch from a host snapshot.  Snapshots are placement-
        free (oracle-ordered trimmed relations), so a checkpoint written by
        a single-device batch restores into a sharded one and vice versa —
        relations re-residentify under *this* batch's mesh config."""
        views = {int(k[1:]): jnp.asarray(v)
                 for k, v in tree["views"].items()}
        if self.mesh is not None:
            self._resolve_shard_rel(
                {name: int(np.asarray(next(iter(cols.values()))).shape[0])
                 for name, cols in tree["relations"].items()})
            from repro.core.distributed import put_replicated
            views = {vid: put_replicated(v, self.mesh)
                     for vid, v in views.items()}
        conv = np.asarray if self.mesh is not None else jnp.asarray
        rels = {name: self._make_resident(
                    Relation(name, {a: conv(c) for a, c in cols.items()}))
                for name, cols in tree["relations"].items()}
        if self._verify:
            for rr in rels.values():
                verify_resident(rr)
        self._current = EpochState(epoch=int(np.asarray(tree["epoch"])),
                                   step=int(np.asarray(tree["step"])),
                                   views=views, relations=rels)

    def save(self, ckpt_dir: str, keep: int = 3,
             epoch: Optional[int] = None) -> str:
        from repro.checkpoint import store
        return store.save_view_state(ckpt_dir, self, keep=keep, epoch=epoch)

    def restore(self, ckpt_dir: str, step: Optional[int] = None) -> int:
        from repro.checkpoint import store
        return store.restore_view_state(ckpt_dir, self, step=step)
