"""Incremental view maintenance: delta programs over the materialized view DAG.

``Engine.compile_incremental(queries)`` returns a :class:`MaintainedBatch`
that keeps every view's dense accumulator as **persistent state** and, per
base relation, derives a **delta program**: the sub-DAG of views transitively
reachable from that relation, re-derived so that an update batch (inserts and
deletes with signed multiplicities) is folded into the stored view tensors
with work proportional to the update — not the database (DESIGN.md §8).

Soundness for the engine's SUM-of-products aggregates, updating relation R:

* every view is linear in the rows of its scanned relation, so a view
  scanning R is maintained by running its *unchanged* scan program over the
  delta tuples only, with per-row weights +1 (insert) / -1 (delete) folded
  into the validity mask (``lowering/*.run_step(weights=...)``);
* a view scanning S ≠ R sees R through **exactly one** child edge — join-tree
  subtrees below distinct children are disjoint, so no product ever has two
  R-dependent factors and the product rule collapses to first order:
  ``Δ(terms × c_R × rest) = terms × Δc_R × rest`` with ``rest`` unchanged.
  The delta view rescans S, gathering the child's *delta* array in place of
  its materialized value; products with no R-dependent factor are dropped
  (their delta is zero), and columns left empty contribute explicit zeros so
  the column layout — which parents index by position — is preserved.

Delta programs reuse the whole existing pipeline unchanged in the inner
loop: view programs are built by ``ir.build_group_program`` from filtered
``ViewDef``s, fused by ``schedule.build_schedule``, and executed by the
batch's configured lowering backend (``xla`` or ``pallas``); a delta scan is
just a scan over a smaller relation plus an in-place ``+=`` into view state.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.groups import ViewGroup
from repro.core.ir import StepProgram, build_programs, fuse_programs
from repro.core.pushdown import AggColSpec, ViewDef
from repro.core.schedule import build_schedule
from repro.core.schema import DatabaseSchema
from repro.data.relations import (Database, DeltaBatchUpdate, Relation,
                                  check_delete_idx, check_update_columns)


def _pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


# ----------------------------------------------------------- delta derivation

def relation_reach(views: Mapping[int, ViewDef]) -> Dict[int, FrozenSet[str]]:
    """vid → set of base relations its value depends on (scanned relation
    plus, transitively, every child's).  Memoized walk over the view DAG."""
    memo: Dict[int, FrozenSet[str]] = {}

    def reach(vid: int) -> FrozenSet[str]:
        if vid not in memo:
            w = views[vid]
            s = {w.rel}
            for col in w.agg_cols:
                for prod in col.products:
                    for ref in prod.child_cols:
                        s |= reach(ref.vid)
            memo[vid] = frozenset(s)
        return memo[vid]

    for vid in views:
        reach(vid)
    return memo


@dataclasses.dataclass(frozen=True)
class DeltaStep:
    """One fused scan step of a delta program.  ``scans_delta`` steps scan
    the update's delta tuples (weighted); the rest rescan their full base
    relation against child *deltas*."""

    prog: StepProgram
    rel: str
    scans_delta: bool


@dataclasses.dataclass(frozen=True)
class DeltaProgram:
    """Compiled maintenance plan for updates to one base relation."""

    rel: str
    affected: FrozenSet[int]        # vids whose state the update changes
    steps: Tuple[DeltaStep, ...]
    base_rels: Tuple[str, ...]      # relations rescanned in full
    state_vids: Tuple[int, ...]     # state entries the runner needs as input

    @property
    def n_scans(self) -> int:
        return len(self.steps)

    def summary(self) -> str:
        return (f"Δ{self.rel}: {len(self.affected)} views, "
                f"{self.n_scans} scans ({sum(s.scans_delta for s in self.steps)} delta, "
                f"rescans {sorted(self.base_rels)})")


def build_delta_program(schema: DatabaseSchema, views: Mapping[int, ViewDef],
                        rel: str, fuse: bool = True) -> DeltaProgram:
    """Derive the delta program for updates to base relation ``rel``."""
    reach = relation_reach(views)
    affected = frozenset(vid for vid, rs in reach.items() if rel in rs)
    if not affected:
        return DeltaProgram(rel=rel, affected=affected, steps=(),
                            base_rels=(), state_vids=())

    # delta view defs: tier-1 (scan rel) keep every product — they are linear
    # in rel's rows; tier-2 keep only products with an affected child factor
    delta_defs: Dict[int, ViewDef] = {}
    for vid in affected:
        w = views[vid]
        if w.rel == rel:
            delta_defs[vid] = w
            continue
        cols = []
        for colspec in w.agg_cols:
            kept = []
            for p in colspec.products:
                hit = [r for r in p.child_cols if r.vid in affected]
                if not hit:
                    continue            # R-independent product: delta is zero
                if len(hit) > 1:
                    # would need second-order delta terms; cannot happen for
                    # join-tree pushdown (subtrees below distinct children
                    # are disjoint), so treat it as a soundness bug
                    raise ValueError(
                        f"view {vid}: product with {len(hit)} {rel}-dependent "
                        "factors — first-order delta derivation is unsound")
                kept.append(p)
            cols.append(AggColSpec(tuple(kept)))
        delta_defs[vid] = ViewDef(
            vid=w.vid, edge=w.edge, rel=w.rel, group_by=w.group_by,
            local_keys=w.local_keys, pulled_keys=w.pulled_keys, agg_cols=cols)

    # group the delta sub-DAG: peel dependency levels restricted to affected
    # vids, bucketing ready views per scanned relation (mirrors group_views)
    deps = {vid: {r.vid for col in delta_defs[vid].agg_cols
                  for p in col.products for r in p.child_cols} & affected
            for vid in affected}
    groups: List[ViewGroup] = []
    vid_group: Dict[int, int] = {}
    remaining, done = set(affected), set()
    level = 0
    while remaining:
        ready = sorted(v for v in remaining if deps[v] <= done)
        if not ready:
            raise ValueError("cyclic delta-view dependencies (bug)")
        buckets: Dict[str, List[int]] = {}
        for vid in ready:
            buckets.setdefault(delta_defs[vid].rel, []).append(vid)
        for r in sorted(buckets):
            vids = tuple(buckets[r])
            gdeps = sorted({vid_group[d] for vid in vids for d in deps[vid]})
            gid = len(groups)
            groups.append(ViewGroup(gid=gid, rel=r, vids=vids, level=level,
                                    deps=tuple(gdeps)))
            for vid in vids:
                vid_group[vid] = gid
        done.update(ready)
        remaining.difference_update(ready)
        level += 1

    # lower through the existing IR builder + shared-scan scheduler; child
    # gather specs only need the (unchanged) group_by of each child ViewDef
    merged = dict(views)
    merged.update(delta_defs)
    progs = build_programs(schema, merged, groups)
    sched = build_schedule(groups, fuse=fuse)
    # a fused step scans one relation, so it is either all-delta (rel == R:
    # every view scanning R is tier-1) or all-base — never mixed
    steps = tuple(DeltaStep(prog=fuse_programs([progs[gid] for gid in st.gids]),
                            rel=st.rel, scans_delta=(st.rel == rel))
                  for st in sched.steps)
    base_rels = tuple(sorted({s.rel for s in steps if not s.scans_delta}))
    gathered = {gs.vid for s in steps for gs in s.prog.gathers}
    return DeltaProgram(rel=rel, affected=affected, steps=steps,
                        base_rels=base_rels,
                        state_vids=tuple(sorted(affected | gathered)))


# -------------------------------------------------------------- maintenance

class MaintainedBatch:
    """A compiled aggregate batch with materialized view state and per-base-
    relation delta programs — ``Engine.compile_incremental``'s return type.

        mb = eng.compile_incremental(queries)
        mb.init(db)                              # full scan, state resident
        mb.apply(update)                         # work ∝ |update|
        results = mb.results()                   # {query: dense array}

    Delta programs are derived lazily per updated relation and cached, as are
    their jitted runners (keyed on padded delta size — deltas pad to the next
    power of two with zero-weight rows, so a stream of varying batch sizes
    compiles at most log₂ distinct executables per relation).
    """

    def __init__(self, batch):
        self.batch = batch
        self.plan = batch.plan
        if self.plan.batched_params:
            raise ValueError(
                "incremental maintenance does not support param-batched "
                f"plans (batched params: {sorted(self.plan.batched_params)})")
        self.state: Optional[Dict[int, jnp.ndarray]] = None
        self.step = 0
        #: delta scan steps executed across all applied updates
        self.n_delta_scan_steps = 0
        self._relations: Optional[Dict[str, Relation]] = None
        self._delta_programs: Dict[str, DeltaProgram] = {}
        self._runners: Dict[Tuple, object] = {}
        self._init_runners: Dict[Tuple, object] = {}

    # -- lifecycle -----------------------------------------------------------

    @property
    def db(self) -> Database:
        """Current database snapshot (base relations after applied updates)."""
        if self._relations is None:
            raise ValueError("call init(db) first")
        return Database(self.batch.schema, dict(self._relations))

    def init(self, db: Database, params=None) -> Dict[str, jnp.ndarray]:
        """Full recompute: materialize every view array as resident state."""
        self._relations = dict(db.relations)
        sizes = db.sizes()
        params = dict(params or {})
        key = (tuple(sorted(sizes.items())), tuple(sorted(params)))
        if key not in self._init_runners:
            run = self.plan.bind_arrays(sizes)
            self._init_runners[key] = jax.jit(lambda c, p: run(c, p))
        cols = {name: dict(r.columns) for name, r in db.relations.items()}
        self.state = dict(self._init_runners[key](cols, params))
        self.step = 0
        return self.results()

    def results(self) -> Dict[str, jnp.ndarray]:
        """Query outputs read from the maintained state (no relation scans)."""
        if self.state is None:
            raise ValueError("call init(db) first")
        return self.plan.extract_outputs(self.state)

    # -- delta path ----------------------------------------------------------

    def delta_program(self, rel: str) -> DeltaProgram:
        """The (cached) maintenance plan for updates to ``rel``."""
        if rel not in self._delta_programs:
            self._delta_programs[rel] = build_delta_program(
                self.batch.schema, self.plan.views, rel,
                fuse=self.plan.config.fuse_scans)
        return self._delta_programs[rel]

    def apply(self, update: DeltaBatchUpdate, params=None) -> Dict[str, jnp.ndarray]:
        """Fold an update batch into view state and the stored relations.

        Relations are processed sequentially in sorted order; the resulting
        state is exactly the state of ``init`` on the post-update database
        (up to fp32 summation order)."""
        if self.state is None:
            raise ValueError("call init(db) first")
        params = dict(params or {})
        for rel in update.relations():
            if rel not in self._relations:
                raise ValueError(f"update targets unknown relation {rel!r}")
            d = update.updates[rel]
            # validate + cast exactly once per tick; the delta scan and the
            # stored-relation update below both reuse the results
            ins = (check_update_columns(self.batch.schema, rel, d.inserts)
                   if d.n_inserts else None)
            del_idx = (check_delete_idx(rel, d.delete_idx,
                                        self._relations[rel].n_rows)
                       if d.n_deletes else None)
            dp = self.delta_program(rel)
            if dp.steps:
                delta_cols, weights = self._delta_relation(rel, ins, del_idx)
                runner, args = self._runner(dp, len(weights), params)
                new = runner(*args, delta_cols, weights, params)
                self.state.update(new)
                self.n_delta_scan_steps += dp.n_scans
            self._apply_to_relation(rel, ins, del_idx)
        self.step += 1
        return self.results()

    def _apply_to_relation(self, rel: str, ins, del_idx) -> None:
        """Advance the stored relation (inputs already validated/cast)."""
        cols = self._relations[rel].columns
        if del_idx is not None:
            keep = np.ones(self._relations[rel].n_rows, dtype=bool)
            keep[del_idx] = False
            cols = {a: jnp.asarray(np.asarray(c)[keep]) for a, c in cols.items()}
        if ins is not None:
            cols = {a: jnp.concatenate([c, ins[a]]) for a, c in cols.items()}
        self._relations[rel] = Relation(rel, dict(cols))

    def _delta_relation(self, rel: str, ins, del_idx):
        """Delta tuples as a padded column dict + signed weight vector:
        inserts (+1) ++ deleted rows gathered from the current relation (-1)
        ++ zero-weight padding up to the next power of two."""
        r = self._relations[rel]
        n_ins = 0 if ins is None else int(next(iter(ins.values())).shape[0])
        n_del = 0 if del_idx is None else len(del_idx)
        parts: Dict[str, List[jnp.ndarray]] = {a: [] for a in r.columns}
        if n_ins:
            for a in parts:
                parts[a].append(ins[a])
        if n_del:
            idx = jnp.asarray(del_idx.astype(np.int32))
            for a in parts:
                parts[a].append(r.columns[a][idx])
        n = n_ins + n_del
        n_pad = _pow2(max(n, 1))
        cols = {}
        for a, chunks in parts.items():
            c = jnp.concatenate(chunks) if chunks else jnp.zeros(
                (0,), r.columns[a].dtype)
            if n_pad > n:
                c = jnp.pad(c, (0, n_pad - n))
            cols[a] = c
        weights = jnp.concatenate([
            jnp.ones((n_ins,), jnp.float32),
            -jnp.ones((n_del,), jnp.float32),
            jnp.zeros((n_pad - n,), jnp.float32)])
        return cols, weights

    def _runner(self, dp: DeltaProgram, n_pad: int, params):
        """Jitted delta executor + its (state, base-columns, base-sizes)
        arguments.  Rescanned base relations are padded to the next power of
        two and their true row counts enter the trace as *dynamic* values,
        so the jit cache grows log₂ with relation size — not one entry per
        tick of a growing stream."""
        base_pad = {r: _pow2(max(self._relations[r].n_rows, 1))
                    for r in dp.base_rels}
        key = (dp.rel, n_pad, tuple(sorted(base_pad.items())),
               tuple(sorted(params)))
        if key not in self._runners:
            backend, cfg = self.plan.backend, self.plan.config

            def run(state, base_cols, base_n, delta_cols, weights, p):
                # arrays doubles as state reads (unaffected children) and
                # delta writes: a step's finalize overwrites its vid, so a
                # later gather of an affected child reads its *delta*
                arrays = dict(state)
                for st in dp.steps:
                    if st.scans_delta:
                        backend.run_step(st.prog, delta_cols, arrays, p,
                                         n_valid=n_pad, offset=0, config=cfg,
                                         weights=weights)
                    else:
                        backend.run_step(st.prog, base_cols[st.rel], arrays, p,
                                         n_valid=base_n[st.rel], offset=0,
                                         config=cfg)
                return {vid: state[vid] + arrays[vid] for vid in dp.affected}

            self._runners[key] = jax.jit(run)
        base_cols = {}
        base_n = {}
        for r in dp.base_rels:
            rel_ = self._relations[r]
            pad = base_pad[r] - rel_.n_rows
            base_cols[r] = {a: (jnp.pad(c, (0, pad)) if pad else c)
                            for a, c in rel_.columns.items()}
            base_n[r] = jnp.asarray(rel_.n_rows, jnp.int32)
        state_in = {vid: self.state[vid] for vid in dp.state_vids}
        return self._runners[key], (state_in, base_cols, base_n)

    # -- snapshots (checkpoint/store.py hooks) -------------------------------

    def state_skeleton(self):
        """A pytree with the snapshot's structure (leaf values unused) —
        lets ``restore`` run before ``init``."""
        return {"step": 0,
                "views": {f"v{vid:04d}": 0 for vid in sorted(self.plan.views)},
                "relations": {name: {a: 0 for a in rs.attrs}
                              for name, rs in self.batch.schema.relations.items()}}

    def snapshot_state(self):
        """Host pytree of the full maintained state: update counter, every
        view tensor, and the current base relations."""
        if self.state is None:
            raise ValueError("call init(db) first")
        return {"step": np.asarray(self.step, np.int64),
                "views": {f"v{vid:04d}": np.asarray(a)
                          for vid, a in sorted(self.state.items())},
                "relations": {name: {a: np.asarray(c)
                                     for a, c in r.columns.items()}
                              for name, r in self._relations.items()}}

    def load_state(self, tree) -> None:
        self.step = int(np.asarray(tree["step"]))
        self.state = {int(k[1:]): jnp.asarray(v)
                      for k, v in tree["views"].items()}
        self._relations = {
            name: Relation(name, {a: jnp.asarray(c) for a, c in cols.items()})
            for name, cols in tree["relations"].items()}

    def save(self, ckpt_dir: str, keep: int = 3) -> str:
        from repro.checkpoint import store
        return store.save_view_state(ckpt_dir, self, keep=keep)

    def restore(self, ckpt_dir: str, step: Optional[int] = None) -> int:
        from repro.checkpoint import store
        return store.restore_view_state(ckpt_dir, self, step=step)
