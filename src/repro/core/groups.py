"""Group Views layer (paper Fig. 1 layer 5).

Views going out of the same join-tree node with no dependency between them
form a *view group* — LMFAO's computational unit: one multi-output scan of the
group's relation computes every view in the group (paper §3.4–3.5).  We build
the view dependency DAG, then peel it level by level, bucketing ready views by
their scanned relation; the resulting group dependency graph (paper Fig. 3,
right) fixes execution order and exposes task parallelism.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Set, Tuple

from repro.core.pushdown import PushdownResult, ViewDef


@dataclasses.dataclass
class ViewGroup:
    gid: int
    rel: str                 # the relation scanned by this group's plan
    vids: Tuple[int, ...]    # views computed by this group
    level: int               # topological level
    deps: Tuple[int, ...]    # gids this group depends on


def view_deps(v: ViewDef) -> Set[int]:
    out: Set[int] = set()
    for col in v.agg_cols:
        for prod in col.products:
            for ref in prod.child_cols:
                out.add(ref.vid)
    return out


def group_views(result: PushdownResult) -> List[ViewGroup]:
    views = result.views
    deps: Dict[int, Set[int]] = {vid: view_deps(v) for vid, v in views.items()}
    remaining = set(views)
    done: Set[int] = set()
    vid_group: Dict[int, int] = {}
    groups: List[ViewGroup] = []
    level = 0
    while remaining:
        ready = sorted(v for v in remaining if deps[v] <= done)
        if not ready:
            raise ValueError("cyclic view dependencies (bug in pushdown)")
        buckets: Dict[str, List[int]] = {}
        for vid in ready:
            buckets.setdefault(views[vid].rel, []).append(vid)
        for rel in sorted(buckets):
            vids = tuple(buckets[rel])
            gdeps = sorted({vid_group[d] for vid in vids for d in deps[vid]})
            gid = len(groups)
            groups.append(ViewGroup(gid=gid, rel=rel, vids=vids, level=level,
                                    deps=tuple(gdeps)))
            for vid in vids:
                vid_group[vid] = gid
        done.update(ready)
        remaining.difference_update(ready)
        level += 1
    return groups


def independent_sets(groups: Sequence[ViewGroup]) -> List[List[int]]:
    """Task-parallelism report: groups at the same level with disjoint deps can
    run concurrently (on TPU, XLA schedules them as independent subgraphs)."""
    by_level: Dict[int, List[int]] = {}
    for g in groups:
        by_level.setdefault(g.level, []).append(g.gid)
    return [by_level[lv] for lv in sorted(by_level)]
