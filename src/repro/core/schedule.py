"""Shared-scan scheduler: fuse view groups into relation scan steps.

``group_views`` (layer 5) buckets ready views per relation within each peel
level of the view-dependency DAG, so it already shares one scan among
same-relation views that become ready together.  What it cannot see is the
cross-level opportunity: with multi-root batches the same relation is often
scanned by several groups at *different* dependency depths (e.g. Inventory
both as a leaf feeding upward views and as an interior node consuming them),
and whenever no dependency path connects two such groups their scans can be
fused into one shared pass — the paper's multi-output optimization applied
across groups (DESIGN.md §4).

``build_schedule`` starts from :func:`independent_sets` (the group-level
report), then greedily merges same-relation groups with no directed path
between them in the group dependency DAG until fixpoint.  Merging two
unordered nodes of a DAG cannot create a cycle, so the result is always
executable; levels are recomputed as longest-path depths over the merged
steps.  The emitted :class:`Schedule` is the ordered list of fused scan
steps the executor drives; ``n_scans`` vs ``n_groups`` is the Table 2
analogue the benchmarks report.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Set, Tuple

from repro.core.groups import ViewGroup, independent_sets


@dataclasses.dataclass(frozen=True)
class ScanStep:
    """One fused multi-output scan over ``rel`` computing every view of the
    fused groups ``gids``."""

    sid: int
    rel: str
    gids: Tuple[int, ...]
    vids: Tuple[int, ...]
    level: int
    deps: Tuple[int, ...]   # sids of steps that must run first


@dataclasses.dataclass
class Schedule:
    """Ordered fused scan steps (topological: deps always precede users)."""

    steps: List[ScanStep]
    n_groups: int

    @property
    def n_scans(self) -> int:
        return len(self.steps)

    @property
    def n_fused_groups(self) -> int:
        """How many relation scans the fusion pass eliminated."""
        return self.n_groups - len(self.steps)

    def levels(self) -> List[List[int]]:
        """Steps per dependency level (same-level steps are independent)."""
        by_level: Dict[int, List[int]] = {}
        for s in self.steps:
            by_level.setdefault(s.level, []).append(s.sid)
        return [by_level[lv] for lv in sorted(by_level)]

    def summary(self) -> str:
        return (f"scans={self.n_scans} (fused {self.n_fused_groups} of "
                f"{self.n_groups} groups) levels={len(self.levels())}")


def build_schedule(groups: Sequence[ViewGroup], fuse: bool = True) -> Schedule:
    """Scheduler entry point: group dependency DAG -> fused scan steps."""
    # node table keyed by representative gid; deps stored as representatives
    members: Dict[int, List[int]] = {g.gid: [g.gid] for g in groups}
    deps: Dict[int, Set[int]] = {g.gid: set(g.deps) for g in groups}
    rel = {g.gid: g.rel for g in groups}
    parent: Dict[int, int] = {}

    def find(x: int) -> int:
        while x in parent:
            x = parent[x]
        return x

    def reachable(users: Dict[int, Set[int]], src: int, dst: int) -> bool:
        """Directed path src -> dst over current (merged) dep edges."""
        seen, stack = set(), [src]
        while stack:
            x = stack.pop()
            for y in users[x]:
                if y == dst:
                    return True
                if y not in seen:
                    seen.add(y)
                    stack.append(y)
        return False

    if fuse:
        # seed candidate order from the group-level report: earlier levels
        # first, so fused steps land at the earliest feasible slot
        order = [gid for lv in independent_sets(groups) for gid in lv]
        changed = True
        while changed:
            changed = False
            # dep edges only move on a merge, and every merge restarts this
            # loop — so one reverse-adjacency build serves the whole sweep
            users: Dict[int, Set[int]] = {r: set() for r in members}
            for r, ds in deps.items():
                for d in ds:
                    users[find(d)].add(r)
            reps = [r for r in order if r in members]
            for i, a in enumerate(reps):
                for b in reps[i + 1:]:
                    if rel[a] != rel[b]:
                        continue
                    if reachable(users, a, b) or reachable(users, b, a):
                        continue
                    # merge b into a
                    members[a].extend(members.pop(b))
                    deps[a] |= deps.pop(b)
                    parent[b] = a
                    for r in deps:
                        deps[r] = {find(d) for d in deps[r]}
                    deps[a].discard(a)
                    changed = True
                    break
                if changed:
                    break

    # longest-path levels over merged nodes
    level: Dict[int, int] = {}

    def depth(r: int) -> int:
        if r not in level:
            ds = {find(d) for d in deps[r]} - {r}
            level[r] = 1 + max((depth(d) for d in ds), default=-1)
        return level[r]

    for r in members:
        depth(r)

    by_gid = {g.gid: g for g in groups}
    reps_sorted = sorted(members, key=lambda r: (level[r], min(members[r])))
    sid_of = {r: i for i, r in enumerate(reps_sorted)}
    steps = []
    for r in reps_sorted:
        gids = tuple(sorted(members[r]))
        vids = tuple(v for gid in gids for v in by_gid[gid].vids)
        step_deps = tuple(sorted({sid_of[find(d)] for d in deps[r]}
                                 - {sid_of[r]}))
        steps.append(ScanStep(sid=sid_of[r], rel=rel[r], gids=gids, vids=vids,
                              level=level[r], deps=step_deps))
    return Schedule(steps=steps, n_groups=len(groups))
