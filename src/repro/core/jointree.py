"""Join-tree layer (paper Fig. 1, layer 1).

Builds one join tree used to compute *all* aggregates in a batch.  The tree is
a maximum spanning tree over shared-attribute weights, verified against the
running-intersection property (RIP).  Cyclic schemas must be pre-decomposed by
materializing hypertree bags (``materialize_bag``), after which the residual
schema is acyclic — mirroring the paper's footnote 1.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from repro.core.schema import DatabaseSchema


@dataclasses.dataclass(frozen=True)
class Edge:
    a: str
    b: str

    def other(self, node: str) -> str:
        return self.b if node == self.a else self.a

    def as_tuple(self) -> Tuple[str, str]:
        return (self.a, self.b)


class JoinTree:
    """Undirected tree over relation names; RIP-validated."""

    def __init__(self, schema: DatabaseSchema, edges: Sequence[Tuple[str, str]]):
        self.schema = schema
        self.nodes: List[str] = list(schema.relations)
        self.edges: List[Edge] = [Edge(a, b) for a, b in edges]
        self.adj: Dict[str, List[str]] = {n: [] for n in self.nodes}
        for e in self.edges:
            self.adj[e.a].append(e.b)
            self.adj[e.b].append(e.a)
        self._validate_tree()
        self._validate_rip()
        # caches
        self._subtree_cache: Dict[Tuple[str, str], FrozenSet[str]] = {}

    # -- construction -----------------------------------------------------

    @staticmethod
    def build(schema: DatabaseSchema, sizes: Optional[Dict[str, int]] = None) -> "JoinTree":
        """Maximum spanning tree over |shared attrs| (ties: larger relations
        first, so big fact tables sit centrally)."""
        nodes = list(schema.relations)
        if len(nodes) == 1:
            return JoinTree(schema, [])
        sizes = sizes or {}
        cand = []
        for i, a in enumerate(nodes):
            for b in nodes[i + 1:]:
                w = len(schema.shared_attrs(a, b))
                if w > 0:
                    tie = sizes.get(a, 0) + sizes.get(b, 0)
                    cand.append((w, tie, a, b))
        cand.sort(reverse=True)
        parent: Dict[str, str] = {n: n for n in nodes}

        def find(x: str) -> str:
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        edges = []
        for w, _, a, b in cand:
            ra, rb = find(a), find(b)
            if ra != rb:
                parent[ra] = rb
                edges.append((a, b))
        if len(edges) != len(nodes) - 1:
            raise ValueError("schema join graph is disconnected; cannot build a join tree")
        return JoinTree(schema, edges)

    # -- validation -------------------------------------------------------

    def _validate_tree(self) -> None:
        if len(self.edges) != len(self.nodes) - 1:
            raise ValueError(f"{len(self.edges)} edges for {len(self.nodes)} nodes: not a tree")
        seen: Set[str] = set()
        stack = [self.nodes[0]] if self.nodes else []
        while stack:
            n = stack.pop()
            if n in seen:
                continue
            seen.add(n)
            stack.extend(self.adj[n])
        if seen != set(self.nodes):
            raise ValueError("join tree is disconnected")

    def _validate_rip(self) -> None:
        """For every pair of nodes, shared attrs must appear along their path."""
        for i, a in enumerate(self.nodes):
            for b in self.nodes[i + 1:]:
                shared = self.schema.shared_attrs(a, b)
                if not shared:
                    continue
                for mid in self._path(a, b)[1:-1]:
                    if not shared <= self.schema.relation(mid).attr_set:
                        raise ValueError(
                            f"running-intersection violated: {sorted(shared)} shared by "
                            f"{a},{b} missing from {mid}; materialize a bag first")

    def _path(self, a: str, b: str) -> List[str]:
        prev: Dict[str, str] = {a: a}
        stack = [a]
        while stack:
            n = stack.pop()
            if n == b:
                break
            for m in self.adj[n]:
                if m not in prev:
                    prev[m] = n
                    stack.append(m)
        path = [b]
        while path[-1] != a:
            path.append(prev[path[-1]])
        return list(reversed(path))

    # -- orientation / subtree queries -------------------------------------

    def join_attrs(self, a: str, b: str) -> FrozenSet[str]:
        return self.schema.shared_attrs(a, b)

    def subtree_nodes(self, child: str, parent: str) -> FrozenSet[str]:
        """Relations in the subtree rooted at ``child`` when the edge
        (child, parent) is cut — i.e. the scope of a directional view
        child→parent."""
        key = (child, parent)
        if key not in self._subtree_cache:
            seen = {parent, child}
            stack = [child]
            out = {child}
            while stack:
                n = stack.pop()
                for m in self.adj[n]:
                    if m not in seen:
                        seen.add(m)
                        out.add(m)
                        stack.append(m)
            self._subtree_cache[key] = frozenset(out)
        return self._subtree_cache[key]

    def subtree_attrs(self, child: str, parent: str) -> FrozenSet[str]:
        out: FrozenSet[str] = frozenset()
        for n in self.subtree_nodes(child, parent):
            out |= self.schema.relation(n).attr_set
        return out

    def children(self, node: str, root: str) -> List[str]:
        """Neighbors of ``node`` away from ``root`` (node's children when the
        tree is rooted at ``root``)."""
        if node == root:
            return list(self.adj[node])
        path = self._path(node, root)
        toward_root = path[1]
        return [m for m in self.adj[node] if m != toward_root]

    def parent(self, node: str, root: str) -> Optional[str]:
        if node == root:
            return None
        return self._path(node, root)[1]

    def attrs_at_or_below(self, node: str, root: str) -> FrozenSet[str]:
        out = self.schema.relation(node).attr_set
        for c in self.children(node, root):
            out |= self.subtree_attrs(c, node)
        return out


def materialize_bag(schema_in: DatabaseSchema, bag: Sequence[str], bag_name: str):
    """Hypertree-decomposition helper: declare that the relations in ``bag``
    will be joined into a single materialized relation ``bag_name``.

    Returns the new :class:`DatabaseSchema`; the caller materializes the bag's
    data with :func:`repro.core.plan.materialize_join` before execution.
    """
    from repro.core.schema import RelationSchema

    bag_set = set(bag)
    attrs: List[str] = []
    for r in bag:
        for a in schema_in.relation(r).attrs:
            if a not in attrs:
                attrs.append(a)
    new_rels = [r for n, r in schema_in.relations.items() if n not in bag_set]
    new_rels.append(RelationSchema(bag_name, tuple(attrs)))
    return DatabaseSchema(list(schema_in.attributes.values()), new_rels)
