"""The LMFAO aggregate DSL: sums of products of user-defined functions.

Every aggregate is  α = Σ_{j∈[s]} Π_{k∈[p_j]} f_jk  (paper §1.1).  Terms
evaluate against an *environment* mapping attribute names to broadcastable
arrays; the multi-output executor provides row columns and pulled-up domain
axes through the same interface, so a term never knows whether its attribute
is a scanned column or a pulled group-by dimension.

Dynamic UDAFs (paper §1.2 "dynamic functions", used by decision trees) are
expressed with :class:`Param` references resolved from a runtime params dict —
traced by JAX, so changing a threshold never triggers recompilation (DESIGN.md
§7.3).

A :class:`Param` declared with ``batched=True`` carries a leading *param-batch
axis* of size ``N`` at run time (DESIGN.md §7.4): one compiled batch then
evaluates ``N`` parameter settings — e.g. every node of a decision-tree
frontier — in a single fused device dispatch via
``CompiledBatch.run_batched``.  Batched terms return arrays with the node
axis *leading* (before the row axis); payload construction broadcasts
non-batched factors against it from the right.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, FrozenSet, Mapping, Optional, Sequence, Tuple

import jax.numpy as jnp

Env = Mapping[str, jnp.ndarray]
Params = Mapping[str, jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class Param:
    """Reference to a runtime parameter (dynamic UDAF input).

    ``batched=True`` declares that the runtime value carries a leading
    param-batch (node) axis of size ``N``; the lowering then threads that
    axis through payloads and accumulators (DESIGN.md §7.4).
    """

    name: str
    batched: bool = False


def _resolve(v, params: Params):
    if isinstance(v, Param):
        return params[v.name]
    return v


class Term:
    """A function f(attrs...) appearing in a product."""

    def attrs(self) -> FrozenSet[str]:
        raise NotImplementedError

    def evaluate(self, env: Env, params: Params) -> jnp.ndarray:
        raise NotImplementedError

    def params(self) -> Tuple[Param, ...]:
        """The runtime :class:`Param` references this term resolves."""
        return ()

    def is_batched(self) -> bool:
        """True if any referenced param carries the param-batch axis."""
        return any(p.batched for p in self.params())

    def is_invertible(self) -> bool:
        """True if the term's contribution can be *retracted*: deleting a
        row must subtract exactly what inserting it added.  Every built-in
        term is a per-row function folded by SUM, which commutes with signed
        multiplicities — only UDAFs with MIN/MAX-style semantics (declared
        via ``Lambda(invertible=False)``) break this, and the IVM subsystem
        rejects them at ``compile_incremental`` time."""
        return True

    def key(self) -> Tuple:
        """Structural identity for view merging/dedup."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Constant(Term):
    value: object = 1.0  # float or Param

    def attrs(self) -> FrozenSet[str]:
        return frozenset()

    def evaluate(self, env: Env, params: Params) -> jnp.ndarray:
        return jnp.asarray(_resolve(self.value, params), dtype=jnp.float32)

    def params(self) -> Tuple[Param, ...]:
        return (self.value,) if isinstance(self.value, Param) else ()

    def key(self) -> Tuple:
        return ("const", self.value)


@dataclasses.dataclass(frozen=True)
class Var(Term):
    """Identity f(X) = X."""

    attr: str

    def attrs(self) -> FrozenSet[str]:
        return frozenset([self.attr])

    def evaluate(self, env: Env, params: Params) -> jnp.ndarray:
        return env[self.attr].astype(jnp.float32)

    def key(self) -> Tuple:
        return ("var", self.attr)


@dataclasses.dataclass(frozen=True)
class Pow(Term):
    """f(X) = X**k."""

    attr: str
    k: int

    def attrs(self) -> FrozenSet[str]:
        return frozenset([self.attr])

    def evaluate(self, env: Env, params: Params) -> jnp.ndarray:
        x = env[self.attr].astype(jnp.float32)
        return x ** self.k

    def key(self) -> Tuple:
        return ("pow", self.attr, self.k)


_OPS: Dict[str, Callable] = {
    "<=": lambda x, t: x <= t,
    "<": lambda x, t: x < t,
    ">=": lambda x, t: x >= t,
    ">": lambda x, t: x > t,
    "==": lambda x, t: x == t,
    "!=": lambda x, t: x != t,
}


@dataclasses.dataclass(frozen=True)
class Delta(Term):
    """Kronecker delta 1[X op t] — selection conditions / decision-tree nodes.

    ``threshold`` may be a Python scalar (static) or a :class:`Param`
    (dynamic: resolved from the runtime params dict, traced, recompile-free).
    """

    attr: str
    op: str
    threshold: object

    def __post_init__(self):
        if self.op not in _OPS:
            raise ValueError(f"unknown op {self.op!r}")

    def attrs(self) -> FrozenSet[str]:
        return frozenset([self.attr])

    def evaluate(self, env: Env, params: Params) -> jnp.ndarray:
        t = _resolve(self.threshold, params)
        x = env[self.attr]
        if isinstance(self.threshold, Param) and self.threshold.batched:
            # (N,) thresholds -> (N, 1, ..., 1): node axis leads, row/frame
            # axes of x broadcast from the right
            t = jnp.asarray(t)
            t = t.reshape(t.shape + (1,) * x.ndim)
        return _OPS[self.op](x, t).astype(jnp.float32)

    def params(self) -> Tuple[Param, ...]:
        return (self.threshold,) if isinstance(self.threshold, Param) else ()

    def key(self) -> Tuple:
        return ("delta", self.attr, self.op, self.threshold)


@dataclasses.dataclass(frozen=True)
class Lambda(Term):
    """Generic UDAF over one or more attributes: f(X_a, X_b, ...).

    ``fn`` receives broadcastable arrays in ``attr_order`` and the params
    dict.  ``tag`` provides structural identity (callables do not hash
    stably across sessions).  ``param_refs`` declares which runtime params
    ``fn`` resolves; if any is ``batched``, ``fn`` must return its result
    with the node axis leading (e.g. ``jnp.take(params[p], x, axis=-1)``
    turns an ``(N, D)`` lookup table into an ``(N, *x.shape)`` output).

    ``invertible=False`` declares MIN/MAX-style semantics: the UDAF's
    aggregate cannot be maintained under deletions by signed
    multiplicities (retracting a row would not subtract what inserting it
    added), so ``Engine.compile_incremental`` rejects the query batch with
    a clear error instead of silently producing wrong retractions.  The
    batch (non-incremental) path is unaffected.
    """

    attr_order: Tuple[str, ...]
    fn: Callable
    tag: str = ""
    param_refs: Tuple[Param, ...] = ()
    invertible: bool = True

    def attrs(self) -> FrozenSet[str]:
        return frozenset(self.attr_order)

    def evaluate(self, env: Env, params: Params) -> jnp.ndarray:
        return self.fn(*[env[a] for a in self.attr_order], params).astype(jnp.float32)

    def params(self) -> Tuple[Param, ...]:
        return self.param_refs

    def is_invertible(self) -> bool:
        return self.invertible

    def key(self) -> Tuple:
        return ("lambda", self.attr_order, self.tag or id(self.fn),
                tuple((p.name, p.batched) for p in self.param_refs),
                self.invertible)


@dataclasses.dataclass(frozen=True)
class ProductAgg:
    """One product Π_k f_k — the unit pushed through the join tree."""

    terms: Tuple[Term, ...] = ()

    def attrs(self) -> FrozenSet[str]:
        out: FrozenSet[str] = frozenset()
        for t in self.terms:
            out |= t.attrs()
        return out

    def key(self) -> Tuple:
        return tuple(sorted((t.key() for t in self.terms), key=repr))


@dataclasses.dataclass(frozen=True)
class Aggregate:
    """α = Σ_j products_j  (sum of products)."""

    products: Tuple[ProductAgg, ...]

    def attrs(self) -> FrozenSet[str]:
        out: FrozenSet[str] = frozenset()
        for p in self.products:
            out |= p.attrs()
        return out

    def key(self) -> Tuple:
        return tuple(p.key() for p in self.products)


def agg(*terms: Term) -> Aggregate:
    """Single-product aggregate Σ Π terms (the common case: count, sum, covar)."""
    return Aggregate((ProductAgg(tuple(terms)),))


COUNT = agg()  # SUM(1)


def sum_of(attr: str) -> Aggregate:
    return agg(Var(attr))


def sum_sq(attr: str) -> Aggregate:
    return agg(Pow(attr, 2))


def sum_prod(a1: str, a2: str) -> Aggregate:
    if a1 == a2:
        return sum_sq(a1)
    return agg(Var(a1), Var(a2))


@dataclasses.dataclass(frozen=True)
class Query:
    """Q(F_1,...,F_f ; α_1,...,α_ℓ) += R_1 ⋈ ... ⋈ R_m   (paper eq. (1)).

    ``group_by`` attributes must be discrete (dictionary-encoded); the output
    is a dense array over their code domains with a trailing aggregate axis.
    """

    name: str
    group_by: Tuple[str, ...]
    aggregates: Tuple[Aggregate, ...]

    def __post_init__(self):
        if len(set(self.group_by)) != len(self.group_by):
            raise ValueError(f"query {self.name!r}: duplicate group-by attrs")

    def all_attrs(self) -> FrozenSet[str]:
        out = frozenset(self.group_by)
        for a in self.aggregates:
            out |= a.attrs()
        return out


def query(name: str, group_by: Sequence[str], aggregates: Sequence[Aggregate]) -> Query:
    return Query(name, tuple(group_by), tuple(aggregates))
