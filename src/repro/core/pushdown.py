"""Aggregate Pushdown + Merge Views layers (paper Fig. 1 layers 3–4).

Each (query, root) pair is decomposed into one *directional view* per join-tree
edge, flowing from the leaves toward the query's root (paper §3.2): the view at
edge c→p computes the query's aggregate restricted to the subtree rooted at c,
grouped by the edge's join attributes plus any attributes that must be *pulled
up* (needed above c: query group-bys living in the subtree, or attributes of
terms evaluated above c).

Merging is integrated into construction: views live in **merged containers**
keyed by ``(edge, group_by)``; structurally identical aggregate columns are
deduplicated (paper merge type 3), distinct aggregates over the same key join
their column lists (type 2), and same-key views with different bodies share one
dense container (type 1 — sound because dense code-domain arrays make the
"join on group-by attributes" an axis-aligned concatenation; DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

from repro.core.aggregates import Aggregate, Constant, ProductAgg, Query, Term
from repro.core.jointree import JoinTree


@dataclasses.dataclass(frozen=True)
class ColRef:
    """Reference to aggregate column ``col`` of view ``vid``."""

    vid: int
    col: int


@dataclasses.dataclass(frozen=True)
class ProductSpec:
    """One product contribution at a node: local terms × child-view columns."""

    local_terms: Tuple[Term, ...]
    child_cols: Tuple[ColRef, ...]

    def skey(self) -> Tuple:
        return (tuple(sorted((t.key() for t in self.local_terms), key=repr)),
                tuple(sorted((c.vid, c.col) for c in self.child_cols)))


@dataclasses.dataclass(frozen=True)
class AggColSpec:
    """One output aggregate column: a sum of products."""

    products: Tuple[ProductSpec, ...]

    def skey(self) -> Tuple:
        return tuple(sorted((p.skey() for p in self.products), key=repr))


@dataclasses.dataclass
class ViewDef:
    """A merged directional-view container (or query-output container)."""

    vid: int
    edge: Optional[Tuple[str, str]]  # (child, parent); None for query outputs
    rel: str                         # relation scanned to compute this view
    group_by: Tuple[str, ...]        # canonical: sorted local keys + sorted pulled keys
    local_keys: Tuple[str, ...]      # group_by ∩ ω_rel (segment ids during the scan)
    pulled_keys: Tuple[str, ...]     # group_by \ ω_rel (axes pulled from child views)
    agg_cols: List[AggColSpec] = dataclasses.field(default_factory=list)
    _agg_index: Dict[Tuple, int] = dataclasses.field(default_factory=dict)
    bodies: set = dataclasses.field(default_factory=set)  # distinct bodies merged (stats)

    @property
    def n_aggs(self) -> int:
        return len(self.agg_cols)

    def add_col(self, spec: AggColSpec, body: FrozenSet[str]) -> Tuple[int, bool]:
        """Returns (column index, was_new)."""
        self.bodies.add(body)
        k = spec.skey()
        if k in self._agg_index:
            return self._agg_index[k], False
        idx = len(self.agg_cols)
        self.agg_cols.append(spec)
        self._agg_index[k] = idx
        return idx, True


@dataclasses.dataclass
class QueryOutput:
    """How to read a query's result out of its output container."""

    query: Query
    vid: int
    cols: Tuple[int, ...]           # one per aggregate of the query
    canonical_group_by: Tuple[str, ...]


@dataclasses.dataclass
class PushdownStats:
    n_app_aggregates: int = 0
    n_views_premerge: int = 0       # one per (product × edge) as in the paper's 3,256
    n_intermediate_cols: int = 0    # synthesized aggregate columns across all views
    n_views: int = 0                # merged containers
    n_dedup_hits: int = 0


class PushdownResult:
    def __init__(self, views: Dict[int, ViewDef], outputs: Dict[str, QueryOutput],
                 stats: PushdownStats):
        self.views = views
        self.outputs = outputs
        self.stats = stats


class _Orientation:
    """Per-root orientation of the join tree with LCA support."""

    def __init__(self, tree: JoinTree, root: str):
        self.tree = tree
        self.root = root
        self.parent: Dict[str, Optional[str]] = {root: None}
        self.depth: Dict[str, int] = {root: 0}
        stack = [root]
        while stack:
            n = stack.pop()
            for c in tree.adj[n]:
                if c not in self.depth:
                    self.parent[c] = n
                    self.depth[c] = self.depth[n] + 1
                    stack.append(c)

    def children(self, n: str) -> List[str]:
        return [m for m in self.tree.adj[n] if self.parent.get(m) == n]

    def lca(self, nodes: Sequence[str]) -> str:
        cur = nodes[0]
        for other in nodes[1:]:
            a, b = cur, other
            while self.depth[a] > self.depth[b]:
                a = self.parent[a]
            while self.depth[b] > self.depth[a]:
                b = self.parent[b]
            while a != b:
                a, b = self.parent[a], self.parent[b]
            cur = a
        return cur

    def home(self, attr: str) -> str:
        """Node containing ``attr`` closest to the root (unique: the nodes
        containing an attribute form a connected subtree by RIP)."""
        rels = self.tree.schema.relations_with(attr)
        if not rels:
            raise ValueError(f"attribute {attr!r} not in any relation")
        return min(rels, key=lambda r: self.depth[r])

    def eval_node(self, term: Term) -> str:
        attrs = term.attrs()
        if not attrs:
            return self.root
        return self.lca([self.home(a) for a in attrs])


class PushdownBuilder:
    """Builds the merged directional-view DAG for a query batch."""

    def __init__(self, tree: JoinTree):
        self.tree = tree
        self.schema = tree.schema
        self.views: Dict[int, ViewDef] = {}
        self._by_key: Dict[Tuple, int] = {}   # (edge_or_out_marker, group_by) → vid
        self.outputs: Dict[str, QueryOutput] = {}
        self.stats = PushdownStats()

    # -- containers ---------------------------------------------------------

    def _container(self, edge: Optional[Tuple[str, str]], rel: str,
                   group_by: Tuple[str, ...]) -> ViewDef:
        key = (edge if edge is not None else ("__out__", rel), group_by)
        if key not in self._by_key:
            vid = len(self.views)
            local = tuple(a for a in group_by if a in self.schema.relation(rel).attr_set)
            pulled = tuple(a for a in group_by if a not in self.schema.relation(rel).attr_set)
            vd = ViewDef(vid=vid, edge=edge, rel=rel, group_by=group_by,
                         local_keys=local, pulled_keys=pulled)
            self.views[vid] = vd
            self._by_key[key] = vid
        return self.views[self._by_key[key]]

    # -- public entry ---------------------------------------------------------

    def add_query(self, q: Query, root: str) -> None:
        if q.name in self.outputs:
            raise ValueError(f"duplicate query name {q.name!r}")
        ori = _Orientation(self.tree, root)
        for a in q.group_by:
            if not self.schema.attr(a).is_discrete:
                raise ValueError(f"query {q.name!r}: group-by {a!r} must be discrete")
        out_gb = self._canonical(root, q.group_by)
        container = self._container(None, root, out_gb)
        cols = []
        for agg_i in q.aggregates:
            self.stats.n_app_aggregates += 1
            prods = []
            for prod in agg_i.products:
                prods.append(self._place_product(ori, root, None, prod.terms,
                                                 frozenset(q.group_by)))
            col, new = container.add_col(AggColSpec(tuple(prods)),
                                         frozenset(self.tree.nodes))
            if not new:
                self.stats.n_dedup_hits += 1
            cols.append(col)
        self.outputs[q.name] = QueryOutput(q, container.vid, tuple(cols), out_gb)

    def finish(self) -> PushdownResult:
        self.stats.n_views = len(self.views)
        self.stats.n_intermediate_cols = sum(
            v.n_aggs for v in self.views.values() if v.edge is not None)
        return PushdownResult(self.views, self.outputs, self.stats)

    # -- recursion ------------------------------------------------------------

    def _canonical(self, rel: str, attrs: Sequence[str]) -> Tuple[str, ...]:
        rel_attrs = self.schema.relation(rel).attr_set
        local = sorted(a for a in attrs if a in rel_attrs)
        pulled = sorted(a for a in attrs if a not in rel_attrs)
        return tuple(local + pulled)

    def _place_product(self, ori: _Orientation, node: str, parent: Optional[str],
                       terms: Tuple[Term, ...], needed_out: FrozenSet[str]) -> ProductSpec:
        """Contribution of the subtree at ``node`` to one product: evaluates
        local terms at ``node`` and recurses one directional view per child
        edge.  ``needed_out`` = attrs this node's output must carry (the view's
        group_by for edge views; the query group-by at the root)."""
        node_attrs = self.schema.relation(node).attr_set
        local_terms = tuple(t for t in terms if ori.eval_node(t) == node)
        child_cols: List[ColRef] = []
        for c in ori.children(node):
            sub_nodes = self.tree.subtree_nodes(c, node)
            sub_attrs = self.tree.subtree_attrs(c, node)
            terms_below = tuple(t for t in terms if ori.eval_node(t) in sub_nodes)
            terms_outside = tuple(t for t in terms if ori.eval_node(t) not in sub_nodes)
            need_above = set(needed_out)
            for t in terms_outside:
                need_above |= t.attrs()
            pulled = sorted(a for a in need_above
                            if a in sub_attrs and a not in node_attrs)
            for a in pulled:
                if not self.schema.attr(a).is_discrete:
                    raise ValueError(
                        f"continuous attribute {a!r} would need to be pulled through "
                        f"edge {c}->{node}; only discrete attributes can be view keys "
                        "(paper §3.2: added as group-by attributes)")
            join = sorted(self.tree.join_attrs(c, node))
            gb = tuple(sorted(set(join)) + [a for a in pulled if a not in join])
            self.stats.n_views_premerge += 1
            col = self._build_edge_view(ori, c, node, gb, terms_below)
            child_cols.append(col)
        return ProductSpec(local_terms, tuple(child_cols))

    def _build_edge_view(self, ori: _Orientation, child: str, parent: str,
                         group_by: Tuple[str, ...], terms: Tuple[Term, ...]) -> ColRef:
        container = self._container((child, parent), child, group_by)
        spec = self._place_product(ori, child, parent, terms, frozenset(group_by))
        body = self.tree.subtree_nodes(child, parent)
        col, new = container.add_col(AggColSpec((spec,)), body)
        if not new:
            self.stats.n_dedup_hits += 1
        return ColRef(container.vid, col)


def push_down(tree: JoinTree, queries: Sequence[Query],
              roots: Dict[str, str]) -> PushdownResult:
    b = PushdownBuilder(tree)
    for q in queries:
        b.add_query(q, roots[q.name])
    return b.finish()
