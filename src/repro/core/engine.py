"""Engine internals: compile a batch of aggregate queries into an executable.

The *public* entry point is the session facade (``repro.connect`` →
``Database.views``, DESIGN.md §9); this module is what it drives:

    eng = Engine(schema, sizes=db.sizes())
    batch = eng._compile(queries)             # layers 1-6 + jit (codegen)
    results = batch(db)                       # {query name: dense array}
    results = batch.run_sharded(db, mesh)     # domain-parallel over chips

``Engine.compile`` / ``Engine.compile_incremental`` remain as deprecated
shims (one release) that emit :class:`EngineDeprecationWarning`.

Compilation lowers through three separable stages (DESIGN.md §3-§5): the
group-program IR (``ir.py``), the shared-scan scheduler (``schedule.py``),
and a pluggable lowering backend (``lowering/``: ``xla`` or ``pallas``).
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import roots as roots_mod
from repro.core.aggregates import Params, Query
from repro.core.groups import ViewGroup, group_views, independent_sets
from repro.core.jointree import JoinTree
from repro.core.plan import ExecutablePlan, PlanConfig
from repro.core.pushdown import PushdownResult, push_down
from repro.core.schema import DatabaseSchema
from repro.obs.trace import span


class EngineDeprecationWarning(DeprecationWarning):
    """Raised (as a warning) by the legacy compile entry points; the
    session facade (``repro.connect`` → ``Database.views``, DESIGN.md §9)
    replaces them.  A distinct category so CI can fail hard on deprecated
    API leaking out of this package without tripping on third-party
    DeprecationWarnings."""


@dataclasses.dataclass
class BatchStats:
    """Paper Table 2 analogue.  ``n_scan_steps`` counts the relation scans
    actually executed after shared-scan fusion; ``n_fused_scans`` is how many
    of the ``n_groups`` group scans the scheduler eliminated."""

    n_app_aggregates: int
    n_intermediate_cols: int
    n_views_premerge: int
    n_views: int
    n_groups: int
    group_levels: int
    n_scan_steps: int
    n_fused_scans: int
    roots: Dict[str, str]
    #: static kernel-launch sites per full pass (pallas; 0 for xla) — the
    #: quantity launch fusion shrinks to one per scan step
    n_kernel_launches: int = 0

    def summary(self) -> str:
        return (f"A={self.n_app_aggregates} I={self.n_intermediate_cols} "
                f"V={self.n_views} (pre-merge {self.n_views_premerge}) "
                f"G={self.n_groups} levels={self.group_levels} "
                f"scans={self.n_scan_steps} (fused {self.n_fused_scans}) "
                f"launches={self.n_kernel_launches}")


class CompiledBatch:
    def __init__(self, schema: DatabaseSchema, tree: JoinTree,
                 result: PushdownResult, groups: List[ViewGroup],
                 config: PlanConfig, roots: Dict[str, str]):
        self.schema = schema
        self.tree = tree
        self.result = result
        self.groups = groups
        self.config = config
        self.roots = roots
        self.plan = ExecutablePlan(schema, tree, result, groups, config)
        self._jitted = {}
        #: device dispatches issued (``__call__`` + ``run_batched``); the
        #: frontier-batched tree builder asserts one per tree level on this
        self.n_dispatches = 0

    @property
    def stats(self) -> BatchStats:
        s = self.result.stats
        sched = self.plan.schedule
        return BatchStats(
            n_app_aggregates=s.n_app_aggregates,
            n_intermediate_cols=s.n_intermediate_cols,
            n_views_premerge=s.n_views_premerge,
            n_views=s.n_views,
            n_groups=len(self.groups),
            group_levels=len(independent_sets(self.groups)),
            n_scan_steps=sched.n_scans,
            n_fused_scans=sched.n_fused_groups,
            roots=self.roots,
            n_kernel_launches=self.plan.n_kernel_launches(),
        )

    @property
    def schedule(self):
        """The fused scan schedule this batch executes."""
        return self.plan.schedule

    # -- single-device ------------------------------------------------------

    def __call__(self, db, params: Optional[Params] = None) -> Dict[str, jnp.ndarray]:
        params = dict(params or {})
        n_rows = db.sizes()
        key = ("local", tuple(sorted(n_rows.items())), tuple(sorted(params)))
        if key not in self._jitted:
            run = self.plan.bind(n_rows)
            self._jitted[key] = jax.jit(lambda cols, p: run(cols, p))
        cols = {name: dict(rel.columns) for name, rel in db.relations.items()}
        self.n_dispatches += 1
        return self._jitted[key](cols, params)

    # -- param-batched (node frontier) ---------------------------------------

    @property
    def batched_params(self):
        """Names of the batch's ``Param(batched=True)`` declarations."""
        return self.plan.batched_params

    def run_batched(self, db, params: Params, n_nodes: Optional[int] = None,
                    pad_to_pow2: bool = True) -> Dict[str, jnp.ndarray]:
        """Evaluate ``N`` parameter settings of the compiled batch in ONE
        fused device dispatch (DESIGN.md §7.4).

        Every batched param in ``params`` must carry a leading axis of size
        ``N`` (inferred from the first batched param when ``n_nodes`` is
        omitted); batched query outputs come back as ``(N, *group_dims,
        n_aggs)``.  The relation-scan schedule is identical to the N=1 case —
        one pass over each relation serves all ``N`` nodes.

        ``pad_to_pow2`` (default) rounds the node axis up to the next power
        of two with zeroed param rows (sliced off the outputs), so a growing
        tree frontier hits at most ``log2`` distinct jit cache entries
        instead of one per level."""
        params = dict(params or {})
        if not self.plan.batched_params:
            raise ValueError("batch was compiled without batched params; "
                             "declare Param(..., batched=True) terms first")
        if n_nodes is None:
            name = sorted(self.plan.batched_params)[0]
            n_nodes = int(jnp.shape(params[name])[0])
        n_run = n_nodes
        if pad_to_pow2:
            n_run = 1
            while n_run < n_nodes:
                n_run *= 2
            if n_run != n_nodes:
                pad = n_run - n_nodes
                for name in self.plan.batched_params:
                    v = jnp.asarray(params[name])
                    params[name] = jnp.pad(
                        v, [(0, pad)] + [(0, 0)] * (v.ndim - 1))
        n_rows = db.sizes()
        key = ("batched", n_run, tuple(sorted(n_rows.items())),
               tuple(sorted(params)))
        if key not in self._jitted:
            run = self.plan.bind(n_rows, n_nodes=n_run)
            self._jitted[key] = jax.jit(lambda cols, p: run(cols, p))
        cols = {name: dict(rel.columns) for name, rel in db.relations.items()}
        self.n_dispatches += 1
        out = self._jitted[key](cols, params)
        if n_run != n_nodes:
            batched_vids = self.plan.batched_vids
            out = {q: (v[:n_nodes]
                       if self.result.outputs[q].vid in batched_vids else v)
                   for q, v in out.items()}
        return out

    def lower(self, db, params: Optional[Params] = None,
              n_nodes: Optional[int] = None):
        """Lower without executing (dry-run / HLO inspection); pass
        ``n_nodes`` for plans with batched params."""
        params = dict(params or {})
        run = self.plan.bind(db.sizes(), n_nodes=n_nodes)
        cols = {name: {a: jax.ShapeDtypeStruct(c.shape, c.dtype)
                       for a, c in rel.columns.items()}
                for name, rel in db.relations.items()}
        pspec = {k: jax.ShapeDtypeStruct(jnp.shape(v), jnp.asarray(v).dtype)
                 for k, v in params.items()}
        return jax.jit(lambda c, p: run(c, p)).lower(cols, pspec)

    # -- domain-parallel (paper layer 7 on a chip mesh) ----------------------

    def run_sharded(self, db, mesh, axis: str = "data",
                    shard_rel: Optional[str] = None,
                    params: Optional[Params] = None,
                    n_nodes: Optional[int] = None) -> Dict[str, jnp.ndarray]:
        """Partition ``shard_rel`` (default: the largest relation — the
        paper's choice) across the mesh axis; every device runs the
        multi-output plans on its partition; partial dense views are psum'd
        right after their group (LMFAO's merge of per-thread results).

        Batched plans shard too: ``n_nodes`` is inferred from the first
        batched param when omitted, so a node frontier can be evaluated
        domain-parallel in one collective pass."""
        from repro.core.distributed import sharded_runner

        params = dict(params or {})
        if self.plan.batched_params and n_nodes is None:
            name = sorted(self.plan.batched_params)[0]
            n_nodes = int(jnp.shape(params[name])[0])
        shard_rel = shard_rel or max(db.sizes(), key=lambda k: db.sizes()[k])
        fn, cols = sharded_runner(self.plan, db, mesh, axis, shard_rel,
                                  n_nodes=n_nodes)
        self.n_dispatches += 1
        return fn(cols, params)


class Engine:
    """Layer driver: join tree -> roots -> pushdown+merge -> groups -> IR ->
    schedule -> backend lowering."""

    def __init__(self, schema: DatabaseSchema,
                 edges: Optional[Sequence[Tuple[str, str]]] = None,
                 sizes: Optional[Dict[str, int]] = None):
        self.schema = schema
        self.sizes = dict(sizes or {})
        if edges is not None:
            self.tree = JoinTree(schema, edges)
        else:
            self.tree = JoinTree.build(schema, self.sizes)

    def compile(self, queries: Sequence[Query], *, multi_root: bool = True,
                block_size=4096, backend: str = "xla",
                interpret: Optional[bool] = None, fuse_scans: bool = True,
                block_rows=512, fuse_kernels: bool = True,
                double_buffer: bool = True,
                autotune_cache: Optional[str] = None,
                root_override: Optional[Dict[str, str]] = None,
                verify_plans: Optional[bool] = None) -> CompiledBatch:
        """Deprecated shim over :meth:`_compile` — use the session facade:
        ``repro.connect(..., config=ExecutionConfig(...)).views(queries)``."""
        warnings.warn(
            "Engine.compile is deprecated; open a session with "
            "repro.connect(dataset_or_schema, config=ExecutionConfig(...)) "
            "and register the batch with Database.views(queries) "
            "(DESIGN.md §9)", EngineDeprecationWarning, stacklevel=2)
        return self._compile(queries, multi_root=multi_root,
                             block_size=block_size, backend=backend,
                             interpret=interpret, fuse_scans=fuse_scans,
                             block_rows=block_rows, fuse_kernels=fuse_kernels,
                             double_buffer=double_buffer,
                             autotune_cache=autotune_cache,
                             root_override=root_override,
                             verify_plans=verify_plans)

    def _compile(self, queries: Sequence[Query], *, multi_root: bool = True,
                 block_size=4096, backend: str = "xla",
                 interpret: Optional[bool] = None, fuse_scans: bool = True,
                 block_rows=512, fuse_kernels: bool = True,
                 double_buffer: bool = True,
                 autotune_cache: Optional[str] = None,
                 root_override: Optional[Dict[str, str]] = None,
                 verify_plans: Optional[bool] = None) -> CompiledBatch:
        """Compile a query batch.  ``backend`` selects the lowering path
        (``"xla"``: blocked lax.scan; ``"pallas"``: MXU kernels, with
        ``interpret`` controlling CPU interpret mode — None auto-detects);
        ``fuse_scans`` toggles the scheduler's shared-scan fusion.

        Blocking: ``block_size`` is the outer lax.scan row block,
        ``block_rows`` the Pallas kernel row grid — either may be the string
        ``"auto"`` to defer to the bind-time autotuner (``core/autotune.py``,
        cache path overridable via ``autotune_cache``).  ``fuse_kernels``
        collapses each step's bucket/hist reductions into one fused launch
        per row block; ``double_buffer`` enables its manual HBM→VMEM DMA
        pipeline (DESIGN.md §10)."""
        with span("compile", n_queries=len(queries), backend=backend):
            with span("compile.roots"):
                if root_override is not None:
                    roots = dict(root_override)
                elif multi_root:
                    roots = roots_mod.find_roots(self.tree, queries,
                                                 self.sizes)
                else:
                    roots = roots_mod.single_root(self.tree, queries,
                                                  self.sizes)
            with span("compile.pushdown"):
                result = push_down(self.tree, queries, roots)
            with span("compile.group"):
                groups = group_views(result)
            cfg = PlanConfig(block_size=block_size, backend=backend,
                             interpret=interpret, fuse_scans=fuse_scans,
                             block_rows=block_rows, fuse_kernels=fuse_kernels,
                             double_buffer=double_buffer,
                             autotune_cache=autotune_cache,
                             verify_plans=verify_plans)
            # CompiledBatch builds the ExecutablePlan, which emits the
            # compile.ir / compile.schedule child spans
            return CompiledBatch(self.schema, self.tree, result, groups, cfg,
                                 roots)

    def compile_incremental(self, queries: Sequence[Query], *,
                            multi_root: bool = True, block_size=4096,
                            backend: str = "xla",
                            interpret: Optional[bool] = None,
                            fuse_scans: bool = True, block_rows=512,
                            fuse_kernels: bool = True,
                            double_buffer: bool = True,
                            autotune_cache: Optional[str] = None,
                            root_override: Optional[Dict[str, str]] = None,
                            warm_rels: Sequence[str] = (),
                            verify_plans: Optional[bool] = None):
        """Deprecated shim over :meth:`_compile_incremental` — use
        ``repro.connect(...).views(queries, maintain=True)``."""
        warnings.warn(
            "Engine.compile_incremental is deprecated; open a session with "
            "repro.connect(...) and register maintained views with "
            "Database.views(queries, maintain=True) (DESIGN.md §9)",
            EngineDeprecationWarning, stacklevel=2)
        return self._compile_incremental(
            queries, multi_root=multi_root, block_size=block_size,
            backend=backend, interpret=interpret, fuse_scans=fuse_scans,
            block_rows=block_rows, fuse_kernels=fuse_kernels,
            double_buffer=double_buffer, autotune_cache=autotune_cache,
            root_override=root_override, warm_rels=warm_rels,
            verify_plans=verify_plans)

    def _compile_incremental(self, queries: Sequence[Query], *,
                             multi_root: bool = True, block_size=4096,
                             backend: str = "xla",
                             interpret: Optional[bool] = None,
                             fuse_scans: bool = True, block_rows=512,
                             fuse_kernels: bool = True,
                             double_buffer: bool = True,
                             autotune_cache: Optional[str] = None,
                             root_override: Optional[Dict[str, str]] = None,
                             warm_rels: Sequence[str] = (),
                             mesh=None, mesh_axis: str = "data",
                             shard_rel: Optional[str] = None,
                             verify_plans: Optional[bool] = None):
        """Compile a query batch for incremental view maintenance: returns a
        :class:`~repro.core.ivm.MaintainedBatch` whose ``init(db)``
        materializes every view as persistent state and whose ``apply``
        folds a :class:`~repro.data.relations.DeltaBatchUpdate` into that
        state via per-relation delta programs (DESIGN.md §8).

        With a ``mesh`` the maintained state shards: ``shard_rel`` (default
        the largest relation at init) partitions row-wise over ``mesh_axis``
        and every relation tick runs as one cached ``jit(shard_map)``
        (DESIGN.md §6/§8).

        Delta programs are derived lazily on first update of each relation
        and cached; ``warm_rels`` pre-builds the programs for relations you
        expect to stream updates for (e.g. the fact table), moving that
        compile cost out of the first ``apply``.

        Rejects non-invertible (MIN/MAX-style) aggregates up front: signed
        multiplicities maintain SUM-like aggregates only, and a silent wrong
        retraction is far worse than a compile error."""
        from repro.core.ivm import MaintainedBatch

        for q in queries:
            for a in q.aggregates:
                for prod in a.products:
                    for t in prod.terms:
                        if not t.is_invertible():
                            raise ValueError(
                                f"query {q.name!r}: aggregate term {t.key()!r} "
                                "is not invertible under retraction (MIN/MAX-"
                                "style UDAF) — incremental maintenance by "
                                "signed multiplicities would produce wrong "
                                "results on deletes; use Engine.compile for "
                                "batch recomputation instead")

        batch = self._compile(queries, multi_root=multi_root,
                              block_size=block_size, backend=backend,
                              interpret=interpret, fuse_scans=fuse_scans,
                              block_rows=block_rows,
                              fuse_kernels=fuse_kernels,
                              double_buffer=double_buffer,
                              autotune_cache=autotune_cache,
                              root_override=root_override,
                              verify_plans=verify_plans)
        mb = MaintainedBatch(batch, mesh=mesh, mesh_axis=mesh_axis,
                             shard_rel=shard_rel)
        for rel in warm_rels:
            mb.delta_program(rel)
        return mb
