"""Sharded checkpoint store: crash-safe save/restore for train state pytrees.

Layout (one directory per step):
    <dir>/step_000123/
        manifest.json      tree structure, shapes, dtypes, integrity hashes
        <leafpath>.npy     one file per leaf (host-sharded in multi-process
                           runs: each process writes its addressable shards;
                           on this single-process container that is one host)

Writes go to a temp dir renamed atomically into place; a checkpoint is only
visible once complete (crash during save can never corrupt the latest good
step).  ``restore`` returns plain numpy trees — placing them onto a (possibly
different-sized) mesh is the caller's jit/device_put, which is what makes
elastic restarts work: the store is mesh-agnostic.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _leaf_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, leaf))
    return out


def _digest(a: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest()[:16]


def save(ckpt_dir: str, step: int, state: Any, keep: int = 3) -> str:
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest: Dict[str, Any] = {"step": step, "leaves": {}}
    # one explicit batched device→host gather for the whole tree (numpy
    # leaves pass through untouched); sharded/replicated leaves land as
    # plain host arrays, keeping the store mesh-agnostic
    state = jax.device_get(state)
    for name, leaf in _leaf_paths(state):
        a = np.asarray(leaf)
        fn = name.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fn), a)
        manifest["leaves"][name] = {"file": fn, "shape": list(a.shape),
                                    "dtype": str(a.dtype), "sha": _digest(a)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic visibility
    _gc(ckpt_dir, keep)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None,
            verify: bool = True) -> Tuple[Any, int]:
    """Rebuild a pytree shaped like ``like`` from disk (numpy leaves)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = {}
    for name, meta in manifest["leaves"].items():
        a = np.load(os.path.join(d, meta["file"]))
        if verify and _digest(a) != meta["sha"]:
            raise IOError(f"checkpoint corruption in {name} at step {step}")
        leaves[name] = a
    names = [n for n, _ in _leaf_paths(like)]
    missing = set(names) - set(leaves)
    if missing:
        raise KeyError(f"checkpoint missing leaves: {sorted(missing)[:5]}...")
    flat = [leaves[n] for n in names]
    tdef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(tdef, flat), step


# --------------------------------------------------------------------------
# IVM view-state snapshots (core/ivm.py): a MaintainedBatch's state — epoch
# and update counters, every materialized view tensor, and the base
# relations (trimmed to valid rows) — is a pytree, so it rides the same
# crash-safe store as train state.
# --------------------------------------------------------------------------

def save_view_state(ckpt_dir: str, maintained, keep: int = 3,
                    epoch: Optional[int] = None) -> str:
    """Snapshot a ``MaintainedBatch`` (its update counter names the step).

    The snapshot is epoch-atomic: ``snapshot_state`` resolves one immutable
    :class:`~repro.core.ivm.EpochState` before serializing anything, so a
    concurrent ``apply`` publishing mid-save cannot tear it.  Pass a pinned
    ``epoch`` to checkpoint that exact version instead of whatever is
    current at call time."""
    tree = maintained.snapshot_state(epoch=epoch)
    return save(ckpt_dir, int(np.asarray(tree["step"])), tree, keep=keep)


def restore_view_state(ckpt_dir: str, maintained, step: Optional[int] = None) -> int:
    """Load a view-state snapshot back into a ``MaintainedBatch`` compiled
    for the same query batch (view ids and relation schemas must match; the
    skeleton tree supplies the structure, so ``init`` need not have run)."""
    tree, s = restore(ckpt_dir, maintained.state_skeleton(), step=step)
    maintained.load_state(tree)
    return s


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
