"""checkpoint substrate."""
