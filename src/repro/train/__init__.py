"""train substrate."""
