"""Train / prefill step builders with GSPMD sharding.

Features:
  * chunked cross-entropy — the LM head + softmax run over sequence chunks
    (rematerialized), so (B, S, vocab) logits never materialize;
  * microbatch gradient accumulation via ``lax.scan`` (compute/comm overlap:
    the per-microbatch backward's reduce-scatters overlap the next
    microbatch's forward under XLA's async collectives);
  * optional int8 error-feedback gradient compression between accumulation
    and the optimizer (distributed/compression.py);
  * AdamW with global-norm clipping, cosine/WSD schedules.

State layout (a plain dict pytree): params / m / v / step / (ef residual).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import compression as comp
from repro.distributed.sharding import (mesh_context, param_pspecs, rules_for,
                                        spec_for)
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.models.layers import abstract_params, init_params, logits_apply
from repro.train import adamw
from repro.train.schedules import SCHEDULES


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"
    adam: adamw.AdamWConfig = dataclasses.field(default_factory=adamw.AdamWConfig)
    ce_chunk: int = 512
    grad_accum: int = 1
    aux_loss_weight: float = 0.01
    compress_grads: bool = False
    attn_impl: str = "chunked"


def chunked_ce(params, x, labels, mask, cfg: ModelConfig, chunk: int):
    """Mean next-token CE; head applied per sequence chunk under remat."""
    b, s, d = x.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = x.shape[1] // chunk
    xc = x.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)
    mc = mask.reshape(b, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_loss(xi, li, mi):
        from repro.distributed.sharding import constrain
        logits = logits_apply(params, xi).astype(jnp.float32)
        logits = constrain(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * mi)

    def body(acc, xs):
        xi, li, mi = xs
        return acc + chunk_loss(xi, li, mi), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc, mc))
    return total / jnp.maximum(mask.sum(), 1.0)


def _loss_fn(params, batch, cfg: ModelConfig, tcfg: TrainConfig):
    x, aux = M.forward_hidden(params, batch, cfg, impl=tcfg.attn_impl)
    tokens = batch["tokens"]
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
    loss = chunked_ce(params, x, labels, mask, cfg, tcfg.ce_chunk)
    loss = loss + tcfg.aux_loss_weight * aux["aux_loss"]
    return loss, aux


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    mesh: Optional[Mesh] = None, rules=None):
    """Returns train_step(state, batch) -> (state, metrics)."""
    sched = SCHEDULES[tcfg.schedule]

    def train_step(state, batch):
        with mesh_context(mesh, rules):
            params = state["params"]
            if tcfg.grad_accum > 1:
                micro = jax.tree.map(
                    lambda a: a.reshape((tcfg.grad_accum, a.shape[0] // tcfg.grad_accum)
                                        + a.shape[1:]), batch)

                def acc_body(carry, mb):
                    gsum, lsum = carry
                    (l, _), g = jax.value_and_grad(_loss_fn, has_aux=True)(
                        params, mb, cfg, tcfg)
                    return (jax.tree.map(jnp.add, gsum, g), lsum + l), None

                zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (grads, ltot), _ = jax.lax.scan(acc_body, (zeros, 0.0), micro)
                grads = jax.tree.map(lambda g: g / tcfg.grad_accum, grads)
                loss = ltot / tcfg.grad_accum
            else:
                (loss, _), grads = jax.value_and_grad(_loss_fn, has_aux=True)(
                    params, batch, cfg, tcfg)

            if tcfg.compress_grads:
                grads, new_ef = comp.compress_decompress(grads, state["ef"])
            else:
                new_ef = state.get("ef")

            lr = sched(state["step"], peak_lr=tcfg.peak_lr, warmup=tcfg.warmup,
                       total=tcfg.total_steps)
            new_p, new_m, new_v, gnorm = adamw.update(
                params, grads, state["m"], state["v"], state["step"], lr, tcfg.adam)
            new_state = {"params": new_p, "m": new_m, "v": new_v,
                         "step": state["step"] + 1}
            if new_ef is not None:
                new_state["ef"] = new_ef
            metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
            return new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, tcfg: TrainConfig,
                      mesh: Optional[Mesh] = None, rules=None):
    """Forward-only prefill: backbone + last-position logits."""

    def prefill_step(params, batch):
        with mesh_context(mesh, rules):
            x, _ = M.forward_hidden(params, batch, cfg, impl=tcfg.attn_impl)
            logits = logits_apply(params, x[:, -1:, :])
            return logits

    return prefill_step


# ---------------------------------------------------------------------------
# State construction + shardings
# ---------------------------------------------------------------------------


def init_state(cfg: ModelConfig, tcfg: TrainConfig, key) -> Dict[str, Any]:
    specs = M.model_specs(cfg)
    params = init_params(specs, key, cfg.jdtype)
    m, v = adamw.init_moments(params)
    state = {"params": params, "m": m, "v": v,
             "step": jnp.zeros((), jnp.int32)}
    if tcfg.compress_grads:
        state["ef"] = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def abstract_state(cfg: ModelConfig, tcfg: TrainConfig) -> Dict[str, Any]:
    specs = M.model_specs(cfg)
    params = abstract_params(specs, cfg.jdtype)
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    state = {"params": params, "m": jax.tree.map(f32, params),
             "v": jax.tree.map(f32, params),
             "step": jax.ShapeDtypeStruct((), jnp.int32)}
    if tcfg.compress_grads:
        state["ef"] = jax.tree.map(f32, params)
    return state


def state_pspecs(cfg: ModelConfig, tcfg: TrainConfig, mesh: Mesh):
    rules = rules_for(mesh)
    pspec = param_pspecs(M.model_specs(cfg), rules, mesh)
    state = {"params": pspec, "m": pspec, "v": pspec, "step": P()}
    if tcfg.compress_grads:
        state["ef"] = pspec
    return state


def batch_pspecs(cfg: ModelConfig, mesh: Mesh):
    rules = rules_for(mesh)
    bspec = spec_for(("batch", None), rules)
    out = {"tokens": bspec}
    if cfg.family == "vlm":
        out["vision"] = spec_for(("batch", None, None), rules)
    if cfg.family == "audio":
        out["frames"] = spec_for(("batch", None, None), rules)
    return out
