"""AdamW in raw JAX (pytree-generic, dtype-safe for bf16 params).

Moments are kept in float32 regardless of parameter dtype; the update is
computed in float32 and cast back — the standard mixed-precision recipe.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def init_moments(params) -> Tuple[Any, Any]:
    z = lambda p: jnp.zeros(p.shape, jnp.float32)
    return jax.tree.map(z, params), jax.tree.map(z, params)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree, max_norm):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), n


def update(params, grads, m, v, step, lr, cfg: AdamWConfig):
    """Returns (new_params, new_m, new_v, grad_norm)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    t = jnp.asarray(step, jnp.float32) + 1.0
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m_, v_):
        g32 = g.astype(jnp.float32)
        m_n = cfg.b1 * m_ + (1 - cfg.b1) * g32
        v_n = cfg.b2 * v_ + (1 - cfg.b2) * g32 * g32
        mhat = m_n / bc1
        vhat = v_n / bc2
        p32 = p.astype(jnp.float32)
        step_ = lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p32)
        return (p32 - step_).astype(p.dtype), m_n, v_n

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(m)
    flat_v = tdef.flatten_up_to(v)
    out = [upd(p, g, m_, v_) for p, g, m_, v_ in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, new_m, new_v, gnorm
