"""LR schedules: cosine (default) and WSD (warmup-stable-decay, MiniCPM)."""

from __future__ import annotations

import jax.numpy as jnp


def cosine(step, *, peak_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)


def wsd(step, *, peak_lr: float, warmup: int, total: int, decay_frac: float = 0.1,
        min_frac: float = 0.01):
    """Warmup -> stable plateau -> short exponential-ish decay (MiniCPM §4)."""
    step = jnp.asarray(step, jnp.float32)
    decay_start = total * (1 - decay_frac)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - decay_start) / jnp.maximum(total - decay_start, 1), 0.0, 1.0)
    decay = peak_lr * (min_frac ** prog)
    out = jnp.where(step < warmup, warm, peak_lr)
    return jnp.where(step > decay_start, decay, out)


SCHEDULES = {"cosine": cosine, "wsd": wsd}
