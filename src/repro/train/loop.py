"""Fault-tolerant training loop.

  * checkpoint/restart: atomic sharded checkpoints every N steps; on start
    the loop resumes from the latest complete step (tested: an interrupted
    run's loss trajectory is bitwise-identical to an uninterrupted one);
  * deterministic data: batches are pure functions of (seed, step), so
    restart/elastic-resize replays the exact stream;
  * straggler mitigation: per-step wall time vs. a rolling median — outliers
    beyond ``straggler_factor``× are logged and counted; the hook is where a
    production deployment triggers re-mesh / hot-spare swap (on one host we
    record and expose the signal);
  * elastic scaling: the loop is mesh-agnostic — restore onto a different
    device count and the same global batch keeps the trajectory.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import store
from repro.data.pipeline import TokenPipeline


@dataclasses.dataclass
class LoopConfig:
    max_steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: Optional[str] = None
    keep: int = 3
    straggler_factor: float = 3.0
    log_every: int = 10


@dataclasses.dataclass
class StepRecord:
    step: int
    loss: float
    wall: float
    straggler: bool


class TrainLoop:
    def __init__(self, train_step: Callable, pipeline: TokenPipeline,
                 cfg: LoopConfig, log: Callable[[str], None] = print):
        self.train_step = train_step
        self.pipeline = pipeline
        self.cfg = cfg
        self.log = log
        self.records: List[StepRecord] = []
        self.straggler_events = 0

    def run(self, state: Any) -> Any:
        cfg = self.cfg
        start = 0
        if cfg.ckpt_dir is not None:
            latest = store.latest_step(cfg.ckpt_dir)
            if latest is not None:
                state, start = store.restore(cfg.ckpt_dir, state)
                self.log(f"[loop] resumed from checkpoint step {start}")
        times: List[float] = []
        for step in range(start, cfg.max_steps):
            batch = self.pipeline.batch_at(step)
            t0 = time.perf_counter()
            state, metrics = self.train_step(state, batch)
            loss = float(jax.device_get(metrics["loss"]))  # sync point
            wall = time.perf_counter() - t0
            times.append(wall)
            med = float(np.median(times[-32:]))
            straggle = len(times) > 4 and wall > cfg.straggler_factor * med
            if straggle:
                self.straggler_events += 1
                self.log(f"[loop] straggler at step {step}: {wall:.3f}s vs median "
                         f"{med:.3f}s (event #{self.straggler_events})")
            self.records.append(StepRecord(step, loss, wall, straggle))
            if cfg.log_every and step % cfg.log_every == 0:
                self.log(f"[loop] step {step} loss {loss:.4f} "
                         f"({wall * 1e3:.0f} ms)")
            done = step + 1
            if cfg.ckpt_dir is not None and (done % cfg.ckpt_every == 0
                                             or done == cfg.max_steps):
                path = store.save(cfg.ckpt_dir, done, state, keep=cfg.keep)
                self.log(f"[loop] checkpoint @ step {done} -> {path}")
        return state

    def losses(self) -> List[float]:
        return [r.loss for r in self.records]
