"""Process-local counters, gauges, and fixed-bucket latency histograms.

The serving layer needs latency *distributions* (p50/p95/p99 read and tick
latency), not just totals — but a long-lived server cannot store one sample
per request.  :class:`Histogram` keeps a fixed 1-2-5 log-spaced bucket
ladder (microseconds, ~1us .. 60s by default) and answers percentile
queries by linear interpolation inside the covering bucket, so memory is
O(#buckets) forever and an observation is one binary search + one integer
increment under a lock.  Quantile error is bounded by bucket width (<= 2.5x
at the resolution below — fine for the "did p99 blow up" question these
feed; DESIGN.md §11).

Like the tracing layer, metrics never touch the device: an observation is
a host-side float.  Callers time dispatch walls with ``perf_counter`` and
observe the result — no ``block_until_ready``, so the zero-sync serving
contract survives with metrics enabled.

:class:`Registry` is a tiny name->metric map so a component (a
``MaintainedBatch``, a ``ViewServer``) can own its metrics and surface
them as one ``snapshot()`` dict through ``stats()`` / ``explain()``.
"""

from __future__ import annotations

import bisect
import threading
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "Registry",
           "LATENCY_BUCKETS_US"]


def _ladder_125(lo: float, hi: float) -> Tuple[float, ...]:
    """1-2-5 log ladder covering [lo, hi]."""
    out: List[float] = []
    decade = lo
    while decade <= hi:
        for m in (1.0, 2.0, 5.0):
            v = decade * m
            if lo <= v <= hi:
                out.append(v)
        decade *= 10.0
    return tuple(out)


#: default latency ladder in microseconds: 1us .. 60s
LATENCY_BUCKETS_US = _ladder_125(1.0, 2e7) + (6e7,)


class Counter:
    """Monotone event counter."""

    __slots__ = ("name", "_v", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._v = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._v += n

    @property
    def value(self) -> int:
        return self._v

    def snapshot(self):
        return self._v


class Gauge:
    """Last-write-wins instantaneous value (e.g. pin-table occupancy)."""

    __slots__ = ("name", "_v", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._v = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._v = v

    def max(self, v: float) -> None:
        """Ratchet upward (high-water mark)."""
        with self._lock:
            if v > self._v:
                self._v = v

    @property
    def value(self) -> float:
        return self._v

    def snapshot(self):
        return self._v


class Histogram:
    """Fixed-bucket histogram with interpolated percentiles — p50/p95/p99
    without storing samples.

    ``bounds`` are the bucket *upper* edges (ascending); one overflow
    bucket catches everything above the last edge.  ``min``/``max`` are
    tracked exactly and clamp the interpolation, so degenerate cases (one
    sample, everything in one bucket) stay sensible."""

    def __init__(self, name: str,
                 bounds: Sequence[float] = LATENCY_BUCKETS_US):
        self.name = name
        self.bounds: Tuple[float, ...] = tuple(bounds)
        if list(self.bounds) != sorted(self.bounds) or not self.bounds:
            raise ValueError("histogram bounds must be ascending, non-empty")
        self._counts = [0] * (len(self.bounds) + 1)
        self._count = 0
        self._sum = 0.0
        self._min: Optional[float] = None
        self._max: Optional[float] = None
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        i = bisect.bisect_left(self.bounds, v)
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += v
            if self._min is None or v < self._min:
                self._min = v
            if self._max is None or v > self._max:
                self._max = v

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def percentile(self, p: float) -> float:
        """Interpolated p-th percentile (p in [0, 100]); 0.0 when empty."""
        with self._lock:
            total = self._count
            if not total:
                return 0.0
            rank = (p / 100.0) * total
            seen = 0.0
            for i, c in enumerate(self._counts):
                if not c:
                    continue
                if seen + c >= rank:
                    lo = self.bounds[i - 1] if i > 0 else 0.0
                    hi = (self.bounds[i] if i < len(self.bounds)
                          else (self._max or self.bounds[-1]))
                    lo = max(lo, self._min or lo)
                    hi = min(hi, self._max or hi)
                    if hi < lo:
                        hi = lo
                    frac = (rank - seen) / c
                    return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
                seen += c
            return self._max or 0.0

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            count, total = self._count, self._sum
        return {"count": count, "sum": total,
                "mean": (total / count) if count else 0.0,
                "min": self._min or 0.0, "max": self._max or 0.0,
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99)}


class Registry:
    """A component's named metrics; ``snapshot()`` feeds stats()/explain()."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls, *args):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, *args)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  bounds: Sequence[float] = LATENCY_BUCKETS_US) -> Histogram:
        return self._get(name, Histogram, bounds)

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            items = list(self._metrics.items())
        return {name: m.snapshot() for name, m in sorted(items)}
