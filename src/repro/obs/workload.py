"""Bounded in-memory workload recorder — the view advisor's input.

LMFAO's thesis is that a *batch* of aggregates shares structure; applying
it to a live workload (ROADMAP item 2: route ad-hoc queries to maintained
views, advise which wider views to materialize) first requires a record of
what the workload actually asked: which group-by signatures, through which
path (full scan, epoch read, pinned serving read), at what latency.  This
module captures exactly that.

A :class:`QuerySignature` is the *router key* of a query — its group-by
dims, its static filter predicates, and its aggregate shapes, all rendered
structurally (no callables, no array values) so signatures hash, compare,
and serialize stably across sessions.  Two queries with the same signature
are answerable by the same maintained view; a signature that keeps hitting
the fallback path is the advisor's materialization candidate.

The :class:`WorkloadRecorder` is a bounded ring (``capacity`` newest
records kept, older ones counted in ``n_dropped``) fed by every
``ViewHandle.run``/``run_batched`` and ``ViewServer.read`` call.  It is
process-local and lock-cheap — recording is one deque append — and exports
as JSON (``export_json``) in the shape the future advisor consumes:
per-signature hit counts, hit-path mix, and latency aggregates, plus the
raw trailing records.
"""

from __future__ import annotations

import collections
import dataclasses
import json
import numbers
import os
import threading
import time
from typing import Dict, List, Optional, Tuple

from repro.core.aggregates import (Constant, Delta, Lambda, Param, Pow,
                                   Query, Var)

__all__ = ["QuerySignature", "signature_of", "agg_renders", "routable",
           "WorkloadRecord", "WorkloadRecorder"]


def _norm_const(v) -> str:
    """Canonical render of a literal constant.  Numeric values normalize
    through float so ``Delta("x", "<", 5)`` and ``Delta("x", "<", 5.0)``
    (or a numpy scalar of either) produce the same signature — the router
    must not miss the cache on spelling."""
    if isinstance(v, numbers.Real) and not isinstance(v, bool):
        return repr(float(v))
    return repr(v)


def _render_term(t) -> str:
    if isinstance(t, Var):
        return t.attr
    if isinstance(t, Pow):
        return f"{t.attr}^{t.k}"
    if isinstance(t, Constant):
        if isinstance(t.value, Param):
            return f"?{t.value.name}"
        return _norm_const(t.value)
    if isinstance(t, Delta):
        # selection factors stay *inside* the aggregate render: two queries
        # share a signature only if each aggregate carries the same filters
        # (a query-level filter pool would conflate, e.g., one filtered +
        # one unfiltered column with the same filter applied to both)
        return f"1[{_render_filter(t)}]"
    if isinstance(t, Lambda):
        return f"udaf:{t.tag or 'anon'}({','.join(t.attr_order)})"
    return repr(t.key())


def _render_filter(t: Delta) -> str:
    thr = t.threshold
    rhs = f"?{thr.name}" if isinstance(thr, Param) else _norm_const(thr)
    return f"{t.attr}{t.op}{rhs}"


@dataclasses.dataclass(frozen=True)
class QuerySignature:
    """Structural identity of a group-by aggregate query: what the serving
    router matches on and the advisor aggregates over."""

    dims: Tuple[str, ...]       # group-by attributes, sorted
    filters: Tuple[str, ...]    # rendered Delta predicates, sorted+deduped
                                # (advisor-facing rollup; matching uses the
                                # per-aggregate renders, where filters are
                                # inline factors)
    aggs: Tuple[str, ...]       # one canonical sum-of-products render per
                                # aggregate, sorted

    def key(self) -> str:
        """Stable string form (dict key / JSON field)."""
        return (f"dims[{','.join(self.dims)}]"
                f"|filters[{','.join(self.filters)}]"
                f"|aggs[{';'.join(self.aggs)}]")

    def to_dict(self) -> Dict[str, list]:
        return {"dims": list(self.dims), "filters": list(self.filters),
                "aggs": list(self.aggs)}


def _render_agg(a) -> str:
    """Canonical sum-of-products render of one aggregate.  Multiplication
    and addition commute, so term renders sort within each product and
    product renders sort within the sum — semantically identical aggregates
    written in different orders render identically."""
    prods = []
    for p in a.products:
        terms = sorted(_render_term(t) for t in p.terms)
        prods.append("*".join(terms) if terms else "1")
    return "+".join(sorted(prods))


def agg_renders(q: Query) -> Tuple[str, ...]:
    """Canonical render of each aggregate **in query order** — the router's
    column map: position i of the query's output agg axis carries the
    aggregate rendered as ``agg_renders(q)[i]``."""
    return tuple(_render_agg(a) for a in q.aggregates)


def signature_of(q: Query) -> QuerySignature:
    """Extract a query's canonical signature.  Group-by order only permutes
    output axes and aggregate order only permutes output columns, so both
    sort: two queries share a ``key()`` iff they are answerable from each
    other by an axis/column shuffle.  ``filters`` is a derived rollup of the
    ``Delta`` factors (sorted, deduped) kept for the advisor; matching
    soundness lives in the per-aggregate renders where each filter stays
    attached to its aggregate."""
    filters = set()
    for a in q.aggregates:
        for p in a.products:
            for t in p.terms:
                if isinstance(t, Delta):
                    filters.add(_render_filter(t))
    return QuerySignature(dims=tuple(sorted(q.group_by)),
                          filters=tuple(sorted(filters)),
                          aggs=tuple(sorted(agg_renders(q))))


def routable(q: Query) -> bool:
    """Whether the query's signature is a *sound* routing key.  Untagged
    ``Lambda`` UDAFs render as ``udaf:anon(...)`` — two different callables
    collide — so queries carrying one must bypass signature matching and
    the plan cache (the router answers them with a one-shot fallback
    scan)."""
    for a in q.aggregates:
        for p in a.products:
            for t in p.terms:
                if isinstance(t, Lambda) and not t.tag:
                    return False
    return True


@dataclasses.dataclass(frozen=True)
class WorkloadRecord:
    """One observed call: which view, through which path, how slow."""

    ts: float                   # wall-clock (time.time) at record time
    kind: str                   # "run" | "run_batched" | "read"
    view: str                   # registered view (query) name
    signature: QuerySignature
    hit: str                    # "full_scan" | "epoch_read" | "batch_scan"
                                # | "sharded_scan" | "pinned_read"
    latency_us: float           # host dispatch wall (no device sync)
    epoch: Optional[int] = None
    route: Optional[str] = None  # router tier for routed queries: "exact" |
                                 # "subsumed" | "compiled" | "fallback_scan";
                                 # None for direct (non-routed) calls

    def to_dict(self) -> Dict[str, object]:
        return {"ts": self.ts, "kind": self.kind, "view": self.view,
                "signature": self.signature.to_dict(), "hit": self.hit,
                "latency_us": self.latency_us, "epoch": self.epoch,
                "route": self.route}


class WorkloadRecorder:
    """Bounded ring of :class:`WorkloadRecord`; ``capacity=0`` disables
    recording entirely (every ``record`` is a cheap no-op)."""

    def __init__(self, capacity: int = 4096):
        if capacity < 0:
            raise ValueError("workload recorder capacity must be >= 0")
        self.capacity = capacity
        # maxlen=0 (disabled) keeps the ring genuinely empty — a disabled
        # recorder allocates nothing beyond this empty deque
        self._records: "collections.deque[WorkloadRecord]" = \
            collections.deque(maxlen=capacity)
        self._lock = threading.Lock()
        #: total records ever observed (including those rotated out)
        self.n_recorded = 0

    @property
    def enabled(self) -> bool:
        return self.capacity > 0

    @property
    def n_dropped(self) -> int:
        return self.n_recorded - len(self._records)

    def record(self, kind: str, view: str, signature: QuerySignature,
               hit: str, latency_us: float,
               epoch: Optional[int] = None,
               route: Optional[str] = None) -> None:
        if not self.capacity:
            return
        rec = WorkloadRecord(ts=time.time(), kind=kind, view=view,
                             signature=signature, hit=hit,
                             latency_us=latency_us, epoch=epoch,
                             route=route)
        with self._lock:
            self._records.append(rec)
            self.n_recorded += 1

    def records(self) -> List[WorkloadRecord]:
        with self._lock:
            return list(self._records)

    def clear(self) -> None:
        with self._lock:
            self._records.clear()
            self.n_recorded = 0

    # -- advisor-facing aggregation ------------------------------------------

    def by_signature(self) -> Dict[str, Dict[str, object]]:
        """Per-signature rollup: call count, hit-path mix, latency mean/max,
        and the views answering it — the advisor's ranking input."""
        out: Dict[str, Dict[str, object]] = {}
        for rec in self.records():
            key = rec.signature.key()
            e = out.get(key)
            if e is None:
                e = out[key] = {"signature": rec.signature.to_dict(),
                                "n": 0, "views": set(), "hits": {},
                                "routes": {},
                                "latency_us_sum": 0.0, "latency_us_max": 0.0}
            e["n"] += 1
            e["views"].add(rec.view)
            e["hits"][rec.hit] = e["hits"].get(rec.hit, 0) + 1
            if rec.route is not None:
                e["routes"][rec.route] = e["routes"].get(rec.route, 0) + 1
            e["latency_us_sum"] += rec.latency_us
            e["latency_us_max"] = max(e["latency_us_max"], rec.latency_us)
        for e in out.values():
            e["views"] = sorted(e["views"])
            e["latency_us_mean"] = e.pop("latency_us_sum") / e["n"]
        return out

    def to_payload(self) -> Dict[str, object]:
        return {"capacity": self.capacity, "n_recorded": self.n_recorded,
                "n_dropped": self.n_dropped,
                "signatures": self.by_signature(),
                "records": [r.to_dict() for r in self.records()]}

    def export_json(self, path: Optional[str] = None) -> Dict[str, object]:
        """The advisor input: write ``path`` if given, return the payload."""
        payload = self.to_payload()
        if path is not None:
            tmp = f"{path}.tmp.{os.getpid()}"
            with open(tmp, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, path)
        return payload
