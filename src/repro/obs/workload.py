"""Bounded in-memory workload recorder — the view advisor's input.

LMFAO's thesis is that a *batch* of aggregates shares structure; applying
it to a live workload (ROADMAP item 2: route ad-hoc queries to maintained
views, advise which wider views to materialize) first requires a record of
what the workload actually asked: which group-by signatures, through which
path (full scan, epoch read, pinned serving read), at what latency.  This
module captures exactly that.

A :class:`QuerySignature` is the *router key* of a query — its group-by
dims, its static filter predicates, and its aggregate shapes, all rendered
structurally (no callables, no array values) so signatures hash, compare,
and serialize stably across sessions.  Two queries with the same signature
are answerable by the same maintained view; a signature that keeps hitting
the fallback path is the advisor's materialization candidate.

The :class:`WorkloadRecorder` is a bounded ring (``capacity`` newest
records kept, older ones counted in ``n_dropped``) fed by every
``ViewHandle.run``/``run_batched`` and ``ViewServer.read`` call.  It is
process-local and lock-cheap — recording is one deque append — and exports
as JSON (``export_json``) in the shape the future advisor consumes:
per-signature hit counts, hit-path mix, and latency aggregates, plus the
raw trailing records.
"""

from __future__ import annotations

import collections
import dataclasses
import json
import os
import threading
import time
from typing import Dict, List, Optional, Tuple

from repro.core.aggregates import (Constant, Delta, Lambda, Param, Pow,
                                   Query, Var)

__all__ = ["QuerySignature", "signature_of", "WorkloadRecord",
           "WorkloadRecorder"]


def _render_term(t) -> str:
    if isinstance(t, Var):
        return t.attr
    if isinstance(t, Pow):
        return f"{t.attr}^{t.k}"
    if isinstance(t, Constant):
        if isinstance(t.value, Param):
            return f"?{t.value.name}"
        return repr(t.value)
    if isinstance(t, Lambda):
        return f"udaf:{t.tag or 'anon'}({','.join(t.attr_order)})"
    return repr(t.key())


def _render_filter(t: Delta) -> str:
    thr = t.threshold
    rhs = f"?{thr.name}" if isinstance(thr, Param) else repr(thr)
    return f"{t.attr}{t.op}{rhs}"


@dataclasses.dataclass(frozen=True)
class QuerySignature:
    """Structural identity of a group-by aggregate query: what the serving
    router matches on and the advisor aggregates over."""

    dims: Tuple[str, ...]       # group-by attributes, user order
    filters: Tuple[str, ...]    # rendered Delta predicates, sorted+deduped
    aggs: Tuple[str, ...]       # one rendered sum-of-products per aggregate

    def key(self) -> str:
        """Stable string form (dict key / JSON field)."""
        return (f"dims[{','.join(self.dims)}]"
                f"|filters[{','.join(self.filters)}]"
                f"|aggs[{';'.join(self.aggs)}]")

    def to_dict(self) -> Dict[str, list]:
        return {"dims": list(self.dims), "filters": list(self.filters),
                "aggs": list(self.aggs)}


def signature_of(q: Query) -> QuerySignature:
    """Extract a query's signature.  ``Delta`` terms are classified as
    filters (they restrict rows); everything else renders into the
    aggregate's sum-of-products shape."""
    filters = set()
    aggs = []
    for a in q.aggregates:
        prods = []
        for p in a.products:
            terms = []
            for t in p.terms:
                if isinstance(t, Delta):
                    filters.add(_render_filter(t))
                else:
                    terms.append(_render_term(t))
            prods.append("*".join(terms) if terms else "1")
        aggs.append("+".join(prods))
    return QuerySignature(dims=tuple(q.group_by),
                          filters=tuple(sorted(filters)),
                          aggs=tuple(aggs))


@dataclasses.dataclass(frozen=True)
class WorkloadRecord:
    """One observed call: which view, through which path, how slow."""

    ts: float                   # wall-clock (time.time) at record time
    kind: str                   # "run" | "run_batched" | "read"
    view: str                   # registered view (query) name
    signature: QuerySignature
    hit: str                    # "full_scan" | "epoch_read" | "batch_scan"
                                # | "sharded_scan" | "pinned_read"
    latency_us: float           # host dispatch wall (no device sync)
    epoch: Optional[int] = None

    def to_dict(self) -> Dict[str, object]:
        return {"ts": self.ts, "kind": self.kind, "view": self.view,
                "signature": self.signature.to_dict(), "hit": self.hit,
                "latency_us": self.latency_us, "epoch": self.epoch}


class WorkloadRecorder:
    """Bounded ring of :class:`WorkloadRecord`; ``capacity=0`` disables
    recording entirely (every ``record`` is a cheap no-op)."""

    def __init__(self, capacity: int = 4096):
        if capacity < 0:
            raise ValueError("workload recorder capacity must be >= 0")
        self.capacity = capacity
        # maxlen=0 (disabled) keeps the ring genuinely empty — a disabled
        # recorder allocates nothing beyond this empty deque
        self._records: "collections.deque[WorkloadRecord]" = \
            collections.deque(maxlen=capacity)
        self._lock = threading.Lock()
        #: total records ever observed (including those rotated out)
        self.n_recorded = 0

    @property
    def enabled(self) -> bool:
        return self.capacity > 0

    @property
    def n_dropped(self) -> int:
        return self.n_recorded - len(self._records)

    def record(self, kind: str, view: str, signature: QuerySignature,
               hit: str, latency_us: float,
               epoch: Optional[int] = None) -> None:
        if not self.capacity:
            return
        rec = WorkloadRecord(ts=time.time(), kind=kind, view=view,
                             signature=signature, hit=hit,
                             latency_us=latency_us, epoch=epoch)
        with self._lock:
            self._records.append(rec)
            self.n_recorded += 1

    def records(self) -> List[WorkloadRecord]:
        with self._lock:
            return list(self._records)

    def clear(self) -> None:
        with self._lock:
            self._records.clear()
            self.n_recorded = 0

    # -- advisor-facing aggregation ------------------------------------------

    def by_signature(self) -> Dict[str, Dict[str, object]]:
        """Per-signature rollup: call count, hit-path mix, latency mean/max,
        and the views answering it — the advisor's ranking input."""
        out: Dict[str, Dict[str, object]] = {}
        for rec in self.records():
            key = rec.signature.key()
            e = out.get(key)
            if e is None:
                e = out[key] = {"signature": rec.signature.to_dict(),
                                "n": 0, "views": set(), "hits": {},
                                "latency_us_sum": 0.0, "latency_us_max": 0.0}
            e["n"] += 1
            e["views"].add(rec.view)
            e["hits"][rec.hit] = e["hits"].get(rec.hit, 0) + 1
            e["latency_us_sum"] += rec.latency_us
            e["latency_us_max"] = max(e["latency_us_max"], rec.latency_us)
        for e in out.values():
            e["views"] = sorted(e["views"])
            e["latency_us_mean"] = e.pop("latency_us_sum") / e["n"]
        return out

    def to_payload(self) -> Dict[str, object]:
        return {"capacity": self.capacity, "n_recorded": self.n_recorded,
                "n_dropped": self.n_dropped,
                "signatures": self.by_signature(),
                "records": [r.to_dict() for r in self.records()]}

    def export_json(self, path: Optional[str] = None) -> Dict[str, object]:
        """The advisor input: write ``path`` if given, return the payload."""
        payload = self.to_payload()
        if path is not None:
            tmp = f"{path}.tmp.{os.getpid()}"
            with open(tmp, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, path)
        return payload
