"""Nestable timed spans with a chrome://tracing exporter.

The engine asserts hard *runtime* contracts — zero retraces, zero host
transfers, epoch-consistent serving — but had no way to *see* where a
compile, a maintenance tick, or a served read spends its time.  This module
is the always-compiled-in tracing layer (DESIGN.md §11): code wraps its
phases in ``with span("ivm.apply"):`` and, when tracing is enabled, every
span becomes one complete ("ph": "X") event in a chrome://tracing JSON
(load via chrome://tracing or https://ui.perfetto.dev).

Two properties are load-bearing:

* **Off-by-default cheap.**  ``span()`` with tracing disabled returns a
  shared no-op context manager after one module-global check — no object
  allocation, no clock read, no lock.  Instrumented hot paths (the
  steady-state IVM tick, the serving read) stay within noise when tracing
  is off, which is why the instrumentation can live in the engine
  permanently instead of behind a build flag.

* **No device syncs.**  A span timer reads ``time.perf_counter`` at enter
  and exit — it never calls ``block_until_ready`` or otherwise forces the
  device to drain.  Around asynchronously-dispatched jitted calls a span
  therefore measures *host dispatch* time (trace time on a cache miss);
  the caller's own sync points (e.g. a benchmark blocking on results) are
  the only places device latency becomes visible.  This is what keeps the
  transfer-guard / zero-retrace steady-state contracts intact with
  telemetry enabled — the headline test of the subsystem.

Spans nest naturally: chrome's complete events reconstruct the hierarchy
from time containment per thread, so no explicit parent bookkeeping is
needed.  The event buffer is bounded (``max_events``); once full, new spans
are counted in ``n_dropped`` instead of growing without limit under
sustained load.

    from repro.obs import trace
    trace.enable()
    ... run a workload ...
    trace.export_chrome("trace.json")
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Dict, List, Optional

__all__ = ["span", "enable", "disable", "enabled", "Tracer", "get_tracer",
           "export_chrome", "clear"]

#: hard cap on buffered events — sustained-load runs must not leak memory
DEFAULT_MAX_EVENTS = 200_000


class _NullSpan:
    """The disabled-tracing fast path: a shared, stateless no-op."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL = _NullSpan()


class _Span:
    """One live span; appends its complete event to the tracer on exit."""

    __slots__ = ("_tracer", "name", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args: Optional[dict]):
        self._tracer = tracer
        self.name = name
        self.args = args

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        self._tracer._record(self.name, self._t0, t1 - self._t0, self.args)
        return False


class Tracer:
    """Bounded, thread-safe buffer of completed span events."""

    def __init__(self, max_events: int = DEFAULT_MAX_EVENTS):
        self.max_events = max_events
        self._events: List[dict] = []
        self._lock = threading.Lock()
        #: spans dropped because the buffer was full
        self.n_dropped = 0
        # one epoch per tracer so chrome timestamps start near zero
        self._epoch = time.perf_counter()

    def span(self, name: str, **args) -> _Span:
        return _Span(self, name, args or None)

    def _record(self, name: str, t0: float, dur: float,
                args: Optional[dict]) -> None:
        ev = {"name": name, "ph": "X", "cat": name.split(".", 1)[0],
              "ts": (t0 - self._epoch) * 1e6, "dur": dur * 1e6,
              "pid": os.getpid(), "tid": threading.get_ident()}
        if args:
            ev["args"] = args
        with self._lock:
            if len(self._events) >= self.max_events:
                self.n_dropped += 1
                return
            self._events.append(ev)

    def events(self) -> List[dict]:
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.n_dropped = 0

    def chrome_payload(self) -> Dict[str, object]:
        """The chrome://tracing JSON object for the buffered events."""
        return {"traceEvents": self.events(), "displayTimeUnit": "ms",
                "otherData": {"n_dropped": self.n_dropped}}

    def export_chrome(self, path: Optional[str] = None):
        """Serialize to chrome://tracing JSON; write ``path`` if given,
        return the payload either way."""
        payload = self.chrome_payload()
        if path is not None:
            tmp = f"{path}.tmp.{os.getpid()}"
            with open(tmp, "w") as f:
                json.dump(payload, f)
            os.replace(tmp, path)
        return payload


# -- module-level default tracer (what the engine's span() calls hit) --------

_enabled = False
_tracer = Tracer()


def get_tracer() -> Tracer:
    return _tracer


def enable(flag: bool = True) -> None:
    """Turn span recording on (or off with ``enable(False)``)."""
    global _enabled
    _enabled = bool(flag)


def disable() -> None:
    enable(False)


def enabled() -> bool:
    return _enabled


def span(name: str, **args):
    """``with span("ivm.apply", rel="R2"):`` — time a phase.  Returns a
    shared no-op when tracing is disabled (the off-by-default fast path)."""
    if not _enabled:
        return _NULL
    return _tracer.span(name, **args)


def clear() -> None:
    _tracer.clear()


def export_chrome(path: Optional[str] = None):
    return _tracer.export_chrome(path)


if os.environ.get("REPRO_TRACE"):        # opt-in via environment
    enable(True)
