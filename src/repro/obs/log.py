"""Structured, rate-limited logging for the serving layer.

A long-lived server must be able to say "pinned readers are N epochs
behind head" without flooding stderr once per read.  This wraps the stdlib
``logging`` module (handlers/levels stay user-configurable the normal way)
with two additions: structured key=value rendering, and per-key rate
limiting so a condition that holds across thousands of requests emits one
line per interval.
"""

from __future__ import annotations

import logging
import threading
import time
from typing import Dict

__all__ = ["StructuredLogger", "get_logger"]


class StructuredLogger:
    """``log.warning("epoch lag", lag=7, epoch=42)`` →
    ``epoch lag lag=7 epoch=42`` through a stdlib logger.

    ``*_every`` variants emit at most once per ``interval_s`` per ``key``
    (monotonic clock) and return whether they emitted — callers can count
    suppressions."""

    def __init__(self, logger: logging.Logger):
        self._log = logger
        self._last: Dict[str, float] = {}
        self._lock = threading.Lock()

    @staticmethod
    def _fmt(msg: str, fields: dict) -> str:
        if not fields:
            return msg
        kv = " ".join(f"{k}={v}" for k, v in fields.items())
        return f"{msg} {kv}"

    def info(self, msg: str, **fields) -> None:
        self._log.info(self._fmt(msg, fields))

    def warning(self, msg: str, **fields) -> None:
        self._log.warning(self._fmt(msg, fields))

    def warning_every(self, interval_s: float, key: str, msg: str,
                      **fields) -> bool:
        """Rate-limited warning; returns True iff a line was emitted."""
        now = time.monotonic()
        with self._lock:
            last = self._last.get(key)
            if last is not None and now - last < interval_s:
                return False
            self._last[key] = now
        self.warning(msg, **fields)
        return True


def get_logger(name: str = "repro") -> StructuredLogger:
    return StructuredLogger(logging.getLogger(name))
