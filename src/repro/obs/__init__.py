"""Engine-wide observability: tracing spans, metrics, workload recording.

The runtime counterpart to the engine's hard contracts (DESIGN.md §11):

* ``obs.trace``    — nestable timed spans + chrome://tracing export
  (off by default; ``obs.enable_tracing()`` or ``REPRO_TRACE=1``);
* ``obs.metrics``  — process-local counters / gauges / fixed-bucket
  histograms (p50/p95/p99 without stored samples);
* ``obs.workload`` — bounded recorder of every run/read call's query
  signature, hit path, and latency (the future view advisor's input);
* ``obs.log``      — structured, rate-limited logging.

Design rule shared by all four: **never sync the device**.  Telemetry
reads host clocks around dispatch sites only, so the steady-state
zero-transfer / zero-retrace contracts hold with everything enabled.
"""

from repro.obs.log import StructuredLogger, get_logger
from repro.obs.metrics import (Counter, Gauge, Histogram, Registry,
                               LATENCY_BUCKETS_US)
from repro.obs.trace import (Tracer, enabled as tracing_enabled,
                             export_chrome, get_tracer, span)
from repro.obs.trace import enable as enable_tracing
from repro.obs.trace import disable as disable_tracing
from repro.obs.trace import clear as clear_trace
from repro.obs.workload import (QuerySignature, WorkloadRecord,
                                WorkloadRecorder, agg_renders, routable,
                                signature_of)

__all__ = [
    "span", "enable_tracing", "disable_tracing", "tracing_enabled",
    "get_tracer", "export_chrome", "clear_trace", "Tracer",
    "Counter", "Gauge", "Histogram", "Registry", "LATENCY_BUCKETS_US",
    "QuerySignature", "WorkloadRecord", "WorkloadRecorder", "signature_of",
    "agg_renders", "routable",
    "StructuredLogger", "get_logger",
]
