"""Model assembly for all assigned architecture families.

Every family exposes the same four entry points used by the launcher:

  model_specs(cfg)                  -> ParamSpec tree (init/sharding/dry-run)
  forward(params, batch, cfg, impl) -> (logits, aux dict)       [train/prefill]
  cache_specs(cfg, batch, max_len)  -> ParamSpec tree for the decode cache
  decode_step(params, cache, tokens, pos, cfg, context) -> (logits, new cache)

Homogeneous layer stacks are scanned (``lax.scan`` over stacked params) with
per-layer remat — compile time stays flat in depth (100-layer archs lower in
seconds, not minutes).  Heterogeneous patterns (vision cross-attn every 5th
layer, zamba2's shared attention block every 6th) become scans over
*super-blocks*.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import mamba2 as m2
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models.config import ModelConfig
from repro.models.layers import (ParamSpec, attn_apply, attn_specs,
                                 embed_apply, embed_specs, logits_apply,
                                 mlp_apply, mlp_specs, p_, rms_norm)


# --------------------------------------------------------------------------
# Spec helpers
# --------------------------------------------------------------------------


def stack_specs(specs, n: int):
    """Add a leading stacked-layers dim to every ParamSpec in the tree."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def _norm(d):
    return p_((d,), ("embed",), init="ones")


def dense_block_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    attn = mla_mod.mla_specs(cfg) if cfg.kv_lora else attn_specs(cfg)
    return {"ln1": _norm(d), "attn": attn, "ln2": _norm(d),
            "mlp": mlp_specs(d, cfg.d_ff)}


def moe_block_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    attn = mla_mod.mla_specs(cfg) if cfg.kv_lora else attn_specs(cfg)
    return {"ln1": _norm(d), "attn": attn, "ln2": _norm(d),
            "moe": moe_mod.moe_specs(cfg)}


def ssm_block_specs(cfg: ModelConfig) -> Dict[str, Any]:
    return {"ln1": _norm(cfg.d_model), "mamba": m2.mamba_specs(cfg)}


def cross_block_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    return {"ln1": _norm(d), "attn": attn_specs(cfg), "ln2": _norm(d),
            "mlp": mlp_specs(d, cfg.d_ff)}


def model_specs(cfg: ModelConfig) -> Dict[str, Any]:
    s: Dict[str, Any] = dict(embed_specs(cfg))
    fam = cfg.family
    if fam in ("dense",):
        s["layers"] = stack_specs(dense_block_specs(cfg), cfg.n_layers)
    elif fam == "moe":
        s["layers"] = stack_specs(moe_block_specs(cfg), cfg.n_layers)
    elif fam == "ssm":
        s["layers"] = stack_specs(ssm_block_specs(cfg), cfg.n_layers)
    elif fam == "hybrid":
        g, r = divmod(cfg.n_layers, cfg.attn_every)
        s["groups"] = stack_specs(stack_specs(ssm_block_specs(cfg), cfg.attn_every), g)
        if r:
            s["tail"] = stack_specs(ssm_block_specs(cfg), r)
        s["shared_attn"] = dense_block_specs(cfg)    # ONE shared block, reused
    elif fam == "vlm":
        assert cfg.n_layers % cfg.cross_every == 0
        n_super = cfg.n_layers // cfg.cross_every
        n_self = cfg.cross_every - 1
        s["super"] = {
            "self": stack_specs(stack_specs(dense_block_specs(cfg), n_self), n_super),
            "cross": stack_specs(cross_block_specs(cfg), n_super),
        }
    elif fam == "audio":
        s["enc_pos"] = p_((cfg.encoder_frames, cfg.d_model), (None, "embed"))
        s["encoder"] = stack_specs(dense_block_specs(cfg), cfg.encoder_layers)
        s["enc_norm"] = _norm(cfg.d_model)
        dec = {"ln1": _norm(cfg.d_model), "self": attn_specs(cfg),
               "ln2": _norm(cfg.d_model), "cross": attn_specs(cfg),
               "ln3": _norm(cfg.d_model), "mlp": mlp_specs(cfg.d_model, cfg.d_ff)}
        s["decoder"] = stack_specs(dec, cfg.n_layers)
    else:
        raise ValueError(f"unknown family {fam!r}")
    return s


# --------------------------------------------------------------------------
# Blocks (apply)
# --------------------------------------------------------------------------


def _apply_attn(p, x, cfg, *, positions, impl, cache=None, decode_pos=None,
                cross_kv=None, causal=True):
    if cfg.kv_lora and cross_kv is None:
        return mla_mod.mla_apply(p, x, cfg, positions=positions, impl=impl,
                                 cache=cache, decode_pos=decode_pos)
    return attn_apply(p, x, cfg, positions=positions, impl=impl, causal=causal,
                      cross_kv=cross_kv, cache=cache, decode_pos=decode_pos)


def dense_block(p, x, cfg, *, positions, impl, cache=None, decode_pos=None,
                causal=True):
    h, nc = _apply_attn(p["attn"], rms_norm(x, p["ln1"]), cfg,
                        positions=positions, impl=impl, cache=cache,
                        decode_pos=decode_pos, causal=causal)
    x = x + h
    x = x + mlp_apply(p["mlp"], rms_norm(x, p["ln2"]))
    return constrain(x, "batch", "seq", None), nc


def moe_block(p, x, cfg, *, positions, impl, cache=None, decode_pos=None):
    h, nc = _apply_attn(p["attn"], rms_norm(x, p["ln1"]), cfg,
                        positions=positions, impl=impl, cache=cache,
                        decode_pos=decode_pos)
    x = x + h
    h, aux = moe_mod.moe_apply(p["moe"], rms_norm(x, p["ln2"]), cfg)
    x = x + h
    return constrain(x, "batch", "seq", None), nc, aux


def ssm_block(p, x, cfg, *, state=None):
    h, ns = m2.mamba_apply(p["mamba"], rms_norm(x, p["ln1"]), cfg, state=state)
    return constrain(x + h, "batch", "seq", None), ns


def cross_block(p, x, cfg, *, context, impl):
    kv = {"k": jnp.einsum("btd,dhk->bthk", context, p["attn"]["wk"]),
          "v": jnp.einsum("btd,dhk->bthk", context, p["attn"]["wv"])}
    h, _ = attn_apply(p["attn"], rms_norm(x, p["ln1"]), cfg, positions=None,
                      impl=impl, causal=False, cross_kv=(kv["k"], kv["v"]))
    x = x + h
    x = x + mlp_apply(p["mlp"], rms_norm(x, p["ln2"]))
    return constrain(x, "batch", "seq", None)



def _scan(cfg: ModelConfig, f, init, xs):
    """lax.scan that fully unrolls in roofline-measurement mode (see
    ModelConfig.scan_unroll): XLA cost analysis counts while-loop bodies
    once, so measurement builds unroll to get true per-step costs."""
    length = jax.tree.leaves(xs)[0].shape[0]
    return jax.lax.scan(f, init, xs, unroll=length if cfg.scan_unroll else 1)


def _maybe_remat(fn, cfg: ModelConfig):
    return jax.checkpoint(fn) if cfg.remat else fn


# --------------------------------------------------------------------------
# Forward (training / prefill)
# --------------------------------------------------------------------------


def forward(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            impl: str = "dense") -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    x, aux = forward_hidden(params, batch, cfg, impl)
    logits = logits_apply(params, x)
    logits = constrain(logits, "batch", None, "vocab")
    return logits, aux


def forward_hidden(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
                   impl: str = "dense") -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Backbone forward up to the final norm (the LM head is applied in
    sequence chunks by the trainer so (B, S, vocab) logits never fully
    materialize — vocab=152k at S=4k would be tens of GB per device)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    x = embed_apply(params, tokens).astype(cfg.jdtype)
    x = constrain(x, "batch", "seq", None)
    aux_total = jnp.zeros((), jnp.float32)
    fam = cfg.family

    if fam == "dense":
        def body(x, pl):
            y, _ = dense_block(pl, x, cfg, positions=positions, impl=impl)
            return y, None
        x, _ = _scan(cfg, _maybe_remat(body, cfg), x, params["layers"])
    elif fam == "moe":
        def body(carry, pl):
            x, aux = carry
            y, _, a = moe_block(pl, x, cfg, positions=positions, impl=impl)
            return (y, aux + a), None
        (x, aux_total), _ = _scan(cfg, _maybe_remat(body, cfg), (x, aux_total), params["layers"])
    elif fam == "ssm":
        def body(x, pl):
            y, _ = ssm_block(pl, x, cfg)
            return y, None
        x, _ = _scan(cfg, _maybe_remat(body, cfg), x, params["layers"])
    elif fam == "hybrid":
        shared = params["shared_attn"]

        def inner(x, pl):
            y, _ = ssm_block(pl, x, cfg)
            return y, None

        def group(x, gl):
            x, _ = _scan(cfg, _maybe_remat(inner, cfg), x, gl)
            x, _ = dense_block(shared, x, cfg, positions=positions, impl=impl)
            return x, None

        x, _ = _scan(cfg, group, x, params["groups"])
        if "tail" in params:
            x, _ = _scan(cfg, _maybe_remat(inner, cfg), x, params["tail"])
    elif fam == "vlm":
        vision = batch["vision"].astype(cfg.jdtype)

        def self_body(x, pl):
            y, _ = dense_block(pl, x, cfg, positions=positions, impl=impl)
            return y, None

        def super_body(x, pl):
            x, _ = _scan(cfg, _maybe_remat(self_body, cfg), x, pl["self"])
            x = cross_block(pl["cross"], x, cfg, context=vision, impl=impl)
            return x, None

        x, _ = _scan(cfg, super_body, x, params["super"])
    elif fam == "audio":
        enc = _encode_audio(params, batch["frames"].astype(cfg.jdtype), cfg, impl)

        def dec_body(x, pl):
            h, _ = attn_apply(pl["self"], rms_norm(x, pl["ln1"]), cfg,
                              positions=positions, impl=impl, causal=True)
            x = x + h
            kv = (jnp.einsum("btd,dhk->bthk", enc, pl["cross"]["wk"]),
                  jnp.einsum("btd,dhk->bthk", enc, pl["cross"]["wv"]))
            h, _ = attn_apply(pl["cross"], rms_norm(x, pl["ln2"]), cfg,
                              positions=None, impl=impl, causal=False,
                              cross_kv=kv)
            x = x + h
            x = x + mlp_apply(pl["mlp"], rms_norm(x, pl["ln3"]))
            return constrain(x, "batch", "seq", None), None

        x, _ = _scan(cfg, _maybe_remat(dec_body, cfg), x, params["decoder"])
    else:
        raise ValueError(fam)

    x = rms_norm(x, params["final_norm"])
    return x, {"aux_loss": aux_total / max(cfg.n_layers, 1)}


def _encode_audio(params, frames, cfg: ModelConfig, impl: str):
    x = frames + params["enc_pos"][None, :frames.shape[1]].astype(frames.dtype)
    positions = jnp.arange(frames.shape[1], dtype=jnp.int32)

    def body(x, pl):
        y, _ = dense_block(pl, x, cfg, positions=positions, impl=impl,
                           causal=False)
        return y, None

    x, _ = _scan(cfg, _maybe_remat(body, cfg), x, params["encoder"])
    return rms_norm(x, params["enc_norm"])


# --------------------------------------------------------------------------
# Decode caches + serve step
# --------------------------------------------------------------------------


def _kv_cache_specs(cfg: ModelConfig, n: int, batch: int, max_len: int):
    if cfg.kv_lora:
        return {"c": p_((n, batch, max_len, cfg.kv_lora),
                        ("layers", "cache_batch", "cache_seq", None), init="zeros"),
                "kr": p_((n, batch, max_len, cfg.rope_dim),
                         ("layers", "cache_batch", "cache_seq", None), init="zeros")}
    # sliding-window archs only need a window-sized cache (ring addressing is
    # a serve-time optimization; here the dry-run allocates the window)
    t = min(max_len, cfg.window) if cfg.window else max_len
    return {"k": p_((n, batch, t, cfg.n_kv, cfg.hd),
                    ("layers", "cache_batch", "cache_seq", "kv", None), init="zeros"),
            "v": p_((n, batch, t, cfg.n_kv, cfg.hd),
                    ("layers", "cache_batch", "cache_seq", "kv", None), init="zeros")}


def _ssm_state_specs(cfg: ModelConfig, lead: Tuple[int, ...], batch: int):
    nh, n, hp = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    din = cfg.expand * cfg.d_model
    laxes = ("layers",) * len(lead)
    return {"h": ParamSpec(lead + (batch, nh, n, hp),
                           laxes + ("cache_batch", "heads", None, None), "zeros", 0.0),
            "conv": ParamSpec(lead + (batch, 3, din + 2 * n),
                              laxes + ("cache_batch", None, None), "zeros", 0.0)}


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    fam = cfg.family
    if fam in ("dense", "moe"):
        return {"kv": _kv_cache_specs(cfg, cfg.n_layers, batch, max_len)}
    if fam == "ssm":
        return {"ssm": _ssm_state_specs(cfg, (cfg.n_layers,), batch)}
    if fam == "hybrid":
        g, r = divmod(cfg.n_layers, cfg.attn_every)
        out = {"groups": _ssm_state_specs(cfg, (g, cfg.attn_every), batch),
               "shared_kv": _kv_cache_specs(cfg, g, batch, max_len)}
        if r:
            out["tail"] = _ssm_state_specs(cfg, (r,), batch)
        return out
    if fam == "vlm":
        n_super = cfg.n_layers // cfg.cross_every
        return {"kv": _kv_cache_specs(cfg, n_super * (cfg.cross_every - 1),
                                      batch, max_len)}
    if fam == "audio":
        return {"kv": _kv_cache_specs(cfg, cfg.n_layers, batch, max_len)}
    raise ValueError(fam)


def encode_context(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
                   impl: str = "dense") -> Optional[jnp.ndarray]:
    """The static per-request context consumed by decode_step: the audio
    encoder output for enc-dec archs (run once per request, not per token),
    or the vision embeddings as-is for vlm."""
    if cfg.family == "audio":
        return _encode_audio(params, batch["frames"].astype(cfg.jdtype), cfg, impl)
    if cfg.family == "vlm":
        return batch["vision"].astype(cfg.jdtype)
    return None


def decode_step(params, cache, tokens, pos, cfg: ModelConfig,
                context: Optional[jnp.ndarray] = None):
    """One-token decode. tokens: (B, 1); pos: scalar int32 (cache fill level).
    context: vision embeds / encoder output for vlm/audio."""
    b = tokens.shape[0]
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    x = embed_apply(params, tokens).astype(cfg.jdtype)
    fam = cfg.family

    if fam in ("dense", "moe"):
        def body(x, inp):
            pl, cl = inp
            if fam == "moe":
                y, nc, _ = moe_block(pl, x, cfg, positions=positions,
                                     impl="dense", cache=cl, decode_pos=pos)
            else:
                y, nc = dense_block(pl, x, cfg, positions=positions,
                                    impl="dense", cache=cl, decode_pos=pos)
            return y, nc
        x, new_kv = _scan(cfg, body, x, (params["layers"], cache["kv"]))
        new_cache = {"kv": new_kv}
    elif fam == "ssm":
        def body(x, inp):
            pl, st = inp
            y, ns = ssm_block(pl, x, cfg, state=st)
            return y, ns
        x, new_ssm = _scan(cfg, body, x, (params["layers"], cache["ssm"]))
        new_cache = {"ssm": new_ssm}
    elif fam == "hybrid":
        shared = params["shared_attn"]

        def inner(x, inp):
            pl, st = inp
            y, ns = ssm_block(pl, x, cfg, state=st)
            return y, ns

        def group(x, inp):
            gl, gst, kvl = inp
            x, ns = _scan(cfg, inner, x, (gl, gst))
            x, nkv = dense_block(shared, x, cfg, positions=positions,
                                 impl="dense", cache=kvl, decode_pos=pos)
            return x, (ns, nkv)

        x, (new_g, new_kv) = jax.lax.scan(
            group, x, (params["groups"], cache["groups"], cache["shared_kv"]))
        new_cache = {"groups": new_g, "shared_kv": new_kv}
        if "tail" in params:
            x, new_tail = _scan(cfg, inner, x, (params["tail"], cache["tail"]))
            new_cache["tail"] = new_tail
    elif fam == "vlm":
        vision = context.astype(cfg.jdtype)

        def self_body(x, inp):
            pl, cl = inp
            y, nc = dense_block(pl, x, cfg, positions=positions, impl="dense",
                                cache=cl, decode_pos=pos)
            return y, nc

        n_super = cfg.n_layers // cfg.cross_every
        n_self = cfg.cross_every - 1
        kv = jax.tree.map(
            lambda a: a.reshape((n_super, n_self) + a.shape[1:]), cache["kv"])

        def super_body(x, inp):
            pl, kvg = inp
            x, nkv = _scan(cfg, self_body, x, (pl["self"], kvg))
            x = cross_block(pl["cross"], x, cfg, context=vision, impl="dense")
            return x, nkv

        x, new_kv = _scan(cfg, super_body, x, (params["super"], kv))
        new_cache = {"kv": jax.tree.map(
            lambda a: a.reshape((n_super * n_self,) + a.shape[2:]), new_kv)}
    elif fam == "audio":
        enc = context.astype(cfg.jdtype)

        def body(x, inp):
            pl, cl = inp
            h, nc = attn_apply(pl["self"], rms_norm(x, pl["ln1"]), cfg,
                               positions=positions, impl="dense",
                               cache=cl, decode_pos=pos)
            x = x + h
            kv = (jnp.einsum("btd,dhk->bthk", enc, pl["cross"]["wk"]),
                  jnp.einsum("btd,dhk->bthk", enc, pl["cross"]["wv"]))
            h, _ = attn_apply(pl["cross"], rms_norm(x, pl["ln2"]), cfg,
                              positions=None, impl="dense", causal=False,
                              cross_kv=kv)
            x = x + h
            x = x + mlp_apply(pl["mlp"], rms_norm(x, pl["ln3"]))
            return x, nc

        x, new_kv = _scan(cfg, body, x, (params["decoder"], cache["kv"]))
        new_cache = {"kv": new_kv}
    else:
        raise ValueError(fam)

    x = rms_norm(x, params["final_norm"])
    logits = logits_apply(params, x)
    return constrain(logits, "batch", None, "vocab"), new_cache
