"""Core model layers as pure functions over parameter pytrees.

Parameters are declared as :class:`ParamSpec` trees with *logical axis names*
(``embed``, ``heads``, ``ffn``, ``vocab``, ``experts``, ...).  The distributed
layer maps logical axes to mesh axes (FSDP over ``data``, tensor-parallel over
``model``, pure DP over ``pod``) — see ``repro/distributed/sharding.py``.

Attention offers three implementations:
  * ``dense``   — full softmax (small shapes / smoke tests)
  * ``chunked`` — lax.scan over query chunks with a rematerialized chunk body;
                  O(S·chunk) live memory, the XLA analogue of the Pallas flash
                  kernel, used by dry-run prefill at 32k
  * ``pallas``  — the kernels/flash_attention.py blockwise kernel (TPU target;
                  interpret=True for CPU validation)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

# --------------------------------------------------------------------------
# Parameter specs
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]      # logical axis names (len == ndim)
    init: str = "normal"                 # normal | zeros | ones
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def p_(shape, axes, init="normal", scale=0.02) -> ParamSpec:
    return ParamSpec(tuple(shape), tuple(axes), init, scale)


def init_params(specs, key, dtype) -> Any:
    leaves, treedef = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    out = []
    for spec, k in zip(leaves, keys):
        if spec.init == "zeros":
            out.append(jnp.zeros(spec.shape, dtype))
        elif spec.init == "ones":
            out.append(jnp.ones(spec.shape, dtype))
        else:
            out.append((jax.random.normal(k, spec.shape) * spec.scale).astype(dtype))
    return jax.tree.unflatten(treedef, out)


def abstract_params(specs, dtype) -> Any:
    """ShapeDtypeStruct tree (dry-run: no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


# --------------------------------------------------------------------------
# Normalization / embeddings / rope
# --------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, n, d) rotary over the last dim; positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) * (np.log(theta) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# Attention cores
# --------------------------------------------------------------------------


def _dense_attention(q, k, v, *, causal: bool, window: int,
                     q_offset: int = 0, kv_len: Optional[jnp.ndarray] = None):
    """q: (B,S,H,hd), k/v: (B,T,Kv,hd). Returns (B,S,H,hd)."""
    b, s, h, hd = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores = scores / np.sqrt(hd)
    rows = q_offset + jnp.arange(s)[:, None]
    cols = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), dtype=bool)
    if causal:
        mask &= cols <= rows
    if window > 0:
        mask &= cols > rows - window
    if kv_len is not None:
        mask &= cols < kv_len
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgst,btkd->bskgd", p, v)
    return o.reshape(b, s, h, v.shape[-1])   # v head dim may differ (MLA)


def _chunked_attention(q, k, v, *, causal: bool, window: int, chunk: int = 512,
                       unroll: bool = False):
    """Memory-efficient attention: scan over query chunks; the chunk body is
    rematerialized so the backward pass never holds all (S/chunk) score
    blocks at once.  ``unroll`` is the roofline-measurement mode (XLA counts
    loop bodies once)."""
    b, s, h, hd = q.shape
    chunk = min(chunk, s)
    if s % chunk != 0:
        pad = chunk - s % chunk
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = q.shape[1] // chunk
    qc = q.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)

    @jax.checkpoint
    def body(qi, i):
        return _dense_attention(qi, k, v, causal=causal, window=window,
                                q_offset=i * chunk,
                                kv_len=jnp.asarray(s))

    def step(_, xs):
        qi, i = xs
        return None, body(qi, i)

    _, oc = jax.lax.scan(step, None, (qc, jnp.arange(n_chunks)),
                         unroll=n_chunks if unroll else 1)
    d_out = oc.shape[-1]                 # v head dim (differs from q's for MLA)
    o = oc.transpose(1, 0, 2, 3, 4).reshape(b, q.shape[1], h, d_out)
    return o[:, :s]


def attention_core(q, k, v, *, causal: bool = True, window: int = 0,
                   impl: str = "dense", chunk: int = 512,
                   unroll: bool = False, interpret: bool = True):
    if impl == "dense":
        return _dense_attention(q, k, v, causal=causal, window=window)
    if impl == "chunked":
        return _chunked_attention(q, k, v, causal=causal, window=window,
                                  chunk=chunk, unroll=unroll)
    if impl == "pallas":
        from repro.kernels import ops as kops
        qt = q.transpose(0, 2, 1, 3)
        kt = k.transpose(0, 2, 1, 3)
        vt = v.transpose(0, 2, 1, 3)
        o = kops.flash_attention(qt, kt, vt, causal=causal, window=window,
                                 block_q=min(128, q.shape[1]),
                                 block_k=min(128, k.shape[1]),
                                 interpret=interpret)
        return o.transpose(0, 2, 1, 3)
    raise ValueError(f"unknown attention impl {impl!r}")


def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0):
    """Single-token decode: q (B,1,H,hd); caches (B,T,Kv,hd); ``pos`` (scalar)
    is the number of valid cache entries.  Softmax masks the cache tail (and
    the sliding window); with the cache length dim sharded over ``model``,
    GSPMD lowers the reductions to psums — context-parallel decode."""
    b, _, h, hd = q.shape
    t, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    qg = q.reshape(b, kv, g, hd)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache).astype(jnp.float32)
    scores = scores / np.sqrt(hd)
    cols = jnp.arange(t)
    mask = cols < pos
    if window > 0:
        mask &= cols > pos - 1 - window
    scores = jnp.where(mask[None, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgt,btkd->bkgd", p, v_cache)
    return o.reshape(b, 1, h, hd)


# --------------------------------------------------------------------------
# Standard GQA attention block (params + apply)
# --------------------------------------------------------------------------


def attn_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    return {
        "wq": p_((d, h, hd), ("embed", "heads", None)),
        "wk": p_((d, kv, hd), ("embed", "kv", None)),
        "wv": p_((d, kv, hd), ("embed", "kv", None)),
        "wo": p_((h, hd, d), ("heads", None, "embed")),
    }


def attn_apply(p, x, cfg: ModelConfig, *, positions, impl="dense",
               causal=True, cross_kv=None, cache=None, decode_pos=None):
    """Returns (out, new_cache).  ``cache``: dict(k=(B,T,Kv,hd), v=...)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cross_kv is not None:
        k, v = cross_kv
    else:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    new_cache = None
    if cache is not None and cross_kv is None:
        t = cache["k"].shape[1]
        if cfg.window and t == cfg.window:
            # ring buffer: O(window) cache; keys carry their absolute rope
            # phase, so attention over the ring needs no reordering
            widx = jnp.mod(decode_pos, t)
            valid = jnp.minimum(decode_pos + 1, t)
            ring_window = 0          # ring already holds only the window
        else:
            widx = decode_pos
            valid = decode_pos + 1
            ring_window = cfg.window
        k_cache = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                               (0, widx, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                               (0, widx, 0, 0))
        new_cache = {"k": k_cache, "v": v_cache}
        o = decode_attention(q, k_cache, v_cache, valid, window=ring_window)
    elif cache is not None:  # cross-attention during decode: static kv
        o = _dense_attention(q, k, v, causal=False, window=0)
        new_cache = cache
    else:
        o = attention_core(q, k, v, causal=causal and cross_kv is None,
                           window=cfg.window, impl=impl, chunk=cfg.attn_chunk,
                           unroll=cfg.scan_unroll)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, new_cache


# --------------------------------------------------------------------------
# SwiGLU MLP
# --------------------------------------------------------------------------


def mlp_specs(d: int, f: int) -> Dict[str, ParamSpec]:
    return {
        "wg": p_((d, f), ("embed", "ffn")),
        "wu": p_((d, f), ("embed", "ffn")),
        "wd": p_((f, d), ("ffn", "embed")),
    }


def mlp_apply(p, x):
    g = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wg"]))
    u = jnp.einsum("bsd,df->bsf", x, p["wu"])
    return jnp.einsum("bsf,fd->bsd", g * u, p["wd"])


# --------------------------------------------------------------------------
# Embedding / head (tied)
# --------------------------------------------------------------------------


def embed_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    return {"embedding": p_((cfg.vocab, cfg.d_model), ("vocab", "embed")),
            "final_norm": p_((cfg.d_model,), ("embed",), init="ones")}


def embed_apply(p, tokens):
    return jnp.take(p["embedding"], tokens, axis=0)


def logits_apply(p, x):
    return jnp.einsum("bsd,vd->bsv", x, p["embedding"])
