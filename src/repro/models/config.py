"""Unified model configuration for the assigned architecture pool."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int

    head_dim: int = 0           # 0 -> d_model // n_heads
    window: int = 0             # sliding-window attention (0 = full)
    rope_theta: float = 10_000.0

    # MLA (deepseek)
    kv_lora: int = 0            # compressed joint KV dim; 0 = standard GQA
    rope_dim: int = 64          # decoupled rope sub-dim for MLA

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # dispatch groups (GShard-style): ranking/capacity are computed within
    # each group so the cumsum never crosses data shards (1 = global ranking)
    moe_groups: int = 32

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    expand: int = 2
    ssm_chunk: int = 128
    ssd_bf16: bool = False      # dual-form decay/score matrices in bf16

    # hybrid (zamba2): one *shared* attention block after every k SSM layers
    attn_every: int = 0

    # vlm: every k-th layer is a cross-attention layer over vision embeddings
    cross_every: int = 0
    vision_tokens: int = 0

    # audio enc-dec (whisper): encoder over precomputed frame embeddings
    encoder_layers: int = 0
    encoder_frames: int = 0

    dtype: str = "bfloat16"
    remat: bool = True
    attn_chunk: int = 512       # chunked-attention query block
    # roofline-measurement mode: fully unroll layer scans so XLA cost
    # analysis counts every trip (HLO while bodies are otherwise counted
    # once); used by benchmarks/roofline.py two-point extrapolation
    scan_unroll: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def jdtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid / sliding-window)."""
        return self.family in ("ssm", "hybrid") or self.window > 0

    @property
    def ssm_heads(self) -> int:
        return (self.expand * self.d_model) // self.ssm_head_dim

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (drives roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.hd
        n = self.vocab * d                                  # embedding
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "audio", "hybrid"):
            attn = d * self.n_heads * hd + 2 * d * self.n_kv * hd + self.n_heads * hd * d
            if self.kv_lora:
                attn = (d * self.n_heads * (hd + self.rope_dim)      # q (nope+rope)
                        + d * (self.kv_lora + self.rope_dim)          # kv down
                        + self.kv_lora * self.n_kv * 2 * hd           # kv up
                        + self.n_heads * hd * d)
        if self.family == "ssm":
            n += self.n_layers * self._ssm_layer_params()
        elif self.family == "hybrid":
            n += self.n_layers * self._ssm_layer_params()
            n_attn_blocks = 1  # shared block (reused)
            n += n_attn_blocks * (attn + 3 * d * self.d_ff + 2 * d)
        elif self.family == "moe":
            per_layer = attn + 2 * d
            per_layer += self.n_experts * 3 * d * self.moe_d_ff
            per_layer += self.n_shared_experts * 3 * d * self.moe_d_ff
            per_layer += d * self.n_experts                  # router
            n += self.n_layers * per_layer
        else:
            per_layer = attn + 3 * d * self.d_ff + 2 * d
            n += self.n_layers * per_layer
            if self.family == "audio":
                n += self.encoder_layers * (attn + 3 * d * self.d_ff + 2 * d)
                n += self.n_layers * (attn + d)              # cross attn in decoder
            if self.family == "vlm" and self.cross_every:
                pass  # cross layers counted within n_layers
        n += d  # final norm
        return int(n)

    def _ssm_layer_params(self) -> int:
        d = self.d_model
        d_in = self.expand * d
        nh = self.ssm_heads
        return (d * (2 * d_in + 2 * self.ssm_state + nh)   # in_proj(x,z) + B,C + dt
                + d_in * d + 2 * d + nh)                    # out_proj, norms, A

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        attn = d * self.n_heads * self.hd + 2 * d * self.n_kv * self.hd + self.n_heads * self.hd * d
        if self.kv_lora:
            attn = (d * self.n_heads * (self.hd + self.rope_dim)
                    + d * (self.kv_lora + self.rope_dim)
                    + self.kv_lora * self.n_kv * 2 * self.hd
                    + self.n_heads * self.hd * d)
        per_layer = attn + 2 * d + d * self.n_experts
        per_layer += (self.top_k + self.n_shared_experts) * 3 * d * self.moe_d_ff
        return int(self.vocab * d + self.n_layers * per_layer + d)
