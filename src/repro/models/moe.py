"""Mixture-of-Experts FFN with capacity-bucketed top-k dispatch.

Pure-jnp formulation that GSPMD shards end-to-end: experts live on the
``model`` mesh axis (expert parallelism), tokens on (``pod``, ``data``).
Dispatch buckets the top-k assignments into a dense ``(E, C, d)`` tensor via
cumsum ranking (no sort), runs batched per-expert einsums on the MXU, and
scatters back with routing weights.  Tokens beyond an expert's capacity
``C = ceil(T·k/E · capacity_factor)`` are dropped (standard GShard/Switch
semantics) — the routing weights renormalize over surviving assignments.

The auxiliary load-balance loss (Switch-style f·P) and router statistics are
returned alongside; router stats are exactly a *group-by-expert aggregate*,
and the framework also exposes them through the LMFAO engine path (DESIGN.md
§Arch-applicability).
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.config import ModelConfig
from repro.models.layers import ParamSpec, p_


def moe_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    specs = {
        "router": p_((d, e), ("embed", "experts")),
        "wg": p_((e, d, f), ("experts", "embed", None)),
        "wu": p_((e, d, f), ("experts", "embed", None)),
        "wd": p_((e, f, d), ("experts", None, "embed")),
    }
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        specs.update({
            "swg": p_((d, fs), ("embed", "ffn")),
            "swu": p_((d, fs), ("embed", "ffn")),
            "swd": p_((fs, d), ("ffn", "embed")),
        })
    return specs


def _dispatch_groups(cfg: ModelConfig, t: int) -> int:
    g = max(min(cfg.moe_groups, t), 1)
    while t % g:
        g -= 1
    return g


def moe_apply(p, x, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out, aux_loss).

    Grouped dispatch (GShard): tokens split into ``moe_groups`` contiguous
    groups aligned with the batch sharding; ranking cumsums and capacities
    are per group, so dispatch never reduces across data shards (the global
    cumsum was the dominant collective in the baseline — EXPERIMENTS.md
    §Perf Cell C)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    ng = _dispatch_groups(cfg, t)
    tg = t // ng
    xf = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xf, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                    # (t, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss: fraction of tokens per expert × mean router prob
    onehot_k = jax.nn.one_hot(top_e, e, dtype=jnp.float32)    # (t, k, e)
    load = onehot_k.sum(axis=(0, 1)) / (t * k)
    importance = probs.mean(axis=0)
    aux = e * jnp.sum(load * importance)

    # per-group capacity bucketing: rank within (group, expert) via cumsum
    cap = int(math.ceil(tg * k / e * cfg.capacity_factor))
    ge = top_e.reshape(ng, tg * k)
    gw = top_w.reshape(ng, tg * k)
    oh = jax.nn.one_hot(ge, e, dtype=jnp.int32)               # (g, tg·k, e)
    rank = jnp.cumsum(oh, axis=1) - oh                        # prior count in group
    pos = jnp.take_along_axis(rank, ge[..., None], axis=2)[..., 0]
    keep = pos < cap
    w_kept = jnp.where(keep, gw, 0.0)

    # scatter token vectors into (g, e, cap, d) buckets via per-group
    # segment_sum (vmapped: stays local to the group's shard)
    bucket_id = jnp.where(keep, ge * cap + pos, e * cap)      # overflow row
    xg = xf.reshape(ng, tg, d)
    src = jnp.take_along_axis(
        xg, jnp.repeat(jnp.arange(tg), k)[None, :, None].astype(jnp.int32)
        * jnp.ones((ng, 1, 1), jnp.int32), axis=1)            # (g, tg·k, d)
    seg = jax.vmap(lambda s_, i_: jax.ops.segment_sum(
        s_, i_, num_segments=e * cap + 1))(
        src * keep[..., None].astype(src.dtype), bucket_id)
    buckets = seg[:, :-1].reshape(ng, e, cap, d)
    buckets = constrain(buckets, "batch", "experts", None, None)

    # batched per-expert FFN (MXU)
    g = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buckets, p["wg"]))
    u = jnp.einsum("gecd,edf->gecf", buckets, p["wu"])
    yb = jnp.einsum("gecf,efd->gecd", g * u, p["wd"])         # (g, e, cap, d)
    yb = constrain(yb, "batch", "experts", None, None)

    # gather back + weighted combine over the k assignments
    safe_bucket = jnp.where(keep, ge * cap + pos, 0)          # (g, tg·k)
    y_flat = jnp.take_along_axis(
        yb.reshape(ng, e * cap, d), safe_bucket[..., None], axis=1)
    y = (y_flat * w_kept[..., None].astype(y_flat.dtype)) \
        .reshape(ng, tg, k, d).sum(axis=2)

    out = y.reshape(b, s, d).astype(x.dtype)
    if cfg.n_shared_experts:
        gs = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["swg"]))
        us = jnp.einsum("bsd,df->bsf", x, p["swu"])
        out = out + jnp.einsum("bsf,fd->bsd", gs * us, p["swd"])
    return out, aux


def router_stats(p, x, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    """Per-expert load counters — the group-by-expert aggregate (also
    computable through repro.core for the in-database formulation)."""
    t = x.shape[0] * x.shape[1]
    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1).reshape(t, cfg.n_experts)
    _, top_e = jax.lax.top_k(probs, cfg.top_k)
    counts = jnp.zeros(cfg.n_experts).at[top_e.reshape(-1)].add(1.0)
    return {"expert_load": counts, "router_entropy":
            -jnp.mean(jnp.sum(probs * jnp.log(probs + 1e-9), axis=-1))}
