"""Multi-head Latent Attention (DeepSeek-V2) — compressed KV cache.

The KV path is compressed to a joint latent ``c_kv`` of dim ``kv_lora`` plus a
decoupled shared rope key of dim ``rope_dim``; the cache stores only
``(B, T, kv_lora + rope_dim)`` — the arch's whole point for long-context
serving.  Decode uses the absorption trick: q is projected into latent space
so attention runs directly against the compressed cache, and the value
up-projection is applied after the weighted sum.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import ParamSpec, attention_core, p_, rope


def mla_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, h, hd, ck, rd = cfg.d_model, cfg.n_heads, cfg.hd, cfg.kv_lora, cfg.rope_dim
    return {
        "wq": p_((d, h, hd + rd), ("embed", "heads", None)),
        "wdkv": p_((d, ck), ("embed", None)),
        "wkrope": p_((d, rd), ("embed", None)),
        "wkup": p_((ck, h, hd), (None, "heads", None)),
        "wvup": p_((ck, h, hd), (None, "heads", None)),
        "wo": p_((h, hd, d), ("heads", None, "embed")),
    }


def mla_apply(p, x, cfg: ModelConfig, *, positions, impl="dense",
              cache: Optional[dict] = None, decode_pos=None):
    """Returns (out, new_cache). cache = {"c": (B,T,ck), "kr": (B,T,rd)}."""
    h, hd, rd = cfg.n_heads, cfg.hd, cfg.rope_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    c = jnp.einsum("bsd,dc->bsc", x, p["wdkv"])                      # latent
    kr = rope(jnp.einsum("bsd,dr->bsr", x, p["wkrope"])[:, :, None, :],
              positions, cfg.rope_theta)[:, :, 0, :]                 # shared rope key

    if cache is None:
        # training / prefill: expand the latent and run standard attention
        k_nope = jnp.einsum("bsc,chk->bshk", c, p["wkup"])
        v = jnp.einsum("bsc,chk->bshk", c, p["wvup"])
        k = jnp.concatenate([k_nope, jnp.broadcast_to(
            kr[:, :, None, :], kr.shape[:2] + (h, rd))], axis=-1)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        o = attention_core(qq, k, v, causal=True, impl=impl,
                           chunk=cfg.attn_chunk, unroll=cfg.scan_unroll)
        out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
        return out, None

    # decode with absorption: attend in latent space against the compressed cache
    c_cache = jax.lax.dynamic_update_slice(cache["c"], c.astype(cache["c"].dtype),
                                           (0, decode_pos, 0))
    kr_cache = jax.lax.dynamic_update_slice(cache["kr"], kr.astype(cache["kr"].dtype),
                                            (0, decode_pos, 0))
    q_lat = jnp.einsum("bshk,chk->bshc", q_nope, p["wkup"])          # absorb W_kup
    s_lat = jnp.einsum("bshc,btc->bhst", q_lat, c_cache)
    s_rope = jnp.einsum("bshr,btr->bhst", q_rope, kr_cache)
    scores = (s_lat + s_rope).astype(jnp.float32) / np.sqrt(hd + rd)
    t = c_cache.shape[1]
    mask = jnp.arange(t) <= decode_pos
    scores = jnp.where(mask[None, None, None, :], scores, -1e30)
    pr = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhst,btc->bshc", pr, c_cache)
    o = jnp.einsum("bshc,chk->bshk", o_lat, p["wvup"])               # absorb W_vup
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, {"c": c_cache, "kr": kr_cache}
