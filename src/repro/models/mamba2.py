"""Mamba-2 SSD (state-space duality) blocks — arXiv:2405.21060.

Training/prefill uses the chunked SSD algorithm: within a chunk the recurrence
is unrolled into masked matmuls (MXU-friendly quadratic-in-chunk form); across
chunks a small state scan carries ``(heads, head_dim, state)`` — this is the
TPU-native form (the original CUDA kernel's warp-level scan has no TPU
analogue; the matmul duality *is* the adaptation, DESIGN.md §2).

Decode carries O(1) state per layer: ``h ← a·h + dt·B⊗x``; no KV cache, which
is why the SSM archs run the ``long_500k`` shape.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import ParamSpec, p_, rms_norm


def mamba_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    din = cfg.expand * d
    nh, n = cfg.ssm_heads, cfg.ssm_state
    return {
        "wx": p_((d, din), ("embed", "ssm_in")),
        "wz": p_((d, din), ("embed", "ssm_in")),
        "wB": p_((d, n), ("embed", None)),
        "wC": p_((d, n), ("embed", None)),
        "wdt": p_((d, nh), ("embed", "heads")),
        "dt_bias": p_((nh,), ("heads",), init="zeros"),
        "A_log": p_((nh,), ("heads",), init="zeros"),
        "D": p_((nh,), ("heads",), init="ones"),
        "conv_w": p_((4, din + 2 * n), (None, None), scale=0.1),
        "norm": p_((din,), ("ssm_in",), init="ones"),
        "wo": p_((din, d), ("ssm_in", "embed")),
    }


def _causal_conv(u: jnp.ndarray, w: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv, kernel (K, C); u: (B, S, C).
    Returns (out, new_state) where state is the last K-1 inputs."""
    k = w.shape[0]
    if state is None:
        up = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        up = jnp.concatenate([state.astype(u.dtype), u], axis=1)
    out = sum(up[:, i:i + u.shape[1], :] * w[i][None, None, :] for i in range(k))
    new_state = up[:, -(k - 1):, :] if k > 1 else None
    return jax.nn.silu(out), new_state


def ssd_chunked(x, dt, A, B_, C_, chunk: int, compute_dtype=jnp.float32):
    """SSD forward.
    x: (B,S,H,P)  dt: (B,S,H)  A: (H,) negative  B_,C_: (B,S,N).
    Returns y: (B,S,H,P).  ``compute_dtype`` controls the dual-form decay /
    score matrices — the dominant memory traffic (bf16 halves it; the cumsum
    and inter-chunk state stay f32 for stability)."""
    b, s, h, p = x.shape
    n = B_.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    xq = x.reshape(b, nc, chunk, h, p)
    dtq = dt.reshape(b, nc, chunk, h)
    Bq = B_.reshape(b, nc, chunk, n)
    Cq = C_.reshape(b, nc, chunk, n)

    la = dtq * A[None, None, None, :]                  # log decay per step (<=0)
    cum = jnp.cumsum(la, axis=2)                       # (b,nc,Q,h)

    # intra-chunk (quadratic-in-chunk dual form)
    # L[i,j] = exp(cum_i - cum_j) for j <= i
    li = cum[:, :, :, None, :]                         # (b,nc,Q,1,h)
    lj = cum[:, :, None, :, :]                         # (b,nc,1,Q,h)
    idx = jnp.arange(chunk)
    causal = (idx[:, None] >= idx[None, :])[None, None, :, :, None]
    L = jnp.where(causal, jnp.exp(li - lj), 0.0).astype(compute_dtype)
    sc = jnp.einsum("bcin,bcjn->bcij", Cq.astype(compute_dtype),
                    Bq.astype(compute_dtype),
                    preferred_element_type=compute_dtype)
    w = sc[..., None] * L * dtq[:, :, None, :, :].astype(compute_dtype)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xq.astype(compute_dtype),
                         preferred_element_type=jnp.float32)

    # chunk state: S_c = Σ_j exp(cum_end - cum_j)·dt_j·B_j ⊗ x_j — contracted
    # over j by einsum so the (Q,h,n,p) outer product never materializes
    decay_tail = jnp.exp(cum[:, :, -1:, :] - cum)      # (b,nc,Q,h)
    S_c = jnp.einsum("bcqh,bcqn,bcqhp->bchnp", dtq * decay_tail, Bq, xq)
    chunk_decay = jnp.exp(cum[:, :, -1, :])            # (b,nc,h)

    def scan_fn(hprev, inp):
        s_c, dec = inp                                 # (b,h,n,p), (b,h)
        hnew = hprev * dec[:, :, None, None] + s_c
        return hnew, hprev

    h0 = jnp.zeros((b, h, n, p), dtype=jnp.float32)
    _, h_prevs = jax.lax.scan(
        scan_fn, h0,
        (S_c.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         chunk_decay.transpose(1, 0, 2).astype(jnp.float32)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)         # (b,nc,h,n,p) state before chunk

    y_inter = jnp.einsum("bcin,bchnp,bcih->bcihp",
                         Cq, h_prevs.astype(Cq.dtype), jnp.exp(cum))
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y


def mamba_apply(p, x, cfg: ModelConfig, *, state: Optional[dict] = None):
    """Returns (out, new_state).  state = {"h": (B,H,N,P), "conv": (B,3,C)}."""
    b, s, d = x.shape
    nh, n, hp = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    din = cfg.expand * d

    xz = jnp.einsum("bsd,de->bse", x, p["wx"])
    z = jnp.einsum("bsd,de->bse", x, p["wz"])
    Bc = jnp.einsum("bsd,dn->bsn", x, p["wB"])
    Cc = jnp.einsum("bsd,dn->bsn", x, p["wC"])
    dt = jax.nn.softplus(jnp.einsum("bsd,dh->bsh", x, p["wdt"]) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    u = jnp.concatenate([xz, Bc, Cc], axis=-1)
    conv_state = None if state is None else state["conv"]
    u, new_conv = _causal_conv(u, p["conv_w"], conv_state)
    xz, Bc, Cc = u[..., :din], u[..., din:din + n], u[..., din + n:]

    xh = xz.reshape(b, s, nh, hp)
    if state is None:
        cd = jnp.bfloat16 if cfg.ssd_bf16 else jnp.float32
        y = ssd_chunked(xh.astype(jnp.float32), dt.astype(jnp.float32), A,
                        Bc.astype(jnp.float32), Cc.astype(jnp.float32),
                        cfg.ssm_chunk, compute_dtype=cd)
        new_h = None
    else:
        # single-token recurrence: h <- a·h + dt·B⊗x ; y = C·h
        a = jnp.exp(dt[:, 0] * A[None, :])                       # (b,h)
        hprev = state["h"].astype(jnp.float32)                   # (b,h,n,p)
        upd = (dt[:, 0])[:, :, None, None] * \
            Bc[:, 0].astype(jnp.float32)[:, None, :, None] * \
            xh[:, 0].astype(jnp.float32)[:, :, None, :]
        new_h32 = hprev * a[:, :, None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", Cc[:, 0].astype(jnp.float32), new_h32)
        y = y[:, None]                                            # (b,1,h,p)
        new_h = new_h32.astype(state["h"].dtype)

    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, s, din).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    out = jnp.einsum("be,ed->bd", y.reshape(b * s, din), p["wo"]).reshape(b, s, d)
    new_state = None if state is None else {"h": new_h, "conv": new_conv}
    return out, new_state


def init_mamba_state(cfg: ModelConfig, batch: int, dtype):
    nh, n, hp = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    din = cfg.expand * cfg.d_model
    return {"h": jnp.zeros((batch, nh, n, hp), jnp.float32),
            "conv": jnp.zeros((batch, 3, din + 2 * n), dtype)}
