"""models substrate."""
