"""Snapshot-consistent serving over maintained aggregate views.

:class:`ViewServer` is the aggregate engine's serving front end: it wraps a
:class:`~repro.core.ivm.MaintainedBatch` and gives every request a *pinned
epoch* — an immutable version of the whole view state — so concurrent
readers always see mutually consistent aggregates while update batches fold
in behind them (DESIGN.md §8).  This is what lets the engine sit under live
analytics traffic instead of running as a batch job:

    live = db.views(queries, maintain=True)   # repro.connect(...) session
    srv = live.serve(max_pinned_epochs=8)     # started: epoch 0 published
    with srv.snapshot() as snap:          # reader: frozen epoch
        a = snap.results()["q_count"]
        ...                               # writer may publish e+1 here
        b = snap.results()["q_count"]     # still epoch e: a == b, always
    srv.apply(update)                     # writer: validates, folds, swaps

Reads never block writes and writes never block reads — epochs are
immutable device arrays, so a "read lock" is just a reference.  Writers are
serialized by the server's write lock (the maintained batch is
single-writer by contract).  ``checkpoint()`` snapshots a pinned epoch
through the crash-safe store, so a checkpoint taken mid-update-stream is a
clean version, not a torn mix.

The server is mesh-agnostic by construction: when the maintained batch is
sharded (``ExecutionConfig.mesh``), epochs hold replicated view tensors —
every tick psums partial deltas *before* the state fold — so the pin / swap
/ read machinery above is byte-for-byte the same code, reads stay wait-free
on every shard, and only ``apply`` (one ``jit(shard_map)`` per updated
relation) and ``checkpoint`` (one host gather via the snapshot path) touch
the mesh (DESIGN.md §8).  ``stats()["shard"]`` reports the topology.
"""

from __future__ import annotations

import threading
from typing import Dict, Optional

import jax.numpy as jnp

from repro.data.relations import DeltaBatchUpdate


class EpochView:
    """A reader's handle on one pinned epoch (create via
    ``ViewServer.snapshot()``).  All reads through one handle come from the
    same immutable state, no matter how many updates publish meanwhile."""

    def __init__(self, maintained, epoch: int):
        self._mb = maintained
        self.epoch = epoch
        self._results: Optional[Dict[str, jnp.ndarray]] = None

    def results(self) -> Dict[str, jnp.ndarray]:
        # the epoch is immutable, so one extraction serves every read
        # through this handle
        if self._results is None:
            self._results = self._mb.results(epoch=self.epoch)
        return self._results

    def __getitem__(self, query_name: str) -> jnp.ndarray:
        return self.results()[query_name]


class ViewServer:
    """Concurrent read/update front end for a ``MaintainedBatch``.

    Semantics: ``apply`` is transactional (whole batch validated before any
    fold; failure publishes nothing) and serialized across threads; reads
    are wait-free against writers and pin their epoch for as long as the
    snapshot handle lives."""

    def __init__(self, maintained, max_pinned_epochs: Optional[int] = None):
        """``max_pinned_epochs`` bounds how many epochs readers may keep
        device-resident at once (long-lived pins retain whole epochs of
        device memory): past the budget the least-recently-used pin is
        evicted, and reads through an evicted snapshot raise
        :class:`~repro.core.ivm.EpochEvictedError` with a clear message.
        None leaves pins unbounded (trusted traffic only)."""
        if max_pinned_epochs is not None and max_pinned_epochs < 1:
            raise ValueError("max_pinned_epochs must be >= 1 (or None)")
        self.maintained = maintained
        if max_pinned_epochs is not None:
            self.maintained.max_pinned_epochs = max_pinned_epochs
        self._write_lock = threading.Lock()
        self.n_reads = 0
        self.n_updates = 0
        self.n_rejected_updates = 0

    # -- lifecycle -----------------------------------------------------------

    def start(self, db, params=None) -> int:
        """Full scan; publishes the first epoch and returns its id."""
        with self._write_lock:
            self.maintained.init(db, params=params)
            return self.maintained.epoch

    @property
    def epoch(self) -> int:
        return self.maintained.epoch

    # -- read path -----------------------------------------------------------

    def snapshot(self):
        """``with srv.snapshot() as snap:`` — pin the current epoch for the
        block; ``snap.results()`` is frozen at that version."""
        server = self

        class _Pin:
            def __enter__(pin):
                pin._epoch = server.maintained.pin()
                server.n_reads += 1
                return EpochView(server.maintained, pin._epoch)

            def __exit__(pin, *exc):
                server.maintained.unpin(pin._epoch)
                return False

        return _Pin()

    def read(self, query_name: Optional[str] = None):
        """One-shot consistent read at the current epoch (pin, read, unpin).
        Returns the full results dict, or one query's array."""
        with self.snapshot() as snap:
            out = snap.results()
        return out if query_name is None else out[query_name]

    # -- write path ----------------------------------------------------------

    def apply(self, update: DeltaBatchUpdate, params=None) -> int:
        """Fold an update batch and publish the next epoch; returns its id.
        Serialized across threads; a rejected batch raises and leaves the
        served epoch untouched."""
        with self._write_lock:
            try:
                self.maintained.apply(update, params=params)
            except Exception:
                self.n_rejected_updates += 1
                raise
            self.n_updates += 1
            return self.maintained.epoch

    def checkpoint(self, ckpt_dir: str, keep: int = 3) -> str:
        """Crash-safe snapshot of a pinned epoch — consistent even while a
        concurrent ``apply`` folds the next one."""
        with self.maintained.pinned() as epoch:
            return self.maintained.save(ckpt_dir, keep=keep, epoch=epoch)

    def stats(self) -> Dict[str, object]:
        return {"epoch": self.maintained.epoch,
                "step": self.maintained.step,
                "n_reads": self.n_reads,
                "n_updates": self.n_updates,
                "n_rejected_updates": self.n_rejected_updates,
                "n_pinned_epochs": self.maintained.n_pinned_epochs,
                "n_evicted_pins": self.maintained.n_evicted_pins,
                "max_pinned_epochs": self.maintained.max_pinned_epochs,
                "n_delta_scan_steps": self.maintained.n_delta_scan_steps,
                "shard": self.maintained.shard_topology()}
