"""Snapshot-consistent serving over maintained aggregate views.

:class:`ViewServer` is the aggregate engine's serving front end: it wraps a
:class:`~repro.core.ivm.MaintainedBatch` and gives every request a *pinned
epoch* — an immutable version of the whole view state — so concurrent
readers always see mutually consistent aggregates while update batches fold
in behind them (DESIGN.md §8).  This is what lets the engine sit under live
analytics traffic instead of running as a batch job:

    live = db.views(queries, maintain=True)   # repro.connect(...) session
    srv = live.serve(max_pinned_epochs=8)     # started: epoch 0 published
    with srv.snapshot() as snap:          # reader: frozen epoch
        a = snap.results()["q_count"]
        ...                               # writer may publish e+1 here
        b = snap.results()["q_count"]     # still epoch e: a == b, always
    srv.apply(update)                     # writer: validates, folds, swaps

Reads never block writes and writes never block reads — epochs are
immutable device arrays, so a "read lock" is just a reference.  Writers are
serialized by the server's write lock (the maintained batch is
single-writer by contract).  ``checkpoint()`` snapshots a pinned epoch
through the crash-safe store, so a checkpoint taken mid-update-stream is a
clean version, not a torn mix.

The server is mesh-agnostic by construction: when the maintained batch is
sharded (``ExecutionConfig.mesh``), epochs hold replicated view tensors —
every tick psums partial deltas *before* the state fold — so the pin / swap
/ read machinery above is byte-for-byte the same code, reads stay wait-free
on every shard, and only ``apply`` (one ``jit(shard_map)`` per updated
relation) and ``checkpoint`` (one host gather via the snapshot path) touch
the mesh (DESIGN.md §8).  ``stats()["shard"]`` reports the topology.

Telemetry (DESIGN.md §11): every read and update observes a latency
histogram (``serve.read_us`` / ``ivm.tick_us``), reads record their query
signature into the session workload recorder, and a rate-limited warning
fires when pinned readers fall more than ``warn_epoch_lag`` epochs behind
head.  All of it follows the no-sync rule — host clocks around dispatch
sites, never ``block_until_ready`` — so the steady-state zero-transfer /
zero-retrace contracts hold with telemetry enabled.
"""

from __future__ import annotations

import threading
import time
from typing import Dict, Optional

import jax.numpy as jnp

from repro.data.relations import DeltaBatchUpdate
from repro.obs.log import get_logger
from repro.obs.metrics import Registry
from repro.obs.trace import span
from repro.obs.workload import WorkloadRecorder, signature_of

#: seconds between repeated epoch-lag warnings for the same server
_LAG_WARN_INTERVAL_S = 5.0


class EpochView:
    """A reader's handle on one pinned epoch (create via
    ``ViewServer.snapshot()``).  All reads through one handle come from the
    same immutable state, no matter how many updates publish meanwhile."""

    def __init__(self, maintained, epoch: int):
        self._mb = maintained
        self.epoch = epoch
        self._results: Optional[Dict[str, jnp.ndarray]] = None

    @property
    def epoch_lag(self) -> int:
        """How many epochs head has advanced past this handle — 0 means
        the reader sees the newest published state."""
        return self._mb.epoch - self.epoch

    def results(self) -> Dict[str, jnp.ndarray]:
        # the epoch is immutable, so one extraction serves every read
        # through this handle
        if self._results is None:
            with span("serve.read", epoch=self.epoch):
                self._results = self._mb.results(epoch=self.epoch)
        return self._results

    def __getitem__(self, query_name: str) -> jnp.ndarray:
        return self.results()[query_name]


class ViewServer:
    """Concurrent read/update front end for a ``MaintainedBatch``.

    Semantics: ``apply`` is transactional (whole batch validated before any
    fold; failure publishes nothing) and serialized across threads; reads
    are wait-free against writers and pin their epoch for as long as the
    snapshot handle lives."""

    def __init__(self, maintained, max_pinned_epochs: Optional[int] = None,
                 warn_epoch_lag: Optional[int] = None,
                 workload: Optional[WorkloadRecorder] = None,
                 router=None):
        """``max_pinned_epochs`` bounds how many epochs readers may keep
        device-resident at once (long-lived pins retain whole epochs of
        device memory): past the budget the least-recently-used pin is
        evicted, and reads through an evicted snapshot raise
        :class:`~repro.core.ivm.EpochEvictedError` with a clear message.
        None leaves pins unbounded (trusted traffic only).

        ``warn_epoch_lag`` sets the lag threshold (head minus the oldest
        pinned epoch) past which the server logs a rate-limited warning —
        laggard pins are exactly what exhausts the pin budget.  None
        disables the warning.  ``workload`` is the session's shared
        :class:`~repro.obs.workload.WorkloadRecorder`; reads record their
        query signature into it (one per served view).

        ``router`` (optional) is the session's signature router
        (:class:`~repro.serve.router.QueryRouter`); when set, :meth:`query`
        answers *arbitrary* group-by aggregates through it — the session
        facade wires this automatically (``ViewHandle.serve()``)."""
        if max_pinned_epochs is not None and max_pinned_epochs < 1:
            raise ValueError("max_pinned_epochs must be >= 1 (or None)")
        if warn_epoch_lag is not None and warn_epoch_lag < 1:
            raise ValueError("warn_epoch_lag must be >= 1 (or None)")
        self.maintained = maintained
        if max_pinned_epochs is not None:
            self.maintained.max_pinned_epochs = max_pinned_epochs
        self.warn_epoch_lag = warn_epoch_lag
        self.workload = workload
        self.router = router
        self._write_lock = threading.Lock()
        self.n_reads = 0
        self.n_updates = 0
        self.n_rejected_updates = 0
        self.n_lag_warnings = 0
        self._log = get_logger("repro.serve")
        #: per-server telemetry: read-latency distribution + pin high-water
        self.metrics = Registry()
        self._read_hist = self.metrics.histogram("serve.read_us")
        self._lag_gauge = self.metrics.gauge("serve.epoch_lag")
        self._pin_hwm = self.metrics.gauge("serve.pinned_epochs_hwm")
        # query signatures are static per compiled batch — render once, and
        # only when workload recording is on: with workload_capacity=0 the
        # read path must allocate nothing for telemetry
        self._signatures = ({
            q: signature_of(qo.query)
            for q, qo in maintained.batch.result.outputs.items()}
            if workload is not None and workload.enabled else {})

    # -- lifecycle -----------------------------------------------------------

    def start(self, db, params=None) -> int:
        """Full scan; publishes the first epoch and returns its id."""
        with self._write_lock:
            self.maintained.init(db, params=params)
            return self.maintained.epoch

    @property
    def epoch(self) -> int:
        return self.maintained.epoch

    # -- telemetry -----------------------------------------------------------

    @property
    def epoch_lag(self) -> int:
        """Head minus the oldest pinned epoch (0 with no pins): how far the
        laggiest live reader is behind the served state."""
        pinned = self.maintained.pinned_epochs()
        return (self.maintained.epoch - pinned[0]) if pinned else 0

    def _observe_lag(self) -> None:
        lag = self.epoch_lag
        self._lag_gauge.set(lag)
        self._pin_hwm.max(self.maintained.n_pinned_epochs)
        if self.warn_epoch_lag is not None and lag > self.warn_epoch_lag:
            if self._log.warning_every(
                    _LAG_WARN_INTERVAL_S, "epoch_lag",
                    "pinned readers lag served head", lag=lag,
                    threshold=self.warn_epoch_lag,
                    epoch=self.maintained.epoch,
                    n_pinned=self.maintained.n_pinned_epochs):
                self.n_lag_warnings += 1

    def _record_read(self, names, epoch: int, latency_us: float) -> None:
        if self.workload is None or not self.workload.enabled:
            return
        for name in names:
            sig = self._signatures.get(name)
            if sig is not None:
                self.workload.record("read", name, sig, "pinned_read",
                                     latency_us, epoch=epoch)

    # -- read path -----------------------------------------------------------

    def snapshot(self):
        """``with srv.snapshot() as snap:`` — pin the current epoch for the
        block; ``snap.results()`` is frozen at that version."""
        server = self

        class _Pin:
            def __enter__(pin):
                pin._epoch = server.maintained.pin()
                server.n_reads += 1
                server._observe_lag()
                return EpochView(server.maintained, pin._epoch)

            def __exit__(pin, *exc):
                server.maintained.unpin(pin._epoch)
                return False

        return _Pin()

    def read(self, query_name: Optional[str] = None):
        """One-shot consistent read at the current epoch (pin, read, unpin).
        Returns the full results dict, or one query's array."""
        t0 = time.perf_counter()
        with self.snapshot() as snap:
            out = snap.results()
            epoch = snap.epoch
        # host dispatch wall only (no device sync) — DESIGN.md §11
        us = (time.perf_counter() - t0) * 1e6
        self._read_hist.observe(us)
        self._record_read((query_name,) if query_name is not None else out,
                          epoch, us)
        return out if query_name is None else out[query_name]

    def query(self, q, params=None):
        """Serving-side front door for *ad-hoc* aggregates (DESIGN.md §13):
        routes ``q`` through the session's signature router — exact /
        subsumed matches answer from one pinned epoch; misses compile a
        fresh verified plan — and returns the dense answer tensor.  Use
        :meth:`read` for the views this server was compiled for."""
        if self.router is None:
            raise ValueError(
                "this ViewServer has no query router attached; create it "
                "through the session facade (db.views(..., maintain=True)"
                ".serve()) or pass router= explicitly")
        return self.router.route(q, params=params).value

    # -- write path ----------------------------------------------------------

    def apply(self, update: DeltaBatchUpdate, params=None) -> int:
        """Fold an update batch and publish the next epoch; returns its id.
        Serialized across threads; a rejected batch raises and leaves the
        served epoch untouched."""
        with self._write_lock:
            try:
                self.maintained.apply(update, params=params)
            except Exception:
                self.n_rejected_updates += 1
                raise
            self.n_updates += 1
            self._observe_lag()
            return self.maintained.epoch

    def checkpoint(self, ckpt_dir: str, keep: int = 3) -> str:
        """Crash-safe snapshot of a pinned epoch — consistent even while a
        concurrent ``apply`` folds the next one."""
        with self.maintained.pinned() as epoch:
            with span("serve.checkpoint", epoch=epoch):
                return self.maintained.save(ckpt_dir, keep=keep, epoch=epoch)

    def stats(self) -> Dict[str, object]:
        """Counters plus latency distributions: ``read_us`` (this server's
        one-shot reads) and ``tick_us`` (the maintained batch's ``apply``
        dispatch wall) carry count/mean/p50/p95/p99 dicts."""
        mb_metrics = self.maintained.metrics.snapshot()
        return {"epoch": self.maintained.epoch,
                "step": self.maintained.step,
                "n_reads": self.n_reads,
                "n_updates": self.n_updates,
                "n_rejected_updates": self.n_rejected_updates,
                "n_pinned_epochs": self.maintained.n_pinned_epochs,
                "n_evicted_pins": self.maintained.n_evicted_pins,
                "max_pinned_epochs": self.maintained.max_pinned_epochs,
                "n_delta_scan_steps": self.maintained.n_delta_scan_steps,
                "epoch_lag": self.epoch_lag,
                "warn_epoch_lag": self.warn_epoch_lag,
                "n_lag_warnings": self.n_lag_warnings,
                "read_us": self._read_hist.snapshot(),
                "tick_us": mb_metrics.get("ivm.tick_us"),
                "pinned_epochs_hwm": self._pin_hwm.value,
                "shard": self.maintained.shard_topology()}
