"""Serving: single-token decode step + a batched continuous-decode driver.

``make_serve_step`` builds the jit-compiled one-token step (the artifact the
decode_* dry-run shapes lower).  ``BatchedServer`` drives it for a batch of
requests with per-slot positions and greedy sampling — the minimal continuous
batching loop (slot recycling on EOS) the examples exercise.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.distributed.sharding import mesh_context, param_pspecs, rules_for
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.models.layers import init_params


def make_serve_step(cfg: ModelConfig, mesh: Optional[Mesh] = None):
    """serve_step(params, cache, tokens(B,1), pos, context?) ->
    (logits(B,1,V), new_cache)."""

    def serve_step(params, cache, tokens, pos, context=None):
        with mesh_context(mesh):
            return M.decode_step(params, cache, tokens, pos, cfg, context=context)

    return serve_step


def greedy(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]


@dataclasses.dataclass
class BatchedServer:
    """Greedy batched decoding over a fixed slot count.

    ``context`` is the *raw* modality input (frames/vision); the encoder runs
    once here, and decode steps consume the encoded context."""

    cfg: ModelConfig
    params: Any
    max_len: int
    batch: int
    context: Optional[jnp.ndarray] = None

    def __post_init__(self):
        from repro.models.layers import init_params as _ip
        specs = M.cache_specs(self.cfg, self.batch, self.max_len)
        self.cache = _ip(specs, jax.random.PRNGKey(0), self.cfg.jdtype)
        if self.context is not None:
            key = "frames" if self.cfg.family == "audio" else "vision"
            self.context = M.encode_context(self.params, {key: self.context},
                                            self.cfg)
        self._step = jax.jit(make_serve_step(self.cfg))

    def generate(self, prompts: np.ndarray, n_tokens: int) -> np.ndarray:
        """prompts: (B, P) int32. Greedy-decodes n_tokens continuations."""
        b, plen = prompts.shape
        assert b == self.batch
        toks = jnp.asarray(prompts[:, :1])
        out = [np.asarray(toks)]
        cache = self.cache
        for pos in range(plen + n_tokens - 1):
            logits, cache = self._step(self.params, cache, toks,
                                       jnp.asarray(pos, jnp.int32),
                                       self.context)
            if pos + 1 < plen:
                toks = jnp.asarray(prompts[:, pos + 1:pos + 2])  # teacher force
            else:
                toks = greedy(logits)
            out.append(np.asarray(toks))
        return np.concatenate(out, axis=1)
