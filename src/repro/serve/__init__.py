"""Serving layer.

``views.py`` — the aggregate engine's serving front end: epoch-pinned,
snapshot-consistent reads over incrementally maintained views
(:class:`~repro.serve.views.ViewServer`), the piece that turns the engine
into a long-lived service under concurrent reads and update streams.

``router.py`` / ``planner.py`` — ad-hoc query serving (DESIGN.md §13): a
signature router answering *arbitrary* group-by aggregates from the
session's views (exact match / subsumption re-aggregation / verified
compile-and-cache), driven by an adaptive planner over the signature
lattice.  Reached through ``Database.query`` / ``ViewServer.query``.

``engine.py`` — the LM decode loop retained from the model-serving seed
(batched greedy decoding; used by ``examples/serve_lm.py``).
"""

from repro.core.ivm import EpochEvictedError
from repro.serve.planner import AdaptivePlanner, Candidate, RoutePlan
from repro.serve.router import QueryRouter, RouteResult
from repro.serve.views import EpochView, ViewServer

__all__ = ["AdaptivePlanner", "Candidate", "EpochEvictedError", "EpochView",
           "QueryRouter", "RoutePlan", "RouteResult", "ViewServer"]
