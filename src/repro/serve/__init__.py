"""Serving layer.

``views.py`` — the aggregate engine's serving front end: epoch-pinned,
snapshot-consistent reads over incrementally maintained views
(:class:`~repro.serve.views.ViewServer`), the piece that turns the engine
into a long-lived service under concurrent reads and update streams.

``engine.py`` — the LM decode loop retained from the model-serving seed
(batched greedy decoding; used by ``examples/serve_lm.py``).
"""

from repro.core.ivm import EpochEvictedError
from repro.serve.views import EpochView, ViewServer

__all__ = ["EpochEvictedError", "EpochView", "ViewServer"]
