"""serve substrate."""
