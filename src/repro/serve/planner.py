"""Adaptive route planning over the signature lattice (DESIGN.md §13).

The planner is the *decision* half of ad-hoc query serving: given an
arbitrary group-by aggregate and the session's answerable sources —
registered view handles plus the router's compiled-plan cache — it picks
the cheapest sound way to answer, without executing anything:

    tier "exact"     the query's canonical signature equals a source's;
                     the answer is an axis/column shuffle of one view
                     tensor (maintained source → epoch read, no scan;
                     batch/cached source → that handle's shared scan)
    tier "subsumed"  a *wider maintained* view subsumes the query
                     (``core/subsume.py``); the answer re-aggregates its
                     epoch tensor on-device — never a base-relation scan
    miss             nothing answers it; the router compiles a fresh plan

Preference order is by execution cost, not match quality: an epoch read
beats a re-aggregation beats any scan, and among subsuming views the
smallest source tensor wins (``reagg_cost``).  Subsumption is only planned
against maintained sources — re-aggregating a batch view would rescan base
relations anyway, at which point an exact compiled plan is no worse.

Maintained sources bind their parameters at the initial full scan, so a
routed call that passes explicit ``params`` skips them (tiers fall through
to compiled plans, which bind params per run).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.core.aggregates import Query
from repro.core.subsume import (SecondaryProgram, ViewShape,
                                build_secondary_program, reagg_cost,
                                subsumes, view_shape_of)
from repro.obs.workload import signature_of

__all__ = ["Candidate", "RoutePlan", "AdaptivePlanner",
           "has_batched_params"]


def has_batched_params(q: Query) -> bool:
    """Whether any term carries a ``Param(batched=True)`` — those queries
    need the node-frontier axis (``ViewHandle.run_batched``) and are
    rejected by the router with a pointer there."""
    return any(t.is_batched()
               for a in q.aggregates for p in a.products for t in p.terms)


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One answerable source view: a named output of a session handle (or
    of a router cache entry), with its tensor shape."""

    handle: object              # ViewHandle owning the view
    view: str                   # view (query) name within the handle
    shape: ViewShape
    maintained: bool            # epoch source (True) vs scan source


@dataclasses.dataclass
class RoutePlan:
    """The planner's verdict for one query (execution is the router's
    job).  ``secondary`` is always set for view-sourced answers — for
    exact matches it is the pure axis/column adapter (``is_exact``)."""

    tier: str                   # "exact" | "subsumed"
    source: Candidate
    secondary: SecondaryProgram


class AdaptivePlanner:
    """Stateless decision procedure; the router owns all caches."""

    def __init__(self, schema):
        self.schema = schema

    def target_shape(self, q: Query) -> ViewShape:
        return view_shape_of(q, self.schema)

    def candidates_of(self, handle, maintained: bool) -> List[Candidate]:
        """Expand a handle into per-view candidates.  Maintained handles
        only count once initialized — routing must never trigger an
        implicit full scan of an un-run maintained view."""
        if maintained and not handle.maintained.initialized:
            return []
        out = []
        for name, qo in handle.compiled.result.outputs.items():
            out.append(Candidate(
                handle=handle, view=name,
                shape=view_shape_of(qo.query, self.schema, name=name),
                maintained=maintained))
        return out

    def plan(self, q: Query, candidates: Sequence[Candidate], *,
             allow_maintained: bool = True) -> Optional[RoutePlan]:
        """Pick the cheapest sound answer, or None (miss → compile)."""
        target = self.target_shape(q)
        key = signature_of(q).key()
        exact_scan: Optional[Candidate] = None
        best_sub: Optional[Tuple[int, Candidate]] = None
        for c in candidates:
            if c.maintained and not allow_maintained:
                continue
            # handle.signatures() renders once per handle and caches
            c_key = c.handle.signatures()[c.view].key()
            if c_key == key:
                if c.maintained:
                    # epoch read: nothing beats it — decide immediately
                    return RoutePlan(
                        tier="exact", source=c,
                        secondary=build_secondary_program(c.shape, target))
                if exact_scan is None:
                    exact_scan = c
            elif c.maintained and subsumes(c.shape, target):
                cost = reagg_cost(c.shape)
                if best_sub is None or cost < best_sub[0]:
                    best_sub = (cost, c)
        if best_sub is not None:
            c = best_sub[1]
            return RoutePlan(
                tier="subsumed", source=c,
                secondary=build_secondary_program(c.shape, target))
        if exact_scan is not None:
            return RoutePlan(
                tier="exact", source=exact_scan,
                secondary=build_secondary_program(exact_scan.shape, target))
        return None
