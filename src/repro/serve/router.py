"""Signature router: the serving front door for ad-hoc aggregates.

``Database.query(q)`` / ``ViewServer.query(q)`` accept an *arbitrary*
group-by aggregate — not just the batches a session compiled up front —
and answer it through three tiers (DESIGN.md §13):

    exact          the query's canonical signature matches an answerable
                   source (registered view or cached compiled plan); the
                   answer is an axis/column shuffle of that view's tensor
    subsumed       a wider maintained view subsumes it; the answer is a
                   verified secondary program re-aggregating the epoch
                   tensor on-device (``core/subsume.py``) — no base scan
    compiled       a miss: a fresh single-query plan is compiled through
                   the normal ``_compile`` path, admission-gated by the
                   static verifier, cached (bounded LRU), and answered
                   from its one-shot shared scan
    fallback_scan  a one-shot compile-and-scan that is *not* cached:
                   unroutable queries (untagged UDAFs have no stable
                   signature) or a cache disabled with capacity 0

Epoch consistency: every maintained-source answer reads one pinned epoch
(``MaintainedBatch.pinned()``), so a routed answer is never torn across a
concurrent ``apply`` — the same contract ``ViewServer.snapshot`` gives
direct readers.  Scan-tier answers (exact-on-cached, compiled,
fallback_scan) read ``Database.data`` — the session's base-relation
snapshot, which delta folds do NOT advance (maintained state keeps its
own resident copy).  A driver that folds updates and also expects fresh
*scan* answers must keep ``Database.data`` current
(``apply_delta``), exactly as it already must for plain batch views.  Sharded sessions route unchanged: epoch views are
replicated (psum-before-fold), so tier-1/2 answers run the same device
function per shard with no new collectives, and tier-3 scans go through
the session's normal mesh runner.

Every routed query records its tier + latency into the session's
``WorkloadRecorder`` (``route=`` field), feeding the view advisor
(ROADMAP item 2): signatures that keep arriving as ``compiled`` /
``fallback_scan`` are exactly the views worth materializing.

Admission gate: every plan this router compiles — cached or one-shot —
passes ``analysis.verify.verify_plan`` *unconditionally* (the session's
``verify_plans`` tri-state does not apply: serving-time compiles are
plans no human reviewed), and every secondary program passes
``verify_secondary_program`` before lowering.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Dict, List, Optional, Tuple

from repro.analysis.verify import verify_plan, verify_secondary_program
from repro.core.aggregates import Params, Query
from repro.core.subsume import lower_secondary
from repro.obs.metrics import Registry
from repro.obs.workload import QuerySignature, routable, signature_of
from repro.serve.planner import (AdaptivePlanner, Candidate, RoutePlan,
                                 has_batched_params)

__all__ = ["RouteResult", "QueryRouter"]

#: routed-tier labels (also the ``WorkloadRecord.route`` vocabulary)
TIERS = ("exact", "subsumed", "compiled", "fallback_scan")


@dataclasses.dataclass
class RouteResult:
    """One routed answer plus how it was produced (``Database.route``)."""

    query: str                  # the asking query's name
    tier: str                   # one of TIERS
    value: object               # the dense answer tensor
    signature: QuerySignature
    source: Optional[str]       # answering view name (None for tier 3/4)
    epoch: Optional[int]        # pinned epoch for maintained sources
    latency_us: float           # host dispatch wall (no device sync)
    scanned: bool               # True iff base relations were scanned


class _CacheEntry:
    __slots__ = ("handle", "hits")

    def __init__(self, handle):
        self.handle = handle
        self.hits = 0           # per-signature hit counter


class QueryRouter:
    """Bounded-LRU routing engine owned by a :class:`~repro.api.Database`.

    Thread-safe: planning and cache maintenance run under one lock;
    answer execution relies on the epoch-pin machinery (reads) and each
    handle's own dispatch path (scans)."""

    def __init__(self, database, capacity: int = 32):
        if not isinstance(capacity, int) or isinstance(capacity, bool) \
                or capacity < 0:
            raise ValueError("route cache capacity must be an int >= 0 "
                             "(0 disables caching)")
        self._db = database
        self.capacity = capacity
        self.planner = AdaptivePlanner(database.schema)
        self._lock = threading.RLock()
        self._cache: "collections.OrderedDict[str, _CacheEntry]" = \
            collections.OrderedDict()
        self._cached_ids: Dict[int, str] = {}   # id(handle) -> cache key
        self._cand_cache: Dict[int, List[Candidate]] = {}
        self._secondary: Dict[Tuple[int, object], object] = {}
        # telemetry: tier counters + routed-latency distribution
        self.n_queries = 0
        self.tier_counts: Dict[str, int] = {t: 0 for t in TIERS}
        self.n_plans_compiled = 0
        self.n_evictions = 0
        self.n_admission_checks = 0
        self.n_admission_failures = 0
        self.n_base_scans = 0
        self.n_reaggs = 0
        self.metrics = Registry()
        self._route_hist = self.metrics.histogram("route.us")

    # -- candidate enumeration ----------------------------------------------

    def _candidates(self) -> List[Candidate]:
        out: List[Candidate] = []
        sources = [(h, h.is_maintained) for h in self._db._registered]
        sources += [(e.handle, False) for e in self._cache.values()]
        for h, maintained in sources:
            ck = id(h)
            cands = self._cand_cache.get(ck)
            if cands is None:
                cands = self.planner.candidates_of(h, maintained)
                # an uninitialized maintained handle expands to nothing —
                # don't cache that, it becomes answerable after its first
                # full scan
                if cands or not maintained:
                    self._cand_cache[ck] = cands
            out.extend(cands)
        return out

    # -- admission-gated compilation ----------------------------------------

    def _compile_fresh(self, q: Query):
        """One fresh single-query plan through the session's normal
        compile path (NOT registered — the router's cache owns it)."""
        return self._db.views([q], register=False)

    def _admit(self, handle) -> None:
        """The admission gate: a serving-time compile is a plan no human
        reviewed, so it must pass the static verifier before it answers
        anything or enters the cache — unconditionally, whatever the
        session's ``verify_plans`` setting."""
        self.n_admission_checks += 1
        try:
            verify_plan(handle.compiled.plan)
        except Exception:
            self.n_admission_failures += 1
            raise

    def _secondary_fn(self, cand: Candidate, sp):
        """Verified, lowered, and cached once per (source handle,
        program) — repeat hits reuse the jitted function."""
        key = (id(cand.handle), sp)
        fn = self._secondary.get(key)
        if fn is None:
            self.n_admission_checks += 1
            try:
                verify_secondary_program(sp)
            except Exception:
                self.n_admission_failures += 1
                raise
            fn = lower_secondary(sp)
            self._secondary[key] = fn
        return fn

    # -- cache maintenance ---------------------------------------------------

    def _cache_insert(self, key: str, handle) -> None:
        self._cache[key] = _CacheEntry(handle)
        self._cached_ids[id(handle)] = key
        while len(self._cache) > self.capacity:
            old_key, old = self._cache.popitem(last=False)
            self._evict(old_key, old)

    def _evict(self, key: str, entry: _CacheEntry) -> None:
        self.n_evictions += 1
        hid = id(entry.handle)
        self._cached_ids.pop(hid, None)
        self._cand_cache.pop(hid, None)
        for k in [k for k in self._secondary if k[0] == hid]:
            del self._secondary[k]

    def _touch(self, handle) -> Optional[str]:
        """LRU bump + hit count when the answering handle is cached."""
        key = self._cached_ids.get(id(handle))
        if key is not None:
            entry = self._cache[key]
            entry.hits += 1
            self._cache.move_to_end(key)
        return key

    # -- execution -----------------------------------------------------------

    def _execute(self, plan: RoutePlan, params: Optional[Params]):
        cand = plan.source
        fn = self._secondary_fn(cand, plan.secondary)
        if cand.maintained:
            mb = cand.handle.maintained
            with mb.pinned() as epoch:
                value = fn(mb.results(epoch=epoch)[cand.view])
            if not plan.secondary.is_exact:
                self.n_reaggs += 1
            return value, epoch, "epoch_read", False
        out = cand.handle.run(params)
        self.n_base_scans += 1
        hit = ("sharded_scan" if self._db.config.mesh is not None
               else "batch_scan")
        return fn(out[cand.view]), None, hit, True

    def _compile_and_run(self, q: Query, params: Optional[Params],
                         cache: bool):
        handle = self._compile_fresh(q)
        self.n_plans_compiled += 1
        self._admit(handle)
        if cache:
            self._cache_insert(signature_of(q).key(), handle)
        out = handle.run(params)
        self.n_base_scans += 1
        hit = ("sharded_scan" if self._db.config.mesh is not None
               else "batch_scan")
        return out[q.name], hit

    # -- front door ----------------------------------------------------------

    def route(self, q: Query, params: Optional[Params] = None) -> RouteResult:
        """Answer an arbitrary group-by aggregate; returns the value plus
        tier / source / epoch provenance.  ``Database.query`` is the
        value-only convenience wrapper."""
        if has_batched_params(q):
            raise ValueError(
                f"query {q.name!r} carries batched params; the router "
                "serves scalar-param queries — use db.views([q])"
                ".run_batched(params) for the node-frontier axis")
        t0 = time.perf_counter()
        sig = signature_of(q)
        source = epoch = None
        with self._lock:
            self.n_queries += 1
            if not routable(q):
                # untagged UDAFs have no stable signature: never matched,
                # never cached — one verified compile-and-scan
                tier = "fallback_scan"
                value, hit = self._compile_and_run(q, params, cache=False)
                scanned = True
            else:
                plan = self.planner.plan(q, self._candidates(),
                                         allow_maintained=not params)
                if plan is not None:
                    tier = plan.tier
                    value, epoch, hit, scanned = self._execute(plan, params)
                    source = plan.source.view
                    self._touch(plan.source.handle)
                else:
                    cache = self.capacity > 0
                    tier = "compiled" if cache else "fallback_scan"
                    value, hit = self._compile_and_run(q, params,
                                                       cache=cache)
                    scanned = True
            self.tier_counts[tier] += 1
        us = (time.perf_counter() - t0) * 1e6
        self._route_hist.observe(us)
        rec = self._db.workload
        if rec.enabled:
            rec.record("query", q.name, sig, hit, us, epoch=epoch,
                       route=tier)
        return RouteResult(query=q.name, tier=tier, value=value,
                           signature=sig, source=source, epoch=epoch,
                           latency_us=us, scanned=scanned)

    # -- telemetry -----------------------------------------------------------

    @property
    def hit_rate(self) -> float:
        """Fraction of routed queries answered without compiling a fresh
        plan (tiers exact + subsumed)."""
        if not self.n_queries:
            return 0.0
        hits = self.tier_counts["exact"] + self.tier_counts["subsumed"]
        return hits / self.n_queries

    def cache_stats(self) -> List[Dict[str, object]]:
        """Per-signature hit counters, LRU order (oldest first)."""
        with self._lock:
            return [{"signature": k, "hits": e.hits}
                    for k, e in self._cache.items()]

    def stats(self) -> Dict[str, object]:
        with self._lock:
            return {"n_queries": self.n_queries,
                    "tiers": dict(self.tier_counts),
                    "hit_rate": self.hit_rate,
                    "cache_size": len(self._cache),
                    "capacity": self.capacity,
                    "n_plans_compiled": self.n_plans_compiled,
                    "n_evictions": self.n_evictions,
                    "n_admission_checks": self.n_admission_checks,
                    "n_admission_failures": self.n_admission_failures,
                    "n_base_scans": self.n_base_scans,
                    "n_reaggs": self.n_reaggs,
                    "route_us": self._route_hist.snapshot(),
                    "cache": self.cache_stats()}
