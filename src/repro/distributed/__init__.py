"""distributed substrate."""
