"""Logical-axis → mesh-axis sharding rules (FSDP + TP + pod-DP).

Parameters declare logical axes (``embed``, ``heads``, ``ffn``, ``vocab``,
``experts``, ...).  The rules below shard every tensor-parallel dimension over
``model``, the d_model dimension over ``data`` (ZeRO-3/FSDP: GSPMD inserts
per-layer all-gathers forward and reduce-scatters backward), and replicate
across ``pod`` (pure DP between pods; gradients psum over pod+data).

Activations are constrained at block boundaries: batch over (pod, data); KV
caches shard their *length* over ``model`` (context-parallel decode,
DESIGN.md §6).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.layers import ParamSpec

Rules = Dict[str, Union[None, str, Tuple[str, ...]]]

DEFAULT_RULES: Rules = {
    "batch": ("pod", "data"),
    "embed": "data",        # FSDP dim on params
    "heads": "model",
    "kv": "model",
    "ffn": "model",
    "vocab": "model",
    "experts": "model",
    "ssm_in": "model",
    "layers": None,
    "cache_seq": "model",   # context-parallel decode
    "cache_batch": ("pod", "data"),
    # sequence-parallel activations (Megatron-SP): residual-stream tensors at
    # block boundaries shard their sequence dim over "model"; attention/mlp
    # re-gather internally.  Cuts the per-layer remat carry by the TP degree —
    # required to fit 100-layer train_4k activations (DESIGN.md §6).
    "seq": "model",
    "capacity": "data",     # MoE dispatch-bucket capacity dim
}

SINGLE_POD_RULES: Rules = dict(DEFAULT_RULES, batch=("data",), cache_batch=("data",))


def rules_for(mesh: Optional[Mesh], seq_shard: bool = True) -> Rules:
    if mesh is None:
        return DEFAULT_RULES
    base = DEFAULT_RULES if "pod" in mesh.axis_names else SINGLE_POD_RULES
    if not seq_shard:
        base = dict(base, seq=None)
    return base


def spec_for(axes: Sequence[Optional[str]], rules: Rules,
             shape: Optional[Sequence[int]] = None,
             mesh: Optional[Mesh] = None) -> P:
    """Logical axes -> PartitionSpec.  With ``shape``+``mesh``, any mapping
    whose mesh extent does not divide the dimension falls back to replicated
    (e.g. kv=8 heads on a 16-way model axis, odd vocabs)."""
    out = []
    used = set()
    for i, a in enumerate(axes):
        mapped = rules.get(a) if a is not None else None
        if mapped is not None and shape is not None and mesh is not None:
            n = _axis_size(mesh, mapped)
            if n > 1 and (shape[i] % n != 0):
                mapped = None
        # a mesh axis may appear at most once per spec: first dim wins
        # (e.g. caches prefer cache_seq over kv on the model axis —
        # context-parallel decode)
        if mapped is not None:
            names = (mapped,) if isinstance(mapped, str) else tuple(mapped)
            if any(m in used for m in names):
                mapped = None
            else:
                used.update(names)
        out.append(mapped)
    return P(*out)


def param_pspecs(specs_tree, rules: Rules, mesh: Optional[Mesh] = None):
    return jax.tree.map(lambda s: spec_for(s.axes, rules, s.shape, mesh),
                        specs_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def param_shardings(specs_tree, mesh: Mesh, rules: Optional[Rules] = None):
    rules = rules or rules_for(mesh)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, spec_for(s.axes, rules, s.shape, mesh)),
        specs_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


# -- activation sharding constraints (no-op outside a mesh context) ---------

_STATE = threading.local()


@contextlib.contextmanager
def mesh_context(mesh: Optional[Mesh], rules: Optional[Rules] = None):
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = (mesh, rules or rules_for(mesh)) if mesh is not None else None
    try:
        yield
    finally:
        _STATE.ctx = prev


def current_mesh() -> Optional[Mesh]:
    ctx = getattr(_STATE, "ctx", None)
    return ctx[0] if ctx else None


def _axis_size(mesh: Mesh, mapped) -> int:
    if mapped is None:
        return 1
    if isinstance(mapped, str):
        return mesh.shape[mapped]
    out = 1
    for m in mapped:
        out *= mesh.shape[m]
    return out


def constrain(x, *axes: Optional[str]):
    """Apply a sharding constraint by logical axis names (no-op without mesh).
    Axes whose mesh extent does not divide the dimension are dropped to
    replicated rather than padded."""
    ctx = getattr(_STATE, "ctx", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    eff = []
    used = set()
    for i, a in enumerate(axes):
        mapped = rules.get(a) if a is not None else None
        n = _axis_size(mesh, mapped)
        if mapped is None or n <= 1 or x.shape[i] % n != 0 or x.shape[i] < n:
            eff.append(None)
            continue
        names = (mapped,) if isinstance(mapped, str) else tuple(mapped)
        if any(m in used for m in names):
            eff.append(None)
        else:
            used.update(names)
            eff.append(mapped)
    spec = P(*eff)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
