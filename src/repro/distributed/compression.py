"""Int8 error-feedback gradient compression.

Quantize→dequantize each gradient leaf to int8 with a per-leaf scale before
the optimizer; the quantization error is carried in a residual buffer and
added back next step (error feedback keeps SGD/Adam convergence, 1-bit-Adam
style).  On a real fabric the int8 representation is what crosses pod links
(4× fewer bytes on the pure-DP ``pod`` axis); here the quantize/dequantize
pair is the numerics-faithful simulation, applied between gradient
accumulation and the optimizer.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _q(g, ef):
    g32 = g.astype(jnp.float32) + ef
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, g32 - deq


def compress_decompress(grads: Any, ef: Any) -> Tuple[Any, Any]:
    """Returns (dequantized grads, new error-feedback residuals)."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(ef)
    out = [_q(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))
