"""repro — A Layered Aggregate Engine for Analytics Workloads, in JAX.

Public session API (DESIGN.md §9):

    import repro
    db = repro.connect(dataset, config=repro.ExecutionConfig(...))
    out = db.views(queries).run()

Submodules (``repro.core``, ``repro.ml``, ``repro.data``, ``repro.serve``,
…) import independently; the facade loads lazily so ``import repro`` stays
cheap and cycle-free.
"""

_API = ("connect", "Database", "ExecutionConfig", "ViewHandle", "ViewReport")

__all__ = list(_API) + ["obs"]


def __getattr__(name):
    if name in _API:
        from repro import api
        return getattr(api, name)
    if name == "obs":
        import importlib
        return importlib.import_module("repro.obs")
    if name == "EngineDeprecationWarning":
        from repro.core.engine import EngineDeprecationWarning
        return EngineDeprecationWarning
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
