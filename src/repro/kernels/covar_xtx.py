"""Pallas TPU kernel: masked blocked XᵀX accumulation — the covar batch.

The paper's flagship workload (the covar matrix, 814 aggregates for Retailer)
reduces on TPU to ``C = Xᵀ·diag(w)·X`` over the (factorized) feature matrix:
LMFAO's scalar accumulator loops become one systolic-array matmul per row
block (DESIGN.md §2).

Tiling: rows stream HBM→VMEM in ``(bm, F)`` tiles; the ``(F, F)`` fp32
accumulator block is pinned in VMEM across the whole grid (its index_map is
constant), so partial products never round-trip to HBM.  ``bm`` and ``F`` are
padded to MXU-friendly multiples (8×128 lanes) by the ops wrapper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _covar_kernel(x_ref, w_ref, o_ref, acc_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                       # (bm, F)  VMEM tile
    w = w_ref[...]                       # (bm, 1)  row weights / validity
    xw = x * w                           # VPU elementwise
    acc_ref[...] += jnp.dot(xw.T, x, preferred_element_type=jnp.float32)  # MXU

    @pl.when(i == pl.num_programs(0) - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def covar_xtx_pallas(x: jnp.ndarray, w: jnp.ndarray, *, block_rows: int = 512,
                     interpret: bool = False) -> jnp.ndarray:
    """C[f,g] = Σ_n w[n]·x[n,f]·x[n,g].  x: (N, F) f32, w: (N,) f32.

    N must be a multiple of ``block_rows`` (ops.py pads with w=0 rows)."""
    n, f = x.shape
    assert n % block_rows == 0, (n, block_rows)
    grid = (n // block_rows,)
    return pl.pallas_call(
        _covar_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, f), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((f, f), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((f, f), jnp.float32),
        scratch_shapes=[pltpu.VMEM((f, f), jnp.float32)],
        interpret=interpret,
    )(x, w.reshape(n, 1))
