"""Pallas TPU kernel: fused decision-tree node histogram.

The paper's regression-tree-node workload (Table 3 row 3): for a candidate
split attribute with D buckets, compute per-bucket [COUNT, SUM(y), SUM(y²)]
under the node's ancestor-condition mask — eq. (8) extended with a group-by.
Fuses payload construction (cond·[1, y, y²]) with the one-hot scatter matmul
so the row block is read once from VMEM.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _hist_kernel(code_ref, y_ref, cond_ref, o_ref, acc_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    code = code_ref[...]                      # (bm, 1) int32 bucket codes
    y = y_ref[...]                            # (bm, 1)
    cond = cond_ref[...]                      # (bm, 1) node mask in {0,1}
    payload = jnp.concatenate([cond, cond * y, cond * y * y], axis=1)  # (bm, 3)
    d = acc_ref.shape[0]
    onehot = (code == jax.lax.broadcasted_iota(jnp.int32, (1, d), 1)).astype(jnp.float32)
    acc_ref[...] += jnp.dot(onehot.T, payload, preferred_element_type=jnp.float32)

    @pl.when(i == pl.num_programs(0) - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def tree_hist_pallas(codes: jnp.ndarray, y: jnp.ndarray, cond: jnp.ndarray,
                     n_buckets: int, *, block_rows: int = 512,
                     interpret: bool = False) -> jnp.ndarray:
    """out[b] = [Σ cond, Σ cond·y, Σ cond·y²] over rows with codes==b."""
    n = codes.shape[0]
    assert n % block_rows == 0, (n, block_rows)
    return pl.pallas_call(
        _hist_kernel,
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((n_buckets, 3), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_buckets, 3), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n_buckets, 3), jnp.float32)],
        interpret=interpret,
    )(codes.reshape(n, 1).astype(jnp.int32), y.reshape(n, 1), cond.reshape(n, 1))
