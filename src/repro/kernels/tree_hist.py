"""Pallas TPU kernel: fused decision-tree node histogram.

The paper's regression-tree-node workload (Table 3 row 3): for a candidate
split attribute with D buckets, compute per-bucket [COUNT, SUM(y), SUM(y²)]
under the node's ancestor-condition mask — eq. (8) extended with a group-by.
Fuses payload construction (cond·[1, y, y²]) with the one-hot scatter matmul
so the row block is read once from VMEM.

The batched variant evaluates a whole *node frontier* at once: ``cond`` is
``(n, N)`` — one mask column per tree node — and the kernel forms the
``(bm, N·3)`` payload ``cond ⊗ [1, y, y²]`` before a single one-hot matmul,
so the MXU contraction is shared across all ``N`` nodes and the accumulator
(``(D, N·3)`` in VMEM, returned as ``(N, D, 3)``) stays resident across the
row grid (DESIGN.md §7.4).

Arbitrary row counts are handled by padding the row axis with zeroed ``cond``
inside the wrappers here (padded rows contribute nothing), so callers never
need ``n % block_rows == 0``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.padding import pad_rows as _pad_rows


def _hist_kernel(code_ref, y_ref, cond_ref, o_ref, acc_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    code = code_ref[...]                      # (bm, 1) int32 bucket codes
    y = y_ref[...]                            # (bm, 1)
    cond = cond_ref[...]                      # (bm, 1) node mask in {0,1}
    payload = jnp.concatenate([cond, cond * y, cond * y * y], axis=1)  # (bm, 3)
    d = acc_ref.shape[0]
    onehot = (code == jax.lax.broadcasted_iota(jnp.int32, (1, d), 1)).astype(jnp.float32)
    acc_ref[...] += jnp.dot(onehot.T, payload, preferred_element_type=jnp.float32)

    @pl.when(i == pl.num_programs(0) - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def tree_hist_pallas(codes: jnp.ndarray, y: jnp.ndarray, cond: jnp.ndarray,
                     n_buckets: int, *, block_rows: int = 512,
                     interpret: bool = False) -> jnp.ndarray:
    """out[b] = [Σ cond, Σ cond·y, Σ cond·y²] over rows with codes==b.

    Rows are padded to a ``block_rows`` multiple with zeroed ``cond`` (padded
    rows contribute nothing), so any ``n`` works."""
    codes = _pad_rows(codes.astype(jnp.int32), block_rows)
    y = _pad_rows(y, block_rows)
    cond = _pad_rows(cond, block_rows)   # zero-pad: dead rows
    n = codes.shape[0]
    return pl.pallas_call(
        _hist_kernel,
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((n_buckets, 3), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_buckets, 3), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n_buckets, 3), jnp.float32)],
        interpret=interpret,
    )(codes.reshape(n, 1), y.reshape(n, 1), cond.reshape(n, 1))


def _hist_batched_kernel(code_ref, yk_ref, cond_ref, o_ref, acc_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    code = code_ref[...]                      # (bm, 1) int32 bucket codes
    yk = yk_ref[...]                          # (bm, 3) = [1, y, y²]
    cond = cond_ref[...]                      # (bm, N) node masks
    bm, n_nodes = cond.shape
    # payload[r, j*3 + k] = cond[r, j] * yk[r, k]  — the N·3 aggregate columns
    payload = (cond[:, :, None] * yk[:, None, :]).reshape(bm, n_nodes * 3)
    d = acc_ref.shape[0]
    onehot = (code == jax.lax.broadcasted_iota(jnp.int32, (1, d), 1)).astype(jnp.float32)
    acc_ref[...] += jnp.dot(onehot.T, payload, preferred_element_type=jnp.float32)

    @pl.when(i == pl.num_programs(0) - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def tree_hist_batched_pallas(codes: jnp.ndarray, y: jnp.ndarray,
                             cond: jnp.ndarray, n_buckets: int, *,
                             block_rows: int = 512,
                             interpret: bool = False) -> jnp.ndarray:
    """out[j, b] = [Σ cond_j, Σ cond_j·y, Σ cond_j·y²] over rows with
    codes==b, for every node column j of ``cond`` (shape (n, N)).

    One fused pass serves the entire node frontier: the accumulator is kept
    as (D, N·3) in VMEM (MXU-friendly one-hot matmul batched over nodes) and
    reshaped to (N, D, 3) on return."""
    n_nodes = cond.shape[1]
    codes = _pad_rows(codes.astype(jnp.int32), block_rows)
    n = codes.shape[0]
    yp = _pad_rows(y.astype(jnp.float32), block_rows)
    condp = _pad_rows(cond.astype(jnp.float32), block_rows)  # zero: dead rows
    yk = jnp.stack([jnp.ones_like(yp), yp, yp * yp], axis=1)  # (n, 3)
    out = pl.pallas_call(
        _hist_batched_kernel,
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 3), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, n_nodes), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((n_buckets, n_nodes * 3), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_buckets, n_nodes * 3), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n_buckets, n_nodes * 3), jnp.float32)],
        interpret=interpret,
    )(codes.reshape(n, 1), yk, condp)
    return jnp.transpose(out.reshape(n_buckets, n_nodes, 3), (1, 0, 2))
