"""Pallas TPU kernel: whole-step fused scan-block reduction.

One scheduler step may carry several *kernel-level* reductions per row block:
one ``seg_aggregate`` per distinct local group-by key (bucket) plus one
``tree_hist`` per histogram-pattern view.  Launching them separately re-reads
the row block from HBM once per reduction; this kernel fuses the **union of a
step's view buckets** into a single launch — every reduction is a one-hot
matmul against the same VMEM-resident row block, so the block is read once
and the MXU runs back-to-back contractions (DESIGN.md §10).

Inputs are packed by the lowering backend into two arrays:

  * ``codes``  (n, C) int32 — one column per reduction: the flattened
    segment id (bucket reductions) or the histogram bucket code (hist
    reductions);
  * ``fpay``   (n, W) f32  — all float payloads concatenated: bucket view
    payloads, the ``[1, y, y²]`` triples, and hist cond masks.  Static
    :class:`ReduceSpec` offsets say which slice belongs to whom, so the
    kernel never materializes a hist payload in HBM — ``cond ⊗ [1,y,y²]`` is
    formed in VMEM exactly like the dedicated ``tree_hist`` kernel.

Each reduction ``r`` writes its own output ``(n_segments_r, width_r)``.

Two execution strategies (both bit-identical to the unfused kernels):

  * **grid pipeline** (``double_buffer=False``): the standard Pallas row
    grid — the compiler's automatic pipelining streams row blocks;
  * **manual double buffering** (``double_buffer=True``): inputs stay in
    HBM (``memory_space=ANY``) and the kernel drives its own two-slot
    HBM→VMEM DMA pipeline — the copy of block ``i+1`` is started *before*
    the compute on block ``i``, so the MXU contractions overlap the next
    block's loads instead of stalling on them (DESIGN.md §10).

Row counts pad to a ``block_rows`` multiple with zeroed payload/cond (padded
rows contribute nothing — validity is already folded into the payloads by
``lowering/common.view_payload``), so any ``n`` works.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.padding import pad_rows as _pad_rows


@dataclasses.dataclass(frozen=True)
class ReduceSpec:
    """One fused reduction: ``kind`` "seg" sums ``fpay[:, pay_off:pay_off +
    width]`` into ``n_segments`` rows keyed by ``codes[:, code_col]``;
    ``kind`` "hist" builds the payload ``cond ⊗ [1, y, y²]`` in VMEM from
    ``n_cond`` mask columns at ``pay_off`` and the y-triple at ``yk_off``
    (output width is ``n_cond * 3``)."""

    kind: str
    code_col: int
    n_segments: int
    width: int
    pay_off: int
    n_cond: int = 0
    yk_off: int = 0

    def __post_init__(self):
        assert self.kind in ("seg", "hist"), self.kind
        if self.kind == "hist":
            assert self.width == self.n_cond * 3, (self.width, self.n_cond)


def _reduce_block(sp: ReduceSpec, codes, fpay):
    """(bm,)-block contribution of one reduction: (n_segments, width)."""
    bm = codes.shape[0]
    code = codes[:, sp.code_col:sp.code_col + 1]
    if sp.kind == "seg":
        pay = fpay[:, sp.pay_off:sp.pay_off + sp.width]
    else:
        cond = fpay[:, sp.pay_off:sp.pay_off + sp.n_cond]
        yk = fpay[:, sp.yk_off:sp.yk_off + 3]
        # payload[r, j*3 + k] = cond[r, j] * yk[r, k] — formed in VMEM, never
        # written back to HBM (same trick as the dedicated tree_hist kernel)
        pay = (cond[:, :, None] * yk[:, None, :]).reshape(bm, sp.n_cond * 3)
    onehot = (code == jax.lax.broadcasted_iota(
        jnp.int32, (1, sp.n_segments), 1)).astype(jnp.float32)
    return jnp.dot(onehot.T, pay, preferred_element_type=jnp.float32)


def _grid_kernel(specs: Tuple[ReduceSpec, ...]):
    n_r = len(specs)

    def kernel(codes_ref, fpay_ref, *refs):
        o_refs, acc_refs = refs[:n_r], refs[n_r:]
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            for acc in acc_refs:
                acc[...] = jnp.zeros_like(acc)

        codes = codes_ref[...]
        fpay = fpay_ref[...]
        for sp, acc in zip(specs, acc_refs):
            acc[...] += _reduce_block(sp, codes, fpay)

        @pl.when(i == pl.num_programs(0) - 1)
        def _flush():
            for o, acc in zip(o_refs, acc_refs):
                o[...] = acc[...]

    return kernel


def _dbuf_kernel(specs: Tuple[ReduceSpec, ...], block_rows: int,
                 n_blocks: int):
    n_r = len(specs)

    def kernel(codes_hbm, fpay_hbm, *o_refs):
        def body(codes_scr, fpay_scr, code_sem, fpay_sem):
            for o in o_refs:
                o[...] = jnp.zeros_like(o)

            def dmas(slot, blk):
                rows = pl.ds(blk * block_rows, block_rows)
                return (pltpu.make_async_copy(codes_hbm.at[rows],
                                              codes_scr.at[slot],
                                              code_sem.at[slot]),
                        pltpu.make_async_copy(fpay_hbm.at[rows],
                                              fpay_scr.at[slot],
                                              fpay_sem.at[slot]))

            for d in dmas(0, 0):        # warm-up: first block's copies
                d.start()

            def step(blk, _):
                slot = jax.lax.rem(blk, 2)

                @pl.when(blk + 1 < n_blocks)
                def _prefetch():        # overlap: next block's HBM→VMEM copy
                    for d in dmas(jax.lax.rem(blk + 1, 2), blk + 1):
                        d.start()

                for d in dmas(slot, blk):
                    d.wait()
                codes = codes_scr[slot]
                fpay = fpay_scr[slot]
                for sp, o in zip(specs, o_refs):
                    o[...] += _reduce_block(sp, codes, fpay)
                return _

            jax.lax.fori_loop(0, n_blocks, step, None)

        n_codes = codes_hbm.shape[1]
        n_fpay = fpay_hbm.shape[1]
        pl.run_scoped(
            body,
            codes_scr=pltpu.VMEM((2, block_rows, n_codes), jnp.int32),
            fpay_scr=pltpu.VMEM((2, block_rows, n_fpay), jnp.float32),
            code_sem=pltpu.SemaphoreType.DMA((2,)),
            fpay_sem=pltpu.SemaphoreType.DMA((2,)),
        )

    return kernel


def fused_scan_block_pallas(codes: jnp.ndarray, fpay: jnp.ndarray,
                            specs: Tuple[ReduceSpec, ...], *,
                            block_rows: int = 512, interpret: bool = False,
                            double_buffer: bool = True):
    """Run every reduction of ``specs`` over the same row blocks in ONE
    kernel launch; returns a tuple of ``(n_segments_r, width_r)`` arrays
    aligned with ``specs``.  ``codes`` (n, C) int32, ``fpay`` (n, W) f32."""
    assert specs, "fused_scan_block needs at least one reduction"
    assert codes.ndim == 2 and fpay.ndim == 2, (codes.shape, fpay.shape)
    assert codes.shape[0] == fpay.shape[0], (codes.shape, fpay.shape)
    codes = _pad_rows(codes.astype(jnp.int32), block_rows)
    fpay = _pad_rows(fpay.astype(jnp.float32), block_rows)
    n = codes.shape[0]
    n_blocks = n // block_rows
    out_shapes = tuple(jax.ShapeDtypeStruct((sp.n_segments, sp.width),
                                            jnp.float32) for sp in specs)
    if double_buffer:
        return pl.pallas_call(
            _dbuf_kernel(specs, block_rows, n_blocks),
            in_specs=[pl.BlockSpec(memory_space=pltpu.ANY),
                      pl.BlockSpec(memory_space=pltpu.ANY)],
            out_specs=tuple(pl.BlockSpec(memory_space=pltpu.VMEM)
                            for _ in out_shapes),
            out_shape=out_shapes,
            interpret=interpret,
        )(codes, fpay)
    return pl.pallas_call(
        _grid_kernel(specs),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, codes.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, fpay.shape[1]), lambda i: (i, 0)),
        ],
        out_specs=tuple(pl.BlockSpec(s.shape, lambda i: (0, 0))
                        for s in out_shapes),
        out_shape=out_shapes,
        scratch_shapes=[pltpu.VMEM(s.shape, jnp.float32) for s in out_shapes],
        interpret=interpret,
    )(codes, fpay)
