"""Shared row-padding helpers for the Pallas kernel wrappers."""

from __future__ import annotations

import jax.numpy as jnp


def pad_rows(x: jnp.ndarray, m: int) -> jnp.ndarray:
    """Zero-pad axis 0 up to the next multiple of ``m``."""
    n = x.shape[0]
    target = ((n + m - 1) // m) * m
    if target == n:
        return x
    pad = [(0, target - n)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad)


def pad_dim(x: jnp.ndarray, axis: int, m: int) -> jnp.ndarray:
    """Zero-pad ``axis`` up to the next multiple of ``m``."""
    n = x.shape[axis]
    target = ((n + m - 1) // m) * m
    if target == n:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - n)
    return jnp.pad(x, pad)
