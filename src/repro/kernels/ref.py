"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def covar_xtx_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("nf,n,ng->fg", x.astype(jnp.float32), w.astype(jnp.float32),
                      x.astype(jnp.float32))


def seg_aggregate_ref(seg: jnp.ndarray, payload: jnp.ndarray, n_segments: int) -> jnp.ndarray:
    # out-of-range segment ids must contribute nowhere (padding convention)
    ok = (seg >= 0) & (seg < n_segments)
    pay = payload * ok[:, None].astype(payload.dtype)
    sid = jnp.where(ok, seg, 0)
    return jax.ops.segment_sum(pay, sid, num_segments=n_segments)


def tree_hist_ref(codes: jnp.ndarray, y: jnp.ndarray, cond: jnp.ndarray,
                  n_buckets: int) -> jnp.ndarray:
    payload = jnp.stack([cond, cond * y, cond * y * y], axis=1)
    return seg_aggregate_ref(codes, payload, n_buckets)


def tree_hist_batched_ref(codes: jnp.ndarray, y: jnp.ndarray,
                          cond: jnp.ndarray, n_buckets: int) -> jnp.ndarray:
    """cond (n, N) node-mask columns -> (N, n_buckets, 3)."""
    return jnp.stack([tree_hist_ref(codes, y, cond[:, j], n_buckets)
                      for j in range(cond.shape[1])])


def fused_scan_block_ref(codes: jnp.ndarray, fpay: jnp.ndarray, specs):
    """Oracle for the whole-step fused kernel: each :class:`ReduceSpec` is
    just a seg-sum of its payload slice (hist payloads formed as cond⊗yk)."""
    outs = []
    for sp in specs:
        code = codes[:, sp.code_col]
        if sp.kind == "seg":
            pay = fpay[:, sp.pay_off:sp.pay_off + sp.width]
        else:
            cond = fpay[:, sp.pay_off:sp.pay_off + sp.n_cond]
            yk = fpay[:, sp.yk_off:sp.yk_off + 3]
            pay = (cond[:, :, None] * yk[:, None, :]).reshape(
                codes.shape[0], sp.n_cond * 3)
        outs.append(seg_aggregate_ref(code, pay, sp.n_segments))
    return tuple(outs)


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int = 0) -> jnp.ndarray:
    """Dense reference attention with GQA, causal and sliding-window masks."""
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = h // hkv
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kr).astype(jnp.float32) / (d ** 0.5)
    rows = jnp.arange(sq)[:, None]
    cols = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask = mask & (cols <= rows)
    if window > 0:
        mask = mask & (cols > rows - window)
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vr).astype(q.dtype)
