"""Pallas TPU kernel: blockwise (flash) attention with online softmax.

Used by the LM-zoo side of the framework for prefill: O(S²) attention without
materializing the (S, S) score matrix.  Supports causal masking, sliding
windows (SWA archs), and GQA via an index_map that folds the query-head →
kv-head mapping into the BlockSpec (no KV replication in HBM).

Grid: (batch, q_heads, q_blocks, kv_blocks).  Running max / normalizer /
accumulator live in VMEM scratch pinned across the kv_blocks axis; the output
block is written once on the final kv step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, bq: int, bk: int,
                  kv_len: int):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                                  # (bq, d)
    k = k_ref[0, 0]                                  # (bk, d)
    v = v_ref[0, 0]                                  # (bk, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, bk)

    rows = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = cols < kv_len                             # padded key tail
    if causal:
        mask = jnp.logical_and(mask, cols <= rows)
    if window > 0:
        mask = jnp.logical_and(mask, cols > rows - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                              # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == pl.num_programs(3) - 1)
    def _flush():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True, window: int = 0,
                           kv_len: int = 0, block_q: int = 128,
                           block_k: int = 128, interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, S, D); k, v: (B, Hkv, S, D) with H % Hkv == 0.

    S must divide into block_q/block_k tiles (ops.py pads + re-slices);
    ``kv_len`` is the valid (unpadded) key length."""
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert h % hkv == 0
    group = h // hkv
    assert sq % block_q == 0 and sk % block_k == 0
    scale = 1.0 / (d ** 0.5)
    grid = (b, h, sq // block_q, sk // block_k)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, bq=block_q, bk=block_k,
                               kv_len=kv_len or sk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, iq, ik, g=group: (ib, ih // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, iq, ik, g=group: (ib, ih // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
