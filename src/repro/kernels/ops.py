"""Jit'd public wrappers around the Pallas kernels.

Handles padding to MXU-aligned tile multiples, dtype management, and the
``interpret`` switch (True on CPU — the kernel body executes in Python for
validation; False on real TPU).  Every wrapper has a matching oracle in
``ref.py``; tests sweep shapes/dtypes asserting allclose.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.covar_xtx import covar_xtx_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fused_scan import ReduceSpec, fused_scan_block_pallas
from repro.kernels.padding import pad_dim as _pad_dim
from repro.kernels.padding import pad_rows as _pad_rows
from repro.kernels.seg_aggregate import seg_aggregate_pallas
from repro.kernels.tree_hist import tree_hist_batched_pallas, tree_hist_pallas

__all__ = ["covar_xtx", "seg_aggregate", "tree_hist", "tree_hist_batched",
           "fused_scan_block", "flash_attention", "ReduceSpec"]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret", "feature_align"))
def covar_xtx(x: jnp.ndarray, w: Optional[jnp.ndarray] = None, *,
              block_rows: int = 512, interpret: bool = False,
              feature_align: int = 8) -> jnp.ndarray:
    """C = Xᵀ diag(w) X with row/feature padding; returns (F, F) unpadded."""
    n, f = x.shape
    if w is None:
        w = jnp.ones((n,), jnp.float32)
    x = _pad_dim(x.astype(jnp.float32), 1, feature_align)
    xp = _pad_rows(x, block_rows)
    wp = _pad_rows(w.astype(jnp.float32), block_rows)  # pad weight = 0
    c = covar_xtx_pallas(xp, wp, block_rows=block_rows, interpret=interpret)
    return c[:f, :f]


@functools.partial(jax.jit, static_argnames=("n_segments", "block_rows", "interpret"))
def seg_aggregate(seg: jnp.ndarray, payload: jnp.ndarray, n_segments: int, *,
                  block_rows: int = 512, interpret: bool = False) -> jnp.ndarray:
    """Segment-sum payload rows into n_segments (padding rows -> id n_segments,
    accumulated into a sacrificial extra row then dropped)."""
    n, a = payload.shape
    segp = _pad_rows(seg.astype(jnp.int32), block_rows)
    pad = segp.shape[0] - n
    if pad:
        segp = segp.at[n:].set(n_segments)
    payp = _pad_rows(payload.astype(jnp.float32), block_rows)
    out = seg_aggregate_pallas(segp, payp, n_segments + 1,
                               block_rows=block_rows, interpret=interpret)
    return out[:n_segments]


@functools.partial(jax.jit, static_argnames=("n_buckets", "block_rows", "interpret"))
def tree_hist(codes: jnp.ndarray, y: jnp.ndarray, cond: jnp.ndarray,
              n_buckets: int, *, block_rows: int = 512,
              interpret: bool = False) -> jnp.ndarray:
    """Per-bucket [count, Σy, Σy²] under the node mask."""
    n = codes.shape[0]
    codesp = _pad_rows(codes.astype(jnp.int32), block_rows)
    pad = codesp.shape[0] - n
    if pad:
        codesp = codesp.at[n:].set(n_buckets)  # out-of-range -> sacrificial row
    yp = _pad_rows(y.astype(jnp.float32), block_rows)
    condp = _pad_rows(cond.astype(jnp.float32), block_rows)
    out = tree_hist_pallas(codesp, yp, condp, n_buckets + 1,
                           block_rows=block_rows, interpret=interpret)
    return out[:n_buckets]


@functools.partial(jax.jit, static_argnames=("n_buckets", "block_rows", "interpret"))
def tree_hist_batched(codes: jnp.ndarray, y: jnp.ndarray, cond: jnp.ndarray,
                      n_buckets: int, *, block_rows: int = 512,
                      interpret: bool = False) -> jnp.ndarray:
    """Per-node, per-bucket [count, Σy, Σy²]: ``cond`` is (n, N) — one mask
    column per frontier node — and the result is (N, n_buckets, 3), computed
    in one fused kernel pass over the rows (DESIGN.md §7.4).  No sacrificial
    bucket: the kernel zero-pads ``cond``, so padded rows contribute nothing
    wherever their codes land."""
    return tree_hist_batched_pallas(codes.astype(jnp.int32),
                                    y.astype(jnp.float32),
                                    cond.astype(jnp.float32), n_buckets,
                                    block_rows=block_rows, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("specs", "block_rows",
                                             "interpret", "double_buffer"))
def fused_scan_block(codes: jnp.ndarray, fpay: jnp.ndarray,
                     specs, *, block_rows: int = 512,
                     interpret: bool = False, double_buffer: bool = True):
    """Whole-step fused reduction: every bucket/hist reduction of a scan
    step in ONE kernel launch over the shared row block (DESIGN.md §10).
    ``specs`` is a (hashable) tuple of :class:`ReduceSpec`; returns a tuple
    of ``(n_segments, width)`` arrays aligned with it.  Rows pad with zeroed
    payload (validity is pre-folded into the payloads), so any ``n`` works;
    ``double_buffer`` selects the manual two-slot HBM→VMEM DMA pipeline."""
    return fused_scan_block_pallas(codes.astype(jnp.int32),
                                   fpay.astype(jnp.float32), tuple(specs),
                                   block_rows=block_rows, interpret=interpret,
                                   double_buffer=double_buffer)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """Blockwise attention; pads sequence dims to tile multiples.  Padded
    query rows produce garbage sliced away below; padded key columns are
    excluded inside the kernel via the ``kv_len`` mask."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    qp = _pad_dim(q, 2, block_q)
    kp = _pad_dim(k, 2, block_k)
    vp = _pad_dim(v, 2, block_k)
    out = flash_attention_pallas(qp, kp, vp, causal=causal, window=window,
                                 kv_len=sk, block_q=block_q, block_k=block_k,
                                 interpret=interpret)
    return out[:, :, :sq, :]
