"""Pallas TPU kernel: multi-aggregate segment reduction — the MOO scan.

One pass over a relation block computes *all* aggregate columns of a view
group keyed by a (flattened) group-by code: the TPU-native form of LMFAO's
multi-output trie scan.  The scatter-accumulate is expressed as a one-hot
matmul ``onehot(seg)ᵀ @ payload`` so it runs on the MXU instead of a serial
scatter; the dense ``(S, A)`` view accumulator is pinned in VMEM across the
grid (views are small relative to fact tables — paper Table 2 — so they fit).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.padding import pad_rows as _pad_rows


def _seg_kernel(seg_ref, pay_ref, o_ref, acc_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    seg = seg_ref[...]                             # (bm, 1) int32
    pay = pay_ref[...]                             # (bm, A)
    s = acc_ref.shape[0]
    onehot = (seg == jax.lax.broadcasted_iota(jnp.int32, (1, s), 1)).astype(jnp.float32)
    acc_ref[...] += jnp.dot(onehot.T, pay, preferred_element_type=jnp.float32)

    @pl.when(i == pl.num_programs(0) - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def seg_aggregate_pallas(seg: jnp.ndarray, payload: jnp.ndarray, n_segments: int,
                         *, block_rows: int = 512, interpret: bool = False) -> jnp.ndarray:
    """out[s, a] = Σ_{n: seg[n]=s} payload[n, a].

    seg: (N,) int32 in [0, n_segments) (out-of-range rows contribute nowhere —
    the ops wrapper uses seg = n_segments for padding); payload: (N, A) f32.
    Rows are padded to a ``block_rows`` multiple with zeroed payload (padded
    rows land in segment 0 but contribute 0), so any N works."""
    assert seg.shape == (payload.shape[0],)
    seg = _pad_rows(seg.astype(jnp.int32), block_rows)
    payload = _pad_rows(payload, block_rows)
    n, a = payload.shape
    return pl.pallas_call(
        _seg_kernel,
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, a), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((n_segments, a), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_segments, a), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n_segments, a), jnp.float32)],
        interpret=interpret,
    )(seg.reshape(n, 1).astype(jnp.int32), payload)
