"""Pallas TPU kernels for the compute hot-spots (DESIGN.md §2).

covar_xtx       masked blocked XtX (the covar-matrix batch on the MXU)
seg_aggregate   multi-aggregate segment reduction (the MOO scan)
tree_hist       fused decision-tree node histogram (RT-node workload)
flash_attention blockwise online-softmax attention (LM-zoo prefill)

Each kernel: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit wrappers,
padding, interpret switch), ref.py (pure-jnp oracles).
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
