"""launch substrate."""
