"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b --smoke \
        --steps 200 --ckpt-dir /tmp/ckpt

``--smoke`` uses the arch's reduced config (CPU-runnable ~100M-and-below);
without it the exact assigned config is used (real hardware).  The loop is
fault-tolerant: kill it at any step and rerun the same command — it resumes
from the latest complete checkpoint with an identical trajectory.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.launch.mesh import make_host_mesh
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.step import TrainConfig, init_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--schedule", default=None)
    ap.add_argument("--d-model", type=int, default=0, help="override width")
    ap.add_argument("--layers", type=int, default=0, help="override depth")
    ap.add_argument("--vocab", type=int, default=0, help="override vocab")
    ap.add_argument("--heads", type=int, default=0, help="override heads")
    ap.add_argument("--d-ff", type=int, default=0, help="override ffn width")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    if args.d_model:
        cfg = cfg.with_(d_model=args.d_model)
    if args.layers:
        cfg = cfg.with_(n_layers=args.layers)
    if args.vocab:
        cfg = cfg.with_(vocab=args.vocab)
    if args.heads:
        cfg = cfg.with_(n_heads=args.heads, n_kv=min(cfg.n_kv, args.heads))
    if args.d_ff:
        cfg = cfg.with_(d_ff=args.d_ff)
    schedule = args.schedule or ("wsd" if args.arch == "minicpm-2b" else "cosine")
    tcfg = TrainConfig(peak_lr=args.lr, warmup=max(args.steps // 20, 5),
                       total_steps=args.steps, schedule=schedule,
                       ce_chunk=min(128, args.seq), attn_impl="dense",
                       compress_grads=args.compress_grads)

    pipe = TokenPipeline(PipelineConfig(args.batch, args.seq, cfg.vocab,
                                        seed=args.seed), cfg)
    state = init_state(cfg, tcfg, jax.random.PRNGKey(args.seed))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(state["params"]))
    print(f"[train] arch={cfg.name} params={n_params:,} schedule={schedule}")

    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    loop = TrainLoop(step_fn, pipe,
                     LoopConfig(max_steps=args.steps, ckpt_every=args.ckpt_every,
                                ckpt_dir=args.ckpt_dir, log_every=10))
    t0 = time.time()
    state = loop.run(state)
    losses = loop.losses()
    if losses:
        print(f"[train] done in {time.time() - t0:.1f}s; "
              f"loss {losses[0]:.4f} -> {losses[-1]:.4f}; "
              f"stragglers={loop.straggler_events}")
    return loop


if __name__ == "__main__":
    main()
