"""Production mesh construction (a function — importing this module never
touches jax device state)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU demos)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
