import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax -------------------------------------
"""Multi-pod dry-run: lower + compile every (architecture × input shape) cell
on the production meshes, record memory/cost analysis and the collective
schedule for the roofline (EXPERIMENTS.md §Dry-run / §Roofline).

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out reports/dryrun

Each cell: jit(step).lower(...).compile() on the 16×16 single-pod mesh and
the (2,16,16) multi-pod mesh.  Failures (sharding mismatch, OOM at compile,
unsupported collective) are bugs in the system, per the brief.
"""

import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.shapes import SHAPES, InputShape, applicable
from repro.distributed.sharding import param_pspecs, rules_for, spec_for
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.models.layers import abstract_params
from repro.serve.engine import make_serve_step
from repro.train.step import (TrainConfig, abstract_state, batch_pspecs,
                              make_prefill_step, make_train_step, state_pspecs)

# per-(family) grad-accum so microbatch activations fit HBM (DESIGN.md §6)
GRAD_ACCUM = {"ssm": 4, "hybrid": 4, "moe": 2, "vlm": 2, "dense": 2, "audio": 1}


def train_cfg_for(cfg: ModelConfig, shape: InputShape) -> TrainConfig:
    ga = GRAD_ACCUM.get(cfg.family, 1)
    # keep microbatch >= 1 per data shard
    while shape.global_batch // ga < 32 and ga > 1:
        ga //= 2
    return TrainConfig(ce_chunk=256, grad_accum=ga, attn_impl="chunked")


def input_specs(cfg: ModelConfig, shape: InputShape, mesh) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    rules = rules_for(mesh)
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.family == "vlm":
            batch["vision"] = jax.ShapeDtypeStruct((b, cfg.vision_tokens, cfg.d_model),
                                                   jnp.float32)
        if cfg.family == "audio":
            batch["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_frames, cfg.d_model),
                                                   jnp.float32)
        return batch
    # decode: one new token against a cache of seq_len
    out = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
           "pos": jax.ShapeDtypeStruct((), jnp.int32),
           "cache": jax.tree.map(
               lambda sp: jax.ShapeDtypeStruct(sp.shape, cfg.jdtype),
               M.cache_specs(cfg, b, s),
               is_leaf=lambda x: hasattr(x, "axes"))}
    if cfg.family == "vlm":
        out["context"] = jax.ShapeDtypeStruct((b, cfg.vision_tokens, cfg.d_model),
                                              jnp.float32)
    if cfg.family == "audio":
        out["context"] = jax.ShapeDtypeStruct((b, cfg.encoder_frames, cfg.d_model),
                                              jnp.float32)
    return out


def collective_bytes_from_hlo(hlo: str) -> Dict[str, float]:
    """Per-device bytes crossing links, by collective type, from optimized HLO.

    Model (ring algorithms, (n-1)/n ≈ 1): all-reduce 2×operand; all-gather
    result; reduce-scatter operand; all-to-all operand; collective-permute
    operand."""
    dt_bytes = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f8e4m3": 1,
                "f8e5m2": 1, "s16": 2, "u16": 2}

    def shape_bytes(stext: str) -> float:
        total = 0.0
        for m in re.finditer(r"(\w+)\[([\d,]*)\]", stext):
            dt, dims = m.group(1), m.group(2)
            if dt not in dt_bytes:
                continue
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            total += n * dt_bytes[dt]
        return total

    out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    pat = re.compile(
        r"=\s*((?:\w+\[[\d,]*\]|\(.*?\)))\s+"
        r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
        r"(?:-start|-done)?\(([^)]*)\)")
    seen_done = set()
    for line in hlo.splitlines():
        m = pat.search(line)
        if not m:
            continue
        result_s, kind, operands = m.group(1), m.group(2), m.group(3)
        if "-done(" in line:   # avoid double counting start/done pairs
            continue
        rb = shape_bytes(result_s)
        ob = shape_bytes(operands)
        if kind == "all-reduce":
            out[kind] += 2 * (ob or rb)
        elif kind == "all-gather":
            out[kind] += rb or ob
        else:
            out[kind] += ob or rb
    out["total"] = sum(out.values())
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               compile_: bool = True) -> Dict[str, Any]:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped", "why": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(mesh)
    tcfg = train_cfg_for(cfg, shape)
    t0 = time.time()

    if shape.kind == "train" and shape.name not in ("prefill_32k",):
        step = make_train_step(cfg, tcfg, mesh)
        state_sds = abstract_state(cfg, tcfg)
        sspec = state_pspecs(cfg, tcfg, mesh)
        bspec = batch_pspecs(cfg, mesh)
        batch_sds = input_specs(cfg, shape, mesh)
        jitted = jax.jit(step,
                         in_shardings=(jax.tree.map(lambda s: NamedSharding(mesh, s), sspec),
                                       jax.tree.map(lambda s: NamedSharding(mesh, s), bspec)),
                         donate_argnums=(0,))
        lowered = jitted.lower(state_sds, batch_sds)
    elif shape.name == "prefill_32k":
        step = make_prefill_step(cfg, tcfg, mesh)
        pspec = param_pspecs(M.model_specs(cfg), rules, mesh)
        bspec = batch_pspecs(cfg, mesh)
        params_sds = abstract_params(M.model_specs(cfg), cfg.jdtype)
        batch_sds = input_specs(cfg, shape, mesh)
        jitted = jax.jit(step,
                         in_shardings=(jax.tree.map(lambda s: NamedSharding(mesh, s), pspec),
                                       jax.tree.map(lambda s: NamedSharding(mesh, s), bspec)))
        lowered = jitted.lower(params_sds, batch_sds)
    else:  # decode
        step = make_serve_step(cfg, mesh)
        pspec = param_pspecs(M.model_specs(cfg), rules, mesh)
        cspec = param_pspecs(M.cache_specs(cfg, shape.global_batch, shape.seq_len),
                             rules, mesh)
        params_sds = abstract_params(M.model_specs(cfg), cfg.jdtype)
        ins = input_specs(cfg, shape, mesh)
        tok_spec = spec_for(("batch", None), rules, ins["tokens"].shape, mesh)
        ctx = ins.get("context")
        ctx_spec = (spec_for(("batch", None, None), rules, ctx.shape, mesh)
                    if ctx is not None else None)
        shardify = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t)
        in_sh = (shardify(pspec), shardify(cspec), NamedSharding(mesh, tok_spec),
                 NamedSharding(mesh, P()))
        args = (params_sds, ins["cache"], ins["tokens"], ins["pos"])
        if ctx is not None:
            in_sh = in_sh + (NamedSharding(mesh, ctx_spec),)
            args = args + (ctx,)
        jitted = jax.jit(step, in_shardings=in_sh, donate_argnums=(1,))
        lowered = jitted.lower(*args)

    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "n_devices": 512 if multi_pod else 256,
        "status": "lowered", "lower_s": round(time.time() - t0, 2),
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
        "kind": shape.kind, "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
    }
    if not compile_:
        return rec

    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)
    rec["status"] = "compiled"

    mem = compiled.memory_analysis()
    try:
        rec["memory"] = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
        }
    except Exception:
        rec["memory"] = str(mem)

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    rec["cost"] = {k: float(v) for k, v in dict(cost or {}).items()
                   if isinstance(v, (int, float)) and (
                       "flops" in k or "bytes" in k or "utilization" not in k)}
    hlo = compiled.as_text()
    rec["collectives"] = collective_bytes_from_hlo(hlo)
    rec["hlo_lines"] = hlo.count("\n")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--no-compile", action="store_true")
    args = ap.parse_args(argv)

    archs = configs.ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                try:
                    rec = lower_cell(arch, shape, mp, compile_=not args.no_compile)
                except Exception as e:  # a failure here is a bug in the system
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "status": "FAILED", "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                    failures += 1
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                extra = ""
                if status == "compiled":
                    extra = (f" flops/dev={rec['cost'].get('flops', 0):.3e}"
                             f" coll={rec['collectives']['total']:.3e}B"
                             f" compile={rec['compile_s']}s")
                print(f"[dryrun] {tag}: {status}{extra}", flush=True)
    if failures:
        print(f"[dryrun] {failures} FAILURES", flush=True)
        sys.exit(1)


if __name__ == "__main__":
    main()
