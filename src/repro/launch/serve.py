"""Serving driver: batched greedy decoding with a reduced config.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --batch 4 --prompt-len 8 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import model as M
from repro.models.layers import init_params
from repro.serve.engine import BatchedServer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch)
    params = init_params(M.model_specs(cfg), jax.random.PRNGKey(args.seed),
                         cfg.jdtype)
    rng = np.random.default_rng(args.seed)
    context = None
    if cfg.family == "vlm":
        context = 0.02 * rng.standard_normal(
            (args.batch, cfg.vision_tokens, cfg.d_model)).astype(np.float32)
    if cfg.family == "audio":
        context = 0.02 * rng.standard_normal(
            (args.batch, cfg.encoder_frames, cfg.d_model)).astype(np.float32)

    server = BatchedServer(cfg, params, max_len=args.prompt_len + args.gen,
                           batch=args.batch,
                           context=None if context is None else jax.numpy.asarray(context))
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    out = server.generate(prompts, args.gen)
    dt = time.time() - t0
    tps = args.batch * args.gen / dt
    print(f"[serve] arch={cfg.name} generated {out.shape} in {dt:.2f}s "
          f"({tps:.1f} tok/s batched greedy)")
    print("[serve] sample:", out[0].tolist())
    return out


if __name__ == "__main__":
    main()
