"""In-database ML applications over the LMFAO engine (paper §2)."""

from repro.ml.chowliu import ChowLiuResult, chow_liu
from repro.ml.covar import CovarLayout, assemble_covar, compute_covar, covar_queries
from repro.ml.cubes import StreamingCube, cube_queries, cube_rollup, cube_via_engine
from repro.ml.forest import GradientBoostedTrees, RandomForest
from repro.ml.online import OnlineRidge
from repro.ml.polyreg import compute_poly_covar, fit_polyreg, predict_poly
from repro.ml.ridge import RidgeResult, bgd, closed_form, rmse
from repro.ml.trees import DecisionTree

__all__ = ["ChowLiuResult", "chow_liu", "CovarLayout", "assemble_covar",
           "compute_covar", "covar_queries", "StreamingCube", "cube_queries",
           "cube_rollup", "cube_via_engine", "compute_poly_covar",
           "fit_polyreg", "predict_poly", "OnlineRidge", "RidgeResult", "bgd",
           "closed_form", "rmse", "DecisionTree", "RandomForest",
           "GradientBoostedTrees"]
