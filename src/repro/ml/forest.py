"""Tree ensembles over the frontier-batched aggregate engine (DESIGN.md §7.4).

Both workloads here only become feasible with the param-batch (node) axis:

* :class:`RandomForest` — bagged CART trees diversified by per-tree *feature
  masks* (each tree may only split on a random feature subset).  All trees
  share ONE compiled aggregate batch, and fitting is level-synchronous across
  the whole ensemble: the union of every tree's current frontier is evaluated
  in a single ``CompiledBatch.run_batched`` dispatch per forest level, so a
  16-tree forest costs the same number of relation scans per level as one
  tree.

* :class:`GradientBoostedTrees` — squared-loss gradient boosting with
  *in-engine residual relabeling* (the AC/DC idea, arXiv 1803.07480): the
  residual r = y − base − Σ_ℓ v_ℓ·leafmask_ℓ never materializes as a column.
  Because node conditions and leaf regions are both mask *products*
  Π_a mask[x_a], SUM(r·cond_node) decomposes into SUM(y·cond_node) minus a
  combination of COUNT aggregates under *composed* masks (node ∧ leaf =
  elementwise mask product) — all evaluated as extra entries on the node
  axis of the same compiled batch.  Split scoring uses the first-order
  (gradient-sum) criterion gain = G_L²/n_L + G_R²/n_R − G²/n, standard for
  squared-loss GBMs, so only COUNT and SUM(y) histograms are needed.

Both ensembles are deterministic under a fixed ``seed``.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.api import Database, ExecutionConfig
from repro.data.datasets import Dataset
from repro.ml.trees import (DecisionTree, TreeNode, build_tree_batch,
                            build_tree_features, child_masks, predict_nodes,
                            stack_mask_params)


class RandomForest:
    """Feature-bagged CART forest, level-synchronous over one shared batch.

    ``feature_fraction`` of the split features (at least one) is sampled per
    tree with ``np.random.default_rng(seed)``; tree growth itself is
    deterministic, so the whole ensemble is reproducible from ``seed``.
    """

    def __init__(self, ds: Dataset, n_trees: int = 8, task: str = "regression",
                 label: Optional[str] = None,
                 split_attrs: Optional[Sequence[str]] = None,
                 max_depth: int = 4, min_instances: int = 1000,
                 max_nodes: int = 31, feature_fraction: float = 0.6,
                 seed: int = 0, block_size: int = 4096,
                 multi_root: bool = True, backend: str = "xla",
                 interpret: Optional[bool] = None,
                 config: Optional[ExecutionConfig] = None,
                 database: Optional[Database] = None):
        if n_trees < 1:
            raise ValueError("n_trees must be >= 1")
        self.ds = ds
        self.task = task
        self.label = label or (ds.label if task == "regression" else None)
        if self.label is None:
            raise ValueError(
                "no label: classification needs an explicit categorical label; "
                "regression needs label= or a dataset with a default label")
        self.n_trees = n_trees
        self.seed = seed

        self.features = build_tree_features(
            ds, self.label if task == "classification" else None, split_attrs)
        n_classes = ds.schema.domain(self.label) if task == "classification" else 0
        self.view, _ = build_tree_batch(
            ds, self.features, task, self.label, n_classes, node_batch=True,
            block_size=block_size, multi_root=multi_root, backend=backend,
            interpret=interpret, config=config, database=database)
        self.batch = self.view.compiled

        rng = np.random.default_rng(seed)
        k = max(1, int(round(feature_fraction * len(self.features))))
        attrs = [f.attr for f in self.features]
        self.trees: List[DecisionTree] = []
        for _ in range(n_trees):
            subset = list(rng.choice(attrs, size=k, replace=False))
            self.trees.append(DecisionTree(
                ds, task=task, label=self.label,
                split_attrs=[f.attr for f in self.features],
                max_depth=max_depth, min_instances=min_instances,
                max_nodes=max_nodes, node_batch=True,
                allowed_attrs=subset, batch=self.view))

    def fit(self) -> "RandomForest":
        """Grow every tree level-synchronously: one fused dispatch evaluates
        the union of all trees' frontiers per forest level."""
        for t in self.trees:
            t.init_fit()
        while any(t.growing for t in self.trees):
            spans: List[Tuple[DecisionTree, int]] = []
            mask_list: List[Dict[str, np.ndarray]] = []
            for t in self.trees:
                ms = t.frontier_masks() if t.growing else []
                spans.append((t, len(ms)))
                mask_list += ms
            params = stack_mask_params(self.features, mask_list)
            outputs = self.view.run_batched(params)
            stats = {f.attr: np.asarray(outputs[f"split_{f.attr}"], np.float64)
                     for f in self.features}
            o = 0
            for t, k in spans:
                if k:
                    t.advance({a: s[o:o + k] for a, s in stats.items()})
                    o += k
        return self

    def predict(self, rows: Dict[str, np.ndarray]) -> np.ndarray:
        preds = np.stack([t.predict(rows) for t in self.trees])
        if self.task == "regression":
            return preds.mean(axis=0)
        # majority vote over class codes
        votes = preds.astype(np.int64)
        n_classes = int(votes.max()) + 1
        counts = np.zeros((votes.shape[1], n_classes), dtype=np.int64)
        for t in range(votes.shape[0]):
            np.add.at(counts, (np.arange(votes.shape[1]), votes[t]), 1)
        return counts.argmax(axis=1).astype(np.float64)


class GradientBoostedTrees:
    """Squared-loss gradient boosting, residual-relabeled inside the engine.

    Each round grows a regression tree on the residual
    r = y − base − Σ_ℓ v_ℓ·1[x ∈ region_ℓ] using only COUNT/SUM(y)
    histograms of the shared compiled batch: residual sums are reconstructed
    from counts under composed (node ∧ leaf) masks riding the same node
    axis, so a frontier of F nodes against L prior leaves is one
    ``run_batched`` dispatch with N = F·(1+L) entries — never a second scan.
    """

    def __init__(self, ds: Dataset, n_rounds: int = 4,
                 learning_rate: float = 0.3,
                 split_attrs: Optional[Sequence[str]] = None,
                 max_depth: int = 3, min_instances: int = 1000,
                 max_nodes: int = 15, block_size: int = 4096,
                 multi_root: bool = True, backend: str = "xla",
                 interpret: Optional[bool] = None,
                 config: Optional[ExecutionConfig] = None,
                 database: Optional[Database] = None):
        self.ds = ds
        self.label = ds.label
        self.n_rounds = n_rounds
        self.lr = learning_rate
        self.max_depth = max_depth
        self.min_instances = min_instances
        self.max_nodes = max_nodes

        self.features = build_tree_features(ds, None, split_attrs)
        self.view, _ = build_tree_batch(
            ds, self.features, "regression", self.label, 0, node_batch=True,
            block_size=block_size, multi_root=multi_root, backend=backend,
            interpret=interpret, config=config, database=database)
        self.batch = self.view.compiled

        self.base: float = 0.0
        self.trees: List[List[TreeNode]] = []
        self._leaves: List[Tuple[Dict[str, np.ndarray], float]] = []
        self._base_set = False

    # -- fitting --------------------------------------------------------------

    def fit(self) -> "GradientBoostedTrees":
        self.trees = []
        self._leaves = []
        self._base_set = False
        for _ in range(self.n_rounds):
            nodes = self._grow_round()
            self.trees.append(nodes)
            for nd in nodes:
                if nd.is_leaf:
                    self._leaves.append((nd.masks, self.lr * nd.prediction))
        return self

    def _residual_hists(self, frontier_masks: List[Dict[str, np.ndarray]]):
        """One dispatch for the whole frontier × prior-leaf grid; returns per
        frontier node, per feature: (count hist, residual-sum hist)."""
        F, L = len(frontier_masks), len(self._leaves)
        mask_list = list(frontier_masks)
        for m in frontier_masks:
            for lmask, _ in self._leaves:
                mask_list.append({a: m[a] * lmask[a] for a in m})
        params = stack_mask_params(self.features, mask_list)
        outputs = self.view.run_batched(params)
        stats = {f.attr: np.asarray(outputs[f"split_{f.attr}"], np.float64)
                 for f in self.features}
        if not self._base_set:
            tot = stats[self.features[0].attr][0].sum(axis=0)
            self.base = float(tot[1] / max(tot[0], 1e-9))
            self._base_set = True
        hists = []
        for i in range(F):
            per_feat = {}
            for f in self.features:
                cnt = stats[f.attr][i, :, 0]
                sr = stats[f.attr][i, :, 1] - self.base * cnt
                for j, (_, val) in enumerate(self._leaves):
                    sr = sr - val * stats[f.attr][F + i * L + j, :, 0]
                per_feat[f.attr] = (cnt, sr)
            hists.append(per_feat)
        return hists

    def _best_split(self, hist) -> Optional[Tuple[str, str, int, float]]:
        """First-order gain G_L²/n_L + G_R²/n_R − G²/n over all features."""
        best = None
        for f in self.features:
            cnt, sr = hist[f.attr]
            n_tot, g_tot = cnt.sum(), sr.sum()
            if n_tot < 2 * self.min_instances:
                continue
            if f.kind == "ordered":
                nl, gl = np.cumsum(cnt)[:-1], np.cumsum(sr)[:-1]
            else:
                nl, gl = cnt, sr
            nr, gr = n_tot - nl, g_tot - gl
            ok = (nl >= self.min_instances) & (nr >= self.min_instances)
            gain = np.where(
                ok,
                gl ** 2 / np.maximum(nl, 1e-9) + gr ** 2 / np.maximum(nr, 1e-9)
                - g_tot ** 2 / max(n_tot, 1e-9),
                -np.inf)
            if gain.size and np.max(gain) > -np.inf:
                t = int(np.argmax(gain))
                cand = (f.attr, f.kind, t, float(gain[t]))
                if best is None or cand[3] > best[3]:
                    best = cand
        return best

    def _grow_round(self) -> List[TreeNode]:
        root_masks = {f.attr: np.ones(f.domain, dtype=np.float32)
                      for f in self.features}
        nodes = [TreeNode(0, 0, root_masks)]
        frontier = [0]
        while frontier:
            hists = self._residual_hists([nodes[i].masks for i in frontier])
            next_frontier = []
            for hist, nid in zip(hists, frontier):
                node = nodes[nid]
                cnt, sr = hist[self.features[0].attr]
                n_tot, g_tot = cnt.sum(), sr.sum()
                node.n = float(n_tot)
                node.prediction = float(g_tot / max(n_tot, 1e-9))  # mean residual
                if node.depth >= self.max_depth:
                    continue
                best = self._best_split(hist)
                if best is None:
                    continue
                feat, kind, thr, gain = best
                if gain <= 1e-9 or len(nodes) + 2 > self.max_nodes:
                    continue
                lm, rm = child_masks(node.masks, feat, kind, thr)
                node.feature, node.kind, node.threshold = feat, kind, thr
                node.left = len(nodes)
                nodes.append(TreeNode(node.left, node.depth + 1, lm))
                node.right = len(nodes)
                nodes.append(TreeNode(node.right, node.depth + 1, rm))
                next_frontier += [node.left, node.right]
            frontier = next_frontier
        return nodes

    # -- inference ------------------------------------------------------------

    def predict(self, rows: Dict[str, np.ndarray]) -> np.ndarray:
        n = len(next(iter(rows.values())))
        out = np.full(n, self.base, dtype=np.float64)
        for nodes in self.trees:
            out += self.lr * predict_nodes(nodes, rows, self.max_depth)
        return out
