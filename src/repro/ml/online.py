"""Streaming model refresh over maintained aggregates (IVM application layer).

:class:`OnlineRidge` keeps the covar-matrix batch (paper §2) **live** under
data changes: the engine maintains every covar view incrementally
(``core/ivm.py``), and each update batch triggers a closed-form re-solve over
the refreshed (p, p) sufficient statistics.  Refresh cost is the delta scans
plus one tiny host solve — proportional to the update, not the database,
which is what lets the model sit behind a write-heavy workload (AC/DC's
in-database learning, arXiv 1803.07480, made incremental).

All covar queries are rooted at the fact table by default, so a fact-only
update touches *only* views scanned over the fact — the delta program then
scans just the delta tuples (see ``benchmarks/bench_ivm.py`` for the
resulting speedup over full recomputation).
"""

from __future__ import annotations

from typing import Mapping, Optional, Sequence

import numpy as np

from repro.api import Database, ExecutionConfig, connect
from repro.data.relations import DeltaBatchUpdate
from repro.ml import ridge
from repro.ml.covar import assemble_covar, covar_queries


class OnlineRidge:
    """Ridge regression with incrementally maintained sufficient statistics.

        olr = OnlineRidge(ds)
        olr.fit()                                  # full scan once
        olr.update(DeltaBatchUpdate().insert(...)) # work ∝ |update|
        olr.theta, olr.rmse(rows)
    """

    def __init__(self, ds, lam: float = 1e-3,
                 cont: Optional[Sequence[str]] = None,
                 cat: Optional[Sequence[str]] = None,
                 backend: str = "xla", interpret: Optional[bool] = None,
                 block_size: int = 4096, root_at_fact: bool = True,
                 config: Optional[ExecutionConfig] = None,
                 database: Optional[Database] = None):
        self.ds = ds
        self.lam = lam
        qs, self.layout = covar_queries(ds, cont, cat)
        db = database or connect(ds, config=config or ExecutionConfig(
            backend=backend, interpret=interpret, block_size=block_size))
        roots = {q.name: ds.fact for q in qs} if root_at_fact else None
        self.view = db.views(qs, maintain=True, roots=roots,
                             warm_rels=(ds.fact,))
        self.maintained = self.view.maintained
        self.theta: Optional[np.ndarray] = None
        self.C: Optional[np.ndarray] = None
        self.N = 0.0

    def fit(self, db=None) -> np.ndarray:
        """Materialize the covar batch (full scan) and solve.  Re-fitting
        rescans and publishes a fresh epoch (like the legacy path)."""
        self.maintained.init(db if db is not None else self.ds.db)
        return self._refresh()

    def update(self, update: DeltaBatchUpdate) -> np.ndarray:
        """Fold an update batch into the maintained views and re-solve."""
        self.view.apply(update)
        return self._refresh()

    def update_fact(self, inserts: Optional[Mapping[str, np.ndarray]] = None,
                    delete_idx: Optional[np.ndarray] = None) -> np.ndarray:
        """Convenience: an update touching only the fact table."""
        upd = DeltaBatchUpdate()
        if inserts is not None:
            upd.insert(self.ds.fact, inserts)
        if delete_idx is not None:
            upd.delete(self.ds.fact, delete_idx)
        return self.update(upd)

    def _refresh(self) -> np.ndarray:
        out = {k: np.asarray(v) for k, v in self.maintained.results().items()}
        self.C, self.N = assemble_covar(out, self.layout)
        self.theta = ridge.closed_form(self.C, self.N, self.layout, self.lam)
        return self.theta

    def predict(self, rows: dict) -> np.ndarray:
        return ridge.predict(self.theta, self.layout, rows)

    def rmse(self, rows: dict) -> float:
        return ridge.rmse(self.theta, self.layout, rows)
