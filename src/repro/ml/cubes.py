"""Data cubes (paper §2, eq. (6)): 2^k group-by aggregates, v measures each.

Three evaluation paths:
  * ``cube_via_engine`` — all 2^k subset queries as one LMFAO batch (the
    paper's path; view merging shares the per-edge count views across cells);
  * ``cube_rollup`` — beyond-paper: compute only the finest cell with the
    engine, then roll coarser cells up the lattice by marginalizing axes
    (classic Harinarayan-style reuse, exact for SUM measures);
  * ``StreamingCube`` — incremental mode: every cell stays live under
    insert/delete batches via the IVM subsystem (``core/ivm.py``), exact for
    the SUM measures the cube is built from.
Tests assert the paths agree.
"""

from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import Engine, query, sum_of
from repro.data.datasets import Dataset
from repro.data.relations import DeltaBatchUpdate


def cube_name(subset: Sequence[str]) -> str:
    return "cube_" + ("_".join(subset) if subset else "ALL")


def cube_queries(dims: Sequence[str], measures: Sequence[str]):
    qs = []
    for r in range(len(dims) + 1):
        for subset in itertools.combinations(dims, r):
            qs.append(query(cube_name(subset), list(subset),
                            [sum_of(m) for m in measures]))
    return qs


def cube_via_engine(ds: Dataset, dims: Sequence[str], measures: Sequence[str],
                    multi_root: bool = True, block_size: int = 4096,
                    engine: Optional[Engine] = None) -> Dict[str, np.ndarray]:
    qs = cube_queries(dims, measures)
    eng = engine or Engine(ds.schema, edges=ds.edges, sizes=ds.db.sizes())
    batch = eng.compile(qs, multi_root=multi_root, block_size=block_size)
    return {k: np.asarray(v, np.float64) for k, v in batch(ds.db).items()}


class StreamingCube:
    """All 2^k cube cells maintained incrementally under data changes.

        cube = StreamingCube(ds, dims, measures)   # full scan once
        cube.update(DeltaBatchUpdate().insert(...))
        cube.cells()[cube_name(("city",))]

    Queries are rooted at the fact table, so fact-only streams maintain every
    cell by scanning just the delta tuples."""

    def __init__(self, ds: Dataset, dims: Sequence[str], measures: Sequence[str],
                 backend: str = "xla", interpret: Optional[bool] = None,
                 block_size: int = 4096):
        qs = cube_queries(dims, measures)
        eng = Engine(ds.schema, edges=ds.edges, sizes=ds.db.sizes())
        self.maintained = eng.compile_incremental(
            qs, backend=backend, interpret=interpret, block_size=block_size,
            root_override={q.name: ds.fact for q in qs}, warm_rels=(ds.fact,))
        self.maintained.init(ds.db)

    def update(self, update: DeltaBatchUpdate) -> Dict[str, np.ndarray]:
        self.maintained.apply(update)
        return self.cells()

    def cells(self) -> Dict[str, np.ndarray]:
        return {k: np.asarray(v, np.float64)
                for k, v in self.maintained.results().items()}


def cube_rollup(ds: Dataset, dims: Sequence[str], measures: Sequence[str],
                block_size: int = 4096) -> Dict[str, np.ndarray]:
    finest = cube_via_engine(ds, dims, measures, block_size=block_size,
                             multi_root=True)[cube_name(dims)]
    out: Dict[str, np.ndarray] = {}
    for r in range(len(dims) + 1):
        for subset in itertools.combinations(dims, r):
            axes = tuple(i for i, d in enumerate(dims) if d not in subset)
            arr = finest.sum(axis=axes) if axes else finest
            # finest axes order == dims order; subset keeps relative order
            out[cube_name(subset)] = arr
    return out
