"""Data cubes (paper §2, eq. (6)): 2^k group-by aggregates, v measures each.

Three evaluation paths:
  * ``cube_via_engine`` — all 2^k subset queries as one LMFAO batch (the
    paper's path; view merging shares the per-edge count views across cells);
  * ``cube_rollup`` — beyond-paper: compute only the finest cell with the
    engine, then roll coarser cells up the lattice by marginalizing axes
    (classic Harinarayan-style reuse, exact for SUM measures);
  * ``StreamingCube`` — incremental mode: every cell stays live under
    insert/delete batches via the IVM subsystem (``core/ivm.py``), exact for
    the SUM measures the cube is built from.
Tests assert the paths agree.

All three thread the session's :class:`~repro.api.ExecutionConfig` —
``backend``/``block_size`` select the lowering path for cubes exactly as for
every other workload (they used to be silently dropped here).
"""

from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.api import Database, ExecutionConfig, connect
from repro.core import query, sum_of
from repro.data.datasets import Dataset
from repro.data.relations import DeltaBatchUpdate


def cube_name(subset: Sequence[str]) -> str:
    return "cube_" + ("_".join(subset) if subset else "ALL")


def cube_queries(dims: Sequence[str], measures: Sequence[str]):
    qs = []
    for r in range(len(dims) + 1):
        for subset in itertools.combinations(dims, r):
            qs.append(query(cube_name(subset), list(subset),
                            [sum_of(m) for m in measures]))
    return qs


def _session(ds: Dataset, database: Optional[Database],
             config: Optional[ExecutionConfig], multi_root: bool,
             block_size: int, backend: str,
             interpret: Optional[bool]) -> Database:
    if database is not None:
        return database
    return connect(ds, config=config or ExecutionConfig(
        multi_root=multi_root, block_size=block_size, backend=backend,
        interpret=interpret))


def cube_via_engine(ds: Dataset, dims: Sequence[str], measures: Sequence[str],
                    multi_root: bool = True, block_size: int = 4096,
                    backend: str = "xla", interpret: Optional[bool] = None,
                    config: Optional[ExecutionConfig] = None,
                    database: Optional[Database] = None) -> Dict[str, np.ndarray]:
    qs = cube_queries(dims, measures)
    db = _session(ds, database, config, multi_root, block_size, backend,
                  interpret)
    return {k: np.asarray(v, np.float64) for k, v in db.views(qs).run().items()}


class StreamingCube:
    """All 2^k cube cells maintained incrementally under data changes.

        cube = StreamingCube(ds, dims, measures)   # full scan once
        cube.update(DeltaBatchUpdate().insert(...))
        cube.cells()[cube_name(("city",))]

    Queries are rooted at the fact table, so fact-only streams maintain every
    cell by scanning just the delta tuples."""

    def __init__(self, ds: Dataset, dims: Sequence[str], measures: Sequence[str],
                 backend: str = "xla", interpret: Optional[bool] = None,
                 block_size: int = 4096,
                 config: Optional[ExecutionConfig] = None,
                 database: Optional[Database] = None):
        qs = cube_queries(dims, measures)
        db = _session(ds, database, config, True, block_size, backend,
                      interpret)
        self.view = db.views(qs, maintain=True,
                             roots={q.name: ds.fact for q in qs},
                             warm_rels=(ds.fact,))
        self.maintained = self.view.maintained
        self.view.run()                        # full scan -> epoch 0

    def update(self, update: DeltaBatchUpdate) -> Dict[str, np.ndarray]:
        self.view.apply(update)
        return self.cells()

    def cells(self) -> Dict[str, np.ndarray]:
        return {k: np.asarray(v, np.float64)
                for k, v in self.view.results().items()}


def cube_rollup(ds: Dataset, dims: Sequence[str], measures: Sequence[str],
                block_size: int = 4096, backend: str = "xla",
                interpret: Optional[bool] = None,
                config: Optional[ExecutionConfig] = None,
                database: Optional[Database] = None) -> Dict[str, np.ndarray]:
    finest = cube_via_engine(ds, dims, measures, block_size=block_size,
                             multi_root=True, backend=backend,
                             interpret=interpret, config=config,
                             database=database)[cube_name(dims)]
    out: Dict[str, np.ndarray] = {}
    for r in range(len(dims) + 1):
        for subset in itertools.combinations(dims, r):
            axes = tuple(i for i, d in enumerate(dims) if d not in subset)
            arr = finest.sum(axis=axes) if axes else finest
            # finest axes order == dims order; subset keeps relative order
            out[cube_name(subset)] = arr
    return out
