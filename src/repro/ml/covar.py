"""Covar-matrix workload (paper §2, eqs. (2)-(4)).

The non-centered covariance matrix over the join defines ridge (and
polynomial) regression.  Continuous×continuous entries are scalar aggregates
SUM(Xi·Xk); a categorical attribute becomes a group-by (one-hot semantics);
two categoricals become a two-attribute group-by.  One engine batch computes
every entry; this is the paper's flagship workload (814 aggregates → 34 views
for Retailer).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.api import Database, ExecutionConfig, connect
from repro.core import COUNT, Var, agg, query, sum_of, sum_prod
from repro.core.aggregates import Query
from repro.data.datasets import Dataset


@dataclasses.dataclass
class CovarLayout:
    """Feature layout of the dense covar matrix: [intercept] + continuous +
    one-hot categorical blocks + [label]."""

    cont: Tuple[str, ...]
    cat: Tuple[str, ...]
    cat_offsets: Dict[str, int]
    cat_domains: Dict[str, int]
    label: str
    p: int

    @property
    def label_idx(self) -> int:
        return self.p - 1

    def cont_idx(self, attr: str) -> int:
        return 1 + self.cont.index(attr)

    def cat_slice(self, attr: str) -> slice:
        o = self.cat_offsets[attr]
        return slice(o, o + self.cat_domains[attr])


def covar_queries(ds: Dataset, cont: Optional[Sequence[str]] = None,
                  cat: Optional[Sequence[str]] = None) -> Tuple[List[Query], CovarLayout]:
    cont = tuple(cont if cont is not None else ds.features_cont)
    cat = tuple(cat if cat is not None else ds.features_cat)
    label = ds.label
    doms = {c: ds.schema.domain(c) for c in cat}
    offs = {}
    o = 1 + len(cont)
    for c in cat:
        offs[c] = o
        o += doms[c]
    layout = CovarLayout(cont=cont, cat=cat, cat_offsets=offs, cat_domains=doms,
                         label=label, p=o + 1)

    xs = list(cont) + [label]  # continuous block incl. label
    queries: List[Query] = []

    # scalar block: intercept row/col + all pairwise continuous sums
    aggs = [COUNT] + [sum_of(x) for x in xs]
    for i, xi in enumerate(xs):
        for xk in xs[i:]:
            aggs.append(sum_prod(xi, xk))
    queries.append(query("cm_scalar", [], aggs))

    # categorical × continuous (eq. 3): group by the categorical
    for c in cat:
        queries.append(query(f"cm_cat_{c}", [c], [COUNT] + [sum_of(x) for x in xs]))

    # categorical × categorical (eq. 4): group by both
    for i, ci in enumerate(cat):
        for ck in cat[i + 1:]:
            queries.append(query(f"cm_cat2_{ci}_{ck}", [ci, ck], [COUNT]))

    return queries, layout


def assemble_covar(outputs: Dict[str, np.ndarray], layout: CovarLayout) -> Tuple[np.ndarray, float]:
    """Dense symmetric (p, p) covar matrix + dataset size N from the batch
    outputs (the application layer is cheap: paper §1)."""
    p = layout.p
    C = np.zeros((p, p), dtype=np.float64)
    xs = list(layout.cont) + [layout.label]
    xidx = [layout.cont_idx(x) for x in layout.cont] + [layout.label_idx]

    sc = np.asarray(outputs["cm_scalar"], dtype=np.float64)
    N = float(sc[0])
    C[0, 0] = N
    for i, xi in enumerate(xs):
        C[0, xidx[i]] = C[xidx[i], 0] = sc[1 + i]
    k = 1 + len(xs)
    for i in range(len(xs)):
        for j in range(i, len(xs)):
            C[xidx[i], xidx[j]] = C[xidx[j], xidx[i]] = sc[k]
            k += 1

    for c in layout.cat:
        out = np.asarray(outputs[f"cm_cat_{c}"], dtype=np.float64)  # (D, 1+len(xs))
        sl = layout.cat_slice(c)
        cnt = out[:, 0]
        C[sl, 0] = C[0, sl] = cnt
        np.fill_diagonal(C[sl, sl], cnt)  # one-hot: Xc·Xc = diag(count)
        for i, xi in enumerate(xs):
            C[sl, xidx[i]] = out[:, 1 + i]
            C[xidx[i], sl] = out[:, 1 + i]

    for i, ci in enumerate(layout.cat):
        for ck in layout.cat[i + 1:]:
            out = np.asarray(outputs[f"cm_cat2_{ci}_{ck}"], dtype=np.float64)[..., 0]
            C[layout.cat_slice(ci), layout.cat_slice(ck)] = out
            C[layout.cat_slice(ck), layout.cat_slice(ci)] = out.T
    return C, N


def compute_covar(ds: Dataset, database: Optional[Database] = None,
                  cont: Optional[Sequence[str]] = None,
                  cat: Optional[Sequence[str]] = None,
                  multi_root: bool = True, block_size: int = 4096,
                  backend: str = "xla", interpret: Optional[bool] = None,
                  config: Optional[ExecutionConfig] = None):
    """End-to-end: register the covar batch as views on a session, run it,
    assemble the dense covar.  Pass ``database`` to reuse an open session
    (its config wins), or ``config`` / the legacy kwargs to open one."""
    qs, layout = covar_queries(ds, cont, cat)
    db = database or connect(ds, config=config or ExecutionConfig(
        multi_root=multi_root, block_size=block_size, backend=backend,
        interpret=interpret))
    views = db.views(qs)
    outputs = views.run()
    C, N = assemble_covar({k: np.asarray(v) for k, v in outputs.items()}, layout)
    return C, N, layout, views.compiled
