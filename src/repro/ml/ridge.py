"""Ridge linear regression over the covar matrix (paper §2 + §4.2).

Training never touches the (never-materialized) join: batch gradient descent
runs on the (p, p) covar matrix — the paper's (and AC/DC's) optimizer with
Armijo backtracking line search and Barzilai-Borwein step sizes.  A
closed-form solve cross-checks accuracy (the MADlib comparison).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.ml.covar import CovarLayout


@dataclasses.dataclass
class RidgeResult:
    theta: np.ndarray      # (p-1,) parameters for [intercept, features...]
    iterations: int
    objective: float


def _split(C: np.ndarray, layout: CovarLayout):
    li = layout.label_idx
    f = np.arange(C.shape[0]) != li
    Cff = C[np.ix_(f, f)]
    Cfl = C[f, li]
    Cll = C[li, li]
    return Cff, Cfl, Cll


def closed_form(C: np.ndarray, N: float, layout: CovarLayout, lam: float = 1e-3) -> np.ndarray:
    Cff, Cfl, _ = _split(C, layout)
    A = Cff / N + lam * np.eye(Cff.shape[0])
    return np.linalg.solve(A, Cfl / N)


def bgd(C: np.ndarray, N: float, layout: CovarLayout, lam: float = 1e-3,
        max_iters: int = 2000, tol: float = 1e-10) -> RidgeResult:
    """BGD with Armijo backtracking + Barzilai-Borwein step sizes.

    J(θ) = 1/(2N)·θ̃ᵀCθ̃ + λ/2·‖θ‖²  with θ̃ = [θ; -1] (label coefficient
    fixed at -1, paper §2).  The covar matrix is tiny relative to the data, so
    the convergence loop runs in float64 on host — the paper's point is that
    this step is *cheap* once the engine has produced the sufficient
    statistics."""
    Cff, Cfl, Cll = _split(C, layout)
    n_f = Cff.shape[0]

    # Jacobi preconditioning: one-hot blocks make the covar badly
    # conditioned; substituting θ = D·φ with D = diag(Cff/N + λ)^{-1/2}
    # solves the *same* ridge problem in a well-scaled space
    dscale = 1.0 / np.sqrt(np.maximum(np.diag(Cff) / N + lam, 1e-12))
    Cff = Cff * dscale[:, None] * dscale[None, :]
    Cfl = Cfl * dscale
    d2 = dscale * dscale

    def obj(th):
        return (th @ Cff @ th - 2 * th @ Cfl + Cll) / (2 * N) + \
            0.5 * lam * (th * th) @ d2

    def grad(th):
        return (Cff @ th - Cfl) / N + lam * d2 * th

    th = np.zeros(n_f)
    g = grad(th)
    prev_th, prev_g = th, g
    alpha = 1e-6
    it = 0
    while it < max_iters and np.linalg.norm(g) > tol * max(1.0, np.linalg.norm(th)):
        if it > 0:
            dth, dg = th - prev_th, g - prev_g
            denom = dth @ dg
            alpha = abs((dth @ dth) / denom) if abs(denom) > 1e-300 else alpha
            alpha = float(np.clip(alpha, 1e-12, 1e6))
        j0, gg = obj(th), g @ g
        while obj(th - alpha * g) > j0 - 0.5 * alpha * gg and alpha > 1e-16:
            alpha *= 0.5
        prev_th, prev_g = th, g
        th = th - alpha * g
        g = grad(th)
        it += 1
    final_obj = float(obj(th))
    th = th * dscale          # back to the unscaled parameterization
    return RidgeResult(theta=th, iterations=it, objective=final_obj)


def predict(theta: np.ndarray, layout: CovarLayout, rows: dict) -> np.ndarray:
    """Apply the model to materialized rows (test-time only; numpy)."""
    n = len(next(iter(rows.values())))
    yhat = np.full(n, theta[0], dtype=np.float64)
    for x in layout.cont:
        yhat += theta[layout.cont_idx(x)] * np.asarray(rows[x], dtype=np.float64)
    for c in layout.cat:
        sl = layout.cat_slice(c)
        yhat += theta[np.arange(sl.start, sl.stop)[np.asarray(rows[c])] ]
    return yhat


def rmse(theta: np.ndarray, layout: CovarLayout, rows: dict) -> float:
    y = np.asarray(rows[layout.label], dtype=np.float64)
    return float(np.sqrt(np.mean((predict(theta, layout, rows) - y) ** 2)))
