"""Classification & regression trees (CART) over aggregate batches (paper §2).

Each CART node needs, per candidate split, COUNT / SUM(y) / SUM(y²) (variance,
regression) or per-class counts (Gini, classification) over the *fragment* of
the join satisfying the node's ancestor conditions — queries (8)-(10) of the
paper, "extended with the group-by attribute X" so that ONE query per feature
covers every threshold at once.

Dynamic functions, recompile-free: the node's conjunction of ancestor
conditions is Π_g mask_g[X_g], one mask-lookup UDAF per split attribute whose
(0/1) mask arrays are **runtime parameters**.  LMFAO recompiles + dlopens
per-node C++ for these (paper §1.2); under JAX tracing the masks are traced
arguments, so the whole tree is built from a single compiled batch.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import COUNT, Delta, Engine, Lambda, Pow, Var, agg, query
from repro.data.datasets import Dataset


def _mask_term(attr: str) -> Lambda:
    def fn(x, params, _attr=attr):
        return params[f"mask_{_attr}"][x]
    return Lambda((attr,), fn, tag=f"mask_{attr}")


@dataclasses.dataclass
class SplitFeature:
    attr: str          # categorical attr grouped by (bucket code for continuous)
    kind: str          # 'ordered' (threshold splits) | 'categorical' (one-vs-rest)
    domain: int


@dataclasses.dataclass
class TreeNode:
    node_id: int
    depth: int
    masks: Dict[str, np.ndarray]
    n: float = 0.0
    prediction: float = 0.0
    feature: Optional[str] = None
    kind: str = ""
    threshold: int = -1        # bucket threshold (ordered) or category (cat)
    left: int = -1
    right: int = -1

    @property
    def is_leaf(self) -> bool:
        return self.left < 0


class DecisionTree:
    """CART via one LMFAO batch; task ∈ {'regression', 'classification'}."""

    def __init__(self, ds: Dataset, task: str = "regression",
                 label: Optional[str] = None,
                 split_attrs: Optional[Sequence[str]] = None,
                 max_depth: int = 4, min_instances: int = 1000,
                 max_nodes: int = 31, block_size: int = 4096,
                 multi_root: bool = True, backend: str = "xla",
                 interpret: Optional[bool] = None):
        self.ds = ds
        self.task = task
        self.label = label or (ds.label if task == "regression" else None)
        if self.label is None:
            raise ValueError("classification needs an explicit categorical label")
        self.max_depth = max_depth
        self.min_instances = min_instances
        self.max_nodes = max_nodes

        if split_attrs is None:
            split_attrs = ([ds.bucket_attr(c) for c in ds.features_cont
                            if ds.bucket_attr(c) in ds.schema.attributes] +
                           [c for c in ds.features_cat if c != self.label])
        self.features: List[SplitFeature] = []
        for a in split_attrs:
            kind = "ordered" if a.endswith("__b") else "categorical"
            self.features.append(SplitFeature(a, kind, ds.schema.domain(a)))

        if task == "classification":
            self.n_classes = ds.schema.domain(self.label)
        else:
            self.n_classes = 0

        self._build_batch(block_size, multi_root, backend, interpret)
        self.nodes: List[TreeNode] = []

    # -- the aggregate batch (compiled once for the whole tree) --------------

    def _build_batch(self, block_size: int, multi_root: bool,
                     backend: str = "xla",
                     interpret: Optional[bool] = None) -> None:
        cond = [_mask_term(f.attr) for f in self.features]
        queries = []
        for f in self.features:
            if self.task == "regression":
                aggs = [agg(*cond), agg(Var(self.label), *cond),
                        agg(Pow(self.label, 2), *cond)]
            else:
                aggs = [agg(*cond)] + [agg(Delta(self.label, "==", c), *cond)
                                       for c in range(self.n_classes)]
            queries.append(query(f"split_{f.attr}", [f.attr], aggs))
        eng = Engine(self.ds.schema, edges=self.ds.edges, sizes=self.ds.db.sizes())
        self.batch = eng.compile(queries, multi_root=multi_root,
                                 block_size=block_size, backend=backend,
                                 interpret=interpret)
        self.n_aggregates = sum(len(q.aggregates) * self.ds.schema.domain(q.group_by[0])
                                for q in queries)

    def _node_params(self, masks: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        return {f"mask_{a}": m.astype(np.float32) for a, m in masks.items()}

    # -- cost functions -------------------------------------------------------

    def _cost(self, stats: np.ndarray) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """stats (..., n_aggs) -> (count, cost, prediction)."""
        n = stats[..., 0]
        safe_n = np.maximum(n, 1e-9)
        if self.task == "regression":
            s, s2 = stats[..., 1], stats[..., 2]
            cost = s2 - s * s / safe_n           # sum of squared errors
            pred = s / safe_n
        else:
            probs = stats[..., 1:] / safe_n[..., None]
            gini = 1.0 - (probs ** 2).sum(-1)
            cost = n * gini
            pred = stats[..., 1:].argmax(-1).astype(np.float64)
        return n, cost, pred

    # -- fitting ---------------------------------------------------------------

    def fit(self) -> "DecisionTree":
        root_masks = {f.attr: np.ones(f.domain, dtype=np.float32) for f in self.features}
        self.nodes = [TreeNode(0, 0, root_masks)]
        frontier = [0]
        while frontier and len(self.nodes) < self.max_nodes:
            nid = frontier.pop(0)
            node = self.nodes[nid]
            outputs = self.batch(self.ds.db, params=self._node_params(node.masks))
            best = self._best_split(outputs)
            # record node stats from any feature's totals
            first = np.asarray(outputs[f"split_{self.features[0].attr}"], np.float64)
            tot = first.sum(axis=0)
            n, cost, pred = self._cost(tot)
            node.n, node.prediction = float(n), float(pred)
            if best is None or node.depth >= self.max_depth:
                continue
            feat, kind, thr, gain = best
            if gain <= 1e-9:
                continue
            lm, rm = self._child_masks(node.masks, feat, kind, thr)
            node.feature, node.kind, node.threshold = feat, kind, thr
            node.left = len(self.nodes)
            self.nodes.append(TreeNode(node.left, node.depth + 1, lm))
            node.right = len(self.nodes)
            self.nodes.append(TreeNode(node.right, node.depth + 1, rm))
            frontier += [node.left, node.right]
        # fill leaf stats for nodes never expanded
        for node in self.nodes:
            if node.n == 0.0:
                outputs = self.batch(self.ds.db, params=self._node_params(node.masks))
                first = np.asarray(outputs[f"split_{self.features[0].attr}"], np.float64)
                n, _, pred = self._cost(first.sum(axis=0))
                node.n, node.prediction = float(n), float(pred)
        return self

    def _best_split(self, outputs) -> Optional[Tuple[str, str, int, float]]:
        best = None
        for f in self.features:
            stats = np.asarray(outputs[f"split_{f.attr}"], np.float64)  # (D, n_aggs)
            tot = stats.sum(axis=0)
            n_tot, cost_tot, _ = self._cost(tot)
            if n_tot < 2 * self.min_instances:
                continue
            if f.kind == "ordered":
                left = np.cumsum(stats, axis=0)[:-1]      # thresholds 0..D-2
            else:
                left = stats                               # one-vs-rest
            right = tot[None, :] - left
            nl, cl, _ = self._cost(left)
            nr, cr, _ = self._cost(right)
            ok = (nl >= self.min_instances) & (nr >= self.min_instances)
            gain = np.where(ok, cost_tot - (cl + cr), -np.inf)
            if gain.size and np.max(gain) > -np.inf:
                t = int(np.argmax(gain))
                cand = (f.attr, f.kind, t, float(gain[t]))
                if best is None or cand[3] > best[3]:
                    best = cand
        return best

    def _child_masks(self, masks, feat: str, kind: str, thr: int):
        lm = {a: m.copy() for a, m in masks.items()}
        rm = {a: m.copy() for a, m in masks.items()}
        d = lm[feat].shape[0]
        if kind == "ordered":
            ind = (np.arange(d) <= thr).astype(np.float32)
        else:
            ind = (np.arange(d) == thr).astype(np.float32)
        lm[feat] = lm[feat] * ind
        rm[feat] = rm[feat] * (1.0 - ind)
        return lm, rm

    # -- inference over materialized rows (test-time only) ---------------------

    def predict(self, rows: Dict[str, np.ndarray]) -> np.ndarray:
        n = len(next(iter(rows.values())))
        out = np.zeros(n, dtype=np.float64)
        idx = np.zeros(n, dtype=np.int64)
        active = np.ones(n, dtype=bool)
        # iterative tree walk (vectorized per node)
        for _ in range(self.max_depth + 1):
            moved = False
            for nid, node in enumerate(self.nodes):
                sel = active & (idx == nid)
                if not sel.any():
                    continue
                if node.is_leaf:
                    out[sel] = node.prediction
                    active[sel] = False
                else:
                    moved = True
                    codes = np.asarray(rows[node.feature])[sel]
                    if node.kind == "ordered":
                        goleft = codes <= node.threshold
                    else:
                        goleft = codes == node.threshold
                    tmp = idx[sel]
                    tmp[goleft] = node.left
                    tmp[~goleft] = node.right
                    idx[sel] = tmp
            if not moved:
                break
        for nid, node in enumerate(self.nodes):  # flush remaining
            sel = active & (idx == nid)
            if sel.any():
                out[sel] = node.prediction
        return out

    def n_split_nodes(self) -> int:
        return sum(1 for n in self.nodes if not n.is_leaf)
