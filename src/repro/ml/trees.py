"""Classification & regression trees (CART) over aggregate batches (paper §2).

Each CART node needs, per candidate split, COUNT / SUM(y) / SUM(y²) (variance,
regression) or per-class counts (Gini, classification) over the *fragment* of
the join satisfying the node's ancestor conditions — queries (8)-(10) of the
paper, "extended with the group-by attribute X" so that ONE query per feature
covers every threshold at once.

Dynamic functions, recompile-free: the node's conjunction of ancestor
conditions is Π_g mask_g[X_g], one mask-lookup UDAF per split attribute whose
(0/1) mask arrays are **runtime parameters**.  LMFAO recompiles + dlopens
per-node C++ for these (paper §1.2); under JAX tracing the masks are traced
arguments, so the whole tree is built from a single compiled batch.

Frontier-batched fitting (DESIGN.md §7.4): with ``node_batch=True`` (default)
the mask params are declared ``batched``, the engine threads a param-batch
(node) axis through every layer, and ``fit()`` grows the tree
*level-synchronously* — all frontier nodes of a level are evaluated in ONE
``CompiledBatch.run_batched`` dispatch, and each node's own stats (count,
prediction) are read from the same pass that scores its splits, so there is
no per-leaf backfill.  ``node_batch=False`` keeps the per-node dispatch loop
(one engine call per node) for comparison; both produce identical trees.
The stepping API (``init_fit`` / ``frontier_masks`` / ``advance``) lets
``ml/forest.py`` drive many trees' frontiers through one shared batch.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax.numpy as jnp
import numpy as np

from repro.api import Database, ExecutionConfig, ViewHandle, connect
from repro.core import Delta, Lambda, Pow, Var, agg, query
from repro.core.aggregates import Param
from repro.data.datasets import Dataset


def _mask_term(attr: str, batched: bool = False) -> Lambda:
    p = Param(f"mask_{attr}", batched=batched)

    def fn(x, params, _name=p.name):
        # lookup-table UDAF: (D,) mask -> row mask; (N, D) batched masks ->
        # (N, *rows) with the node axis leading (DESIGN.md §7.4)
        return jnp.take(params[_name], x, axis=-1)

    tag = f"mask_{attr}" + (":batched" if batched else "")
    return Lambda((attr,), fn, tag=tag, param_refs=(p,))


@dataclasses.dataclass
class SplitFeature:
    attr: str          # categorical attr grouped by (bucket code for continuous)
    kind: str          # 'ordered' (threshold splits) | 'categorical' (one-vs-rest)
    domain: int


@dataclasses.dataclass
class TreeNode:
    node_id: int
    depth: int
    masks: Dict[str, np.ndarray]
    n: float = 0.0
    prediction: float = 0.0
    feature: Optional[str] = None
    kind: str = ""
    threshold: int = -1        # bucket threshold (ordered) or category (cat)
    left: int = -1
    right: int = -1

    @property
    def is_leaf(self) -> bool:
        return self.left < 0


def build_tree_features(ds: Dataset, label: Optional[str],
                        split_attrs: Optional[Sequence[str]]) -> List[SplitFeature]:
    if split_attrs is None:
        split_attrs = ([ds.bucket_attr(c) for c in ds.features_cont
                        if ds.bucket_attr(c) in ds.schema.attributes] +
                       [c for c in ds.features_cat if c != label])
    feats = []
    for a in split_attrs:
        kind = "ordered" if a.endswith("__b") else "categorical"
        feats.append(SplitFeature(a, kind, ds.schema.domain(a)))
    return feats


def build_tree_batch(ds: Dataset, features: Sequence[SplitFeature], task: str,
                     label: str, n_classes: int, *, node_batch: bool = True,
                     block_size: int = 4096, multi_root: bool = True,
                     backend: str = "xla", interpret: Optional[bool] = None,
                     config: Optional[ExecutionConfig] = None,
                     database: Optional[Database] = None):
    """Register the per-feature split-statistics batch shared by a whole tree
    (or forest) as session views.  One query per feature: [COUNT, SUM(y),
    SUM(y²)] (regression) or [COUNT, per-class counts] (classification) under
    the node-condition mask product, grouped by the feature's code domain.
    Returns ``(ViewHandle, queries)``."""
    cond = [_mask_term(f.attr, batched=node_batch) for f in features]
    queries = []
    for f in features:
        if task == "regression":
            aggs = [agg(*cond), agg(Var(label), *cond),
                    agg(Pow(label, 2), *cond)]
        else:
            aggs = [agg(*cond)] + [agg(Delta(label, "==", c), *cond)
                                   for c in range(n_classes)]
        queries.append(query(f"split_{f.attr}", [f.attr], aggs))
    db = database or connect(ds, config=config or ExecutionConfig(
        multi_root=multi_root, block_size=block_size, backend=backend,
        interpret=interpret))
    return db.views(queries), queries


def stack_mask_params(features: Sequence[SplitFeature],
                      mask_list: Sequence[Dict[str, np.ndarray]]) -> Dict[str, np.ndarray]:
    """Stack per-node mask dicts into the (N, D) batched param arrays."""
    return {f"mask_{f.attr}": np.stack([m[f.attr] for m in mask_list]
                                       ).astype(np.float32)
            for f in features}


def child_masks(masks: Dict[str, np.ndarray], feat: str, kind: str,
                thr: int) -> Tuple[Dict[str, np.ndarray], Dict[str, np.ndarray]]:
    """Left/right node masks after splitting on ``feat`` at ``thr``."""
    lm = {a: m.copy() for a, m in masks.items()}
    rm = {a: m.copy() for a, m in masks.items()}
    d = lm[feat].shape[0]
    if kind == "ordered":
        ind = (np.arange(d) <= thr).astype(np.float32)
    else:
        ind = (np.arange(d) == thr).astype(np.float32)
    lm[feat] = lm[feat] * ind
    rm[feat] = rm[feat] * (1.0 - ind)
    return lm, rm


def predict_nodes(nodes: Sequence[TreeNode], rows: Dict[str, np.ndarray],
                  max_depth: int) -> np.ndarray:
    """Vectorized tree walk over materialized rows (test-time only)."""
    n = len(next(iter(rows.values())))
    out = np.zeros(n, dtype=np.float64)
    idx = np.zeros(n, dtype=np.int64)
    active = np.ones(n, dtype=bool)
    for _ in range(max_depth + 1):
        moved = False
        for nid, node in enumerate(nodes):
            sel = active & (idx == nid)
            if not sel.any():
                continue
            if node.is_leaf:
                out[sel] = node.prediction
                active[sel] = False
            else:
                moved = True
                codes = np.asarray(rows[node.feature])[sel]
                if node.kind == "ordered":
                    goleft = codes <= node.threshold
                else:
                    goleft = codes == node.threshold
                tmp = idx[sel]
                tmp[goleft] = node.left
                tmp[~goleft] = node.right
                idx[sel] = tmp
        if not moved:
            break
    for nid, node in enumerate(nodes):  # flush remaining
        sel = active & (idx == nid)
        if sel.any():
            out[sel] = node.prediction
    return out


class DecisionTree:
    """CART via one LMFAO batch; task ∈ {'regression', 'classification'}.

    ``node_batch=True`` grows the tree frontier-batched (one fused dispatch
    per level); ``node_batch=False`` dispatches once per node.  Both run the
    same level-synchronous algorithm and produce identical trees.
    ``allowed_attrs`` restricts the split search to a feature subset (random
    forests pass per-tree subsets while sharing one compiled batch); ``batch``
    injects a pre-registered shared :class:`~repro.api.ViewHandle` (see
    ``ml/forest.py``); ``config``/``database`` thread a session's
    :class:`~repro.api.ExecutionConfig` instead of the legacy kwargs.
    """

    def __init__(self, ds: Dataset, task: str = "regression",
                 label: Optional[str] = None,
                 split_attrs: Optional[Sequence[str]] = None,
                 max_depth: int = 4, min_instances: int = 1000,
                 max_nodes: int = 31, block_size: int = 4096,
                 multi_root: bool = True, backend: str = "xla",
                 interpret: Optional[bool] = None, node_batch: bool = True,
                 allowed_attrs: Optional[Sequence[str]] = None,
                 batch: Optional[ViewHandle] = None,
                 config: Optional[ExecutionConfig] = None,
                 database: Optional[Database] = None):
        self.ds = ds
        self.task = task
        self.label = label or (ds.label if task == "regression" else None)
        if self.label is None:
            raise ValueError("classification needs an explicit categorical label")
        self.max_depth = max_depth
        self.min_instances = min_instances
        self.max_nodes = max_nodes
        self.node_batch = node_batch

        self.features: List[SplitFeature] = build_tree_features(
            ds, self.label if task == "classification" else None, split_attrs)
        self.allowed_attrs: Optional[Set[str]] = (
            set(allowed_attrs) if allowed_attrs is not None else None)

        if task == "classification":
            self.n_classes = ds.schema.domain(self.label)
        else:
            self.n_classes = 0

        if batch is None:
            batch, queries = build_tree_batch(
                ds, self.features, task, self.label, self.n_classes,
                node_batch=node_batch, block_size=block_size,
                multi_root=multi_root, backend=backend, interpret=interpret,
                config=config, database=database)
            self._queries = queries
        elif not isinstance(batch, ViewHandle):
            # legacy injection contract: a bare CompiledBatch (one-release
            # shim, like Engine.compile itself)
            import warnings

            from repro.core.engine import EngineDeprecationWarning
            warnings.warn(
                "passing a CompiledBatch as DecisionTree(batch=...) is "
                "deprecated; pass the ViewHandle from build_tree_batch "
                "(repro.connect session) instead", EngineDeprecationWarning,
                stacklevel=2)
            batch = ViewHandle(connect(ds), batch)
        self.view: ViewHandle = batch
        #: the underlying CompiledBatch (schedule/stats/dispatch counters)
        self.batch = batch.compiled
        self.n_aggregates = sum(
            (3 if task == "regression" else 1 + self.n_classes)
            * self.ds.schema.domain(f.attr) for f in self.features)
        self.nodes: List[TreeNode] = []
        self._frontier: List[int] = []

    def _node_params(self, masks: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        return {f"mask_{a}": m.astype(np.float32) for a, m in masks.items()}

    # -- cost functions -------------------------------------------------------

    def _cost(self, stats: np.ndarray) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """stats (..., n_aggs) -> (count, cost, prediction)."""
        n = stats[..., 0]
        safe_n = np.maximum(n, 1e-9)
        if self.task == "regression":
            s, s2 = stats[..., 1], stats[..., 2]
            cost = s2 - s * s / safe_n           # sum of squared errors
            pred = s / safe_n
        else:
            probs = stats[..., 1:] / safe_n[..., None]
            gini = 1.0 - (probs ** 2).sum(-1)
            cost = n * gini
            pred = stats[..., 1:].argmax(-1).astype(np.float64)
        return n, cost, pred

    # -- level-synchronous fitting (stepping API shared with ml/forest.py) ----

    def init_fit(self) -> None:
        root_masks = {f.attr: np.ones(f.domain, dtype=np.float32)
                      for f in self.features}
        self.nodes = [TreeNode(0, 0, root_masks)]
        self._frontier = [0]

    @property
    def growing(self) -> bool:
        return bool(self._frontier)

    def frontier_masks(self) -> List[Dict[str, np.ndarray]]:
        """Masks of the current frontier nodes, in frontier order."""
        return [self.nodes[nid].masks for nid in self._frontier]

    def advance(self, stats: Dict[str, np.ndarray]) -> None:
        """Consume one level's statistics — ``stats[attr]`` is
        ``(n_frontier, D_attr, n_aggs)`` — record every frontier node's count
        and prediction (leaf stats come from the same pass that scores the
        splits: no backfill), expand the winners, and move the frontier down
        one level."""
        next_frontier: List[int] = []
        for i, nid in enumerate(self._frontier):
            node = self.nodes[nid]
            node_stats = {f.attr: stats[f.attr][i] for f in self.features}
            tot = node_stats[self.features[0].attr].sum(axis=0)
            n, _, pred = self._cost(tot)
            node.n, node.prediction = float(n), float(pred)
            if node.depth >= self.max_depth:
                continue
            best = self._best_split(node_stats)
            if best is None:
                continue
            feat, kind, thr, gain = best
            if gain <= 1e-9:
                continue
            if len(self.nodes) + 2 > self.max_nodes:
                continue
            lm, rm = self._child_masks(node.masks, feat, kind, thr)
            node.feature, node.kind, node.threshold = feat, kind, thr
            node.left = len(self.nodes)
            self.nodes.append(TreeNode(node.left, node.depth + 1, lm))
            node.right = len(self.nodes)
            self.nodes.append(TreeNode(node.right, node.depth + 1, rm))
            next_frontier += [node.left, node.right]
        self._frontier = next_frontier

    def _eval_frontier(self) -> Dict[str, np.ndarray]:
        """One level's statistics, (n_frontier, D, n_aggs) per feature: a
        single fused dispatch when node-batched, one dispatch per node in the
        per-node comparison mode."""
        mask_list = self.frontier_masks()
        if self.node_batch:
            params = stack_mask_params(self.features, mask_list)
            outputs = self.view.run_batched(params)
            return {f.attr: np.asarray(outputs[f"split_{f.attr}"], np.float64)
                    for f in self.features}
        per_node = [self.view.run(params=self._node_params(m))
                    for m in mask_list]
        return {f.attr: np.stack([np.asarray(o[f"split_{f.attr}"], np.float64)
                                  for o in per_node])
                for f in self.features}

    def fit(self) -> "DecisionTree":
        self.init_fit()
        while self.growing:
            self.advance(self._eval_frontier())
        return self

    def _best_split(self, stats: Dict[str, np.ndarray]) -> Optional[Tuple[str, str, int, float]]:
        best = None
        for f in self.features:
            if self.allowed_attrs is not None and f.attr not in self.allowed_attrs:
                continue
            fstats = stats[f.attr]                        # (D, n_aggs)
            tot = fstats.sum(axis=0)
            n_tot, cost_tot, _ = self._cost(tot)
            if n_tot < 2 * self.min_instances:
                continue
            if f.kind == "ordered":
                left = np.cumsum(fstats, axis=0)[:-1]     # thresholds 0..D-2
            else:
                left = fstats                              # one-vs-rest
            right = tot[None, :] - left
            nl, cl, _ = self._cost(left)
            nr, cr, _ = self._cost(right)
            ok = (nl >= self.min_instances) & (nr >= self.min_instances)
            gain = np.where(ok, cost_tot - (cl + cr), -np.inf)
            if gain.size and np.max(gain) > -np.inf:
                t = int(np.argmax(gain))
                cand = (f.attr, f.kind, t, float(gain[t]))
                if best is None or cand[3] > best[3]:
                    best = cand
        return best

    def _child_masks(self, masks, feat: str, kind: str, thr: int):
        return child_masks(masks, feat, kind, thr)

    # -- inference over materialized rows (test-time only) ---------------------

    def predict(self, rows: Dict[str, np.ndarray]) -> np.ndarray:
        return predict_nodes(self.nodes, rows, self.max_depth)

    def n_split_nodes(self) -> int:
        return sum(1 for n in self.nodes if not n.is_leaf)
