"""Chow-Liu tree structure learning via pairwise mutual information (paper §2).

The MI of every attribute pair needs the 2-D count data cube over {Xi, Xj}
(paper eq. (7)): one count per (i,j) pair, one marginal per attribute, plus
the total — all group-by aggregates over the same join, evaluated as one
LMFAO batch.  This workload is the paper's Example 3.3: multi-root evaluation
turns the O(n²)-view chain into 2n linear-time views.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.api import Database, ExecutionConfig, connect
from repro.core import COUNT, query
from repro.data.datasets import Dataset


@dataclasses.dataclass
class ChowLiuResult:
    attrs: List[str]
    mi: np.ndarray                    # (n, n) pairwise mutual information
    edges: List[Tuple[str, str]]      # the learned tree
    n_aggregates: int = 0


def mi_queries(attrs: Sequence[str]):
    qs = [query("mi_total", [], [COUNT])]
    for a in attrs:
        qs.append(query(f"mi_m_{a}", [a], [COUNT]))
    for i, a in enumerate(attrs):
        for b in attrs[i + 1:]:
            qs.append(query(f"mi_p_{a}_{b}", [a, b], [COUNT]))
    return qs


def mutual_information(joint: np.ndarray, ma: np.ndarray, mb: np.ndarray,
                       total: float) -> float:
    """MI from counts: Σ δ/α · log(α·δ / (β·γ))  (paper's 4-ary f)."""
    d = joint / total
    denom = np.outer(ma, mb) / (total * total)
    with np.errstate(divide="ignore", invalid="ignore"):
        t = d * np.log(d / denom)
    return float(np.nansum(np.where(joint > 0, t, 0.0)))


def chow_liu(ds: Dataset, attrs: Optional[Sequence[str]] = None,
             multi_root: bool = True, block_size: int = 4096,
             backend: str = "xla", interpret: Optional[bool] = None,
             config: Optional[ExecutionConfig] = None,
             database: Optional[Database] = None) -> ChowLiuResult:
    """Learn the Chow-Liu tree.  ``backend``/``block_size`` (or a full
    ``config`` / an open ``database`` session) select the lowering path —
    this workload threads the execution config like every other."""
    attrs = list(attrs if attrs is not None else ds.features_cat)
    qs = mi_queries(attrs)
    db = database or connect(ds, config=config or ExecutionConfig(
        multi_root=multi_root, block_size=block_size, backend=backend,
        interpret=interpret))
    out = {k: np.asarray(v, np.float64) for k, v in db.views(qs).run().items()}

    n = len(attrs)
    total = float(out["mi_total"][0])
    mi = np.zeros((n, n))
    for i, a in enumerate(attrs):
        for j_, b in enumerate(attrs[i + 1:], start=i + 1):
            joint = out[f"mi_p_{a}_{b}"][..., 0]
            v = mutual_information(joint, out[f"mi_m_{a}"][..., 0],
                                   out[f"mi_m_{b}"][..., 0], total)
            mi[i, j_] = mi[j_, i] = v

    # Chow-Liu = maximum spanning tree over MI (Kruskal)
    cand = sorted(((mi[i, j], i, j) for i in range(n) for j in range(i + 1, n)),
                  reverse=True)
    parent = list(range(n))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    edges = []
    for w, i, j in cand:
        ri, rj = find(i), find(j)
        if ri != rj:
            parent[ri] = rj
            edges.append((attrs[i], attrs[j]))
    return ChowLiuResult(attrs=attrs, mi=mi, edges=edges,
                         n_aggregates=len(qs))
