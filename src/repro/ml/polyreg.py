"""Degree-d polynomial regression over joins (paper §2, eq. (5)).

The PR_d covar matrix needs SUM(X^{a_1}·…·X^{a_n}) for every exponent vector
with Σa_j ≤ 2d — the heaviest sharing workload in the paper: most monomial
products are common subexpressions across covar entries, which the engine's
merge layer deduplicates (observe ``stats.n_dedup_hits``).  Degree 2 over the
continuous features (categoricals enter linearly, as in ml/covar.py's
one-hot treatment) is what the experiments exercise.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.api import Database, ExecutionConfig, connect
from repro.core import COUNT, Pow, Var, agg, query
from repro.core.aggregates import Aggregate, ProductAgg, Term
from repro.data.datasets import Dataset

Monomial = Tuple[Tuple[str, int], ...]   # ((attr, power), ...) sorted


def monomials(attrs: Sequence[str], degree: int) -> List[Monomial]:
    """All monomials of total degree ≤ ``degree`` (incl. the constant ())."""
    out: List[Monomial] = []
    for total in range(degree + 1):
        for combo in itertools.combinations_with_replacement(sorted(attrs), total):
            powers: Dict[str, int] = {}
            for a in combo:
                powers[a] = powers.get(a, 0) + 1
            out.append(tuple(sorted(powers.items())))
    # dedupe (combinations_with_replacement already yields unique multisets)
    return list(dict.fromkeys(out))


def _mono_terms(m: Monomial) -> List[Term]:
    terms: List[Term] = []
    for attr, p in m:
        terms.append(Var(attr) if p == 1 else Pow(attr, p))
    return terms


def _mono_product(m1: Monomial, m2: Monomial) -> Monomial:
    powers: Dict[str, int] = {}
    for attr, p in list(m1) + list(m2):
        powers[attr] = powers.get(attr, 0) + p
    return tuple(sorted(powers.items()))


@dataclasses.dataclass
class PolyLayout:
    features: List[Monomial]        # design-matrix columns (incl. constant)
    label: str
    index: Dict[Monomial, int]


def polyreg_queries(ds: Dataset, degree: int = 2,
                    attrs: Optional[Sequence[str]] = None):
    """One query holding every SUM(monomial) the PR_d covar needs."""
    attrs = list(attrs if attrs is not None else ds.features_cont)
    feats = monomials(attrs, degree)
    layout = PolyLayout(feats, ds.label, {m: i for i, m in enumerate(feats)})

    needed: Dict[Monomial, int] = {}
    for i, f in enumerate(feats):
        for g in feats[i:]:
            needed.setdefault(_mono_product(f, g), 0)
        # label column: SUM(f · y)
        needed.setdefault(_mono_product(f, ((ds.label, 1),)), 0)
    mono_list = list(needed)
    aggs = [agg(*_mono_terms(m)) if m else COUNT for m in mono_list]
    q = query(f"pr{degree}_covar", [], aggs)
    return [q], layout, mono_list


def compute_poly_covar(ds: Dataset, degree: int = 2,
                       attrs: Optional[Sequence[str]] = None,
                       block_size: int = 4096, backend: str = "xla",
                       interpret: Optional[bool] = None,
                       config: Optional[ExecutionConfig] = None,
                       database: Optional[Database] = None):
    """Returns (C (p,p), b (p,), N, layout, batch) for the normal equations
    C/N θ = b/N (+ ridge)."""
    qs, layout, mono_list = polyreg_queries(ds, degree, attrs)
    db = database or connect(ds, config=config or ExecutionConfig(
        block_size=block_size, backend=backend, interpret=interpret))
    views = db.views(qs)
    out = np.asarray(views.run()[qs[0].name], np.float64)
    val = {m: out[i] for i, m in enumerate(mono_list)}

    p = len(layout.features)
    C = np.zeros((p, p))
    b = np.zeros(p)
    for i, f in enumerate(layout.features):
        b[i] = val[_mono_product(f, ((ds.label, 1),))]
        for j in range(i, p):
            C[i, j] = C[j, i] = val[_mono_product(f, layout.features[j])]
    N = val[()]
    return C, b, N, layout, views.compiled


def fit_polyreg(ds: Dataset, degree: int = 2, lam: float = 1e-3,
                attrs: Optional[Sequence[str]] = None):
    C, b, N, layout, batch = compute_poly_covar(ds, degree, attrs)
    # feature scaling for conditioning (monomials span wild magnitudes)
    scale = 1.0 / np.sqrt(np.maximum(np.diag(C) / N, 1e-12))
    Cs = C * scale[:, None] * scale[None, :]
    theta_s = np.linalg.solve(Cs / N + lam * np.eye(len(b)), (b * scale) / N)
    theta = theta_s * scale
    return theta, layout, batch


def predict_poly(theta: np.ndarray, layout: PolyLayout,
                 rows: Dict[str, np.ndarray]) -> np.ndarray:
    n = len(next(iter(rows.values())))
    yhat = np.zeros(n)
    for i, m in enumerate(layout.features):
        col = np.ones(n)
        for attr, pw in m:
            col = col * np.asarray(rows[attr], np.float64) ** pw
        yhat += theta[i] * col
    return yhat
