"""Beyond-paper optimized covar path: factorized gather + blocked XᵀX.

For FK-join (star/snowflake) schemas every fact row matches exactly one row
per dimension, so the joined row count equals the fact row count and each
joined feature vector is a *gather*, never an expansion.  The whole covar
batch (hundreds of engine queries) then collapses into one blocked
``C += EᵀE`` over the gathered one-hot-extended feature matrix — the MXU-
native form (DESIGN.md §2); the `kernels/covar_xtx` Pallas kernel is its TPU
implementation and the jnp path below its portable equivalent.

The join is still never materialized as a table: per block we gather O(B·p)
values from the columnar store.  Many-to-many schemas (Yelp's
Category/Attribute) violate the one-match precondition — ``supports_fused``
detects this and callers fall back to the general engine path.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.datasets import Dataset
from repro.ml.covar import CovarLayout, covar_queries


def supports_fused(ds: Dataset) -> bool:
    """True when every non-fact relation is keyed uniquely by its join key(s)
    reachable FK-style from the fact table (each fact row joins exactly one
    row per dimension)."""
    from repro.core.jointree import JoinTree
    tree = JoinTree(ds.schema, ds.edges)
    for rel in tree.nodes:
        if rel == ds.fact:
            continue
        parent = tree.parent(rel, ds.fact)
        keys = sorted(tree.join_attrs(rel, parent))
        cols = [np.asarray(ds.tables[rel][k]) for k in keys]
        n = len(cols[0])
        flat = cols[0].astype(np.int64)
        for c in cols[1:]:
            flat = flat * (c.max() + 1) + c
        if len(np.unique(flat)) != n:
            return False
    return True


def _dim_maps(ds: Dataset) -> Dict[str, Dict]:
    """Per non-fact relation: key attrs + dense key->row lookup tables."""
    from repro.core.jointree import JoinTree
    tree = JoinTree(ds.schema, ds.edges)
    maps = {}
    for rel in tree.nodes:
        if rel == ds.fact:
            continue
        parent = tree.parent(rel, ds.fact)
        keys = sorted(tree.join_attrs(rel, parent))
        doms = [ds.schema.domain(k) for k in keys]
        size = int(np.prod(doms))
        lut = np.zeros(size, dtype=np.int32)
        cols = [np.asarray(ds.tables[rel][k]) for k in keys]
        flat = cols[0].astype(np.int64)
        for c, d in zip(cols[1:], doms[1:]):
            flat = flat * d + c
        lut[flat] = np.arange(len(flat))
        maps[rel] = {"keys": keys, "doms": doms, "lut": jnp.asarray(lut),
                     "parent": parent}
    return maps


def make_fused_covar(ds: Dataset, layout: Optional[CovarLayout] = None,
                     block_size: int = 8192, use_pallas: bool = False):
    """Build a reusable jitted callable computing the (p, p) covar via
    blocked gathered XᵀX.  Returns (fn, layout) with fn() -> (p, p) array."""
    if layout is None:
        _, layout = covar_queries(ds)
    assert supports_fused(ds), "many-to-many join: use the engine path"
    maps = _dim_maps(ds)
    from repro.core.jointree import JoinTree
    tree = JoinTree(ds.schema, ds.edges)

    # resolve, for every feature attr, its relation + row-index expression
    fact_cols = {a: jnp.asarray(np.asarray(c)) for a, c in ds.tables[ds.fact].items()}
    rel_of = {}
    for a in list(layout.cont) + list(layout.cat) + [layout.label]:
        home = min(ds.schema.relations_with(a),
                   key=lambda r: 0 if r == ds.fact else 1)
        rel_of[a] = home

    rel_cols = {r: {a: jnp.asarray(np.asarray(c)) for a, c in t.items()}
                for r, t in ds.tables.items()}
    n = ds.db.relation(ds.fact).n_rows
    p = layout.p

    # chain of gathers fact -> dim (snowflake: dim of dim via parent rows)
    def row_index(rel, fact_block):
        m = maps[rel]
        if m["parent"] == ds.fact:
            key_cols = {k: fact_block[k] for k in m["keys"]}
        else:
            pidx = row_index(m["parent"], fact_block)
            key_cols = {k: rel_cols[m["parent"]][k][pidx] for k in m["keys"]}
        flat = key_cols[m["keys"][0]].astype(jnp.int32)
        for k, d in zip(m["keys"][1:], m["doms"][1:]):
            flat = flat * d + key_cols[k]
        return m["lut"][flat]

    def block_features(fact_block, valid):
        cols = [valid]  # intercept (0 on padding)
        idx_cache = {}
        def col_of(a):
            r = rel_of[a]
            if r == ds.fact:
                return fact_block[a]
            if r not in idx_cache:
                idx_cache[r] = row_index(r, fact_block)
            return rel_cols[r][a][idx_cache[r]]
        for a in layout.cont:
            cols.append(col_of(a).astype(jnp.float32) * valid)
        feats = [jnp.stack(cols, axis=1)]
        for a in layout.cat:
            oh = jax.nn.one_hot(col_of(a), layout.cat_domains[a],
                                dtype=jnp.float32) * valid[:, None]
            feats.append(oh)
        y = col_of(layout.label).astype(jnp.float32) * valid
        feats.append(y[:, None])
        return jnp.concatenate(feats, axis=1)      # (B, p)

    n_pad = ((n + block_size - 1) // block_size) * block_size
    fact_padded = {a: jnp.pad(c, (0, n_pad - n)) for a, c in fact_cols.items()}
    blocked = {a: c.reshape(-1, block_size) for a, c in fact_padded.items()}
    n_blocks = n_pad // block_size

    @jax.jit
    def run(blocked_cols):
        def body(acc, xs):
            blk, bi = xs
            ridx = bi * block_size + jnp.arange(block_size)
            valid = (ridx < n).astype(jnp.float32)
            e = block_features(blk, valid)
            if use_pallas:
                from repro.kernels.covar_xtx import covar_xtx_pallas
                c = covar_xtx_pallas(e, valid, block_rows=block_size,
                                     interpret=True)
            else:
                c = jnp.einsum("bp,bq->pq", e, e)
            return acc + c, None
        acc0 = jnp.zeros((p, p), jnp.float32)
        acc, _ = jax.lax.scan(body, acc0,
                              (blocked_cols, jnp.arange(n_blocks)))
        return acc

    return (lambda: run(blocked)), layout


def compute_covar_fused(ds: Dataset, layout: Optional[CovarLayout] = None,
                        block_size: int = 8192,
                        use_pallas: bool = False) -> Tuple[np.ndarray, float, CovarLayout]:
    """One-shot convenience wrapper around :func:`make_fused_covar`."""
    fn, layout = make_fused_covar(ds, layout, block_size, use_pallas)
    n = ds.db.relation(ds.fact).n_rows
    return np.asarray(fn(), dtype=np.float64), float(n), layout
