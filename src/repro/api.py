"""Session-centric public API: one `Database` facade over every engine mode.

LMFAO's pitch is *one* engine behind every workload — ridge, trees,
Chow-Liu, cubes are all "a batch of group-by aggregates over the join tree"
(PAPER.md) — and this module is where that shows in the API (DESIGN.md §9).
A session owns the schema, join tree, resident relations, and ONE frozen
:class:`ExecutionConfig`; queries become **named views** with a uniform
lifecycle, and batch / frontier-batched / incremental / sharded / served
execution are config and method choices on the *same* compiled artifact,
not four parallel class hierarchies:

    import repro
    db = repro.connect(dataset, config=repro.ExecutionConfig(backend="pallas"))

    v = db.views(queries)                  # compile once
    out = v.run()                          # batch (sharded iff config.mesh)
    out = v.run_batched(params)            # param-batched node frontier
    print(v.explain().summary())           # unified stats report

    m = db.views(queries, maintain=True)   # incremental views
    m.run()                                # full scan -> epoch 0
    m.apply(update)                        # work ∝ |update|
    srv = m.serve(max_pinned_epochs=8)     # epoch-pinned concurrent serving
    m.snapshot(ckpt_dir)                   # crash-safe epoch checkpoint

The legacy entry points (``Engine.compile``, ``Engine.compile_incremental``)
still work but emit :class:`~repro.core.engine.EngineDeprecationWarning`;
they are thin shims over the same internals this facade drives.
"""

from __future__ import annotations

import dataclasses
import time as _time
from typing import Dict, Mapping, Optional, Sequence, Tuple

from repro.core.aggregates import Params, Query
from repro.core.engine import BatchStats, CompiledBatch, Engine
from repro.core.schema import DatabaseSchema
from repro.data import relations as rel_mod

__all__ = ["ExecutionConfig", "Database", "ViewHandle", "ViewReport",
           "connect"]


@dataclasses.dataclass(frozen=True)
class ExecutionConfig:
    """One frozen execution policy for a whole session, threaded once at
    :func:`connect` instead of per-call kwargs.

    Compilation: ``backend`` selects the lowering path ("xla": blocked
    lax.scan; "pallas": MXU kernels, ``interpret`` controlling CPU interpret
    mode — None auto-detects); ``fuse_scans`` toggles shared-scan fusion;
    ``multi_root`` enables the paper's find-roots layer.

    Kernel blocking: ``block_size`` is the outer lax.scan row block,
    ``block_rows`` the Pallas kernel row grid (a positive multiple of 8 —
    the MXU sublane tile).  Either may be the string ``"auto"``: blocking is
    then resolved per scan step by the compile-time autotuner
    (``core/autotune.py``), which times candidate grids against the step's
    signature and persists winners to an on-disk cache
    (``autotune_cache`` path > ``REPRO_AUTOTUNE_CACHE`` env >
    ``~/.cache/repro/autotune.json``) so warm sessions never re-tune; the
    resolution shows up in ``ViewHandle.explain()``.  ``fuse_kernels``
    (default) collapses each step's bucket/hist reductions into ONE fused
    Pallas launch per row block; ``double_buffer`` enables that kernel's
    manual HBM→VMEM DMA pipeline (DESIGN.md §10).

    Placement: a non-None ``mesh`` makes every ``ViewHandle.run`` /
    ``run_batched`` domain-parallel over ``mesh_axis`` (``shard_rel``
    defaults to the largest relation, the paper's choice) — sharding is a
    config choice, not a different method on a different class.  Maintained
    views shard the same way: ``shard_rel`` lives row-partitioned on device
    and every delta tick runs as one cached ``jit(shard_map)``
    (DESIGN.md §6/§8), so serving and maintenance scale together.

    Frontier batching: ``pad_nodes_to_pow2`` rounds the param-batch (node)
    axis up to a power of two so a growing tree frontier hits at most log2
    distinct jit entries.

    Serving: ``max_pinned_epochs`` bounds how many epochs concurrent readers
    may keep device-resident; beyond it the least-recently-used pin is
    evicted (reads of an evicted epoch raise
    :class:`~repro.core.ivm.EpochEvictedError`).

    Telemetry (DESIGN.md §11): ``warn_epoch_lag`` sets the pinned-reader lag
    (served head minus oldest pin) past which the server logs a rate-limited
    warning (None disables); ``workload_capacity`` bounds the session's
    in-memory workload recorder (``Database.workload``) — every run/read
    records its query signature, hit path, and latency there; 0 disables
    recording.

    Verification (DESIGN.md §12): ``verify_plans`` runs the static plan
    verifier (``repro.analysis.verify``) over every compiled artifact —
    group programs, the shared-scan schedule, delta and tick programs,
    resident-relation metadata — raising
    :class:`~repro.analysis.verify.PlanInvariantError` at compile time on
    any violated invariant.  ``None`` (default) auto-enables under pytest
    or when the ``REPRO_VERIFY`` env var is truthy;
    ``Database.views(debug=True)`` forces it on per batch.

    Routing (DESIGN.md §13): ``route_cache_capacity`` bounds the ad-hoc
    query router's LRU cache of serving-time compiled plans
    (``Database.query`` / ``Database.route``); 0 disables caching, so
    every routed miss is answered by a one-shot ``fallback_scan``.
    Plans the router compiles are always admission-gated by the static
    verifier, independent of ``verify_plans``.
    """

    backend: str = "xla"
    block_size: object = 4096               # int | "auto"
    interpret: Optional[bool] = None
    fuse_scans: bool = True
    block_rows: object = 512                # int (multiple of 8) | "auto"
    fuse_kernels: bool = True
    double_buffer: bool = True
    autotune_cache: Optional[str] = None
    multi_root: bool = True
    mesh: Optional[object] = None           # jax.sharding.Mesh
    mesh_axis: str = "data"
    shard_rel: Optional[str] = None
    pad_nodes_to_pow2: bool = True
    max_pinned_epochs: Optional[int] = None
    warn_epoch_lag: Optional[int] = None
    workload_capacity: int = 4096
    verify_plans: Optional[bool] = None
    route_cache_capacity: int = 32

    def __post_init__(self):
        from repro.core.plan import validate_blocking

        if self.backend not in ("xla", "pallas"):
            raise ValueError(f"unknown backend {self.backend!r} "
                             "(expected 'xla' or 'pallas')")
        validate_blocking(self.block_size, self.block_rows)
        if self.max_pinned_epochs is not None and self.max_pinned_epochs < 1:
            raise ValueError("max_pinned_epochs must be >= 1 (or None)")
        if self.warn_epoch_lag is not None and self.warn_epoch_lag < 1:
            raise ValueError("warn_epoch_lag must be >= 1 (or None)")
        if self.verify_plans not in (None, True, False):
            raise ValueError("verify_plans must be True, False, or None "
                             f"(auto); got {self.verify_plans!r}")
        if (not isinstance(self.workload_capacity, int)
                or isinstance(self.workload_capacity, bool)
                or self.workload_capacity < 0):
            raise ValueError("workload_capacity must be an int >= 0 "
                             "(0 disables recording)")
        if (not isinstance(self.route_cache_capacity, int)
                or isinstance(self.route_cache_capacity, bool)
                or self.route_cache_capacity < 0):
            raise ValueError("route_cache_capacity must be an int >= 0 "
                             "(0 disables plan caching)")
        if self.mesh is not None and self.mesh_axis not in self.mesh.shape:
            raise ValueError(f"mesh has no axis {self.mesh_axis!r} "
                             f"(axes: {tuple(self.mesh.shape)})")

    def replace(self, **overrides) -> "ExecutionConfig":
        """A copy with the given fields changed."""
        return dataclasses.replace(self, **overrides)

    def compile_kwargs(self) -> Dict[str, object]:
        """The compile-stage subset, as `Engine._compile` keywords."""
        return dict(multi_root=self.multi_root, block_size=self.block_size,
                    backend=self.backend, interpret=self.interpret,
                    fuse_scans=self.fuse_scans, block_rows=self.block_rows,
                    fuse_kernels=self.fuse_kernels,
                    double_buffer=self.double_buffer,
                    autotune_cache=self.autotune_cache,
                    verify_plans=self.verify_plans)


@dataclasses.dataclass
class ViewReport:
    """Unified ``explain()`` report across execution modes: the compile-time
    layer statistics (paper Table 2) always, plus the IVM epoch counters for
    maintained views and the server counters once ``serve()`` is live."""

    mode: str                    # "batch" | "maintained" | "served"
    backend: str
    sharded: bool
    batch: BatchStats
    # batch-mode device dispatches; None for maintained views (their unit of
    # work is the delta tick: see step / n_delta_scan_steps / n_fold_traces)
    n_dispatches: Optional[int]
    # maintained-view counters (None in batch mode)
    epoch: Optional[int] = None
    step: Optional[int] = None
    n_delta_scan_steps: Optional[int] = None
    n_fold_traces: Optional[int] = None
    n_pinned_epochs: Optional[int] = None
    n_evicted_pins: Optional[int] = None
    max_pinned_epochs: Optional[int] = None
    # full server stats dict (None until serve()) — counters plus the
    # read/tick latency distributions and epoch lag (DESIGN.md §11)
    serving: Optional[Dict[str, object]] = None
    # per-step blocking resolution from the last bind with "auto" blocking
    # (None when blocking is static or nothing has bound yet); the delta
    # variant is the IVM tick resolution — both render, labeled, when set
    autotune: Optional[list] = None
    autotune_delta: Optional[list] = None
    # shard topology for sharded runs (None when config.mesh is None):
    # device count, mesh axis, partitioned relation, per-shard row/capacity
    # geometry, and the psum count per tick (maintained) or per run (batch)
    shard: Optional[Dict[str, object]] = None
    # static-verification coverage (DESIGN.md §12): joined summaries of the
    # plan / delta / tick reports, or None when verification is off
    verification: Optional[str] = None
    # session query-router stats (DESIGN.md §13): tier hit mix, cache
    # occupancy, eviction count — None until Database.query has routed
    # something
    routing: Optional[Dict[str, object]] = None

    @staticmethod
    def _render_autotune(report: list) -> str:
        return ", ".join(
            f"{a['rel']}: bs={a['block_size']} br={a['block_rows']}"
            + (" (cached)" if a["from_cache"] else "")
            + (" (fallback)" if a.get("fallback") else "")
            for a in report)

    @staticmethod
    def _render_latency(label: str, snap: Optional[Dict[str, float]]) -> str:
        if not snap or not snap.get("count"):
            return ""
        return (f" {label}_p50={snap['p50']:.0f}us"
                f" {label}_p99={snap['p99']:.0f}us")

    def summary(self) -> str:
        """Every populated field renders — the line set is keyed on what the
        report carries, not on the mode label, so batch / maintained / served
        handles print consistently."""
        lines = [f"[{self.mode}] backend={self.backend}"
                 f"{' sharded' if self.sharded else ''}"
                 + (f" dispatches={self.n_dispatches}"
                    if self.n_dispatches is not None else ""),
                 "  " + self.batch.summary()]
        if self.shard is not None:
            t = self.shard
            geom = (f" rows/shard={t['rows_per_shard']}"
                    f" cap/shard={t['capacity_per_shard']}"
                    if "rows_per_shard" in t else "")
            if "psums_per_tick" in t:
                psums = " psums/tick={%s}" % ", ".join(
                    f"{r}: {n}" for r, n in t["psums_per_tick"].items())
            else:
                psums = f" psums/run={t['psums_per_run']}"
            lines.append(f"  shard: devices={t['n_devices']} "
                         f"axis={t['mesh_axis']} rel={t['shard_rel']}"
                         + geom + psums)
        if self.step is not None:
            lines.append(
                "  ivm: epoch="
                + ("-" if self.epoch is None else str(self.epoch))
                + f" step={self.step} "
                f"delta_scans={self.n_delta_scan_steps} "
                f"fold_traces={self.n_fold_traces} "
                f"pinned={self.n_pinned_epochs}"
                + (f"/{self.max_pinned_epochs}"
                   if self.max_pinned_epochs else "")
                + f" evicted={self.n_evicted_pins}")
        if self.serving is not None:
            s = self.serving
            lines.append(f"  serve: reads={s['n_reads']} "
                         f"updates={s['n_updates']} "
                         f"rejected={s['n_rejected_updates']} "
                         f"lag={s.get('epoch_lag', 0)}"
                         + self._render_latency("read", s.get("read_us"))
                         + self._render_latency("tick", s.get("tick_us")))
        if self.routing is not None and self.routing.get("n_queries"):
            r = self.routing
            tiers = r["tiers"]
            lines.append(
                f"  routing: n={r['n_queries']} "
                + " ".join(f"{t}={tiers[t]}" for t in
                           ("exact", "subsumed", "compiled", "fallback_scan")
                           if tiers.get(t))
                + f" hit_rate={r['hit_rate']:.2f}"
                  f" cache={r['cache_size']}/{r['capacity']}"
                  f" evicted={r['n_evictions']}")
        if self.verification:
            lines.append("  verify: " + self.verification)
        if self.autotune:
            lines.append("  autotune[batch]: "
                         + self._render_autotune(self.autotune))
        if self.autotune_delta:
            lines.append("  autotune[delta]: "
                         + self._render_autotune(self.autotune_delta))
        return "\n".join(lines)


class ViewHandle:
    """A registered batch of named views — the one handle every execution
    mode dispatches through (create via :meth:`Database.views`).

    Batch views: ``run(params=)`` (one fused device dispatch; domain-parallel
    when the session config carries a mesh), ``run_batched(params)`` (the
    param-batch / node-frontier axis, DESIGN.md §7.4), ``lower()``.

    Maintained views (``maintain=True``): ``run()`` materializes epoch 0 via
    a full scan (later calls read the current epoch), ``apply(update)`` folds
    a delta batch and publishes the next epoch, ``serve()`` wraps the state
    in an epoch-pinning :class:`~repro.serve.views.ViewServer`, and
    ``snapshot()``/``restore()`` checkpoint one clean epoch.

    ``explain()`` returns one :class:`ViewReport` for all of it.
    """

    def __init__(self, database: "Database", compiled: CompiledBatch,
                 maintained=None):
        self._database = database
        self.compiled = compiled        #: the underlying CompiledBatch
        self._maintained = maintained
        self._server = None
        self._sharded = {}              # cached (fn, cols) mesh runners
        self._signatures = None         # lazy {name: QuerySignature}

    # -- workload recording (DESIGN.md §11) ----------------------------------

    def signatures(self) -> Dict[str, "object"]:
        """Structural query signatures per view name (the workload
        recorder's router key; see ``repro.obs.workload``)."""
        if self._signatures is None:
            from repro.obs.workload import signature_of

            self._signatures = {
                q: signature_of(qo.query)
                for q, qo in self.compiled.result.outputs.items()}
        return self._signatures

    def _record(self, kind: str, hit: str, t0: float,
                epoch: Optional[int] = None) -> None:
        rec = self._database.workload
        if not rec.enabled:
            return
        us = (_time.perf_counter() - t0) * 1e6
        for name, sig in self.signatures().items():
            rec.record(kind, name, sig, hit, us, epoch=epoch)

    # -- introspection -------------------------------------------------------

    @property
    def config(self) -> ExecutionConfig:
        return self._database.config

    @property
    def is_maintained(self) -> bool:
        return self._maintained is not None

    @property
    def maintained(self):
        """The underlying :class:`~repro.core.ivm.MaintainedBatch`."""
        if self._maintained is None:
            raise ValueError(
                "views were compiled without maintenance; register them with "
                "db.views(queries, maintain=True) to get apply()/serve()")
        return self._maintained

    @property
    def names(self) -> Tuple[str, ...]:
        """The registered view (query) names, in output order."""
        return tuple(self.compiled.result.outputs)

    @property
    def stats(self) -> BatchStats:
        """Compile-time layer statistics (paper Table 2 analogue)."""
        return self.compiled.stats

    @property
    def schedule(self):
        return self.compiled.schedule

    @property
    def batched_params(self):
        return self.compiled.batched_params

    # -- batch execution -----------------------------------------------------

    def _run_sharded(self, params: Optional[Params],
                     n_nodes: Optional[int] = None):
        """Mesh execution with the runner cached per (shard choice, node
        axis, relation sizes) — repeated ``run()`` calls hit the same jitted
        shard_map program.  The node axis pads to the next power of two
        (``config.pad_nodes_to_pow2``, like the local ``run_batched``) so a
        growing tree frontier reuses at most log2 runners instead of
        rebuilding the collective program every level."""
        import jax.numpy as jnp

        from repro.core.distributed import sharded_runner

        cfg = self.config
        params = dict(params or {})
        plan = self.compiled.plan
        if plan.batched_params and n_nodes is None:
            name = sorted(plan.batched_params)[0]
            n_nodes = int(jnp.shape(params[name])[0])
        n_run = n_nodes
        if n_nodes is not None and cfg.pad_nodes_to_pow2:
            n_run = 1
            while n_run < n_nodes:
                n_run *= 2
            if n_run != n_nodes:
                pad = n_run - n_nodes
                for name in plan.batched_params:
                    v = jnp.asarray(params[name])
                    params[name] = jnp.pad(
                        v, [(0, pad)] + [(0, 0)] * (v.ndim - 1))
        db = self._database.data
        shard_rel = cfg.shard_rel or max(db.sizes(), key=lambda k: db.sizes()[k])
        key = (cfg.mesh_axis, shard_rel, n_run,
               tuple(sorted(db.sizes().items())))
        if key not in self._sharded:
            self._sharded[key] = sharded_runner(plan, db, cfg.mesh,
                                                cfg.mesh_axis, shard_rel,
                                                n_nodes=n_run)
        fn, cols = self._sharded[key]
        self.compiled.n_dispatches += 1
        out = fn(cols, params)
        if n_run != n_nodes and n_nodes is not None:
            batched_vids = plan.batched_vids
            outputs = self.compiled.result.outputs
            out = {q: (v[:n_nodes] if outputs[q].vid in batched_vids else v)
                   for q, v in out.items()}
        return out

    def run(self, params: Optional[Params] = None):
        """Evaluate the views and return ``{name: dense array}``.

        Batch views: one fused device dispatch over the session's relations
        (domain-parallel over ``config.mesh`` when set).  Maintained views:
        the first call runs the full scan and publishes epoch 0; later calls
        read the current epoch (no rescans — use :meth:`apply` to advance)."""
        t0 = _time.perf_counter()
        if self._maintained is not None:
            mb = self._maintained
            if not mb.initialized:
                out = mb.init(self._database.data, params=params)
                self._record("run", "full_scan", t0, epoch=mb.epoch)
                return out
            if params:
                raise ValueError(
                    "maintained views bind params at the initial full scan; "
                    "re-init via handle.maintained.init(db, params=...) to "
                    "change them (a later run() only reads the epoch)")
            out = mb.results()
            self._record("run", "epoch_read", t0, epoch=mb.epoch)
            return out
        if self.config.mesh is not None:
            out = self._run_sharded(params)
            self._record("run", "sharded_scan", t0)
            return out
        out = self.compiled(self._database.data, params)
        self._record("run", "batch_scan", t0)
        return out

    def run_batched(self, params: Params, n_nodes: Optional[int] = None):
        """Evaluate N parameter settings in ONE fused dispatch (the node
        frontier of DESIGN.md §7.4); batched outputs gain a leading N axis.
        Sharded iff the session config carries a mesh."""
        if self._maintained is not None:
            raise ValueError("maintained views do not support the "
                             "param-batch axis; register a batch view")
        if not self.compiled.plan.batched_params:
            raise ValueError("views were compiled without batched params; "
                             "declare Param(..., batched=True) terms first")
        t0 = _time.perf_counter()
        if self.config.mesh is not None:
            out = self._run_sharded(params, n_nodes=n_nodes)
            self._record("run_batched", "sharded_scan", t0)
            return out
        out = self.compiled.run_batched(
            self._database.data, params, n_nodes=n_nodes,
            pad_to_pow2=self.config.pad_nodes_to_pow2)
        self._record("run_batched", "batch_scan", t0)
        return out

    def lower(self, params: Optional[Params] = None,
              n_nodes: Optional[int] = None):
        """Lower without executing (dry-run / HLO inspection)."""
        return self.compiled.lower(self._database.data, params,
                                   n_nodes=n_nodes)

    # -- incremental maintenance ---------------------------------------------

    def apply(self, update, params: Optional[Params] = None):
        """Fold a :class:`~repro.data.relations.DeltaBatchUpdate` into the
        maintained state and publish the next epoch; returns the refreshed
        results.  Initializes (full scan) first if :meth:`run` has not."""
        mb = self.maintained
        if not mb.initialized:
            mb.init(self._database.data)
        return mb.apply(update, params=params)

    def results(self, epoch: Optional[int] = None):
        """Maintained-view outputs read from one epoch's frozen state."""
        return self.maintained.results(epoch=epoch)

    def serve(self, max_pinned_epochs: Optional[int] = None,
              warn_epoch_lag: Optional[int] = None):
        """An epoch-pinning :class:`~repro.serve.views.ViewServer` over the
        maintained state (started — epoch 0 is published if needed).  The
        pin budget defaults to ``config.max_pinned_epochs``, the lag-warning
        threshold to ``config.warn_epoch_lag``; reads record into the
        session's workload recorder (``Database.workload``)."""
        from repro.serve.views import ViewServer

        mb = self.maintained
        if max_pinned_epochs is None:
            max_pinned_epochs = self.config.max_pinned_epochs
        if max_pinned_epochs is not None and max_pinned_epochs < 1:
            raise ValueError("max_pinned_epochs must be >= 1 (or None)")
        if warn_epoch_lag is None:
            warn_epoch_lag = self.config.warn_epoch_lag
        if self._server is None:
            self._server = ViewServer(mb, max_pinned_epochs=max_pinned_epochs,
                                      warn_epoch_lag=warn_epoch_lag,
                                      workload=self._database.workload,
                                      router=self._database.router)
        elif max_pinned_epochs is not None:
            mb.max_pinned_epochs = max_pinned_epochs
        if not mb.initialized:
            self._server.start(self._database.data)
        return self._server

    def snapshot(self, ckpt_dir: str, keep: int = 3,
                 epoch: Optional[int] = None) -> str:
        """Crash-safe checkpoint of one clean epoch of maintained state."""
        return self.maintained.save(ckpt_dir, keep=keep, epoch=epoch)

    def restore(self, ckpt_dir: str, step: Optional[int] = None) -> int:
        """Restore maintained state from a checkpoint (works before any
        ``run()`` — the state skeleton comes from the compiled plan)."""
        return self.maintained.restore(ckpt_dir, step=step)

    # -- unified report ------------------------------------------------------

    def explain(self) -> ViewReport:
        """One report across modes: compile-time layer stats (always), IVM
        epoch counters (maintained views), serving counters (after
        ``serve()``)."""
        cfg = self.config
        rep = ViewReport(
            mode="batch", backend=cfg.backend,
            sharded=cfg.mesh is not None, batch=self.compiled.stats,
            n_dispatches=self.compiled.n_dispatches,
            autotune=self.compiled.plan.last_autotune)
        mb = self._maintained
        if mb is not None:
            rep.mode = "served" if self._server is not None else "maintained"
            rep.n_dispatches = None
            rep.epoch = mb.epoch if mb.initialized else None
            rep.step = mb.step
            rep.n_delta_scan_steps = mb.n_delta_scan_steps
            rep.n_fold_traces = mb.n_fold_traces
            rep.n_pinned_epochs = mb.n_pinned_epochs
            rep.n_evicted_pins = mb.n_evicted_pins
            rep.max_pinned_epochs = mb.max_pinned_epochs
            # both resolutions, labeled — the delta lane no longer shadows
            # the init full scan's
            rep.autotune_delta = self.compiled.plan.last_autotune_delta
            rep.shard = mb.shard_topology()
            if self._server is not None:
                rep.serving = self._server.stats()
        elif cfg.mesh is not None:
            rep.shard = self._shard_topology_batch()
        pieces = []
        if self.compiled.plan.last_verification is not None:
            pieces.append(self.compiled.plan.last_verification.summary())
        if mb is not None:
            pieces.extend(r.summary() for _, r in
                          sorted(mb.last_verifications.items()))
        rep.verification = "; ".join(pieces) if pieces else None
        rep.routing = self._database.routing_stats()
        return rep

    def _shard_topology_batch(self) -> Dict[str, object]:
        """Shard facts for a batch-mode mesh run: the relation the next
        ``run()`` would partition, its per-shard geometry, and how many
        psums one sharded pass issues (one per view of every step scanning
        the partitioned relation — distributed.py's combine rule)."""
        cfg = self.config
        sizes = self._database.sizes()
        shard_rel = cfg.shard_rel or max(sorted(sizes), key=lambda k: sizes[k])
        ndev = int(cfg.mesh.shape[cfg.mesh_axis])
        n = sizes.get(shard_rel, 0)
        return {"n_devices": ndev, "mesh_axis": cfg.mesh_axis,
                "shard_rel": shard_rel, "rows": n,
                "rows_per_shard": -(-n // ndev) if n else 0,
                "capacity_per_shard": -(-max(n, 1) // ndev),
                "psums_per_run": sum(
                    len(step.vids) for step in self.compiled.schedule.steps
                    if step.rel == shard_rel)}


class Database:
    """The session facade: schema + join tree + resident relations + one
    frozen :class:`ExecutionConfig`.  Create via :func:`repro.connect`;
    register query batches as named views with :meth:`views`."""

    def __init__(self, schema: DatabaseSchema, data: rel_mod.Database,
                 edges: Optional[Sequence[Tuple[str, str]]] = None,
                 config: Optional[ExecutionConfig] = None,
                 fact: Optional[str] = None,
                 _engine: Optional[Engine] = None):
        from repro.obs.workload import WorkloadRecorder

        self.schema = schema
        self.data = data                      #: resident relations
        self.config = config or ExecutionConfig()
        self.fact = fact
        self.edges = list(edges) if edges is not None else None
        self._engine = _engine or Engine(schema, edges=edges,
                                         sizes=data.sizes())
        #: session-wide workload recorder (DESIGN.md §11): every view run
        #: and served read lands here; ``workload.export_json(path)`` is
        #: the future view advisor's input (ROADMAP item 2)
        self.workload = WorkloadRecorder(self.config.workload_capacity)
        #: registered view handles, in registration order — the query
        #: router's answerable sources (DESIGN.md §13)
        self._registered = []
        self._router = None

    # -- data access ---------------------------------------------------------

    @property
    def tree(self):
        """The join tree every view batch is pushed down over."""
        return self._engine.tree

    def sizes(self) -> Dict[str, int]:
        return self.data.sizes()

    def relation(self, name: str):
        return self.data.relation(name)

    # -- configuration -------------------------------------------------------

    def with_config(self, **overrides) -> "Database":
        """A sibling session over the same schema/data/join tree with some
        config fields changed (e.g. ``db.with_config(backend="pallas")``) —
        the cheap way to compare backends or toggle sharding."""
        return Database(self.schema, self.data, edges=self.edges,
                        config=self.config.replace(**overrides),
                        fact=self.fact, _engine=self._engine)

    # -- view registration ---------------------------------------------------

    def views(self, queries: Sequence[Query], maintain: bool = False, *,
              roots: Optional[Dict[str, str]] = None,
              warm_rels: Sequence[str] = (),
              debug: bool = False, register: bool = True) -> ViewHandle:
        """Compile a query batch into one :class:`ViewHandle`.

        ``maintain=False``: a batch view — ``run()``/``run_batched()`` scan
        the session's relations on every call.  ``maintain=True``: an
        incrementally maintained view — ``run()`` materializes epoch 0 and
        ``apply(update)`` folds delta batches with work ∝ |update|
        (DESIGN.md §8); ``warm_rels`` pre-builds delta programs.

        ``roots`` overrides the find-roots layer per query (e.g. rooting
        every covar view at the fact table so fact-only update streams stay
        delta-only).  ``debug=True`` forces the static plan verifier on for
        this batch regardless of the session's ``verify_plans`` setting
        (DESIGN.md §12) — ``explain()`` then reports the coverage.

        Registered handles (``register=True``, the default) become the
        query router's answerable sources: :meth:`query` matches routed
        aggregates against them by signature and, for maintained handles,
        by subsumption (DESIGN.md §13).  ``register=False`` keeps a handle
        private (the router uses it for its own cached plans)."""
        cfg = self.config
        if debug and cfg.verify_plans is not True:
            cfg = cfg.replace(verify_plans=True)
        if maintain:
            mb = self._engine._compile_incremental(
                queries, root_override=roots, warm_rels=warm_rels,
                mesh=cfg.mesh, mesh_axis=cfg.mesh_axis,
                shard_rel=cfg.shard_rel, **cfg.compile_kwargs())
            handle = ViewHandle(self, mb.batch, maintained=mb)
        else:
            batch = self._engine._compile(queries, root_override=roots,
                                          **cfg.compile_kwargs())
            handle = ViewHandle(self, batch)
        if register:
            self._registered.append(handle)
        return handle

    def view(self, q: Query, maintain: bool = False, **kw) -> ViewHandle:
        """Single-query convenience wrapper around :meth:`views`."""
        return self.views([q], maintain=maintain, **kw)

    # -- ad-hoc query routing (DESIGN.md §13) --------------------------------

    @property
    def router(self):
        """The session's signature router (created on first use; its LRU
        plan-cache bound comes from ``config.route_cache_capacity``)."""
        if self._router is None:
            from repro.serve.router import QueryRouter

            self._router = QueryRouter(
                self, capacity=self.config.route_cache_capacity)
        return self._router

    def route(self, q: Query, params: Optional[Params] = None):
        """Answer an *arbitrary* group-by aggregate — no prior
        registration — returning a
        :class:`~repro.serve.router.RouteResult` with the value plus
        provenance (tier, answering view, pinned epoch, latency).  Exact
        and subsumed matches answer from registered views (maintained
        sources: one pinned epoch, no base scan); misses compile a fresh
        verified plan and cache it for the next ask."""
        return self.router.route(q, params=params)

    def query(self, q: Query, params: Optional[Params] = None):
        """Value-only front door: ``db.query(q)`` → dense answer tensor
        shaped ``(*[domain(a) for a in q.group_by], n_aggs)``."""
        return self.route(q, params=params).value

    def routing_stats(self) -> Optional[Dict[str, object]]:
        """Router telemetry (tier mix, hit rate, cache occupancy), or
        None if nothing was ever routed in this session."""
        return None if self._router is None else self._router.stats()


def connect(source, config: Optional[ExecutionConfig] = None, *,
            tables: Optional[Mapping[str, Mapping[str, object]]] = None,
            data: Optional[rel_mod.Database] = None,
            edges: Optional[Sequence[Tuple[str, str]]] = None,
            fact: Optional[str] = None) -> Database:
    """Open a session: ``repro.connect(dataset_or_schema, config=...)``.

    ``source`` may be a :class:`~repro.data.datasets.Dataset` (schema, join
    edges, relations, and fact table all come from it), a
    :class:`~repro.data.relations.Database` (schema and relations), or a
    bare :class:`~repro.core.schema.DatabaseSchema` plus either ``data=``
    (a relations Database) or ``tables=`` (numpy column dicts).  ``edges``
    overrides the join tree (otherwise built from relation sizes)."""
    if hasattr(source, "schema") and hasattr(source, "db"):       # Dataset
        return Database(source.schema, source.db,
                        edges=edges if edges is not None else source.edges,
                        config=config,
                        fact=fact if fact is not None else source.fact)
    if isinstance(source, rel_mod.Database):
        return Database(source.schema, source, edges=edges, config=config,
                        fact=fact)
    if isinstance(source, DatabaseSchema):
        if data is None:
            if tables is None:
                raise ValueError("connect(schema, ...) needs data= (a "
                                 "relations Database) or tables= (numpy "
                                 "column dicts)")
            data = rel_mod.from_numpy(source, tables)
        return Database(source, data, edges=edges, config=config, fact=fact)
    raise TypeError(f"cannot connect to {type(source).__name__}: expected a "
                    "Dataset, a relations Database, or a DatabaseSchema")
