"""Paper Table 3: aggregate-batch computation — LMFAO-JAX vs. the
materialize-the-join-then-aggregate strategy (the general-purpose-DBMS
evaluation the paper outperforms).

Workloads per dataset: count; covar matrix (CM); regression-tree node (RT);
pairwise mutual information (MI); 3-dim data cube (DC)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import BENCH_SCALE, row, timeit
from repro.api import connect
from repro.core import COUNT, query
from repro.core.plan import materialize_join
from repro.data import datasets as D
from repro.ml import chowliu, cubes, trees
from repro.ml.covar import covar_queries

ORDERS = {
    "favorita": ["Oil", "Transactions", "Stores", "Sales", "Holiday", "Items"],
    "retailer": ["Census", "Location", "Weather", "Inventory", "Items"],
    "yelp": ["User", "Review", "Business", "Category", "Attribute"],
    "tpcds": ["customer_demographics", "customer", "household_demographics",
              "customer_address", "store_sales", "date_dim", "time_dim", "item",
              "store", "promotion"],
}

MI_ATTRS = {
    "favorita": ["city", "state", "stype", "htype", "locale", "family"],
    "retailer": ["rain", "snow", "rgn_cd", "clim_zn", "category"],
    "yelp": ["b_city", "b_open", "cat", "attr"],
    "tpcds": ["d_moy", "d_dow", "i_category", "cd_gender", "cd_marital",
              "s_city", "p_channel"],
}

CUBE_DIMS = {
    "favorita": (["stype", "locale", "family"], ["units", "txns"]),
    "retailer": (["rgn_cd", "clim_zn", "category"], ["inventoryunits", "maxtemp"]),
    "yelp": (["b_city", "b_open", "cat"], ["stars", "useful"]),
    "tpcds": (["d_moy", "i_category", "s_city"], ["ss_quantity", "ss_sales_price"]),
}


def _naive_group_aggregate(J, group_by, vals_fn, dims):
    vals = vals_fn(J)
    if not group_by:
        return vals.sum(axis=0)
    out = np.zeros(tuple(dims) + vals.shape[1:])
    np.add.at(out, tuple(J[g] for g in group_by), vals)
    return out


def bench(dataset_name: str):
    ds = D.make(dataset_name, scale=BENCH_SCALE)
    db = connect(ds)
    lines = []

    def naive_join():
        return materialize_join(ds.schema, ds.tables, order=ORDERS[dataset_name])

    t_join = timeit(naive_join, warmup=0, iters=1)
    J = naive_join()
    n_join = len(next(iter(J.values())))

    # -- count ---------------------------------------------------------------
    b = db.views([query("cnt", [], [COUNT])])
    t = timeit(lambda: b.run())
    lines.append(row(f"t3/{dataset_name}/count/lmfao", t, f"rows={n_join}"))
    lines.append(row(f"t3/{dataset_name}/count/naive", t_join, "join_materialize"))

    # -- covar matrix ----------------------------------------------------------
    qs, layout = covar_queries(ds)
    b = db.views(qs)
    t = timeit(lambda: b.run())
    n_aggs = b.stats.n_app_aggregates

    def naive_cm():
        Jn = naive_join()
        n = len(Jn[layout.label])
        X = [np.ones(n)]
        X += [np.asarray(Jn[c], np.float64) for c in layout.cont]
        for c in layout.cat:
            oh = np.zeros((n, layout.cat_domains[c]))
            oh[np.arange(n), Jn[c]] = 1
            X += list(oh.T)
        X.append(np.asarray(Jn[layout.label], np.float64))
        Xm = np.stack(X, 1)
        return Xm.T @ Xm

    tn = timeit(naive_cm, warmup=0, iters=1)
    lines.append(row(f"t3/{dataset_name}/covar/lmfao", t,
                     f"aggs={n_aggs};views={b.stats.n_views};speedup={tn / t:.1f}x"))
    lines.append(row(f"t3/{dataset_name}/covar/naive", tn, ""))

    # -- regression-tree node ---------------------------------------------------
    dt = trees.DecisionTree(ds, task="regression", max_depth=1, min_instances=10,
                            max_nodes=1, node_batch=False)
    params = dt._node_params({f.attr: np.ones(f.domain, np.float32)
                              for f in dt.features})
    t = timeit(lambda: dt.batch(ds.db, params=params))

    def naive_rt():
        Jn = naive_join()
        y = np.asarray(Jn[dt.label], np.float64)
        outs = {}
        for f in dt.features:
            st = np.zeros((f.domain, 3))
            np.add.at(st, Jn[f.attr], np.stack([np.ones_like(y), y, y * y], -1))
            outs[f.attr] = st
        return outs

    tn = timeit(naive_rt, warmup=0, iters=1)
    lines.append(row(f"t3/{dataset_name}/rtnode/lmfao", t,
                     f"aggs={dt.n_aggregates};speedup={tn / t:.1f}x"))
    lines.append(row(f"t3/{dataset_name}/rtnode/naive", tn, ""))

    # -- mutual information -------------------------------------------------------
    attrs = MI_ATTRS[dataset_name]
    qs = chowliu.mi_queries(attrs)
    b = db.views(qs)
    t = timeit(lambda: b.run())

    def naive_mi():
        Jn = naive_join()
        outs = {}
        for i, a in enumerate(attrs):
            for bb in attrs[i + 1:]:
                h = np.zeros((ds.schema.domain(a), ds.schema.domain(bb)))
                np.add.at(h, (Jn[a], Jn[bb]), 1.0)
                outs[(a, bb)] = h
        return outs

    tn = timeit(naive_mi, warmup=0, iters=1)
    lines.append(row(f"t3/{dataset_name}/mi/lmfao", t,
                     f"queries={len(qs)};speedup={tn / t:.1f}x"))
    lines.append(row(f"t3/{dataset_name}/mi/naive", tn, ""))

    # -- data cube -----------------------------------------------------------------
    dims, meas = CUBE_DIMS[dataset_name]
    finest = db.views(cubes.cube_queries(dims, meas)[-1:])  # finest cell only
    finest.run()  # warm

    def cube_lmfao():
        import itertools
        fin = np.asarray(finest.run()[cubes.cube_name(dims)], np.float64)
        out = {}
        for r in range(len(dims) + 1):
            for subset in itertools.combinations(dims, r):
                axes = tuple(i for i, d in enumerate(dims) if d not in subset)
                out[subset] = fin.sum(axis=axes) if axes else fin
        return out

    t = timeit(cube_lmfao)

    def naive_dc():
        Jn = naive_join()
        import itertools
        outs = {}
        vals = np.stack([Jn[m] for m in meas], -1).astype(np.float64)
        for r in range(len(dims) + 1):
            for subset in itertools.combinations(dims, r):
                outs[subset] = _naive_group_aggregate(
                    Jn, list(subset), lambda j: vals,
                    [ds.schema.domain(d) for d in subset])
        return outs

    tn = timeit(naive_dc, warmup=0, iters=1)
    lines.append(row(f"t3/{dataset_name}/cube/lmfao", t,
                     f"cells=8;speedup={tn / t:.1f}x"))
    lines.append(row(f"t3/{dataset_name}/cube/naive", tn, ""))
    return lines


def main():
    lines = []
    for name in ["favorita", "retailer", "yelp", "tpcds"]:
        lines += bench(name)
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
