"""Paper Table 2: per-workload layer statistics — application aggregates (A),
intermediate aggregates synthesized by the engine (I), merged views (V), and
view groups (G) for each dataset × workload."""

from __future__ import annotations

from benchmarks.common import BENCH_SCALE, row
from repro.core import Engine
from repro.data import datasets as D
from repro.ml import chowliu, cubes, trees
from repro.ml.covar import covar_queries
from benchmarks.bench_table3_aggregates import CUBE_DIMS, MI_ATTRS


def stats_for(ds, queries):
    eng = Engine(ds.schema, edges=ds.edges, sizes=ds.db.sizes())
    b = eng.compile(queries)
    s = b.stats
    return s


def main():
    lines = []
    for name in ["favorita", "retailer", "yelp", "tpcds"]:
        ds = D.make(name, scale=BENCH_SCALE)

        qs, _ = covar_queries(ds)
        s = stats_for(ds, qs)
        lines.append(row(f"t2/{name}/CM", 0.0,
                         f"A={s.n_app_aggregates};I={s.n_intermediate_cols};"
                         f"V={s.n_views};G={s.n_groups};premerge={s.n_views_premerge}"))

        dt = trees.DecisionTree(ds, task="regression", max_depth=1,
                                min_instances=10, max_nodes=1)
        s = dt.batch.stats
        lines.append(row(f"t2/{name}/RT", 0.0,
                         f"A={s.n_app_aggregates};I={s.n_intermediate_cols};"
                         f"V={s.n_views};G={s.n_groups};premerge={s.n_views_premerge}"))

        s = stats_for(ds, chowliu.mi_queries(MI_ATTRS[name]))
        lines.append(row(f"t2/{name}/MI", 0.0,
                         f"A={s.n_app_aggregates};I={s.n_intermediate_cols};"
                         f"V={s.n_views};G={s.n_groups};premerge={s.n_views_premerge}"))

        dims, meas = CUBE_DIMS[name]
        s = stats_for(ds, cubes.cube_queries(dims, meas))
        lines.append(row(f"t2/{name}/DC", 0.0,
                         f"A={s.n_app_aggregates};I={s.n_intermediate_cols};"
                         f"V={s.n_views};G={s.n_groups};premerge={s.n_views_premerge}"))
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
