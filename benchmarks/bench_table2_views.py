"""Paper Table 2: per-workload layer statistics — application aggregates (A),
intermediate aggregates synthesized by the engine (I), merged views (V), view
groups (G), and relation scans before/after the scheduler's shared-scan
fusion for each dataset × workload."""

from __future__ import annotations

from benchmarks.common import BENCH_SCALE, row
from repro.api import connect
from repro.data import datasets as D
from repro.ml import chowliu, cubes, trees
from repro.ml.covar import covar_queries
from benchmarks.bench_table3_aggregates import CUBE_DIMS, MI_ATTRS


def stats_for(ds, queries):
    return connect(ds).views(queries).stats


def fmt(s) -> str:
    # scans: one per view group before fusion vs fused scheduler steps after
    return (f"A={s.n_app_aggregates};I={s.n_intermediate_cols};"
            f"V={s.n_views};G={s.n_groups};premerge={s.n_views_premerge};"
            f"scans_pre={s.n_groups};scans_post={s.n_scan_steps};"
            f"fused={s.n_fused_scans}")


def main():
    lines = []
    for name in ["favorita", "retailer", "yelp", "tpcds"]:
        ds = D.make(name, scale=BENCH_SCALE)

        qs, _ = covar_queries(ds)
        lines.append(row(f"t2/{name}/CM", 0.0, fmt(stats_for(ds, qs))))

        dt = trees.DecisionTree(ds, task="regression", max_depth=1,
                                min_instances=10, max_nodes=1)
        lines.append(row(f"t2/{name}/RT", 0.0, fmt(dt.batch.stats)))

        s = stats_for(ds, chowliu.mi_queries(MI_ATTRS[name]))
        lines.append(row(f"t2/{name}/MI", 0.0, fmt(s)))

        dims, meas = CUBE_DIMS[name]
        s = stats_for(ds, cubes.cube_queries(dims, meas))
        lines.append(row(f"t2/{name}/DC", 0.0, fmt(s)))
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
