"""§Perf hillclimb — Cell A: the paper's flagship workload (covar batch).

Hypothesis → change → measure loop on real CPU wall-clock (the engine is the
one component that *runs* here, not just lowers).  Results append to
EXPERIMENTS.md §Perf by hand; JSON to reports/perf_engine.json.

    PYTHONPATH=src python -m benchmarks.perf_engine [--scale 1.0]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.common import timeit
from repro.api import connect
from repro.data import datasets as D
from repro.ml.covar import assemble_covar, covar_queries
from repro.ml.covar_fused import compute_covar_fused, supports_fused


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--dataset", default="favorita")
    args = ap.parse_args(argv)

    ds = D.make(args.dataset, scale=args.scale)
    qs, layout = covar_queries(ds)
    db = connect(ds)
    results = {}
    n_fact = ds.db.relation(ds.fact).n_rows
    print(f"[perf] dataset={args.dataset} scale={args.scale} "
          f"fact_rows={n_fact:,} p={layout.p}")

    # -- baseline: paper-faithful engine path (multi-root, block 4096) -------
    b0 = db.views(qs)
    out0 = b0.run()
    C0, N0 = assemble_covar({k: np.asarray(v) for k, v in out0.items()}, layout)
    t0 = timeit(lambda: b0.run())
    results["baseline_block4096"] = t0
    print(f"[perf] baseline (engine, multi-root, block=4096): {t0:.3f}s")

    # -- iteration 1: block size ---------------------------------------------
    for bs in (1024, 16384, 65536):
        bb = db.with_config(block_size=bs).views(qs)
        bb.run()
        t = timeit(lambda: bb.run())
        results[f"block{bs}"] = t
        print(f"[perf] block_size={bs}: {t:.3f}s ({t0 / t:.2f}x vs baseline)")

    # -- iteration 2: single-root ablation (negative control) ----------------
    bsr = db.with_config(multi_root=False).views(qs)
    bsr.run()
    t = timeit(lambda: bsr.run())
    results["single_root"] = t
    print(f"[perf] single-root: {t:.3f}s ({t0 / t:.2f}x vs baseline)")

    # -- iteration 3: beyond-paper fused gathered XtX -------------------------
    if supports_fused(ds):
        from repro.ml.covar_fused import make_fused_covar
        for fbs in (8192, 32768):
            fn, _ = make_fused_covar(ds, layout, block_size=fbs)
            C1 = np.asarray(fn(), np.float64)
            err = np.abs(C1 - C0).max() / max(1.0, np.abs(C0).max())
            assert err < 1e-4, f"fused path disagrees with engine ({err})"
            t = timeit(fn)
            results[f"fused_xtx_block{fbs}"] = t
            print(f"[perf] fused gathered-XtX block={fbs}: {t:.3f}s "
                  f"({t0 / t:.2f}x vs baseline, correct to {err:.1e})")
    else:
        print("[perf] fused path unsupported (many-to-many joins)")

    os.makedirs("reports", exist_ok=True)
    with open("reports/perf_engine.json", "w") as f:
        json.dump({"dataset": args.dataset, "scale": args.scale,
                   "fact_rows": n_fact, "results": results}, f, indent=1)
    return results


if __name__ == "__main__":
    main()
