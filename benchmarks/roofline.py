import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline analysis from compiled dry-run artifacts (EXPERIMENTS.md §Roofline).

    compute    term = HLO_FLOPs / (chips × 197 TF/s bf16)
    memory     term = HLO_bytes / (chips × 819 GB/s HBM)
    collective term = collective_bytes / (chips × 50 GB/s ICI link)

XLA's cost analysis counts while-loop bodies ONCE (verified), so the scanned
production build under-reports loop costs.  This harness therefore lowers
each cell twice at small *unrolled* depths (scan_unroll=True, single-chunk CE,
dense attention, no grad-accum loop) and extrapolates per-layer costs
linearly to the full depth — per-layer HLO is depth-invariant, so the
two-point fit is exact up to the constant (embedding/head) term.

    PYTHONPATH=src python -m benchmarks.roofline --arch llama3-8b --shape train_4k
    PYTHONPATH=src python -m benchmarks.roofline --table   # aggregate markdown
"""

import argparse
import dataclasses
import json
import sys
import time
from typing import Any, Dict, Optional, Tuple

#: per-chip peak defaults by host platform.  TPU numbers are v5e-class (per
#: brief); CPU numbers are honest host-class ceilings so CI utilization
#: reports are meaningful instead of vanishing against TPU constants.
PLATFORM_PEAKS = {
    "tpu": {"flops": 197e12, "hbm_bw": 819e9, "link_bw": 50e9},
    "gpu": {"flops": 312e12, "hbm_bw": 2039e9, "link_bw": 300e9},  # A100-class
    "cpu": {"flops": 2e11,   "hbm_bw": 40e9,   "link_bw": 10e9},   # host-class
}


def detect_platform() -> str:
    """The host accelerator platform (``jax.default_backend()``), "cpu" when
    jax is unavailable.  Overridable via ``REPRO_PLATFORM``."""
    env = os.environ.get("REPRO_PLATFORM")
    if env:
        return env
    try:
        import jax
        return jax.default_backend()
    except Exception:
        return "cpu"


def peaks(platform: Optional[str] = None, *,
          flops: Optional[float] = None, hbm_bw: Optional[float] = None,
          link_bw: Optional[float] = None) -> Dict[str, Any]:
    """Per-chip peak FLOPs / HBM / link bandwidth for ``platform`` (default:
    auto-detected host backend).  Precedence per value: explicit argument >
    env (``REPRO_PEAK_FLOPS`` / ``REPRO_HBM_BW`` / ``REPRO_LINK_BW``) >
    platform table."""
    plat = platform or detect_platform()
    base = PLATFORM_PEAKS.get(plat, PLATFORM_PEAKS["cpu"])

    def pick(arg, env_key, table_val):
        if arg is not None:
            return float(arg)
        env = os.environ.get(env_key)
        return float(env) if env else float(table_val)

    return {"platform": plat,
            "flops": pick(flops, "REPRO_PEAK_FLOPS", base["flops"]),
            "hbm_bw": pick(hbm_bw, "REPRO_HBM_BW", base["hbm_bw"]),
            "link_bw": pick(link_bw, "REPRO_LINK_BW", base["link_bw"])}


_P = peaks()
PEAK_FLOPS = _P["flops"]    # per chip (auto-detected platform; env-overridable)
HBM_BW = _P["hbm_bw"]       # B/s per chip
LINK_BW = _P["link_bw"]     # B/s per ICI link


def measure_costs(arch: str, shape_name: str, n_layers: int,
                  enc_layers: Optional[int] = None,
                  overrides: Optional[Dict[str, Any]] = None,
                  rules=None) -> Dict[str, float]:
    """Lower+compile one unrolled measurement build; return per-device costs."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import configs
    from repro.configs.shapes import SHAPES
    from repro.distributed.sharding import param_pspecs, rules_for, spec_for
    from repro.launch.dryrun import collective_bytes_from_hlo, input_specs
    from repro.launch.mesh import make_production_mesh
    from repro.models import model as M
    from repro.models.layers import abstract_params
    from repro.serve.engine import make_serve_step
    from repro.train.step import (TrainConfig, abstract_state, batch_pspecs,
                                  make_prefill_step, make_train_step,
                                  state_pspecs)

    # measurement build: unrolled scans, the *deployed* chunked attention
    # (bigger chunks keep unrolled HLO small), single-chunk CE, no accum loop
    cfg = configs.get(arch).with_(scan_unroll=True, n_layers=n_layers,
                                  attn_chunk=4096)
    if enc_layers is not None:
        cfg = cfg.with_(encoder_layers=enc_layers)
    if overrides:
        cfg = cfg.with_(**overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=False)
    rules = rules or rules_for(mesh)
    tcfg = TrainConfig(ce_chunk=shape.seq_len, grad_accum=1, attn_impl="chunked")
    shardify = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t)

    if shape.kind == "train" and shape.name != "prefill_32k":
        step = make_train_step(cfg, tcfg, mesh, rules=rules)
        jitted = jax.jit(step, in_shardings=(
            shardify(state_pspecs(cfg, tcfg, mesh)),
            shardify(batch_pspecs(cfg, mesh))), donate_argnums=(0,))
        lowered = jitted.lower(abstract_state(cfg, tcfg),
                               input_specs(cfg, shape, mesh))
    elif shape.name == "prefill_32k":
        step = make_prefill_step(cfg, tcfg, mesh, rules=rules)
        jitted = jax.jit(step, in_shardings=(
            shardify(param_pspecs(M.model_specs(cfg), rules, mesh)),
            shardify(batch_pspecs(cfg, mesh))))
        lowered = jitted.lower(abstract_params(M.model_specs(cfg), cfg.jdtype),
                               input_specs(cfg, shape, mesh))
    else:
        step = make_serve_step(cfg, mesh)
        ins = input_specs(cfg, shape, mesh)
        cspec = param_pspecs(M.cache_specs(cfg, shape.global_batch, shape.seq_len),
                             rules, mesh)
        in_sh = (shardify(param_pspecs(M.model_specs(cfg), rules, mesh)),
                 shardify(cspec),
                 NamedSharding(mesh, spec_for(("batch", None), rules,
                                              ins["tokens"].shape, mesh)),
                 NamedSharding(mesh, P()))
        args = (abstract_params(M.model_specs(cfg), cfg.jdtype), ins["cache"],
                ins["tokens"], ins["pos"])
        if "context" in ins:
            in_sh = in_sh + (NamedSharding(
                mesh, spec_for(("batch", None, None), rules,
                               ins["context"].shape, mesh)),)
            args = args + (ins["context"],)
        jitted = jax.jit(step, in_shardings=in_sh)
        lowered = jitted.lower(*args)

    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    cost = dict(cost or {})
    coll = collective_bytes_from_hlo(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(coll["total"]),
            "coll_ar": float(coll["all-reduce"]),
            "coll_ag": float(coll["all-gather"]),
            "coll_rs": float(coll["reduce-scatter"]),
            "coll_a2a": float(coll["all-to-all"]),
            "coll_cp": float(coll["collective-permute"])}


def layer_points(cfg) -> Tuple[Dict, Dict, float, float]:
    """Two measurement depths + their 'unit' counts for extrapolation."""
    fam = cfg.family
    if fam == "hybrid":
        tail = cfg.n_layers % cfg.attn_every
        u1, u2 = 1, 2
        L1, L2 = cfg.attn_every * u1 + tail, cfg.attn_every * u2 + tail
        units_full = cfg.n_layers // cfg.attn_every
    elif fam == "vlm":
        u1, u2 = 1, 2
        L1, L2 = cfg.cross_every * u1, cfg.cross_every * u2
        units_full = cfg.n_layers // cfg.cross_every
    else:
        u1, u2 = 1, 3
        L1, L2 = 1, 3
        units_full = cfg.n_layers
    return L1, L2, (u1, u2), units_full


def extrapolate(c1: Dict[str, float], c2: Dict[str, float], u1: float, u2: float,
                units_full: float) -> Dict[str, float]:
    out = {}
    for k in c1:
        delta = (c2[k] - c1[k]) / (u2 - u1)
        out[k] = max(c1[k] + delta * (units_full - u1), 0.0)
    return out


def roofline_cell(arch: str, shape_name: str) -> Dict[str, Any]:
    from repro import configs
    from repro.configs.shapes import SHAPES, applicable

    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped", "why": why}

    t0 = time.time()
    if cfg.family == "audio" and shape.kind == "train":
        c11 = measure_costs(arch, shape_name, 1, enc_layers=1)
        c31 = measure_costs(arch, shape_name, 3, enc_layers=1)
        c13 = measure_costs(arch, shape_name, 1, enc_layers=3)
        dec = {k: (c31[k] - c11[k]) / 2 for k in c11}
        enc = {k: (c13[k] - c11[k]) / 2 for k in c11}
        costs = {k: max(c11[k] + dec[k] * (cfg.n_layers - 1)
                        + enc[k] * (cfg.encoder_layers - 1), 0.0) for k in c11}
    else:
        L1, L2, (u1, u2), units_full = layer_points(cfg)
        c1 = measure_costs(arch, shape_name, L1)
        c2 = measure_costs(arch, shape_name, L2)
        costs = extrapolate(c1, c2, u1, u2, units_full)

    n_dev = 256
    t_comp = costs["flops"] / PEAK_FLOPS
    t_mem = costs["bytes"] / HBM_BW
    t_coll = costs["coll"] / LINK_BW
    dominant = max(("compute", t_comp), ("memory", t_mem),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]

    # MODEL_FLOPS: 6·N·D train, 2·N·D forward-only (prefill/decode)
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind == "train" else 1)
    mult = 6.0 if (shape.kind == "train" and shape.name != "prefill_32k") else 2.0
    model_flops = mult * n_active * tokens
    hlo_flops_global = costs["flops"] * n_dev
    useful = model_flops / hlo_flops_global if hlo_flops_global else 0.0
    t_useful = model_flops / (n_dev * PEAK_FLOPS)
    bottleneck_t = max(t_comp, t_mem, t_coll)
    frac = t_useful / bottleneck_t if bottleneck_t > 0 else 0.0

    return {"arch": arch, "shape": shape_name, "status": "ok",
            "measure_s": round(time.time() - t0, 1),
            "per_device": costs,
            "terms_s": {"compute": t_comp, "memory": t_mem, "collective": t_coll},
            "dominant": dominant,
            "model_flops": model_flops,
            "hlo_flops_global": hlo_flops_global,
            "useful_flop_ratio": useful,
            "roofline_fraction": frac}


LEVERS = {
    "compute": "cut recompute (remat policy) / raise MXU utilization via fusion",
    "memory": "widen arithmetic intensity: fuse elementwise chains, bf16 "
              "intermediates, larger effective tiles",
    "collective": "reshard to cut all-gathers (sequence- vs tensor-parallel "
                  "balance), overlap collectives with compute",
}


def write_table(report_dir: str, out_md: str):
    import glob
    rows = []
    for f in sorted(glob.glob(os.path.join(report_dir, "*.json"))):
        rows.append(json.load(open(f)))
    lines = ["| arch | shape | compute s | memory s | collective s | dominant | "
             "MODEL/HLO flops | roofline frac | lever |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | "
                         f"{r['why']} |")
            continue
        if r.get("status") != "ok":
            continue
        t = r["terms_s"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute']:.3e} | {t['memory']:.3e} "
            f"| {t['collective']:.3e} | **{r['dominant']}** "
            f"| {r['useful_flop_ratio']:.2f} | {r['roofline_fraction']:.2f} "
            f"| {LEVERS[r['dominant']]} |")
    md = "\n".join(lines)
    with open(out_md, "w") as f:
        f.write(md + "\n")
    print(md)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--out", default="reports/roofline")
    ap.add_argument("--table", action="store_true")
    ap.add_argument("--platform", help="peak table to use (tpu/gpu/cpu; "
                    "default: auto-detected host backend)")
    ap.add_argument("--peak-flops", type=float, help="per-chip peak FLOP/s")
    ap.add_argument("--hbm-bw", type=float, help="per-chip HBM B/s")
    ap.add_argument("--link-bw", type=float, help="per-link ICI B/s")
    args = ap.parse_args(argv)

    global PEAK_FLOPS, HBM_BW, LINK_BW
    p = peaks(args.platform, flops=args.peak_flops, hbm_bw=args.hbm_bw,
              link_bw=args.link_bw)
    PEAK_FLOPS, HBM_BW, LINK_BW = p["flops"], p["hbm_bw"], p["link_bw"]

    if args.table:
        write_table(args.out, os.path.join(args.out, "roofline_table.md"))
        return

    os.makedirs(args.out, exist_ok=True)
    rec = roofline_cell(args.arch, args.shape)
    tag = f"{args.arch}__{args.shape}"
    with open(os.path.join(args.out, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    if rec["status"] == "ok":
        t = rec["terms_s"]
        print(f"[roofline] {tag}: comp={t['compute']:.3e}s mem={t['memory']:.3e}s "
              f"coll={t['collective']:.3e}s dom={rec['dominant']} "
              f"frac={rec['roofline_fraction']:.3f}", flush=True)
    else:
        print(f"[roofline] {tag}: {rec['status']} {rec.get('why', '')}", flush=True)


if __name__ == "__main__":
    main()
