"""Regenerate the §Dry-run and §Roofline tables of EXPERIMENTS.md from
reports/dryrun/*.json and reports/roofline/*.json.

    PYTHONPATH=src python -m benchmarks.report_experiments
"""

from __future__ import annotations

import glob
import json
import os

ARCHS = ["zamba2-1.2b", "llama-3.2-vision-90b", "mamba2-2.7b",
         "qwen3-moe-235b-a22b", "deepseek-v2-lite-16b", "h2o-danube-3-4b",
         "minicpm-2b", "internlm2-1.8b", "llama3-8b", "whisper-small"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def _load(path):
    try:
        return json.load(open(path))
    except Exception:
        return None


def _fmt_b(x):
    if x >= 1e9:
        return f"{x / 1e9:.2f} GB"
    if x >= 1e6:
        return f"{x / 1e6:.1f} MB"
    return f"{x / 1e3:.0f} KB"


def dryrun_table(d="reports/dryrun"):
    lines = ["| arch | shape | mesh | status | compile s | HLO flops/dev | "
             "HLO bytes/dev | collective B/dev | temp bytes/dev |",
             "|---|---|---|---|---|---|---|---|---|"]
    for a in ARCHS:
        for s in SHAPES:
            for m in ("single", "multi"):
                r = _load(os.path.join(d, f"{a}__{s}__{m}.json"))
                if r is None:
                    lines.append(f"| {a} | {s} | {m} | MISSING | | | | | |")
                    continue
                if r["status"] == "skipped":
                    lines.append(f"| {a} | {s} | {m} | skipped | | | | | "
                                 f"{r['why']} |"[:-2] + "|")
                    continue
                cost = r.get("cost", {})
                coll = r.get("collectives", {})
                mem = r.get("memory", {}) if isinstance(r.get("memory"), dict) else {}
                lines.append(
                    f"| {a} | {s} | {m} | {r['status']} | {r.get('compile_s', '')} "
                    f"| {cost.get('flops', 0):.3e} | {cost.get('bytes accessed', 0):.3e} "
                    f"| {coll.get('total', 0):.3e} | {_fmt_b(mem.get('temp_bytes', 0))} |")
    return "\n".join(lines)


def roofline_table(d="reports/roofline"):
    lines = ["| arch | shape | compute s | memory s | collective s | dominant "
             "| MODEL/HLO | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    fracs = []
    for a in ARCHS:
        for s in SHAPES:
            r = _load(os.path.join(d, f"{a}__{s}.json"))
            if r is None:
                lines.append(f"| {a} | {s} | pending | | | | | |")
                continue
            if r["status"] == "skipped":
                lines.append(f"| {a} | {s} | — | — | — | — | — | skip (full attn) |")
                continue
            t = r["terms_s"]
            fracs.append((r["roofline_fraction"], a, s, r["dominant"]))
            lines.append(
                f"| {a} | {s} | {t['compute']:.3e} | {t['memory']:.3e} | "
                f"{t['collective']:.3e} | **{r['dominant']}** | "
                f"{r['useful_flop_ratio']:.2f} | {r['roofline_fraction']:.3f} |")
    summary = ""
    if fracs:
        fracs.sort()
        worst = fracs[:3]
        summary = ("\n\nWorst roofline fractions (hillclimb candidates): " +
                   "; ".join(f"{a}/{s} = {f:.3f} ({d}-bound)"
                             for f, a, s, d in worst))
    return "\n".join(lines) + summary


def main():
    print("## §Dry-run\n")
    print(dryrun_table())
    print("\n## §Roofline\n")
    print(roofline_table())


if __name__ == "__main__":
    main()
