"""§Perf hillclimb — Cell C: qwen3-moe-235b train_4k (collective-bound).

Levers on the collective term (napkin math in EXPERIMENTS.md §Perf):

  H1  sequence-parallel OFF    — block boundaries stop resharding seq over
      'model'; removes per-layer seq all-gathers but raises activation
      memory (negative control on memory term)
  H2  capacity dim replicated  — MoE buckets stop sharding over 'data';
      removes the dispatch resharding collectives, costs bucket memory
  H3  bf16 dispatch one-hot    — memory lever, collective-neutral
  H4  best combination

    PYTHONPATH=src python -m benchmarks.perf_moe
"""

from __future__ import annotations

import argparse
import json
import os

from benchmarks.perf_ssd import run_variant, terms
from benchmarks.roofline import extrapolate, measure_costs


def run_rules_variant(arch, shape, name, overrides, rules_patch):
    from repro import configs
    from repro.distributed.sharding import rules_for
    from repro.launch.mesh import make_production_mesh
    import os as _os
    cfg = configs.get(arch)
    # rules built against the single-pod mesh + patch
    import jax
    mesh = make_production_mesh(multi_pod=False)
    rules = dict(rules_for(mesh))
    rules.update(rules_patch)
    c1 = measure_costs(arch, shape, 1, overrides=overrides, rules=rules)
    c2 = measure_costs(arch, shape, 3, overrides=overrides, rules=rules)
    costs = extrapolate(c1, c2, 1, 3, cfg.n_layers)
    t = terms(costs)
    dom = max(t, key=t.get)
    print(f"[perf-moe] {name:28s} comp={t['compute']:.3e}s mem={t['memory']:.3e}s "
          f"coll={t['collective']:.3e}s dom={dom}", flush=True)
    return {"name": name, "terms": t, "dominant": dom, "costs": costs}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-235b-a22b")
    ap.add_argument("--shape", default="train_4k")
    args = ap.parse_args(argv)

    out = []
    out.append(run_rules_variant(args.arch, args.shape, "baseline", {}, {}))
    out.append(run_rules_variant(args.arch, args.shape, "H1_no_seq_parallel",
                                 {}, {"seq": None}))
    out.append(run_rules_variant(args.arch, args.shape, "H2_capacity_replicated",
                                 {}, {"capacity": None}))
    os.makedirs("reports", exist_ok=True)
    with open(f"reports/perf_moe_{args.arch}_{args.shape}.json", "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
