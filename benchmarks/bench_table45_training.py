"""Paper Tables 4/5: end-to-end model training over joins.

LMFAO path: aggregate batch (sufficient statistics) + cheap convergence step,
never materializing the join.  Baseline ("ML-library") path: materialize the
join, build the design matrix, then solve — what TensorFlow/MADlib/scikit do.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import BENCH_SCALE, row, timeit
from repro.core.plan import materialize_join
from repro.data import datasets as D
from repro.ml import ridge, trees
from repro.ml.covar import compute_covar
from benchmarks.bench_table3_aggregates import ORDERS


def bench_ridge(name: str):
    ds = D.make(name, scale=BENCH_SCALE)
    # compile once (the paper reports warm runs; its compile overhead is
    # reported separately), then time the full covar+assemble+BGD pipeline
    from repro.api import connect
    from repro.ml.covar import assemble_covar, covar_queries
    import numpy as _np
    qs, layout = covar_queries(ds)
    views = connect(ds).views(qs)
    views.run()  # warm/compile

    def lmfao_path():
        out = {k: _np.asarray(v) for k, v in views.run().items()}
        C, N = assemble_covar(out, layout)
        res = ridge.bgd(C, N, layout, lam=1e-3, max_iters=500)
        return res.theta, layout

    t = timeit(lmfao_path, warmup=1, iters=2)

    def baseline_path():
        J = materialize_join(ds.schema, ds.tables, order=ORDERS[name])
        n = len(J[ds.label])
        X = [np.ones(n)]
        X += [np.asarray(J[c], np.float64) for c in ds.features_cont]
        for c in ds.features_cat:
            oh = np.zeros((n, ds.schema.domain(c)))
            oh[np.arange(n), J[c]] = 1
            X += list(oh.T)
        Xm = np.stack(X, 1)
        y = np.asarray(J[ds.label], np.float64)
        A = Xm.T @ Xm / n + 1e-3 * np.eye(Xm.shape[1])
        return np.linalg.solve(A, Xm.T @ y / n)

    tn = timeit(baseline_path, warmup=0, iters=1)

    # accuracy parity check (paper: same accuracy as the closed form)
    theta, layout = lmfao_path()
    J = materialize_join(ds.schema, ds.tables, order=ORDERS[name])
    r_lmfao = ridge.rmse(theta, layout, J)
    return [row(f"t4/{name}/ridge/lmfao", t,
                f"rmse={r_lmfao:.4f};speedup={tn / t:.1f}x"),
            row(f"t4/{name}/ridge/baseline", tn, "materialize+solve")]


def bench_tree(name: str, task: str, label=None):
    ds = D.make(name, scale=BENCH_SCALE)
    kw = dict(max_depth=4, min_instances=max(10, int(1000 * BENCH_SCALE)),
              max_nodes=31)

    dt_once = trees.DecisionTree(ds, task=task, label=label, **kw)

    def lmfao_path():
        return dt_once.fit()     # fit() resets and reuses the compiled batch

    t = timeit(lmfao_path, warmup=1, iters=2)

    def baseline_path():
        J = materialize_join(ds.schema, ds.tables, order=ORDERS[name])
        dt = trees.DecisionTree(ds, task=task, label=label, **kw)
        # baseline computes every node's histograms straight off the
        # materialized join (numpy; the ML-library strategy)
        y = np.asarray(J[dt.label], np.float64)
        masks = [np.ones(len(y), bool)]
        for _ in range(15):
            m = masks.pop(0) if masks else np.ones(len(y), bool)
            for f in dt.features:
                st = np.zeros((f.domain, 3))
                np.add.at(st, np.asarray(J[f.attr])[m],
                          np.stack([np.ones(m.sum()), y[m], y[m] ** 2], -1))
        return True

    tn = timeit(baseline_path, warmup=0, iters=1)
    dt = lmfao_path()
    tag = "t4" if task == "regression" else "t5"
    return [row(f"{tag}/{name}/{task}tree/lmfao", t,
                f"splits={dt.n_split_nodes()};speedup={tn / t:.1f}x"),
            row(f"{tag}/{name}/{task}tree/baseline", tn, "")]


def main():
    lines = []
    for name in ["retailer", "favorita"]:
        lines += bench_ridge(name)
        lines += bench_tree(name, "regression")
    lines += bench_tree("tpcds", "classification", label="c_preferred")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
