"""Kernel roofline harness: achieved vs peak FLOPs and bandwidth, per kernel
and per end-to-end batch (ISSUE 6 / ROADMAP item 5).

Per kernel (``seg_aggregate``, ``tree_hist``, the whole-step
``fused_scan_block``, ``covar_xtx``): analytic FLOP/byte counts over a fixed
shape, warm median wall time, and utilization against the host platform's
peaks from ``benchmarks.roofline.peaks()`` (auto-detected backend;
env/CLI-overridable — CPU CI reports against honest host ceilings, not TPU
constants).  Every kernel is also checked against its jnp oracle, so the
bench doubles as a correctness gate.

End-to-end: the warm ridge-covar batch and the warm frontier-batched tree
build, each autotuned+fused (``block_size="auto"``, ``block_rows="auto"``,
``fuse_kernels=True``) vs static-block unfused — the ``speedup_fused_auto``
ratio is the machine-portable number CI's perf gate tracks (absolute times
vary per runner; the ratio is the trajectory claim: the fused, tuned path
must keep beating the static path).

Machine-readable results land in ``JSON_PAYLOAD``; ``benchmarks/run.py``
writes them to ``BENCH_kernels.json`` (env ``BENCH_KERNELS_JSON``) and CI
diffs that against ``benchmarks/baselines/BENCH_kernels.json`` via
``tools/perf_gate.py``.

    PYTHONPATH=src python -m benchmarks.bench_kernels
"""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np
import jax.numpy as jnp

from benchmarks.common import BENCH_SCALE, row, timeit
from benchmarks.roofline import peaks
from repro.kernels import ops, ref

#: machine-readable results of the last ``main()`` run (benchmarks/run.py
#: writes this out as BENCH_kernels.json)
JSON_PAYLOAD: dict = {}

#: on CPU the kernels execute in interpret mode — a correctness vehicle with
#: real (if modest) throughput; on TPU the same harness measures the MXU
def _interpret() -> bool:
    import jax
    return jax.default_backend() != "tpu"


def _entry(t_s: float, flops: float, nbytes: float, pk: dict,
           maxerr: float) -> dict:
    return {"t_s": t_s, "flops": flops, "bytes": nbytes,
            "achieved_flops": flops / t_s, "achieved_bw": nbytes / t_s,
            "util_flops": flops / t_s / pk["flops"],
            "util_bw": nbytes / t_s / pk["hbm_bw"],
            "maxerr": maxerr}


def _kernel_rows(pk: dict, interpret: bool):
    rng = np.random.default_rng(0)
    lines, kernels = [], {}

    # seg_aggregate: one-hot matmul scatter, (n, W) rows into S segments
    n, S, W = 32768, 128, 16
    seg = jnp.asarray(rng.integers(0, S, n).astype(np.int32))
    pay = jnp.asarray(rng.normal(size=(n, W)).astype(np.float32))
    t = timeit(lambda: ops.seg_aggregate(seg, pay, S, interpret=interpret))
    err = float(jnp.max(jnp.abs(ops.seg_aggregate(seg, pay, S,
                                                  interpret=interpret)
                                - ref.seg_aggregate_ref(seg, pay, S))))
    kernels["seg_aggregate"] = _entry(t, 2.0 * n * S * W,
                                      4.0 * n * (1 + W) + 4.0 * S * W, pk, err)

    # tree_hist: cond ⊗ [1, y, y²] histogram over D buckets
    D = 64
    codes = jnp.asarray(rng.integers(0, D, n).astype(np.int32))
    y = jnp.asarray(rng.normal(size=n).astype(np.float32))
    cond = jnp.asarray((rng.random(n) < 0.5).astype(np.float32))
    t = timeit(lambda: ops.tree_hist(codes, y, cond, D, interpret=interpret))
    err = float(jnp.max(jnp.abs(ops.tree_hist(codes, y, cond, D,
                                              interpret=interpret)
                                - ref.tree_hist_ref(codes, y, cond, D))))
    kernels["tree_hist"] = _entry(t, 2.0 * n * D * 3 + 5.0 * n,
                                  4.0 * n * 3 + 4.0 * D * 3, pk, err)

    # fused_scan_block: the whole-step union — two seg buckets + one hist
    # in ONE launch (the row block is read once for all three)
    S2, W2 = 32, 8
    specs = (ops.ReduceSpec("seg", 0, S, W, 0),
             ops.ReduceSpec("seg", 1, S2, W2, W),
             ops.ReduceSpec("hist", 2, D, 3, W + W2, n_cond=1,
                            yk_off=W + W2 + 1))
    fcodes = jnp.stack([seg, jnp.asarray(rng.integers(0, S2, n, dtype=np.int32)),
                        codes], axis=1)
    yk = jnp.stack([jnp.ones_like(y), y, y * y], axis=1)
    fpay = jnp.concatenate(
        [pay, jnp.asarray(rng.normal(size=(n, W2)).astype(np.float32)),
         cond[:, None], yk], axis=1)
    t = timeit(lambda: ops.fused_scan_block(fcodes, fpay, specs,
                                            interpret=interpret))
    outs = ops.fused_scan_block(fcodes, fpay, specs, interpret=interpret)
    refs = ref.fused_scan_block_ref(fcodes, fpay, specs)
    err = max(float(jnp.max(jnp.abs(o - r))) for o, r in zip(outs, refs))
    fl = 2.0 * n * (S * W + S2 * W2 + D * 3)
    nb = 4.0 * n * (3 + fpay.shape[1]) + 4.0 * (S * W + S2 * W2 + D * 3)
    kernels["fused_scan_block"] = _entry(t, fl, nb, pk, err)

    # covar_xtx: Xᵀ diag(w) X
    nc, F = 8192, 32
    x = jnp.asarray(rng.normal(size=(nc, F)).astype(np.float32))
    w = jnp.ones(nc, jnp.float32)
    t = timeit(lambda: ops.covar_xtx(x, w, interpret=interpret))
    err = float(jnp.max(jnp.abs(ops.covar_xtx(x, w, interpret=interpret)
                                - ref.covar_xtx_ref(x, w))))
    kernels["covar_xtx"] = _entry(t, 2.0 * nc * F * F,
                                  4.0 * nc * (F + 1) + 4.0 * F * F, pk, err)

    for name, k in kernels.items():
        lines.append(row(
            f"kern/{name}", k["t_s"],
            f"gflops={k['achieved_flops'] / 1e9:.2f};"
            f"gbps={k['achieved_bw'] / 1e9:.2f};"
            f"util_f={k['util_flops']:.4f};util_b={k['util_bw']:.4f};"
            f"maxerr={k['maxerr']:.1e}"))
    return lines, kernels


#: e2e datasets never shrink below this scale: the fused-vs-static ratio is
#: the gated trajectory claim, and at bench-smoke scale (0.01) the warm runs
#: are ~100µs — pure dispatch noise, not kernel work
E2E_SCALE = max(BENCH_SCALE, 0.05)


def _warm_run(handle, reps: int = 5) -> float:
    handle.run()                      # compile + autotune warm-up
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        handle.run()
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2]


def _e2e_ridge(cache: str):
    import repro
    from repro.data import datasets as D
    from repro.ml.covar import covar_queries

    ds = D.make("retailer", scale=E2E_SCALE)
    qs, _ = covar_queries(ds)
    v_auto = repro.connect(ds, config=repro.ExecutionConfig(
        backend="pallas", block_size="auto", block_rows="auto",
        fuse_kernels=True, autotune_cache=cache)).views(qs)
    v_stat = repro.connect(ds, config=repro.ExecutionConfig(
        backend="pallas", fuse_kernels=False)).views(qs)
    v_xla = repro.connect(ds, config=repro.ExecutionConfig(
        backend="xla")).views(qs)

    t_auto = _warm_run(v_auto)
    t_stat = _warm_run(v_stat)
    o_auto, o_xla = v_auto.run(), v_xla.run()
    close = all(np.allclose(np.asarray(o_auto[k]), np.asarray(o_xla[k]),
                            rtol=1e-4, atol=1e-4) for k in o_xla)
    return {"t_fused_auto_s": t_auto, "t_static_unfused_s": t_stat,
            "speedup_fused_auto": t_stat / t_auto,
            "allclose_xla": bool(close),
            "n_launches_fused": v_auto.stats.n_kernel_launches,
            "n_launches_unfused": v_stat.stats.n_kernel_launches}


def _e2e_tree_frontier(cache: str, n_nodes: int = 8):
    """One frontier-batched histogram dispatch (N node masks — the per-level
    unit of CART work), timed warm.  Full ``fit()`` would mix in host-side
    split selection that dilutes the kernel work the gate is about."""
    import jax
    import repro
    from repro.data import datasets as D
    from repro.ml.trees import DecisionTree, stack_mask_params

    ds = D.make("favorita", scale=E2E_SCALE)
    kw = dict(task="regression", max_depth=3, min_instances=20, max_nodes=15,
              node_batch=True)

    def warm_level(config):
        rng = np.random.default_rng(7)
        dt = DecisionTree(ds, config=config, **kw)
        masks = [{f.attr: (rng.random(f.domain) < 0.7).astype(np.float32)
                  for f in dt.features} for _ in range(n_nodes)]
        params = stack_mask_params(dt.features, masks)
        out = jax.block_until_ready(dt.batch.run_batched(ds.db, params))
        times = []
        for _ in range(5):
            t0 = time.perf_counter()
            jax.block_until_ready(dt.batch.run_batched(ds.db, params))
            times.append(time.perf_counter() - t0)
        return dt, out, sorted(times)[len(times) // 2]

    dt_auto, o_auto, t_auto = warm_level(repro.ExecutionConfig(
        backend="pallas", block_size="auto", block_rows="auto",
        fuse_kernels=True, autotune_cache=cache))
    dt_stat, _, t_stat = warm_level(repro.ExecutionConfig(
        backend="pallas", fuse_kernels=False))
    _, o_xla, _ = warm_level(repro.ExecutionConfig(backend="xla"))

    close = all(np.allclose(np.asarray(o_auto[k]), np.asarray(o_xla[k]),
                            rtol=1e-4, atol=1e-4) for k in o_xla)
    return {"t_fused_auto_s": t_auto, "t_static_unfused_s": t_stat,
            "speedup_fused_auto": t_stat / t_auto,
            "allclose_xla": bool(close),
            "n_launches_fused": dt_auto.batch.stats.n_kernel_launches,
            "n_launches_unfused": dt_stat.batch.stats.n_kernel_launches}


def main():
    pk = peaks()
    interpret = _interpret()
    lines, kernels = _kernel_rows(pk, interpret)

    # e2e comparisons share one autotune cache file so the "warm" claim is
    # honest within the run without leaking state between CI jobs
    cache = os.environ.get("REPRO_AUTOTUNE_CACHE") or os.path.join(
        tempfile.gettempdir(), f"repro_autotune_bench_{os.getpid()}.json")
    e2e = {"ridge": _e2e_ridge(cache), "tree_frontier": _e2e_tree_frontier(cache)}
    for name, r in e2e.items():
        lines.append(row(
            f"e2e/{name}/fused_auto", r["t_fused_auto_s"],
            f"speedup={r['speedup_fused_auto']:.2f}x;"
            f"launches={r['n_launches_fused']}vs{r['n_launches_unfused']};"
            f"allclose_xla={r['allclose_xla']}"))

    JSON_PAYLOAD.clear()
    JSON_PAYLOAD.update({"peaks": pk, "interpret": interpret,
                         "bench_scale": BENCH_SCALE, "e2e_scale": E2E_SCALE,
                         "kernels": kernels, "e2e": e2e})
    return lines


if __name__ == "__main__":
    import json
    print("\n".join(main()))
    print(json.dumps(JSON_PAYLOAD, indent=1, sort_keys=True))
