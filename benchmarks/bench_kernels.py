"""Kernel micro-bench: Pallas (interpret) vs jnp oracle vs jit'd oracle.

On this CPU container interpret mode is a correctness vehicle, not a speed
one; the derived column records allclose deltas so the bench doubles as a
regression gate.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import row, timeit
from repro.kernels import ops, ref


def main():
    rng = np.random.default_rng(0)
    lines = []

    x = jnp.asarray(rng.normal(size=(4096, 64)).astype(np.float32))
    w = jnp.ones(4096, jnp.float32)
    t_ref = timeit(lambda: ref.covar_xtx_ref(x, w).block_until_ready())
    t_pal = timeit(lambda: ops.covar_xtx(x, w, interpret=True).block_until_ready())
    err = float(jnp.max(jnp.abs(ops.covar_xtx(x, w, interpret=True)
                                - ref.covar_xtx_ref(x, w))))
    lines.append(row("kern/covar_xtx/ref", t_ref, "4096x64"))
    lines.append(row("kern/covar_xtx/pallas_interpret", t_pal, f"maxerr={err:.1e}"))

    seg = jnp.asarray(rng.integers(0, 64, 8192).astype(np.int32))
    pay = jnp.asarray(rng.normal(size=(8192, 8)).astype(np.float32))
    t_ref = timeit(lambda: ref.seg_aggregate_ref(seg, pay, 64).block_until_ready())
    t_pal = timeit(lambda: ops.seg_aggregate(seg, pay, 64, interpret=True)
                   .block_until_ready())
    lines.append(row("kern/seg_aggregate/ref", t_ref, "8192x8,S=64"))
    lines.append(row("kern/seg_aggregate/pallas_interpret", t_pal, ""))

    q = jnp.asarray(rng.normal(size=(1, 4, 256, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 32)).astype(np.float32))
    t_ref = timeit(lambda: ref.attention_ref(q, k, v, causal=True).block_until_ready())
    t_pal = timeit(lambda: ops.flash_attention(q, k, v, causal=True, block_q=64,
                                               block_k=64, interpret=True)
                   .block_until_ready())
    lines.append(row("kern/flash_attention/ref", t_ref, "S=256"))
    lines.append(row("kern/flash_attention/pallas_interpret", t_pal, ""))
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
