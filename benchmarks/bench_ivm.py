"""Incremental view maintenance vs full recomputation (DESIGN.md §8).

Maintains the ridge covar batch under a streaming 1% update to the fact
table (equal-count inserts + deletes, so sizes — and jit cache entries —
stay fixed) and compares the warm per-tick cost against rerunning the full
compiled batch over the current database.  The delta path scans only the
delta tuples (all covar queries root at the fact), so the gap is the
engine's |update| vs |database| work ratio — the IVM promise.

    PYTHONPATH=src python -m benchmarks.bench_ivm
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import BENCH_SCALE, row, timeit
from repro.data import datasets as D
from repro.data.relations import DeltaBatchUpdate
from repro.ml.cubes import StreamingCube, cube_name
from repro.ml.online import OnlineRidge


def _fact_update(ds, rng, frac: float) -> DeltaBatchUpdate:
    """Insert/delete ``frac`` of the fact rows each (sampled with repl.)."""
    fact = ds.tables[ds.fact]
    n = len(next(iter(fact.values())))
    k = max(int(n * frac), 1)
    pick = rng.integers(0, n, k)
    ins = {a: np.asarray(c)[pick] for a, c in fact.items()}
    return (DeltaBatchUpdate().insert(ds.fact, ins)
            .delete(ds.fact, rng.choice(n, k, replace=False)))


def main():
    ds = D.make("favorita", scale=BENCH_SCALE)
    rng = np.random.default_rng(11)
    lines = []

    olr = OnlineRidge(ds)
    olr.fit()
    mb = olr.maintained
    n_fact = ds.db.relation(ds.fact).n_rows
    upd = _fact_update(ds, rng, 0.01)

    t_delta = timeit(lambda: mb.apply(upd))
    t_full = timeit(lambda: mb.batch(mb.db))
    dp = mb.delta_program(ds.fact)
    lines.append(row(
        "ivm/ridge_delta_1pct", t_delta,
        f"rows={upd.updates[ds.fact].n_rows};delta_scans={dp.n_scans}"))
    lines.append(row(
        "ivm/ridge_full_recompute", t_full,
        f"rows={n_fact};scans={mb.batch.stats.n_scan_steps};"
        f"speedup={t_full / t_delta:.1f}x"))

    # streaming cube: every 2^k cell live under the same update stream
    dims = ["promo", "city", "stype"]
    cube = StreamingCube(ds, dims, measures=["units"])
    upd_c = _fact_update(ds, rng, 0.01)
    t_cube = timeit(lambda: cube.update(upd_c))
    lines.append(row(
        "ivm/cube_delta_1pct", t_cube,
        f"cells={2 ** len(dims)};finest={cube_name(dims)}"))

    return lines


if __name__ == "__main__":
    print("\n".join(main()))
