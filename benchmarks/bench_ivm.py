"""Incremental view maintenance vs full recomputation (DESIGN.md §8).

Maintains the ridge covar batch under a streaming 1% update to the fact
table (equal-count inserts + deletes, so sizes — and jit cache entries —
stay fixed) and compares the warm per-tick cost against rerunning the full
compiled batch over the current database.  The delta path scans only the
delta tuples (all covar queries root at the fact), so the gap is the
engine's |update| vs |database| work ratio — the IVM promise.

Also measures the device-residency win: a steady-state tick is one cached
jit call (epoch-versioned resident state), versus the pre-resident
baseline that round-tripped the stored fact relation through host numpy
every tick.  Results land in ``JSON_PAYLOAD`` (retrace counts included),
which ``benchmarks/run.py`` serializes to ``BENCH_ivm.json`` so CI records
the perf trajectory.

Sharded rows (DESIGN.md §6): the same ridge workload over 2- and 4-device
host meshes — steady-state tick under ``jax.transfer_guard("disallow")``
plus sharded serving read latency.  Device count is fixed at jax import
time, so each mesh size runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``; the contract
fields (retraces, allclose vs a local recompute) ride along so the perf
gate can hold them hard while wall times gate loose.

    PYTHONPATH=src python -m benchmarks.bench_ivm
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np

from benchmarks.common import BENCH_SCALE, row, timeit
from repro.data import datasets as D
from repro.data import relations as relmod
from repro.data.relations import DeltaBatchUpdate
from repro.ml.cubes import StreamingCube, cube_name
from repro.ml.online import OnlineRidge

SHARDED_DEVICE_COUNTS = (2, 4)

#: machine-readable results of the last ``main()`` run (benchmarks/run.py
#: writes this out as BENCH_ivm.json)
JSON_PAYLOAD: dict = {}


def _fact_update(ds, rng, frac: float) -> DeltaBatchUpdate:
    """Insert/delete ``frac`` of the fact rows each (sampled with repl.)."""
    fact = ds.tables[ds.fact]
    n = len(next(iter(fact.values())))
    k = max(int(n * frac), 1)
    pick = rng.integers(0, n, k)
    ins = {a: np.asarray(c)[pick] for a, c in fact.items()}
    return (DeltaBatchUpdate().insert(ds.fact, ins)
            .delete(ds.fact, rng.choice(n, k, replace=False)))


def sharded_main(ndev: int) -> dict:
    """Sharded-IVM measurement body.  Runs in a subprocess whose XLA host
    platform was forced to ``ndev`` devices (``_run_sharded``); measures the
    steady-state sharded tick under ``transfer_guard("disallow")`` — the
    zero-host-transfer contract — and the sharded serving read latency."""
    import jax

    from repro.api import ExecutionConfig

    if len(jax.devices()) < ndev:
        raise RuntimeError(f"need {ndev} devices, have {len(jax.devices())}")
    mesh = jax.make_mesh((ndev,), ("data",))
    ds = D.make("favorita", scale=BENCH_SCALE)
    rng = np.random.default_rng(11)
    # shard the fact explicitly: at small BENCH_SCALE the dense
    # date×store Transactions table out-sizes Sales, and the default
    # largest-relation pick would leave the updated fact replicated
    olr = OnlineRidge(ds, config=ExecutionConfig(
        block_size=4096, mesh=mesh, shard_rel=ds.fact))
    olr.fit()
    mb = olr.maintained
    upd = _fact_update(ds, rng, 0.01)        # fixed sizes -> one pad bucket

    timeit(lambda: mb.apply(upd))            # warm pad buckets and capacity
    traces0 = mb.n_fold_traces + relmod.advance_trace_count()
    with jax.transfer_guard("disallow"):     # steady-state contract
        t_tick = timeit(lambda: mb.apply(upd))
    retraces = mb.n_fold_traces + relmod.advance_trace_count() - traces0

    srv = olr.view.serve()
    t_read = timeit(lambda: srv.read())

    # numeric agreement: the maintained sharded epoch vs a from-scratch
    # single-device recompute over the gathered post-update relations
    check = OnlineRidge(ds, config=ExecutionConfig(block_size=4096))
    check.fit(db=mb.db)
    a, b = mb.results(), check.maintained.results()
    allclose = all(np.allclose(np.asarray(a[k]), np.asarray(b[k]),
                               rtol=1e-3, atol=1e-3) for k in a)
    topo = mb.shard_topology()
    return {
        "n_devices": ndev,
        "tick_us_sharded": t_tick * 1e6,
        "read_us_sharded": t_read * 1e6,
        "steady_state_retraces": int(retraces),
        "allclose_local": bool(allclose),
        "rows_per_shard": int(topo["rows_per_shard"]),
        "psums_per_tick_fact": int(topo["psums_per_tick"][ds.fact]),
    }


def _run_sharded(ndev: int) -> dict:
    """Spawn ``sharded_main(ndev)`` with a forced ``ndev``-device host mesh
    (device count is fixed at jax import time, hence the subprocess)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={ndev}").strip()
    env["JAX_PLATFORMS"] = "cpu"             # host mesh: portable everywhere
    env.setdefault("PYTHONPATH", "src")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_ivm", "--sharded", str(ndev)],
        check=True, env=env, capture_output=True, text=True)
    return json.loads(out.stdout.splitlines()[-1])


def main():
    import jax

    ds = D.make("favorita", scale=BENCH_SCALE)
    rng = np.random.default_rng(11)
    lines = []

    olr = OnlineRidge(ds)
    olr.fit()
    mb = olr.maintained
    n_fact = ds.db.relation(ds.fact).n_rows
    upd = _fact_update(ds, rng, 0.01)

    t_delta = timeit(lambda: mb.apply(upd))
    t_full = timeit(lambda: mb.batch(mb.db))
    dp = mb.delta_program(ds.fact)
    lines.append(row(
        "ivm/ridge_delta_1pct", t_delta,
        f"rows={upd.updates[ds.fact].n_rows};delta_scans={dp.n_scans}"))
    lines.append(row(
        "ivm/ridge_full_recompute", t_full,
        f"rows={n_fact};scans={mb.batch.stats.n_scan_steps};"
        f"speedup={t_full / t_delta:.1f}x"))

    # device residency: steady-state resident tick (one cached jit call,
    # zero relation-column host transfers) vs the pre-resident baseline's
    # per-tick host round-trip of the stored fact relation (delete-mask +
    # concat on host numpy, then back to device)
    def resident_tick():
        mb.apply(_fact_update(ds, rng, 0.01))

    def host_roundtrip_tick():
        mb.apply(_fact_update(ds, rng, 0.01))
        r = mb.db.relation(ds.fact)
        cols = {a: np.asarray(c) for a, c in r.columns.items()}  # dev->host
        jax.block_until_ready(jax.device_put(cols))              # host->dev

    t_tick = timeit(resident_tick)           # timeit warms before measuring
    traces0 = mb.n_fold_traces + relmod.advance_trace_count()
    timeit(resident_tick)
    retraces = mb.n_fold_traces + relmod.advance_trace_count() - traces0
    t_tick_host = timeit(host_roundtrip_tick)
    lines.append(row(
        "ivm/tick_resident", t_tick,
        f"epoch={mb.epoch};steady_retraces={retraces}"))
    lines.append(row(
        "ivm/tick_host_roundtrip", t_tick_host,
        f"overhead={t_tick_host / t_tick:.2f}x"))

    # streaming cube: every 2^k cell live under the same update stream
    dims = ["promo", "city", "stype"]
    cube = StreamingCube(ds, dims, measures=["units"])
    upd_c = _fact_update(ds, rng, 0.01)
    t_cube = timeit(lambda: cube.update(upd_c))
    lines.append(row(
        "ivm/cube_delta_1pct", t_cube,
        f"cells={2 ** len(dims)};finest={cube_name(dims)}"))

    # sharded IVM: steady-state tick + serving read over forced host meshes
    sharded = {}
    for ndev in SHARDED_DEVICE_COUNTS:
        r = _run_sharded(ndev)
        sharded[f"ndev{ndev}"] = r
        lines.append(row(
            f"ivm/sharded_tick_{ndev}dev", r["tick_us_sharded"] / 1e6,
            f"devices={ndev};retraces={r['steady_state_retraces']};"
            f"allclose={r['allclose_local']}"))
        lines.append(row(
            f"ivm/sharded_read_{ndev}dev", r["read_us_sharded"] / 1e6,
            f"devices={ndev};rows_per_shard={r['rows_per_shard']};"
            f"psums={r['psums_per_tick_fact']}"))

    JSON_PAYLOAD.clear()
    JSON_PAYLOAD.update({
        "dataset": "favorita", "scale": BENCH_SCALE,
        "fact_rows": int(n_fact),
        "update_rows": int(upd.updates[ds.fact].n_rows),
        "delta_scans": int(dp.n_scans),
        "tick_us_resident": t_tick * 1e6,
        "tick_us_host_roundtrip": t_tick_host * 1e6,
        "host_roundtrip_overhead_x": t_tick_host / t_tick,
        "steady_state_retraces": int(retraces),
        "full_recompute_us": t_full * 1e6,
        "delta_us": t_delta * 1e6,
        "speedup_delta_vs_full_x": t_full / t_delta,
        "cube_tick_us": t_cube * 1e6,
        "sharded": sharded,
    })
    return lines


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--sharded":
        print(json.dumps(sharded_main(int(sys.argv[2])), sort_keys=True))
    else:
        print("\n".join(main()))
