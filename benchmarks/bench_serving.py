"""Sustained-load serving stress: one writer, N readers, one ViewServer.

The serving contract (DESIGN.md §8) is concurrency machinery — wait-free
epoch-pinned reads behind a single-writer update stream — so its benchmark
must *be* concurrent: a writer thread folds fixed-size fact updates through
``ViewServer.apply`` while reader threads hammer ``read()``; a deterministic
laggard phase then pins more epochs than the budget allows to exercise LRU
eviction (``EpochEvictedError``) under churn.

What it measures (``JSON_PAYLOAD`` → ``BENCH_serving.json`` via
``benchmarks/run.py``):

* reader-observed read latency p50/p99 (includes ``block_until_ready`` —
  the caller's sync, like real serving traffic) and the server's own
  dispatch-wall histogram (``stats()["read_us"]``);
* sustained ticks/s through the writer;
* eviction churn: evicted pins + reads that landed on an evicted epoch;
* contract fields the perf gate holds hard: zero rejected updates, zero
  reader errors, one recorded workload signature per served view, and a
  non-degenerate latency distribution.

Telemetry is ON for the whole run (tracing + metrics + workload recorder)
— the harness doubles as the regression net for the no-sync rule: a chrome
trace sample is exported (``BENCH_SERVING_TRACE`` env, default
``trace_serving.json``) for CI to archive.

    PYTHONPATH=src python -m benchmarks.bench_serving
"""

from __future__ import annotations

import json
import os
import threading
import time

import numpy as np

from benchmarks.common import BENCH_SCALE, row

#: machine-readable results of the last ``main()`` run (benchmarks/run.py
#: writes this out as BENCH_serving.json)
JSON_PAYLOAD: dict = {}

N_READERS = 3
MAX_PINNED = 4
#: laggard phase holds this many distinct-epoch pins (> MAX_PINNED)
N_LAGGARD_PINS = MAX_PINNED + 2


def _n_ticks() -> int:
    env = os.environ.get("BENCH_SERVING_TICKS")
    if env:
        return max(int(env), 4)
    return max(int(round(200 * BENCH_SCALE)), 8)


def main():
    import jax

    from repro import obs
    from repro.data import datasets as D
    from repro.ml.online import OnlineRidge
    from benchmarks.bench_ivm import _fact_update

    ds = D.make("favorita", scale=BENCH_SCALE)
    rng = np.random.default_rng(7)
    n_ticks = _n_ticks()

    obs.clear_trace()
    obs.enable_tracing()
    olr = OnlineRidge(ds)
    olr.fit()
    srv = olr.view.serve(max_pinned_epochs=MAX_PINNED, warn_epoch_lag=2)
    workload = olr.view._database.workload

    # fixed-size updates -> one pad bucket -> steady state after the warmup
    upd = _fact_update(ds, rng, 0.01)
    srv.apply(upd)                           # warm the tick runner
    srv.read()                               # warm the read path
    read_hist = obs.Histogram("bench.read_synced_us")

    stop = threading.Event()
    errors = []

    def writer():
        try:
            for _ in range(n_ticks):
                srv.apply(upd)
        except Exception as e:               # pragma: no cover - bench guard
            errors.append(f"writer: {e!r}")
        finally:
            stop.set()

    def reader():
        try:
            while not stop.is_set():
                t0 = time.perf_counter()
                out = srv.read()
                jax.block_until_ready(out)   # the caller's sync
                read_hist.observe((time.perf_counter() - t0) * 1e6)
        except Exception as e:               # pragma: no cover - bench guard
            errors.append(f"reader: {e!r}")

    threads = [threading.Thread(target=writer)]
    threads += [threading.Thread(target=reader) for _ in range(N_READERS)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall_s = time.perf_counter() - t0

    # deterministic eviction churn: hold more distinct-epoch pins than the
    # budget, advancing an epoch between takes, then read the oldest —
    # transient reader pins alone never outlive the LRU window
    from repro.core.ivm import EpochEvictedError

    evicted_before = olr.maintained.n_evicted_pins
    held = []
    for _ in range(N_LAGGARD_PINS):
        pin = srv.snapshot()
        held.append((pin, pin.__enter__()))
        srv.apply(upd)
    n_evicted_reads = 0
    for pin, view in held:
        try:
            view.results()
        except EpochEvictedError:
            n_evicted_reads += 1
        pin.__exit__(None, None, None)
    n_evictions = olr.maintained.n_evicted_pins - evicted_before

    stats = srv.stats()
    rh = read_hist.snapshot()
    trace_path = os.environ.get("BENCH_SERVING_TRACE", "trace_serving.json")
    obs.export_chrome(trace_path)
    n_trace_events = len(obs.get_tracer().events())
    obs.disable_tracing()
    wl = workload.by_signature()
    served_sigs = sum(1 for e in wl.values()
                      if "pinned_read" in e["hits"])

    JSON_PAYLOAD.clear()
    JSON_PAYLOAD.update({
        "dataset": "favorita", "scale": BENCH_SCALE,
        "n_ticks": n_ticks, "n_readers": N_READERS,
        "max_pinned_epochs": MAX_PINNED,
        "wall_s": wall_s,
        "ticks_per_s": n_ticks / wall_s,
        # reader-observed (synced) latency — the serving SLO numbers
        "read_count": int(rh["count"]),
        "read_p50_us": rh["p50"], "read_p99_us": rh["p99"],
        # server-side dispatch walls (no sync — the telemetry view)
        "server_read_p50_us": stats["read_us"]["p50"],
        "server_read_p99_us": stats["read_us"]["p99"],
        "tick_p50_us": stats["tick_us"]["p50"],
        "tick_p99_us": stats["tick_us"]["p99"],
        # eviction churn
        "n_evictions": int(n_evictions),
        "n_evicted_reads": int(n_evicted_reads),
        "pinned_epochs_hwm": stats["pinned_epochs_hwm"],
        # contract fields (perf gate holds these hard)
        "n_rejected_updates": int(stats["n_rejected_updates"]),
        "n_reader_errors": len(errors),
        "served_view_signatures": int(served_sigs),
        "n_served_views": len(olr.view.names),
        "trace_events": int(n_trace_events),
        "errors": errors,
    })
    return [
        row("serving/read_p50", rh["p50"] / 1e6,
            f"readers={N_READERS};n={int(rh['count'])}"),
        row("serving/read_p99", rh["p99"] / 1e6,
            f"readers={N_READERS};n={int(rh['count'])}"),
        row("serving/tick", 1.0 / max(JSON_PAYLOAD["ticks_per_s"], 1e-9),
            f"ticks_per_s={JSON_PAYLOAD['ticks_per_s']:.1f};"
            f"evictions={n_evictions};"
            f"evicted_reads={n_evicted_reads};"
            f"rejected={stats['n_rejected_updates']};"
            f"errors={len(errors)}"),
    ]


if __name__ == "__main__":
    lines = main()
    print("\n".join(lines))
    path = os.environ.get("BENCH_SERVING_JSON", "BENCH_serving.json")
    with open(path, "w") as f:
        json.dump(JSON_PAYLOAD, f, indent=1, sort_keys=True)
    print(f"# wrote {path}")
