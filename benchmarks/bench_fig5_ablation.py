"""Paper Figure 5: the covar-matrix batch under increasing optimization.

  per_query    one compile+run per query, nothing shared (the AC/DC-like
               interpreted proxy: no cross-query view sharing)
  single_root  one batch, shared views, all queries at one root
  multi_root   + find-roots (the paper's 2-5x layer)
  parallel     + domain parallelism over 4 host devices (subprocess)
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

from benchmarks.common import BENCH_SCALE, row, timeit
from repro.api import connect
from repro.data import datasets as D
from repro.ml.covar import covar_queries


def main():
    name = os.environ.get("ABLATION_DATASET", "favorita")
    ds = D.make(name, scale=BENCH_SCALE)
    qs, _ = covar_queries(ds)
    db = connect(ds)
    lines = []

    # per-query: no sharing across queries
    batches = [db.views([q]) for q in qs]
    t_pq = timeit(lambda: [b.run() for b in batches], warmup=1, iters=2)
    lines.append(row(f"f5/{name}/per_query", t_pq, f"queries={len(qs)}"))

    b_sr = db.with_config(multi_root=False).views(qs)
    t_sr = timeit(lambda: b_sr.run())
    lines.append(row(f"f5/{name}/single_root", t_sr,
                     f"V={b_sr.stats.n_views};speedup={t_pq / t_sr:.1f}x"))

    b_mr = db.views(qs)
    t_mr = timeit(lambda: b_mr.run())
    lines.append(row(f"f5/{name}/multi_root", t_mr,
                     f"V={b_mr.stats.n_views};speedup={t_sr / t_mr:.2f}x"))

    # parallel: shard_map over 4 forced host devices (own process)
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import time, jax
import repro
from repro.data import datasets as D
from repro.ml.covar import covar_queries
ds = D.make({name!r}, scale={BENCH_SCALE})
qs, _ = covar_queries(ds)
mesh = jax.make_mesh((4,), ("data",))
db = repro.connect(ds, config=repro.ExecutionConfig(mesh=mesh))
v = db.views(qs)
jax.block_until_ready(v.run())   # warmup/compile once (runner is cached)
t0 = time.perf_counter()
for _ in range(3):
    jax.block_until_ready(v.run())
print((time.perf_counter() - t0) / 3)
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    try:
        out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                             env=env, capture_output=True, text=True, timeout=600)
        t_par = float(out.stdout.strip().splitlines()[-1])
        lines.append(row(f"f5/{name}/parallel4", t_par,
                         f"speedup={t_mr / t_par:.2f}x"))
    except Exception as e:  # pragma: no cover
        lines.append(row(f"f5/{name}/parallel4", 0.0, f"failed:{e}"))
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
