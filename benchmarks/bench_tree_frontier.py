"""Frontier-batched vs per-node decision-tree building (DESIGN.md §7.4).

The per-node loop issues one engine dispatch per tree node (plus a host sync
between nodes); frontier batching evaluates an entire tree level in ONE
fused dispatch via the param-batch (node) axis, so dispatches grow with
*depth*, not node count.  Reports wall time, total device dispatches, and
dispatches/node for both strategies, plus the forest workloads that only
exist because of the axis.

    PYTHONPATH=src python -m benchmarks.bench_tree_frontier
"""

from __future__ import annotations

import time

from benchmarks.common import BENCH_SCALE, row
from repro.data import datasets as D
from repro.ml.forest import GradientBoostedTrees, RandomForest
from repro.ml.trees import DecisionTree


def fit_tree(ds, node_batch: bool, depth: int):
    """Returns (tree, cold seconds, warm median seconds, dispatches/fit).

    Cold includes jit trace+compile of every frontier size; warm re-fits
    against the hot ``CompiledBatch._jitted`` cache — the steady-state cost
    of the evaluation strategy itself (compilation amortizes over the many
    trees of a forest / boosting run, exactly like LMFAO's compiled C++)."""
    dt = DecisionTree(ds, task="regression", max_depth=depth,
                      min_instances=20, max_nodes=2 ** (depth + 1) - 1,
                      node_batch=node_batch)
    t0 = time.perf_counter()
    dt.fit()
    cold = time.perf_counter() - t0
    d0 = dt.batch.n_dispatches
    warm = []
    for _ in range(3):
        t0 = time.perf_counter()
        dt.fit()
        warm.append(time.perf_counter() - t0)
    disp = (dt.batch.n_dispatches - d0) // 3
    return dt, cold, sorted(warm)[1], disp


def main():
    ds = D.make("favorita", scale=BENCH_SCALE)
    lines = []
    for depth in (2, 4, 5):
        per_node, cold_pn, warm_pn, disp_pn = fit_tree(ds, False, depth)
        frontier, cold_fr, warm_fr, disp_fr = fit_tree(ds, True, depth)
        n_nodes = len(frontier.nodes)
        assert n_nodes == len(per_node.nodes), "strategies must agree"
        lines.append(row(
            f"tree/d{depth}/per_node", warm_pn,
            f"nodes={n_nodes};dispatches={disp_pn};"
            f"disp_per_node={disp_pn / n_nodes:.2f};cold_s={cold_pn:.2f}"))
        lines.append(row(
            f"tree/d{depth}/frontier", warm_fr,
            f"nodes={n_nodes};dispatches={disp_fr};"
            f"disp_per_node={disp_fr / n_nodes:.2f};cold_s={cold_fr:.2f};"
            f"warm_speedup={warm_pn / warm_fr:.2f}x"))

    t0 = time.perf_counter()
    rf = RandomForest(ds, n_trees=8, max_depth=4, min_instances=20,
                      max_nodes=31, seed=0).fit()
    t_rf = time.perf_counter() - t0
    total = sum(len(t.nodes) for t in rf.trees)
    lines.append(row(
        "forest/rf8", t_rf,
        f"nodes={total};dispatches={rf.batch.n_dispatches};"
        f"disp_per_node={rf.batch.n_dispatches / total:.2f}"))

    t0 = time.perf_counter()
    gbt = GradientBoostedTrees(ds, n_rounds=4, learning_rate=0.3,
                               max_depth=3, min_instances=20).fit()
    t_g = time.perf_counter() - t0
    total = sum(len(t) for t in gbt.trees)
    lines.append(row(
        "forest/gbt4", t_g,
        f"nodes={total};dispatches={gbt.batch.n_dispatches};"
        f"disp_per_node={gbt.batch.n_dispatches / total:.2f}"))

    return lines


if __name__ == "__main__":
    print("\n".join(main()))
