"""§Perf hillclimb — Cell B: mamba2-2.7b prefill_32k (memory-bound SSD).

The roofline showed the SSD dual form's decay/score matrices dominate HBM
traffic.  Candidate levers, napkin-math first (see EXPERIMENTS.md §Perf):

  H1  bf16 dual-form matrices  — L/w traffic halves       (predict mem ≈ −35%)
  H2  smaller chunk Q=64       — L bytes ∝ S·Q per layer  (predict mem ≈ −25%,
      but more inter-chunk state steps)
  H3  larger chunk Q=256       — negative control (mem should RISE)
  H4  H1+H2 combined

    PYTHONPATH=src python -m benchmarks.perf_ssd [--arch mamba2-2.7b --shape prefill_32k]
"""

from __future__ import annotations

import argparse
import json
import os

from benchmarks.roofline import (HBM_BW, LINK_BW, PEAK_FLOPS, extrapolate,
                                 measure_costs)


def terms(costs):
    return {"compute": costs["flops"] / PEAK_FLOPS,
            "memory": costs["bytes"] / HBM_BW,
            "collective": costs["coll"] / LINK_BW}


def run_variant(arch, shape, name, overrides):
    from repro import configs
    cfg = configs.get(arch)
    c1 = measure_costs(arch, shape, 1, overrides=overrides)
    c2 = measure_costs(arch, shape, 3, overrides=overrides)
    costs = extrapolate(c1, c2, 1, 3, cfg.n_layers)
    t = terms(costs)
    dom = max(t, key=t.get)
    print(f"[perf-ssd] {name:28s} comp={t['compute']:.3e}s mem={t['memory']:.3e}s "
          f"coll={t['collective']:.3e}s dom={dom}", flush=True)
    return {"name": name, "overrides": {k: str(v) for k, v in overrides.items()},
            "terms": t, "dominant": dom, "costs": costs}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-2.7b")
    ap.add_argument("--shape", default="prefill_32k")
    args = ap.parse_args(argv)

    variants = [
        ("baseline_f32_Q128", {}),
        ("H1_bf16_dual", {"ssd_bf16": True}),
        ("H2_chunk64", {"ssm_chunk": 64}),
        ("H3_chunk256_negctl", {"ssm_chunk": 256}),
        ("H4_bf16_chunk64", {"ssd_bf16": True, "ssm_chunk": 64}),
    ]
    out = [run_variant(args.arch, args.shape, n, o) for n, o in variants]
    os.makedirs("reports", exist_ok=True)
    with open(f"reports/perf_ssd_{args.arch}_{args.shape}.json", "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
