"""Shared benchmark utilities."""

from __future__ import annotations

import os
import time
from typing import Callable, Tuple

BENCH_SCALE = float(os.environ.get("BENCH_SCALE", "0.1"))


def timeit(fn: Callable, *, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds over ``iters`` runs (after warmup).  Blocks on
    JAX async dispatch so device work is actually measured."""
    import jax

    def run():
        return jax.block_until_ready(fn())

    for _ in range(warmup):
        run()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        run()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"
