"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  BENCH_SCALE env (default 0.1)
scales the synthetic datasets.  Machine-readable payloads are written per
module — ``BENCH_ivm.json`` (tick latency with/without host round-trips,
retrace counts), ``BENCH_kernels.json`` (rooflines, fused/autotuned e2e),
``BENCH_serving.json`` (sustained-load read p50/p99, ticks/s, eviction
churn; a chrome-trace sample lands in ``trace_serving.json``),
``BENCH_routing.json`` (ad-hoc routing: per-tier latency, hit rate, plan
cache churn) — paths overridable via BENCH_IVM_JSON / BENCH_KERNELS_JSON /
BENCH_SERVING_JSON / BENCH_ROUTING_JSON — so CI can archive the perf
trajectory as artifacts.
"""

from __future__ import annotations

import json
import os
import sys
import traceback


def main() -> None:
    from benchmarks import (bench_fig5_ablation, bench_ivm, bench_kernels,
                            bench_routing, bench_serving, bench_table2_views,
                            bench_table3_aggregates, bench_table45_training,
                            bench_tree_frontier)
    print("name,us_per_call,derived")
    ok = True
    for mod in [bench_table2_views, bench_table3_aggregates,
                bench_table45_training, bench_fig5_ablation, bench_kernels,
                bench_tree_frontier, bench_ivm, bench_serving,
                bench_routing]:
        try:
            for line in mod.main():
                print(line, flush=True)
        except Exception:
            ok = False
            print(f"{mod.__name__},0,FAILED", flush=True)
            traceback.print_exc()

    for payload, env, default in [
            (bench_ivm.JSON_PAYLOAD, "BENCH_IVM_JSON", "BENCH_ivm.json"),
            (bench_kernels.JSON_PAYLOAD, "BENCH_KERNELS_JSON",
             "BENCH_kernels.json"),
            (bench_serving.JSON_PAYLOAD, "BENCH_SERVING_JSON",
             "BENCH_serving.json"),
            (bench_routing.JSON_PAYLOAD, "BENCH_ROUTING_JSON",
             "BENCH_routing.json")]:
        if not payload:
            continue
        path = os.environ.get(env, default)
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        print(f"# wrote {path}", file=sys.stderr)

    # dry-run + roofline tables (read from reports/, written by
    # repro.launch.dryrun --all and benchmarks.roofline)
    try:
        if os.path.isdir("reports/dryrun"):
            from benchmarks import report_experiments
            print()
            report_experiments.main()
    except Exception:
        traceback.print_exc()
    if not ok:
        sys.exit(1)


if __name__ == '__main__':
    main()
