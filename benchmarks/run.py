"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  BENCH_SCALE env (default 0.1)
scales the synthetic datasets.
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_fig5_ablation, bench_ivm, bench_kernels,
                            bench_table2_views, bench_table3_aggregates,
                            bench_table45_training, bench_tree_frontier)
    print("name,us_per_call,derived")
    ok = True
    for mod in [bench_table2_views, bench_table3_aggregates,
                bench_table45_training, bench_fig5_ablation, bench_kernels,
                bench_tree_frontier, bench_ivm]:
        try:
            for line in mod.main():
                print(line, flush=True)
        except Exception:
            ok = False
            print(f"{mod.__name__},0,FAILED", flush=True)
            traceback.print_exc()

    # dry-run + roofline tables (read from reports/, written by
    # repro.launch.dryrun --all and benchmarks.roofline)
    try:
        import os
        if os.path.isdir("reports/dryrun"):
            from benchmarks import report_experiments
            print()
            report_experiments.main()
    except Exception:
        traceback.print_exc()
    if not ok:
        sys.exit(1)


if __name__ == '__main__':
    main()
