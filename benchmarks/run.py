"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  BENCH_SCALE env (default 0.1)
scales the synthetic datasets.  The IVM module's machine-readable results
(tick latency with/without host round-trips, retrace counts) are written to
``BENCH_ivm.json`` (path overridable via the BENCH_IVM_JSON env var) so CI
can archive the perf trajectory as an artifact.
"""

from __future__ import annotations

import json
import os
import sys
import traceback


def main() -> None:
    from benchmarks import (bench_fig5_ablation, bench_ivm, bench_kernels,
                            bench_table2_views, bench_table3_aggregates,
                            bench_table45_training, bench_tree_frontier)
    print("name,us_per_call,derived")
    ok = True
    for mod in [bench_table2_views, bench_table3_aggregates,
                bench_table45_training, bench_fig5_ablation, bench_kernels,
                bench_tree_frontier, bench_ivm]:
        try:
            for line in mod.main():
                print(line, flush=True)
        except Exception:
            ok = False
            print(f"{mod.__name__},0,FAILED", flush=True)
            traceback.print_exc()

    if bench_ivm.JSON_PAYLOAD:
        path = os.environ.get("BENCH_IVM_JSON", "BENCH_ivm.json")
        with open(path, "w") as f:
            json.dump(bench_ivm.JSON_PAYLOAD, f, indent=1, sort_keys=True)
        print(f"# wrote {path}", file=sys.stderr)

    if bench_kernels.JSON_PAYLOAD:
        path = os.environ.get("BENCH_KERNELS_JSON", "BENCH_kernels.json")
        with open(path, "w") as f:
            json.dump(bench_kernels.JSON_PAYLOAD, f, indent=1, sort_keys=True)
        print(f"# wrote {path}", file=sys.stderr)

    # dry-run + roofline tables (read from reports/, written by
    # repro.launch.dryrun --all and benchmarks.roofline)
    try:
        if os.path.isdir("reports/dryrun"):
            from benchmarks import report_experiments
            print()
            report_experiments.main()
    except Exception:
        traceback.print_exc()
    if not ok:
        sys.exit(1)


if __name__ == '__main__':
    main()
