"""Ad-hoc query routing benchmark (DESIGN.md §13).

Measures the signature router's serving value on Favorita: a maintained
cube view answers an ad-hoc workload through the three tiers, and the
payload captures both the *contract* (every tier allclose to a
from-scratch compile of the same query; zero admission failures; LRU
eviction actually exercised) and the *latencies* the tiers exist to
separate — an exact epoch-read and a subsumption re-aggregation are
microseconds-scale dispatches, while a tier-3 miss pays a full compile.

What it measures (``JSON_PAYLOAD`` → ``BENCH_routing.json`` via
``benchmarks/run.py``):

* caller-observed routed latency p50/p99 per hit tier (includes
  ``block_until_ready`` — real serving traffic syncs on the answer) and
  the first-miss compile wall;
* the workload hit rate and plan-cache churn (compiles, evictions,
  per-signature hits) under a bounded cache (capacity 2 here, so the
  eviction path runs deterministically);
* contract fields the perf gate holds hard: per-tier allclose vs fresh
  compiles, zero admission failures, eviction churn exercised.

    PYTHONPATH=src python -m benchmarks.bench_routing
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import BENCH_SCALE, row

#: machine-readable results of the last ``main()`` run (benchmarks/run.py
#: writes this out as BENCH_routing.json)
JSON_PAYLOAD: dict = {}

#: small on purpose: three distinct tier-3 misses through a capacity-2
#: cache make eviction churn deterministic
CACHE_CAPACITY = 2


def _n_iters() -> int:
    env = os.environ.get("BENCH_ROUTING_ITERS")
    if env:
        return max(int(env), 8)
    return max(int(round(300 * BENCH_SCALE)), 20)


def _pcts(us):
    arr = np.asarray(us, dtype=np.float64)
    return float(np.percentile(arr, 50)), float(np.percentile(arr, 99))


def main():
    import jax

    import repro
    from repro.core import COUNT, query, sum_of
    from repro.data import datasets as D

    ds = D.make("favorita", scale=BENCH_SCALE)
    n_iters = _n_iters()

    cfg = repro.ExecutionConfig(route_cache_capacity=CACHE_CAPACITY)
    sess = repro.connect(ds, config=cfg)
    cube = query("cube", ["state", "family"], [COUNT, sum_of("units")])
    sess.views([cube], maintain=True).run()

    # the ad-hoc workload: exact (dims + aggs permuted vs the cube),
    # subsumed rollups, and three distinct misses for the eviction phase
    q_exact = query("q_exact", ["family", "state"], [sum_of("units"), COUNT])
    q_sub_state = query("q_state", ["state"], [COUNT])
    q_sub_total = query("q_total", [], [sum_of("units"), COUNT])
    misses = [query("q_stype", ["stype"], [COUNT]),
              query("q_htype", ["htype"], [COUNT]),
              query("q_cluster", ["cluster"], [COUNT])]

    def timed_route(q):
        t0 = time.perf_counter()
        r = sess.route(q)
        jax.block_until_ready(r.value)       # the caller's sync
        return r, (time.perf_counter() - t0) * 1e6

    def fresh(q):
        return repro.connect(ds, config=cfg).views([q]).run()[q.name]

    def close(a, b):
        return bool(np.allclose(np.asarray(a), np.asarray(b),
                                rtol=1e-3, atol=1e-3))

    # -- warm + correctness anchors per tier ------------------------------
    r0, _ = timed_route(q_exact)
    allclose_exact = r0.tier == "exact" and close(r0.value, fresh(q_exact))
    r1, _ = timed_route(q_sub_state)
    r2, _ = timed_route(q_sub_total)
    allclose_subsumed = (r1.tier == r2.tier == "subsumed"
                         and close(r1.value, fresh(q_sub_state))
                         and close(r2.value, fresh(q_sub_total)))
    rm, compile_us = timed_route(misses[0])
    allclose_compiled = rm.tier == "compiled" and close(rm.value,
                                                        fresh(misses[0]))

    # -- steady-state latency per tier ------------------------------------
    exact_us, sub_us, cached_us = [], [], []
    for _ in range(n_iters):
        r, us = timed_route(q_exact)
        assert r.tier == "exact"
        exact_us.append(us)
        r, us = timed_route(q_sub_state)
        assert r.tier == "subsumed"
        sub_us.append(us)
        r, us = timed_route(misses[0])       # cached plan: exact scan hit
        assert r.tier == "exact"
        cached_us.append(us)

    # -- eviction churn: 3 distinct misses through a capacity-2 cache -----
    for q in misses[1:]:
        timed_route(q)
    r_evicted, _ = timed_route(misses[0])    # evicted: recompiles

    st = sess.routing_stats()
    exact_p50, exact_p99 = _pcts(exact_us)
    sub_p50, sub_p99 = _pcts(sub_us)
    cached_p50, cached_p99 = _pcts(cached_us)

    JSON_PAYLOAD.clear()
    JSON_PAYLOAD.update({
        "dataset": "favorita", "scale": BENCH_SCALE,
        "n_iters": n_iters,
        "cache_capacity": CACHE_CAPACITY,
        # contract fields (perf gate holds these hard)
        "allclose_exact": allclose_exact,
        "allclose_subsumed": allclose_subsumed,
        "allclose_compiled": allclose_compiled,
        "n_admission_failures": int(st["n_admission_failures"]),
        "n_evictions": int(st["n_evictions"]),
        "evicted_recompiles": bool(r_evicted.tier == "compiled"),
        "route_hit_rate": float(st["hit_rate"]),
        "n_queries": int(st["n_queries"]),
        "n_plans_compiled": int(st["n_plans_compiled"]),
        "n_base_scans": int(st["n_base_scans"]),
        "n_reaggs": int(st["n_reaggs"]),
        # caller-observed (synced) routed latencies per tier
        "route_exact_p50_us": exact_p50, "route_exact_p99_us": exact_p99,
        "route_subsumed_p50_us": sub_p50, "route_subsumed_p99_us": sub_p99,
        "route_cached_scan_p50_us": cached_p50,
        "route_cached_scan_p99_us": cached_p99,
        "route_compile_us": compile_us,      # the tier-3 first-miss wall
    })
    return [
        row("routing/exact", exact_p50 / 1e6,
            f"p99={exact_p99:.0f}us;n={n_iters}"),
        row("routing/subsumed", sub_p50 / 1e6,
            f"p99={sub_p99:.0f}us;n={n_iters}"),
        row("routing/cached_scan", cached_p50 / 1e6,
            f"p99={cached_p99:.0f}us;n={n_iters}"),
        row("routing/compile_miss", compile_us / 1e6,
            f"hit_rate={st['hit_rate']:.3f};"
            f"plans={st['n_plans_compiled']};"
            f"evictions={st['n_evictions']};"
            f"admission_failures={st['n_admission_failures']}"),
    ]


if __name__ == "__main__":
    lines = main()
    print("\n".join(lines))
    path = os.environ.get("BENCH_ROUTING_JSON", "BENCH_routing.json")
    with open(path, "w") as f:
        json.dump(JSON_PAYLOAD, f, indent=1, sort_keys=True)
    print(f"# wrote {path}")
