"""The session facade (`repro.connect` → `Database` → `ViewHandle`,
DESIGN.md §9): equivalence against the legacy `Engine.compile` /
`compile_incremental` / `run_sharded` paths (bit-identical results on both
lowering backends), deprecation-shim warnings, the unified `explain()`
report, config threading into the cubes/Chow-Liu applications, and the
serving pin budget (LRU epoch eviction) under a background updater."""

import threading
import warnings

import numpy as np
import pytest

import repro
from repro.core import (COUNT, Delta, Engine, EngineDeprecationWarning, Var,
                        agg, query, schema, sum_of)
from repro.core.ivm import EpochEvictedError
from repro.data import DeltaBatchUpdate, apply_delta, from_numpy
from repro.data import datasets as D

BACKENDS = [("xla", None), ("pallas", True)]  # (backend, interpret)


def legacy_engine(S, db, **kw):
    return Engine(S, sizes=db.sizes(), **kw)


def legacy_compile(eng, queries, **kw):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return eng.compile(queries, **kw)


def legacy_compile_incremental(eng, queries, **kw):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return eng.compile_incremental(queries, **kw)


def make_schema():
    return schema(
        [("x1", "categorical", 3), ("x2", "key", 4), ("x3", "key", 5),
         ("x4", "categorical", 3), ("u", "continuous", 0)],
        [("R1", ["x1", "x2"]), ("R2", ["x2", "x3", "u"]), ("R3", ["x3", "x4"])])


def make_tables(seed=0, n1=17, n2=29, n3=13):
    rng = np.random.default_rng(seed)
    return {"R1": {"x1": rng.integers(0, 3, n1), "x2": rng.integers(0, 4, n1)},
            "R2": {"x2": rng.integers(0, 4, n2), "x3": rng.integers(0, 5, n2),
                   "u": rng.normal(size=n2).astype(np.float32)},
            "R3": {"x3": rng.integers(0, 5, n3), "x4": rng.integers(0, 3, n3)}}


QUERIES = [
    query("q_count", [], [COUNT]),
    query("q_g1", ["x1"], [COUNT, sum_of("u")]),
    query("q_delta", ["x4"], [agg(Var("u"), Delta("x1", "==", 1))]),
]


def assert_identical(a, b):
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]),
                                      err_msg=k)


def assert_close(a, b):
    """For folded-state vs from-scratch oracles: equal up to fp32 summation
    order (the IVM contract, DESIGN.md §8)."""
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                   rtol=1e-4, atol=1e-4, err_msg=k)


@pytest.fixture(scope="module")
def fav():
    return D.make("favorita", scale=0.02)


# ---------------------------------------------------------------- equivalence

@pytest.mark.parametrize("backend,interpret", BACKENDS)
def test_ridge_batch_identical_to_legacy(fav, backend, interpret):
    """Database-path covar results are bit-identical to Engine.compile."""
    from repro.ml.covar import covar_queries
    qs, _ = covar_queries(fav)
    legacy = legacy_compile(
        Engine(fav.schema, edges=fav.edges, sizes=fav.db.sizes()), qs,
        backend=backend, interpret=interpret)
    want = legacy(fav.db)
    db = repro.connect(fav, config=repro.ExecutionConfig(
        backend=backend, interpret=interpret))
    got = db.views(qs).run()
    assert_identical(got, want)


@pytest.mark.parametrize("backend,interpret", BACKENDS)
def test_tree_frontier_identical_to_legacy(fav, backend, interpret):
    """run_batched through the facade == legacy CompiledBatch.run_batched."""
    from repro.ml.trees import build_tree_batch, build_tree_features
    feats = build_tree_features(fav, None, None)
    cfg = repro.ExecutionConfig(backend=backend, interpret=interpret)
    handle, queries = build_tree_batch(fav, feats, "regression", fav.label, 0,
                                       config=cfg)
    legacy = legacy_compile(
        Engine(fav.schema, edges=fav.edges, sizes=fav.db.sizes()), queries,
        backend=backend, interpret=interpret)
    rng = np.random.default_rng(7)
    params = {f"mask_{f.attr}": rng.integers(0, 2, (3, f.domain))
              .astype(np.float32) for f in feats}
    want = legacy.run_batched(fav.db, dict(params))
    got = handle.run_batched(dict(params))
    assert_identical(got, want)


@pytest.mark.parametrize("backend,interpret", BACKENDS)
def test_streaming_identical_to_legacy(backend, interpret):
    """Maintained views through the facade publish bit-identical state to
    the legacy compile_incremental path, update batch by update batch."""
    S = make_schema()
    db = from_numpy(S, make_tables())
    legacy = legacy_compile_incremental(
        legacy_engine(S, db), QUERIES, block_size=8, backend=backend,
        interpret=interpret)
    legacy.init(db)
    session = repro.connect(S, data=db, config=repro.ExecutionConfig(
        backend=backend, interpret=interpret, block_size=8))
    view = session.views(QUERIES, maintain=True)
    assert_identical(view.run(), legacy.results())

    rng = np.random.default_rng(3)
    n1 = 17
    for k in (2, 5):
        upd = (DeltaBatchUpdate()
               .insert("R2", {"x2": rng.integers(0, 4, k),
                              "x3": rng.integers(0, 5, k),
                              "u": rng.normal(size=k).astype(np.float32)})
               .delete("R1", rng.choice(n1, 2, replace=False)))
        n1 -= 2
        legacy.apply(upd)
        got = view.apply(upd)
        assert_identical(got, legacy.results())
        assert view.maintained.epoch == legacy.epoch


def test_sharded_identical_to_legacy(fav):
    """config.mesh makes run() domain-parallel; results are bit-identical
    to the legacy CompiledBatch.run_sharded entry point."""
    import jax
    from repro.ml.covar import covar_queries
    qs, _ = covar_queries(fav)
    mesh = jax.make_mesh((1,), ("data",))
    legacy = legacy_compile(
        Engine(fav.schema, edges=fav.edges, sizes=fav.db.sizes()), qs)
    want = legacy.run_sharded(fav.db, mesh)
    db = repro.connect(fav, config=repro.ExecutionConfig(mesh=mesh))
    v = db.views(qs)
    got = v.run()
    assert_identical(got, want)
    # the sharded runner is built once and cached across run() calls
    assert_identical(v.run(), want)


# ------------------------------------------------------------------- shims

def test_legacy_compile_warns():
    S = make_schema()
    db = from_numpy(S, make_tables())
    eng = legacy_engine(S, db)
    with pytest.warns(EngineDeprecationWarning, match="repro.connect"):
        eng.compile(QUERIES, block_size=8)
    with pytest.warns(EngineDeprecationWarning, match="maintain=True"):
        eng.compile_incremental(QUERIES, block_size=8)
    # the facade itself never routes through the deprecated shims
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        sess = repro.connect(S, data=db,
                             config=repro.ExecutionConfig(block_size=8))
        sess.views(QUERIES).run()
        sess.views(QUERIES, maintain=True).run()


# ---------------------------------------------------------- facade semantics

def test_connect_forms_and_errors():
    S = make_schema()
    T = make_tables()
    db = from_numpy(S, T)
    out1 = repro.connect(S, tables=T).views(QUERIES).run()
    out2 = repro.connect(db).views(QUERIES).run()
    assert_identical(out1, out2)
    with pytest.raises(ValueError, match="tables="):
        repro.connect(S)
    with pytest.raises(TypeError, match="cannot connect"):
        repro.connect(42)
    with pytest.raises(ValueError, match="backend"):
        repro.ExecutionConfig(backend="cuda")
    with pytest.raises(ValueError, match="max_pinned_epochs"):
        repro.ExecutionConfig(max_pinned_epochs=0)


def test_viewhandle_mode_errors():
    S = make_schema()
    db = repro.connect(S, tables=make_tables(),
                       config=repro.ExecutionConfig(block_size=8))
    batch_view = db.views(QUERIES)
    with pytest.raises(ValueError, match="maintain=True"):
        batch_view.apply(DeltaBatchUpdate())
    with pytest.raises(ValueError, match="maintain=True"):
        batch_view.serve()
    live = db.views(QUERIES, maintain=True)
    with pytest.raises(ValueError, match="param-batch"):
        live.run_batched({})
    import jax
    mesh = jax.make_mesh((1,), ("data",))
    # sharded maintained views compile and run (PR: sharded IVM); on a
    # 1-device mesh the shard_map path must agree with the local batch
    sharded = db.with_config(mesh=mesh).views(QUERIES, maintain=True)
    out_sh, out_local = sharded.run(), live.run()
    for q in QUERIES:
        np.testing.assert_allclose(np.asarray(out_sh[q.name]),
                                   np.asarray(out_local[q.name]),
                                   rtol=1e-4, atol=1e-4, err_msg=q.name)
    assert sharded.explain().shard["n_devices"] == 1
    with pytest.raises(ValueError, match="shard_rel"):
        db.with_config(mesh=mesh, shard_rel="nope").views(
            QUERIES, maintain=True).run()


def test_review_hardening(fav):
    """Regressions from review: serve() validates the budget on every call;
    maintained run() refuses silently-dropped params; a legacy CompiledBatch
    still injects into DecisionTree (with the deprecation warning); the
    sharded frontier pads the node axis so runner caching is log2-bounded."""
    S = make_schema()
    session = repro.connect(S, tables=make_tables(),
                            config=repro.ExecutionConfig(block_size=8))
    live = session.views(QUERIES, maintain=True)
    live.serve(max_pinned_epochs=2)
    with pytest.raises(ValueError, match="max_pinned_epochs"):
        live.serve(max_pinned_epochs=0)
    with pytest.raises(ValueError, match="bind params"):
        live.run(params={"t": np.int32(1)})

    from repro.ml.trees import DecisionTree
    legacy_dt_batch = None

    def build_legacy():
        from repro.ml.trees import build_tree_batch, build_tree_features
        feats = build_tree_features(fav, None, None)
        handle, queries = build_tree_batch(fav, feats, "regression",
                                           fav.label, 0)
        return legacy_compile(
            Engine(fav.schema, edges=fav.edges, sizes=fav.db.sizes()),
            queries)

    legacy_dt_batch = build_legacy()
    with pytest.warns(EngineDeprecationWarning, match="ViewHandle"):
        dt = DecisionTree(fav, task="regression", max_depth=1,
                          min_instances=10, max_nodes=3,
                          batch=legacy_dt_batch)
    assert dt.batch is legacy_dt_batch


def test_sharded_frontier_pads_nodes_to_pow2(fav):
    """With a mesh config, run_batched pads the node axis like the local
    path: frontiers of 3 and 4 nodes share ONE cached sharded runner, and
    padded rows are sliced off the outputs."""
    import jax
    from repro.ml.trees import build_tree_batch, build_tree_features
    feats = build_tree_features(fav, None, None)
    mesh = jax.make_mesh((1,), ("data",))
    handle, _ = build_tree_batch(
        fav, feats, "regression", fav.label, 0,
        config=repro.ExecutionConfig(mesh=mesh))
    rng = np.random.default_rng(11)

    def masks(n):
        return {f"mask_{f.attr}": rng.integers(0, 2, (n, f.domain))
                .astype(np.float32) for f in feats}

    p3, p4 = masks(3), masks(4)
    out3 = handle.run_batched(dict(p3))
    assert len(handle._sharded) == 1
    out4 = handle.run_batched(dict(p4))
    assert len(handle._sharded) == 1          # 3 padded to 4: runner reused
    q = f"split_{feats[0].attr}"
    assert np.asarray(out3[q]).shape[0] == 3  # pad sliced off
    assert np.asarray(out4[q]).shape[0] == 4
    # equivalence with the unsharded facade path on the same params
    local, _ = build_tree_batch(fav, feats, "regression", fav.label, 0)
    assert_identical(out3, local.run_batched(dict(p3)))


def test_maintained_lifecycle_and_snapshot(tmp_path):
    S = make_schema()
    T = make_tables()
    session = repro.connect(S, tables=T,
                            config=repro.ExecutionConfig(block_size=8))
    view = session.views(QUERIES, maintain=True)
    first = view.run()                        # full scan -> epoch 0
    assert view.maintained.epoch == 0
    again = view.run()                        # read, no rescan
    assert_identical(first, again)

    rng = np.random.default_rng(1)
    upd = DeltaBatchUpdate().insert(
        "R2", {"x2": rng.integers(0, 4, 3), "x3": rng.integers(0, 5, 3),
               "u": rng.normal(size=3).astype(np.float32)})
    view.apply(upd)
    saved = {k: np.asarray(v).copy() for k, v in view.results().items()}
    path = view.snapshot(str(tmp_path))
    assert path

    view.apply(DeltaBatchUpdate().delete("R1", np.array([0, 1])))
    view.restore(str(tmp_path))
    assert_identical(view.results(), saved)

    # oracle: restored state equals init on the post-update database
    oracle = apply_delta(from_numpy(S, T), upd)
    fresh = session.views(QUERIES).compiled(oracle)
    assert_close(view.results(), fresh)


def test_explain_unified_report():
    S = make_schema()
    session = repro.connect(S, tables=make_tables(),
                            config=repro.ExecutionConfig(block_size=8))
    v = session.views(QUERIES)
    rep = v.explain()
    assert rep.mode == "batch" and rep.n_dispatches == 0
    v.run()
    assert v.explain().n_dispatches == 1
    assert "scans=" in v.explain().summary()

    live = session.views(QUERIES, maintain=True)
    live.run()
    live.apply(DeltaBatchUpdate().delete("R1", np.array([2])))
    rep = live.explain()
    assert rep.mode == "maintained" and rep.epoch == 1 and rep.step == 1
    assert rep.n_delta_scan_steps > 0
    srv = live.serve(max_pinned_epochs=4)
    srv.read("q_count")
    rep = live.explain()
    assert rep.mode == "served" and rep.serving["n_reads"] == 1
    assert rep.max_pinned_epochs == 4
    assert "serve:" in rep.summary()


# ---------------------------------------------------- config threading (apps)

@pytest.mark.parametrize("backend,interpret", BACKENDS)
def test_cubes_honor_backend(fav, backend, interpret):
    """Regression: ml/cubes used to drop backend/block_size on the floor."""
    from repro.ml import cubes
    dims, meas = ["promo", "stype"], ["units"]
    got = cubes.cube_via_engine(fav, dims, meas, backend=backend,
                                interpret=interpret, block_size=512)
    ref = cubes.cube_via_engine(fav, dims, meas)
    for k in ref:
        np.testing.assert_allclose(got[k], ref[k], rtol=1e-4, atol=1e-4,
                                   err_msg=k)
    sc = cubes.StreamingCube(fav, dims, meas, backend=backend,
                             interpret=interpret)
    assert sc.maintained.plan.config.backend == backend


@pytest.mark.parametrize("backend,interpret", BACKENDS)
def test_chowliu_honors_backend(fav, backend, interpret):
    from repro.ml import chowliu
    attrs = ["city", "stype", "family"]
    got = chowliu.chow_liu(fav, attrs=attrs, backend=backend,
                           interpret=interpret, block_size=512)
    ref = chowliu.chow_liu(fav, attrs=attrs)
    np.testing.assert_allclose(got.mi, ref.mi, rtol=1e-6, atol=1e-8)
    assert got.edges == ref.edges


def test_apps_reject_unknown_backend(fav):
    """The sharp end of the threading regression: before the fix an invalid
    backend was silently ignored here."""
    from repro.ml import chowliu, cubes
    with pytest.raises(ValueError, match="backend"):
        cubes.cube_via_engine(fav, ["promo"], ["units"], backend="cuda")
    with pytest.raises(ValueError, match="backend"):
        chowliu.chow_liu(fav, attrs=["city", "stype"], backend="cuda")


# ------------------------------------------------------- pin budget (serving)

def test_pin_budget_lru_eviction_under_background_updater():
    """With max_pinned_epochs=2, pinning a third epoch while a background
    updater publishes new versions evicts the least-recently-used pin;
    reads of the evicted epoch raise EpochEvictedError with a clear
    message, the surviving pins stay frozen, and post-stream reads match
    the from-scratch oracle."""
    S = make_schema()
    T = make_tables()
    session = repro.connect(S, tables=T,
                            config=repro.ExecutionConfig(block_size=8))
    view = session.views(QUERIES, maintain=True)
    srv = view.serve(max_pinned_epochs=2)

    rng = np.random.default_rng(9)
    updates = [DeltaBatchUpdate().insert(
        "R2", {"x2": rng.integers(0, 4, 2), "x3": rng.integers(0, 5, 2),
               "u": rng.normal(size=2).astype(np.float32)})
        for _ in range(3)]

    applied = threading.Event()
    proceed = threading.Event()
    failures = []

    def updater():
        try:
            for upd in updates:
                proceed.wait(timeout=30)
                proceed.clear()
                srv.apply(upd)
                applied.set()
        except Exception as e:     # pragma: no cover
            failures.append(e)

    t = threading.Thread(target=updater)
    t.start()
    pins = []                      # (ctx manager, EpochView), oldest first
    try:
        for _ in range(3):         # pin an epoch, then let one update publish
            ctx = srv.snapshot()
            pins.append((ctx, ctx.__enter__()))
            proceed.set()
            assert applied.wait(timeout=30)
            applied.clear()
    finally:
        t.join(timeout=30)
    assert not failures

    # 3 distinct epochs pinned against a budget of 2 -> the oldest evicted
    assert srv.stats()["n_evicted_pins"] == 1
    assert srv.stats()["n_pinned_epochs"] == 2
    with pytest.raises(EpochEvictedError, match="pin budget"):
        pins[0][1].results()
    # the most-recent surviving pins still read their frozen epochs
    for _, snap in pins[1:]:
        assert snap.results()["q_count"].shape == (1,)
    for ctx, _ in pins:            # unpin of an evicted epoch is a no-op
        ctx.__exit__(None, None, None)
    assert srv.stats()["n_pinned_epochs"] == 0

    # current-epoch reads match the from-scratch oracle
    oracle_db = from_numpy(S, T)
    for upd in updates:
        oracle_db = apply_delta(oracle_db, upd)
    fresh = session.views(QUERIES).compiled(oracle_db)
    assert_close(srv.read(), fresh)


def test_pin_budget_keeps_hot_pins_by_recency():
    """LRU, not FIFO: re-reading an old pin keeps it resident while a
    colder (less recently used) pin is evicted instead."""
    S = make_schema()
    session = repro.connect(S, tables=make_tables(),
                            config=repro.ExecutionConfig(block_size=8))
    view = session.views(QUERIES, maintain=True)
    view.run()
    mb = view.maintained
    mb.max_pinned_epochs = 2
    rng = np.random.default_rng(2)

    def tick():
        view.apply(DeltaBatchUpdate().insert(
            "R2", {"x2": rng.integers(0, 4, 1), "x3": rng.integers(0, 5, 1),
                   "u": rng.normal(size=1).astype(np.float32)}))

    e0 = mb.pin()
    tick()
    e1 = mb.pin()
    assert (e0, e1) == (0, 1)
    mb.results(epoch=e0)           # LRU touch: e0 hotter than e1
    tick()
    mb.pin()                       # budget 2: evicts e1 (the cold one)
    mb.results(epoch=e0)           # still resident
    with pytest.raises(EpochEvictedError):
        mb.results(epoch=e1)
    assert mb.n_evicted_pins == 1
