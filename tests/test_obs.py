"""Engine-wide telemetry (DESIGN.md §11): tracing spans, metrics, workload
recording, structured logging — and the headline design constraint that
instrumentation must NOT break the steady-state contracts: the sharded tick
stays zero-transfer / zero-retrace and the epoch-pinning serving semantics
hold with tracing + metrics + workload recording all enabled."""

import json
import logging
import threading
import time

import numpy as np
import pytest

import repro
from repro import obs
from repro.core import COUNT, Delta, Var, agg, query, schema, sum_of
from repro.data import DeltaBatchUpdate, from_numpy
from repro.obs.metrics import Counter, Gauge, Histogram, Registry
from repro.obs.workload import WorkloadRecorder, signature_of


def make_schema():
    return schema(
        [("x1", "categorical", 3), ("x2", "key", 4), ("x3", "key", 5),
         ("x4", "categorical", 3), ("u", "continuous", 0)],
        [("R1", ["x1", "x2"]), ("R2", ["x2", "x3", "u"]),
         ("R3", ["x3", "x4"])])


def make_tables(seed=0):
    rng = np.random.default_rng(seed)
    return {"R1": {"x1": rng.integers(0, 3, 17), "x2": rng.integers(0, 4, 17)},
            "R2": {"x2": rng.integers(0, 4, 29), "x3": rng.integers(0, 5, 29),
                   "u": rng.normal(size=29).astype(np.float32)},
            "R3": {"x3": rng.integers(0, 5, 13), "x4": rng.integers(0, 3, 13)}}


QUERIES = [
    query("q_count", [], [COUNT]),
    query("q_g1", ["x1"], [COUNT, sum_of("u")]),
    query("q_delta", ["x4"], [agg(Var("u"), Delta("x1", "==", 1))]),
]


def r2_rows(rng, k):
    return {"x2": rng.integers(0, 4, k), "x3": rng.integers(0, 5, k),
            "u": rng.normal(size=k).astype(np.float32)}


@pytest.fixture
def tracing():
    """Tracing enabled for the test, state restored after."""
    obs.clear_trace()
    obs.enable_tracing()
    yield obs.get_tracer()
    obs.disable_tracing()
    obs.clear_trace()


# ------------------------------------------------------------------- metrics

def test_histogram_percentiles_without_samples():
    h = Histogram("t", bounds=(10.0, 100.0, 1000.0))
    for v in (5, 5, 50, 50, 50, 500, 5000):
        h.observe(v)
    s = h.snapshot()
    assert s["count"] == 7 and s["min"] == 5 and s["max"] == 5000
    assert s["mean"] == pytest.approx(sum((5, 5, 50, 50, 50, 500, 5000)) / 7)
    # p50 falls in the (10, 100] bucket; interpolation stays inside it
    assert 10 <= s["p50"] <= 100
    # p99 lands in the overflow bucket, clamped by the tracked max
    assert 1000 <= s["p99"] <= 5000
    assert s["p50"] <= s["p95"] <= s["p99"]


def test_histogram_degenerate_cases():
    h = Histogram("t")
    assert h.snapshot()["p99"] == 0.0          # empty
    h.observe(42.0)
    s = h.snapshot()                           # single sample: min==max clamp
    assert s["p50"] == pytest.approx(42.0)
    assert s["p99"] == pytest.approx(42.0)
    with pytest.raises(ValueError):
        Histogram("bad", bounds=(5.0, 1.0))


def test_counter_gauge_registry():
    r = Registry()
    c = r.counter("n")
    c.inc(); c.inc(2)
    assert c.value == 3
    g = r.gauge("hwm")
    g.set(2.0); g.max(5.0); g.max(1.0)
    assert g.value == 5.0
    assert r.counter("n") is c                 # same name -> same metric
    with pytest.raises(TypeError):
        r.gauge("n")                           # name/type conflict
    snap = r.snapshot()
    assert snap["n"] == 3 and snap["hwm"] == 5.0


def test_metrics_are_thread_safe():
    h = Histogram("t")
    c = Counter("c")

    def work():
        for _ in range(500):
            h.observe(7.0)
            c.inc()

    ts = [threading.Thread(target=work) for _ in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert h.count == 2000 and c.value == 2000


# -------------------------------------------------------------------- tracing

def test_span_noop_when_disabled():
    obs.disable_tracing()
    obs.clear_trace()
    with obs.span("never.recorded", x=1):
        pass
    assert obs.get_tracer().events() == []


def test_spans_nest_and_export_chrome(tracing, tmp_path):
    with obs.span("outer", step=1):
        with obs.span("inner"):
            time.sleep(0.001)
    evs = tracing.events()
    names = {e["name"] for e in evs}
    assert names == {"outer", "inner"}
    outer = next(e for e in evs if e["name"] == "outer")
    inner = next(e for e in evs if e["name"] == "inner")
    assert outer["ph"] == "X" and outer["args"] == {"step": 1}
    # nesting is reconstructed by time containment: inner ⊆ outer
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1
    assert inner["dur"] >= 1000                # slept >= 1ms, in us

    path = tmp_path / "trace.json"
    obs.export_chrome(str(path))
    blob = json.loads(path.read_text())
    assert len(blob["traceEvents"]) == 2
    assert blob["displayTimeUnit"] == "ms"


def test_tracer_bounds_memory():
    t = obs.Tracer(max_events=4)
    for i in range(10):
        t._record(f"e{i}", 0.0, 1e-6, {})
    assert len(t.events()) == 4 and t.n_dropped == 6
    t.clear()
    assert t.events() == [] and t.n_dropped == 0


# ------------------------------------------------------------------- workload

def test_query_signatures_render_structurally():
    sigs = {q.name: signature_of(q) for q in QUERIES}
    assert sigs["q_count"].dims == () and sigs["q_count"].aggs == ("1",)
    assert sigs["q_g1"].dims == ("x1",) and sigs["q_g1"].aggs == ("1", "u")
    # filters: advisor-facing rollup (normalized constants); matching
    # soundness lives in the per-agg renders, where the Delta factor rides
    # inline so it stays attached to its aggregate
    assert sigs["q_delta"].filters == ("x1==1.0",)
    assert sigs["q_delta"].aggs == ("1[x1==1.0]*u",)
    # stable, distinct keys
    keys = {s.key() for s in sigs.values()}
    assert len(keys) == 3
    assert sigs["q_g1"].key() == signature_of(QUERIES[1]).key()


def test_signature_canonicalization_commutes():
    """Routing equality (DESIGN.md §13): signatures are order-insensitive
    in group-by dims, aggregate order, and product term order, and
    normalize filter constants — semantically identical queries must not
    miss the router's cache on spelling."""
    from repro.core import Pow
    from repro.obs.workload import agg_renders

    a = query("qa", ["x1", "x4"], [COUNT, sum_of("u")])
    b = query("qb", ["x4", "x1"], [sum_of("u"), COUNT])   # permuted both
    assert signature_of(a).key() == signature_of(b).key()

    # term order within a product commutes
    c = query("qc", ["x4"], [agg(Var("u"), Delta("x1", "==", 1))])
    d = query("qd", ["x4"], [agg(Delta("x1", "==", 1), Var("u"))])
    assert signature_of(c).key() == signature_of(d).key()

    # filter constants normalize: int 5 == float 5.0 == np.float32(5)
    e = query("qe", [], [agg(Var("u"), Delta("x2", "<", 2))])
    f = query("qf", [], [agg(Var("u"), Delta("x2", "<", 2.0))])
    g = query("qg", [], [agg(Var("u"), Delta("x2", "<", np.float32(2)))])
    assert signature_of(e).key() == signature_of(f).key() \
        == signature_of(g).key()

    # but different structure stays distinct
    assert signature_of(a).key() != signature_of(c).key()
    assert signature_of(e).key() != \
        signature_of(query("qh", [], [agg(Var("u"),
                                          Delta("x2", "<", 3))])).key()
    assert signature_of(query("qi", [], [sum_of("u")])).key() != \
        signature_of(query("qj", [], [agg(Pow("u", 2))])).key()

    # agg_renders preserves query order (the router's column map) while
    # signature_of sorts
    k = query("qk", [], [sum_of("u"), COUNT])
    assert agg_renders(k) == ("u", "1")
    assert signature_of(k).aggs == ("1", "u")

    # a filter attached to one agg differs from the same filter on both
    m = query("qm", [], [agg(Var("u"), Delta("x1", "==", 1)), COUNT])
    n = query("qn", [], [agg(Var("u"), Delta("x1", "==", 1)),
                         agg(Delta("x1", "==", 1))])
    assert signature_of(m).key() != signature_of(n).key()
    assert signature_of(m).filters == signature_of(n).filters


def test_workload_recorder_bounded_and_aggregates(tmp_path):
    rec = WorkloadRecorder(capacity=4)
    sig = signature_of(QUERIES[0])
    for i in range(10):
        rec.record("read", "q_count", sig, "pinned_read", 100.0 + i, epoch=i)
    assert rec.n_recorded == 10 and rec.n_dropped == 6
    assert len(rec.records()) == 4
    by = rec.by_signature()
    e = by[sig.key()]
    assert e["n"] == 4 and e["hits"] == {"pinned_read": 4}
    assert e["views"] == ["q_count"]
    assert e["latency_us_mean"] == pytest.approx(107.5)

    path = tmp_path / "workload.json"
    payload = rec.export_json(str(path))
    on_disk = json.loads(path.read_text())
    assert on_disk["n_recorded"] == payload["n_recorded"] == 10
    assert len(on_disk["records"]) == 4

    off = WorkloadRecorder(capacity=0)         # disabled: record is a no-op
    off.record("read", "q", sig, "pinned_read", 1.0)
    assert not off.enabled and off.n_recorded == 0


def test_structured_logger_rate_limits(caplog):
    log = obs.get_logger("repro.test_obs")
    with caplog.at_level(logging.WARNING, logger="repro.test_obs"):
        assert log.warning_every(60.0, "k", "lagging", lag=3)
        assert not log.warning_every(60.0, "k", "lagging", lag=4)
        assert log.warning_every(60.0, "k2", "other key passes")
    assert sum("lagging lag=3" in r.message for r in caplog.records) == 1
    assert not any("lag=4" in r.message for r in caplog.records)


# ------------------------------------------------- wiring: compile/IVM/serve

def test_spans_thread_through_engine(tracing, tmp_path):
    """One session exercising compile -> init -> tick -> serve leaves the
    full span taxonomy in the trace, and the chrome export is loadable."""
    db = repro.connect(make_schema(), tables=make_tables(),
                       config=repro.ExecutionConfig(block_size=8))
    v = db.views(QUERIES)
    v.run()
    live = db.views(QUERIES, maintain=True)
    live.run()
    rng = np.random.default_rng(3)
    live.apply(DeltaBatchUpdate().insert("R2", r2_rows(rng, 3)))
    srv = live.serve(max_pinned_epochs=4)
    srv.read("q_count")

    names = {e["name"] for e in tracing.events()}
    assert {"compile", "compile.roots", "compile.pushdown", "compile.group",
            "compile.ir", "compile.schedule", "compile.bind",
            "ivm.init", "ivm.apply", "ivm.validate", "ivm.tick",
            "ivm.publish", "serve.read"} <= names
    tick = next(e for e in tracing.events() if e["name"] == "ivm.tick")
    assert tick["args"]["rel"] == "R2"
    path = tmp_path / "trace.json"
    obs.export_chrome(str(path))
    assert json.loads(path.read_text())["traceEvents"]


def test_autotune_span_and_delta_provenance(tmp_path, tracing):
    """Auto blocking resolves through autotune.tune spans, and explain()
    carries BOTH labeled resolutions (batch + delta) for maintained views —
    the delta lane no longer shadows the init full scan's."""
    cfg = repro.ExecutionConfig(
        block_size="auto", autotune_cache=str(tmp_path / "cache.json"))
    db = repro.connect(make_schema(), tables=make_tables(), config=cfg)
    live = db.views(QUERIES, maintain=True)
    live.run()
    rng = np.random.default_rng(3)
    live.apply(DeltaBatchUpdate().insert("R2", r2_rows(rng, 3)))
    rep = live.explain()
    assert rep.autotune and rep.autotune_delta
    s = rep.summary()
    assert "autotune[batch]:" in s and "autotune[delta]:" in s
    names = {e["name"] for e in tracing.events()}
    assert "compile.autotune" in names and "autotune.tune" in names
    assert "autotune.probe" in names


def test_server_stats_latency_lag_and_warning():
    db = repro.connect(make_schema(), tables=make_tables(),
                       config=repro.ExecutionConfig(block_size=8))
    live = db.views(QUERIES, maintain=True)
    srv = live.serve(max_pinned_epochs=8, warn_epoch_lag=1)
    rng = np.random.default_rng(5)

    def upd():
        return DeltaBatchUpdate().insert("R2", r2_rows(rng, 2))

    srv.read()
    with srv.snapshot() as snap:               # laggard pin
        assert snap.epoch_lag == 0
        srv.apply(upd())
        srv.apply(upd())
        assert snap.epoch_lag == 2             # head advanced past the pin
        st = srv.stats()
        assert st["epoch_lag"] == 2
        assert st["n_lag_warnings"] >= 1       # lag 2 > threshold 1
    assert srv.epoch_lag == 0                  # pin released
    st = srv.stats()
    assert st["read_us"]["count"] == 1 and st["read_us"]["p50"] > 0
    assert st["tick_us"]["count"] == 2         # the init full scan not counted
    assert st["pinned_epochs_hwm"] >= 1
    # summary renders the serving latency line
    s = live.explain().summary()
    assert "serve:" in s and "lag=" in s and "read_p50=" in s


def test_workload_records_every_path():
    """The recorder sees one signature per view through every hit path:
    batch scan, maintained full scan, epoch read, pinned serving read."""
    db = repro.connect(make_schema(), tables=make_tables(),
                       config=repro.ExecutionConfig(block_size=8))
    v = db.views(QUERIES)
    v.run()                                    # batch_scan
    live = db.views(QUERIES, maintain=True)
    live.run()                                 # full_scan
    live.run()                                 # epoch_read
    srv = live.serve()
    srv.read()                                 # pinned_read x all views
    srv.read("q_g1")                           # pinned_read x one view

    by = db.workload.by_signature()
    assert len(by) == len(QUERIES)
    for q in QUERIES:
        e = by[signature_of(q).key()]
        assert e["hits"]["batch_scan"] == 1
        assert e["hits"]["full_scan"] == 1
        assert e["hits"]["epoch_read"] == 1
        assert e["hits"]["pinned_read"] >= 1
        assert e["latency_us_mean"] > 0
    assert by[signature_of(QUERIES[1]).key()]["hits"]["pinned_read"] == 2
    # capacity 0 disables recording end to end
    db0 = repro.connect(make_schema(), tables=make_tables(),
                        config=repro.ExecutionConfig(block_size=8,
                                                     workload_capacity=0))
    db0.views(QUERIES).run()
    assert db0.workload.n_recorded == 0


def test_execution_config_validates_telemetry_knobs():
    with pytest.raises(ValueError):
        repro.ExecutionConfig(warn_epoch_lag=0)
    with pytest.raises(ValueError):
        repro.ExecutionConfig(workload_capacity=-1)
    with pytest.raises(ValueError):
        from repro.serve.views import ViewServer
        db = repro.connect(make_schema(), tables=make_tables())
        ViewServer(db.views(QUERIES, maintain=True).maintained,
                   warn_epoch_lag=0)


# ----------------------------------------- contracts with telemetry enabled

def test_sharded_steady_state_contract_with_telemetry(subproc):
    """Headline constraint: the sharded steady-state tick keeps the
    zero-transfer / zero-retrace contract with tracing, metrics, and the
    workload recorder ALL enabled — identical contract counters to the
    telemetry-off run in test_ivm_sharded.py."""
    subproc("""
import numpy as np
import jax

import repro
from repro import obs
from repro.core import COUNT, Delta, Var, agg, query, schema, sum_of
from repro.data import DeltaBatchUpdate, from_numpy
from repro.data import relations as relmod

S = schema(
    [("x1", "categorical", 3), ("x2", "key", 4), ("x3", "key", 5),
     ("x4", "categorical", 3), ("u", "continuous", 0)],
    [("R1", ["x1", "x2"]), ("R2", ["x2", "x3", "u"]), ("R3", ["x3", "x4"])])
rng = np.random.default_rng(7)
tables = {
    "R1": {"x1": rng.integers(0, 3, 17), "x2": rng.integers(0, 4, 17)},
    "R2": {"x2": rng.integers(0, 4, 29), "x3": rng.integers(0, 5, 29),
           "u": rng.normal(size=29).astype(np.float32)},
    "R3": {"x3": rng.integers(0, 5, 13), "x4": rng.integers(0, 3, 13)}}
QUERIES = [
    query("q_count", [], [COUNT]),
    query("q_g1", ["x1"], [COUNT, sum_of("u")]),
    query("q_delta", ["x4"], [agg(Var("u"), Delta("x1", "==", 1))]),
]

obs.enable_tracing()                 # telemetry ON for the whole run
mesh = jax.make_mesh((len(jax.devices()),), ("data",))
sharded = repro.connect(from_numpy(S, tables),
                        config=repro.ExecutionConfig(block_size=8, mesh=mesh))
vs = sharded.views(QUERIES, maintain=True)
vs.run()
mb = vs.maintained
srv = vs.serve(max_pinned_epochs=8, warn_epoch_lag=2)

def r2_rows(k):
    return {"x2": rng.integers(0, 4, k), "x3": rng.integers(0, 5, k),
            "u": rng.normal(size=k).astype(np.float32)}

def fixed_update():
    return (DeltaBatchUpdate().insert("R2", r2_rows(4))
            .delete("R2", rng.choice(20, 2, replace=False)))

for _ in range(3):                      # warm pad buckets and capacity
    srv.apply(fixed_update())
srv.read()                              # warm the read path
runners = len(mb._runners)
traces = mb.n_fold_traces + relmod.advance_trace_count()
with jax.transfer_guard("disallow"):    # the steady-state contract
    for _ in range(5):
        srv.apply(fixed_update())
        srv.read("q_count")             # telemetry-on serving read, no sync
assert mb.n_fold_traces + relmod.advance_trace_count() == traces
assert len(mb._runners) == runners == 1

# telemetry actually observed the steady-state work it rode along with
st = srv.stats()
assert st["tick_us"]["count"] >= 8 and st["tick_us"]["p50"] > 0
assert st["read_us"]["count"] >= 6
names = {e["name"] for e in obs.get_tracer().events()}
assert {"ivm.apply", "ivm.tick", "ivm.publish", "serve.read"} <= names
assert sharded.workload.n_recorded > 0
print("OK")
""", 4)


@pytest.mark.slow
def test_serving_epoch_consistent_under_updates_with_telemetry():
    """The concurrent-updater serving semantics (mirrors
    test_serve_views.py) hold with tracing + metrics + workload recording
    enabled: a pinned reader's epoch stays frozen while the writer
    publishes, and the contract counters match the telemetry-off run."""
    obs.clear_trace()
    obs.enable_tracing()
    try:
        db = repro.connect(make_schema(), tables=make_tables(),
                           config=repro.ExecutionConfig(block_size=8))
        live = db.views(QUERIES, maintain=True)
        srv = live.serve(max_pinned_epochs=8, warn_epoch_lag=4)
        rng = np.random.default_rng(9)
        updates = [DeltaBatchUpdate().insert("R2", r2_rows(rng, 3))
                   for _ in range(6)]
        errors = []
        with srv.snapshot() as snap:
            first = {n: np.asarray(v).copy()
                     for n, v in snap.results().items()}
            e0 = snap.epoch

            def updater():
                try:
                    for upd in updates:
                        srv.apply(upd)
                except Exception as exc:
                    errors.append(exc)

            t = threading.Thread(target=updater)
            t.start()
            for _ in range(6):          # re-extract, bypassing the cache
                got = srv.maintained.results(epoch=snap.epoch)
                for n in first:
                    np.testing.assert_allclose(
                        first[n], np.asarray(got[n]), rtol=1e-5, err_msg=n)
            t.join()
            assert not errors, errors
            assert srv.epoch == e0 + len(updates)
        st = srv.stats()
        assert st["n_updates"] == len(updates)
        assert st["n_rejected_updates"] == 0
        assert st["tick_us"]["count"] == len(updates)
        names = {e["name"] for e in obs.get_tracer().events()}
        assert {"ivm.apply", "serve.read"} <= names
    finally:
        obs.disable_tracing()
        obs.clear_trace()


@pytest.mark.slow
def test_telemetry_overhead_under_5_percent():
    """The no-sync instrumentation rule, quantified: steady-state tick wall
    with tracing+metrics enabled stays within 5% of disabled (interleaved
    min-of-N pairs — min is robust to scheduler noise in both directions)."""
    db = repro.connect(make_schema(), tables=make_tables(),
                       config=repro.ExecutionConfig(block_size=8))
    live = db.views(QUERIES, maintain=True)
    live.run()
    mb = live.maintained
    rng = np.random.default_rng(13)

    def fixed_update():
        return (DeltaBatchUpdate().insert("R2", r2_rows(rng, 4))
                .delete("R2", rng.choice(20, 2, replace=False)))

    import jax

    def tick():
        jax.block_until_ready(mb.apply(fixed_update())["q_count"])

    for _ in range(5):                          # warm pad buckets + runners
        tick()
    t_off, t_on = [], []
    for _ in range(40):                         # interleaved A/B pairs
        obs.disable_tracing()
        t0 = time.perf_counter()
        tick()
        t_off.append(time.perf_counter() - t0)
        obs.enable_tracing()
        t0 = time.perf_counter()
        tick()
        t_on.append(time.perf_counter() - t0)
    obs.disable_tracing()
    obs.clear_trace()
    assert min(t_on) <= min(t_off) * 1.05 + 200e-6, (
        f"telemetry overhead: on={min(t_on) * 1e6:.0f}us "
        f"off={min(t_off) * 1e6:.0f}us")
