"""Static analysis: the plan verifier and the engine-contract linter.

Verifier coverage comes in two halves (DESIGN.md §12):

* zero false positives — every artifact the engine actually compiles
  (batch plans, batched-param plans, delta programs, tick programs under a
  synthetic placement, resident relations) must verify clean;
* a violating witness per invariant — each rule in the catalog gets a
  mutation test that corrupts a *real* compiled artifact in exactly the way
  the rule forbids and asserts the structured error names that rule.  No
  invariant ships without a witness that it can actually fire.
"""

import dataclasses
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import lint as L
from repro.analysis.verify import (ALL_INVARIANTS, PlanInvariantError,
                                   verification_enabled, verify_delta_program,
                                   verify_plan, verify_resident,
                                   verify_tick_program)
from repro.api import ExecutionConfig, connect
from repro.core import COUNT, Delta, Pow, Var, agg, query, schema, sum_of
from repro.core.aggregates import Param
from repro.core.ivm import build_tick_program
from repro.data import DeltaBatchUpdate, from_numpy
from repro.data.relations import ResidentRelation

ROOT = Path(__file__).resolve().parent.parent


def chain_schema():
    return schema(
        [("x1", "categorical", 3), ("x2", "key", 4), ("x3", "key", 5),
         ("x4", "categorical", 3), ("u", "continuous", 0)],
        [("R1", ["x1", "x2"]), ("R2", ["x2", "x3", "u"]), ("R3", ["x3", "x4"])])


def chain_db(seed=0, n1=17, n2=29, n3=13):
    rng = np.random.default_rng(seed)
    return {"R1": {"x1": rng.integers(0, 3, n1), "x2": rng.integers(0, 4, n1)},
            "R2": {"x2": rng.integers(0, 4, n2), "x3": rng.integers(0, 5, n2),
                   "u": rng.normal(size=n2).astype(np.float32)},
            "R3": {"x3": rng.integers(0, 5, n3), "x4": rng.integers(0, 3, n3)}}


QUERIES = [
    query("q_count", [], [COUNT]),
    query("q_sums", [], [sum_of("u"), agg(Pow("u", 2))]),
    query("q_g1", ["x1"], [COUNT, sum_of("u")]),
    query("q_g2", ["x1", "x4"], [COUNT]),
    query("q_delta", ["x4"], [agg(Var("u"), Delta("x1", "==", 1))]),
]


@pytest.fixture(scope="module")
def sess():
    return connect(from_numpy(chain_schema(), chain_db()))


@pytest.fixture(scope="module")
def plan(sess):
    return sess.views(QUERIES).compiled.plan


@pytest.fixture(scope="module")
def maintained(sess):
    h = sess.views(QUERIES, maintain=True, warm_rels=["R1", "R2", "R3"])
    h.run()
    return h


class _Mutant:
    """A plan stand-in for :func:`verify_plan`: copies the real plan's
    artifacts so a witness can corrupt one without touching the shared
    module-scoped fixture."""

    def __init__(self, plan, **over):
        self.schema = plan.schema
        self.views = plan.views
        self.programs = dict(plan.programs)
        self.groups = list(plan.groups)
        self.schedule = plan.schedule
        self.step_programs = list(plan.step_programs)
        for k, v in over.items():
            setattr(self, k, v)


def _expect(invariant, fn, *args):
    with pytest.raises(PlanInvariantError) as ei:
        fn(*args)
    assert ei.value.invariant == invariant, ei.value
    return ei.value


def _first(seq, pred):
    for x in seq:
        if pred(x):
            return x
    raise AssertionError("fixture plan lacks the structure this witness "
                         "needs — extend QUERIES")


# -- zero false positives on real artifacts ----------------------------------

def test_real_plan_verifies_clean(plan):
    rep = verify_plan(plan)
    assert rep.n_checks > 0
    assert set(rep.invariants) <= set(ALL_INVARIANTS)
    assert "plan ok" in rep.summary()
    # the compile itself ran the verifier (auto-on under pytest)
    assert plan.last_verification is not None


def test_batched_param_plan_verifies_clean(sess):
    q = query("qb", ["x4"],
              [agg(Var("u"), Delta("x1", "==", Param("t", batched=True)))])
    p = sess.views(QUERIES + [q]).compiled.plan
    rep = verify_plan(p)
    assert "batched-flag" in rep.invariants
    # at least one view actually carries the node axis, so the flag checks
    # exercised both polarities
    assert any(vp.batched for sp in p.step_programs for vp in sp.views)


def test_maintained_artifacts_verify_clean(maintained):
    mb = maintained.maintained
    for rel in ["R1", "R2", "R3"]:
        dp = mb.delta_program(rel)
        rep = verify_delta_program(mb.batch.plan, dp)
        assert rep.n_checks > 0
        tp = mb.tick_program(rel)
        assert verify_tick_program(tp, dp).n_checks > 0
    rng = np.random.default_rng(0)
    maintained.apply(DeltaBatchUpdate().insert(
        "R2", {"x2": rng.integers(0, 4, 3), "x3": rng.integers(0, 5, 3),
               "u": rng.normal(size=3).astype(np.float32)}))
    assert any(k.startswith("tick Δ") for k in mb.last_verifications)


def test_sharded_tick_program_verifies_clean(maintained):
    """The sharded placement is verifiable without a mesh: build the tick
    for a synthetic shard choice and check psum-before-fold structurally."""
    mb = maintained.maintained
    dp = mb.delta_program("R1")
    tp = build_tick_program(dp, shard_rel="R2", axis="data")
    rep = verify_tick_program(tp, dp)
    assert "psum-before-fold" in rep.invariants
    assert any(ts.partitioned and ts.psum_vids for ts in tp.steps)


def test_resident_relation_verifies_clean(sess):
    rr = ResidentRelation.from_relation(sess.relation("R1"))
    rep = verify_resident(rr)
    assert "resident-capacity" in rep.invariants


def test_explain_surfaces_verification(maintained):
    rep = maintained.explain()
    assert rep.verification is not None and "ok" in rep.verification
    assert "verify:" in rep.summary()


def test_debug_views_force_verification(sess):
    db = sess.with_config(verify_plans=False)
    off = db.views([query("q", [], [COUNT])])
    assert off.compiled.plan.last_verification is None
    on = db.views([query("q", [], [COUNT])], debug=True)
    assert on.compiled.plan.last_verification is not None


def test_verification_enabled_resolution(monkeypatch):
    assert verification_enabled(True) is True
    assert verification_enabled(False) is False
    monkeypatch.setenv("REPRO_VERIFY", "0")
    assert verification_enabled(None) is False
    monkeypatch.setenv("REPRO_VERIFY", "1")
    assert verification_enabled(None) is True
    monkeypatch.delenv("REPRO_VERIFY")
    assert verification_enabled(None) is True   # PYTEST_CURRENT_TEST is set


# -- mutation witnesses: one per invariant -----------------------------------

def _programs_with(plan, pred):
    return [(gid, plan.programs[gid]) for gid in sorted(plan.programs)
            if pred(plan.programs[gid])]


def test_witness_gather_prefix(plan):
    gid, prog = _first(_programs_with(plan, lambda p: p.gathers),
                       lambda _: True)
    gs = _first(prog.gathers, lambda g: g.gather)
    bad = dataclasses.replace(gs, gather=(), rest=gs.gather + gs.rest)
    m = _Mutant(plan)
    m.programs[gid] = dataclasses.replace(
        prog, gathers=tuple(bad if g is gs else g for g in prog.gathers))
    _expect("gather-prefix", verify_plan, m)


def test_witness_segment_layout(plan):
    gid, prog = _first(
        _programs_with(plan, lambda p: any(v.seg for v in p.views)),
        lambda _: True)
    vp = _first(prog.views, lambda v: v.seg is not None)
    bad = dataclasses.replace(
        vp, seg=dataclasses.replace(vp.seg, n_segments=vp.seg.n_segments + 1))
    m = _Mutant(plan)
    m.programs[gid] = dataclasses.replace(
        prog, views=tuple(bad if v is vp else v for v in prog.views))
    _expect("segment-layout", verify_plan, m)


def test_witness_acc_shape(plan):
    gid = sorted(plan.programs)[0]
    prog = plan.programs[gid]
    vp = prog.views[0]
    bad = dataclasses.replace(
        vp, acc_shape=vp.acc_shape[:-1] + (vp.acc_shape[-1] + 1,))
    m = _Mutant(plan)
    m.programs[gid] = dataclasses.replace(
        prog, views=tuple(bad if v is vp else v for v in prog.views))
    _expect("acc-shape", verify_plan, m)


def _mutate_product(prog, pred, fn):
    """Replace the first product satisfying ``pred`` via ``fn`` inside a
    (frozen, deeply nested) scan program; returns the rebuilt program."""
    for vi, vp in enumerate(prog.views):
        for ci, col in enumerate(vp.cols):
            for pi, pr in enumerate(col.products):
                if not pred(pr):
                    continue
                new_col = dataclasses.replace(
                    col, products=tuple(fn(p) if i == pi else p
                                        for i, p in enumerate(col.products)))
                new_vp = dataclasses.replace(
                    vp, cols=tuple(new_col if i == ci else c
                                   for i, c in enumerate(vp.cols)))
                return dataclasses.replace(
                    prog, views=tuple(new_vp if i == vi else v
                                      for i, v in enumerate(prog.views)))
    raise AssertionError("no product matched the witness predicate")


def test_witness_axis_frame(plan):
    gid = sorted(plan.programs)[0]
    m = _Mutant(plan)
    m.programs[gid] = _mutate_product(
        plan.programs[gid], lambda p: True,
        lambda p: dataclasses.replace(p, n_keep=p.n_keep + 1))
    _expect("axis-frame", verify_plan, m)


def test_witness_dtype_flow(plan):
    gid, prog = _first(
        _programs_with(plan, lambda p: any(
            pr.child_refs for v in p.views for c in v.cols
            for pr in c.products)),
        lambda _: True)
    m = _Mutant(plan)
    m.programs[gid] = _mutate_product(
        prog, lambda p: p.child_refs,
        lambda p: dataclasses.replace(
            p, child_refs=(dataclasses.replace(p.child_refs[0], col=999),)
            + p.child_refs[1:]))
    _expect("dtype-flow", verify_plan, m)


def test_witness_schedule_topo(plan):
    sched = plan.schedule
    steps = list(sched.steps)
    steps[0] = dataclasses.replace(steps[0], rel="NoSuchRel")
    m = _Mutant(plan, schedule=dataclasses.replace(sched, steps=steps))
    _expect("schedule-topo", verify_plan, m)


def test_witness_batched_flag(sess):
    q = query("qb", ["x4"],
              [agg(Var("u"), Delta("x1", "==", Param("t", batched=True)))])
    p = sess.views(QUERIES + [q]).compiled.plan
    gid, prog = _first(
        [(g, p.programs[g]) for g in sorted(p.programs)],
        lambda gp: any(v.batched for v in gp[1].views))
    vp = _first(prog.views, lambda v: v.batched)
    bad = dataclasses.replace(vp, batched=False)
    m = _Mutant(p)
    m.programs[gid] = dataclasses.replace(
        prog, views=tuple(bad if v is vp else v for v in prog.views))
    _expect("batched-flag", verify_plan, m)


def test_witness_weight_compat(maintained):
    mb = maintained.maintained
    dp = mb.delta_program("R2")
    st0 = dp.steps[0]
    bad = dataclasses.replace(
        dp, steps=(dataclasses.replace(st0, scans_delta=not st0.scans_delta),)
        + dp.steps[1:])
    _expect("weight-compat", verify_delta_program, mb.batch.plan, bad)


def test_witness_delta_first_order(maintained):
    """Duplicating the one affected child factor of a tier-2 product makes
    it second-order — the rule the whole IVM soundness argument rests on."""
    mb = maintained.maintained
    dp = _first([mb.delta_program(r) for r in ["R1", "R2", "R3"]],
                lambda d: any(not s.scans_delta for s in d.steps))
    idx, st = _first(list(enumerate(dp.steps)),
                     lambda t: not t[1].scans_delta)

    def dup_affected(p):
        ref = _first(p.child_refs, lambda r: r.vid in dp.affected)
        return dataclasses.replace(p, child_refs=p.child_refs + (ref,))

    bad_prog = _mutate_product(
        st.prog,
        lambda p: any(r.vid in dp.affected for r in p.child_refs),
        dup_affected)
    bad = dataclasses.replace(
        dp, steps=tuple(dataclasses.replace(s, prog=bad_prog) if i == idx
                        else s for i, s in enumerate(dp.steps)))
    err = _expect("delta-first-order", verify_delta_program,
                  mb.batch.plan, bad)
    assert "first-order" in err.detail


def test_witness_psum_before_fold(maintained):
    mb = maintained.maintained
    dp = mb.delta_program("R1")
    tp = build_tick_program(dp, shard_rel="R2", axis="data")
    idx, ts = _first(list(enumerate(tp.steps)), lambda t: t[1].partitioned)
    # dropping the psum on a partitioned scan leaks per-shard partials
    bad = dataclasses.replace(
        tp, steps=tuple(dataclasses.replace(s, psum_vids=()) if i == idx
                        else s for i, s in enumerate(tp.steps)))
    _expect("psum-before-fold", verify_tick_program, bad, dp)
    # psumming a replicated scan would multiply its delta by the device count
    jdx, js = _first(list(enumerate(tp.steps)),
                     lambda t: not t[1].partitioned)
    vids = tuple(vp.vid for vp in js.prog.views)
    bad2 = dataclasses.replace(
        tp, steps=tuple(dataclasses.replace(s, psum_vids=vids) if i == jdx
                        else s for i, s in enumerate(tp.steps)))
    _expect("psum-before-fold", verify_tick_program, bad2, dp)


def test_witness_weight_compat_tick(maintained):
    mb = maintained.maintained
    dp = mb.delta_program("R2")
    tp = build_tick_program(dp)
    idx, ts = _first(list(enumerate(tp.steps)), lambda t: t[1].weighted)
    bad = dataclasses.replace(
        tp, steps=tuple(dataclasses.replace(s, weighted=False) if i == idx
                        else s for i, s in enumerate(tp.steps)))
    _expect("weight-compat", verify_tick_program, bad, dp)


def test_witness_resident_capacity(sess):
    rr = ResidentRelation.from_relation(sess.relation("R1"))
    _expect("resident-capacity", verify_resident,
            dataclasses.replace(rr, n_valid=rr.capacity + 1))
    ragged = dataclasses.replace(
        rr, buffers={a: (c[:-1] if i == 0 else c)
                     for i, (a, c) in enumerate(rr.buffers.items())})
    _expect("resident-capacity", verify_resident, ragged)


class _FakeShardedResident:
    """Host-only stand-in matching the duck type :func:`verify_resident`
    reads for sharded relations (``gids`` marks it sharded)."""

    def __init__(self, ndev=4, cap=8, n_valid=10):
        self.name = "F"
        self.n_devices = ndev
        self.buffers = {"x": np.zeros(ndev * cap, np.int32)}
        self.gids = np.arange(ndev * cap, dtype=np.int32)
        self.n_valid = n_valid
        per = [min(cap, max(0, n_valid - i * cap)) for i in range(ndev)]
        self.n_valid_ub = np.asarray(per, np.int32)
        self.n_valid_dev = np.asarray(per, np.int32)

    @property
    def capacity(self):
        return self.buffers["x"].shape[0] // self.n_devices


def test_witness_resident_capacity_sharded():
    ok = _FakeShardedResident()
    assert verify_resident(ok).n_checks > 0
    bad = _FakeShardedResident()
    bad.n_valid_ub = bad.n_valid_ub + bad.capacity + 1  # escapes [0, cap]
    _expect("resident-capacity", verify_resident, bad)


def test_witness_route_subsume():
    from repro.analysis.verify import verify_secondary_program
    from repro.core.subsume import ViewShape, build_secondary_program

    wide = ViewShape("cube", ("x1", "x4"), (3, 3), ("1", "u"))
    narrow = ViewShape("probe", ("x4",), (3,), ("u",))
    sp = build_secondary_program(wide, narrow)
    assert verify_secondary_program(sp).n_checks > 0   # real program: clean

    # dropping the sum axis would answer the wide grouping, not the probe
    _expect("route-subsume", verify_secondary_program,
            dataclasses.replace(sp, sum_axes=()))
    # picking the COUNT column for a SUM(u) target breaks render equality
    _expect("route-subsume", verify_secondary_program,
            dataclasses.replace(sp, col_idx=(0,)))
    # a target dim outside the source view is not derivable
    _expect("route-subsume", verify_secondary_program,
            dataclasses.replace(
                sp, target=dataclasses.replace(narrow, dims=("x3",))))
    # domain disagreement on a shared dim mis-shapes the answer tensor
    _expect("route-subsume", verify_secondary_program,
            dataclasses.replace(
                sp, target=dataclasses.replace(narrow, domains=(4,))))
    # a broken output permutation scrambles the user's dim order
    _expect("route-subsume", verify_secondary_program,
            dataclasses.replace(sp, perm=(0, 0)))


def test_every_invariant_has_a_witness():
    """The witness suite must cover the full DESIGN.md §12 catalog: each
    rule id appears in some test name above (no invariant without a way to
    make it fire)."""
    src = Path(__file__).read_text()
    for inv in ALL_INVARIANTS:
        probe = "test_witness_" + inv.replace("-", "_")
        assert probe in src, f"invariant {inv} has no mutation witness"


# -- engine-contract linter ---------------------------------------------------

def test_lint_clean_on_src_with_committed_allowlist():
    allow = L.load_allowlist(ROOT / "tools" / "lint_allowlist.json")
    violations = L.lint_paths([ROOT / "src"], allow, root=ROOT)
    assert violations == [], "\n".join(v.render() for v in violations)


_SEEDS = {
    "sync-call": (
        "import jax\nimport jax.numpy as jnp\nimport numpy as np\n"
        "def f(x):\n"
        "    jax.device_get(x)\n"
        "    x.block_until_ready()\n"
        "    float(jnp.sum(x))\n"
        "    np.asarray(jnp.mean(x))\n",
        4),
    "obs-no-device": (
        "import jax.numpy as jnp\n", 1),
    "engine-outside-core": (
        "from repro.core import Engine\n"
        "eng = Engine(None)\n"
        "eng.compile([])\n"
        "other.compile_incremental([])\n",
        3),
    "random-key": (
        "import jax\nkey = jax.random.PRNGKey(0)\n", 1),
}


@pytest.mark.parametrize("rule", sorted(_SEEDS))
def test_lint_rule_fires_on_seeded_violation(rule, tmp_path):
    src, n = _SEEDS[rule]
    rel = ("repro/obs/seeded.py" if rule == "obs-no-device"
           else "repro/seeded.py")
    hits = [v for v in L.lint_source(src, rel) if v.rule == rule]
    assert len(hits) == n, hits
    for v in hits:
        assert rule in v.render() and "remedy:" in v.render()
    # the allowlist remedy actually silences the violation
    f = tmp_path / rel
    f.parent.mkdir(parents=True, exist_ok=True)
    f.write_text(src)
    flagged = L.lint_paths([tmp_path], {}, root=tmp_path)
    assert any(v.rule == rule for v in flagged)
    allowed = L.lint_paths([tmp_path], {rule: {rel: "test waiver"}},
                           root=tmp_path)
    assert not any(v.rule == rule for v in allowed)


def test_lint_no_false_positives():
    clean = (
        "import re\nimport numpy as np\n"
        "import jax.numpy as jnp\n"
        "pat = re.compile('x')\n"              # .compile on non-engine recv
        "def f(lowered, cfg, xs, key):\n"
        "    lowered.compile()\n"              # jax lowering compile is fine
        "    np.asarray(xs)\n"                 # host data, no device call
        "    import jax\n"
        "    return jax.random.PRNGKey(cfg.seed)\n")  # non-literal seed
    assert L.lint_source(clean, "repro/clean.py") == []


def test_lint_allowlist_validation(tmp_path):
    bad_rule = tmp_path / "a.json"
    bad_rule.write_text('{"not-a-rule": {}}')
    with pytest.raises(ValueError, match="unknown rule"):
        L.load_allowlist(bad_rule)
    no_reason = tmp_path / "b.json"
    no_reason.write_text('{"sync-call": {"src/x.py": ""}}')
    with pytest.raises(ValueError, match="reason"):
        L.load_allowlist(no_reason)


def test_lint_cli_exit_codes(tmp_path):
    """``tools/lint_contracts.py`` is the CI gate: exit 0 on the repo, exit
    1 (printing rule + location + remedy) on a seeded violation."""
    r = subprocess.run([sys.executable, str(ROOT / "tools" / "lint_contracts.py")],
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "engine contracts clean" in r.stdout
    bad = tmp_path / "bad.py"
    bad.write_text("import jax\njax.device_get(1)\n")
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", str(bad),
         "--root", str(tmp_path), "--allowlist", str(tmp_path / "none.json")],
        capture_output=True, text=True,
        env={**__import__("os").environ,
             "PYTHONPATH": str(ROOT / "src")})
    assert r.returncode == 1
    assert "sync-call" in r.stdout and "bad.py:2" in r.stdout
    assert "remedy:" in r.stdout
