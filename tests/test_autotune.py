"""Compile-time blocking autotuner (core/autotune.py): cache hits do zero
timing work, the on-disk cache survives process restarts, and corrupt or
stale cache state degrades to defaults without ever raising mid-compile."""

import json

import pytest

from repro.core import autotune as at
from repro.core.autotune import (Autotuner, TuneSignature,
                                 signature_for_step)


def _sig(**kw):
    base = dict(backend="pallas", platform="cpu", interpret=True,
                n_rows=4096, n_segments=128, payload_width=16, n_nodes=None)
    base.update(kw)
    return signature_for_step(**base)


@pytest.fixture
def fast_tuner(monkeypatch):
    """Autotuner factory whose candidate timing is instant but still counts
    ``n_timed`` — tests assert on the counters, not wall time."""
    def make(path):
        t = Autotuner(str(path))

        def fake_time_candidates(sig):
            t.n_timed += len(at.BLOCK_SIZE_CANDIDATES)
            t.n_timed += len(at.BLOCK_ROWS_CANDIDATES)
            return 1024, 256
        monkeypatch.setattr(t, "_time_candidates", fake_time_candidates)
        return t
    return make


def test_signature_buckets_and_key():
    a = _sig(n_rows=4000)
    b = _sig(n_rows=4096)
    assert a.key() == b.key()          # same pow2 bucket -> same cache line
    assert _sig(n_rows=5000).key() != a.key()
    assert _sig(n_nodes=8).key() != a.key()
    assert a.key().startswith(f"v{at.CACHE_VERSION}/pallas/cpu/i1/")


def test_delta_signature_gets_own_cache_lane():
    """IVM delta ticks tune against |update|-sized shapes: the delta flag
    splits the cache line, so a full-scan tuning at the same pow2 bucket
    can never serve (or be polluted by) a delta-tick blocking."""
    a = _sig(n_rows=4096)
    d = _sig(n_rows=4096, delta=True)
    assert d.key() != a.key()
    assert d.key().endswith("/d1") and a.key().endswith("/d0")


def test_cache_hit_does_zero_timing(fast_tuner, tmp_path):
    path = tmp_path / "cache.json"
    t = fast_tuner(path)
    r1 = t.tune(_sig())
    assert (r1.block_size, r1.block_rows) == (1024, 256)
    assert not r1.from_cache and t.n_misses == 1 and t.n_timed > 0

    timed_after_miss = t.n_timed
    r2 = t.tune(_sig(n_rows=4000))     # same bucket -> hit, no timing
    assert r2.from_cache and (r2.block_size, r2.block_rows) == (1024, 256)
    assert t.n_hits == 1 and t.n_timed == timed_after_miss


def test_cache_survives_restart(fast_tuner, tmp_path):
    path = tmp_path / "cache.json"
    fast_tuner(path).tune(_sig())

    fresh = Autotuner(str(path))       # "new process": no monkeypatch needed
    r = fresh.tune(_sig())
    assert r.from_cache and (r.block_size, r.block_rows) == (1024, 256)
    assert fresh.n_timed == 0 and fresh.n_hits == 1 and fresh.n_misses == 0


def test_corrupt_cache_file_retunes_without_raising(fast_tuner, tmp_path):
    path = tmp_path / "cache.json"
    path.write_text("{not json!!")
    t = fast_tuner(path)
    r = t.tune(_sig())                 # load failure -> empty cache -> re-tune
    assert not r.from_cache and t.n_misses == 1
    # the re-tune rewrote a valid file
    blob = json.loads(path.read_text())
    assert blob["version"] == at.CACHE_VERSION and blob["entries"]


def test_corrupt_entry_falls_back_to_defaults(fast_tuner, tmp_path):
    path = tmp_path / "cache.json"
    key = _sig().key()
    path.write_text(json.dumps({
        "version": at.CACHE_VERSION,
        "entries": {key: {"block_size": "huge", "block_rows": 7}}}))
    t = fast_tuner(path)
    r = t.tune(_sig())                 # bad types / misaligned rows
    assert r.fallback and not r.from_cache
    assert (r.block_size, r.block_rows) == (at.DEFAULT_BLOCK_SIZE,
                                            at.DEFAULT_BLOCK_ROWS)
    assert t.n_fallbacks == 1 and t.n_timed == 0


def test_stale_version_discarded(fast_tuner, tmp_path):
    path = tmp_path / "cache.json"
    key = _sig().key()
    path.write_text(json.dumps({
        "version": at.CACHE_VERSION + 1,
        "entries": {key: {"block_size": 1024, "block_rows": 256}}}))
    t = fast_tuner(path)
    r = t.tune(_sig())                 # version mismatch -> whole cache dropped
    assert not r.from_cache and t.n_misses == 1


def test_real_timing_probe_smoke(tmp_path):
    """One un-mocked tune on a tiny signature: the probes must run (capped at
    MAX_PROBE_ROWS) and return a valid aligned blocking."""
    t = Autotuner(str(tmp_path / "cache.json"))
    r = t.tune(_sig(n_rows=512, n_segments=16, payload_width=4))
    assert isinstance(r.block_size, int) and r.block_size > 0
    assert r.block_rows % 8 == 0 and r.block_rows > 0
    assert t.n_timed > 0

    warm = Autotuner(str(tmp_path / "cache.json"))
    r2 = warm.tune(_sig(n_rows=512, n_segments=16, payload_width=4))
    assert r2.from_cache and warm.n_timed == 0
