"""Tests see 1 CPU device by default (dry-run sets its own XLA_FLAGS in a
subprocess).  Distributed tests spawn subprocesses with forced host device
counts."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int, timeout: int = 480) -> str:
    """Run a python snippet in a subprocess with n forced host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         env=env, capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_with_devices
