"""Fault tolerance: checkpoint/restart, resume-identical trajectories,
corruption detection, deterministic restart-safe data, straggler signal."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.slow  # end-to-end train/restart loops

from repro import configs
from repro.checkpoint import store
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.step import TrainConfig, init_state, make_train_step


def tiny_setup(tmp=None, compress=False):
    cfg = configs.get_smoke("internlm2-1.8b")
    tcfg = TrainConfig(peak_lr=1e-2, warmup=2, total_steps=30, ce_chunk=8,
                       attn_impl="dense", compress_grads=compress)
    pipe = TokenPipeline(PipelineConfig(4, 16, cfg.vocab, seed=0), cfg)
    state = init_state(cfg, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, tcfg))
    return cfg, tcfg, pipe, state, step


def test_checkpoint_roundtrip(tmp_path):
    _, _, _, state, _ = tiny_setup()
    path = store.save(str(tmp_path), 7, state)
    assert os.path.exists(os.path.join(path, "manifest.json"))
    restored, step = store.restore(str(tmp_path), state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_corruption_detected(tmp_path):
    _, _, _, state, _ = tiny_setup()
    store.save(str(tmp_path), 1, state)
    d = os.path.join(str(tmp_path), "step_00000001")
    victim = sorted(f for f in os.listdir(d) if f.endswith(".npy"))[0]
    arr = np.load(os.path.join(d, victim))
    np.save(os.path.join(d, victim), arr + 1.0)
    with pytest.raises(IOError):
        store.restore(str(tmp_path), state)


def test_checkpoint_gc_keeps_last_k(tmp_path):
    _, _, _, state, _ = tiny_setup()
    for s in range(5):
        store.save(str(tmp_path), s, state, keep=2)
    steps = sorted(d for d in os.listdir(str(tmp_path)) if d.startswith("step_"))
    assert len(steps) == 2
    assert store.latest_step(str(tmp_path)) == 4


def test_resume_identical_trajectory(tmp_path):
    """Interrupted-at-step-10 + resume == uninterrupted 20 steps."""
    cfg, tcfg, pipe, state0, step = tiny_setup()

    straight = TrainLoop(step, pipe, LoopConfig(max_steps=20, ckpt_every=100,
                                                ckpt_dir=None, log_every=0))
    s_state = straight.run(jax.tree.map(jnp.copy, state0))

    ck = str(tmp_path / "ck")
    first = TrainLoop(step, pipe, LoopConfig(max_steps=10, ckpt_every=10,
                                             ckpt_dir=ck, log_every=0))
    first.run(jax.tree.map(jnp.copy, state0))          # "crash" after step 10
    second = TrainLoop(step, pipe, LoopConfig(max_steps=20, ckpt_every=10,
                                              ckpt_dir=ck, log_every=0))
    r_state = second.run(jax.tree.map(jnp.copy, state0))

    resumed_losses = second.losses()
    straight_tail = straight.losses()[10:]
    np.testing.assert_allclose(resumed_losses, straight_tail, rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(s_state), jax.tree.leaves(r_state)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=1e-5, atol=1e-6)


def test_pipeline_determinism_and_sharding():
    cfg = configs.get_smoke("llama3-8b")
    pipe = TokenPipeline(PipelineConfig(8, 16, cfg.vocab, seed=5), cfg)
    b1 = pipe.batch_at(3)
    b2 = pipe.batch_at(3)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(pipe.batch_at(4)["tokens"]),
                              np.asarray(b1["tokens"]))
    # host shards tile the global batch
    parts = [pipe.host_shard(b1, i, 4)["tokens"] for i in range(4)]
    np.testing.assert_array_equal(np.concatenate([np.asarray(p) for p in parts]),
                                  np.asarray(b1["tokens"]))


def test_loss_decreases_end_to_end():
    cfg, tcfg, pipe, state, step = tiny_setup()
    loop = TrainLoop(step, pipe, LoopConfig(max_steps=30, ckpt_every=1000,
                                            log_every=0))
    loop.run(state)
    losses = loop.losses()
    assert losses[-1] < losses[0] - 0.3, losses[:3] + losses[-3:]


def test_grad_compression_still_learns():
    cfg, tcfg, pipe, state, step = tiny_setup(compress=True)
    loop = TrainLoop(step, pipe, LoopConfig(max_steps=30, ckpt_every=1000,
                                            log_every=0))
    loop.run(state)
    losses = loop.losses()
    assert losses[-1] < losses[0] - 0.3   # int8+EF does not break convergence


def test_straggler_detection():
    import time as _t
    cfg, tcfg, pipe, state, step = tiny_setup()
    calls = {"n": 0}

    def slow_step(s, b):
        calls["n"] += 1
        if calls["n"] == 12:
            _t.sleep(1.0)                 # inject a straggler
        return step(s, b)

    loop = TrainLoop(slow_step, pipe, LoopConfig(max_steps=15, ckpt_every=1000,
                                                 log_every=0, straggler_factor=3.0))
    loop.run(state)
    assert loop.straggler_events >= 1
    assert any(r.straggler for r in loop.records)
