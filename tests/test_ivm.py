"""IVM correctness: maintained view state must equal from-scratch
recomputation after any sequence of insert/delete batches, on both lowering
backends (deterministic sequences + a hypothesis property test), plus the
update API validation, snapshot/restore, and the streaming ML applications.

Everything compiles through the session facade (``repro.connect`` →
``Database.views``); the legacy ``Engine.compile*`` shims are no longer
exercised here."""

import jax
import numpy as np
import pytest

try:  # optional dev dependency: only the property test needs it
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    st = None

from repro.api import ExecutionConfig, connect
from repro.core import COUNT, Delta, Lambda, Pow, Var, agg, query, schema, \
    sum_of
from repro.data import DeltaBatchUpdate, apply_delta, from_numpy
from repro.data import relations as relmod
from repro.data.relations import Relation, ResidentRelation

BACKENDS = [("xla", None), ("pallas", True)]  # (backend, interpret)


def session(db, backend="xla", interpret=None, block_size=8):
    return connect(db, config=ExecutionConfig(
        block_size=block_size, backend=backend, interpret=interpret))


def compile_maintained(db, **kw):
    """A MaintainedBatch through the facade (init stays explicit)."""
    return session(db, **kw).views(QUERIES, maintain=True).maintained


class ScratchOracle:
    """From-scratch oracle on the facade: compile the batch once, then
    answer each check by swapping the session's resident relations to the
    updated database and re-running the shared scan."""

    def __init__(self, db, **kw):
        self._sess = session(db, **kw)
        self._handle = self._sess.views(QUERIES)

    def __call__(self, db):
        self._sess.data = db
        return self._handle.run()


def chain_schema():
    return schema(
        [("x1", "categorical", 3), ("x2", "key", 4), ("x3", "key", 5),
         ("x4", "categorical", 3), ("u", "continuous", 0)],
        [("R1", ["x1", "x2"]), ("R2", ["x2", "x3", "u"]), ("R3", ["x3", "x4"])])


def chain_db(seed=0, n1=17, n2=29, n3=13):
    rng = np.random.default_rng(seed)
    return {"R1": {"x1": rng.integers(0, 3, n1), "x2": rng.integers(0, 4, n1)},
            "R2": {"x2": rng.integers(0, 4, n2), "x3": rng.integers(0, 5, n2),
                   "u": rng.normal(size=n2).astype(np.float32)},
            "R3": {"x3": rng.integers(0, 5, n3), "x4": rng.integers(0, 3, n3)}}


QUERIES = [
    query("q_count", [], [COUNT]),
    query("q_sums", [], [sum_of("u"), agg(Pow("u", 2))]),
    query("q_g1", ["x1"], [COUNT, sum_of("u")]),
    query("q_g2", ["x1", "x4"], [COUNT]),
    query("q_delta", ["x4"], [agg(Var("u"), Delta("x1", "==", 1))]),
]

_ROW_MAKERS = {
    "R1": lambda rng, k: {"x1": rng.integers(0, 3, k), "x2": rng.integers(0, 4, k)},
    "R2": lambda rng, k: {"x2": rng.integers(0, 4, k), "x3": rng.integers(0, 5, k),
                          "u": rng.normal(size=k).astype(np.float32)},
    "R3": lambda rng, k: {"x3": rng.integers(0, 5, k), "x4": rng.integers(0, 3, k)},
}


def rand_update(rng, sizes):
    upd = DeltaBatchUpdate()
    for rel in ["R1", "R2", "R3"]:
        if rng.random() < 0.45:
            upd.insert(rel, _ROW_MAKERS[rel](rng, int(rng.integers(1, 6))))
        n = sizes[rel]
        if n > 0 and rng.random() < 0.35:
            k = int(rng.integers(1, min(n, 5) + 1))
            upd.delete(rel, rng.choice(n, size=k, replace=False))
    if not upd.relations():  # guarantee a non-trivial update
        upd.insert("R2", _ROW_MAKERS["R2"](rng, 2))
    return upd


def assert_matches_scratch(mb, fresh_batch, db):
    got = mb.results()
    exp = fresh_batch(db)
    for q in QUERIES:
        np.testing.assert_allclose(np.asarray(got[q.name]),
                                   np.asarray(exp[q.name]),
                                   rtol=1e-3, atol=1e-3, err_msg=q.name)


@pytest.mark.parametrize("backend,interpret", BACKENDS)
def test_ivm_sequence_matches_scratch(backend, interpret):
    """Fixed update sequence (every relation, inserts + deletes, including
    emptying a relation): maintained results == fresh compile after every
    step, on both backends."""
    S = chain_schema()
    db = from_numpy(S, chain_db())
    mb = compile_maintained(db, backend=backend, interpret=interpret)
    mb.init(db)
    fresh = ScratchOracle(db, backend=backend, interpret=interpret)
    rng = np.random.default_rng(3)
    updates = [
        # fact-ish update
        DeltaBatchUpdate().insert("R2", _ROW_MAKERS["R2"](rng, 5))
                          .delete("R2", np.array([0, 7, 11])),
        # two relations at once
        (DeltaBatchUpdate().insert("R1", _ROW_MAKERS["R1"](rng, 4))
                           .delete("R3", np.array([2, 5]))),
        # empty R3 entirely ...
        DeltaBatchUpdate().delete("R3", np.arange(11)),
        # ... and repopulate it
        DeltaBatchUpdate().insert("R3", _ROW_MAKERS["R3"](rng, 6)),
    ]
    for upd in updates:
        mb.apply(upd)
        db = apply_delta(db, upd)
        assert_matches_scratch(mb, fresh, db)
    assert mb.step == len(updates)
    assert mb.n_delta_scan_steps > 0


def test_delta_program_structure():
    """Delta programs cover exactly the reachable sub-DAG: a leaf-relation
    update rescans downstream relations, and programs are cached."""
    S = chain_schema()
    db = from_numpy(S, chain_db())
    mb = compile_maintained(db)
    dp = mb.delta_program("R2")
    assert any(s.scans_delta for s in dp.steps)
    assert all(s.rel == "R2" for s in dp.steps if s.scans_delta)
    assert "R2" not in dp.base_rels
    assert dp is mb.delta_program("R2")          # cached
    # affected = views whose reach includes R2; all state inputs are known vids
    assert set(dp.affected) <= set(mb.plan.views)
    assert set(dp.affected) <= set(dp.state_vids)


def test_runner_cache_bounded_under_growth():
    """A growing stream must not retrace per tick: rescanned base relations
    pad to pow2 with dynamic validity, so jit entries grow log₂ with size."""
    S = chain_schema()
    db = from_numpy(S, chain_db())
    mb = compile_maintained(db)
    mb.init(db)
    fresh = ScratchOracle(db)
    rng = np.random.default_rng(1)
    for _ in range(5):
        # R2 grows every tick while R1's delta program rescans it; without
        # padding this would be a fresh trace per apply
        upd = (DeltaBatchUpdate().insert("R2", _ROW_MAKERS["R2"](rng, 3))
               .insert("R1", _ROW_MAKERS["R1"](rng, 2)))
        mb.apply(upd)
        db = apply_delta(db, upd)
    assert_matches_scratch(mb, fresh, db)
    # R2 crosses one pow2 boundary (32→64) over the stream: ≤2 runners for
    # R1's program + 1 for R2's — never one per tick
    assert len(mb._runners) <= 4


def test_apply_requires_init():
    S = chain_schema()
    db = from_numpy(S, chain_db())
    mb = compile_maintained(db)
    with pytest.raises(ValueError, match="init"):
        mb.apply(DeltaBatchUpdate().insert("R1", _ROW_MAKERS["R1"](
            np.random.default_rng(0), 2)))


def test_snapshot_restore_roundtrip(tmp_path):
    """save → restore into a *fresh* MaintainedBatch (no init), then keep
    applying updates; state and results must carry over exactly."""
    S = chain_schema()
    db = from_numpy(S, chain_db())
    mb = compile_maintained(db)
    mb.init(db)
    rng = np.random.default_rng(5)
    upd = (DeltaBatchUpdate().insert("R2", _ROW_MAKERS["R2"](rng, 3))
           .delete("R1", np.array([1])))
    mb.apply(upd)
    db = apply_delta(db, upd)
    mb.save(str(tmp_path))

    mb2 = compile_maintained(db)
    assert mb2.restore(str(tmp_path)) == 1
    assert mb2.step == 1
    r1, r2 = mb.results(), mb2.results()
    for q in QUERIES:
        np.testing.assert_allclose(np.asarray(r2[q.name]),
                                   np.asarray(r1[q.name]), err_msg=q.name)
    upd2 = DeltaBatchUpdate().insert("R3", _ROW_MAKERS["R3"](rng, 4))
    mb2.apply(upd2)
    db = apply_delta(db, upd2)
    assert_matches_scratch(mb2, ScratchOracle(db), db)


# -- epoch versioning / transactional apply -----------------------------------

def test_rejected_batch_is_clean_noop():
    """Regression: a batch whose *second* relation (sorted order) is invalid
    must leave results, epoch, and stored relations untouched — the old code
    folded R1 before noticing R3's bad rows, leaving state half-updated."""
    S = chain_schema()
    db = from_numpy(S, chain_db())
    mb = compile_maintained(db)
    mb.init(db)
    before = {q.name: np.asarray(v).copy()
              for q, v in zip(QUERIES, [mb.results()[q.name] for q in QUERIES])}
    epoch0, step0 = mb.epoch, mb.step
    rng = np.random.default_rng(0)
    bad = (DeltaBatchUpdate()
           .insert("R1", _ROW_MAKERS["R1"](rng, 4))              # valid
           .insert("R3", {"x3": np.array([0]), "x4": np.array([99])}))  # bad
    with pytest.raises(ValueError, match="outside"):
        mb.apply(bad)
    assert (mb.epoch, mb.step) == (epoch0, step0)
    after = mb.results()
    for q in QUERIES:
        np.testing.assert_array_equal(before[q.name], np.asarray(after[q.name]),
                                      err_msg=q.name)
    # stored relations also untouched: a valid follow-up matches the oracle
    good = DeltaBatchUpdate().insert("R1", _ROW_MAKERS["R1"](rng, 2))
    mb.apply(good)
    db = apply_delta(db, good)
    assert_matches_scratch(mb, ScratchOracle(db), db)

    # an out-of-range delete index is caught up front too
    with pytest.raises(ValueError, match="outside"):
        mb.apply(DeltaBatchUpdate().insert("R1", _ROW_MAKERS["R1"](rng, 1))
                                   .delete("R3", np.array([999])))
    assert mb.step == step0 + 1


def test_pinned_epoch_frozen_across_apply():
    """A reader pinned to epoch e sees bit-identical results before and
    after a concurrent apply publishes e+1; unpinned reads see e+1."""
    S = chain_schema()
    db = from_numpy(S, chain_db())
    mb = compile_maintained(db)
    mb.init(db)
    fresh = ScratchOracle(db)
    rng = np.random.default_rng(7)
    with mb.pinned() as e:
        before = {q.name: np.asarray(mb.results(epoch=e)[q.name]).copy()
                  for q in QUERIES}
        upd = (DeltaBatchUpdate().insert("R2", _ROW_MAKERS["R2"](rng, 4))
               .delete("R1", np.array([0, 2])))
        mb.apply(upd)
        db = apply_delta(db, upd)
        assert mb.epoch == e + 1
        after = mb.results(epoch=e)
        for q in QUERIES:
            np.testing.assert_array_equal(
                before[q.name], np.asarray(after[q.name]), err_msg=q.name)
        assert_matches_scratch(mb, fresh, db)   # current epoch advanced
    # released epoch is no longer addressable
    with pytest.raises(KeyError, match="pinned"):
        mb.results(epoch=e)


@pytest.mark.parametrize("backend,interpret", BACKENDS)
def test_steady_state_tick_no_transfers_no_retrace(backend, interpret):
    """Acceptance: a steady-state apply tick performs zero host transfers of
    relation columns (update payloads enter via explicit device_put, which
    the transfer guard permits) and zero retraces, on both backends."""
    S = chain_schema()
    db = from_numpy(S, chain_db())
    mb = compile_maintained(db, backend=backend, interpret=interpret)
    mb.init(db)
    rng = np.random.default_rng(13)

    def tick():
        # equal-count insert/delete: sizes, capacities, pad buckets all fixed
        return (DeltaBatchUpdate().insert("R2", _ROW_MAKERS["R2"](rng, 3))
                .delete("R2", rng.choice(29, 3, replace=False)))

    for _ in range(3):                      # warm: trace fold + extract once
        jax.block_until_ready(mb.apply(tick())["q_count"])
    traces0 = mb.n_fold_traces + relmod.advance_trace_count()
    with jax.transfer_guard("disallow"):    # implicit host<->device = error
        for _ in range(4):
            out = mb.apply(tick())
            jax.block_until_ready(out["q_count"])
    assert mb.n_fold_traces + relmod.advance_trace_count() == traces0
    # still correct after the guarded ticks
    fresh = ScratchOracle(mb.db, backend=backend, interpret=interpret)
    assert_matches_scratch(mb, fresh, mb.db)


def test_resident_relation_advance_matches_oracle():
    """Device-side delete-compact + append == the host Relation ops, order
    included; capacity grows by pow2 doubling and reuses buffers otherwise."""
    rng = np.random.default_rng(4)
    cols = {"a": rng.integers(0, 9, 11).astype(np.int32),
            "u": rng.normal(size=11).astype(np.float32)}
    host = Relation("T", {k: np.asarray(v) for k, v in cols.items()})
    rr = ResidentRelation.from_relation(
        Relation("T", {k: np.asarray(v) for k, v in cols.items()}))
    assert rr.capacity == 16 and rr.n_valid == 11
    # delete 3, insert 2 — stays within capacity
    del_idx = np.array([1, 4, 9], np.int32)
    ins = {"a": np.array([7, 8], np.int32),
           "u": np.array([0.5, -0.5], np.float32)}
    host = host.delete_rows(del_idx)
    host = Relation("T", {a: np.concatenate([np.asarray(host.columns[a]), ins[a]])
                          for a in host.columns})
    ins_dev = {a: jax.device_put(np.pad(c, (0, 2))) for a, c in ins.items()}  # pow2 pad
    dd = jax.device_put(np.pad(del_idx, (0, 1), constant_values=rr.capacity))
    rr = rr.advance(ins_dev, dd, 2, 3)
    assert rr.n_valid == 10 and int(rr.n_valid_dev) == 10
    got = rr.to_relation()
    for a in cols:
        np.testing.assert_array_equal(np.asarray(got.columns[a]),
                                      np.asarray(host.columns[a]), err_msg=a)
    # growth: insert 10 more crosses 16 -> 32
    ins2 = {"a": np.arange(10, dtype=np.int32),
            "u": np.ones(10, np.float32)}
    rr2 = rr.advance({a: jax.device_put(np.pad(c, (0, 6))) for a, c in ins2.items()},
                     jax.device_put(np.zeros((0,), np.int32)), 10, 0)
    assert rr2.capacity == 32 and rr2.n_valid == 20
    np.testing.assert_array_equal(
        np.asarray(rr2.to_relation().columns["a"])[:10],
        np.asarray(got.columns["a"]))


def test_non_invertible_aggregate_rejected():
    """MIN/MAX-style UDAFs (Lambda(invertible=False)) are rejected at
    compile_incremental time — signed multiplicities cannot retract them —
    while the batch path still compiles them."""
    S = chain_schema()
    db = from_numpy(S, chain_db())
    sess = session(db)
    qs = [query("q_softmax_max", [], [agg(Lambda(
        ("u",), lambda u, p: u, tag="running_max", invertible=False))])]
    with pytest.raises(ValueError, match="not invertible"):
        sess.views(qs, maintain=True)
    sess.views(qs)                                    # batch path: fine
    sess.views(QUERIES, maintain=True)                # SUM-like: fine


# -- update API validation ----------------------------------------------------

def test_append_delete_validation():
    S = chain_schema()
    db = from_numpy(S, chain_db())
    r1 = db.relation("R1")
    # happy paths
    assert r1.append({"x1": np.array([1]), "x2": np.array([2])}, S).n_rows == 18
    assert r1.delete_rows(np.array([0, 3])).n_rows == 15
    # schema-checked append: out-of-domain code / wrong dtype kind / bad cols
    with pytest.raises(ValueError, match="outside"):
        r1.append({"x1": np.array([99]), "x2": np.array([0])}, S)
    with pytest.raises(ValueError, match="integer"):
        r1.append({"x1": np.array([0.5]), "x2": np.array([0])}, S)
    with pytest.raises(ValueError, match="columns"):
        r1.append({"x1": np.array([0])}, S)
    with pytest.raises(ValueError, match="shape"):
        r1.append({"x1": np.array([0, 1]), "x2": np.array([0])}, S)
    # schema-less append still checks names/lengths/dtype kinds
    with pytest.raises(ValueError, match="dtype"):
        r1.append({"x1": np.array([0.5]), "x2": np.array([0])})
    # ... and refuses discrete columns outright: without a schema the code
    # domain is unknowable, and out-of-range codes would be silently dropped
    # by segment_sum (corrupted aggregates) instead of failing here
    with pytest.raises(ValueError, match="schema"):
        r1.append({"x1": np.array([1]), "x2": np.array([2])})
    # deletes: duplicates / out of range
    with pytest.raises(ValueError, match="duplicate"):
        r1.delete_rows(np.array([1, 1]))
    with pytest.raises(ValueError, match="outside"):
        r1.delete_rows(np.array([99]))


def test_delta_batch_update_validation():
    S = chain_schema()
    db = from_numpy(S, chain_db())
    with pytest.raises(ValueError, match="unknown relation"):
        apply_delta(db, DeltaBatchUpdate().insert(
            "Nope", {"x1": np.array([0])}))
    with pytest.raises(ValueError, match="outside"):
        apply_delta(db, DeltaBatchUpdate().delete("R1", np.array([99])))
    with pytest.raises(ValueError, match="already has inserts"):
        (DeltaBatchUpdate().insert("R1", {}).insert("R1", {}))


# -- hypothesis property test -------------------------------------------------

if st is None:
    def test_property_ivm_equals_scratch():
        pytest.skip("hypothesis not installed (pip install .[dev])")
else:
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**31), n_updates=st.integers(1, 3),
           backend_i=st.integers(0, len(BACKENDS) - 1))
    def test_property_ivm_equals_scratch(seed, n_updates, backend_i):
        """Any random sequence of insert/delete batches yields results
        allclose to compiling + running from scratch, on both backends."""
        backend, interpret = BACKENDS[backend_i]
        S = chain_schema()
        db = from_numpy(S, chain_db(seed=seed % 97))
        mb = compile_maintained(db, backend=backend, interpret=interpret)
        mb.init(db)
        fresh = ScratchOracle(db, backend=backend, interpret=interpret)
        rng = np.random.default_rng(seed)
        for _ in range(n_updates):
            upd = rand_update(rng, db.sizes())
            mb.apply(upd)
            db = apply_delta(db, upd)
            assert_matches_scratch(mb, fresh, db)


# -- streaming ML applications ------------------------------------------------

def test_online_ridge_matches_scratch():
    """OnlineRidge under a fact insert/delete stream: maintained covar ==
    fresh engine run on the updated database; fact updates must compile to
    delta-only scans (the fast path the benchmark measures)."""
    from repro.data import datasets as D
    from repro.ml.online import OnlineRidge

    ds = D.make("favorita", scale=0.02)
    olr = OnlineRidge(ds, cont=["txns"], cat=["promo", "city", "stype"])
    olr.fit()
    dp = olr.maintained.delta_program(ds.fact)
    assert all(s.scans_delta for s in dp.steps), \
        "fact-rooted covar queries must maintain fact updates delta-only"

    rng = np.random.default_rng(9)
    fact = ds.tables[ds.fact]
    n = ds.db.relation(ds.fact).n_rows
    for _ in range(2):
        pick = rng.integers(0, n, 30)
        olr.update_fact(
            inserts={a: np.asarray(c)[pick] for a, c in fact.items()},
            delete_idx=rng.choice(n, 30, replace=False))
    got = olr.maintained.results()
    exp = olr.maintained.batch(olr.maintained.db)
    for k in got:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(exp[k]),
                                   rtol=2e-3, atol=0.5, err_msg=k)
    assert olr.theta is not None and np.all(np.isfinite(olr.theta))


def test_streaming_cube_matches_batch():
    """StreamingCube cells after updates == cube_via_engine on the updated
    dataset (SUM measures are exact under signed multiplicities)."""
    from repro.data import datasets as D
    from repro.ml.cubes import StreamingCube, cube_via_engine

    ds = D.make("favorita", scale=0.02)
    dims, measures = ["promo", "stype"], ["units"]
    cube = StreamingCube(ds, dims, measures)
    rng = np.random.default_rng(2)
    fact = ds.tables[ds.fact]
    n = ds.db.relation(ds.fact).n_rows
    pick = rng.integers(0, n, 25)
    cells = cube.update(DeltaBatchUpdate()
                        .insert(ds.fact, {a: np.asarray(c)[pick]
                                          for a, c in fact.items()})
                        .delete(ds.fact, rng.choice(n, 25, replace=False)))

    db2 = cube.maintained.db
    ds2 = D.Dataset(ds.name, ds.schema,
                    {nm: {a: np.asarray(c) for a, c in r.columns.items()}
                     for nm, r in db2.relations.items()},
                    ds.edges, ds.features_cont, ds.features_cat,
                    ds.label, ds.fact)
    exp = cube_via_engine(ds2, dims, measures)
    for k in cells:
        np.testing.assert_allclose(cells[k], exp[k], rtol=1e-3, atol=1e-2,
                                   err_msg=k)
