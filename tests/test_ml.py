"""ML applications vs. materialized-join oracles (the paper's §4.2 workloads)."""

import numpy as np
import pytest

from repro.core.plan import materialize_join
from repro.data import datasets as D
from repro.ml import chowliu, cubes, ridge, trees
from repro.ml.covar import compute_covar

ORDERS = {
    "favorita": ["Oil", "Transactions", "Stores", "Sales", "Holiday", "Items"],
    "retailer": ["Census", "Location", "Weather", "Inventory", "Items"],
    "yelp": ["User", "Review", "Business", "Category", "Attribute"],
    "tpcds": ["customer_demographics", "customer", "household_demographics",
              "customer_address", "store_sales", "date_dim", "time_dim", "item",
              "store", "promotion"],
}


@pytest.fixture(scope="module")
def fav():
    ds = D.make("favorita", scale=0.05)
    J = materialize_join(ds.schema, ds.tables, order=ORDERS["favorita"])
    return ds, J


def _oracle_covar(J, layout):
    n = len(J[layout.label])
    X = [np.ones(n)]
    for c in layout.cont:
        X.append(np.asarray(J[c], np.float64))
    for c in layout.cat:
        oh = np.zeros((n, layout.cat_domains[c]))
        oh[np.arange(n), J[c]] = 1
        X += list(oh.T)
    X.append(np.asarray(J[layout.label], np.float64))
    Xm = np.stack(X, 1)
    return Xm.T @ Xm, n


def test_covar_matches_oracle(fav):
    ds, J = fav
    C, N, layout, batch = compute_covar(ds)
    Cref, n = _oracle_covar(J, layout)
    assert n == N
    scale = max(1.0, np.abs(Cref).max())
    assert np.abs(C - Cref).max() / scale < 1e-5
    # Table-2-style invariants: merging collapsed the view count
    assert batch.stats.n_views < batch.stats.n_views_premerge


def test_ridge_closed_form_vs_bgd(fav):
    ds, J = fav
    C, N, layout, _ = compute_covar(ds)
    th_cf = ridge.closed_form(C, N, layout, lam=1e-3)
    res = ridge.bgd(C, N, layout, lam=1e-3, max_iters=5000)
    r_cf = ridge.rmse(th_cf, layout, J)
    r_b = ridge.rmse(res.theta, layout, J)
    base = float(np.std(np.asarray(J[layout.label])))
    assert r_cf < 0.8 * base          # the model actually learns
    assert r_b < 1.2 * r_cf           # BGD reaches closed-form-level accuracy


def test_regression_tree_learns(fav):
    ds, J = fav
    dt = trees.DecisionTree(ds, task="regression", max_depth=3,
                            min_instances=50, max_nodes=15).fit()
    yhat = dt.predict(J)
    y = np.asarray(J[ds.label], np.float64)
    base = np.sqrt(np.mean((y - y.mean()) ** 2))
    got = np.sqrt(np.mean((y - yhat) ** 2))
    assert dt.n_split_nodes() >= 1
    assert got < 0.95 * base


def test_classification_tree_learns():
    ds = D.make("tpcds", scale=0.05)
    J = materialize_join(ds.schema, ds.tables, order=ORDERS["tpcds"])
    dt = trees.DecisionTree(ds, task="classification", label="c_preferred",
                            max_depth=3, min_instances=50, max_nodes=15).fit()
    yhat = dt.predict(J)
    y = np.asarray(J["c_preferred"])
    base = max(y.mean(), 1 - y.mean())   # majority-class accuracy
    acc = (yhat.astype(np.int64) == y).mean()
    assert acc > base + 0.02             # demographics carry real signal


def test_chow_liu_recovers_dependence(fav):
    ds, _ = fav
    # city & state are both store attributes (correlated through store);
    # htype lives on an independent date dimension
    res = chowliu.chow_liu(ds, attrs=["city", "state", "htype"])
    i, j = res.attrs.index("city"), res.attrs.index("state")
    k = res.attrs.index("htype")
    assert res.mi[i, j] > res.mi[i, k]
    assert len(res.edges) == 2           # spanning tree over 3 nodes


def test_cubes_engine_equals_rollup(fav):
    ds, J = fav
    dims = ["stype", "locale", "family"]
    meas = ["units", "txns"]
    a = cubes.cube_via_engine(ds, dims, meas)
    b = cubes.cube_rollup(ds, dims, meas)
    assert set(a) == set(b) and len(a) == 8
    for k in a:
        np.testing.assert_allclose(a[k], b[k], rtol=1e-4, atol=1e-3, err_msg=k)
    # oracle for the finest cell
    fin = np.zeros((5, 3, 33, 2))
    np.add.at(fin, (J["stype"], J["locale"], J["family"]),
              np.stack([J["units"], J["txns"]], -1))
    np.testing.assert_allclose(a[cubes.cube_name(dims)], fin, rtol=1e-4, atol=1e-2)


@pytest.mark.slow
@pytest.mark.parametrize("name", ["retailer", "yelp", "tpcds"])
def test_covar_other_schemas(name):
    ds = D.make(name, scale=0.03)
    J = materialize_join(ds.schema, ds.tables, order=ORDERS[name])
    C, N, layout, _ = compute_covar(ds)
    Cref, n = _oracle_covar(J, layout)
    assert n == N, (n, N)
    scale = max(1.0, np.abs(Cref).max())
    assert np.abs(C - Cref).max() / scale < 1e-5


def test_engine_backed_dataset_statistics(fav):
    """data/statistics.py: the LM framework's data-layer statistics run
    through the LMFAO engine and match the materialized join."""
    from repro.data.statistics import expert_load_aggregate, feature_moments
    ds, J = fav
    stats = feature_moments(ds, attrs=["txns", "price"])
    for a in ("txns", "price"):
        col = np.asarray(J[a], np.float64)
        assert abs(stats[a]["mean"] - col.mean()) < 1e-3 * max(1, abs(col.mean()))
        assert abs(stats[a]["var"] - col.var()) < 1e-2 * max(1.0, col.var())
    ids = np.random.default_rng(0).integers(0, 8, 1000)
    load = expert_load_aggregate(ids, 8)
    np.testing.assert_array_equal(load, np.bincount(ids, minlength=8))


def test_polynomial_regression_degree2(fav):
    """PR_2 (paper §2 eq. (5)): engine covar == materialized-join oracle, and
    the quadratic model beats linear on curvature-bearing data."""
    from repro.ml.polyreg import (compute_poly_covar, fit_polyreg,
                                  monomials, predict_poly)
    ds, J = fav
    attrs = ["txns", "price"]
    C, b, N, layout, batch = compute_poly_covar(ds, degree=2, attrs=attrs)
    assert batch.result.stats.n_dedup_hits > 0   # monomial sharing really happens

    # oracle design matrix on the materialized join
    n = len(J[ds.label])
    X = np.stack([np.prod([np.asarray(J[a], np.float64) ** p for a, p in m],
                          axis=0) if m else np.ones(n)
                  for m in layout.features], axis=1)
    y = np.asarray(J[ds.label], np.float64)
    np.testing.assert_allclose(C, X.T @ X, rtol=1e-5)
    np.testing.assert_allclose(b, X.T @ y, rtol=1e-5)
    assert N == n

    theta, layout2, _ = fit_polyreg(ds, degree=2, attrs=attrs)
    rmse2 = float(np.sqrt(np.mean((predict_poly(theta, layout2, J) - y) ** 2)))
    base = float(np.std(y))
    assert rmse2 < base                      # it learns
    assert len(monomials(attrs, 2)) == 6     # 1, t, p, t², tp, p²
