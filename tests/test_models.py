"""Per-arch smoke tests (reduced configs) + decode-vs-forward parity.

The parity test is the cache-correctness oracle: teacher-forced single-token
decoding through the cache must reproduce the full-sequence forward logits at
every position (validates KV caches, MLA latent caches + absorption, SSD
chunked-vs-recurrent duality, ring-buffer SWA, and hybrid shared-block
caches in one go).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.slow  # per-arch training/decode smokes: minutes-scale

from repro import configs
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.models import model as M
from repro.models.layers import init_params
from repro.train.step import TrainConfig, init_state, make_train_step

B, S = 2, 16


def setup_arch(arch):
    cfg = configs.get_smoke(arch)
    params = init_params(M.model_specs(cfg), jax.random.PRNGKey(0), cfg.jdtype)
    pipe = TokenPipeline(PipelineConfig(B, S, cfg.vocab, seed=1), cfg)
    batch = pipe.batch_at(0)
    return cfg, params, batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_forward_shapes_no_nans(arch):
    cfg, params, batch = setup_arch(arch)
    logits, aux = M.forward(params, batch, cfg, impl="dense")
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux["aux_loss"]))


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_train_step_finite(arch):
    cfg, params, batch = setup_arch(arch)
    tcfg = TrainConfig(ce_chunk=8, attn_impl="dense", total_steps=10, warmup=2)
    state = init_state(cfg, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, tcfg))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state["step"]) == 1


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_decode_matches_forward(arch):
    """Teacher-forced decode logits == forward logits at every position."""
    cfg, params, batch = setup_arch(arch)
    if cfg.family == "moe":
        # capacity dropping is batch-dependent (GShard semantics), so exact
        # parity needs a no-drop capacity factor
        cfg = cfg.with_(capacity_factor=float(cfg.n_experts))
    ctx = M.encode_context(params, batch, cfg)
    full_logits, _ = M.forward(params, batch, cfg, impl="dense")
    full = np.asarray(full_logits, np.float32)

    cache = init_params(M.cache_specs(cfg, B, S), jax.random.PRNGKey(0), cfg.jdtype)
    step = jax.jit(lambda p, c, t, pos, ctx=None:
                   M.decode_step(p, c, t, pos, cfg, context=ctx))
    tol = 2e-2 if cfg.window else 5e-3   # ring-buffer f32 path is slightly looser
    for pos in range(S):
        toks = batch["tokens"][:, pos:pos + 1]
        lg, cache = step(params, cache, toks, jnp.asarray(pos, jnp.int32), ctx)
        got = np.asarray(lg[:, 0], np.float32)
        np.testing.assert_allclose(got, full[:, pos], rtol=tol, atol=tol,
                                   err_msg=f"{arch} pos {pos}")


def test_swa_ring_buffer_window_semantics():
    """With a cache smaller than the sequence, decode must equal a forward
    pass whose attention window matches the ring size."""
    cfg = configs.get_smoke("h2o-danube-3-4b").with_(window=8)
    params = init_params(M.model_specs(cfg), jax.random.PRNGKey(1), cfg.jdtype)
    pipe = TokenPipeline(PipelineConfig(B, S, cfg.vocab, seed=3), cfg)
    batch = pipe.batch_at(0)
    full = np.asarray(M.forward(params, batch, cfg, impl="dense")[0], np.float32)
    cache = init_params(M.cache_specs(cfg, B, S), jax.random.PRNGKey(0), cfg.jdtype)
    # ring cache is window-sized, strictly smaller than S
    assert cache["kv"]["k"].shape[2] == 8 < S
    step = jax.jit(lambda p, c, t, pos: M.decode_step(p, c, t, pos, cfg))
    for pos in range(S):
        toks = batch["tokens"][:, pos:pos + 1]
        lg, cache = step(params, cache, toks, jnp.asarray(pos, jnp.int32))
        np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32), full[:, pos],
                                   rtol=2e-2, atol=2e-2, err_msg=f"pos {pos}")


def test_attention_impls_agree():
    cfg = configs.get_smoke("llama3-8b")
    params = init_params(M.model_specs(cfg), jax.random.PRNGKey(0), cfg.jdtype)
    pipe = TokenPipeline(PipelineConfig(B, 32, cfg.vocab, seed=1), cfg)
    batch = pipe.batch_at(0)
    dense, _ = M.forward(params, batch, cfg, impl="dense")
    chunked, _ = M.forward(params, batch, cfg, impl="chunked")
    pallas, _ = M.forward(params, batch, cfg, impl="pallas")
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(pallas),
                               rtol=2e-3, atol=2e-3)


def test_exact_configs_match_assignment():
    """The full configs carry the exact assigned hyperparameters."""
    expect = {
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "mamba2-2.7b": (64, 2560, 1, 1, 0, 50280),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 0, 151936),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 0, 102400),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        c = configs.get(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == \
            (L, d, h, kv, ff, v), arch
    assert configs.get("zamba2-1.2b").ssm_state == 64
    assert configs.get("mamba2-2.7b").ssm_state == 128
    assert configs.get("deepseek-v2-lite-16b").kv_lora == 512
    assert configs.get("qwen3-moe-235b-a22b").n_experts == 128
    assert configs.get("qwen3-moe-235b-a22b").top_k == 8
    assert configs.get("deepseek-v2-lite-16b").n_experts == 64
    assert configs.get("deepseek-v2-lite-16b").top_k == 6


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor >= 1 and balanced-ish routing, most assignments
    survive; the combine weights renormalize."""
    from repro.models import moe as moe_mod
    cfg = configs.get_smoke("qwen3-moe-235b-a22b")
    specs = moe_mod.moe_specs(cfg)
    p = init_params(specs, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model))
    out, aux = moe_mod.moe_apply(p, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert 0.5 < float(aux) < 4.0        # aux ~ 1 when balanced


def test_scan_unroll_equivalence():
    """Roofline-measurement mode (unrolled scans) is numerically identical."""
    cfg = configs.get_smoke("internlm2-1.8b")
    params = init_params(M.model_specs(cfg), jax.random.PRNGKey(0), cfg.jdtype)
    pipe = TokenPipeline(PipelineConfig(B, S, cfg.vocab, seed=1), cfg)
    batch = pipe.batch_at(0)
    a, _ = M.forward(params, batch, cfg, impl="dense")
    b, _ = M.forward(params, batch, cfg.with_(scan_unroll=True), impl="dense")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)
