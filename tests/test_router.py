"""Ad-hoc query routing (DESIGN.md §13): the signature router must answer
arbitrary group-by aggregates *correctly* from whatever the session has —
exact view matches, subsumption re-aggregation over wider maintained cube
views, or verified compile-and-cache — on both lowering backends, with the
tier contracts holding structurally:

* tier-1/2 answers from maintained views never scan base relations
  (asserted on the handle's dispatch counter and the router's scan
  counters);
* every routed answer equals a from-scratch compile of the same query;
* maintained-source answers are epoch-consistent under a concurrent
  updater (each routed value matches the replayed oracle *at its epoch*);
* the plan cache is a bounded LRU with per-signature hit counters;
* every router-compiled plan passes the static verifier before it answers
  anything or enters the cache;
* sharded sessions route identically to single-device ones.
"""

import dataclasses
import threading
import time

import numpy as np
import pytest

from repro.analysis.verify import PlanInvariantError
from repro.api import ExecutionConfig, connect
from repro.core import COUNT, Delta, Lambda, Var, agg, query, schema, sum_of
from repro.core.aggregates import Param
from repro.data import DeltaBatchUpdate, apply_delta, from_numpy
from repro.serve.router import QueryRouter

BACKENDS = [("xla", None), ("pallas", True)]


def chain_schema():
    return schema(
        [("x1", "categorical", 3), ("x2", "key", 4), ("x3", "key", 5),
         ("x4", "categorical", 3), ("u", "continuous", 0)],
        [("R1", ["x1", "x2"]), ("R2", ["x2", "x3", "u"]), ("R3", ["x3", "x4"])])


def chain_db(seed=0, n1=17, n2=29, n3=13):
    rng = np.random.default_rng(seed)
    return {"R1": {"x1": rng.integers(0, 3, n1), "x2": rng.integers(0, 4, n1)},
            "R2": {"x2": rng.integers(0, 4, n2), "x3": rng.integers(0, 5, n2),
                   "u": rng.normal(size=n2).astype(np.float32)},
            "R3": {"x3": rng.integers(0, 5, n3), "x4": rng.integers(0, 3, n3)}}


# the maintained "cube": wide group-bys whose signature lattice covers the
# narrow ad-hoc probes below
CUBE = [
    query("cube_g14", ["x1", "x4"], [COUNT, sum_of("u")]),
    query("cube_g2", ["x2"], [sum_of("u")]),
]

# ad-hoc probes: exact (dims AND aggs permuted vs cube_g14 — the match is
# canonical, not spelling), subsumed (strictly narrower), and a miss
Q_EXACT = query("q_exact", ["x4", "x1"], [sum_of("u"), COUNT])
Q_SUB = query("q_sub", ["x4"], [COUNT])
Q_TOTAL = query("q_total", [], [sum_of("u"), COUNT])
Q_MISS = query("q_miss", ["x3"], [COUNT])


def session(db, capacity=32, backend="xla", interpret=None, **kw):
    return connect(db, config=ExecutionConfig(
        block_size=8, backend=backend, interpret=interpret,
        route_cache_capacity=capacity, **kw))


def fresh_answer(db, q, backend="xla", interpret=None):
    """From-scratch oracle: an independent session compiling exactly q."""
    return session(db, backend=backend, interpret=interpret) \
        .views([q]).run()[q.name]


def assert_answer(got, db, q, **kw):
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(fresh_answer(db, q, **kw)),
                               rtol=1e-3, atol=1e-3, err_msg=q.name)


# -- tier correctness ---------------------------------------------------------

@pytest.mark.parametrize("backend,interpret", BACKENDS,
                         ids=["xla", "pallas-interpret"])
def test_three_tiers_match_scratch_oracle(backend, interpret):
    """Every tier's answer equals a from-scratch compile of the same query,
    and tier-1/2 answers come from the pinned epoch, not a base scan."""
    if backend == "pallas":
        pytest.importorskip("jax.experimental.pallas")
    db = from_numpy(chain_schema(), chain_db())
    sess = session(db, backend=backend, interpret=interpret)
    h = sess.views(CUBE, maintain=True)
    h.run()                                  # epoch 0 published
    dispatches0 = h.compiled.n_dispatches

    # tier 1: exact, with group-by AND aggregate order permuted
    r = sess.route(Q_EXACT)
    assert r.tier == "exact" and r.source == "cube_g14"
    assert not r.scanned and r.epoch == 0
    assert np.asarray(r.value).shape == (3, 3, 2)   # user dim order + aggs
    assert_answer(r.value, db, Q_EXACT, backend=backend, interpret=interpret)

    # tier 2: strictly narrower group-bys re-aggregate the cube tensor
    for q in (Q_SUB, Q_TOTAL):
        r = sess.route(q)
        assert r.tier == "subsumed" and r.source == "cube_g14"
        assert not r.scanned and r.epoch == 0
        assert_answer(r.value, db, q, backend=backend, interpret=interpret)

    # no base relations were scanned for tiers 1-2
    assert h.compiled.n_dispatches == dispatches0
    assert sess.router.n_base_scans == 0
    assert sess.router.n_reaggs == 2

    # tier 3: nothing answers x3 — compile, admit, cache, scan once
    r = sess.route(Q_MISS)
    assert r.tier == "compiled" and r.source is None and r.scanned
    assert_answer(r.value, db, Q_MISS, backend=backend, interpret=interpret)
    assert sess.router.n_plans_compiled == 1
    assert sess.router.n_base_scans == 1

    # the miss is now cached: the repeat is an exact hit on the cached
    # plan's scan (not a recompile), still correct
    r2 = sess.route(Q_MISS)
    assert r2.tier == "exact" and r2.source == "q_miss" and r2.scanned
    assert sess.router.n_plans_compiled == 1
    np.testing.assert_allclose(np.asarray(r2.value), np.asarray(r.value))

    st = sess.routing_stats()
    assert st["n_queries"] == 5
    assert st["tiers"] == {"exact": 2, "subsumed": 2, "compiled": 1,
                           "fallback_scan": 0}
    assert st["hit_rate"] == pytest.approx(4 / 5)
    assert st["n_admission_failures"] == 0


def test_subsumption_tracks_updates_without_scanning():
    """After delta batches fold into the cube, tier-2 answers re-aggregate
    the *new* epoch tensor — correct w.r.t. the updated database, still
    with zero base scans."""
    S = chain_schema()
    db = from_numpy(S, chain_db())
    sess = session(db)
    h = sess.views(CUBE, maintain=True)
    h.run()
    rng = np.random.default_rng(11)
    cur = db
    for i in range(3):
        upd = DeltaBatchUpdate().insert(
            "R2", {"x2": rng.integers(0, 4, 4), "x3": rng.integers(0, 5, 4),
                   "u": rng.normal(size=4).astype(np.float32)})
        if i == 1:
            upd.delete("R1", np.array([0, 3]))
        h.apply(upd)
        cur = apply_delta(cur, upd)
        r = sess.route(Q_SUB)
        assert r.tier == "subsumed" and not r.scanned and r.epoch == i + 1
        assert_answer(r.value, cur, Q_SUB)
    assert sess.router.n_base_scans == 0


def test_epoch_consistency_under_concurrent_updater():
    """Routed maintained-source answers pin one epoch: with an updater
    folding batches concurrently, every routed value must equal the
    replayed oracle at exactly the epoch the result reports — never a torn
    mix of two epochs."""
    S = chain_schema()
    db = from_numpy(S, chain_db())
    sess = session(db)
    h = sess.views(CUBE, maintain=True)
    srv = h.serve()                         # started: epoch 0 published

    rng = np.random.default_rng(23)
    updates = [DeltaBatchUpdate().insert(
        "R2", {"x2": rng.integers(0, 4, 3), "x3": rng.integers(0, 5, 3),
               "u": rng.normal(size=3).astype(np.float32)})
        for _ in range(5)]
    # replayed database per epoch (epoch e == after e folds)
    db_at = [db]
    for upd in updates:
        db_at.append(apply_delta(db_at[-1], upd))

    got, done = [], threading.Event()

    def reader():
        while not done.is_set():
            r = sess.route(Q_SUB)
            got.append((r.epoch, np.asarray(r.value)))
        r = sess.route(Q_SUB)           # one read at the final epoch
        got.append((r.epoch, np.asarray(r.value)))

    t = threading.Thread(target=reader)
    t.start()
    try:
        for upd in updates:
            srv.apply(upd)
            time.sleep(0.02)
    finally:
        done.set()
        t.join()

    # data-swap oracle: one compile answers every epoch's expectation
    osess = session(db)
    oh = osess.views([Q_SUB])
    assert len(got) >= 2 and {e for e, _ in got} <= set(range(6))
    for epoch, value in got:
        assert epoch is not None
        osess.data = db_at[epoch]
        np.testing.assert_allclose(value, np.asarray(oh.run()[Q_SUB.name]),
                                   rtol=1e-3, atol=1e-3,
                                   err_msg=f"epoch {epoch}")


# -- plan cache ---------------------------------------------------------------

def test_lru_eviction_and_readmission():
    """capacity=1: the second distinct miss evicts the first; re-asking the
    evicted signature recompiles (and re-admits) it; a repeat then hits."""
    db = from_numpy(chain_schema(), chain_db())
    sess = session(db, capacity=1)
    qa = query("qa", ["x1"], [COUNT])
    qb = query("qb", ["x3"], [sum_of("u")])

    assert sess.route(qa).tier == "compiled"
    assert sess.route(qa).tier == "exact"            # cached
    assert sess.route(qb).tier == "compiled"         # evicts qa
    rt = sess.router
    assert rt.n_evictions == 1 and len(rt._cache) == 1
    assert sess.route(qa).tier == "compiled"         # re-admitted
    assert rt.n_plans_compiled == 3
    # 3 plan admissions + one secondary-program check per exact hit
    assert rt.n_admission_checks >= 3 and rt.n_admission_failures == 0
    assert sess.route(qa).tier == "exact"
    stats = rt.cache_stats()
    assert len(stats) == 1 and stats[0]["hits"] == 1
    assert rt.hit_rate == pytest.approx(2 / 5)


def test_cache_capacity_zero_disables_caching():
    """capacity=0: every miss is a one-shot fallback_scan, nothing cached,
    answers still correct."""
    db = from_numpy(chain_schema(), chain_db())
    sess = session(db, capacity=0)
    for _ in range(2):
        r = sess.route(Q_MISS)
        assert r.tier == "fallback_scan" and r.scanned
        assert_answer(r.value, db, Q_MISS)
    assert sess.router.n_plans_compiled == 2
    assert sess.routing_stats()["cache_size"] == 0


def test_unroutable_udaf_falls_back_uncached():
    """An untagged Lambda has no stable signature: it can never be matched
    or cached, but it still gets a correct one-shot verified scan."""
    db = from_numpy(chain_schema(), chain_db())
    sess = session(db)
    q = query("q_anon", ["x2"], [agg(Lambda(
        ("x1",), lambda a, p: (a * 2).astype(np.float32)))])   # no tag=
    exp = fresh_answer(db, q)
    for _ in range(2):
        r = sess.route(q)
        assert r.tier == "fallback_scan" and r.scanned
        np.testing.assert_allclose(np.asarray(r.value), np.asarray(exp),
                                   rtol=1e-3, atol=1e-3)
    assert sess.router.n_plans_compiled == 2      # never cached
    assert sess.routing_stats()["cache_size"] == 0


# -- admission gate -----------------------------------------------------------

def test_admission_rejects_corrupted_plan(monkeypatch):
    """Serving-time compiles pass the static verifier before answering or
    entering the cache: a corrupted plan raises the structured invariant
    error and is NOT cached."""
    db = from_numpy(chain_schema(), chain_db())
    sess = session(db)
    orig = QueryRouter._compile_fresh

    def corrupting(self, q):
        handle = orig(self, q)
        plan = handle.compiled.plan
        steps = list(plan.schedule.steps)
        steps[0] = dataclasses.replace(steps[0], rel="NoSuchRel")
        plan.schedule = dataclasses.replace(plan.schedule,
                                            steps=tuple(steps))
        return handle

    monkeypatch.setattr(QueryRouter, "_compile_fresh", corrupting)
    with pytest.raises(PlanInvariantError) as ei:
        sess.route(Q_MISS)
    assert ei.value.invariant == "schedule-topo"
    rt = sess.router
    assert rt.n_admission_failures == 1
    assert sess.routing_stats()["cache_size"] == 0

    # the gate is unconditional — un-corrupted compiles admit fine after
    monkeypatch.setattr(QueryRouter, "_compile_fresh", orig)
    r = sess.route(Q_MISS)
    assert r.tier == "compiled"
    assert_answer(r.value, db, Q_MISS)


# -- params -------------------------------------------------------------------

def test_params_skip_maintained_sources():
    """Maintained views bake their params at init, so an explicit-params
    route must NOT answer from them — it compiles (then scan-hits) a plan
    that binds params per run."""
    db = from_numpy(chain_schema(), chain_db())
    sess = session(db)
    h = sess.views([query("cube_t", ["x4"],
                          [agg(Var("u"), Delta("x1", "==", Param("t")))])],
                   maintain=True)
    h.run(params={"t": 1.0})
    q = query("q_t", ["x4"], [agg(Var("u"), Delta("x1", "==", Param("t")))])

    r1 = sess.route(q, params={"t": 1.0})
    assert r1.tier == "compiled" and r1.scanned
    r2 = sess.route(q, params={"t": 2.0})
    assert r2.tier == "exact" and r2.scanned          # cached plan, rebinds
    for t, r in ((1.0, r1), (2.0, r2)):
        exp = session(db).views(
            [query("qo", ["x4"],
                   [agg(Var("u"), Delta("x1", "==", Param("t")))])]) \
            .run(params={"t": t})["qo"]
        np.testing.assert_allclose(np.asarray(r.value), np.asarray(exp),
                                   rtol=1e-3, atol=1e-3, err_msg=f"t={t}")

    # without params, the maintained view answers exactly (its baked t=1.0)
    r3 = sess.route(q)
    assert r3.tier == "exact" and not r3.scanned
    np.testing.assert_allclose(np.asarray(r3.value), np.asarray(r1.value),
                               rtol=1e-3, atol=1e-3)


def test_batched_params_rejected_with_pointer():
    db = from_numpy(chain_schema(), chain_db())
    sess = session(db)
    q = query("q_b", [], [agg(Lambda(
        ("x1",), lambda a, p: p["m"][..., a], tag="mask",
        param_refs=(Param("m", batched=True),)))])
    with pytest.raises(ValueError, match="run_batched"):
        sess.route(q, params={"m": np.ones((2, 3), np.float32)})


# -- facade + telemetry -------------------------------------------------------

def test_front_doors_and_workload_records():
    """Database.query / ViewServer.query return the plain tensor; every
    routed query lands in the workload recorder with its route tier, and
    explain() surfaces the routing mix."""
    db = from_numpy(chain_schema(), chain_db())
    sess = session(db)
    h = sess.views(CUBE, maintain=True)
    srv = h.serve()                           # started: epoch 0 published

    v1 = sess.query(Q_SUB)                    # session front door
    v2 = srv.query(Q_SUB)                     # serving front door
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2))
    sess.query(Q_MISS)

    by_sig = sess.workload.by_signature()
    routes = {}
    for entry in by_sig.values():
        for tier, n in entry["routes"].items():
            routes[tier] = routes.get(tier, 0) + n
    assert routes == {"subsumed": 2, "compiled": 1}

    rep = h.explain()
    assert rep.routing is not None and rep.routing["n_queries"] == 3
    assert "routing:" in rep.summary() and "hit_rate" in rep.summary()

    # a server constructed without a router says how to get one
    from repro.serve.views import ViewServer
    bare = ViewServer(h.maintained)
    with pytest.raises(ValueError, match="router"):
        bare.query(Q_SUB)


def test_router_capacity_validation():
    db = from_numpy(chain_schema(), chain_db())
    with pytest.raises(ValueError, match="route_cache_capacity"):
        session(db, capacity=-1)
    sess = session(db)
    with pytest.raises(ValueError, match="capacity"):
        QueryRouter(sess, capacity=True)


# -- sharded equivalence ------------------------------------------------------

def test_sharded_routing_matches_local(subproc):
    """Routing over a 4-device mesh session: same tiers, same answers as
    the single-device session, before and after a delta fold — the router
    is mesh-agnostic by construction (replicated epoch views)."""
    subproc("""
import numpy as np
import jax

import repro
from repro.core import COUNT, query, schema, sum_of
from repro.data import DeltaBatchUpdate, apply_delta, from_numpy

S = schema(
    [("x1", "categorical", 3), ("x2", "key", 4), ("x3", "key", 5),
     ("x4", "categorical", 3), ("u", "continuous", 0)],
    [("R1", ["x1", "x2"]), ("R2", ["x2", "x3", "u"]), ("R3", ["x3", "x4"])])
rng = np.random.default_rng(7)
tables = {
    "R1": {"x1": rng.integers(0, 3, 17), "x2": rng.integers(0, 4, 17)},
    "R2": {"x2": rng.integers(0, 4, 29), "x3": rng.integers(0, 5, 29),
           "u": rng.normal(size=29).astype(np.float32)},
    "R3": {"x3": rng.integers(0, 5, 13), "x4": rng.integers(0, 3, 13)}}
CUBE = [query("cube_g14", ["x1", "x4"], [COUNT, sum_of("u")])]
PROBES = [query("q_exact", ["x4", "x1"], [sum_of("u"), COUNT]),
          query("q_sub", ["x4"], [COUNT]),
          query("q_miss", ["x3"], [COUNT])]

mesh = jax.make_mesh((len(jax.devices()),), ("data",))
cfg = repro.ExecutionConfig(block_size=8)
db = from_numpy(S, tables)
local = repro.connect(db, config=cfg)
sharded = repro.connect(db, config=cfg.replace(mesh=mesh))
hl = local.views(CUBE, maintain=True)
hs = sharded.views(CUBE, maintain=True)
hl.run(); hs.run()

def check(tag, oracle_db):
    for q in PROBES:
        rl, rs = local.route(q), sharded.route(q)
        assert rl.tier == rs.tier, (tag, q.name, rl.tier, rs.tier)
        np.testing.assert_allclose(
            np.asarray(rs.value), np.asarray(rl.value),
            rtol=1e-3, atol=1e-3, err_msg=f"{tag} {q.name}")
        exp = repro.connect(oracle_db, config=cfg).views([q]).run()[q.name]
        np.testing.assert_allclose(
            np.asarray(rs.value), np.asarray(exp),
            rtol=1e-3, atol=1e-3, err_msg=f"{tag} {q.name} vs fresh")

check("init", db)
upd = DeltaBatchUpdate().insert(
    "R2", {"x2": rng.integers(0, 4, 5), "x3": rng.integers(0, 5, 5),
           "u": rng.normal(size=5).astype(np.float32)})
hl.apply(upd); hs.apply(upd)
# scan-tier answers read Database.data — keep the base snapshot current
# alongside the maintained fold (the session contract; DESIGN.md §13)
new_db = apply_delta(db, upd)
local.data = new_db
sharded.data = new_db
check("after-fold", new_db)

st = sharded.routing_stats()
assert st["tiers"]["exact"] >= 3 and st["tiers"]["subsumed"] == 2
assert st["n_admission_failures"] == 0
print("OK")
""", 4)
