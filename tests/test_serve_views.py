"""Concurrent epoch semantics of the view-serving path: a reader pinned to
an epoch sees a frozen snapshot while a background updater folds and
publishes new epochs; post-swap reads match the from-scratch oracle; and a
checkpoint taken mid-update-stream restores to a clean (untorn) version.
Runs on both lowering backends."""

import threading

import numpy as np
import pytest

from repro.core import COUNT, Delta, Engine, Var, agg, query, schema, sum_of
from repro.data import DeltaBatchUpdate, apply_delta, from_numpy
from repro.serve import ViewServer

BACKENDS = [("xla", None), ("pallas", True)]  # (backend, interpret)


def make_schema():
    return schema(
        [("x1", "categorical", 3), ("x2", "key", 4), ("x3", "key", 5),
         ("x4", "categorical", 3), ("u", "continuous", 0)],
        [("R1", ["x1", "x2"]), ("R2", ["x2", "x3", "u"]), ("R3", ["x3", "x4"])])


def make_tables(seed=0, n1=17, n2=29, n3=13):
    rng = np.random.default_rng(seed)
    return {"R1": {"x1": rng.integers(0, 3, n1), "x2": rng.integers(0, 4, n1)},
            "R2": {"x2": rng.integers(0, 4, n2), "x3": rng.integers(0, 5, n2),
                   "u": rng.normal(size=n2).astype(np.float32)},
            "R3": {"x3": rng.integers(0, 5, n3), "x4": rng.integers(0, 3, n3)}}


QUERIES = [
    query("q_count", [], [COUNT]),
    query("q_g1", ["x1"], [COUNT, sum_of("u")]),
    query("q_delta", ["x4"], [agg(Var("u"), Delta("x1", "==", 1))]),
]


def r2_rows(rng, k):
    return {"x2": rng.integers(0, 4, k), "x3": rng.integers(0, 5, k),
            "u": rng.normal(size=k).astype(np.float32)}


def results_equal(a, b):
    for q in QUERIES:
        np.testing.assert_array_equal(np.asarray(a[q.name]),
                                      np.asarray(b[q.name]), err_msg=q.name)


@pytest.mark.parametrize("backend,interpret", BACKENDS)
def test_background_updater_foreground_reader(backend, interpret):
    """Reader pins an epoch, then a background thread applies several update
    batches: every re-read through the pin is bit-identical to the first,
    and after the updater finishes, current-epoch reads match the
    from-scratch oracle on the final database."""
    S = make_schema()
    db = from_numpy(S, make_tables())
    eng = Engine(S, sizes=db.sizes())
    srv = ViewServer(eng.compile_incremental(
        QUERIES, block_size=8, backend=backend, interpret=interpret))
    srv.start(db)
    fresh = eng.compile(QUERIES, block_size=8, backend=backend,
                        interpret=interpret)
    rng = np.random.default_rng(21)
    updates = [
        (DeltaBatchUpdate().insert("R2", r2_rows(rng, 3))
         .delete("R2", rng.choice(29, 3, replace=False))),
        DeltaBatchUpdate().delete("R1", np.array([0, 5])),
        DeltaBatchUpdate().insert("R2", r2_rows(rng, 6)),
    ]
    oracle = db
    errors = []

    with srv.snapshot() as snap:
        first = {q.name: np.asarray(snap.results()[q.name]).copy()
                 for q in QUERIES}
        e0 = snap.epoch

        def updater():
            try:
                nonlocal oracle
                for upd in updates:
                    srv.apply(upd)
                    oracle = apply_delta(oracle, upd)
            except Exception as exc:             # surface in the main thread
                errors.append(exc)

        t = threading.Thread(target=updater)
        t.start()
        # interleave pinned reads with the updater's publishes; re-extract
        # from the pinned epoch each time (bypassing EpochView's cache) so
        # this asserts the state itself is frozen, not just a cached dict
        for _ in range(6):
            results_equal(first, srv.maintained.results(epoch=snap.epoch))
        t.join()
        assert not errors, errors
        assert srv.epoch == e0 + len(updates)
        results_equal(first, srv.maintained.results(epoch=snap.epoch))
        results_equal(first, snap.results())     # handle view agrees
    # post-swap: current epoch == oracle on the post-update database
    exp = fresh(oracle)
    got = srv.read()
    for q in QUERIES:
        np.testing.assert_allclose(np.asarray(got[q.name]),
                                   np.asarray(exp[q.name]),
                                   rtol=1e-3, atol=1e-3, err_msg=q.name)
    st = srv.stats()
    assert st["n_updates"] == len(updates)
    assert st["n_pinned_epochs"] == 0            # all pins released


@pytest.mark.parametrize("backend,interpret", BACKENDS)
def test_snapshot_mid_update_roundtrip(backend, interpret, tmp_path):
    """A checkpoint taken while an updater thread keeps publishing restores
    into a fresh MaintainedBatch as one *clean* epoch: its results equal the
    oracle for exactly the step it captured, and the restored batch keeps
    applying updates correctly."""
    S = make_schema()
    db = from_numpy(S, make_tables(seed=3))
    eng = Engine(S, sizes=db.sizes())
    srv = ViewServer(eng.compile_incremental(
        QUERIES, block_size=8, backend=backend, interpret=interpret))
    srv.start(db)
    fresh = eng.compile(QUERIES, block_size=8, backend=backend,
                        interpret=interpret)
    rng = np.random.default_rng(5)
    db_by_step = {0: db}
    errors = []

    def updater():
        try:
            d = db
            for i in range(4):
                upd = (DeltaBatchUpdate().insert("R2", r2_rows(rng, 2))
                       .delete("R3", np.array([i])))
                srv.apply(upd)
                d = apply_delta(d, upd)
                db_by_step[i + 1] = d
        except Exception as exc:
            errors.append(exc)

    t = threading.Thread(target=updater)
    t.start()
    path = srv.checkpoint(str(tmp_path))        # racing the updater
    t.join()
    assert not errors, errors

    mb2 = eng.compile_incremental(QUERIES, block_size=8, backend=backend,
                                  interpret=interpret)
    step = mb2.restore(str(tmp_path))
    assert mb2.step == step and step in db_by_step, path
    exp = fresh(db_by_step[step])                # clean version, not a tear
    got = mb2.results()
    for q in QUERIES:
        np.testing.assert_allclose(np.asarray(got[q.name]),
                                   np.asarray(exp[q.name]),
                                   rtol=1e-3, atol=1e-3, err_msg=q.name)
    # restored state keeps maintaining
    upd = DeltaBatchUpdate().insert("R2", r2_rows(rng, 3))
    mb2.apply(upd)
    db2 = apply_delta(db_by_step[step], upd)
    exp = fresh(db2)
    got = mb2.results()
    for q in QUERIES:
        np.testing.assert_allclose(np.asarray(got[q.name]),
                                   np.asarray(exp[q.name]),
                                   rtol=1e-3, atol=1e-3, err_msg=q.name)


def test_rejected_update_leaves_served_epoch(tmp_path):
    """ViewServer.apply on an invalid batch raises, counts the rejection,
    and the served epoch/results are untouched."""
    S = make_schema()
    db = from_numpy(S, make_tables(seed=9))
    eng = Engine(S, sizes=db.sizes())
    srv = ViewServer(eng.compile_incremental(QUERIES, block_size=8))
    e0 = srv.start(db)
    before = srv.read()
    with pytest.raises(ValueError, match="outside"):
        srv.apply(DeltaBatchUpdate()
                  .insert("R1", {"x1": np.array([0]), "x2": np.array([1])})
                  .insert("R3", {"x3": np.array([0]), "x4": np.array([77])}))
    assert srv.epoch == e0
    results_equal(before, srv.read())
    st = srv.stats()
    assert st["n_rejected_updates"] == 1 and st["n_updates"] == 0
