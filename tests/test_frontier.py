"""Frontier-batched node evaluation (DESIGN.md §7.4).

The param-batch (node) axis must be *invisible* in the results: one
``run_batched`` call with N node masks equals N single dispatches, on both
lowering backends, for regression and classification trees — and it must not
change the relation-scan schedule (the whole point: one pass serves all N
nodes).  Forest workloads built on the axis must be deterministic under a
fixed seed.
"""

import numpy as np
import pytest

from repro.core.plan import materialize_join
from repro.data import datasets as D
from repro.ml.forest import GradientBoostedTrees, RandomForest
from repro.ml.trees import DecisionTree, predict_nodes

FAV_ORDER = ["Oil", "Transactions", "Stores", "Sales", "Holiday", "Items"]
TPCDS_ORDER = ["customer_demographics", "customer", "household_demographics",
               "customer_address", "store_sales", "date_dim", "time_dim",
               "item", "store", "promotion"]


@pytest.fixture(scope="module")
def fav():
    ds = D.make("favorita", scale=0.02)
    J = materialize_join(ds.schema, ds.tables, order=FAV_ORDER)
    return ds, J


@pytest.fixture(scope="module")
def tpcds():
    ds = D.make("tpcds", scale=0.02)
    J = materialize_join(ds.schema, ds.tables, order=TPCDS_ORDER)
    return ds, J


def _tree_signature(dt: DecisionTree):
    return [(n.feature, n.kind, n.threshold, round(n.n, 6),
             round(n.prediction, 6)) for n in dt.nodes]


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_frontier_matches_per_node_regression(fav, backend):
    ds, J = fav
    kw = dict(task="regression", max_depth=3, min_instances=50, max_nodes=15,
              backend=backend)
    frontier = DecisionTree(ds, node_batch=True, **kw).fit()
    per_node = DecisionTree(ds, node_batch=False, **kw).fit()
    assert _tree_signature(frontier) == _tree_signature(per_node)
    np.testing.assert_allclose(frontier.predict(J), per_node.predict(J))


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_frontier_matches_per_node_classification(tpcds, backend):
    ds, J = tpcds
    kw = dict(task="classification", label="c_preferred", max_depth=2,
              min_instances=50, max_nodes=7, backend=backend)
    frontier = DecisionTree(ds, node_batch=True, **kw).fit()
    per_node = DecisionTree(ds, node_batch=False, **kw).fit()
    assert _tree_signature(frontier) == _tree_signature(per_node)
    np.testing.assert_allclose(frontier.predict(J), per_node.predict(J))


def test_run_batched_equals_single_runs_and_keeps_scan_schedule(fav):
    """Acceptance: one run_batched call with N=8 node masks issues the same
    number of relation scans as N=1 (schedule introspection), and the stacked
    results equal 8 independent single-node dispatches."""
    ds, _ = fav
    batched = DecisionTree(ds, max_depth=1, min_instances=10, node_batch=True)
    single = DecisionTree(ds, max_depth=1, min_instances=10, node_batch=False)

    # the node axis must not change the compiled scan schedule
    assert batched.batch.schedule.n_scans == single.batch.schedule.n_scans
    assert batched.batch.stats.n_scan_steps == single.batch.stats.n_scan_steps

    rng = np.random.default_rng(0)
    N = 8
    masks = [{f.attr: (rng.random(f.domain) < 0.7).astype(np.float32)
              for f in batched.features} for _ in range(N)]
    from repro.ml.trees import stack_mask_params
    before = batched.batch.n_dispatches
    outs = batched.batch.run_batched(
        ds.db, stack_mask_params(batched.features, masks))
    assert batched.batch.n_dispatches == before + 1   # ONE fused dispatch
    for i in range(N):
        ref = single.batch(ds.db, params=single._node_params(masks[i]))
        for f in batched.features:
            q = f"split_{f.attr}"
            np.testing.assert_allclose(
                np.asarray(outs[q])[i], np.asarray(ref[q]),
                rtol=1e-4, atol=1e-4, err_msg=f"{q} node {i}")


def test_fit_dispatches_once_per_level(fav):
    """Acceptance: frontier-batched fit performs at most one engine dispatch
    per tree level, with no per-leaf backfill dispatches."""
    ds, _ = fav
    dt = DecisionTree(ds, task="regression", max_depth=3, min_instances=50,
                      max_nodes=15, node_batch=True).fit()
    n_levels = max(n.depth for n in dt.nodes) + 1
    assert dt.batch.n_dispatches <= n_levels
    # every node got stats from its own frontier pass (no zero-stat leaves)
    assert all(n.n > 0 for n in dt.nodes)


def test_batched_output_layout(fav):
    """Batched query outputs are (N, *group_dims, n_aggs) with the node axis
    leading."""
    ds, _ = fav
    dt = DecisionTree(ds, max_depth=1, min_instances=10, node_batch=True)
    masks = [{f.attr: np.ones(f.domain, np.float32) for f in dt.features}
             for _ in range(3)]
    from repro.ml.trees import stack_mask_params
    outs = dt.batch.run_batched(ds.db, stack_mask_params(dt.features, masks))
    f0 = dt.features[0]
    assert np.asarray(outs[f"split_{f0.attr}"]).shape == (3, f0.domain, 3)


def test_random_forest_deterministic_and_learns(fav):
    ds, J = fav
    kw = dict(n_trees=4, max_depth=3, min_instances=50, max_nodes=15, seed=7)
    rf1 = RandomForest(ds, **kw).fit()
    rf2 = RandomForest(ds, **kw).fit()
    p1, p2 = rf1.predict(J), rf2.predict(J)
    np.testing.assert_array_equal(p1, p2)        # fixed seed -> same forest
    assert [t.allowed_attrs for t in rf1.trees] == \
           [t.allowed_attrs for t in rf2.trees]
    y = np.asarray(J[ds.label], np.float64)
    base = np.sqrt(np.mean((y - y.mean()) ** 2))
    assert np.sqrt(np.mean((y - p1) ** 2)) < 0.95 * base
    # whole-forest frontier batching: one dispatch per forest level
    max_levels = max(max(n.depth for n in t.nodes) for t in rf1.trees) + 1
    assert rf1.batch.n_dispatches <= max_levels


def test_gbt_residual_relabeling_in_engine(fav):
    """The reconstructed residual histograms must equal host-side residuals
    of the fitted ensemble, and training RMSE must improve with rounds."""
    ds, J = fav
    y = np.asarray(J[ds.label], np.float64)
    gbt = GradientBoostedTrees(ds, n_rounds=2, learning_rate=0.5, max_depth=2,
                               min_instances=50).fit()
    r_host = y - gbt.predict(J)
    root = [{f.attr: np.ones(f.domain, np.float32) for f in gbt.features}]
    cnt, sr = gbt._residual_hists(root)[0][gbt.features[0].attr]
    codes = np.asarray(J[gbt.features[0].attr])
    sr_host = np.zeros(gbt.features[0].domain)
    np.add.at(sr_host, codes, r_host)
    np.testing.assert_allclose(cnt, np.bincount(codes, minlength=len(cnt)),
                               rtol=1e-6)
    np.testing.assert_allclose(sr, sr_host, rtol=1e-4, atol=1e-2)

    rmse1 = np.sqrt(np.mean((y - GradientBoostedTrees(
        ds, n_rounds=1, learning_rate=0.5, max_depth=2,
        min_instances=50).fit().predict(J)) ** 2))
    rmse2 = np.sqrt(np.mean((y - gbt.predict(J)) ** 2))
    base = np.sqrt(np.mean((y - y.mean()) ** 2))
    assert rmse1 < base
    assert rmse2 < rmse1


def test_gbt_deterministic(fav):
    ds, J = fav
    kw = dict(n_rounds=2, learning_rate=0.5, max_depth=2, min_instances=50)
    g1 = GradientBoostedTrees(ds, **kw).fit()
    g2 = GradientBoostedTrees(ds, **kw).fit()
    np.testing.assert_array_equal(g1.predict(J), g2.predict(J))
    assert len(g1.trees) == 2
    for t1, t2 in zip(g1.trees, g2.trees):
        assert [n.feature for n in t1] == [n.feature for n in t2]
