"""Core engine correctness: layers, merging, multi-root, and a hypothesis
property test — engine output == brute-force (materialize join, then
aggregate) on random chain schemas/data/queries.

All compilation goes through the session facade (``repro.connect`` →
``Database.views``); the legacy ``Engine.compile*`` entry points are
core-internal (enforced by the engine-contract linter, DESIGN.md §12).
"""

import numpy as np
import pytest

try:  # optional dev dependency: only the property test needs it
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    st = None

from repro.api import ExecutionConfig, connect
from repro.core import (COUNT, Delta, Lambda, Pow, Var, agg, query, schema,
                        sum_of, sum_prod)
from repro.core.groups import group_views, independent_sets
from repro.core.jointree import JoinTree
from repro.core.plan import materialize_join
from repro.core.pushdown import push_down
from repro.core.roots import find_roots, single_root
from repro.data import from_numpy


def chain_schema():
    return schema(
        [("x1", "categorical", 3), ("x2", "key", 4), ("x3", "key", 5),
         ("x4", "categorical", 3), ("u", "continuous", 0)],
        [("R1", ["x1", "x2"]), ("R2", ["x2", "x3", "u"]), ("R3", ["x3", "x4"])])


def chain_db(seed=0, n1=17, n2=29, n3=13):
    rng = np.random.default_rng(seed)
    T = {"R1": {"x1": rng.integers(0, 3, n1), "x2": rng.integers(0, 4, n1)},
         "R2": {"x2": rng.integers(0, 4, n2), "x3": rng.integers(0, 5, n2),
                "u": rng.normal(size=n2).astype(np.float32)},
         "R3": {"x3": rng.integers(0, 5, n3), "x4": rng.integers(0, 3, n3)}}
    return T


def brute(schema_, tables, q):
    J = materialize_join(schema_, tables, order=["R1", "R2", "R3"])
    n = len(J["x1"])
    cols = []
    for a in q.aggregates:
        tot = np.zeros(1)
        val = np.zeros(n)
        for prod in a.products:
            v = np.ones(n)
            for t in prod.terms:
                env = {at: J[at] for at in t.attrs()}
                v = v * np.asarray(t.evaluate(env, {}), dtype=np.float64)
            val = val + v
        if q.group_by:
            dims = [schema_.domain(g) for g in q.group_by]
            out = np.zeros(dims)
            np.add.at(out, tuple(J[g] for g in q.group_by), val)
        else:
            out = np.sum(val)
        cols.append(out)
    return np.stack([np.asarray(c, dtype=np.float64) for c in cols], axis=-1)


QUERIES = [
    query("q_count", [], [COUNT]),
    query("q_sums", [], [sum_of("u"), agg(Pow("u", 2)), sum_prod("u", "u")]),
    query("q_g1", ["x1"], [COUNT, sum_of("u")]),
    query("q_g2", ["x1", "x4"], [COUNT]),
    query("q_delta", ["x4"], [agg(Var("u"), Delta("x1", "==", 1))]),
    query("q_lambda", ["x2"], [agg(Lambda(("x1", "x4"),
                                          lambda a, b, p: (a * 2 + b).astype(np.float32),
                                          tag="t1"))]),
]


@pytest.mark.parametrize("multi_root", [True, False])
@pytest.mark.parametrize("block_size", [7, 64])
def test_engine_matches_bruteforce(multi_root, block_size):
    S = chain_schema()
    T = chain_db()
    db = from_numpy(S, T)
    sess = connect(db, config=ExecutionConfig(multi_root=multi_root,
                                              block_size=block_size))
    out = sess.views(QUERIES).run()
    for q in QUERIES:
        expect = brute(S, T, q)
        got = np.asarray(out[q.name], dtype=np.float64)
        np.testing.assert_allclose(got, expect, rtol=1e-4, atol=1e-4,
                                   err_msg=q.name)


def test_merging_reduces_views():
    S = chain_schema()
    db = from_numpy(S, chain_db())
    h = connect(db).views(QUERIES)
    st_ = h.stats
    assert st_.n_views < st_.n_views_premerge
    assert st_.n_groups >= 1
    assert st_.n_app_aggregates == sum(len(q.aggregates) for q in QUERIES)


def test_multi_root_uses_multiple_roots():
    S = chain_schema()
    db = from_numpy(S, chain_db())
    tree = JoinTree.build(S, db.sizes())
    roots = find_roots(tree, QUERIES, db.sizes())
    assert len(set(roots.values())) > 1          # Example 3.3's point
    sroots = single_root(tree, QUERIES, db.sizes())
    assert len(set(sroots.values())) == 1


def test_group_dependency_levels():
    S = chain_schema()
    db = from_numpy(S, chain_db())
    tree = JoinTree.build(S, db.sizes())
    result = push_down(tree, QUERIES, find_roots(tree, QUERIES, db.sizes()))
    groups = group_views(result)
    levels = independent_sets(groups)
    seen = set()
    for lv in levels:
        for gid in lv:
            for dep in groups[gid].deps:
                assert dep in seen
        seen.update(lv)


def test_schedule_topology_and_fusion():
    """Fused steps must stay topologically ordered, cover every group exactly
    once, and only ever fuse same-relation groups."""
    from repro.core.schedule import build_schedule
    from repro.data import datasets as D
    from repro.ml.covar import covar_queries

    ds = D.make("retailer", scale=0.02)
    qs, _ = covar_queries(ds)
    h = connect(ds).views(qs)
    groups = h.compiled.groups
    sched = h.schedule
    # partition of groups
    all_gids = sorted(g for s in sched.steps for g in s.gids)
    assert all_gids == sorted(g.gid for g in groups)
    by_gid = {g.gid: g for g in groups}
    sid_of = {g: s.sid for s in sched.steps for g in s.gids}
    for s in sched.steps:
        assert all(by_gid[g].rel == s.rel for g in s.gids)
        # every group dependency resolves to a strictly earlier step (fused
        # groups are dependency-independent, so never in the same step)
        for g in s.gids:
            for dep in by_gid[g].deps:
                assert sid_of[dep] < s.sid
    # the multi-root covar batch has cross-level same-relation groups: fusion
    # must strictly reduce the scan count (paper's shared-scan claim)
    assert sched.n_scans < len(groups)
    unfused = build_schedule(groups, fuse=False)
    assert unfused.n_scans == len(groups)


def test_fused_scans_match_oracle():
    """Shared-scan fusion must not change any query output (retailer covar
    batch vs the materialized-join oracle)."""
    from repro.data import datasets as D
    from repro.ml.covar import covar_queries

    ds = D.make("retailer", scale=0.02)
    qs, _ = covar_queries(ds)
    h = connect(ds).views(qs)
    assert h.stats.n_fused_scans > 0
    out = h.run()
    J = materialize_join(ds.schema, ds.tables,
                         order=["Census", "Location", "Weather", "Inventory",
                                "Items"])
    n = len(next(iter(J.values())))
    for q in qs[:8]:
        cols = []
        for a in q.aggregates:
            val = np.zeros(n)
            for prod in a.products:
                v = np.ones(n)
                for t in prod.terms:
                    env = {at: J[at] for at in t.attrs()}
                    v = v * np.asarray(t.evaluate(env, {}), dtype=np.float64)
                val += v
            if q.group_by:
                o = np.zeros([ds.schema.domain(g) for g in q.group_by])
                np.add.at(o, tuple(J[g] for g in q.group_by), val)
            else:
                o = np.sum(val)
            cols.append(np.asarray(o, np.float64))
        expect = np.stack(cols, axis=-1)
        got = np.asarray(out[q.name], dtype=np.float64)
        np.testing.assert_allclose(got, expect, rtol=1e-3, atol=1e-3,
                                   err_msg=q.name)


def test_dynamic_params_no_retrace():
    """Decision-tree-style dynamic UDAFs: changing the threshold params must
    reuse the same compiled executable (paper's dynamic functions, minus the
    recompilation)."""
    from repro.core.aggregates import Param
    S = chain_schema()
    T = chain_db()
    db = from_numpy(S, T)
    q = query("qd", ["x4"], [agg(Var("u"), Delta("x1", "==", Param("t")))])
    h = connect(db).views([q])
    o1 = h.run(params={"t": np.int32(1)})["qd"]
    o2 = h.run(params={"t": np.int32(2)})["qd"]
    J = materialize_join(S, T, order=["R1", "R2", "R3"])
    for t, o in [(1, o1), (2, o2)]:
        exp = np.zeros(3)
        np.add.at(exp, J["x4"], J["u"] * (J["x1"] == t))
        np.testing.assert_allclose(np.asarray(o)[..., 0], exp, rtol=1e-4, atol=1e-4)
    assert len(h.compiled._jitted) == 1  # one executable served both


# -- hypothesis property test -------------------------------------------------

if st is None:
    def test_property_engine_equals_bruteforce():
        pytest.skip("hypothesis not installed (pip install .[dev])")
else:
    @st.composite
    def random_case(draw):
        d1 = draw(st.integers(2, 4))
        d2 = draw(st.integers(2, 4))
        d3 = draw(st.integers(2, 4))
        n1 = draw(st.integers(1, 25))
        n2 = draw(st.integers(1, 25))
        rng = np.random.default_rng(draw(st.integers(0, 2**31)))
        S = schema(
            [("a", "categorical", d1), ("k", "key", d2), ("b", "categorical", d3),
             ("u", "continuous", 0)],
            [("L", ["a", "k"]), ("R", ["k", "b", "u"])])
        T = {"L": {"a": rng.integers(0, d1, n1), "k": rng.integers(0, d2, n1)},
             "R": {"k": rng.integers(0, d2, n2), "b": rng.integers(0, d3, n2),
                   "u": rng.normal(size=n2).astype(np.float32)}}
        gb = draw(st.sampled_from([[], ["a"], ["b"], ["a", "b"], ["k"], ["k", "b"]]))
        aggs = draw(st.lists(st.sampled_from(
            [COUNT, sum_of("u"), agg(Pow("u", 2)), agg(Var("u"), Delta("a", "<=", 1)),
             agg(Delta("b", "==", 0))]), min_size=1, max_size=3))
        return S, T, query("q", gb, aggs)

    @settings(max_examples=25, deadline=None)
    @given(random_case())
    def test_property_engine_equals_bruteforce(case):
        S, T, q = case
        db = from_numpy(S, T)
        h = connect(db, config=ExecutionConfig(block_size=8)).views([q])
        got = np.asarray(h.run()[q.name], dtype=np.float64)

        J = materialize_join(S, T, order=["L", "R"])
        n = len(J["a"])
        cols = []
        for a in q.aggregates:
            val = np.zeros(n)
            for prod in a.products:
                v = np.ones(n)
                for t in prod.terms:
                    env = {at: J[at] for at in t.attrs()}
                    v = v * np.asarray(t.evaluate(env, {}), dtype=np.float64)
                val += v
            if q.group_by:
                out = np.zeros([S.domain(g) for g in q.group_by])
                np.add.at(out, tuple(J[g] for g in q.group_by), val)
            else:
                out = np.sum(val)
            cols.append(np.asarray(out, np.float64))
        expect = np.stack(cols, axis=-1)
        np.testing.assert_allclose(got, expect, rtol=1e-4, atol=1e-4)


def test_rip_validation_rejects_bad_tree():
    S = schema([("a", "key", 2), ("b", "key", 2), ("c", "key", 2)],
               [("R1", ["a", "b"]), ("R2", ["b", "c"]), ("R3", ["a", "c"])])
    with pytest.raises(ValueError):
        JoinTree(S, [("R1", "R2"), ("R2", "R3")])  # a shared by R1,R3 missing in R2
