"""Per-kernel shape/dtype sweeps vs. the pure-jnp oracles (interpret mode)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("n,f,block", [(64, 4, 32), (1000, 13, 256), (513, 7, 128),
                                       (2048, 32, 512)])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_covar_xtx(n, f, block, dtype):
    rng = np.random.default_rng(n + f)
    x = rng.normal(size=(n, f)).astype(dtype)
    w = (rng.random(n) < 0.8).astype(np.float32)
    got = ops.covar_xtx(jnp.asarray(x), jnp.asarray(w), block_rows=block,
                        interpret=True)
    want = ref.covar_xtx_ref(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("n,s,a,block", [(64, 5, 3, 32), (1000, 37, 5, 128),
                                         (777, 20, 1, 256), (4096, 64, 16, 512)])
def test_seg_aggregate(n, s, a, block):
    rng = np.random.default_rng(n + s)
    seg = rng.integers(0, s, n).astype(np.int32)
    pay = rng.normal(size=(n, a)).astype(np.float32)
    got = ops.seg_aggregate(jnp.asarray(seg), jnp.asarray(pay), s,
                            block_rows=block, interpret=True)
    want = ref.seg_aggregate_ref(jnp.asarray(seg), jnp.asarray(pay), s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,d,block", [(100, 20, 64), (1000, 20, 128), (333, 7, 64)])
def test_tree_hist(n, d, block):
    rng = np.random.default_rng(n)
    codes = rng.integers(0, d, n).astype(np.int32)
    y = rng.normal(size=n).astype(np.float32)
    cond = (rng.random(n) < 0.5).astype(np.float32)
    got = ops.tree_hist(jnp.asarray(codes), jnp.asarray(y), jnp.asarray(cond), d,
                        block_rows=block, interpret=True)
    want = ref.tree_hist_ref(jnp.asarray(codes), jnp.asarray(y),
                             jnp.asarray(cond), d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,d,n_nodes,block", [(100, 20, 1, 64), (517, 20, 8, 128),
                                               (1000, 7, 3, 512)])
def test_tree_hist_batched(n, d, n_nodes, block):
    """Multi-node kernel == per-node oracle, including unaligned row counts."""
    rng = np.random.default_rng(n + n_nodes)
    codes = rng.integers(0, d, n).astype(np.int32)
    y = rng.normal(size=n).astype(np.float32)
    cond = (rng.random((n, n_nodes)) < 0.5).astype(np.float32)
    got = ops.tree_hist_batched(jnp.asarray(codes), jnp.asarray(y),
                                jnp.asarray(cond), d, block_rows=block,
                                interpret=True)
    want = ref.tree_hist_batched_ref(jnp.asarray(codes), jnp.asarray(y),
                                     jnp.asarray(cond), d)
    assert got.shape == (n_nodes, d, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n", [1, 100, 517, 513])
def test_kernels_pad_unaligned_rows(n):
    """The raw pallas entry points accept any row count: rows are padded with
    zeroed cond/payload instead of hard-asserting n % block_rows == 0."""
    from repro.kernels.seg_aggregate import seg_aggregate_pallas
    from repro.kernels.tree_hist import tree_hist_pallas
    rng = np.random.default_rng(n)
    d = 6
    codes = rng.integers(0, d, n).astype(np.int32)
    y = rng.normal(size=n).astype(np.float32)
    cond = (rng.random(n) < 0.5).astype(np.float32)
    got = tree_hist_pallas(jnp.asarray(codes), jnp.asarray(y),
                           jnp.asarray(cond), d, block_rows=256,
                           interpret=True)
    want = ref.tree_hist_ref(jnp.asarray(codes), jnp.asarray(y),
                             jnp.asarray(cond), d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    pay = rng.normal(size=(n, 3)).astype(np.float32)
    seg = rng.integers(0, d, n).astype(np.int32)
    got = seg_aggregate_pallas(jnp.asarray(seg), jnp.asarray(pay), d,
                               block_rows=256, interpret=True)
    want = ref.seg_aggregate_ref(jnp.asarray(seg), jnp.asarray(pay), d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def _fused_case(n, n_cond=1, extra_hist=False, seed=0):
    """Two seg buckets + hist(s) over one shared row block — the whole-step
    union the launch-level fusion path builds (DESIGN.md §10)."""
    rng = np.random.default_rng(n + n_cond + seed)
    S1, W1, S2, W2, D = 13, 5, 7, 3, 6
    c1 = rng.integers(0, S1, n).astype(np.int32)
    c2 = rng.integers(0, S2, n).astype(np.int32)
    ch = rng.integers(0, D, n).astype(np.int32)
    p1 = rng.normal(size=(n, W1)).astype(np.float32)
    p2 = rng.normal(size=(n, W2)).astype(np.float32)
    cond = (rng.random((n, n_cond)) < 0.5).astype(np.float32)
    y = rng.normal(size=n).astype(np.float32)
    yk = np.stack([np.ones(n, np.float32), y, y * y], axis=1)
    off = W1 + W2
    pay_cols = [p1, p2, cond]
    codes_cols = [c1, c2, ch]
    specs = [ops.ReduceSpec("seg", 0, S1, W1, 0),
             ops.ReduceSpec("seg", 1, S2, W2, W1),
             ops.ReduceSpec("hist", 2, D, 3 * n_cond, off, n_cond=n_cond,
                            yk_off=off + n_cond)]
    off += n_cond
    if extra_hist:
        # second hist on a different code column but SHARING the yk triple
        # (the lowering dedups yk per distinct y attribute)
        D2 = 9
        codes_cols.append(rng.integers(0, D2, n).astype(np.int32))
        c2nd = (rng.random((n, n_cond)) < 0.5).astype(np.float32)
        pay_cols.append(c2nd)
        specs.append(ops.ReduceSpec("hist", 3, D2, 3 * n_cond, off,
                                    n_cond=n_cond, yk_off=off + n_cond))
        off += n_cond
    pay_cols.append(yk)
    codes = jnp.asarray(np.stack(codes_cols, axis=1))
    fpay = jnp.asarray(np.concatenate(pay_cols, axis=1))
    return codes, fpay, tuple(specs)


@pytest.mark.parametrize("n", [64, 100, 517, 2048])
@pytest.mark.parametrize("double_buffer", [True, False])
def test_fused_scan_block_multi_spec(n, double_buffer):
    codes, fpay, specs = _fused_case(n)
    got = ops.fused_scan_block(codes, fpay, specs, block_rows=128,
                               interpret=True, double_buffer=double_buffer)
    want = ref.fused_scan_block_ref(codes, fpay, specs)
    for sp, g, w in zip(specs, got, want):
        assert g.shape == (sp.n_segments, sp.width)
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-4, atol=1e-4, err_msg=str(sp))


@pytest.mark.parametrize("n,n_cond", [(257, 4), (1000, 8)])
def test_fused_scan_block_batched_cond(n, n_cond):
    """Frontier-batched hists (n_cond = node-axis width) inside the fused
    launch, plus a second hist sharing the same yk columns."""
    codes, fpay, specs = _fused_case(n, n_cond=n_cond, extra_hist=True)
    got = ops.fused_scan_block(codes, fpay, specs, block_rows=256,
                               interpret=True)
    want = ref.fused_scan_block_ref(codes, fpay, specs)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-4, atol=1e-4)


def test_fused_scan_block_dbuf_matches_grid_bitwise():
    """The two-slot DMA pipeline is a pure data-movement change: it must be
    bit-identical to the grid-pipelined path, not merely close."""
    codes, fpay, specs = _fused_case(517, n_cond=2, extra_hist=True)
    a = ops.fused_scan_block(codes, fpay, specs, block_rows=128,
                             interpret=True, double_buffer=True)
    b = ops.fused_scan_block(codes, fpay, specs, block_rows=128,
                             interpret=True, double_buffer=False)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("b,h,hkv,s,d", [(1, 2, 1, 64, 8), (2, 4, 2, 100, 16),
                                         (1, 4, 4, 96, 32)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 24), (False, 0)])
def test_flash_attention(b, h, hkv, s, d, causal, window):
    rng = np.random.default_rng(b * s)
    q = rng.normal(size=(b, h, s, d)).astype(np.float32)
    k = rng.normal(size=(b, hkv, s, d)).astype(np.float32)
    v = rng.normal(size=(b, hkv, s, d)).astype(np.float32)
    got = ops.flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                              causal=causal, window=window, block_q=32,
                              block_k=32, interpret=True)
    want = ref.attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                             causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(1, 2, 64, 16)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 64, 16)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 64, 16)), jnp.bfloat16)
    got = ops.flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                              interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=5e-2, atol=5e-2)
