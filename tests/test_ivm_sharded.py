"""Sharded IVM (DESIGN.md §6/§8): maintained views over a mesh must be
indistinguishable from the single-device path — same results under
deterministic delta sequences (allclose vs the single-device oracle), same
zero-host-transfer / bounded-retrace steady-state contract, interchangeable
checkpoints, and epoch-consistent serving under a concurrent updater.

Each test runs in a subprocess with a forced 4-device host mesh
(``XLA_FLAGS=--xla_force_host_platform_device_count=4``), on the xla and
pallas-interpret backends."""

import pytest

# Shared subprocess preamble: the 3-relation chain schema of the serving
# tests, a deterministic mixed update stream (inserts + deletes on the
# sharded fact R2 AND the replicated R1/R3), and a side-by-side sharded /
# local pair of maintained batches.
PREAMBLE = """
import numpy as np
import jax

import repro
from repro.core import COUNT, Delta, Var, agg, query, schema, sum_of
from repro.data import DeltaBatchUpdate, apply_delta, from_numpy
from repro.data import relations as relmod

S = schema(
    [("x1", "categorical", 3), ("x2", "key", 4), ("x3", "key", 5),
     ("x4", "categorical", 3), ("u", "continuous", 0)],
    [("R1", ["x1", "x2"]), ("R2", ["x2", "x3", "u"]), ("R3", ["x3", "x4"])])
rng = np.random.default_rng(7)
tables = {
    "R1": {"x1": rng.integers(0, 3, 17), "x2": rng.integers(0, 4, 17)},
    "R2": {"x2": rng.integers(0, 4, 29), "x3": rng.integers(0, 5, 29),
           "u": rng.normal(size=29).astype(np.float32)},
    "R3": {"x3": rng.integers(0, 5, 13), "x4": rng.integers(0, 3, 13)}}
QUERIES = [
    query("q_count", [], [COUNT]),
    query("q_g1", ["x1"], [COUNT, sum_of("u")]),
    query("q_delta", ["x4"], [agg(Var("u"), Delta("x1", "==", 1))]),
]
NAMES = [q.name for q in QUERIES]
mesh = jax.make_mesh((len(jax.devices()),), ("data",))

def r2_rows(k):
    return {"x2": rng.integers(0, 4, k), "x3": rng.integers(0, 5, k),
            "u": rng.normal(size=k).astype(np.float32)}

def update_stream(n2):
    # deterministic mixed stream; yields (update, new |R2|)
    out = []
    for i in range(6):
        upd = DeltaBatchUpdate()
        k = int(rng.integers(1, 7))
        upd.insert("R2", r2_rows(k))
        nd = int(rng.integers(1, 5))
        upd.delete("R2", rng.choice(n2, nd, replace=False))
        if i % 2:
            upd.insert("R1", {"x1": rng.integers(0, 3, 2),
                              "x2": rng.integers(0, 4, 2)})
        if i % 3 == 2:
            upd.delete("R3", np.array([i]))
        n2 += k - nd
        out.append(upd)
    return out, n2

def connect_pair(backend, interpret):
    cfg = repro.ExecutionConfig(block_size=8, backend=backend,
                                interpret=interpret)
    db = from_numpy(S, tables)
    local = repro.connect(db, config=cfg)
    sharded = repro.connect(db, config=cfg.replace(mesh=mesh))
    return local, sharded

def assert_close(a, b, msg):
    for n in NAMES:
        np.testing.assert_allclose(np.asarray(a[n]), np.asarray(b[n]),
                                   rtol=1e-3, atol=1e-3,
                                   err_msg=f"{msg} {n}")
"""

BACKENDS = [("xla", "None"), ("pallas", "True")]


@pytest.mark.parametrize("backend,interpret", BACKENDS,
                         ids=["xla", "pallas-interpret"])
def test_sharded_matches_local_oracle(subproc, backend, interpret):
    """Deterministic delta sequence: after every apply, the sharded batch's
    results AND its gathered relation contents equal the single-device
    oracle's; the final epoch equals a from-scratch recompute."""
    if backend == "pallas":
        pytest.importorskip("jax.experimental.pallas")
    subproc(PREAMBLE + f"""
local, sharded = connect_pair({backend!r}, {interpret})
vl = local.views(QUERIES, maintain=True)
vs = sharded.views(QUERIES, maintain=True)
assert vs.maintained.mesh is not None
assert_close(vs.run(), vl.run(), "init")
assert vs.maintained.shard_rel == "R2"   # largest relation by default

oracle = from_numpy(S, tables)
updates, _ = update_stream(29)
for i, upd in enumerate(updates):
    out_s, out_l = vs.apply(upd), vl.apply(upd)
    oracle = apply_delta(oracle, upd)
    assert_close(out_s, out_l, f"apply {{i}}")

# gathered relations restore the oracle row order exactly (gid contract)
for name in ("R1", "R2", "R3"):
    got, exp = vs.maintained.db.relation(name), oracle.relation(name)
    for a in exp.columns:
        np.testing.assert_array_equal(np.asarray(got.columns[a]),
                                      np.asarray(exp.columns[a]),
                                      err_msg=f"{{name}}.{{a}}")

# final epoch == from-scratch recompute on the post-update database
fresh = repro.connect(oracle, config=repro.ExecutionConfig(
    block_size=8, backend={backend!r}, interpret={interpret}))
assert_close(vs.results(), fresh.views(QUERIES).run(), "fresh")
print("OK")
""", 4)


def test_sharded_steady_state_no_transfers_no_retrace(subproc):
    """The sharded tentpole contract: after warmup, fixed-size update
    batches run under ``jax.transfer_guard("disallow")`` — zero implicit
    host transfers of relation columns — without growing the fold- or
    advance-trace counters, and the runner cache stays one entry per
    (relation, pad bucket)."""
    subproc(PREAMBLE + """
_, sharded = connect_pair("xla", None)
vs = sharded.views(QUERIES, maintain=True)
vs.run()
mb = vs.maintained

def fixed_update():
    return (DeltaBatchUpdate().insert("R2", r2_rows(4))
            .delete("R2", rng.choice(20, 2, replace=False)))

for _ in range(3):                      # warm pad buckets and capacity
    vs.apply(fixed_update())
runners = len(mb._runners)
traces = mb.n_fold_traces + relmod.advance_trace_count()
with jax.transfer_guard("disallow"):
    for _ in range(5):
        vs.apply(fixed_update())
assert mb.n_fold_traces + relmod.advance_trace_count() == traces
assert len(mb._runners) == runners == 1   # one cached shard_map tick
print("OK")
""", 4)


@pytest.mark.parametrize("backend,interpret", BACKENDS,
                         ids=["xla", "pallas-interpret"])
def test_sharded_snapshot_restore_roundtrip(subproc, backend, interpret, tmp_path):
    """Checkpoints are placement-free: a sharded epoch snapshot restores
    into a local batch and vice versa, allclose to the single-device
    oracle, and the restored sharded batch keeps maintaining."""
    if backend == "pallas":
        pytest.importorskip("jax.experimental.pallas")
    subproc(PREAMBLE + f"""
import tempfile
local, sharded = connect_pair({backend!r}, {interpret})
vl = local.views(QUERIES, maintain=True)
vs = sharded.views(QUERIES, maintain=True)
vl.run(); vs.run()
updates, _ = update_stream(29)
for upd in updates[:3]:
    vl.apply(upd); vs.apply(upd)

d_sharded, d_local = {str(tmp_path / 's')!r}, {str(tmp_path / 'l')!r}
vs.snapshot(d_sharded)
vl.snapshot(d_local)

# sharded -> local and local -> sharded
vl2 = local.views(QUERIES, maintain=True)
assert vl2.restore(d_sharded) == 3
assert_close(vl2.results(), vl.results(), "sharded->local")
vs2 = sharded.views(QUERIES, maintain=True)
assert vs2.restore(d_local) == 3
assert_close(vs2.results(), vl.results(), "local->sharded")

# the re-sharded batch keeps maintaining, still matching the oracle
for i, upd in enumerate(updates[3:]):
    assert_close(vs2.apply(upd), vl.apply(upd), f"post-restore {{i}}")
print("OK")
""", 4)


@pytest.mark.slow
def test_sharded_server_epoch_consistent_under_updates(subproc):
    """A sharded ViewServer under a concurrent updater: a pinned reader's
    epoch is frozen while updates publish, post-swap reads equal the
    from-scratch oracle, and stats report the shard topology."""
    subproc(PREAMBLE + """
import threading
_, sharded = connect_pair("xla", None)
vs = sharded.views(QUERIES, maintain=True)
srv = vs.serve(max_pinned_epochs=8)
updates, _ = update_stream(29)
oracle = from_numpy(S, tables)
errors = []
with srv.snapshot() as snap:
    first = {n: np.asarray(snap.results()[n]).copy() for n in NAMES}
    e0 = snap.epoch
    def updater():
        global oracle
        try:
            for upd in updates:
                srv.apply(upd)
        except Exception as exc:
            errors.append(exc)
    t = threading.Thread(target=updater)
    t.start()
    for _ in range(6):   # re-extract from the pinned epoch, bypassing cache
        assert_close(first, srv.maintained.results(epoch=snap.epoch),
                     "pinned")
    t.join()
    assert not errors, errors
    assert srv.epoch == e0 + len(updates)
    assert_close(first, snap.results(), "pinned-final")
for upd in updates:
    oracle = apply_delta(oracle, upd)
fresh = repro.connect(oracle, config=repro.ExecutionConfig(block_size=8))
assert_close(srv.read(), fresh.views(QUERIES).run(), "post-swap")
st = srv.stats()
assert st["n_updates"] == len(updates)
assert st["shard"]["n_devices"] == 4
assert st["shard"]["shard_rel"] == "R2"
assert st["shard"]["psums_per_tick"]["R2"] >= 1
print("OK")
""", 4)


def test_explain_reports_shard_topology(subproc):
    """Satellite: ``explain()`` on sharded runs carries the topology dict —
    device count, rows/shard, psum counts — for maintained AND batch mode
    (no bare ``sharded=True`` flag)."""
    subproc(PREAMBLE + """
_, sharded = connect_pair("xla", None)
vs = sharded.views(QUERIES, maintain=True)
vs.run()
vs.apply(DeltaBatchUpdate().insert("R2", r2_rows(3)))
rep = vs.explain()
t = rep.shard
assert t["n_devices"] == 4 and t["mesh_axis"] == "data"
assert t["shard_rel"] == "R2"
assert t["rows_per_shard"] == -(-t["rows"] // 4)
assert t["capacity_per_shard"] >= t["rows_per_shard"]
assert t["psums_per_tick"]["R2"] >= 1
s = rep.summary()
assert "devices=4" in s and "psums/tick" in s

vb = sharded.views(QUERIES)          # batch mode over the same mesh
vb.run()
tb = vb.explain().shard
assert tb["n_devices"] == 4 and tb["shard_rel"] == "R2"
assert tb["psums_per_run"] >= 1
assert "psums/run" in vb.explain().summary()
print("OK")
""", 4)
