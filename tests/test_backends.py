"""Lowering-backend equivalence: ``backend="pallas"`` (interpret mode on CPU)
must produce the same results as the default ``backend="xla"`` path across
the example workload batches (ridge covar and decision-tree node batches),
and the Pallas hist fast path must actually engage for the tree batch."""

import numpy as np
import pytest

from repro.core import COUNT, Engine, agg, query, schema, sum_of
from repro.core.aggregates import Delta, Lambda, Pow, Var
from repro.data import datasets as D
from repro.data import from_numpy


def _run_both(S_or_ds, queries, **compile_kw):
    if hasattr(S_or_ds, "db"):
        ds = S_or_ds
        db, edges = ds.db, ds.edges
        eng_kw = dict(edges=edges, sizes=db.sizes())
        Ssch = ds.schema
    else:
        Ssch, db = S_or_ds
        eng_kw = dict(sizes=db.sizes())
    outs = {}
    for be in ("xla", "pallas"):
        eng = Engine(Ssch, **eng_kw)
        batch = eng.compile(queries, backend=be, **compile_kw)
        outs[be] = {k: np.asarray(v, np.float64)
                    for k, v in batch(db).items()}
    return outs


def _assert_equal(outs):
    assert outs["xla"].keys() == outs["pallas"].keys()
    for k in outs["xla"]:
        np.testing.assert_allclose(outs["pallas"][k], outs["xla"][k],
                                   rtol=1e-4, atol=1e-4, err_msg=k)


def test_pallas_matches_xla_chain_batch():
    S = schema(
        [("x1", "categorical", 3), ("x2", "key", 4), ("x3", "key", 5),
         ("x4", "categorical", 3), ("u", "continuous", 0)],
        [("R1", ["x1", "x2"]), ("R2", ["x2", "x3", "u"]), ("R3", ["x3", "x4"])])
    rng = np.random.default_rng(3)
    T = {"R1": {"x1": rng.integers(0, 3, 21), "x2": rng.integers(0, 4, 21)},
         "R2": {"x2": rng.integers(0, 4, 33), "x3": rng.integers(0, 5, 33),
                "u": rng.normal(size=33).astype(np.float32)},
         "R3": {"x3": rng.integers(0, 5, 11), "x4": rng.integers(0, 3, 11)}}
    queries = [
        query("q_count", [], [COUNT]),
        query("q_sums", [], [sum_of("u"), agg(Pow("u", 2))]),
        query("q_g", ["x1", "x4"], [COUNT, sum_of("u")]),
        query("q_delta", ["x4"], [agg(Var("u"), Delta("x1", "==", 1))]),
    ]
    _assert_equal(_run_both((S, from_numpy(S, T)), queries, block_size=16))


def test_pallas_matches_xla_ridge_batch():
    from repro.ml.covar import covar_queries
    ds = D.make("retailer", scale=0.02)
    qs, _ = covar_queries(ds)
    _assert_equal(_run_both(ds, qs))


def test_pallas_matches_xla_tree_batch():
    from repro.ml.trees import DecisionTree
    ds = D.make("favorita", scale=0.02)
    masks = None
    outs = {}
    for be in ("xla", "pallas"):
        # node_batch=False: the single-node hist fast path (the batched
        # variant is covered by tests/test_frontier.py)
        dt = DecisionTree(ds, task="regression", max_depth=1, min_instances=10,
                          max_nodes=3, backend=be, node_batch=False)
        if masks is None:
            masks = {f"mask_{f.attr}": np.ones(f.domain, dtype=np.float32)
                     for f in dt.features}
        if be == "pallas":
            # the node-histogram pattern must route through tree_hist
            nhist = sum(1 for sp in dt.batch.plan.step_programs
                        for vp in sp.views if vp.hist is not None)
            assert nhist > 0
        outs[be] = {k: np.asarray(v, np.float64)
                    for k, v in dt.batch(ds.db, params=masks).items()}
    _assert_equal(outs)


def test_pallas_matches_xla_dynamic_params():
    """Dynamic UDAF params (decision-tree thresholds) stay recompile-free and
    equivalent on the Pallas path."""
    from repro.core.aggregates import Param
    S = schema([("k", "key", 6), ("c", "categorical", 4), ("u", "continuous", 0)],
               [("F", ["k", "u"]), ("D", ["k", "c"])])
    rng = np.random.default_rng(5)
    n = 257
    T = {"F": {"k": rng.integers(0, 6, n),
               "u": rng.normal(size=n).astype(np.float32)},
         "D": {"k": np.arange(6), "c": rng.integers(0, 4, 6)}}
    db = from_numpy(S, T)
    q = query("qd", ["c"], [agg(Var("u"), Delta("c", "==", Param("t")))])
    for be in ("xla", "pallas"):
        eng = Engine(S, sizes=db.sizes())
        batch = eng.compile([q], backend=be, block_size=64)
        o1 = np.asarray(batch(db, params={"t": np.int32(1)})["qd"])
        o2 = np.asarray(batch(db, params={"t": np.int32(2)})["qd"])
        assert len(batch._jitted) == 1
        if be == "xla":
            ref1, ref2 = o1, o2
        else:
            np.testing.assert_allclose(o1, ref1, rtol=1e-4, atol=1e-4)
            np.testing.assert_allclose(o2, ref2, rtol=1e-4, atol=1e-4)


def test_unknown_backend_rejected():
    from repro.core.lowering import get_backend
    with pytest.raises(ValueError):
        get_backend("cuda")


# ---------------------------------------------------------------------------
# launch-level kernel fusion (ISSUE 6): fused == unfused == xla, and the
# static launch-site count actually reflects the fusion


def _ridge_setup():
    from repro.ml.covar import covar_queries
    ds = D.make("retailer", scale=0.02)
    qs, _ = covar_queries(ds)
    return ds, qs


@pytest.mark.parametrize("fuse_scans", [True, False])
def test_fused_kernels_match_unfused_ridge(fuse_scans):
    """Launch-level fusion (fuse_kernels) composes with scheduler-level
    shared-scan fusion (fuse_scans): every combination agrees with xla.
    Fused vs unfused pallas is allclose, not bitwise — the single fused dot
    reassociates fp32 sums differently than per-view launches."""
    ds, qs = _ridge_setup()
    outs, stats = {}, {}
    for be, fuse_kernels in [("xla", True), ("pallas", True),
                             ("pallas", False)]:
        eng = Engine(ds.schema, edges=ds.edges, sizes=ds.db.sizes())
        batch = eng.compile(qs, backend=be, fuse_scans=fuse_scans,
                            fuse_kernels=fuse_kernels)
        key = (be, fuse_kernels)
        outs[key] = {k: np.asarray(v, np.float64)
                     for k, v in batch(ds.db).items()}
        stats[key] = batch.stats
    for key in [("pallas", True), ("pallas", False)]:
        for k in outs[("xla", True)]:
            np.testing.assert_allclose(outs[key][k], outs[("xla", True)][k],
                                       rtol=1e-4, atol=1e-4,
                                       err_msg=f"{key}/{k}")
    # xla has no pallas launch sites; fused pallas = 1 per scan step with
    # views; unfused = one per bucket/hist view, strictly more here
    assert stats[("xla", True)].n_kernel_launches == 0
    n_fused = stats[("pallas", True)].n_kernel_launches
    n_unfused = stats[("pallas", False)].n_kernel_launches
    assert 0 < n_fused < n_unfused
    assert n_fused <= stats[("pallas", True)].n_scan_steps


def test_fused_kernels_match_unfused_tree_frontier():
    """Frontier-batched node-histogram batch (the tree workload) under
    launch fusion: batched hists ride the same fused launch."""
    from repro.ml.trees import DecisionTree, stack_mask_params
    import repro
    ds = D.make("favorita", scale=0.02)
    rng = np.random.default_rng(11)
    outs, stats = {}, {}
    for key, cfg in {
            ("pallas", True): repro.ExecutionConfig(backend="pallas"),
            ("pallas", False): repro.ExecutionConfig(backend="pallas",
                                                     fuse_kernels=False),
            ("xla", True): repro.ExecutionConfig(backend="xla")}.items():
        dt = DecisionTree(ds, task="regression", max_depth=2,
                          min_instances=10, max_nodes=7, node_batch=True,
                          config=cfg)
        masks = [{f.attr: np.ones(f.domain, np.float32)
                  for f in dt.features} for _ in range(4)]
        out = dt.batch.run_batched(ds.db, stack_mask_params(dt.features,
                                                            masks))
        outs[key] = {k: np.asarray(v, np.float64) for k, v in out.items()}
        stats[key] = dt.batch.stats
    for key in [("pallas", True), ("pallas", False)]:
        for k in outs[("xla", True)]:
            np.testing.assert_allclose(outs[key][k], outs[("xla", True)][k],
                                       rtol=1e-4, atol=1e-4,
                                       err_msg=f"{key}/{k}")
    assert stats[("xla", True)].n_kernel_launches == 0
    assert (0 < stats[("pallas", True)].n_kernel_launches
            < stats[("pallas", False)].n_kernel_launches)


def test_block_rows_threads_through_config():
    """block_rows reaches the pallas lowering via PlanConfig (no more
    backend class attribute) and any aligned value gives the same answer."""
    ds, qs = _ridge_setup()
    outs = []
    for br in (128, 512):
        eng = Engine(ds.schema, edges=ds.edges, sizes=ds.db.sizes())
        batch = eng.compile(qs, backend="pallas", block_rows=br)
        assert batch.plan.config.block_rows == br
        outs.append({k: np.asarray(v, np.float64)
                     for k, v in batch(ds.db).items()})
    for k in outs[0]:
        np.testing.assert_allclose(outs[0][k], outs[1][k],
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bad", [0, -8, 7, 129, "biggish"])
def test_invalid_block_rows_rejected(bad):
    import repro
    with pytest.raises(ValueError, match="multiple of 8|block_rows"):
        repro.ExecutionConfig(backend="pallas", block_rows=bad)


@pytest.mark.parametrize("bad", [0, -1, "large"])
def test_invalid_block_size_rejected(bad):
    import repro
    with pytest.raises(ValueError, match="block_size"):
        repro.ExecutionConfig(block_size=bad)


def test_autotuned_blocking_smoke(tmp_path):
    """block_size="auto" resolves per-step blockings at bind time, records
    them in plan.last_autotune, and matches the xla reference."""
    ds, qs = _ridge_setup()
    cache = str(tmp_path / "autotune.json")
    eng = Engine(ds.schema, edges=ds.edges, sizes=ds.db.sizes())
    batch = eng.compile(qs, backend="pallas", block_size="auto",
                        block_rows="auto", autotune_cache=cache)
    out = {k: np.asarray(v, np.float64) for k, v in batch(ds.db).items()}
    rep = batch.plan.last_autotune
    assert rep and all(isinstance(r["block_size"], int)
                       and r["block_rows"] % 8 == 0 for r in rep)
    eng2 = Engine(ds.schema, edges=ds.edges, sizes=ds.db.sizes())
    ref_out = eng2.compile(qs, backend="xla")(ds.db)
    for k in out:
        np.testing.assert_allclose(out[k], np.asarray(ref_out[k], np.float64),
                                   rtol=1e-4, atol=1e-4)
