"""Lowering-backend equivalence: ``backend="pallas"`` (interpret mode on CPU)
must produce the same results as the default ``backend="xla"`` path across
the example workload batches (ridge covar and decision-tree node batches),
and the Pallas hist fast path must actually engage for the tree batch."""

import numpy as np
import pytest

from repro.core import COUNT, Engine, agg, query, schema, sum_of
from repro.core.aggregates import Delta, Lambda, Pow, Var
from repro.data import datasets as D
from repro.data import from_numpy


def _run_both(S_or_ds, queries, **compile_kw):
    if hasattr(S_or_ds, "db"):
        ds = S_or_ds
        db, edges = ds.db, ds.edges
        eng_kw = dict(edges=edges, sizes=db.sizes())
        Ssch = ds.schema
    else:
        Ssch, db = S_or_ds
        eng_kw = dict(sizes=db.sizes())
    outs = {}
    for be in ("xla", "pallas"):
        eng = Engine(Ssch, **eng_kw)
        batch = eng.compile(queries, backend=be, **compile_kw)
        outs[be] = {k: np.asarray(v, np.float64)
                    for k, v in batch(db).items()}
    return outs


def _assert_equal(outs):
    assert outs["xla"].keys() == outs["pallas"].keys()
    for k in outs["xla"]:
        np.testing.assert_allclose(outs["pallas"][k], outs["xla"][k],
                                   rtol=1e-4, atol=1e-4, err_msg=k)


def test_pallas_matches_xla_chain_batch():
    S = schema(
        [("x1", "categorical", 3), ("x2", "key", 4), ("x3", "key", 5),
         ("x4", "categorical", 3), ("u", "continuous", 0)],
        [("R1", ["x1", "x2"]), ("R2", ["x2", "x3", "u"]), ("R3", ["x3", "x4"])])
    rng = np.random.default_rng(3)
    T = {"R1": {"x1": rng.integers(0, 3, 21), "x2": rng.integers(0, 4, 21)},
         "R2": {"x2": rng.integers(0, 4, 33), "x3": rng.integers(0, 5, 33),
                "u": rng.normal(size=33).astype(np.float32)},
         "R3": {"x3": rng.integers(0, 5, 11), "x4": rng.integers(0, 3, 11)}}
    queries = [
        query("q_count", [], [COUNT]),
        query("q_sums", [], [sum_of("u"), agg(Pow("u", 2))]),
        query("q_g", ["x1", "x4"], [COUNT, sum_of("u")]),
        query("q_delta", ["x4"], [agg(Var("u"), Delta("x1", "==", 1))]),
    ]
    _assert_equal(_run_both((S, from_numpy(S, T)), queries, block_size=16))


def test_pallas_matches_xla_ridge_batch():
    from repro.ml.covar import covar_queries
    ds = D.make("retailer", scale=0.02)
    qs, _ = covar_queries(ds)
    _assert_equal(_run_both(ds, qs))


def test_pallas_matches_xla_tree_batch():
    from repro.ml.trees import DecisionTree
    ds = D.make("favorita", scale=0.02)
    masks = None
    outs = {}
    for be in ("xla", "pallas"):
        # node_batch=False: the single-node hist fast path (the batched
        # variant is covered by tests/test_frontier.py)
        dt = DecisionTree(ds, task="regression", max_depth=1, min_instances=10,
                          max_nodes=3, backend=be, node_batch=False)
        if masks is None:
            masks = {f"mask_{f.attr}": np.ones(f.domain, dtype=np.float32)
                     for f in dt.features}
        if be == "pallas":
            # the node-histogram pattern must route through tree_hist
            nhist = sum(1 for sp in dt.batch.plan.step_programs
                        for vp in sp.views if vp.hist is not None)
            assert nhist > 0
        outs[be] = {k: np.asarray(v, np.float64)
                    for k, v in dt.batch(ds.db, params=masks).items()}
    _assert_equal(outs)


def test_pallas_matches_xla_dynamic_params():
    """Dynamic UDAF params (decision-tree thresholds) stay recompile-free and
    equivalent on the Pallas path."""
    from repro.core.aggregates import Param
    S = schema([("k", "key", 6), ("c", "categorical", 4), ("u", "continuous", 0)],
               [("F", ["k", "u"]), ("D", ["k", "c"])])
    rng = np.random.default_rng(5)
    n = 257
    T = {"F": {"k": rng.integers(0, 6, n),
               "u": rng.normal(size=n).astype(np.float32)},
         "D": {"k": np.arange(6), "c": rng.integers(0, 4, 6)}}
    db = from_numpy(S, T)
    q = query("qd", ["c"], [agg(Var("u"), Delta("c", "==", Param("t")))])
    for be in ("xla", "pallas"):
        eng = Engine(S, sizes=db.sizes())
        batch = eng.compile([q], backend=be, block_size=64)
        o1 = np.asarray(batch(db, params={"t": np.int32(1)})["qd"])
        o2 = np.asarray(batch(db, params={"t": np.int32(2)})["qd"])
        assert len(batch._jitted) == 1
        if be == "xla":
            ref1, ref2 = o1, o2
        else:
            np.testing.assert_allclose(o1, ref1, rtol=1e-4, atol=1e-4)
            np.testing.assert_allclose(o2, ref2, rtol=1e-4, atol=1e-4)


def test_unknown_backend_rejected():
    from repro.core.lowering import get_backend
    with pytest.raises(ValueError):
        get_backend("cuda")
