"""Distributed semantics on forced host devices (subprocess isolation so the
main pytest process keeps a single device)."""

import pytest


def test_engine_sharded_matches_local(subproc):
    subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import Engine, schema, query, COUNT, sum_of, agg, Pow
from repro.data import from_numpy
rng = np.random.default_rng(1)
S = schema([("k","key",16),("c","categorical",5),("u","continuous",0)],
           [("F",["k","u"]),("D",["k","c"])])
n = 1003
T = {"F": {"k": rng.integers(0,16,n), "u": rng.normal(size=n).astype(np.float32)},
     "D": {"k": np.arange(16), "c": rng.integers(0,5,16)}}
db = from_numpy(S, T)
eng = Engine(S, sizes=db.sizes())
batch = eng.compile([query("byc", ["c"], [COUNT, sum_of("u"), agg(Pow("u",2))])],
                    block_size=64)
local = batch(db)
mesh = jax.make_mesh((8,), ("data",))
shard = batch.run_sharded(db, mesh)
for k in local:
    assert np.allclose(local[k], shard[k], rtol=1e-4, atol=1e-4)
print("OK")
""", n_devices=8)


def test_engine_sharded_matches_local_1xN_mesh(subproc):
    """run_sharded on a (1, N) mesh (data axis second) must equal the local
    __call__ — covers the fused-scan schedule under shard_map + psum."""
    subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import Engine, schema, query, COUNT, sum_of, agg, Pow
from repro.data import from_numpy
rng = np.random.default_rng(7)
S = schema([("k","key",16),("c","categorical",5),("u","continuous",0)],
           [("F",["k","u"]),("D",["k","c"])])
n = 517
T = {"F": {"k": rng.integers(0,16,n), "u": rng.normal(size=n).astype(np.float32)},
     "D": {"k": np.arange(16), "c": rng.integers(0,5,16)}}
db = from_numpy(S, T)
eng = Engine(S, sizes=db.sizes())
batch = eng.compile([query("byc", ["c"], [COUNT, sum_of("u"), agg(Pow("u",2))]),
                     query("tot", [], [COUNT, sum_of("u")])],
                    block_size=32)
local = batch(db)
mesh = jax.make_mesh((1, 4), ("model", "data"))
shard = batch.run_sharded(db, mesh, axis="data")
for k in local:
    assert np.allclose(local[k], shard[k], rtol=1e-4, atol=1e-4), k
print("OK")
""", n_devices=4)


@pytest.mark.slow
def test_train_step_parity_1_vs_8_devices(subproc):
    """Same global batch, same init -> same loss/params on a (2,4) mesh as on
    one device (elastic scaling correctness)."""
    subproc("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding
from repro import configs
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.train.step import (TrainConfig, init_state, make_train_step,
                              state_pspecs, batch_pspecs)
from repro.train import adamw

cfg = configs.get_smoke("internlm2-1.8b")
tcfg = TrainConfig(peak_lr=1e-2, warmup=2, total_steps=10, ce_chunk=8,
                   attn_impl="dense")
pipe = TokenPipeline(PipelineConfig(8, 16, cfg.vocab, seed=0), cfg)
batch = pipe.batch_at(0)
state = init_state(cfg, tcfg, jax.random.PRNGKey(0))

# single-device reference
s1, m1 = jax.jit(make_train_step(cfg, tcfg))(jax.tree.map(jnp.copy, state), batch)

mesh = jax.make_mesh((2, 4), ("data", "model"))
sspec = jax.tree.map(lambda s: NamedSharding(mesh, s), state_pspecs(cfg, tcfg, mesh))
bspec = jax.tree.map(lambda s: NamedSharding(mesh, s), batch_pspecs(cfg, mesh))
step8 = jax.jit(make_train_step(cfg, tcfg, mesh), in_shardings=(sspec, bspec))
s8, m8 = step8(jax.tree.map(jnp.copy, state), batch)

assert abs(float(m1["loss"]) - float(m8["loss"])) < 1e-3, (m1["loss"], m8["loss"])
d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s8["params"])))
assert d < 5e-3, d
print("OK", float(m1["loss"]), float(m8["loss"]))
""", n_devices=8)


@pytest.mark.slow
def test_serve_step_sharded_decode(subproc):
    """Decode with a context-parallel (seq-sharded) cache matches the
    single-device decode."""
    subproc("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.models import model as M
from repro.models.layers import init_params
from repro.distributed.sharding import param_pspecs, rules_for
from repro.serve.engine import make_serve_step

cfg = configs.get_smoke("llama3-8b")
B, S = 2, 16
params = init_params(M.model_specs(cfg), jax.random.PRNGKey(0), cfg.jdtype)
pipe = TokenPipeline(PipelineConfig(B, S, cfg.vocab, seed=1), cfg)
batch = pipe.batch_at(0)
cache = init_params(M.cache_specs(cfg, B, S), jax.random.PRNGKey(0), cfg.jdtype)

ref_step = jax.jit(make_serve_step(cfg))
mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = rules_for(mesh)
psh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                   param_pspecs(M.model_specs(cfg), rules, mesh))
csh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                   param_pspecs(M.cache_specs(cfg, B, S), rules, mesh))
sh_step = jax.jit(make_serve_step(cfg, mesh),
                  in_shardings=(psh, csh, NamedSharding(mesh, P(("data",))),
                                NamedSharding(mesh, P())))
c1, c2 = cache, jax.device_put(cache, csh)
p2 = jax.device_put(params, psh)
for pos in range(4):
    toks = batch["tokens"][:, pos:pos+1]
    l1, c1 = ref_step(params, c1, toks, jnp.asarray(pos, jnp.int32))
    l2, c2 = sh_step(p2, c2, jax.device_put(toks, NamedSharding(mesh, P(("data",)))),
                     jnp.asarray(pos, jnp.int32))
    assert np.allclose(np.asarray(l1, np.float32), np.asarray(l2, np.float32),
                       rtol=5e-3, atol=5e-3), pos
print("OK")
""", n_devices=8)


def test_compression_error_feedback_bounded():
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.distributed.compression import compress_decompress
    g = {"a": jnp.asarray(np.linspace(-1, 1, 100), jnp.float32)}
    ef = {"a": jnp.zeros(100)}
    total = jnp.zeros(100)
    exact = jnp.zeros(100)
    for _ in range(10):
        dq, ef = compress_decompress(g, ef)
        total = total + dq["a"]
        exact = exact + g["a"]
    # error feedback: accumulated quantized sum tracks the exact sum
    assert float(jnp.max(jnp.abs(total - exact))) < 0.05
