"""Quickstart: a batch of group-by aggregates over a join, the LMFAO way.

    PYTHONPATH=src python examples/quickstart.py

Builds a small Favorita-like database (6 relations, star schema — paper
Fig. 3), declares a batch of aggregate queries in the paper's Q(F; α) form,
compiles it through the engine's layers (join tree -> roots -> directional
views -> merging -> view groups -> multi-output jit plans), and runs it.
"""

import numpy as np

from repro.core import COUNT, Delta, Engine, Var, agg, query, sum_of, sum_prod
from repro.data import datasets as D


def main():
    ds = D.make("favorita", scale=0.1)
    print(f"database: {ds.db.total_tuples():,} tuples across "
          f"{len(ds.tables)} relations")

    queries = [
        # Q1: total units sold (paper Example 3.1 shape)
        query("total_units", [], [sum_of("units")]),
        # Q2: per-family oil-price-weighted sales (Example 3.2 shape)
        query("by_family", ["family"], [COUNT, sum_of("units"),
                                        sum_prod("units", "price")]),
        # Q3: covar-style entries (eq. 2-4)
        query("cm_units_txns", [], [sum_prod("units", "txns")]),
        query("cm_by_city", ["city"], [sum_of("units")]),
        query("cm_city_family", ["city", "family"], [COUNT]),
        # Q4: a decision-tree-node aggregate (eq. 8): promo items only
        query("rt_node", [], [agg(Delta("promo", "==", 1)),
                              agg(Var("units"), Delta("promo", "==", 1))]),
    ]

    eng = Engine(ds.schema, edges=ds.edges, sizes=ds.db.sizes())
    batch = eng.compile(queries)
    print("layer stats:", batch.stats.summary())
    print("roots:", batch.stats.roots)

    out = batch(ds.db)
    print(f"total_units = {float(out['total_units'][0]):,.0f}")
    bf = np.asarray(out["by_family"])
    print(f"by_family: {bf.shape[0]} families; "
          f"busiest family sold {bf[:, 1].max():,.0f} units")
    print(f"covar(units, txns) = {float(out['cm_units_txns'][0]):,.0f}")
    print(f"promo rows = {float(out['rt_node'][..., 0]):,.0f}, "
          f"promo units = {float(out['rt_node'][..., 1]):,.0f}")


if __name__ == "__main__":
    main()
